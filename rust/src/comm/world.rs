//! In-process collectives between the K worker threads.
//!
//! All methods are *collective*: every rank must call the same method in
//! the same order (lockstep), as with MPI/NCCL. Data really moves (the
//! numerics of distributed training are exact); time is charged separately
//! through [`super::CostModel`] by the coordinator.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

/// Byte counters per collective, for reporting and model cross-checks.
#[derive(Debug, Default)]
pub struct CommStats {
    pub all_gather_bytes: AtomicU64,
    pub all_reduce_bytes: AtomicU64,
    pub broadcast_bytes: AtomicU64,
    pub ops: AtomicU64,
}

impl CommStats {
    pub fn snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.all_gather_bytes.load(Ordering::Relaxed),
            self.all_reduce_bytes.load(Ordering::Relaxed),
            self.broadcast_bytes.load(Ordering::Relaxed),
            self.ops.load(Ordering::Relaxed),
        )
    }
}

pub struct CommWorld {
    k: usize,
    barrier: Barrier,
    /// per-rank input slots
    slots: Vec<Mutex<Vec<f32>>>,
    /// per-chunk reduction outputs (chunk c owned by rank c)
    chunks: Vec<Mutex<Vec<f32>>>,
    pub stats: CommStats,
}

impl CommWorld {
    pub fn new(k: usize) -> Arc<Self> {
        assert!(k > 0);
        Arc::new(Self {
            k,
            barrier: Barrier::new(k),
            slots: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            chunks: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            stats: CommStats::default(),
        })
    }

    pub fn world_size(&self) -> usize {
        self.k
    }

    pub fn handle(self: &Arc<Self>, rank: usize) -> WorkerComm {
        assert!(rank < self.k);
        WorkerComm { world: Arc::clone(self), rank }
    }
}

/// Per-worker handle to the collective world.
pub struct WorkerComm {
    world: Arc<CommWorld>,
    rank: usize,
}

impl WorkerComm {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.world.k
    }

    pub fn barrier(&self) {
        self.world.barrier.wait();
    }

    /// Concatenate every rank's `data` in rank order. All ranks must pass
    /// equal-length slices.
    pub fn all_gather(&self, data: &[f32]) -> Vec<f32> {
        let w = &self.world;
        if w.k == 1 {
            return data.to_vec();
        }
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
        }
        w.stats.all_gather_bytes.fetch_add((data.len() * 4) as u64, Ordering::Relaxed);
        w.stats.ops.fetch_add(1, Ordering::Relaxed);
        self.barrier();
        let mut out = Vec::with_capacity(data.len() * w.k);
        for r in 0..w.k {
            out.extend_from_slice(&w.slots[r].lock().unwrap());
        }
        self.barrier(); // slots free for reuse
        out
    }

    /// Element-wise SUM across ranks, result replicated into `buf`.
    /// Implemented reduce-scatter + all-gather style: rank r reduces chunk
    /// r so the reduction parallelizes across workers (O(n) per rank).
    pub fn all_reduce_sum(&self, buf: &mut [f32]) {
        let w = &self.world;
        if w.k == 1 {
            return;
        }
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(buf);
        }
        w.stats.all_reduce_bytes.fetch_add((buf.len() * 4) as u64, Ordering::Relaxed);
        w.stats.ops.fetch_add(1, Ordering::Relaxed);
        self.barrier();

        let n = buf.len();
        let chunk = n.div_ceil(w.k);
        let lo = (self.rank * chunk).min(n);
        let hi = ((self.rank + 1) * chunk).min(n);
        {
            let mut acc = vec![0.0f32; hi - lo];
            for r in 0..w.k {
                let slot = w.slots[r].lock().unwrap();
                for (a, v) in acc.iter_mut().zip(&slot[lo..hi]) {
                    *a += v;
                }
            }
            let mut out = w.chunks[self.rank].lock().unwrap();
            *out = acc;
        }
        self.barrier();
        for r in 0..w.k {
            let lo_r = (r * chunk).min(n);
            let hi_r = ((r + 1) * chunk).min(n);
            let part = w.chunks[r].lock().unwrap();
            buf[lo_r..hi_r].copy_from_slice(&part);
        }
        self.barrier();
    }

    /// Mean across ranks (sum then scale).
    pub fn all_reduce_mean(&self, buf: &mut [f32]) {
        self.all_reduce_sum(buf);
        let inv = 1.0 / self.world.k as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
    }

    /// Copy `root`'s buffer to every rank.
    pub fn broadcast(&self, buf: &mut [f32], root: usize) {
        let w = &self.world;
        if w.k == 1 {
            return;
        }
        if self.rank == root {
            let mut slot = w.slots[root].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(buf);
            w.stats.broadcast_bytes.fetch_add((buf.len() * 4) as u64, Ordering::Relaxed);
            w.stats.ops.fetch_add(1, Ordering::Relaxed);
        }
        self.barrier();
        if self.rank != root {
            let slot = w.slots[root].lock().unwrap();
            buf.copy_from_slice(&slot);
        }
        self.barrier();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_workers<F>(k: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(WorkerComm) -> Vec<f32> + Send + Sync + 'static,
    {
        let world = CommWorld::new(k);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..k)
            .map(|r| {
                let h = world.handle(r);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(h))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_orders_by_rank() {
        for k in [1, 2, 4, 7] {
            let outs = run_workers(k, move |c| {
                let mine = vec![c.rank() as f32; 3];
                c.all_gather(&mine)
            });
            let expect: Vec<f32> =
                (0..k).flat_map(|r| std::iter::repeat(r as f32).take(3)).collect();
            for o in outs {
                assert_eq!(o, expect);
            }
        }
    }

    #[test]
    fn all_reduce_sum_correct() {
        for k in [1, 2, 3, 8] {
            let n = 1000; // exercises uneven chunking for k=3
            let outs = run_workers(k, move |c| {
                let mut buf: Vec<f32> =
                    (0..n).map(|i| (i as f32) + c.rank() as f32).collect();
                c.all_reduce_sum(&mut buf);
                buf
            });
            let rank_sum: f32 = (0..k).map(|r| r as f32).sum();
            for o in &outs {
                for (i, v) in o.iter().enumerate() {
                    let want = k as f32 * i as f32 + rank_sum;
                    assert!((v - want).abs() < 1e-3, "k={k} i={i} {v} vs {want}");
                }
            }
        }
    }

    #[test]
    fn all_reduce_mean_correct() {
        let outs = run_workers(4, |c| {
            let mut buf = vec![c.rank() as f32; 5];
            c.all_reduce_mean(&mut buf);
            buf
        });
        for o in outs {
            for v in o {
                assert!((v - 1.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let outs = run_workers(4, |c| {
            let mut buf = if c.rank() == 2 { vec![7.0; 4] } else { vec![0.0; 4] };
            c.broadcast(&mut buf, 2);
            buf
        });
        for o in outs {
            assert_eq!(o, vec![7.0; 4]);
        }
    }

    #[test]
    fn repeated_collectives_no_deadlock() {
        let outs = run_workers(3, |c| {
            let mut acc = vec![0.0f32; 2];
            for it in 0..50 {
                let g = c.all_gather(&[it as f32, c.rank() as f32]);
                acc[0] += g.iter().sum::<f32>();
                let mut buf = vec![1.0f32; 2];
                c.all_reduce_sum(&mut buf);
                acc[1] += buf[0];
            }
            acc
        });
        for o in &outs {
            assert_eq!(o, &outs[0]);
        }
    }

    #[test]
    fn stats_accumulate() {
        let world = CommWorld::new(2);
        let h0 = world.handle(0);
        let h1 = world.handle(1);
        let t = std::thread::spawn(move || {
            h1.all_gather(&[1.0; 8]);
        });
        h0.all_gather(&[2.0; 8]);
        t.join().unwrap();
        let (ag, _, _, ops) = world.stats.snapshot();
        assert_eq!(ag, 2 * 8 * 4);
        assert_eq!(ops, 2);
    }
}
