//! Embedding-table encoder forward/backward — the native model's towers
//! (DESIGN.md §10).
//!
//! The native backend trades the artifact bundle's transformer towers for
//! a deliberately small, exactly-differentiable pair of encoders over the
//! *same* interface shapes:
//!
//! * **image**: mean over patches, then a linear projection —
//!   `pooled_i = mean_p(x_{i,p}) · W_v + b_v`, `W_v: (v_patch_dim, d)`;
//! * **text**: token-embedding-table mean —
//!   `pooled_i = mean_l(T[tok_{i,l}]) + b_t`, `T: (t_vocab, d)`.
//!
//! Both are followed by the shared row L2-normalize
//! ([`super::norm`]). The backward passes are exact transposes: the image
//! side is a [`super::gemm::matmul_at_b`] weight gradient, the text side
//! a deterministic scatter-add into the table (tokens walked in ascending
//! (sample, position) order — order-independent parallelism is never
//! attempted, so gradients are bitwise stable at any thread count).

use super::gemm::{col_sums, matmul, matmul_at_b};

/// Mean over patches: images `(bl, v_patches, v_patch_dim)` row-major →
/// `xbar (bl, v_patch_dim)`, each patch feature averaged in ascending
/// patch order.
pub fn patch_mean(images: &[f32], bl: usize, v_patches: usize, v_patch_dim: usize) -> Vec<f32> {
    assert_eq!(images.len(), bl * v_patches * v_patch_dim);
    let mut xbar = vec![0.0f32; bl * v_patch_dim];
    let inv = 1.0 / v_patches as f32;
    for i in 0..bl {
        let out = &mut xbar[i * v_patch_dim..(i + 1) * v_patch_dim];
        for p in 0..v_patches {
            let at = (i * v_patches + p) * v_patch_dim;
            let patch = &images[at..at + v_patch_dim];
            for (o, v) in out.iter_mut().zip(patch) {
                *o += *v;
            }
        }
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
    xbar
}

/// Image forward: `pooled = xbar · W + b`, `W (pd, d)` row-major.
pub fn image_fwd(
    w: &[f32],
    bias: &[f32],
    xbar: &[f32],
    bl: usize,
    pd: usize,
    d: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(w.len(), pd * d);
    assert_eq!(bias.len(), d);
    assert_eq!(xbar.len(), bl * pd);
    let mut pooled = vec![0.0f32; bl * d];
    matmul(xbar, w, &mut pooled, bl, pd, d, threads);
    for row in pooled.chunks_mut(d) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += *b;
        }
    }
    pooled
}

/// Image backward: given `dpooled (bl, d)`, returns
/// `(dW = xbarᵀ·dpooled, db = column sums of dpooled)`.
pub fn image_bwd(
    xbar: &[f32],
    dpooled: &[f32],
    bl: usize,
    pd: usize,
    d: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(xbar.len(), bl * pd);
    assert_eq!(dpooled.len(), bl * d);
    let mut dw = vec![0.0f32; pd * d];
    matmul_at_b(xbar, dpooled, &mut dw, bl, pd, d, threads);
    let mut db = vec![0.0f32; d];
    col_sums(dpooled, bl, d, &mut db);
    (dw, db)
}

/// Text forward: `pooled_i = (1/L)·Σ_l T[tok_{i,l}] + b_t`, tokens walked
/// in ascending position order.
pub fn text_fwd(
    table: &[f32],
    bias: &[f32],
    texts: &[i32],
    bl: usize,
    t_len: usize,
    vocab: usize,
    d: usize,
) -> Vec<f32> {
    assert_eq!(table.len(), vocab * d);
    assert_eq!(bias.len(), d);
    assert_eq!(texts.len(), bl * t_len);
    let inv = 1.0 / t_len as f32;
    let mut pooled = vec![0.0f32; bl * d];
    for i in 0..bl {
        let out = &mut pooled[i * d..(i + 1) * d];
        for l in 0..t_len {
            let tok = texts[i * t_len + l] as usize;
            debug_assert!(tok < vocab, "token {tok} out of vocab {vocab}");
            let row = &table[tok * d..(tok + 1) * d];
            for (o, v) in out.iter_mut().zip(row) {
                *o += *v;
            }
        }
        for (o, b) in out.iter_mut().zip(bias) {
            *o = *o * inv + *b;
        }
    }
    pooled
}

/// Text backward: scatter-add `dT[tok_{i,l}] += (1/L)·dpooled_i` in
/// ascending (i, l) order (deterministic by construction), plus the bias
/// gradient `db = column sums of dpooled`. Returns `(dTable, db)`.
pub fn text_bwd(
    texts: &[i32],
    dpooled: &[f32],
    bl: usize,
    t_len: usize,
    vocab: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(texts.len(), bl * t_len);
    assert_eq!(dpooled.len(), bl * d);
    let inv = 1.0 / t_len as f32;
    let mut dtable = vec![0.0f32; vocab * d];
    for i in 0..bl {
        let drow = &dpooled[i * d..(i + 1) * d];
        for l in 0..t_len {
            let tok = texts[i * t_len + l] as usize;
            let out = &mut dtable[tok * d..(tok + 1) * d];
            for (o, v) in out.iter_mut().zip(drow) {
                *o += inv * *v;
            }
        }
    }
    let mut db = vec![0.0f32; d];
    col_sums(dpooled, bl, d, &mut db);
    (dtable, db)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn patch_mean_averages() {
        // 1 sample, 2 patches of dim 2: mean([[1,2],[3,4]]) = [2,3]
        let images = [1.0f32, 2.0, 3.0, 4.0];
        let xbar = patch_mean(&images, 1, 2, 2);
        assert_eq!(xbar, vec![2.0, 3.0]);
    }

    #[test]
    fn image_fwd_bwd_finite_difference() {
        let (bl, pd, d) = (3usize, 4usize, 5usize);
        let xbar = randn(bl * pd, 40);
        let w = randn(pd * d, 41);
        let bias = randn(d, 42);
        let cot = randn(bl * d, 43);
        let value = |w_: &[f32], b_: &[f32]| -> f64 {
            let p = image_fwd(w_, b_, &xbar, bl, pd, d, 1);
            p.iter().zip(&cot).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let (dw, db) = image_bwd(&xbar, &cot, bl, pd, d, 1);
        let h = 1e-3f32;
        for idx in [0usize, 7, pd * d - 1] {
            let mut wp = w.clone();
            let mut wm = w.clone();
            wp[idx] += h;
            wm[idx] -= h;
            let num = (value(&wp, &bias) - value(&wm, &bias)) / (2.0 * h as f64);
            assert!((num - dw[idx] as f64).abs() < 1e-2 * num.abs().max(1.0), "dw[{idx}]");
        }
        for idx in 0..d {
            let mut bp = bias.clone();
            let mut bm = bias.clone();
            bp[idx] += h;
            bm[idx] -= h;
            let num = (value(&w, &bp) - value(&w, &bm)) / (2.0 * h as f64);
            assert!((num - db[idx] as f64).abs() < 1e-2 * num.abs().max(1.0), "db[{idx}]");
        }
    }

    #[test]
    fn text_fwd_bwd_finite_difference() {
        let (bl, t_len, vocab, d) = (3usize, 4usize, 7usize, 5usize);
        let table = randn(vocab * d, 50);
        let bias = randn(d, 51);
        let mut rng = Rng::new(52);
        let texts: Vec<i32> = (0..bl * t_len).map(|_| rng.below(vocab) as i32).collect();
        let cot = randn(bl * d, 53);
        let value = |t_: &[f32], b_: &[f32]| -> f64 {
            let p = text_fwd(t_, b_, &texts, bl, t_len, vocab, d);
            p.iter().zip(&cot).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let (dt, db) = text_bwd(&texts, &cot, bl, t_len, vocab, d);
        let h = 1e-3f32;
        for idx in [0usize, 11, vocab * d - 1] {
            let mut tp = table.clone();
            let mut tm = table.clone();
            tp[idx] += h;
            tm[idx] -= h;
            let num = (value(&tp, &bias) - value(&tm, &bias)) / (2.0 * h as f64);
            assert!(
                (num - dt[idx] as f64).abs() < 1e-2 * num.abs().max(1.0) + 1e-6,
                "dt[{idx}] {num} vs {}",
                dt[idx]
            );
        }
        for idx in 0..d {
            let mut bp = bias.clone();
            let mut bm = bias.clone();
            bp[idx] += h;
            bm[idx] -= h;
            let num = (value(&table, &bp) - value(&table, &bm)) / (2.0 * h as f64);
            assert!((num - db[idx] as f64).abs() < 1e-2 * num.abs().max(1.0), "db[{idx}]");
        }
    }

    #[test]
    fn text_unused_tokens_get_zero_grad() {
        let (bl, t_len, vocab, d) = (1usize, 2usize, 5usize, 3usize);
        let texts = [1i32, 3];
        let dpooled = [1.0f32, 1.0, 1.0];
        let (dt, _) = text_bwd(&texts, &dpooled, bl, t_len, vocab, d);
        assert!(dt[0..d].iter().all(|v| *v == 0.0), "token 0 untouched");
        assert!(dt[d..2 * d].iter().all(|v| *v == 0.5), "token 1 gets 1/L");
        assert!(dt[2 * d..3 * d].iter().all(|v| *v == 0.0), "token 2 untouched");
    }
}
