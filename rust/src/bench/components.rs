//! Component-study experiments — §5.1 of the paper:
//! * `table3` — inner-LR (γ) schedule: constant vs cosine, three pairs;
//! * `table4` — temperature update rules: FastCLIP-v0..v3;
//! * `table5` — optimizers: SGDM / LAMB / Lion / AdamW on FastCLIP-v3.
//!
//! Each runner prints the paper-shaped rows (mean (std) over seeds) and
//! writes CSV + JSON under `results/`.

use anyhow::Result;

use crate::config::{Algorithm, GammaSchedule, OptimizerKind};
use crate::output::{mean_std_cell, Table};
use crate::util::{Args, Json};

use super::common::{algo_config, apply_overrides, results_dir, run_seeds, scores, Setting};

fn settings_from(args: &Args) -> Result<Vec<Setting>> {
    match args.get("setting") {
        Some("all") => Ok(vec![Setting::Medium, Setting::Large]),
        Some(s) => Ok(vec![Setting::from_id(s)?]),
        None => Ok(vec![Setting::Medium]),
    }
}

/// Table 3 / Fig. 8: constant γ vs cosine γ, three algorithm pairs.
pub fn table3(args: &Args) -> Result<()> {
    let mut table = Table::new(
        "Table 3 — inner LR schedule (constant vs cosine gamma)",
        &["Setting", "Algorithm", "Schedule", "Datacomp", "Retrieval", "IN&Var"],
    );
    let mut json_rows = Vec::new();
    for setting in settings_from(args)? {
        // (label, base algorithm, override-to-constant?)
        let pairs: [(&str, Algorithm, bool); 6] = [
            ("SogCLR", Algorithm::SogClr, false),
            ("FastCLIP-v1", Algorithm::FastClipV1, false),
            ("iSogCLR", Algorithm::ISogClr, false),
            ("FastCLIP-v2", Algorithm::FastClipV2, false),
            ("v3 (Const. gamma)", Algorithm::FastClipV3, true),
            ("FastCLIP-v3", Algorithm::FastClipV3, false),
        ];
        for (label, algo, force_const) in pairs {
            let mut cfg = algo_config(setting, algo);
            if force_const {
                cfg.gamma = GammaSchedule::Constant { gamma: 0.6 };
            }
            let seeds = apply_overrides(&mut cfg, args)?;
            let results = run_seeds(&cfg, &seeds, label)?;
            let s = scores(&results);
            let schedule = match cfg.gamma {
                GammaSchedule::Constant { .. } => "constant",
                GammaSchedule::Cosine { .. } => "cosine",
            };
            table.row(vec![
                setting.name().into(),
                label.into(),
                schedule.into(),
                mean_std_cell(&s.datacomp),
                mean_std_cell(&s.retrieval),
                mean_std_cell(&s.in_variants),
            ]);
            json_rows.push(result_json(setting, label, schedule, &s));
        }
    }
    finish(args, "table3", table, json_rows)
}

/// Table 4 / Fig. 9(a,b): temperature update rules v0–v3.
pub fn table4(args: &Args) -> Result<()> {
    let mut table = Table::new(
        "Table 4 — temperature parameter updates (FastCLIP-v0..v3)",
        &["Setting", "Algorithm", "Datacomp", "Retrieval", "IN&Var"],
    );
    let mut json_rows = Vec::new();
    for setting in settings_from(args)? {
        for algo in [
            Algorithm::FastClipV0,
            Algorithm::FastClipV1,
            Algorithm::FastClipV2,
            Algorithm::FastClipV3,
        ] {
            let mut cfg = algo_config(setting, algo);
            let seeds = apply_overrides(&mut cfg, args)?;
            let results = run_seeds(&cfg, &seeds, algo.name())?;
            let s = scores(&results);
            table.row(vec![
                setting.name().into(),
                algo.name().into(),
                mean_std_cell(&s.datacomp),
                mean_std_cell(&s.retrieval),
                mean_std_cell(&s.in_variants),
            ]);
            json_rows.push(result_json(setting, algo.name(), "-", &s));
        }
    }
    finish(args, "table4", table, json_rows)
}

/// Table 5 / Fig. 9(c,d): optimizers on FastCLIP-v3.
pub fn table5(args: &Args) -> Result<()> {
    let mut table = Table::new(
        "Table 5 — optimizers (FastCLIP-v3 base)",
        &["Setting", "Optimizer", "Datacomp", "Retrieval", "IN&Var"],
    );
    let mut json_rows = Vec::new();
    for setting in settings_from(args)? {
        for kind in [
            OptimizerKind::Sgdm,
            OptimizerKind::Lamb,
            OptimizerKind::Lion,
            OptimizerKind::AdamW,
        ] {
            let mut cfg = algo_config(setting, Algorithm::FastClipV3);
            cfg.optimizer = crate::config::OptimizerConfig::with_kind(kind);
            // Table 10 tuned (lr, wd) scaled: SGDM needs a far larger lr,
            // Lion a smaller one, than AdamW's peak
            match kind {
                OptimizerKind::Sgdm => {
                    cfg.lr.peak = 1.0;
                    cfg.optimizer.weight_decay = 3e-6;
                }
                OptimizerKind::Lion => {
                    cfg.lr.peak = setting.lion_lr();
                    cfg.optimizer.weight_decay = 0.3;
                }
                OptimizerKind::Lamb => {
                    cfg.lr.peak = 2e-3;
                    cfg.optimizer.weight_decay = 0.1;
                }
                OptimizerKind::AdamW => {}
            }
            let seeds = apply_overrides(&mut cfg, args)?;
            let results = run_seeds(&cfg, &seeds, kind.name())?;
            let s = scores(&results);
            table.row(vec![
                setting.name().into(),
                kind.name().into(),
                mean_std_cell(&s.datacomp),
                mean_std_cell(&s.retrieval),
                mean_std_cell(&s.in_variants),
            ]);
            json_rows.push(result_json(setting, kind.name(), "-", &s));
        }
    }
    finish(args, "table5", table, json_rows)
}

impl Setting {
    fn lion_lr(&self) -> f32 {
        match self {
            Setting::Medium => 2e-4, // Table 10
            _ => 1e-4,
        }
    }
}

fn result_json(setting: Setting, label: &str, extra: &str, s: &super::common::ScoreVecs) -> Json {
    Json::obj(vec![
        ("setting", Json::str(setting.name())),
        ("algorithm", Json::str(label)),
        ("schedule", Json::str(extra)),
        ("datacomp", Json::arr(s.datacomp.iter().map(|&v| Json::num(v as f64)))),
        ("retrieval", Json::arr(s.retrieval.iter().map(|&v| Json::num(v as f64)))),
        ("in_variants", Json::arr(s.in_variants.iter().map(|&v| Json::num(v as f64)))),
    ])
}

fn finish(args: &Args, name: &str, table: Table, rows: Vec<Json>) -> Result<()> {
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join(format!("{name}.csv")))?;
    crate::output::write_result(&dir, name, &Json::arr(rows))?;
    eprintln!("wrote {}/{name}.{{csv,json}}", dir.display());
    Ok(())
}
