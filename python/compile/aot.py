# AOT bridge: lower the L2 training-step graphs to HLO *text* artifacts the
# Rust coordinator loads through PJRT (`xla` crate).
#
# HLO text — NOT `lowered.compile().serialize()` — is the interchange
# format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
# xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
# reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
#
# One bundle per (preset, workers, local-batch):
#   artifacts/<preset>_k<K>_b<bl>/
#     encode.hlo.txt          (params, images, texts) -> (e1, e2)
#     phase_g.hlo.txt         gathered feats + u + gamma -> (g1, g2, u1', u2')
#     step_<variant>.hlo.txt  one per loss family (gcl, gcl_v0, rgcl_i,
#                             rgcl_g, mbcl) -> (grad, loss, tau grads)
#     init_params.bin         f32 LE flat initial parameters (deterministic)
#     manifest.json           shapes, param segmentation, signatures
#
# Python runs ONCE at build time (`make artifacts`); the Rust binary is
# self-contained afterwards.
import argparse
import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import losses
from . import model as model_lib

MANIFEST_VERSION = 1


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args, outs):
    def one(x):
        return {"shape": list(x.shape), "dtype": str(x.dtype)}
    return {"inputs": [dict(name=n, **one(a)) for n, a in args],
            "outputs": [dict(name=n, **one(o)) for n, o in outs]}


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def build_bundle(preset: str, k_workers: int, bl: int, out_dir: str,
                 seed: int = 0, variants=None) -> dict:
    cfg = model_lib.PRESETS[preset]
    bg = k_workers * bl
    p_total = model_lib.n_params(cfg)
    os.makedirs(out_dir, exist_ok=True)
    variants = variants or losses.VARIANTS

    flat_s = _spec((p_total,))
    imgs_s = _spec((bl, cfg.v_patches, cfg.v_patch_dim))
    txts_s = _spec((bl, cfg.t_len), jnp.int32)
    feat_s = _spec((bg, cfg.d_embed))
    uvec_s = _spec((bg,))
    uloc_s = _spec((bl,))
    i32_s = _spec((), jnp.int32)
    f32_s = _spec(())

    executables = {}

    # ---- encode ----------------------------------------------------------
    # keep_unused=True everywhere: the Rust runtime passes every manifest
    # input, so lowering must not prune arguments a variant happens not to
    # use (e.g. rho in step_gcl).
    enc = jax.jit(functools.partial(model_lib.encode, cfg), keep_unused=True)
    lowered = enc.lower(flat_s, imgs_s, txts_s)
    _write(out_dir, "encode", lowered)
    executables["encode"] = _sig(
        [("params", flat_s), ("images", imgs_s), ("texts", txts_s)],
        [("e1", _spec((bl, cfg.d_embed))), ("e2", _spec((bl, cfg.d_embed)))],
    )

    # ---- phase_g (variant-independent; Eq. 1 u update) --------------------
    pg = jax.jit(functools.partial(losses.phase_g, bl=bl), keep_unused=True)
    lowered = pg.lower(feat_s, feat_s, i32_s, uloc_s, uloc_s, uloc_s, uloc_s, f32_s)
    _write(out_dir, "phase_g", lowered)
    executables["phase_g"] = _sig(
        [("e1g", feat_s), ("e2g", feat_s), ("offset", i32_s),
         ("u1", uloc_s), ("u2", uloc_s), ("tau1", uloc_s), ("tau2", uloc_s),
         ("gamma", f32_s)],
        [("g1", uloc_s), ("g2", uloc_s), ("u1_new", uloc_s), ("u2_new", uloc_s)],
    )

    # ---- step_<variant> ----------------------------------------------------
    for variant in variants:
        if variant == "rgcl_i":
            tau_in = [("tau1g", uvec_s), ("tau2g", uvec_s)]
            tau_out = [("tau1_grad", uloc_s), ("tau2_grad", uloc_s)]
        else:
            tau_in = [("tau", f32_s)]
            tau_out = [("tau_grad", f32_s)]

        def fn(flat, images, texts, e1g, e2g, u1g, u2g, offset, eps, rho,
               *taus, _variant=variant):
            out = losses.step(_variant, cfg, flat, images, texts, e1g, e2g,
                              u1g, u2g, tuple(taus), offset, eps, rho,
                              bl=bl, bg=bg, k_workers=k_workers)
            res = [out["grad"], out["loss"]]
            if _variant == "rgcl_i":
                res += [out["tau1_grad"], out["tau2_grad"]]
            else:
                res += [out["tau_grad"]]
            return tuple(res)

        args = [flat_s, imgs_s, txts_s, feat_s, feat_s, uvec_s, uvec_s,
                i32_s, f32_s, f32_s] + [s for _, s in tau_in]
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        _write(out_dir, f"step_{variant}", lowered)
        executables[f"step_{variant}"] = _sig(
            [("params", flat_s), ("images", imgs_s), ("texts", txts_s),
             ("e1g", feat_s), ("e2g", feat_s), ("u1g", uvec_s), ("u2g", uvec_s),
             ("offset", i32_s), ("eps", f32_s), ("rho", f32_s)] + tau_in,
            [("grad", flat_s), ("loss", f32_s)] + tau_out,
        )

    # ---- deterministic initial parameters + manifest ----------------------
    init = model_lib.init_params(cfg, seed)
    init.astype("<f4").tofile(os.path.join(out_dir, "init_params.bin"))

    spec, off = [], 0
    for name, shape in model_lib.param_spec(cfg):
        size = int(np.prod(shape))
        spec.append({"name": name, "shape": list(shape), "offset": off, "size": size})
        off += size

    manifest = {
        "version": MANIFEST_VERSION,
        "preset": preset,
        "model": dataclasses.asdict(cfg),
        "n_params": p_total,
        "param_spec": spec,
        "k_workers": k_workers,
        "local_batch": bl,
        "global_batch": bg,
        "seed": seed,
        "variants": list(variants),
        "executables": executables,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def _write(out_dir, name, lowered):
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="tiny", choices=sorted(model_lib.PRESETS))
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="output dir (default artifacts/<preset>_k<K>_b<bl>)")
    ap.add_argument("--variants", default=None,
                    help="comma-separated subset of " + ",".join(losses.VARIANTS))
    args = ap.parse_args()
    out = args.out or os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts",
        f"{args.preset}_k{args.workers}_b{args.local_batch}")
    variants = args.variants.split(",") if args.variants else None
    print(f"building bundle preset={args.preset} K={args.workers} bl={args.local_batch}")
    build_bundle(args.preset, args.workers, args.local_batch,
                 os.path.abspath(out), args.seed, variants)


if __name__ == "__main__":
    main()
