//! Typed configuration for training runs and experiments.
//!
//! Runs are driven from config-file presets (`configs/*.toml`, parsed by
//! the in-tree TOML-subset parser [`crate::util::KvFile`]), the CLI, or the
//! experiment harness. Presets mirror the paper's "medium / large / xlarge"
//! settings (Table 2) scaled to this testbed (DESIGN.md §1).
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

use anyhow::{bail, ensure, Context, Result};

use crate::util::KvFile;

/// The algorithms of Table 1 in the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Mini-batch contrastive loss baseline (γ ≡ 1, learnable global τ,
    /// REDUCE_SCATTER communication pattern).
    OpenClip,
    /// GCL via FCCO, constant γ, constant global τ.
    SogClr,
    /// RGCL via FCCO, constant γ, individual learnable τ.
    ISogClr,
    /// GCL (unscaled), cosine γ, learnable global τ via Eq. (8).
    FastClipV0,
    /// GCL, cosine γ, constant global τ.
    FastClipV1,
    /// RGCL, cosine γ, individual learnable τ via Eq. (9).
    FastClipV2,
    /// RGCL-g, cosine γ, learnable global τ via Eq. (10).
    FastClipV3,
}

/// How the temperature parameter is updated each iteration (§5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TempRule {
    Constant,
    /// single learnable τ from the loss gradient (MBCL / Eq. 8 / Eq. 10)
    GlobalLearnable,
    /// per-sample learnable τ1_i, τ2_i (Eq. 9)
    Individual,
}

/// Which collectives the algorithm pays for (§4; Fig. 3 cost accounting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommPattern {
    /// ALL_GATHER(feats) + REDUCE_SCATTER(per-pair grad terms, O(K·B·d))
    /// + ALL_REDUCE(param grads).
    OpenClip,
    /// ALL_GATHER(feats) + ALL_GATHER(u scalars, O(K·B)) + ALL_REDUCE(grads).
    FastClip,
}

impl Algorithm {
    /// The `step_<variant>` HLO artifact this algorithm executes.
    pub fn variant(&self) -> &'static str {
        match self {
            Algorithm::OpenClip => "mbcl",
            Algorithm::SogClr | Algorithm::FastClipV1 => "gcl",
            Algorithm::FastClipV0 => "gcl_v0",
            Algorithm::ISogClr | Algorithm::FastClipV2 => "rgcl_i",
            Algorithm::FastClipV3 => "rgcl_g",
        }
    }

    pub fn temp_rule(&self) -> TempRule {
        match self {
            Algorithm::SogClr | Algorithm::FastClipV1 => TempRule::Constant,
            Algorithm::ISogClr | Algorithm::FastClipV2 => TempRule::Individual,
            _ => TempRule::GlobalLearnable,
        }
    }

    pub fn comm_pattern(&self) -> CommPattern {
        match self {
            Algorithm::OpenClip => CommPattern::OpenClip,
            _ => CommPattern::FastClip,
        }
    }

    /// OpenCLIP has no u sequence: γ ≡ 1 regardless of the schedule.
    pub fn forces_gamma_one(&self) -> bool {
        matches!(self, Algorithm::OpenClip)
    }

    /// The default γ schedule family from Table 1.
    pub fn default_cosine_gamma(&self) -> bool {
        matches!(
            self,
            Algorithm::FastClipV0
                | Algorithm::FastClipV1
                | Algorithm::FastClipV2
                | Algorithm::FastClipV3
        )
    }

    pub fn all() -> [Algorithm; 7] {
        [
            Algorithm::OpenClip,
            Algorithm::SogClr,
            Algorithm::ISogClr,
            Algorithm::FastClipV0,
            Algorithm::FastClipV1,
            Algorithm::FastClipV2,
            Algorithm::FastClipV3,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::OpenClip => "OpenCLIP",
            Algorithm::SogClr => "SogCLR",
            Algorithm::ISogClr => "iSogCLR",
            Algorithm::FastClipV0 => "FastCLIP-v0",
            Algorithm::FastClipV1 => "FastCLIP-v1",
            Algorithm::FastClipV2 => "FastCLIP-v2",
            Algorithm::FastClipV3 => "FastCLIP-v3",
        }
    }

    /// Kebab-case id used by the CLI and config files.
    pub fn id(&self) -> &'static str {
        match self {
            Algorithm::OpenClip => "openclip",
            Algorithm::SogClr => "sogclr",
            Algorithm::ISogClr => "isogclr",
            Algorithm::FastClipV0 => "fastclip-v0",
            Algorithm::FastClipV1 => "fastclip-v1",
            Algorithm::FastClipV2 => "fastclip-v2",
            Algorithm::FastClipV3 => "fastclip-v3",
        }
    }

    pub fn from_id(id: &str) -> Result<Algorithm> {
        for a in Algorithm::all() {
            if a.id() == id {
                return Ok(a);
            }
        }
        bail!(
            "unknown algorithm '{id}' (expected one of: {})",
            Algorithm::all().map(|a| a.id()).join(", ")
        )
    }
}

/// Inner learning-rate schedule for γ_t (Eq. 1 / §5 "The Inner LR Schedule").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GammaSchedule {
    Constant { gamma: f32 },
    /// γ_t = 0.5 (1 + cos(π·epoch/E)) (1 − γ_min) + γ_min, clamped past E.
    Cosine { gamma_min: f32, decay_epochs: u32 },
}

impl GammaSchedule {
    pub fn value(&self, epoch: u32) -> f32 {
        match *self {
            GammaSchedule::Constant { gamma } => gamma,
            GammaSchedule::Cosine { gamma_min, decay_epochs } => {
                if epoch >= decay_epochs {
                    return gamma_min;
                }
                let c = (std::f32::consts::PI * epoch as f32 / decay_epochs as f32).cos();
                0.5 * (1.0 + c) * (1.0 - gamma_min) + gamma_min
            }
        }
    }
}

/// Outer (model) learning-rate schedule: linear warmup then cosine decay
/// to `min_lr` (Appendix B).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LrSchedule {
    pub peak: f32,
    pub min: f32,
    pub warmup_iters: u32,
    pub total_iters: u32,
}

impl LrSchedule {
    pub fn value(&self, iter: u32) -> f32 {
        if iter < self.warmup_iters {
            return self.peak * (iter + 1) as f32 / self.warmup_iters.max(1) as f32;
        }
        let t = (iter - self.warmup_iters) as f32
            / (self.total_iters.saturating_sub(self.warmup_iters)).max(1) as f32;
        let t = t.min(1.0);
        self.min + 0.5 * (1.0 + (std::f32::consts::PI * t).cos()) * (self.peak - self.min)
    }
}

/// Optimizer for the model parameters (§5 "The Optimizer", Proc. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    AdamW,
    Lamb,
    Lion,
    Sgdm,
}

impl OptimizerKind {
    pub fn all() -> [OptimizerKind; 4] {
        [OptimizerKind::AdamW, OptimizerKind::Lamb, OptimizerKind::Lion, OptimizerKind::Sgdm]
    }

    pub fn id(&self) -> &'static str {
        match self {
            OptimizerKind::AdamW => "adamw",
            OptimizerKind::Lamb => "lamb",
            OptimizerKind::Lion => "lion",
            OptimizerKind::Sgdm => "sgdm",
        }
    }

    pub fn from_id(id: &str) -> Result<OptimizerKind> {
        for k in OptimizerKind::all() {
            if k.id() == id {
                return Ok(k);
            }
        }
        bail!("unknown optimizer '{id}' (expected adamw|lamb|lion|sgdm)")
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::AdamW => "AdamW",
            OptimizerKind::Lamb => "LAMB",
            OptimizerKind::Lion => "Lion",
            OptimizerKind::Sgdm => "SGDM",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptimizerConfig {
    pub kind: OptimizerKind,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// SGDM momentum
    pub momentum: f32,
}

impl OptimizerConfig {
    pub fn adamw(weight_decay: f32) -> Self {
        Self { kind: OptimizerKind::AdamW, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay, momentum: 0.9 }
    }

    pub fn with_kind(kind: OptimizerKind) -> Self {
        let mut c = Self::adamw(0.1);
        c.kind = kind;
        match kind {
            OptimizerKind::Lion => {
                c.beta1 = 0.9;
                c.beta2 = 0.99;
                c.weight_decay = 0.3;
            }
            OptimizerKind::Sgdm => {
                c.weight_decay = 3e-6;
            }
            _ => {}
        }
        c
    }
}

/// Synthetic paired image–text dataset parameters (DESIGN.md §1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataConfig {
    pub n_train: usize,
    pub n_eval: usize,
    pub n_classes: usize,
    /// image noise σ around class prototype
    pub noise: f32,
    /// zipf exponent for long-tailed class frequencies (0 = uniform)
    pub zipf_s: f64,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self { n_train: 8192, n_eval: 512, n_classes: 64, noise: 0.8, zipf_s: 0.5, seed: 0 }
    }
}

/// Simulated interconnect (DESIGN.md §1 "Hardware"): α–β ring collectives,
/// hierarchical intra-node / inter-node. Profiles in `comm::profiles`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkProfile {
    pub name: &'static str,
    /// inter-node latency per ring step, seconds
    pub inter_alpha: f64,
    /// inter-node bandwidth, bytes/sec
    pub inter_beta: f64,
    /// intra-node (e.g. NVLink/PCIe) latency, seconds
    pub intra_alpha: f64,
    /// intra-node bandwidth, bytes/sec
    pub intra_beta: f64,
}

/// A full training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// artifact bundle directory (contains manifest.json)
    pub artifact_dir: String,
    pub algorithm: Algorithm,
    pub steps: u32,
    /// iterations per "epoch" for the γ schedule (Ê in §5)
    pub iters_per_epoch: u32,
    pub optimizer: OptimizerConfig,
    pub lr: LrSchedule,
    pub gamma: GammaSchedule,
    /// initial temperature τ0
    pub tau_init: f32,
    /// learning rate for learnable τ (AdamW with λ=0, Proc. 5)
    pub tau_lr: f32,
    /// lower bound τ ≥ τ_min (RGCL constraint)
    pub tau_min: f32,
    /// ε in log(ε + g) (1e-14 default; 1e-6 for xlarge per Appendix D)
    pub eps: f32,
    /// ρ margin in RGCL / RGCL-g
    pub rho: f32,
    pub data: DataConfig,
    pub seed: u64,
    /// evaluate every N steps (0 = only at end)
    pub eval_every: u32,
    /// topology for the comm cost model
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub network: crate::comm::ProfileName,
    /// gradient-reduction algorithm (DESIGN.md §4 "Gradient reduction"):
    /// naive | ring | sharded, or auto to let the α–β cost model pick the
    /// cheapest for the gradient size
    pub reduce: crate::comm::ReduceStrategy,
    /// overlap the bucketed gradient reduction with backward compute
    /// (DESIGN.md §11): on | off | auto (auto = overlap whenever K > 1
    /// and the gradient spans more than one bucket)
    pub overlap: crate::comm::OverlapMode,
    /// bucket size for the overlapped reduction, in bytes (the CLI takes
    /// `--bucket-mb`; config files take `bucket_mb` or raw `bucket_bytes`)
    pub bucket_bytes: usize,
    /// FastCLIP-v3: decay tau_lr to 1/3 when τ < 0.03 (Appendix B)
    pub tau_lr_decay_below: Option<f32>,
    /// checkpoint root directory (DESIGN.md §9); required when
    /// `ckpt_every > 0`
    pub ckpt_dir: Option<String>,
    /// snapshot the full training state every N steps (0 = never)
    pub ckpt_every: u32,
    /// retain only the most recent N snapshots (0 = keep all)
    pub keep_last: usize,
    /// resume from a checkpoint: a `step_NNNNNNNN` directory, a
    /// checkpoint root (latest step is used), or the literal "latest"
    /// (resolved against `ckpt_dir`)
    pub resume: Option<String>,
    /// compute backend (DESIGN.md §10): native | pjrt | auto (auto picks
    /// pjrt when the feature + an artifact bundle are present)
    pub backend: crate::runtime::BackendKind,
    /// native-backend model preset (tiny|small|medium|base)
    pub preset: String,
    /// native-backend worker count (artifact bundles carry their own)
    pub n_workers: usize,
    /// native-backend local batch size
    pub local_batch: usize,
    /// threads per worker for the native kernels (0 = auto); any value
    /// yields bitwise-identical results (DESIGN.md §10)
    pub kernel_threads: usize,
    /// compute + gradient-wire storage precision (DESIGN.md §12):
    /// f32 (default) or bf16 (bf16 working copies / activations /
    /// half-width gradient wire; f32 master weights, optimizer state and
    /// checkpoints). bf16 needs the native backend.
    pub precision: crate::kernels::Precision,
    /// gradient wire codec (DESIGN.md §15): f32 | bf16 | int8 | topk.
    /// `None` (the default) follows the compute precision — f32 wire for
    /// f32 runs, bf16 wire for bf16 runs. Set explicitly to compress the
    /// gradient wire independently of compute: int8 moves exactly 4×
    /// fewer gradient bytes than f32, topk moves ~8× fewer with
    /// error-feedback residuals carrying what was dropped.
    pub wire: Option<crate::comm::WireCodec>,
    /// memory-sharded global contrastive loss (DESIGN.md §16):
    /// on | off | auto. `auto` (the default) shards when the run
    /// resolves to the native backend and stays unsharded otherwise;
    /// `on` with the pjrt backend is rejected at startup. Both settings
    /// produce bitwise-identical training — sharding only changes the
    /// loss-stage peak memory (the `loss.peak_bytes` gauge) and the
    /// feature-gradient wire accounting
    pub loss_shard: crate::runtime::LossShardMode,
    /// fault injection (DESIGN.md §13): kill rank R at the top of
    /// iteration N, grammar `rank=R@iter=N`; None = no injected failure
    pub fail: Option<String>,
    /// straggler injection: per-rank latency skew before every
    /// collective, grammar `rank=R:ms=M[,rank=R2:ms=M2]`; None = no skew
    pub straggle: Option<String>,
    /// watchdog for blocking collectives, in milliseconds (0 = default:
    /// 60 s whenever fault injection is active, unbounded otherwise)
    pub watchdog_ms: u64,
    /// structured telemetry (DESIGN.md §14): write one schema-versioned
    /// JSONL event per line to this file (spans, iteration timing,
    /// fault events, metrics); None = telemetry off. Cannot perturb the
    /// numerics — telemetry-on runs are bitwise-identical to
    /// telemetry-off (pinned in `tests/telemetry.rs`).
    pub trace_out: Option<String>,
    /// heartbeat period: every N iterations rank 0 logs step/loss/τ and
    /// (with `trace_out`) emits a heartbeat event; 0 = no heartbeat
    pub log_every: u32,
    /// suppress progress output (run headers, per-seed lines, shrink
    /// notices); result tables and errors still print
    pub quiet: bool,
    /// progress output format: "text" (default, the pre-telemetry
    /// streams byte-for-byte) or "json" (one compact
    /// `{"v":1,"type":"log",...}` object per line on the same stream)
    pub log_format: String,
}

impl TrainConfig {
    /// Point the run at an artifact bundle directory AND, when the
    /// directory basename follows the `<preset>_k<K>_b<B>` bundle naming
    /// convention, mirror that topology into the native-backend fields —
    /// so one configuration drives either backend identically.
    pub fn set_bundle(&mut self, dir: &str) {
        self.artifact_dir = dir.to_string();
        let base = std::path::Path::new(dir)
            .file_name()
            .and_then(|s| s.to_str())
            .unwrap_or("");
        let parts: Vec<&str> = base.split('_').collect();
        if let [preset, k, b] = parts[..] {
            let k = k.strip_prefix('k').and_then(|v| v.parse::<usize>().ok());
            let b = b.strip_prefix('b').and_then(|v| v.parse::<usize>().ok());
            if let (Some(k), Some(b)) = (k, b) {
                if k > 0 && b > 0 {
                    self.preset = preset.to_string();
                    self.n_workers = k;
                    self.local_batch = b;
                }
            }
        }
    }

    /// Defaults mirroring the paper's medium-scale setting, scaled down.
    pub fn new(artifact_dir: impl Into<String>, algorithm: Algorithm) -> Self {
        let steps = 200;
        let iters_per_epoch = 32;
        let epochs = steps / iters_per_epoch;
        let gamma = if algorithm.forces_gamma_one() {
            GammaSchedule::Constant { gamma: 1.0 }
        } else if algorithm.default_cosine_gamma() {
            GammaSchedule::Cosine { gamma_min: 0.2, decay_epochs: (epochs / 2).max(1) }
        } else {
            GammaSchedule::Constant { gamma: 0.6 }
        };
        let tau_init = if algorithm == Algorithm::FastClipV3 { 0.07 } else { 0.03 };
        let mut cfg = Self {
            artifact_dir: String::new(),
            algorithm,
            steps,
            iters_per_epoch,
            optimizer: OptimizerConfig::adamw(0.1),
            lr: LrSchedule { peak: 1e-3, min: 0.0, warmup_iters: steps / 10, total_iters: steps },
            gamma,
            tau_init,
            tau_lr: if algorithm == Algorithm::FastClipV3 { 2e-4 } else { 1e-2 },
            tau_min: 0.005,
            eps: 1e-14,
            rho: 6.5,
            data: DataConfig::default(),
            seed: 0,
            eval_every: 0,
            nodes: 1,
            gpus_per_node: 4,
            network: crate::comm::ProfileName::InfiniBand,
            reduce: crate::comm::ReduceStrategy::Auto,
            overlap: crate::comm::OverlapMode::Auto,
            bucket_bytes: 4 << 20,
            tau_lr_decay_below: if algorithm == Algorithm::FastClipV3 { Some(0.03) } else { None },
            ckpt_dir: None,
            ckpt_every: 0,
            keep_last: 3,
            resume: None,
            backend: crate::runtime::BackendKind::Auto,
            preset: "tiny".to_string(),
            n_workers: 2,
            local_batch: 8,
            kernel_threads: 0,
            precision: crate::kernels::Precision::F32,
            wire: None,
            loss_shard: crate::runtime::LossShardMode::Auto,
            fail: None,
            straggle: None,
            watchdog_ms: 0,
            trace_out: None,
            log_every: 0,
            quiet: false,
            log_format: "text".to_string(),
        };
        let dir: String = artifact_dir.into();
        cfg.set_bundle(&dir);
        cfg
    }

    /// Resolve `backend = auto`: pjrt when both the cargo feature and the
    /// configured artifact bundle are present, native otherwise.
    pub fn resolved_backend(&self) -> crate::runtime::BackendKind {
        use crate::runtime::BackendKind;
        match self.backend {
            BackendKind::Auto => {
                let have_bundle = std::path::Path::new(&self.artifact_dir)
                    .join("manifest.json")
                    .exists();
                if cfg!(feature = "pjrt") && have_bundle {
                    BackendKind::Pjrt
                } else {
                    BackendKind::Native
                }
            }
            k => k,
        }
    }

    /// Build the manifest the resolved backend runs against: synthesized
    /// for native, loaded from `artifact_dir` for pjrt.
    pub fn load_manifest(&self) -> Result<crate::runtime::Manifest> {
        use crate::runtime::{BackendKind, Manifest};
        match self.resolved_backend() {
            BackendKind::Native => {
                Manifest::native(&self.preset, self.n_workers, self.local_batch, self.seed)
            }
            _ => Manifest::load(&self.artifact_dir)
                .with_context(|| format!("loading artifact bundle {}", self.artifact_dir)),
        }
    }

    pub fn epochs(&self) -> u32 {
        self.steps / self.iters_per_epoch.max(1)
    }

    /// The gradient wire codec this run reduces with: the explicit
    /// `wire` choice, or — when unset — the compute precision's default
    /// ([`crate::comm::WireCodec::from_precision`]), which reproduces
    /// the pre-§15 behaviour exactly.
    pub fn wire_codec(&self) -> crate::comm::WireCodec {
        self.wire
            .unwrap_or_else(|| crate::comm::WireCodec::from_precision(self.precision))
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.steps > 0, "steps must be > 0");
        ensure!(self.iters_per_epoch > 0, "iters_per_epoch must be > 0");
        ensure!(self.tau_init > 0.0, "tau_init must be > 0");
        ensure!(self.tau_min > 0.0, "tau_min must be > 0");
        ensure!(self.eps > 0.0, "eps must be > 0");
        ensure!(self.rho >= 0.0, "rho must be >= 0");
        ensure!(self.nodes > 0 && self.gpus_per_node > 0, "topology must be non-empty");
        if let GammaSchedule::Constant { gamma } = self.gamma {
            ensure!(gamma > 0.0 && gamma <= 1.0, "gamma must be in (0,1]");
        }
        if let GammaSchedule::Cosine { gamma_min, .. } = self.gamma {
            ensure!(gamma_min > 0.0 && gamma_min <= 1.0, "gamma_min must be in (0,1]");
        }
        // an empty training set means every worker's strided shard is
        // empty — reject it here so the trainer, `exp` runners and the
        // examples all fail with the same actionable message instead of
        // a downstream shard-math surprise (shard_len_for errors too)
        ensure!(
            self.data.n_train > 0,
            "data.n_train must be > 0: there is nothing to train on — every worker's shard \
             of an empty dataset is empty (default 8192)"
        );
        // evaluation always runs on a materialized split: an empty one
        // (n_eval = 0) would score NaN over zero samples — reject it up
        // front instead of "evaluating" an empty set
        ensure!(
            self.data.n_eval > 0,
            "data.n_eval must be > 0: the trainer evaluates at the end of every run{} — \
             raise data.n_eval (default 512)",
            if self.eval_every > 0 { " and eval_every requests periodic evaluations" } else { "" }
        );
        ensure!(self.n_workers > 0, "n_workers must be > 0");
        ensure!(self.local_batch > 0, "local_batch must be > 0");
        ensure!(
            self.bucket_bytes >= 4,
            "bucket_bytes must hold at least one f32 element (got {})",
            self.bucket_bytes
        );
        ensure!(self.kernel_threads <= 1024, "kernel_threads {} is absurd", self.kernel_threads);
        ensure!(
            self.ckpt_every == 0 || self.ckpt_dir.is_some(),
            "ckpt_every > 0 requires ckpt_dir"
        );
        if let Some(r) = &self.resume {
            ensure!(
                r != "latest" || self.ckpt_dir.is_some(),
                "resume = \"latest\" requires ckpt_dir"
            );
        }
        // fault-injection grammar (DESIGN.md §13): reject malformed specs
        // up front — the parse error spells out the expected grammar
        crate::comm::FaultPlan::parse(
            self.fail.as_deref(),
            self.straggle.as_deref(),
            self.watchdog_ms,
        )?;
        // the progress-output switch (DESIGN.md §14): reject typos here
        // so every entry point (CLI, config file, exp harness) names
        // the accepted formats instead of silently printing text
        crate::telemetry::Logger::from_format(self.quiet, &self.log_format)?;
        if let Some(t) = &self.trace_out {
            ensure!(!t.is_empty(), "trace_out must name a file");
        }
        Ok(())
    }

    /// Load from a config-file preset, overriding the algorithm defaults.
    /// Recognized keys mirror the struct fields; unknown keys are rejected
    /// so presets cannot silently rot.
    pub fn from_file(path: &str) -> Result<Self> {
        let kv = KvFile::parse_file(std::path::Path::new(path))?;
        Self::from_kv(&kv)
    }

    pub fn from_kv(kv: &KvFile) -> Result<Self> {
        let algorithm = Algorithm::from_id(&kv.str_or("algorithm", "fastclip-v3"))?;
        let artifact_dir = kv.str_or("artifact_dir", "artifacts/tiny_k2_b8");
        let mut cfg = TrainConfig::new(artifact_dir, algorithm);

        const KNOWN: &[&str] = &[
            "algorithm", "artifact_dir", "steps", "iters_per_epoch", "seed",
            "tau_init", "tau_lr", "tau_min", "eps", "rho", "eval_every",
            "nodes", "gpus_per_node", "network", "reduce", "overlap",
            "bucket_mb", "bucket_bytes", "tau_lr_decay_below",
            "ckpt_dir", "ckpt_every", "keep_last", "resume",
            "backend", "preset", "n_workers", "local_batch", "kernel_threads",
            "precision", "wire", "loss_shard", "fail", "straggle", "watchdog_ms",
            "trace_out", "log_every", "quiet", "log_format",
            "optimizer.kind", "optimizer.beta1", "optimizer.beta2",
            "optimizer.eps", "optimizer.weight_decay", "optimizer.momentum",
            "lr.peak", "lr.min", "lr.warmup_iters", "lr.total_iters",
            "gamma.kind", "gamma.gamma", "gamma.gamma_min", "gamma.decay_epochs",
            "data.n_train", "data.n_eval", "data.n_classes", "data.noise",
            "data.zipf_s", "data.seed",
        ];
        for k in kv.keys() {
            ensure!(KNOWN.contains(&k), "unknown config key '{k}'");
        }

        cfg.steps = kv.parse_or("steps", cfg.steps)?;
        cfg.iters_per_epoch = kv.parse_or("iters_per_epoch", cfg.iters_per_epoch)?;
        cfg.seed = kv.parse_or("seed", cfg.seed)?;
        cfg.tau_init = kv.parse_or("tau_init", cfg.tau_init)?;
        cfg.tau_lr = kv.parse_or("tau_lr", cfg.tau_lr)?;
        cfg.tau_min = kv.parse_or("tau_min", cfg.tau_min)?;
        cfg.eps = kv.parse_or("eps", cfg.eps)?;
        cfg.rho = kv.parse_or("rho", cfg.rho)?;
        cfg.eval_every = kv.parse_or("eval_every", cfg.eval_every)?;
        cfg.nodes = kv.parse_or("nodes", cfg.nodes)?;
        cfg.gpus_per_node = kv.parse_or("gpus_per_node", cfg.gpus_per_node)?;
        cfg.network = crate::comm::ProfileName::from_id(&kv.str_or("network", "infiniband"))?;
        cfg.reduce = crate::comm::ReduceStrategy::from_id(&kv.str_or("reduce", cfg.reduce.id()))?;
        cfg.overlap = crate::comm::OverlapMode::from_id(&kv.str_or("overlap", cfg.overlap.id()))?;
        if let Some(mb) = kv.get("bucket_mb") {
            let mb: usize = mb.parse().map_err(anyhow::Error::msg)?;
            cfg.bucket_bytes = mb << 20;
        }
        // raw bytes win over bucket_mb (it is what to_file_string writes,
        // so sub-MB test configs round-trip exactly)
        cfg.bucket_bytes = kv.parse_or("bucket_bytes", cfg.bucket_bytes)?;
        if let Some(v) = kv.get("tau_lr_decay_below") {
            cfg.tau_lr_decay_below = Some(v.parse().map_err(anyhow::Error::msg)?);
        }
        if let Some(v) = kv.get("ckpt_dir") {
            cfg.ckpt_dir = Some(v.to_string());
        }
        cfg.ckpt_every = kv.parse_or("ckpt_every", cfg.ckpt_every)?;
        cfg.keep_last = kv.parse_or("keep_last", cfg.keep_last)?;
        if let Some(v) = kv.get("resume") {
            cfg.resume = Some(v.to_string());
        }
        cfg.backend =
            crate::runtime::BackendKind::from_id(&kv.str_or("backend", cfg.backend.id()))?;
        cfg.preset = kv.str_or("preset", &cfg.preset);
        cfg.n_workers = kv.parse_or("n_workers", cfg.n_workers)?;
        cfg.local_batch = kv.parse_or("local_batch", cfg.local_batch)?;
        cfg.kernel_threads = kv.parse_or("kernel_threads", cfg.kernel_threads)?;
        cfg.precision =
            crate::kernels::Precision::from_id(&kv.str_or("precision", cfg.precision.id()))?;
        if let Some(v) = kv.get("wire") {
            cfg.wire = Some(crate::comm::WireCodec::from_id(v)?);
        }
        cfg.loss_shard = crate::runtime::LossShardMode::from_id(
            &kv.str_or("loss_shard", cfg.loss_shard.id()),
        )?;
        if let Some(v) = kv.get("fail") {
            cfg.fail = Some(v.to_string());
        }
        if let Some(v) = kv.get("straggle") {
            cfg.straggle = Some(v.to_string());
        }
        cfg.watchdog_ms = kv.parse_or("watchdog_ms", cfg.watchdog_ms)?;
        if let Some(v) = kv.get("trace_out") {
            cfg.trace_out = Some(v.to_string());
        }
        cfg.log_every = kv.parse_or("log_every", cfg.log_every)?;
        cfg.quiet = kv.parse_or("quiet", cfg.quiet)?;
        cfg.log_format = kv.str_or("log_format", &cfg.log_format);

        if let Some(kind) = kv.get("optimizer.kind") {
            cfg.optimizer.kind = OptimizerKind::from_id(kind)?;
        }
        cfg.optimizer.beta1 = kv.parse_or("optimizer.beta1", cfg.optimizer.beta1)?;
        cfg.optimizer.beta2 = kv.parse_or("optimizer.beta2", cfg.optimizer.beta2)?;
        cfg.optimizer.eps = kv.parse_or("optimizer.eps", cfg.optimizer.eps)?;
        cfg.optimizer.weight_decay =
            kv.parse_or("optimizer.weight_decay", cfg.optimizer.weight_decay)?;
        cfg.optimizer.momentum = kv.parse_or("optimizer.momentum", cfg.optimizer.momentum)?;

        cfg.lr.peak = kv.parse_or("lr.peak", cfg.lr.peak)?;
        cfg.lr.min = kv.parse_or("lr.min", cfg.lr.min)?;
        cfg.lr.warmup_iters = kv.parse_or("lr.warmup_iters", cfg.lr.warmup_iters)?;
        cfg.lr.total_iters = kv.parse_or("lr.total_iters", cfg.steps)?;

        match kv.get("gamma.kind") {
            Some("constant") => {
                cfg.gamma = GammaSchedule::Constant { gamma: kv.parse_or("gamma.gamma", 0.6)? };
            }
            Some("cosine") => {
                cfg.gamma = GammaSchedule::Cosine {
                    gamma_min: kv.parse_or("gamma.gamma_min", 0.2)?,
                    decay_epochs: kv.parse_or("gamma.decay_epochs", cfg.epochs().max(1))?,
                };
            }
            Some(other) => bail!("gamma.kind must be constant|cosine, got '{other}'"),
            None => {}
        }

        cfg.data.n_train = kv.parse_or("data.n_train", cfg.data.n_train)?;
        cfg.data.n_eval = kv.parse_or("data.n_eval", cfg.data.n_eval)?;
        cfg.data.n_classes = kv.parse_or("data.n_classes", cfg.data.n_classes)?;
        cfg.data.noise = kv.parse_or("data.noise", cfg.data.noise)?;
        cfg.data.zipf_s = kv.parse_or("data.zipf_s", cfg.data.zipf_s)?;
        cfg.data.seed = kv.parse_or("data.seed", cfg.data.seed)?;

        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to the config-file format accepted by [`Self::from_file`].
    pub fn to_file_string(&self) -> String {
        let mut s = String::new();
        use std::fmt::Write;
        let _ = writeln!(s, "algorithm = \"{}\"", self.algorithm.id());
        let _ = writeln!(s, "artifact_dir = \"{}\"", self.artifact_dir);
        let _ = writeln!(s, "steps = {}", self.steps);
        let _ = writeln!(s, "iters_per_epoch = {}", self.iters_per_epoch);
        let _ = writeln!(s, "seed = {}", self.seed);
        let _ = writeln!(s, "tau_init = {}", self.tau_init);
        let _ = writeln!(s, "tau_lr = {}", self.tau_lr);
        let _ = writeln!(s, "tau_min = {}", self.tau_min);
        let _ = writeln!(s, "eps = {:e}", self.eps);
        let _ = writeln!(s, "rho = {}", self.rho);
        let _ = writeln!(s, "eval_every = {}", self.eval_every);
        let _ = writeln!(s, "nodes = {}", self.nodes);
        let _ = writeln!(s, "gpus_per_node = {}", self.gpus_per_node);
        let _ = writeln!(s, "network = \"{}\"", self.network.id());
        let _ = writeln!(s, "reduce = \"{}\"", self.reduce.id());
        let _ = writeln!(s, "overlap = \"{}\"", self.overlap.id());
        let _ = writeln!(s, "bucket_bytes = {}", self.bucket_bytes);
        if let Some(v) = self.tau_lr_decay_below {
            let _ = writeln!(s, "tau_lr_decay_below = {v}");
        }
        if let Some(d) = &self.ckpt_dir {
            let _ = writeln!(s, "ckpt_dir = \"{d}\"");
            let _ = writeln!(s, "ckpt_every = {}", self.ckpt_every);
            let _ = writeln!(s, "keep_last = {}", self.keep_last);
        }
        if let Some(r) = &self.resume {
            let _ = writeln!(s, "resume = \"{r}\"");
        }
        let _ = writeln!(s, "backend = \"{}\"", self.backend.id());
        let _ = writeln!(s, "preset = \"{}\"", self.preset);
        let _ = writeln!(s, "n_workers = {}", self.n_workers);
        let _ = writeln!(s, "local_batch = {}", self.local_batch);
        let _ = writeln!(s, "kernel_threads = {}", self.kernel_threads);
        let _ = writeln!(s, "precision = \"{}\"", self.precision.id());
        if let Some(w) = self.wire {
            let _ = writeln!(s, "wire = \"{}\"", w.id());
        }
        if self.loss_shard != crate::runtime::LossShardMode::Auto {
            let _ = writeln!(s, "loss_shard = \"{}\"", self.loss_shard.id());
        }
        if let Some(f) = &self.fail {
            let _ = writeln!(s, "fail = \"{f}\"");
        }
        if let Some(g) = &self.straggle {
            let _ = writeln!(s, "straggle = \"{g}\"");
        }
        if self.watchdog_ms > 0 {
            let _ = writeln!(s, "watchdog_ms = {}", self.watchdog_ms);
        }
        if let Some(t) = &self.trace_out {
            let _ = writeln!(s, "trace_out = \"{t}\"");
        }
        if self.log_every > 0 {
            let _ = writeln!(s, "log_every = {}", self.log_every);
        }
        if self.quiet {
            let _ = writeln!(s, "quiet = true");
        }
        if self.log_format != "text" {
            let _ = writeln!(s, "log_format = \"{}\"", self.log_format);
        }
        let _ = writeln!(s, "\n[optimizer]");
        let _ = writeln!(s, "kind = \"{}\"", self.optimizer.kind.id());
        let _ = writeln!(s, "beta1 = {}", self.optimizer.beta1);
        let _ = writeln!(s, "beta2 = {}", self.optimizer.beta2);
        let _ = writeln!(s, "eps = {:e}", self.optimizer.eps);
        let _ = writeln!(s, "weight_decay = {}", self.optimizer.weight_decay);
        let _ = writeln!(s, "momentum = {}", self.optimizer.momentum);
        let _ = writeln!(s, "\n[lr]");
        let _ = writeln!(s, "peak = {}", self.lr.peak);
        let _ = writeln!(s, "min = {}", self.lr.min);
        let _ = writeln!(s, "warmup_iters = {}", self.lr.warmup_iters);
        let _ = writeln!(s, "total_iters = {}", self.lr.total_iters);
        let _ = writeln!(s, "\n[gamma]");
        match self.gamma {
            GammaSchedule::Constant { gamma } => {
                let _ = writeln!(s, "kind = \"constant\"");
                let _ = writeln!(s, "gamma = {gamma}");
            }
            GammaSchedule::Cosine { gamma_min, decay_epochs } => {
                let _ = writeln!(s, "kind = \"cosine\"");
                let _ = writeln!(s, "gamma_min = {gamma_min}");
                let _ = writeln!(s, "decay_epochs = {decay_epochs}");
            }
        }
        let _ = writeln!(s, "\n[data]");
        let _ = writeln!(s, "n_train = {}", self.data.n_train);
        let _ = writeln!(s, "n_eval = {}", self.data.n_eval);
        let _ = writeln!(s, "n_classes = {}", self.data.n_classes);
        let _ = writeln!(s, "noise = {}", self.data.noise);
        let _ = writeln!(s, "zipf_s = {}", self.data.zipf_s);
        let _ = writeln!(s, "seed = {}", self.data.seed);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algorithm_table1_mapping() {
        assert_eq!(Algorithm::OpenClip.variant(), "mbcl");
        assert_eq!(Algorithm::SogClr.variant(), "gcl");
        assert_eq!(Algorithm::FastClipV1.variant(), "gcl");
        assert_eq!(Algorithm::FastClipV0.variant(), "gcl_v0");
        assert_eq!(Algorithm::FastClipV2.variant(), "rgcl_i");
        assert_eq!(Algorithm::ISogClr.variant(), "rgcl_i");
        assert_eq!(Algorithm::FastClipV3.variant(), "rgcl_g");
        assert!(Algorithm::OpenClip.forces_gamma_one());
        assert_eq!(Algorithm::FastClipV1.temp_rule(), TempRule::Constant);
        assert_eq!(Algorithm::FastClipV2.temp_rule(), TempRule::Individual);
        assert_eq!(Algorithm::FastClipV3.temp_rule(), TempRule::GlobalLearnable);
        assert_eq!(Algorithm::OpenClip.comm_pattern(), CommPattern::OpenClip);
        assert_eq!(Algorithm::FastClipV3.comm_pattern(), CommPattern::FastClip);
    }

    #[test]
    fn gamma_cosine_schedule_shape() {
        let s = GammaSchedule::Cosine { gamma_min: 0.2, decay_epochs: 10 };
        assert!((s.value(0) - 1.0).abs() < 1e-6);
        assert!((s.value(10) - 0.2).abs() < 1e-6);
        assert!((s.value(100) - 0.2).abs() < 1e-6);
        // halfway: γ = 0.5·(1+cos(π/2))·0.8 + 0.2 = 0.6
        assert!((s.value(5) - 0.6).abs() < 1e-5);
        // monotone decreasing
        for e in 0..10 {
            assert!(s.value(e) >= s.value(e + 1));
        }
    }

    #[test]
    fn lr_schedule_warmup_and_decay() {
        let s = LrSchedule { peak: 1e-3, min: 0.0, warmup_iters: 10, total_iters: 110 };
        assert!(s.value(0) > 0.0 && s.value(0) < 1e-3);
        assert!((s.value(9) - 1e-3).abs() < 1e-9);
        assert!((s.value(10) - 1e-3).abs() < 1e-9);
        assert!(s.value(110) < 1e-8);
        assert!(s.value(1000) < 1e-8); // clamped past the end
    }

    #[test]
    fn config_roundtrip_file_format() {
        let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", Algorithm::FastClipV3);
        cfg.steps = 123;
        cfg.optimizer.kind = OptimizerKind::Lion;
        cfg.gamma = GammaSchedule::Cosine { gamma_min: 0.4, decay_epochs: 9 };
        cfg.eps = 1e-6;
        cfg.reduce = crate::comm::ReduceStrategy::Fixed(crate::comm::ReduceAlgo::Sharded);
        let text = cfg.to_file_string();
        let kv = crate::util::KvFile::parse(&text).unwrap();
        let back = TrainConfig::from_kv(&kv).unwrap();
        assert_eq!(back.algorithm, cfg.algorithm);
        assert_eq!(back.gamma, cfg.gamma);
        assert_eq!(back.steps, cfg.steps);
        assert_eq!(back.optimizer.kind, OptimizerKind::Lion);
        assert_eq!(back.reduce, cfg.reduce);
        assert!((back.eps - 1e-6).abs() < 1e-12);
    }

    #[test]
    fn ckpt_fields_roundtrip_and_validate() {
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.ckpt_dir = Some("ckpts/run1".into());
        cfg.ckpt_every = 25;
        cfg.keep_last = 5;
        cfg.resume = Some("latest".into());
        cfg.validate().unwrap();
        let text = cfg.to_file_string();
        let back = TrainConfig::from_kv(&crate::util::KvFile::parse(&text).unwrap()).unwrap();
        assert_eq!(back.ckpt_dir.as_deref(), Some("ckpts/run1"));
        assert_eq!(back.ckpt_every, 25);
        assert_eq!(back.keep_last, 5);
        assert_eq!(back.resume.as_deref(), Some("latest"));
        // ckpt_every without a directory is a config error
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV1);
        bad.ckpt_every = 10;
        assert!(bad.validate().is_err());
        // resume latest without a directory too
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV1);
        bad.resume = Some("latest".into());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn fault_fields_roundtrip_and_validate() {
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV3);
        cfg.fail = Some("rank=1@iter=17".into());
        cfg.straggle = Some("rank=0:ms=20,rank=1:ms=5".into());
        cfg.watchdog_ms = 4000;
        cfg.validate().unwrap();
        let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
        let back = TrainConfig::from_kv(&kv).unwrap();
        assert_eq!(back.fail.as_deref(), Some("rank=1@iter=17"));
        assert_eq!(back.straggle.as_deref(), Some("rank=0:ms=20,rank=1:ms=5"));
        assert_eq!(back.watchdog_ms, 4000);
        // defaults are omitted from the file format entirely
        let text = TrainConfig::new("x", Algorithm::FastClipV3).to_file_string();
        assert!(!text.contains("fail") && !text.contains("straggle"));
        assert!(!text.contains("watchdog_ms"));
        // malformed specs are rejected with the grammar in the message
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV3);
        bad.fail = Some("rank=1,iter=17".into());
        let err = bad.validate().unwrap_err();
        assert!(format!("{err:#}").contains("rank=R@iter=N"), "{err:#}");
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV3);
        bad.straggle = Some("rank=0".into());
        let err = bad.validate().unwrap_err();
        assert!(format!("{err:#}").contains("rank=R:ms=M"), "{err:#}");
    }

    #[test]
    fn telemetry_fields_roundtrip_and_validate() {
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV3);
        cfg.trace_out = Some("traces/run1.jsonl".into());
        cfg.log_every = 10;
        cfg.quiet = true;
        cfg.log_format = "json".into();
        cfg.validate().unwrap();
        let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
        let back = TrainConfig::from_kv(&kv).unwrap();
        assert_eq!(back.trace_out.as_deref(), Some("traces/run1.jsonl"));
        assert_eq!(back.log_every, 10);
        assert!(back.quiet);
        assert_eq!(back.log_format, "json");
        // defaults are omitted from the file format entirely
        let text = TrainConfig::new("x", Algorithm::FastClipV3).to_file_string();
        assert!(!text.contains("trace_out") && !text.contains("log_every"));
        assert!(!text.contains("quiet") && !text.contains("log_format"));
        // unknown formats and empty trace paths are config errors
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV3);
        bad.log_format = "yaml".into();
        assert!(bad.validate().is_err());
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV3);
        bad.trace_out = Some(String::new());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn backend_fields_roundtrip_and_validate() {
        use crate::runtime::BackendKind;
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.backend = BackendKind::Native;
        cfg.preset = "small".into();
        cfg.n_workers = 4;
        cfg.local_batch = 4;
        cfg.kernel_threads = 2;
        cfg.validate().unwrap();
        let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
        let back = TrainConfig::from_kv(&kv).unwrap();
        assert_eq!(back.backend, BackendKind::Native);
        assert_eq!(back.preset, "small");
        assert_eq!(back.n_workers, 4);
        assert_eq!(back.local_batch, 4);
        assert_eq!(back.kernel_threads, 2);
        // explicit native resolves to native; typo'd backend is an error
        assert_eq!(back.resolved_backend(), BackendKind::Native);
        let kv = crate::util::KvFile::parse("backend = \"cuda\"").unwrap();
        let err = TrainConfig::from_kv(&kv).unwrap_err();
        assert!(format!("{err}").contains("native|pjrt|auto"), "{err}");
        // degenerate native topology rejected
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV1);
        bad.n_workers = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn auto_backend_without_artifacts_is_native() {
        let cfg = TrainConfig::new("artifacts/definitely_not_built", Algorithm::FastClipV1);
        assert_eq!(cfg.resolved_backend(), crate::runtime::BackendKind::Native);
        let m = cfg.load_manifest().unwrap();
        assert!(m.native);
        assert_eq!(m.k_workers, cfg.n_workers);
        assert_eq!(m.local_batch, cfg.local_batch);
    }

    #[test]
    fn empty_eval_set_is_a_config_error() {
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.data.n_eval = 0;
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err}").contains("n_eval"), "{err}");
        // with periodic evals requested the message says so too
        cfg.eval_every = 5;
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err}").contains("eval_every"), "{err}");
    }

    #[test]
    fn overlap_fields_roundtrip_and_validate() {
        use crate::comm::OverlapMode;
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        assert_eq!(cfg.overlap, OverlapMode::Auto, "overlap defaults to auto");
        assert_eq!(cfg.bucket_bytes, 4 << 20, "DDP-style 4 MB default bucket");
        cfg.overlap = OverlapMode::On;
        cfg.bucket_bytes = 1024; // sub-MB buckets round-trip exactly
        cfg.validate().unwrap();
        let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
        let back = TrainConfig::from_kv(&kv).unwrap();
        assert_eq!(back.overlap, OverlapMode::On);
        assert_eq!(back.bucket_bytes, 1024);
        // bucket_mb is accepted as a convenience key
        let kv = crate::util::KvFile::parse("bucket_mb = 2").unwrap();
        assert_eq!(TrainConfig::from_kv(&kv).unwrap().bucket_bytes, 2 << 20);
        // typo'd overlap mode errors with the valid choices
        let kv = crate::util::KvFile::parse("overlap = \"maybe\"").unwrap();
        let err = TrainConfig::from_kv(&kv).unwrap_err();
        assert!(format!("{err}").contains("on|off|auto"), "{err}");
        // a bucket too small for one element is a config error
        let mut bad = TrainConfig::new("x", Algorithm::FastClipV1);
        bad.bucket_bytes = 2;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn precision_roundtrips_and_rejects_typos() {
        use crate::kernels::Precision;
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        assert_eq!(cfg.precision, Precision::F32, "precision defaults to f32");
        cfg.precision = Precision::Bf16;
        cfg.validate().unwrap();
        let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
        let back = TrainConfig::from_kv(&kv).unwrap();
        assert_eq!(back.precision, Precision::Bf16);
        // typo'd precision errors with the valid choices listed
        let kv = crate::util::KvFile::parse("precision = \"fp16\"").unwrap();
        let err = TrainConfig::from_kv(&kv).unwrap_err();
        assert!(format!("{err}").contains("f32|bf16"), "{err}");
    }

    #[test]
    fn wire_codec_roundtrips_and_defaults_to_precision() {
        use crate::comm::WireCodec;
        use crate::kernels::Precision;
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        assert_eq!(cfg.wire, None, "wire defaults to unset");
        assert_eq!(cfg.wire_codec(), WireCodec::F32, "f32 precision -> f32 wire");
        cfg.precision = Precision::Bf16;
        assert_eq!(cfg.wire_codec(), WireCodec::Bf16, "bf16 precision -> bf16 wire");
        // unset wire writes no key, so old config files stay valid
        assert!(!cfg.to_file_string().contains("wire ="));
        cfg.precision = Precision::F32;
        for codec in WireCodec::all() {
            cfg.wire = Some(codec);
            cfg.validate().unwrap();
            let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
            let back = TrainConfig::from_kv(&kv).unwrap();
            assert_eq!(back.wire, Some(codec));
            assert_eq!(back.wire_codec(), codec, "explicit wire overrides precision");
        }
        let kv = crate::util::KvFile::parse("wire = \"int4\"").unwrap();
        let err = TrainConfig::from_kv(&kv).unwrap_err();
        assert!(format!("{err}").contains("f32|bf16|int8|topk"), "{err}");
    }

    #[test]
    fn loss_shard_roundtrips_and_defaults_to_auto() {
        use crate::runtime::LossShardMode;
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        assert_eq!(cfg.loss_shard, LossShardMode::Auto, "loss_shard defaults to auto");
        // the default is omitted from the file format, so old configs stay valid
        assert!(!cfg.to_file_string().contains("loss_shard"));
        for mode in [LossShardMode::On, LossShardMode::Off] {
            cfg.loss_shard = mode;
            cfg.validate().unwrap();
            let kv = crate::util::KvFile::parse(&cfg.to_file_string()).unwrap();
            assert_eq!(TrainConfig::from_kv(&kv).unwrap().loss_shard, mode);
        }
        // typos exit with the valid choices listed
        let kv = crate::util::KvFile::parse("loss_shard = \"maybe\"").unwrap();
        let err = TrainConfig::from_kv(&kv).unwrap_err();
        assert!(format!("{err}").contains("on|off|auto"), "{err}");
    }

    #[test]
    fn empty_training_set_is_a_config_error() {
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.data.n_train = 0;
        let err = cfg.validate().unwrap_err();
        assert!(format!("{err}").contains("n_train"), "{err}");
    }

    #[test]
    fn from_kv_rejects_unknown_keys() {
        let kv = crate::util::KvFile::parse("stepz = 100").unwrap();
        assert!(TrainConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn algorithm_id_roundtrip() {
        for a in Algorithm::all() {
            assert_eq!(Algorithm::from_id(a.id()).unwrap(), a);
        }
        assert!(Algorithm::from_id("nope").is_err());
        for k in OptimizerKind::all() {
            assert_eq!(OptimizerKind::from_id(k.id()).unwrap(), k);
        }
    }

    #[test]
    fn validate_rejects_bad_values() {
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.steps = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.gamma = GammaSchedule::Constant { gamma: 1.5 };
        assert!(cfg.validate().is_err());
        let mut cfg = TrainConfig::new("x", Algorithm::FastClipV1);
        cfg.eps = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn defaults_follow_paper_table1() {
        let v3 = TrainConfig::new("x", Algorithm::FastClipV3);
        assert!(matches!(v3.gamma, GammaSchedule::Cosine { .. }));
        assert!((v3.tau_init - 0.07).abs() < 1e-9);
        let sog = TrainConfig::new("x", Algorithm::SogClr);
        assert!(matches!(sog.gamma, GammaSchedule::Constant { gamma } if (gamma - 0.6).abs() < 1e-6));
        let oc = TrainConfig::new("x", Algorithm::OpenClip);
        assert!(matches!(oc.gamma, GammaSchedule::Constant { gamma } if gamma == 1.0));
    }
}
