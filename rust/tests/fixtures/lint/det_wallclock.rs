pub fn tick() -> std::time::Instant {
    std::time::Instant::now()
}
