# L2 losses: the distributed FCCO step graphs.
#
# The central invariant (DESIGN.md §4): the SUM over K workers of the
# per-worker gradient contributions equals the single-worker global-batch
# gradient, for every loss variant. Plus reference checks of the
# surrogate-weight trick against direct autodiff of the true loss.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import losses as L
from compile import model as M
from compile.kernels.ref import pair_exp_rowsum_ref

CFG = M.PRESETS["tiny"]
EPS = jnp.float32(1e-14)
RHO = jnp.float32(6.5)


def _setup(bg, seed=0):
    rng = np.random.default_rng(seed)
    flat = jnp.asarray(M.init_params(CFG, 0))
    imgs = jnp.asarray(rng.standard_normal((bg, CFG.v_patches, CFG.v_patch_dim)).astype(np.float32))
    txts = jnp.asarray(rng.integers(0, CFG.t_vocab, (bg, CFG.t_len)).astype(np.int32))
    e1, e2 = M.encode(CFG, flat, imgs, txts)
    return flat, imgs, txts, e1, e2


def _run_phase_g(e1, e2, bl, gamma=0.9, tau=0.07, u0=None):
    bg = e1.shape[0]
    k = bg // bl
    taus = jnp.full((bl,), tau)
    u1s, u2s = [], []
    for w in range(k):
        off = jnp.int32(w * bl)
        u = jnp.zeros((bl,)) if u0 is None else u0[w * bl:(w + 1) * bl]
        _, _, u1n, u2n = L.phase_g(e1, e2, off, u, u, taus, taus,
                                   jnp.float32(gamma), bl=bl)
        u1s.append(u1n)
        u2s.append(u2n)
    return jnp.concatenate(u1s), jnp.concatenate(u2s)


def test_phase_g_matches_ref():
    _, _, _, e1, e2 = _setup(12)
    bl = 6
    tau = jnp.full((bl,), 0.05)
    u = jnp.full((bl,), 0.3)
    g1, g2, u1n, u2n = L.phase_g(e1, e2, jnp.int32(6), u, u, tau, tau,
                                 jnp.float32(0.4), bl=bl)
    diag = 6 + jnp.arange(bl, dtype=jnp.int32)
    g1r = pair_exp_rowsum_ref(e1[6:], e2, diag, tau)
    g2r = pair_exp_rowsum_ref(e2[6:], e1, diag, tau)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g1r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g2r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(u1n), np.asarray(0.6 * u + 0.4 * g1r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(u2n), np.asarray(0.6 * u + 0.4 * g2r), rtol=1e-5)


def test_phase_g_gamma_one_is_memoryless():
    # gamma=1 (the OpenCLIP equivalence) must ignore u history entirely.
    _, _, _, e1, e2 = _setup(8)
    bl = 4
    tau = jnp.full((bl,), 0.07)
    a = L.phase_g(e1, e2, jnp.int32(0), jnp.zeros((bl,)), jnp.zeros((bl,)),
                  tau, tau, jnp.float32(1.0), bl=bl)
    b = L.phase_g(e1, e2, jnp.int32(0), jnp.full((bl,), 9.9), jnp.full((bl,), -3.0),
                  tau, tau, jnp.float32(1.0), bl=bl)
    np.testing.assert_allclose(np.asarray(a[2]), np.asarray(b[2]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a[3]), np.asarray(b[3]), rtol=1e-6)


def _tau_args(variant, bg, tau=0.07):
    if variant == "rgcl_i":
        return (jnp.full((bg,), tau), jnp.full((bg,), tau * 1.3))
    return (jnp.float32(tau),)


@pytest.mark.parametrize("variant", L.VARIANTS)
@pytest.mark.parametrize("k", [2, 4])
def test_worker_sum_equals_global(variant, k):
    bl = 4
    bg = k * bl
    flat, imgs, txts, e1, e2 = _setup(bg)
    gamma = 1.0 if variant == "mbcl" else 0.7
    u1g, u2g = _run_phase_g(e1, e2, bl, gamma=gamma)
    taus = _tau_args(variant, bg)

    acc = None
    for w in range(k):
        off = w * bl
        out = L.step(variant, CFG, flat, imgs[off:off + bl], txts[off:off + bl],
                     e1, e2, u1g, u2g, taus, jnp.int32(off), EPS, RHO,
                     bl=bl, bg=bg, k_workers=k)
        if acc is None:
            acc = dict(out)
        else:
            for key in out:
                if key.startswith("tau") and variant == "rgcl_i":
                    acc[key] = jnp.concatenate([acc[key], out[key]])
                else:
                    acc[key] = acc[key] + out[key]

    ref = L.step(variant, CFG, flat, imgs, txts, e1, e2, u1g, u2g,
                 _tau_args(variant, bg), jnp.int32(0), EPS, RHO,
                 bl=bg, bg=bg, k_workers=1)

    def close(x, y, tol=2e-4):
        x, y = np.asarray(x), np.asarray(y)
        scale = max(1e-6, float(np.max(np.abs(y))))
        np.testing.assert_allclose(x / scale, y / scale, atol=tol)

    close(acc["grad"], ref["grad"])
    close(acc["loss"], ref["loss"])
    for key in acc:
        if key.startswith("tau"):
            close(acc[key], ref[key])


def test_gcl_grad_matches_direct_autodiff():
    # With weights w = tau/(eps+u) frozen, the surrogate gradient must equal
    # the direct gradient of  tau * mean_i [w1_i g1_i + w2_i g2_i].
    bl = bg = 8
    flat, imgs, txts, e1, e2 = _setup(bg)
    u1g, u2g = _run_phase_g(e1, e2, bl, gamma=0.8)
    tau = 0.07
    out = L.step("gcl", CFG, flat, imgs, txts, e1, e2, u1g, u2g,
                 (jnp.float32(tau),), jnp.int32(0), EPS, RHO,
                 bl=bl, bg=bg, k_workers=1)

    w1 = tau / (1e-14 + u1g)
    w2 = tau / (1e-14 + u2g)
    diag = jnp.arange(bg, dtype=jnp.int32)
    taus = jnp.full((bg,), tau)

    def direct(p):
        f1, f2 = M.encode(CFG, p, imgs, txts)
        g1 = pair_exp_rowsum_ref(f1, f2, diag, taus)
        g2 = pair_exp_rowsum_ref(f2, f1, diag, taus)
        return jnp.mean(w1 * g1 + w2 * g2)

    ref_grad = jax.grad(direct)(flat)
    scale = float(jnp.max(jnp.abs(ref_grad)))
    np.testing.assert_allclose(np.asarray(out["grad"]) / scale,
                               np.asarray(ref_grad) / scale, atol=3e-5)


def test_mbcl_grad_matches_infonce():
    # gamma=1, u=g: the mbcl step gradient must equal the direct gradient of
    # the global-batch MBCL loss mean_i log(1/B + (B-1)/B g_i) (both sides).
    bl = bg = 8
    flat, imgs, txts, e1, e2 = _setup(bg)
    u1g, u2g = _run_phase_g(e1, e2, bl, gamma=1.0)
    tau = 0.07
    out = L.step("mbcl", CFG, flat, imgs, txts, e1, e2, u1g, u2g,
                 (jnp.float32(tau),), jnp.int32(0), EPS, RHO,
                 bl=bl, bg=bg, k_workers=1)
    diag = jnp.arange(bg, dtype=jnp.int32)
    taus = jnp.full((bg,), tau)

    def direct(p):
        f1, f2 = M.encode(CFG, p, imgs, txts)
        g1 = pair_exp_rowsum_ref(f1, f2, diag, taus)
        g2 = pair_exp_rowsum_ref(f2, f1, diag, taus)
        t1 = jnp.log(1.0 / bg + (bg - 1.0) / bg * g1)
        t2 = jnp.log(1.0 / bg + (bg - 1.0) / bg * g2)
        return jnp.mean(t1 + t2)

    ref_grad = jax.grad(direct)(flat)
    scale = float(jnp.max(jnp.abs(ref_grad)))
    np.testing.assert_allclose(np.asarray(out["grad"]) / scale,
                               np.asarray(ref_grad) / scale, atol=3e-5)


def test_rgcl_g_tau_grad_matches_direct():
    # Eq. (10) == d/dtau of the true RGCL-g objective with u == g (gamma=1
    # makes u the exact batch estimator, so the comparison is exact).
    bl = bg = 8
    flat, imgs, txts, e1, e2 = _setup(bg)
    u1g, u2g = _run_phase_g(e1, e2, bl, gamma=1.0)
    tau = 0.07
    out = L.step("rgcl_g", CFG, flat, imgs, txts, e1, e2, u1g, u2g,
                 (jnp.float32(tau),), jnp.int32(0), EPS, RHO,
                 bl=bl, bg=bg, k_workers=1)
    diag = jnp.arange(bg, dtype=jnp.int32)

    def direct(t):
        f1, f2 = M.encode(CFG, flat, imgs, txts)
        taus = jnp.full((bg,), t)
        g1 = pair_exp_rowsum_ref(f1, f2, diag, taus)
        g2 = pair_exp_rowsum_ref(f2, f1, diag, taus)
        # weights 1/(eps+u) frozen at u=g like the estimator does
        l1 = jnp.log(1e-14 + jax.lax.stop_gradient(g1)) \
            + (g1 - jax.lax.stop_gradient(g1)) / (1e-14 + jax.lax.stop_gradient(g1))
        l2 = jnp.log(1e-14 + jax.lax.stop_gradient(g2)) \
            + (g2 - jax.lax.stop_gradient(g2)) / (1e-14 + jax.lax.stop_gradient(g2))
        return t * jnp.mean(l1 + l2 + 2 * RHO)

    ref = jax.grad(direct)(jnp.float32(tau))
    np.testing.assert_allclose(float(out["tau_grad"]), float(ref), rtol=1e-3)


def test_loss_finite_across_variants():
    bl, k = 4, 2
    bg = bl * k
    flat, imgs, txts, e1, e2 = _setup(bg)
    u1g, u2g = _run_phase_g(e1, e2, bl, gamma=0.9)
    for variant in L.VARIANTS:
        out = L.step(variant, CFG, flat, imgs[:bl], txts[:bl], e1, e2,
                     u1g, u2g, _tau_args(variant, bg), jnp.int32(0), EPS, RHO,
                     bl=bl, bg=bg, k_workers=k)
        for key, v in out.items():
            assert bool(jnp.all(jnp.isfinite(v))), (variant, key)
