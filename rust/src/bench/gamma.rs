//! Fig. 5 / Appendix B "Choice of γ_min": the γ_min × batch-size
//! interaction. FastCLIP-v3 with γ_min ∈ {0.2, 0.8} at two global batch
//! sizes; the paper's observation is a three-stage pattern where large
//! γ_min wins in the middle stage and small γ_min catches up late, with
//! the middle stage lasting longer at larger batch.

use anyhow::Result;

use crate::config::{Algorithm, GammaSchedule};
use crate::output::{sparkline, Table};
use crate::util::{Args, Json};

use super::common::{algo_config, apply_overrides, progress_logger, results_dir, run_seeds, Setting};

pub fn gamma_min(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut table = Table::new(
        "Fig. 5 analog — gamma_min x batch size (FastCLIP-v3)",
        &["Bundle", "gamma_min", "Datacomp(mid)", "Datacomp(final)"],
    );
    let bundles = match args.get("bundles") {
        Some(list) => list.split(',').map(|s| s.to_string()).collect::<Vec<_>>(),
        None => vec!["artifacts/tiny_k2_b4".to_string(), "artifacts/tiny_k2_b32".to_string()],
    };
    let mut json_rows = Vec::new();
    for bundle in &bundles {
        for gamma_min in [0.2f32, 0.8] {
            let mut cfg = algo_config(Setting::Medium, Algorithm::FastClipV3);
            cfg.set_bundle(bundle);
            let epochs = (cfg.steps / cfg.iters_per_epoch).max(1);
            cfg.gamma = GammaSchedule::Cosine { gamma_min, decay_epochs: (epochs / 2).max(1) };
            cfg.eval_every = args.u32_or("eval-every", (cfg.steps / 8).max(1))?;
            let seeds = apply_overrides(&mut cfg, args)?;
            cfg.gamma = GammaSchedule::Cosine {
                gamma_min,
                decay_epochs: ((cfg.steps / cfg.iters_per_epoch).max(1) / 2).max(1),
            };
            let results =
                run_seeds(&cfg, &seeds[..1], &format!("{bundle} gmin={gamma_min}"), log)?;
            let r = &results[0];
            let curve: Vec<f32> = r.evals.iter().map(|e| e.summary.datacomp).collect();
            log.status(&format!("  {} gmin={gamma_min}: {}", bundle, sparkline(&curve, 32)));
            let mid = curve.get(curve.len() / 2).copied().unwrap_or(f32::NAN);
            let fin = curve.last().copied().unwrap_or(f32::NAN);
            table.row(vec![
                bundle.clone(),
                format!("{gamma_min}"),
                format!("{mid:.2}"),
                format!("{fin:.2}"),
            ]);
            json_rows.push(Json::obj(vec![
                ("bundle", Json::str(bundle.clone())),
                ("gamma_min", Json::num(gamma_min as f64)),
                (
                    "curve",
                    Json::arr(r.evals.iter().map(|e| {
                        Json::obj(vec![
                            ("step", Json::num(e.step as f64)),
                            ("datacomp", Json::num(e.summary.datacomp as f64)),
                        ])
                    })),
                ),
            ]));
        }
    }
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join("gamma_min.csv"))?;
    crate::output::write_result(&dir, "gamma_min", &Json::arr(json_rows))?;
    Ok(())
}
