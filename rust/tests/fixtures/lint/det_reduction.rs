pub fn total(xs: &[f32]) -> f32 {
    xs.iter().sum::<f32>()
}
