//! Snapshot writing and checkpoint reading (DESIGN.md §9).
//!
//! On-disk layout of one checkpoint (`<ckpt_dir>/step_NNNNNNNN/`):
//!
//! ```text
//! MANIFEST.json        versioned manifest: run identity + hashed blob table
//! params.f32           replicated parameters (written once, by rank 0)
//! u_rank<r>.f32        rank r's u1‖u2 inner estimators (Eq. 1)
//! tau_rank<r>.f32      rank r's temperature state (rule-specific layout)
//! tau_rank<r>.u64      …integer part (Adam step counters, decay flag)
//! loader_rank<r>.u64   rank r's ShardLoader position + RNG stream state
//! opt_full.f32/.u64    replicated optimizer state (naive/ring reduction)
//! opt_rank<r>.f32/.u64 per-rank optimizer shards (sharded reduction)
//! ef_rank<r>.resid     rank r's topk error-feedback residuals (--wire
//!                      topk runs only, DESIGN.md §15)
//! ```
//!
//! **Write protocol** (collective, driven by the trainer): rank 0 creates
//! a staging directory `.stage_step_NNNNNNNN`; every rank writes its own
//! blobs; rank 0 then writes the parameters, hashes every staged blob
//! into the manifest, writes `MANIFEST.json` *last* and atomically
//! renames the staging directory into place. A crash at any point leaves
//! either the previous checkpoints untouched or a dead staging directory
//! that the next successful snapshot sweeps away (`sweep_debris`) —
//! never a half-readable checkpoint. Re-finalizing an already-written
//! step sets the old directory aside before renaming, so not even that
//! window can destroy the only checkpoint for a step.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::config::{Algorithm, OptimizerKind, TempRule, TrainConfig};
use crate::coordinator::{
    GlobalTau, GlobalTauState, IndividualTau, IndividualTauState, TauState, UState,
};
use crate::data::{shard_len_for, LoaderState, ShardLoader};
use crate::optim::OptimState;
use crate::util::RngState;

use super::blob;
use super::manifest::{CkptManifest, CkptMeta, MANIFEST_FILE};

// ------------------------------------------------------------ blob names

fn u_blob(rank: usize) -> String {
    format!("u_rank{rank}")
}

fn tau_blob(rank: usize) -> String {
    format!("tau_rank{rank}")
}

fn loader_blob(rank: usize) -> String {
    format!("loader_rank{rank}")
}

fn opt_blob(rank: usize, sharded: bool) -> String {
    if sharded {
        format!("opt_rank{rank}")
    } else {
        "opt_full".to_string()
    }
}

fn ef_blob(rank: usize) -> String {
    format!("ef_rank{rank}")
}

// ---------------------------------------------------- temperature codec

/// Serializable temperature state, mirroring
/// [`crate::coordinator::TauState`] (whose live types carry run-config
/// hyperparameters that do not belong in a checkpoint).
#[derive(Debug, Clone, PartialEq)]
pub enum TauCkpt {
    /// Constant-τ rule: the single value.
    Constant {
        /// the constant temperature
        tau: f32,
    },
    /// Global learnable τ: value + scalar-Adam moments.
    Global(GlobalTauState),
    /// Per-sample learnable τ: shard values + per-sample Adam moments.
    Individual(IndividualTauState),
}

/// Snapshot a live temperature state.
pub fn export_tau(tau: &TauState) -> TauCkpt {
    match tau {
        TauState::Constant(t) => TauCkpt::Constant { tau: *t },
        TauState::Global(g) => TauCkpt::Global(g.export()),
        TauState::Individual(i) => TauCkpt::Individual(i.export()),
    }
}

/// Rebuild a live temperature state from a checkpoint. The rule comes
/// from the run config and must match the checkpointed variant.
pub fn restore_tau(cfg: &TrainConfig, shard_len: usize, ck: &TauCkpt) -> Result<TauState> {
    match (cfg.algorithm.temp_rule(), ck) {
        (TempRule::Constant, TauCkpt::Constant { tau }) => Ok(TauState::Constant(*tau)),
        (TempRule::GlobalLearnable, TauCkpt::Global(s)) => {
            let mut g = GlobalTau::new(cfg);
            g.import(s);
            Ok(TauState::Global(g))
        }
        (TempRule::Individual, TauCkpt::Individual(s)) => {
            let mut i = IndividualTau::new(shard_len, cfg.tau_init, cfg.tau_min);
            i.import(s.clone())?;
            Ok(TauState::Individual(i))
        }
        (rule, _) => bail!(
            "checkpoint temperature state does not match the {} rule of algorithm {}",
            match rule {
                TempRule::Constant => "constant",
                TempRule::GlobalLearnable => "global-learnable",
                TempRule::Individual => "individual",
            },
            cfg.algorithm.id()
        ),
    }
}

/// Blob layout per rule — f32 part, optional u64 part:
/// constant `[τ]` / — ; global `[τ, lr, m, v]` / `[t, decayed]`;
/// individual `τ1‖τ2‖m1‖v1‖m2‖v2` / `t1‖t2`.
fn tau_to_blobs(t: &TauCkpt) -> (Vec<f32>, Option<Vec<u64>>) {
    match t {
        TauCkpt::Constant { tau } => (vec![*tau], None),
        TauCkpt::Global(s) => (
            vec![s.tau, s.lr, s.adam_m, s.adam_v],
            Some(vec![s.adam_t as u64, s.decayed as u64]),
        ),
        TauCkpt::Individual(s) => {
            let mut f = Vec::with_capacity(6 * s.tau1.len());
            for part in [&s.tau1, &s.tau2, &s.m1, &s.v1, &s.m2, &s.v2] {
                f.extend_from_slice(part);
            }
            let mut u = Vec::with_capacity(2 * s.t1.len());
            u.extend(s.t1.iter().map(|&x| x as u64));
            u.extend(s.t2.iter().map(|&x| x as u64));
            (f, Some(u))
        }
    }
}

fn tau_from_blobs(rule: TempRule, f: Vec<f32>, u: Option<Vec<u64>>) -> Result<TauCkpt> {
    match rule {
        TempRule::Constant => {
            ensure!(f.len() == 1, "constant-tau blob has {} elements, expected 1", f.len());
            Ok(TauCkpt::Constant { tau: f[0] })
        }
        TempRule::GlobalLearnable => {
            let u = u.ok_or_else(|| anyhow!("global-tau checkpoint missing integer blob"))?;
            ensure!(f.len() == 4 && u.len() == 2, "global-tau blob shape mismatch");
            Ok(TauCkpt::Global(GlobalTauState {
                tau: f[0],
                lr: f[1],
                adam_m: f[2],
                adam_v: f[3],
                adam_t: u[0] as i32,
                decayed: u[1] != 0,
            }))
        }
        TempRule::Individual => {
            let u = u.ok_or_else(|| anyhow!("individual-tau checkpoint missing integer blob"))?;
            ensure!(f.len() % 6 == 0, "individual-tau blob length {} not 6·L", f.len());
            let l = f.len() / 6;
            ensure!(u.len() == 2 * l, "individual-tau integer blob length mismatch");
            let part = |i: usize| f[i * l..(i + 1) * l].to_vec();
            Ok(TauCkpt::Individual(IndividualTauState {
                tau1: part(0),
                tau2: part(1),
                m1: part(2),
                v1: part(3),
                m2: part(4),
                v2: part(5),
                t1: u[..l].iter().map(|&x| x as i32).collect(),
                t2: u[l..].iter().map(|&x| x as i32).collect(),
            }))
        }
    }
}

// -------------------------------------------------------- loader codec

fn loader_to_u64s(s: &LoaderState) -> Vec<u64> {
    let mut out = vec![
        s.epoch as u64,
        s.cursor as u64,
        s.rng.state,
        s.rng.spare_bits.is_some() as u64,
        s.rng.spare_bits.unwrap_or(0),
        s.order.len() as u64,
    ];
    out.extend(s.order.iter().map(|&p| p as u64));
    out
}

fn loader_from_u64s(xs: &[u64]) -> Result<LoaderState> {
    ensure!(xs.len() >= 6, "loader blob has {} words, expected >= 6", xs.len());
    let order_len = xs[5] as usize;
    ensure!(xs.len() == 6 + order_len, "loader blob length mismatch");
    Ok(LoaderState {
        epoch: xs[0] as u32,
        cursor: xs[1] as usize,
        order: xs[6..].iter().map(|&v| v as usize).collect(),
        rng: RngState { state: xs[2], spare_bits: if xs[3] != 0 { Some(xs[4]) } else { None } },
    })
}

// ----------------------------------------------------- optimizer codec

fn optim_to_blobs(s: &OptimState) -> (Vec<f32>, Vec<u64>) {
    let mut f = Vec::with_capacity(s.tensors.len() * s.n());
    for t in &s.tensors {
        f.extend_from_slice(t);
    }
    (f, vec![s.t as u64])
}

fn optim_from_blobs(kind: OptimizerKind, f: Vec<f32>, u: &[u64]) -> Result<OptimState> {
    let tc = OptimState::tensor_count(kind);
    ensure!(u.len() == 1, "optimizer integer blob has {} words, expected 1", u.len());
    ensure!(
        f.len() % tc == 0,
        "{} optimizer blob length {} is not a multiple of {tc} tensors",
        kind.id(),
        f.len()
    );
    let n = f.len() / tc;
    let tensors = (0..tc).map(|i| f[i * n..(i + 1) * n].to_vec()).collect();
    Ok(OptimState { kind, t: u[0] as i64, tensors })
}

// --------------------------------------------------------- write side

/// Staging directory for a snapshot at `step` (sibling of the final
/// `step_NNNNNNNN` directory so the rename stays on one filesystem).
pub fn stage_path(root: &Path, step: u32) -> PathBuf {
    root.join(format!(".stage_step_{step:08}"))
}

/// Final directory name for a snapshot at `step`.
pub fn step_path(root: &Path, step: u32) -> PathBuf {
    root.join(format!("step_{step:08}"))
}

/// Create (or sweep and recreate) the staging directory. Rank 0 only.
pub fn prepare_stage(stage: &Path) -> Result<()> {
    if stage.exists() {
        std::fs::remove_dir_all(stage)
            .with_context(|| format!("sweeping stale stage {}", stage.display()))?;
    }
    std::fs::create_dir_all(stage)
        .with_context(|| format!("creating stage {}", stage.display()))
}

/// Write one rank's state blobs into the staging directory. Collective:
/// every rank calls this between the trainer's barriers. `optim` is
/// `Some` on every rank under the sharded reduction (each writes its own
/// shard) and only on rank 0 under replicated reductions (the state is
/// identical everywhere — one blob suffices and keeps snapshots small).
/// `resid` is `Some` on every rank when the gradient wire runs the
/// `topk` codec: each rank's error-feedback residuals are genuinely
/// per-rank state, and snapshotting them is what makes `topk` resume
/// bitwise-exact (DESIGN.md §15).
pub fn write_rank_state(
    stage: &Path,
    rank: usize,
    ustate: &UState,
    tau: &TauState,
    loader: &ShardLoader,
    optim: Option<(&OptimState, bool)>,
    resid: Option<&[f32]>,
) -> Result<()> {
    let (u1, u2) = ustate.parts();
    let mut u = Vec::with_capacity(u1.len() * 2);
    u.extend_from_slice(u1);
    u.extend_from_slice(u2);
    blob::write_f32_blob(stage, &u_blob(rank), &u)?;

    let (tf, tu) = tau_to_blobs(&export_tau(tau));
    blob::write_f32_blob(stage, &tau_blob(rank), &tf)?;
    if let Some(tu) = tu {
        blob::write_u64_blob(stage, &tau_blob(rank), &tu)?;
    }

    blob::write_u64_blob(stage, &loader_blob(rank), &loader_to_u64s(&loader.export()))?;

    if let Some((state, sharded)) = optim {
        let (of, ou) = optim_to_blobs(state);
        let name = opt_blob(rank, sharded);
        blob::write_f32_blob(stage, &name, &of)?;
        blob::write_u64_blob(stage, &name, &ou)?;
    }

    if let Some(resid) = resid {
        blob::write_resid_blob(stage, &ef_blob(rank), resid)?;
    }
    Ok(())
}

/// Finalize a staged snapshot (rank 0 only, after all ranks wrote):
/// write the replicated parameters, hash every staged blob into the
/// manifest, write `MANIFEST.json`, atomically rename the stage into
/// `step_NNNNNNNN`, and apply the retention policy (`keep_last` most
/// recent checkpoints are retained; 0 keeps all). Returns the final
/// checkpoint directory.
pub fn finalize(
    root: &Path,
    stage: &Path,
    meta: &CkptMeta,
    params: &[f32],
    keep_last: usize,
) -> Result<PathBuf> {
    ensure!(
        params.len() == meta.n_params,
        "finalize: params length {} != meta.n_params {}",
        params.len(),
        meta.n_params
    );
    blob::write_f32_blob(stage, "params", params)?;
    let blobs = blob::scan_dir(stage)?;
    CkptManifest { meta: meta.clone(), blobs }.write(stage)?;

    // durability: flush every staged file (and the stage directory) to
    // disk BEFORE the rename, so a power loss cannot persist the rename
    // ahead of the bytes it names — the atomicity claim must hold
    // against OS crashes, not just process crashes
    sync_files_and_dir(stage)?;

    let final_dir = step_path(root, meta.step);
    if final_dir.exists() {
        // never delete a finalized checkpoint before its replacement is
        // in place: move it aside first. A crash between the renames
        // leaves the old state recoverable under .old_step_* (and the
        // completed stage on disk) instead of destroying the only
        // checkpoint for this step.
        let doomed = root.join(format!(".old_step_{:08}", meta.step));
        if doomed.exists() {
            std::fs::remove_dir_all(&doomed)
                .with_context(|| format!("sweeping {}", doomed.display()))?;
        }
        std::fs::rename(&final_dir, &doomed)
            .with_context(|| format!("setting aside {}", final_dir.display()))?;
    }
    std::fs::rename(stage, &final_dir).with_context(|| {
        format!("renaming {} -> {}", stage.display(), final_dir.display())
    })?;
    fsync_dir(root); // persist the rename itself (best effort)

    // sweep debris: the .old_step_* set aside above, plus any stale
    // .stage_step_* a crashed earlier run left behind (a changed
    // ckpt_every would otherwise never revisit that step to sweep it)
    sweep_debris(root)?;

    if keep_last > 0 {
        let mut steps = list_steps(root)?;
        while steps.len() > keep_last {
            let (_, dir) = steps.remove(0); // oldest first
            std::fs::remove_dir_all(&dir)
                .with_context(|| format!("retention: removing {}", dir.display()))?;
        }
    }
    Ok(final_dir)
}

/// fsync every regular file in `dir`, then the directory itself.
fn sync_files_and_dir(dir: &Path) -> Result<()> {
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("syncing {}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_file() {
            std::fs::File::open(&path)
                .and_then(|f| f.sync_all())
                .with_context(|| format!("fsync {}", path.display()))?;
        }
    }
    fsync_dir(dir);
    Ok(())
}

/// Directory fsync, best effort: not every platform allows opening a
/// directory handle, and a missed directory sync only widens the crash
/// window — it never corrupts data the file syncs already persisted.
fn fsync_dir(dir: &Path) {
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Remove leftover staging / set-aside directories. Called after a
/// successful rename, when the freshly finalized checkpoint is already
/// in place — everything still matching a debris prefix is garbage from
/// this or an earlier (possibly crashed) run.
fn sweep_debris(root: &Path) -> Result<()> {
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(_) => return Ok(()),
    };
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if name.starts_with(".stage_step_") || name.starts_with(".old_step_") {
            std::fs::remove_dir_all(&path)
                .with_context(|| format!("sweeping debris {}", path.display()))?;
        }
    }
    Ok(())
}

/// All finalized checkpoints under `root`, oldest first.
fn list_steps(root: &Path) -> Result<Vec<(u32, PathBuf)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(root) {
        Ok(e) => e,
        Err(_) => return Ok(out), // no directory yet: no checkpoints
    };
    for entry in entries {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        let Some(num) = name.strip_prefix("step_") else {
            continue;
        };
        let Ok(step) = num.parse::<u32>() else {
            continue;
        };
        if path.join(MANIFEST_FILE).exists() {
            out.push((step, path));
        }
    }
    out.sort_by_key(|(s, _)| *s);
    Ok(out)
}

/// The most recent finalized checkpoint under `root`, if any.
pub fn latest(root: &Path) -> Result<Option<PathBuf>> {
    Ok(list_steps(root)?.pop().map(|(_, p)| p))
}

// ---------------------------------------------------------- read side

/// One rank's deserialized training state.
#[derive(Debug, Clone)]
pub struct RankState {
    /// Eq. (1) u estimators, image side, one per shard sample
    pub u1: Vec<f32>,
    /// Eq. (1) u estimators, text side
    pub u2: Vec<f32>,
    /// temperature-rule state
    pub tau: TauCkpt,
    /// exact loader position — present for same-world resume; `None`
    /// after elastic resizing (the shard partition changed)
    pub loader: Option<LoaderState>,
    /// epoch to fast-forward a fresh loader to when `loader` is `None`
    pub epoch: u32,
    /// topk error-feedback residuals (full parameter length) — present
    /// only when the checkpointed run banked them (`--wire topk`) and
    /// the world size is unchanged; elastic resume restarts from zeros
    pub resid: Option<Vec<f32>>,
}

/// Outcome of [`Checkpoint::verify`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VerifyReport {
    /// blobs hashed
    pub blobs: usize,
    /// total blob bytes read
    pub bytes: u64,
}

/// An opened (manifest-parsed) checkpoint directory.
pub struct Checkpoint {
    dir: PathBuf,
    manifest: CkptManifest,
}

impl Checkpoint {
    /// Open a checkpoint: `path` is either one `step_NNNNNNNN` directory
    /// (contains `MANIFEST.json`) or a checkpoint root, in which case the
    /// most recent finalized step is opened.
    pub fn open(path: &Path) -> Result<Checkpoint> {
        let dir = if path.join(MANIFEST_FILE).exists() {
            path.to_path_buf()
        } else {
            latest(path)?.ok_or_else(|| {
                anyhow!("no checkpoint found at {} (no MANIFEST.json, no step_* below)", path.display())
            })?
        };
        let manifest = CkptManifest::load(&dir)
            .with_context(|| format!("opening checkpoint {}", dir.display()))?;
        Ok(Checkpoint { dir, manifest })
    }

    /// The resolved `step_NNNNNNNN` directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The run identity recorded at snapshot time.
    pub fn meta(&self) -> &CkptMeta {
        &self.manifest.meta
    }

    /// The full parsed manifest (meta + blob table).
    pub fn manifest(&self) -> &CkptManifest {
        &self.manifest
    }

    /// The algorithm's temperature rule, derived from the manifest.
    fn temp_rule(&self) -> Result<TempRule> {
        Ok(Algorithm::from_id(&self.manifest.meta.algorithm)?.temp_rule())
    }

    fn read_f32(&self, name: &str) -> Result<Vec<f32>> {
        blob::read_f32_verified(&self.dir, self.manifest.blob(&format!("{name}.f32"))?)
    }

    fn read_u64(&self, name: &str) -> Result<Vec<u64>> {
        blob::read_u64_verified(&self.dir, self.manifest.blob(&format!("{name}.u64"))?)
    }

    fn read_u64_opt(&self, name: &str) -> Result<Option<Vec<u64>>> {
        if self.manifest.has_blob(&format!("{name}.u64")) {
            Ok(Some(self.read_u64(name)?))
        } else {
            Ok(None)
        }
    }

    fn read_resid_opt(&self, name: &str) -> Result<Option<Vec<f32>>> {
        if self.manifest.has_blob(&format!("{name}.resid")) {
            let spec = self.manifest.blob(&format!("{name}.resid"))?;
            Ok(Some(blob::read_resid_verified(&self.dir, spec)?))
        } else {
            Ok(None)
        }
    }

    /// The replicated parameters.
    pub fn load_params(&self) -> Result<Vec<f32>> {
        let p = self.read_f32("params")?;
        ensure!(
            p.len() == self.manifest.meta.n_params,
            "params blob has {} values, manifest says {}",
            p.len(),
            self.manifest.meta.n_params
        );
        Ok(p)
    }

    /// One rank's exact state, as written (rank < checkpoint world size).
    pub fn load_rank_state(&self, rank: usize) -> Result<RankState> {
        let world = self.manifest.meta.world;
        ensure!(rank < world, "rank {rank} out of range for checkpoint world {world}");
        let u = self.read_f32(&u_blob(rank))?;
        ensure!(u.len() % 2 == 0, "u blob length {} is odd", u.len());
        let l = u.len() / 2;
        let expect = shard_len_for(self.manifest.meta.n_train, world, rank)?;
        ensure!(l == expect, "u blob covers {l} samples, shard has {expect}");
        let (u1, u2) = (u[..l].to_vec(), u[l..].to_vec());

        let tau = tau_from_blobs(
            self.temp_rule()?,
            self.read_f32(&tau_blob(rank))?,
            self.read_u64_opt(&tau_blob(rank))?,
        )?;

        let loader = loader_from_u64s(&self.read_u64(&loader_blob(rank))?)?;
        ensure!(
            loader.order.len() == l,
            "loader blob covers {} positions, shard has {l}",
            loader.order.len()
        );
        let epoch = loader.epoch;

        let resid = self.read_resid_opt(&ef_blob(rank))?;
        if let Some(r) = &resid {
            ensure!(
                r.len() == self.manifest.meta.n_params,
                "residual blob covers {} elements, model has {} parameters",
                r.len(),
                self.manifest.meta.n_params
            );
        }
        Ok(RankState { u1, u2, tau, loader: Some(loader), epoch, resid })
    }

    /// Optimizer state sized for `target_rank` of a `target_world`-worker
    /// run under the target reduction strategy, converting between
    /// replicated and sharded layouts (and re-partitioning across a world
    /// resize) as needed — DESIGN.md §9 "elastic re-sharding".
    pub fn load_optimizer(
        &self,
        target_rank: usize,
        target_world: usize,
        target_sharded: bool,
    ) -> Result<OptimState> {
        let meta = &self.manifest.meta;
        let kind = OptimizerKind::from_id(&meta.optimizer)?;
        let source_sharded = meta.reduce == "sharded";
        let p = meta.n_params;

        if source_sharded && target_sharded && target_world == meta.world {
            // fast path: shard layouts coincide
            let name = opt_blob(target_rank, true);
            return optim_from_blobs(kind, self.read_f32(&name)?, &self.read_u64(&name)?);
        }

        // materialize the full state, then re-slice for the target
        let full = if source_sharded {
            let mut shards = Vec::with_capacity(meta.world);
            for r in 0..meta.world {
                let name = opt_blob(r, true);
                shards.push(optim_from_blobs(kind, self.read_f32(&name)?, &self.read_u64(&name)?)?);
            }
            super::elastic::concat_optimizer_shards(kind, &shards, p)?
        } else {
            let name = opt_blob(0, false);
            let full = optim_from_blobs(kind, self.read_f32(&name)?, &self.read_u64(&name)?)?;
            ensure!(full.n() == p, "optimizer blob covers {} params, expected {p}", full.n());
            full
        };

        if target_sharded {
            let (lo, hi) = crate::comm::chunk_bounds(p, target_world, target_rank);
            Ok(super::elastic::slice_optimizer_state(&full, lo, hi))
        } else {
            Ok(full)
        }
    }

    /// Re-hash every blob against the manifest — detects any corruption,
    /// down to a single flipped byte. Returns what was checked.
    pub fn verify(&self) -> Result<VerifyReport> {
        let mut bytes = 0u64;
        for spec in &self.manifest.blobs {
            let b = blob::read_verified(&self.dir, spec)?;
            bytes += b.len() as u64;
        }
        Ok(VerifyReport { blobs: self.manifest.blobs.len(), bytes })
    }
}

// ----------------------------------------------------- resume assembly

/// Everything a worker thread needs to continue a run from a checkpoint.
pub struct RestoredWorker {
    /// replicated parameter vector
    pub params: Vec<f32>,
    /// this rank's u estimators
    pub ustate: UState,
    /// this rank's live temperature state
    pub tau: TauState,
    /// this rank's data loader, positioned (or epoch-fast-forwarded)
    pub loader: ShardLoader,
    /// optimizer state sized for this rank (full or chunk, per strategy)
    pub optim: OptimState,
    /// topk error-feedback residuals, bitwise as checkpointed — `None`
    /// when the checkpoint has none or after an elastic resize (the
    /// trainer then starts the codec from zero residuals)
    pub resid: Option<Vec<f32>>,
    /// completed steps at snapshot time — training resumes here
    pub start_step: u32,
}

/// Check a checkpoint was written by a compatible run. The world size
/// and local batch are deliberately *not* checked here — elastic resume
/// handles K ≠ K′ (and may legitimately change the batch size);
/// [`restore_worker`] rejects a batch-size change on the *exact*
/// same-world path, where it would corrupt the restored loader cursor.
pub fn check_compatible(meta: &CkptMeta, cfg: &TrainConfig, n_params: usize) -> Result<()> {
    ensure!(
        meta.algorithm == cfg.algorithm.id(),
        "checkpoint was written by algorithm '{}', run uses '{}'",
        meta.algorithm,
        cfg.algorithm.id()
    );
    ensure!(
        meta.optimizer == cfg.optimizer.kind.id(),
        "checkpoint optimizer '{}' != run optimizer '{}'",
        meta.optimizer,
        cfg.optimizer.kind.id()
    );
    ensure!(
        meta.n_params == n_params,
        "checkpoint covers {} parameters, model has {n_params}",
        meta.n_params
    );
    ensure!(
        meta.n_train == cfg.data.n_train,
        "checkpoint dataset size {} != run's {}",
        meta.n_train,
        cfg.data.n_train
    );
    ensure!(
        meta.seed == cfg.seed && meta.data_seed == cfg.data.seed,
        "checkpoint seeds ({}, {}) != run seeds ({}, {}) — resume would not be deterministic",
        meta.seed,
        meta.data_seed,
        cfg.seed,
        cfg.data.seed
    );
    let run_hyper = super::manifest::hyper_echo(cfg);
    // pre-§12 checkpoints (written before the precision knob existed)
    // lack the trailing " prec=" field; they were all f32 runs, so an
    // f32 resume whose echo matches theirs up to that suffix is the
    // same trajectory — keep them resumable instead of failing with a
    // misleading "hyperparameters differ"
    let legacy_f32_ok = cfg.precision == crate::kernels::Precision::F32
        && run_hyper.strip_suffix(" prec=f32") == Some(meta.hyper.as_str());
    ensure!(
        meta.hyper == run_hyper || legacy_f32_ok,
        "checkpoint hyperparameters differ from the run's — resume would not \
         continue the checkpointed trajectory\n  checkpoint: {}\n  run:        {run_hyper}",
        meta.hyper
    );
    Ok(())
}

/// Assemble one worker's full state from a checkpoint, handling both
/// exact (same-world) and elastic (K → K′) resume. `sharded` says whether
/// the *resuming* run applies per-rank optimizer shards.
pub fn restore_worker(
    ck: &Checkpoint,
    cfg: &TrainConfig,
    rank: usize,
    world: usize,
    local_batch: usize,
    sharded: bool,
) -> Result<RestoredWorker> {
    let params = ck.load_params()?;
    let rs = if world == ck.meta().world {
        // exact resume restores the loader cursor verbatim; under a
        // different batch size the cursor would be reinterpreted against
        // shifted batch boundaries, silently changing every subsequent
        // batch — the very determinism this subsystem guarantees
        ensure!(
            local_batch == ck.meta().local_batch,
            "checkpoint local batch {} != run's {local_batch}; an exact \
             same-world resume requires matching batch boundaries",
            ck.meta().local_batch
        );
        ck.load_rank_state(rank)?
    } else {
        super::elastic::resize_rank_state(ck, rank, world)?
    };

    let mut loader = ShardLoader::new(cfg.data.n_train, rank, world, local_batch, cfg.seed)?;
    match rs.loader {
        Some(state) => loader.import(state).context("restoring loader position")?,
        None => loader.advance_to_epoch(rs.epoch),
    }

    ensure!(
        rs.u1.len() == loader.shard_len(),
        "restored u state covers {} samples, shard has {}",
        rs.u1.len(),
        loader.shard_len()
    );
    let ustate = UState::from_parts(rs.u1, rs.u2);
    let tau = restore_tau(cfg, loader.shard_len(), &rs.tau)?;
    let optim = ck.load_optimizer(rank, world, sharded)?;

    Ok(RestoredWorker {
        params,
        ustate,
        tau,
        loader,
        optim,
        resid: rs.resid,
        start_step: ck.meta().step,
    })
}
