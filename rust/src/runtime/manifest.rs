//! Artifact-bundle manifest: the typed view of `manifest.json`.
//!
//! A bundle directory (e.g. `artifacts/tiny_k2_b8/`) holds one AOT-lowered
//! HLO-text file per executable, the deterministic initial parameters
//! (`init_params.bin`, f32 LE), and this manifest describing shapes,
//! the flat-parameter segmentation and the executable signatures.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::Json;

/// Model dimensions the Rust side needs (a subset of the Python
/// `ModelConfig`; the rest only matters at lowering time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelInfo {
    /// embedding width d
    pub d_embed: usize,
    /// image patches per sample
    pub v_patches: usize,
    /// flattened size of one patch
    pub v_patch_dim: usize,
    /// text vocabulary size
    pub t_vocab: usize,
    /// tokens per text sample
    pub t_len: usize,
}

/// One leaf of the flat parameter vector (LAMB normalizes per leaf).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamSegment {
    /// leaf name, e.g. `v.proj` / `t.tok`
    pub name: String,
    /// first element in the flat vector
    pub offset: usize,
    /// element count
    pub size: usize,
}

/// Shape+dtype of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSig {
    /// argument/result name
    pub name: String,
    /// dimensions (empty = scalar)
    pub shape: Vec<usize>,
    /// dtype string as lowered (e.g. `f32`, `s32`)
    pub dtype: String,
}

impl TensorSig {
    /// Total element count (1 for scalars).
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Signature of one executable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExecSig {
    /// executable name (`encode`, `phase_g`, `step_<variant>`)
    pub name: String,
    /// input signatures, in call order
    pub inputs: Vec<TensorSig>,
    /// output signatures, in result order
    pub outputs: Vec<TensorSig>,
}

/// The typed view of one bundle's `manifest.json` — or, for the native
/// backend, the synthesized equivalent ([`Manifest::native`]).
#[derive(Debug, Clone)]
pub struct Manifest {
    /// bundle directory (empty for native manifests)
    pub dir: PathBuf,
    /// model preset name (tiny|small|medium|base)
    pub preset: String,
    /// interface dimensions
    pub model: ModelInfo,
    /// flat parameter-vector length P
    pub n_params: usize,
    /// per-leaf segmentation of the flat vector (tiles [0, P) in order)
    pub param_spec: Vec<ParamSegment>,
    /// worker count K the bundle was lowered for
    pub k_workers: usize,
    /// per-worker batch size Bl
    pub local_batch: usize,
    /// global batch Bg = K · Bl
    pub global_batch: usize,
    /// init seed (native manifests generate parameters from it)
    pub seed: u64,
    /// the `step_<variant>` graphs available
    pub variants: Vec<String>,
    /// executable signatures (empty for native manifests)
    pub executables: Vec<ExecSig>,
    /// true for synthesized native-backend manifests (DESIGN.md §10):
    /// no artifact directory, no executables, parameters generated
    /// deterministically from `seed` instead of read from disk
    pub native: bool,
}

impl Manifest {
    /// Synthesize a manifest for the native CPU backend: preset model
    /// dims, the native parameter layout, and a `k_workers × local_batch`
    /// topology — no artifact directory involved (DESIGN.md §10).
    pub fn native(
        preset: &str,
        k_workers: usize,
        local_batch: usize,
        seed: u64,
    ) -> Result<Manifest> {
        ensure!(k_workers > 0, "k_workers must be > 0");
        ensure!(local_batch > 0, "local_batch must be > 0");
        let model = super::native::preset_dims(preset)?;
        let param_spec = super::native::param_spec(&model);
        let n_params = param_spec.iter().map(|s| s.size).sum();
        let manifest = Manifest {
            dir: PathBuf::new(),
            preset: preset.to_string(),
            model,
            n_params,
            param_spec,
            k_workers,
            local_batch,
            global_batch: k_workers * local_batch,
            seed,
            variants: super::native::VARIANTS.iter().map(|v| v.to_string()).collect(),
            executables: Vec::new(),
            native: true,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Load and validate `<dir>/manifest.json` (an artifact bundle).
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        ensure!(
            j.get("version")?.as_usize()? == 1,
            "unsupported manifest version in {}",
            dir.display()
        );
        let model = j.get("model")?;
        let model = ModelInfo {
            d_embed: model.get("d_embed")?.as_usize()?,
            v_patches: model.get("v_patches")?.as_usize()?,
            v_patch_dim: model.get("v_patch_dim")?.as_usize()?,
            t_vocab: model.get("t_vocab")?.as_usize()?,
            t_len: model.get("t_len")?.as_usize()?,
        };

        let mut param_spec = Vec::new();
        for seg in j.get("param_spec")?.as_arr()? {
            param_spec.push(ParamSegment {
                name: seg.get("name")?.as_str()?.to_string(),
                offset: seg.get("offset")?.as_usize()?,
                size: seg.get("size")?.as_usize()?,
            });
        }

        let mut executables = Vec::new();
        if let Json::Obj(m) = j.get("executables")? {
            for (name, sig) in m {
                executables.push(ExecSig {
                    name: name.clone(),
                    inputs: parse_tensors(sig.get("inputs")?)?,
                    outputs: parse_tensors(sig.get("outputs")?)?,
                });
            }
        }

        let manifest = Manifest {
            preset: j.get("preset")?.as_str()?.to_string(),
            model,
            n_params: j.get("n_params")?.as_usize()?,
            param_spec,
            k_workers: j.get("k_workers")?.as_usize()?,
            local_batch: j.get("local_batch")?.as_usize()?,
            global_batch: j.get("global_batch")?.as_usize()?,
            seed: j.get("seed")?.as_f64()? as u64,
            variants: j
                .get("variants")?
                .as_arr()?
                .iter()
                .map(|v| Ok(v.as_str()?.to_string()))
                .collect::<Result<_>>()?,
            executables,
            dir,
            native: false,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.k_workers > 0 && self.local_batch > 0, "empty topology");
        ensure!(
            self.global_batch == self.k_workers * self.local_batch,
            "global batch {} != K {} x local {}",
            self.global_batch,
            self.k_workers,
            self.local_batch
        );
        // param segments must tile [0, n_params) exactly in order
        let mut off = 0;
        for seg in &self.param_spec {
            ensure!(seg.offset == off, "param segment {} misaligned", seg.name);
            off += seg.size;
        }
        ensure!(off == self.n_params, "param segments cover {off} != n_params {}", self.n_params);
        // native manifests have no executables — kernels are in-process
        if !self.native {
            for required in ["encode", "phase_g"] {
                ensure!(self.exec_sig(required).is_some(), "manifest missing executable {required}");
            }
            for v in &self.variants {
                ensure!(
                    self.exec_sig(&format!("step_{v}")).is_some(),
                    "manifest missing executable step_{v}"
                );
            }
        }
        Ok(())
    }

    /// Signature of executable `name`, if the bundle carries it.
    pub fn exec_sig(&self, name: &str) -> Option<&ExecSig> {
        self.executables.iter().find(|e| e.name == name)
    }

    /// Path of executable `name`'s HLO-text file in the bundle.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// (offset, len) pairs for the optimizers (LAMB trust ratios).
    pub fn segments(&self) -> Vec<(usize, usize)> {
        self.param_spec.iter().map(|s| (s.offset, s.size)).collect()
    }

    /// The deterministic initial parameters: generated in-process for
    /// native manifests, read from `init_params.bin` (written by aot.py)
    /// for artifact bundles.
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        if self.native {
            return Ok(super::native::init_params(self));
        }
        let path = self.dir.join("init_params.bin");
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        ensure!(
            bytes.len() == self.n_params * 4,
            "{} is {} bytes, expected {} (n_params {} x 4)",
            path.display(),
            bytes.len(),
            self.n_params * 4,
            self.n_params
        );
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Dims needed by the synthetic data generator.
    pub fn model_dims(&self) -> crate::data::ModelDims {
        crate::data::ModelDims {
            v_patches: self.model.v_patches,
            v_patch_dim: self.model.v_patch_dim,
            t_vocab: self.model.t_vocab,
            t_len: self.model.t_len,
        }
    }
}

fn parse_tensors(j: &Json) -> Result<Vec<TensorSig>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                dtype: t.get("dtype")?.as_str()?.to_string(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUNDLE: &str = "artifacts/tiny_k2_b8";

    fn bundle_available() -> bool {
        Path::new(BUNDLE).join("manifest.json").exists()
    }

    #[test]
    fn native_manifest_synthesizes_without_artifacts() {
        let m = Manifest::native("tiny", 2, 8, 7).unwrap();
        assert!(m.native);
        assert_eq!(m.k_workers, 2);
        assert_eq!(m.local_batch, 8);
        assert_eq!(m.global_batch, 16);
        assert_eq!(m.model.d_embed, 64);
        assert!(m.variants.iter().any(|v| v == "gcl"));
        assert!(m.variants.iter().any(|v| v == "rgcl_i"));
        // segments tile the native parameter vector
        let total: usize = m.segments().iter().map(|(_, l)| l).sum();
        assert_eq!(total, m.n_params);
        // deterministic generated init params, correct length
        let p = m.load_init_params().unwrap();
        assert_eq!(p.len(), m.n_params);
        let p2 = Manifest::native("tiny", 2, 8, 7).unwrap().load_init_params().unwrap();
        assert_eq!(p, p2);
        // a different seed gives different params
        let p3 = Manifest::native("tiny", 2, 8, 8).unwrap().load_init_params().unwrap();
        assert_ne!(p, p3);
    }

    #[test]
    fn native_manifest_rejects_bad_topology_and_preset() {
        assert!(Manifest::native("tiny", 0, 8, 0).is_err());
        assert!(Manifest::native("tiny", 2, 0, 0).is_err());
        let err = Manifest::native("gigantic", 2, 8, 0).unwrap_err();
        assert!(format!("{err}").contains("preset"), "{err}");
    }

    #[test]
    #[ignore = "reads an artifact bundle: needs `make artifacts` (JAX toolchain)"]
    fn loads_tiny_bundle() {
        if !bundle_available() {
            eprintln!("skipping: {BUNDLE} not built (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(BUNDLE).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.k_workers, 2);
        assert_eq!(m.local_batch, 8);
        assert_eq!(m.global_batch, 16);
        assert_eq!(m.model.d_embed, 64);
        assert!(m.n_params > 100_000);
        assert!(m.exec_sig("encode").is_some());
        assert!(m.exec_sig("step_rgcl_g").is_some());
        assert!(m.exec_sig("nonexistent").is_none());
        // segments tile the parameter vector
        let total: usize = m.segments().iter().map(|(_, l)| l).sum();
        assert_eq!(total, m.n_params);
    }

    #[test]
    #[ignore = "reads an artifact bundle: needs `make artifacts` (JAX toolchain)"]
    fn init_params_match_n_params() {
        if !bundle_available() {
            return;
        }
        let m = Manifest::load(BUNDLE).unwrap();
        let p = m.load_init_params().unwrap();
        assert_eq!(p.len(), m.n_params);
        // layernorm gains are initialized to exactly 1.0 — spot-check one
        let lnf = m.param_spec.iter().find(|s| s.name == "v.lnf.g").unwrap();
        assert!(p[lnf.offset..lnf.offset + lnf.size].iter().all(|&v| v == 1.0));
        // and the vector is not all zeros
        assert!(p.iter().any(|&v| v != 0.0 && v != 1.0));
    }

    #[test]
    #[ignore = "reads an artifact bundle: needs `make artifacts` (JAX toolchain)"]
    fn signatures_have_expected_shapes() {
        if !bundle_available() {
            return;
        }
        let m = Manifest::load(BUNDLE).unwrap();
        let enc = m.exec_sig("encode").unwrap();
        assert_eq!(enc.inputs[0].shape, vec![m.n_params]);
        assert_eq!(enc.outputs[0].shape, vec![m.local_batch, m.model.d_embed]);
        let step = m.exec_sig("step_gcl").unwrap();
        assert_eq!(step.outputs[0].shape, vec![m.n_params]); // grad
        assert_eq!(step.outputs[1].shape, Vec::<usize>::new()); // loss scalar
        let rgcl_i = m.exec_sig("step_rgcl_i").unwrap();
        assert_eq!(rgcl_i.outputs[2].shape, vec![m.local_batch]); // tau1_grad
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load("artifacts/does_not_exist").is_err());
    }
}
