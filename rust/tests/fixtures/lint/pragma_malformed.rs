pub fn fine() -> u32 {
    // lint:allow(err-unwrap)
    // lint:allow(no-such-rule): bogus rule id
    7
}
