# L2 model: shapes, determinism, param bookkeeping, encoder invariants.
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.PRESETS["tiny"]


def _batch(bsz, seed=0):
    rng = np.random.default_rng(seed)
    imgs = rng.standard_normal((bsz, CFG.v_patches, CFG.v_patch_dim)).astype(np.float32)
    txts = rng.integers(0, CFG.t_vocab, (bsz, CFG.t_len)).astype(np.int32)
    return jnp.asarray(imgs), jnp.asarray(txts)


def test_param_spec_matches_flat_size():
    for name, cfg in M.PRESETS.items():
        total = sum(int(np.prod(s)) for _, s in M.param_spec(cfg))
        assert total == M.n_params(cfg), name


def test_init_deterministic_and_sized():
    a = M.init_params(CFG, seed=3)
    b = M.init_params(CFG, seed=3)
    c = M.init_params(CFG, seed=4)
    assert a.shape == (M.n_params(CFG),)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


def test_unflatten_roundtrip():
    flat = jnp.asarray(M.init_params(CFG, 0))
    tree = M.unflatten(CFG, flat)
    names = [n for n, _ in M.param_spec(CFG)]
    assert set(tree) == set(names)
    rebuilt = jnp.concatenate([tree[n].reshape(-1) for n in names])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))


def test_encode_shapes_and_normalization():
    flat = jnp.asarray(M.init_params(CFG, 0))
    imgs, txts = _batch(6)
    e1, e2 = M.encode(CFG, flat, imgs, txts)
    assert e1.shape == (6, CFG.d_embed) and e2.shape == (6, CFG.d_embed)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(e1), axis=-1), 1.0, rtol=1e-5)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(e2), axis=-1), 1.0, rtol=1e-5)


def test_encode_per_sample_independence():
    # Changing sample 0's input must not change sample 1's embedding.
    flat = jnp.asarray(M.init_params(CFG, 0))
    imgs, txts = _batch(4)
    e1a, _ = M.encode(CFG, flat, imgs, txts)
    imgs2 = imgs.at[0].set(imgs[0] * 3 + 1)
    e1b, _ = M.encode(CFG, flat, imgs2, txts)
    assert not np.allclose(np.asarray(e1a[0]), np.asarray(e1b[0]))
    np.testing.assert_allclose(np.asarray(e1a[1:]), np.asarray(e1b[1:]), atol=1e-6)


def test_encode_differentiable():
    flat = jnp.asarray(M.init_params(CFG, 0))
    imgs, txts = _batch(2)

    def f(p):
        e1, e2 = M.encode(CFG, p, imgs, txts)
        return jnp.sum(e1 * e2)

    g = jax.grad(f)(flat)
    assert g.shape == flat.shape
    assert bool(jnp.any(g != 0)) and bool(jnp.all(jnp.isfinite(g)))


@pytest.mark.parametrize("preset", ["tiny", "small"])
def test_presets_instantiable(preset):
    cfg = M.PRESETS[preset]
    assert cfg.v_width % cfg.v_heads == 0
    assert cfg.t_width % cfg.t_heads == 0
    assert M.n_params(cfg) > 0
