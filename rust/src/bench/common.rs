//! Shared experiment-harness plumbing: the three experiment settings
//! (medium / large / xlarge analogs of Table 2), per-algorithm tuned
//! hyperparameters (Tables 7–9 scaled to this testbed), seeded multi-run
//! execution and metric aggregation.

use anyhow::{bail, Context, Result};

use crate::config::{Algorithm, DataConfig, GammaSchedule, TrainConfig};
use crate::coordinator::{TrainResult, Trainer};
use crate::telemetry::Logger;
use crate::util::Args;

/// The experiment settings of Table 2, scaled to this testbed (see
/// DESIGN.md §1: data scale and tower capacity shrink together; the
/// *relative* structure — batch per worker, schedule shapes, loss
/// hyperparameters — follows the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Setting {
    /// paper: CC3M (2.7M) + ResNet50, batch 1024 → here: tiny preset
    Medium,
    /// paper: CC12M (9.1M) + ViT-B/32, batch 2048 → here: small preset
    Large,
    /// paper: LAION315M + ViT-B/16, batch 5120 → here: medium preset
    XLarge,
}

impl Setting {
    pub fn from_id(id: &str) -> Result<Setting> {
        match id {
            "medium" => Ok(Setting::Medium),
            "large" => Ok(Setting::Large),
            "xlarge" => Ok(Setting::XLarge),
            _ => bail!("unknown setting '{id}' (medium|large|xlarge)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Setting::Medium => "Medium",
            Setting::Large => "Large",
            Setting::XLarge => "xLarge",
        }
    }

    /// Default artifact bundle for the setting.
    pub fn bundle(&self) -> &'static str {
        match self {
            Setting::Medium => "artifacts/tiny_k2_b16",
            Setting::Large => "artifacts/small_k2_b16",
            Setting::XLarge => "artifacts/medium_k2_b8",
        }
    }

    /// Bundle for an N-node scaling run (per-GPU batch fixed, global batch
    /// grows with nodes — the paper's protocol).
    pub fn scaling_bundle(&self, nodes: usize) -> String {
        match self {
            Setting::Medium => format!("artifacts/tiny_k{nodes}_b16"),
            _ => format!("artifacts/small_k{nodes}_b16"),
        }
    }

    fn default_steps(&self) -> u32 {
        match self {
            Setting::Medium => 64,
            Setting::Large => 48,
            Setting::XLarge => 96,
        }
    }

    fn data(&self) -> DataConfig {
        match self {
            Setting::Medium => DataConfig {
                n_train: 1024,
                n_eval: 128,
                n_classes: 32,
                noise: 0.8,
                zipf_s: 0.5,
                seed: 0,
            },
            Setting::Large => DataConfig {
                n_train: 2048,
                n_eval: 128,
                n_classes: 48,
                noise: 0.8,
                zipf_s: 0.5,
                seed: 0,
            },
            Setting::XLarge => DataConfig {
                n_train: 4096,
                n_eval: 128,
                n_classes: 64,
                noise: 0.8,
                zipf_s: 0.5,
                seed: 0,
            },
        }
    }

    fn peak_lr(&self) -> f32 {
        match self {
            Setting::Medium => 1e-3, // Table 7
            Setting::Large => 4e-4,
            Setting::XLarge => 2e-4,
        }
    }

    fn rho(&self) -> f32 {
        match self {
            Setting::Medium => 6.5, // Table 9 (FastCLIP-v3 row)
            Setting::Large => 8.5,
            Setting::XLarge => 16.0,
        }
    }
}

/// The tuned per-(setting, algorithm) configuration — the analog of
/// Appendix B. Constant-γ algorithms get γ=0.6/0.8, cosine-γ ones
/// γ_min=0.2 with E = 50% of the training epochs (Tables 8–9).
pub fn algo_config(setting: Setting, algo: Algorithm) -> TrainConfig {
    let mut cfg = TrainConfig::new(setting.bundle(), algo);
    cfg.steps = setting.default_steps();
    cfg.iters_per_epoch = 8;
    cfg.data = setting.data();
    cfg.lr.peak = setting.peak_lr();
    cfg.lr.warmup_iters = cfg.steps / 10;
    cfg.lr.total_iters = cfg.steps;
    cfg.rho = setting.rho();
    let epochs = (cfg.steps / cfg.iters_per_epoch).max(1);
    cfg.gamma = if algo.forces_gamma_one() {
        GammaSchedule::Constant { gamma: 1.0 }
    } else if algo.default_cosine_gamma() {
        GammaSchedule::Cosine { gamma_min: 0.2, decay_epochs: (epochs / 2).max(1) }
    } else {
        // SogCLR / iSogCLR: tuned constant γ (Table 8)
        let gamma =
            if setting == Setting::Large && algo == Algorithm::ISogClr { 0.8 } else { 0.6 };
        GammaSchedule::Constant { gamma }
    };
    // Appendix B: v2 τ-lr 1e-2 (medium) / 1e-4 (large); v3 2e-4 / 1e-4
    cfg.tau_lr = match (algo, setting) {
        (Algorithm::FastClipV2 | Algorithm::ISogClr, Setting::Medium) => 1e-2,
        (Algorithm::FastClipV2 | Algorithm::ISogClr, _) => 1e-4,
        (Algorithm::FastClipV3, Setting::Medium) => 2e-4,
        (Algorithm::FastClipV3, _) => 1e-4,
        _ => cfg.tau_lr,
    };
    if setting == Setting::XLarge {
        // Appendix B + D: larger γ_min for the big batch, larger ε in RGCL-g
        if algo == Algorithm::FastClipV3 {
            cfg.eps = 1e-6;
            cfg.gamma =
                GammaSchedule::Cosine { gamma_min: 0.8, decay_epochs: (epochs / 2).max(1) };
        }
        cfg.optimizer.weight_decay = 0.2;
    }
    cfg
}

/// Apply the common CLI overrides (`--steps`, `--seeds`, `--bundle`,
/// `--n-train`, `--eval-every`, `--nodes`, `--gpus-per-node`,
/// `--precision`, `--wire`) to a base config. Returns the seed list.
pub fn apply_overrides(cfg: &mut TrainConfig, args: &Args) -> Result<Vec<u64>> {
    cfg.steps = args.u32_or("steps", cfg.steps)?;
    cfg.lr.total_iters = cfg.steps;
    cfg.lr.warmup_iters = cfg.lr.warmup_iters.min(cfg.steps / 4);
    cfg.data.n_train = args.usize_or("n-train", cfg.data.n_train)?;
    cfg.data.n_eval = args.usize_or("n-eval", cfg.data.n_eval)?;
    cfg.eval_every = args.u32_or("eval-every", cfg.eval_every)?;
    cfg.nodes = args.usize_or("nodes", cfg.nodes)?;
    cfg.gpus_per_node = args.usize_or("gpus-per-node", cfg.gpus_per_node)?;
    cfg.precision = crate::kernels::Precision::from_id(
        &args.str_or("precision", cfg.precision.id()),
    )?;
    if let Some(w) = args.get("wire") {
        cfg.wire = Some(crate::comm::WireCodec::from_id(w)?);
    }
    if let Some(b) = args.get("bundle") {
        cfg.set_bundle(b);
    }
    // `--trace-out FILE` wires the run into the telemetry subsystem
    // (DESIGN.md §14); with multiple runs the file holds the LAST one
    if let Some(t) = args.get("trace-out") {
        cfg.trace_out = Some(t.to_string());
    }
    let n_seeds = args.usize_or("seeds", 2)?.max(1);
    Ok((0..n_seeds as u64).collect())
}

/// The progress logger for an experiment runner, from the common
/// `--quiet` / `--log-format text|json` flags (rejects unknown formats).
pub fn progress_logger(args: &Args) -> Result<Logger> {
    Logger::from_format(args.flag("quiet"), &args.str_or("log-format", "text"))
}

/// Common options shared by every experiment runner (for check_known).
pub const COMMON_OPTS: &[&str] = &[
    "steps", "seeds", "setting", "bundle", "n-train", "n-eval", "eval-every",
    "out", "nodes", "gpus-per-node", "precision", "wire", "quiet", "log-format", "trace-out",
];

/// Run one configuration across seeds, reporting per-seed progress
/// through the logger (stderr in text mode; `--quiet` silences it).
pub fn run_seeds(
    base: &TrainConfig,
    seeds: &[u64],
    label: &str,
    log: Logger,
) -> Result<Vec<TrainResult>> {
    let mut out = Vec::with_capacity(seeds.len());
    for &seed in seeds {
        let mut cfg = base.clone();
        cfg.seed = seed;
        cfg.data.seed = seed;
        let t0 = std::time::Instant::now();
        let r = Trainer::new(cfg)
            .with_context(|| format!("{label} seed {seed}"))?
            .run()
            .with_context(|| format!("{label} seed {seed}"))?;
        log.status(&format!(
            "  [{label} seed={seed}] loss {:.4} datacomp {:.2} ({:.1}s)",
            r.tail_loss(8),
            r.final_eval.datacomp,
            t0.elapsed().as_secs_f64()
        ));
        out.push(r);
    }
    Ok(out)
}

/// Aggregated (datacomp, retrieval, in_variants) score vectors.
pub struct ScoreVecs {
    pub datacomp: Vec<f32>,
    pub retrieval: Vec<f32>,
    pub in_variants: Vec<f32>,
}

pub fn scores(results: &[TrainResult]) -> ScoreVecs {
    ScoreVecs {
        datacomp: results.iter().map(|r| r.final_eval.datacomp).collect(),
        retrieval: results.iter().map(|r| r.final_eval.retrieval).collect(),
        in_variants: results.iter().map(|r| r.final_eval.in_variants).collect(),
    }
}

/// The results directory (`results/` by default, `--out` to override).
pub fn results_dir(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.str_or("out", "results"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn settings_map_table2() {
        assert_eq!(Setting::from_id("medium").unwrap(), Setting::Medium);
        assert_eq!(Setting::from_id("xlarge").unwrap(), Setting::XLarge);
        assert!(Setting::from_id("huge").is_err());
        assert!(Setting::Medium.bundle().contains("tiny"));
        assert!(Setting::Large.bundle().contains("small"));
        assert!(Setting::XLarge.bundle().contains("medium"));
        assert_eq!(Setting::Medium.scaling_bundle(4), "artifacts/tiny_k4_b16");
    }

    #[test]
    fn tuned_configs_follow_appendix_b() {
        let sog = algo_config(Setting::Medium, Algorithm::SogClr);
        assert!(
            matches!(sog.gamma, GammaSchedule::Constant { gamma } if (gamma - 0.6).abs() < 1e-6)
        );
        let isog_l = algo_config(Setting::Large, Algorithm::ISogClr);
        assert!(
            matches!(isog_l.gamma, GammaSchedule::Constant { gamma } if (gamma - 0.8).abs() < 1e-6)
        );
        let v3 = algo_config(Setting::Medium, Algorithm::FastClipV3);
        assert!(
            matches!(v3.gamma, GammaSchedule::Cosine { gamma_min, .. } if (gamma_min - 0.2).abs() < 1e-6)
        );
        assert!((v3.tau_lr - 2e-4).abs() < 1e-9);
        assert!((v3.rho - 6.5).abs() < 1e-6);
        let v3x = algo_config(Setting::XLarge, Algorithm::FastClipV3);
        assert!((v3x.eps - 1e-6).abs() < 1e-12, "Appendix D eps");
        assert!(
            matches!(v3x.gamma, GammaSchedule::Cosine { gamma_min, .. } if (gamma_min - 0.8).abs() < 1e-6)
        );
        let oc = algo_config(Setting::Large, Algorithm::OpenClip);
        assert!((oc.lr.peak - 4e-4).abs() < 1e-9);
        assert!(oc.validate().is_ok());
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = algo_config(Setting::Medium, Algorithm::FastClipV3);
        let args = Args::parse(
            ["--steps", "10", "--seeds", "3", "--bundle", "artifacts/x", "--n-train", "256"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let seeds = apply_overrides(&mut cfg, &args).unwrap();
        assert_eq!(seeds, vec![0, 1, 2]);
        assert_eq!(cfg.steps, 10);
        assert_eq!(cfg.artifact_dir, "artifacts/x");
        assert_eq!(cfg.data.n_train, 256);
        assert!(cfg.lr.warmup_iters <= 2);
    }
}
