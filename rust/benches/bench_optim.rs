//! Optimizer benchmarks: the four Proc.-4 optimizers over realistic flat
//! parameter-vector sizes. The optimizer runs once per iteration on every
//! worker (replicated update), so its cost lands in the "others" bar of
//! the Fig. 3 breakdown — it must stay small relative to compute.

#[path = "harness.rs"]
mod harness;

use fastclip::config::{OptimizerConfig, OptimizerKind};
use fastclip::optim;
use harness::{black_box, Bench};

fn main() {
    for &n in &[228_928usize, 4_400_000] {
        // leaf segmentation like a real model: 64 leaves
        let seg: Vec<(usize, usize)> = {
            let leaf = n / 64;
            let mut v: Vec<(usize, usize)> = (0..63).map(|i| (i * leaf, leaf)).collect();
            v.push((63 * leaf, n - 63 * leaf));
            v
        };
        let grad: Vec<f32> = (0..n).map(|i| ((i % 97) as f32 - 48.0) * 1e-3).collect();
        for kind in OptimizerKind::all() {
            let cfg = OptimizerConfig::with_kind(kind);
            let mut opt = optim::build(&cfg, n, seg.clone());
            let mut params = vec![0.1f32; n];
            Bench::new(format!("{} step P={}", kind.name(), n))
                .samples(if n > 1_000_000 { 10 } else { 30 })
                .run(|| {
                    opt.step(&mut params, &grad, 1e-3);
                    black_box(params[0]);
                });
        }
    }
}
