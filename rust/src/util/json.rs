//! Minimal JSON: a recursive-descent parser plus a writer.
//!
//! Used for the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and for machine-readable experiment outputs (`results/*.json`). Covers
//! the full JSON grammar except `\uXXXX` surrogate pairs beyond the BMP,
//! which none of our producers emit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, ensure, Context, Result};

/// A JSON value. Object keys are sorted (BTreeMap) so output is
/// deterministic — results files diff cleanly between runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    // -------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Encode an `f32` with guaranteed bitwise round-trip fidelity through
    /// [`Self::as_f32`] — the designated encoder for any f32 a JSON
    /// document carries (results files, manifest scalars; bulk checkpoint
    /// state lives in binary blobs, `ckpt::blob`, for the same fidelity
    /// reason). Finite values (including subnormals and −0.0) become
    /// exact `Num`s — the f32→f64 widening is lossless and the writer
    /// emits a shortest decimal that re-parses to the same f64. The
    /// non-finite values, which JSON cannot represent as numbers, are
    /// encoded explicitly as the strings `"NaN"` / `"Infinity"` /
    /// `"-Infinity"`.
    pub fn f32(v: f32) -> Json {
        if v.is_nan() {
            Json::Str("NaN".to_string())
        } else if v == f32::INFINITY {
            Json::Str("Infinity".to_string())
        } else if v == f32::NEG_INFINITY {
            Json::Str("-Infinity".to_string())
        } else {
            Json::Num(v as f64)
        }
    }

    /// Decode a value written by [`Self::f32`]. Rejects numbers that are
    /// not exactly representable as f32 rather than silently rounding.
    pub fn as_f32(&self) -> Result<f32> {
        match self {
            Json::Num(n) => {
                let v = *n as f32;
                ensure!(
                    (v as f64).to_bits() == n.to_bits(),
                    "number {n} is not exactly representable as f32"
                );
                Ok(v)
            }
            Json::Str(s) => match s.as_str() {
                "NaN" => Ok(f32::NAN),
                "Infinity" => Ok(f32::INFINITY),
                "-Infinity" => Ok(f32::NEG_INFINITY),
                _ => bail!("not an f32 encoding: {self:?}"),
            },
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn set(&mut self, key: &str, value: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), value);
        } else {
            panic!("Json::set on non-object");
        }
    }

    // ------------------------------------------------------------- parsing
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ------------------------------------------------------------- writing
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    /// Single-line encoding, for JSONL event streams (one event per
    /// line — the `--trace-out` sink, DESIGN.md §14). Same numeric and
    /// escaping rules as [`Json::to_string_pretty`], no newlines.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn write_file(&self, path: &std::path::Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_nan() || n.is_infinite() {
                    // JSON has no non-finite numbers; `{n}` would emit
                    // invalid output. Producers that must round-trip
                    // non-finite f32s use `Json::f32`, which encodes them
                    // as explicit strings; a raw non-finite Num degrades
                    // to null rather than corrupting the document.
                    out.push_str("null");
                } else if *n == 0.0 && n.is_sign_negative() {
                    // the i64 fast path below would drop the sign of -0.0
                    out.push_str("-0.0");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes.get(self.pos).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected '{}' at byte {}, found '{}'", b as char, self.pos, self.peek()? as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected character '{}' at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            while self.pos < self.bytes.len()
                && self.bytes[self.pos] != b'"'
                && self.bytes[self.pos] != b'\\'
            {
                self.pos += 1;
            }
            s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
            match self.peek()? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("invalid \\u{code:04x}"))?,
                            );
                        }
                        c => bail!("invalid escape '\\{}'", c as char),
                    }
                }
                _ => unreachable!(),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek()? == b'-' {
            self.pos += 1;
        }
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number '{text}'"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert_eq!(*arr[2].get("b").unwrap(), Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\ A");
    }

    #[test]
    fn compact_is_one_parseable_line() {
        let orig = Json::obj(vec![
            ("type", Json::str("span")),
            ("msg", Json::str("two\nlines")),
            ("vals", Json::arr([Json::num(1), Json::Null, Json::Bool(true)])),
            ("nested", Json::obj(vec![("k", Json::num(-0.5))])),
            ("empty", Json::obj(vec![])),
        ]);
        let line = orig.to_string_compact();
        assert!(!line.contains('\n'), "compact output must be a single line: {line}");
        assert_eq!(Json::parse(&line).unwrap(), orig);
        // scalar fast paths match the pretty writer's rules
        assert_eq!(Json::num(42).to_string_compact(), "42");
        assert_eq!(Json::Null.to_string_compact(), "null");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let orig = Json::obj(vec![
            ("name", Json::str("fast\"clip")),
            ("n", Json::num(228928.0)),
            ("pi", Json::num(3.25)),
            ("flags", Json::arr([Json::Bool(true), Json::Null])),
            ("nested", Json::obj(vec![("k", Json::num(-7.0))])),
        ]);
        let text = orig.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, orig);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
            "version": 1, "preset": "tiny",
            "model": {"d_embed": 64, "v_patches": 16},
            "param_spec": [{"name": "v.patch.w", "shape": [32, 64], "offset": 0, "size": 2048}],
            "executables": {"encode": {"inputs": [], "outputs": []}}
        }"#;
        let m = Json::parse(text).unwrap();
        assert_eq!(m.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            m.get("param_spec").unwrap().as_arr().unwrap()[0]
                .get("size").unwrap().as_usize().unwrap(),
            2048
        );
    }

    #[test]
    fn integers_written_without_fraction() {
        let text = Json::num(13.0).to_string_pretty();
        assert_eq!(text, "13");
    }

    /// Proptest-style exhaustive-ish sweep of the f32 bit space: every
    /// exponent × a mantissa/sign grid, the IEEE edge cases, and a large
    /// pseudorandom sample — all must survive
    /// `Json::f32 → text → parse → as_f32` bit-for-bit, so JSON result
    /// files and manifests can carry f32 scalars without corruption
    /// (DESIGN.md §9).
    #[test]
    fn f32_roundtrip_is_bitwise_exact() {
        let mut patterns: Vec<u32> = vec![
            0x0000_0000, // +0.0
            0x8000_0000, // -0.0
            0x0000_0001, // smallest positive subnormal
            0x8000_0001, // smallest negative subnormal
            0x007f_ffff, // largest subnormal
            0x807f_ffff,
            0x0080_0000, // smallest positive normal
            0x7f7f_ffff, // f32::MAX
            0xff7f_ffff, // f32::MIN
            0x3f80_0000, // 1.0
            0x3eaa_aaab, // ~1/3
            0x7f80_0000, // +inf
            0xff80_0000, // -inf
        ];
        // stratified: every exponent, a spread of mantissas, both signs
        for exp in 0..=254u32 {
            for mantissa in [0u32, 1, 0x2a_5a5a, 0x40_0000, 0x7f_ffff] {
                for sign in [0u32, 1] {
                    patterns.push((sign << 31) | (exp << 23) | mantissa);
                }
            }
        }
        // pseudorandom sweep over the full bit space
        let mut rng = crate::util::Rng::new(0xf32f32);
        for _ in 0..50_000 {
            patterns.push(rng.next_u64() as u32);
        }
        for bits in patterns {
            let v = f32::from_bits(bits);
            if v.is_nan() {
                continue; // NaN payloads are not preserved; checked below
            }
            let text = Json::f32(v).to_string_pretty();
            let back = Json::parse(&text)
                .unwrap_or_else(|e| panic!("bits {bits:08x} -> {text}: {e}"))
                .as_f32()
                .unwrap_or_else(|e| panic!("bits {bits:08x} -> {text}: {e}"));
            assert_eq!(
                back.to_bits(),
                v.to_bits(),
                "bits {bits:08x} (value {v:e}) round-tripped as {back:e} via {text}"
            );
        }
        // non-finite values are encoded explicitly, not dropped
        let nan = Json::parse(&Json::f32(f32::NAN).to_string_pretty()).unwrap();
        assert!(nan.as_f32().unwrap().is_nan());
        // and a raw non-finite Num degrades to null instead of emitting
        // invalid JSON
        assert_eq!(Json::num(f64::NAN).to_string_pretty(), "null");
        assert_eq!(Json::num(f64::INFINITY).to_string_pretty(), "null");
    }

    #[test]
    fn as_f32_rejects_inexact_numbers() {
        // 0.1 as an f64 literal is not an f32 value
        assert!(Json::parse("0.1").unwrap().as_f32().is_err());
        // but the f64 widening of 0.1f32 is
        let w = Json::f32(0.1f32).to_string_pretty();
        assert_eq!(Json::parse(&w).unwrap().as_f32().unwrap(), 0.1f32);
        assert!(Json::Str("abc".into()).as_f32().is_err());
        assert!(Json::Null.as_f32().is_err());
    }

    #[test]
    fn negative_zero_preserved() {
        let t = Json::f32(-0.0f32).to_string_pretty();
        let back = Json::parse(&t).unwrap().as_f32().unwrap();
        assert_eq!(back.to_bits(), (-0.0f32).to_bits(), "via {t}");
    }
}
