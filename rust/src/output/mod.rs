//! Result sinks: pretty console tables, CSV files and JSON result files.
//! The experiment harness ([`crate::bench`]) prints the paper-shaped rows
//! through [`Table`] and persists machine-readable copies under `results/`.
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// A simple fixed-width console table (right-aligned numeric columns).
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "=== {} ===", self.title);
        }
        let line = |out: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                if i > 0 {
                    out.push_str("  ");
                }
                // left-align first column, right-align the rest
                if i == 0 {
                    out.push_str(&cells[i]);
                    out.push_str(&" ".repeat(pad));
                } else {
                    out.push_str(&" ".repeat(pad));
                    out.push_str(&cells[i]);
                }
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write the table as CSV (no quoting needed: we never emit commas).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        std::fs::write(path, s).with_context(|| format!("writing {}", path.display()))
    }
}

/// `mean (std)` cell formatting used throughout the paper's tables.
pub fn mean_std_cell(values: &[f32]) -> String {
    format!("{:.2} ({:.2})", crate::util::mean(values), crate::util::std_dev(values))
}

pub fn f2(v: f64) -> String {
    format!("{v:.2}")
}

/// Persist a JSON result under `results/<name>.json`.
pub fn write_result(dir: &Path, name: &str, value: &Json) -> Result<()> {
    value.write_file(&dir.join(format!("{name}.json")))
}

/// An ASCII sparkline of a series (loss curves in the console).
pub fn sparkline(values: &[f32], width: usize) -> String {
    if values.is_empty() || width == 0 {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = values.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let span = (hi - lo).max(1e-12);
    // resample to `width` buckets by averaging
    let mut out = String::new();
    for b in 0..width.min(values.len()) {
        let lo_i = b * values.len() / width.min(values.len());
        let hi_i = ((b + 1) * values.len() / width.min(values.len())).max(lo_i + 1);
        let m = crate::util::mean(&values[lo_i..hi_i]);
        let idx = (((m - lo) / span) * 7.0).round().clamp(0.0, 7.0) as usize;
        out.push(BARS[idx]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Algorithm", "Datacomp", "Retrieval"]);
        t.row(vec!["FastCLIP-v3".into(), "24.76".into(), "30.36".into()]);
        t.row(vec!["OpenCLIP".into(), "21.84".into(), "25.20".into()]);
        let s = t.render();
        assert!(s.contains("=== Demo ==="));
        assert!(s.contains("FastCLIP-v3"));
        let lines: Vec<&str> = s.lines().collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
        // columns align: both data rows have the same length
        assert_eq!(lines[3].chars().count(), lines[4].chars().count());
        assert_eq!(t.n_rows(), 2);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new("x", &["k", "v"]);
        t.row(vec!["a".into(), "1".into()]);
        let dir = std::env::temp_dir().join("fastclip_test_csv");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "k,v\na,1\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mean_std_format() {
        assert_eq!(mean_std_cell(&[1.0, 2.0, 3.0]), "2.00 (1.00)");
        assert_eq!(mean_std_cell(&[5.0]), "5.00 (0.00)");
    }

    #[test]
    fn sparkline_shape() {
        let xs: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let s = sparkline(&xs, 10);
        assert_eq!(s.chars().count(), 10);
        let first = s.chars().next().unwrap();
        let last = s.chars().last().unwrap();
        assert!(first < last, "ascending series renders ascending bars");
        assert_eq!(sparkline(&[], 10), "");
    }
}
