pub fn build() {
    let m = std::collections::HashMap::<String, u32>::new();
    let _ = m;
}
