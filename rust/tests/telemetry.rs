//! Telemetry determinism and trace fidelity (DESIGN.md §14).
//!
//! The tentpole contract of the telemetry subsystem: turning on the
//! full observability surface (`--trace-out` JSONL spans + `--log-every`
//! heartbeats) must be **bitwise invisible** to training — telemetry
//! reads clocks and buffers records, it never sits between compute and
//! communication. Checked here for f32 and bf16 at 1 and 4 kernel
//! threads, with the overlap pipeline engaged so every span kind is
//! exercised. The written trace must also validate structurally and
//! reproduce the in-process Fig.-3 breakdown within 1% (the end-of-run
//! `"metrics"` event carries the exact totals, so the comparison is in
//! practice exact).

use std::path::PathBuf;

use fastclip::comm::{OverlapMode, ReduceAlgo, ReduceStrategy};
use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::kernels::Precision;
use fastclip::telemetry::trace;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastclip_telemetry_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Native-backend K=2 run with the overlap pipeline forced through
/// several buckets — the richest span set (encode / gather / phase_g /
/// step / reduce under an `iter` root).
fn base_cfg(precision: Precision, threads: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", Algorithm::FastClipV3);
    cfg.backend = fastclip::runtime::BackendKind::Native;
    cfg.kernel_threads = threads;
    cfg.steps = 8;
    cfg.iters_per_epoch = 4;
    cfg.data.n_train = 64;
    cfg.data.n_eval = 32;
    cfg.data.n_classes = 8;
    cfg.lr.warmup_iters = 2;
    cfg.lr.total_iters = 8;
    cfg.precision = precision;
    cfg.overlap = OverlapMode::On;
    cfg.reduce = ReduceStrategy::Fixed(ReduceAlgo::Ring);
    cfg.bucket_bytes = 1024;
    cfg
}

fn telemetry_is_bitwise_invisible(precision: Precision) {
    for threads in [1usize, 4] {
        let label = format!("precision={} threads={threads}", precision.id());
        let off = Trainer::new(base_cfg(precision, threads)).unwrap().run().unwrap();

        let dir = tmp_dir(&format!("det_{}_{threads}", precision.id()));
        let trace_path = dir.join("trace.jsonl");
        let mut cfg = base_cfg(precision, threads);
        cfg.trace_out = Some(trace_path.to_string_lossy().into_owned());
        cfg.log_every = 2;
        cfg.quiet = true;
        let on = Trainer::new(cfg).unwrap().run().unwrap();

        // ---- bitwise equality: params, τ, and the whole trajectory ----
        assert_eq!(off.final_params, on.final_params, "params: {label}");
        assert_eq!(off.final_tau.to_bits(), on.final_tau.to_bits(), "tau: {label}");
        assert_eq!(off.history.len(), on.history.len(), "{label}");
        for (a, b) in off.history.iter().zip(&on.history) {
            assert_eq!(a.step, b.step, "{label}");
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss at step {}: {label}", a.step);
            assert_eq!(a.tau.to_bits(), b.tau.to_bits(), "tau at step {}: {label}", a.step);
        }
        // telemetry must not change what moves on the wire either
        assert_eq!(off.comm_bytes, on.comm_bytes, "{label}");
        assert_eq!(off.grad_wire_bytes, on.grad_wire_bytes, "{label}");

        // ---- the trace validates and reproduces the breakdown ---------
        trace::verify_file(&trace_path).unwrap();
        let sum = trace::summarize_file(&trace_path).unwrap();
        assert_eq!(sum.breakdown_source, "metrics", "{label}");
        assert_eq!(sum.breakdown.iterations, on.timing.iterations, "{label}");
        for (name, got, want) in [
            ("compute_s", sum.breakdown.compute_s, on.timing.compute_s),
            ("comm_total_s", sum.breakdown.comm_total_s, on.timing.comm_total_s),
            ("comm_overlap_s", sum.breakdown.comm_overlap_s, on.timing.comm_overlap_s),
            ("comm_pure_s", sum.breakdown.comm_pure_s, on.timing.comm_pure_s),
            ("others_s", sum.breakdown.others_s, on.timing.others_s),
            ("overlap_hidden_s", sum.breakdown.overlap_hidden_s, on.timing.overlap_hidden_s),
            ("overlap_exposed_s", sum.breakdown.overlap_exposed_s, on.timing.overlap_exposed_s),
        ] {
            // the acceptance bound is 1%; the metrics event makes it exact
            let tol = want.abs() * 0.01 + 1e-12;
            assert!(
                (got - want).abs() <= tol,
                "trace {name} {got} vs in-process {want}: {label}"
            );
        }

        // ---- span + heartbeat structure -------------------------------
        let meta = sum.meta.as_ref().expect("meta event");
        assert_eq!(meta.get("algo").unwrap().as_str().unwrap(), "fastclip-v3");
        assert_eq!(meta.get("precision").unwrap().as_str().unwrap(), precision.id());
        // the default wire codec follows the precision (DESIGN.md §15)
        assert_eq!(meta.get("wire").unwrap().as_str().unwrap(), precision.id());
        assert_eq!(sum.ranks.len(), 2, "both ranks traced: {label}");
        assert_eq!(sum.heartbeats, 4, "log_every=2 over 8 steps: {label}");
        for name in ["iter", "encode", "phase_g", "step", "reduce"] {
            assert!(sum.span_stats.contains_key(name), "span '{name}' missing: {label}");
        }
        assert_eq!(sum.span_stats["iter"].count, 2 * 8, "2 ranks x 8 iters: {label}");

        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn telemetry_is_bitwise_invisible_f32() {
    telemetry_is_bitwise_invisible(Precision::F32);
}

/// The sharded loss (DESIGN.md §16) is telemetry-pinned. The
/// `loss.peak_bytes` gauge follows the exact analytic formulas for the
/// loss-stage working set —
///   off: 4·(2·Bg·d + 4·Bl·d) bytes (two gathered feature matrices plus
///        the four local-slice gradient buffers),
///   on:  16·Bl·d bytes (everything block-local) —
/// so a K=4 world shards the peak down exactly (2K+4)/4 = 3×, the
/// exchange shows up in `comm.featgrad_wire_bytes`, and the run meta
/// records the resolved mode.
#[test]
fn loss_shard_peak_bytes_gauge_is_pinned_at_k4() {
    use fastclip::runtime::LossShardMode;
    use fastclip::util::Json;
    let (k, bl, steps) = (4usize, 4usize, 4u32);
    let dir = tmp_dir("loss_shard_gauge");
    let trace_path = dir.join("trace.jsonl");
    let mk = |mode: LossShardMode, trace: Option<&PathBuf>| {
        let mut cfg = TrainConfig::new("artifacts/tiny_k4_b4", Algorithm::FastClipV3);
        cfg.backend = fastclip::runtime::BackendKind::Native;
        cfg.n_workers = k;
        cfg.local_batch = bl;
        cfg.kernel_threads = 1;
        cfg.steps = steps;
        cfg.iters_per_epoch = 4;
        cfg.data.n_train = 64;
        cfg.data.n_eval = 32;
        cfg.data.n_classes = 8;
        cfg.lr.warmup_iters = 2;
        cfg.lr.total_iters = steps;
        cfg.loss_shard = mode;
        cfg.trace_out = trace.map(|p| p.to_string_lossy().into_owned());
        cfg.quiet = true;
        cfg
    };
    let d = mk(LossShardMode::On, None).load_manifest().unwrap().model.d_embed;
    let off_peak = (4 * (2 * (k * bl) * d + 4 * bl * d)) as u64;
    let on_peak = (16 * bl * d) as u64;

    let off = Trainer::new(mk(LossShardMode::Off, None)).unwrap().run().unwrap();
    let on = Trainer::new(mk(LossShardMode::On, Some(&trace_path))).unwrap().run().unwrap();

    // the exact formulas, and the exact 3x reduction at K=4
    assert_eq!(off.loss_peak_bytes, off_peak);
    assert_eq!(on.loss_peak_bytes, on_peak);
    assert_eq!(off.loss_peak_bytes, 3 * on.loss_peak_bytes, "(2K+4)/4 = 3 at K=4");

    // sharding is a memory optimization, not a numerics change
    assert_eq!(off.final_params, on.final_params);

    // featgrad wire accounting: per rank, each of the `steps` exchanges
    // moves (K-1) f32 segments of 2*Bl*d elements; off moves nothing
    assert_eq!(on.featgrad_wire_bytes, steps as u64 * (k as u64 - 1) * 4 * (2 * bl * d) as u64);
    assert_eq!(off.featgrad_wire_bytes, 0);

    // the trace carries the same quantities: the resolved mode in the
    // meta event, the gauge and the all-rank wire counter in the
    // end-of-run metrics event
    trace::verify_file(&trace_path).unwrap();
    let text = std::fs::read_to_string(&trace_path).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let typed = |t: &str| {
        lines
            .iter()
            .find(|j| j.get("type").unwrap().as_str().unwrap() == t)
            .unwrap_or_else(|| panic!("no '{t}' event in trace"))
    };
    let meta = typed("meta");
    assert_eq!(meta.get("loss_shard").unwrap().as_str().unwrap(), "on");
    let metrics = typed("metrics");
    let gauges = metrics.get("gauges").unwrap();
    assert_eq!(gauges.get("loss.peak_bytes").unwrap().as_f64().unwrap(), on_peak as f64);
    let counters = metrics.get("counters").unwrap();
    assert_eq!(
        counters.get("comm.featgrad_wire_bytes").unwrap().as_usize().unwrap() as u64,
        on.featgrad_wire_bytes * k as u64,
        "the metrics counter sums all ranks"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn telemetry_is_bitwise_invisible_bf16() {
    telemetry_is_bitwise_invisible(Precision::Bf16);
}
