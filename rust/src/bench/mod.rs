//! Experiment harness: one runner per paper table/figure (DESIGN.md §6).
//!
//! | id        | paper item                  | runner                |
//! |-----------|-----------------------------|-----------------------|
//! | table3    | Table 3 / Fig. 8            | components::table3    |
//! | table4    | Table 4 / Fig. 9(a,b)       | components::table4    |
//! | table5    | Table 5 / Fig. 9(c,d)       | components::table5    |
//! | reduce    | §4 gradient reduction       | components::reduce_table |
//! | scaling   | Fig. 1/2/10, Tables 12–14   | scaling::scaling      |
//! | speedup   | Fig. 4(b,c)                 | scaling::speedup      |
//! | timing    | Fig. 3/11, Tables 15–22     | timing::timing        |
//! | xlarge    | Fig. 4(a) / Table 6         | xlarge::xlarge        |
//! | epsilon   | Fig. 7 / Appendix D         | xlarge::epsilon       |
//! | gamma-min | Fig. 5 / Appendix B         | gamma::gamma_min      |
//! | fits      | Fig. 6 / Appendix C         | fits::fits            |
//! | ckpt      | DESIGN.md §9 resume study   | ckpt::ckpt_study      |
//! | compress  | DESIGN.md §15 wire codecs   | compress::compress    |
//!
//! Every runner accepts `--steps`, `--seeds`, `--out` and runner-specific
//! options, prints the paper-shaped rows, and writes CSV + JSON under
//! `results/`.
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

pub mod ckpt;
pub mod common;
pub mod components;
pub mod compress;
pub mod fits;
pub mod gamma;
pub mod scaling;
pub mod timing;
pub mod xlarge;

use anyhow::{bail, Result};

use crate::util::Args;

pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table3", "inner-LR (gamma) schedule: constant vs cosine (Table 3 / Fig. 8)"),
    ("table4", "temperature update rules v0-v3 (Table 4 / Fig. 9ab)"),
    ("table5", "optimizers SGDM/LAMB/Lion/AdamW (Table 5 / Fig. 9cd)"),
    ("reduce", "gradient-reduction strategies: naive/ring/sharded bytes-on-wire + exactness"),
    ("scaling", "FastCLIP-v3 vs OpenCLIP across nodes (Fig. 1/2/10, Tables 12-14)"),
    ("speedup", "speedup over 1 node (Fig. 4bc)"),
    ("timing", "per-iteration time breakdown (Fig. 3/11, Tables 15-22)"),
    ("xlarge", "xlarge accuracy curves (Fig. 4a / Table 6)"),
    ("epsilon", "eps in RGCL-g at xlarge (Fig. 7)"),
    ("gamma-min", "gamma_min x batch size (Fig. 5)"),
    ("fits", "batch/data-size fits for OpenCLIP (Fig. 6)"),
    ("ckpt", "checkpoint/resume: snapshot+restore overhead, bitwise equivalence (DESIGN.md §9)"),
    ("compress", "gradient wire codecs: bytes vs convergence, f32/bf16/int8/topk (DESIGN.md §15)"),
];

/// Dispatch an experiment id to its runner.
pub fn run_experiment(id: &str, args: &Args) -> Result<()> {
    match id {
        "table3" => components::table3(args),
        "table4" => components::table4(args),
        "table5" => components::table5(args),
        "reduce" => components::reduce_table(args),
        "scaling" => scaling::scaling(args),
        "speedup" => scaling::speedup(args),
        "timing" => timing::timing(args),
        "xlarge" => xlarge::xlarge(args),
        "epsilon" => xlarge::epsilon(args),
        "gamma-min" => gamma::gamma_min(args),
        "fits" => fits::fits(args),
        "ckpt" => ckpt::ckpt_study(args),
        "compress" => compress::compress(args),
        _ => bail!(
            "unknown experiment '{id}'; available:\n{}",
            EXPERIMENTS.iter().map(|(k, v)| format!("  {k:10} {v}")).collect::<Vec<_>>().join("\n")
        ),
    }
}
