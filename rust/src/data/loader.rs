//! Sharded batch loading: the dataset is partitioned evenly across K
//! workers (S_1..S_K in the paper); each worker shuffles *within its
//! shard* each epoch (seeded, deterministic) and yields fixed-size local
//! batches. Local shard positions index the per-worker u/τ state stores.

use crate::util::Rng;

/// A local batch: global sample indices + their shard-local positions.
#[derive(Debug, Clone)]
pub struct Batch {
    pub global_indices: Vec<usize>,
    pub local_positions: Vec<usize>,
    pub epoch: u32,
}

pub struct ShardLoader {
    /// global indices owned by this worker (strided partition)
    shard: Vec<usize>,
    order: Vec<usize>,
    cursor: usize,
    epoch: u32,
    batch: usize,
    rng: Rng,
}

impl ShardLoader {
    pub fn new(n_train: usize, rank: usize, world: usize, batch: usize, seed: u64) -> Self {
        assert!(world > 0 && rank < world && batch > 0);
        let shard: Vec<usize> = (rank..n_train).step_by(world).collect();
        assert!(
            shard.len() >= batch,
            "shard of worker {rank} has {} samples < batch {batch}",
            shard.len()
        );
        let mut s = Self {
            order: (0..shard.len()).collect(),
            shard,
            cursor: 0,
            epoch: 0,
            batch,
            rng: Rng::new(seed ^ 0x10ad).split(rank as u64),
        };
        s.rng.shuffle(&mut s.order);
        s
    }

    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    pub fn iters_per_epoch(&self) -> usize {
        self.shard.len() / self.batch
    }

    /// Next local batch; reshuffles (and bumps epoch) when the shard is
    /// exhausted. Drops the ragged tail like the reference loaders.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.order.len() {
            self.cursor = 0;
            self.epoch += 1;
            self.rng.shuffle(&mut self.order);
        }
        let lo = self.cursor;
        self.cursor += self.batch;
        let local: Vec<usize> = self.order[lo..lo + self.batch].to_vec();
        Batch {
            global_indices: local.iter().map(|&p| self.shard[p]).collect(),
            local_positions: local,
            epoch: self.epoch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shards_partition_dataset() {
        let n = 103;
        let mut seen = HashSet::new();
        for rank in 0..4 {
            let l = ShardLoader::new(n, rank, 4, 5, 1);
            for &g in &l.shard {
                assert!(seen.insert(g), "index {g} in two shards");
                assert_eq!(g % 4, rank);
            }
        }
        assert_eq!(seen.len(), n);
    }

    #[test]
    fn epoch_covers_shard_once() {
        let mut l = ShardLoader::new(64, 1, 2, 8, 3);
        let mut seen = HashSet::new();
        for _ in 0..l.iters_per_epoch() {
            let b = l.next_batch();
            assert_eq!(b.epoch, 0);
            for &g in &b.global_indices {
                assert!(seen.insert(g));
            }
        }
        assert_eq!(seen.len(), 32);
        assert_eq!(l.next_batch().epoch, 1);
    }

    #[test]
    fn local_positions_match_globals() {
        let mut l = ShardLoader::new(40, 3, 4, 4, 7);
        for _ in 0..5 {
            let b = l.next_batch();
            for (&g, &p) in b.global_indices.iter().zip(&b.local_positions) {
                assert_eq!(g, 3 + 4 * p);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ShardLoader::new(50, 0, 2, 5, 9);
        let mut b = ShardLoader::new(50, 0, 2, 5, 9);
        for _ in 0..10 {
            assert_eq!(a.next_batch().global_indices, b.next_batch().global_indices);
        }
    }

    #[test]
    fn reshuffles_between_epochs() {
        let mut l = ShardLoader::new(64, 0, 1, 64, 5);
        let e0 = l.next_batch().global_indices;
        let e1 = l.next_batch().global_indices;
        assert_ne!(e0, e1);
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort();
        s1.sort();
        assert_eq!(s0, s1);
    }

    #[test]
    #[should_panic]
    fn rejects_batch_larger_than_shard() {
        ShardLoader::new(10, 0, 4, 5, 0);
    }
}
