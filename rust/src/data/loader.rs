//! Sharded batch loading: the dataset is partitioned evenly across K
//! workers (S_1..S_K in the paper); each worker shuffles *within its
//! shard* each epoch (seeded, deterministic) and yields fixed-size local
//! batches. Local shard positions index the per-worker u/τ state stores.

use anyhow::{ensure, Result};

use crate::util::{Rng, RngState};

/// A local batch: global sample indices + their shard-local positions.
#[derive(Debug, Clone)]
pub struct Batch {
    pub global_indices: Vec<usize>,
    pub local_positions: Vec<usize>,
    pub epoch: u32,
}

/// Number of samples in rank `rank`'s strided shard of `n_train` samples
/// over `world` workers — |{rank, rank+world, rank+2·world, ...}|.
///
/// Validates the topology with the same rules as [`ShardLoader::new`]
/// (the two must agree — callers size per-rank state off this count):
/// `world == 0`, `rank >= world` and `n_train == 0` are actionable
/// errors, not silent zero-length shards. A rank whose shard is
/// legitimately empty (`rank >= n_train > 0`, i.e. fewer samples than
/// workers) still returns `Ok(0)` — [`ShardLoader::new`] then rejects it
/// against the batch size with its own message.
pub fn shard_len_for(n_train: usize, world: usize, rank: usize) -> Result<usize> {
    ensure!(world > 0, "world size must be > 0");
    ensure!(rank < world, "rank {rank} out of range for world size {world}");
    ensure!(
        n_train > 0,
        "no training samples (n_train = 0): every worker's strided shard is empty — \
         raise data.n_train"
    );
    if rank >= n_train {
        Ok(0)
    } else {
        Ok((n_train - rank).div_ceil(world))
    }
}

/// A serializable snapshot of a [`ShardLoader`]'s exact position
/// (checkpoint/resume, DESIGN.md §9): restoring it reproduces the batch
/// sequence bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct LoaderState {
    pub epoch: u32,
    pub cursor: usize,
    pub order: Vec<usize>,
    pub rng: RngState,
}

pub struct ShardLoader {
    /// global indices owned by this worker (strided partition)
    shard: Vec<usize>,
    order: Vec<usize>,
    cursor: usize,
    epoch: u32,
    batch: usize,
    rng: Rng,
}

impl ShardLoader {
    /// Build the loader for one worker's shard. Errors (rather than
    /// aborting the worker thread) when the topology is degenerate or the
    /// shard cannot fill a single batch — a bad `--nodes`/`--batch`
    /// combination surfaces as an actionable config error.
    pub fn new(
        n_train: usize,
        rank: usize,
        world: usize,
        batch: usize,
        seed: u64,
    ) -> Result<Self> {
        ensure!(world > 0, "world size must be > 0");
        ensure!(rank < world, "rank {rank} out of range for world size {world}");
        ensure!(batch > 0, "local batch must be > 0");
        ensure!(
            n_train > 0,
            "no training samples (n_train = 0): every worker's strided shard is empty — \
             raise data.n_train"
        );
        let shard: Vec<usize> = (rank..n_train).step_by(world).collect();
        ensure!(
            shard.len() >= batch,
            "worker {rank}'s shard has only {} of the {n_train} training samples \
             (strided over {world} workers) — too few for local batch {batch}; \
             lower the batch size or worker count, or raise data.n_train",
            shard.len()
        );
        let mut s = Self {
            order: (0..shard.len()).collect(),
            shard,
            cursor: 0,
            epoch: 0,
            batch,
            rng: Rng::new(seed ^ 0x10ad).split(rank as u64),
        };
        s.rng.shuffle(&mut s.order);
        Ok(s)
    }

    pub fn shard_len(&self) -> usize {
        self.shard.len()
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    pub fn iters_per_epoch(&self) -> usize {
        self.shard.len() / self.batch
    }

    /// Next local batch; reshuffles (and bumps epoch) when the shard is
    /// exhausted. Drops the ragged tail like the reference loaders.
    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.batch > self.order.len() {
            self.cursor = 0;
            self.epoch += 1;
            self.rng.shuffle(&mut self.order);
        }
        let lo = self.cursor;
        self.cursor += self.batch;
        let local: Vec<usize> = self.order[lo..lo + self.batch].to_vec();
        Batch {
            global_indices: local.iter().map(|&p| self.shard[p]).collect(),
            local_positions: local,
            epoch: self.epoch,
        }
    }

    /// Snapshot the loader's exact position for a checkpoint.
    pub fn export(&self) -> LoaderState {
        LoaderState {
            epoch: self.epoch,
            cursor: self.cursor,
            order: self.order.clone(),
            rng: self.rng.export(),
        }
    }

    /// Restore a position exported from a loader with the same shard
    /// (same n_train / rank / world). Validates the permutation so a
    /// corrupt checkpoint cannot index out of the shard.
    pub fn import(&mut self, s: LoaderState) -> Result<()> {
        ensure!(
            s.order.len() == self.shard.len(),
            "loader state covers {} positions, shard has {}",
            s.order.len(),
            self.shard.len()
        );
        ensure!(s.cursor <= s.order.len(), "loader cursor {} out of range", s.cursor);
        let mut seen = vec![false; s.order.len()];
        for &p in &s.order {
            ensure!(
                p < seen.len() && !seen[p],
                "loader order is not a permutation of the shard"
            );
            seen[p] = true;
        }
        self.epoch = s.epoch;
        self.cursor = s.cursor;
        self.order = s.order;
        self.rng = Rng::restore(s.rng);
        Ok(())
    }

    /// Fast-forward a freshly constructed loader to the start of `epoch`,
    /// replaying the per-epoch reshuffles deterministically. Used by
    /// elastic resume (DESIGN.md §9), where the shard partition itself
    /// changed and an exact cursor cannot be mapped: the resized world
    /// restarts cleanly at the checkpoint's epoch.
    pub fn advance_to_epoch(&mut self, epoch: u32) {
        while self.epoch < epoch {
            self.epoch += 1;
            self.rng.shuffle(&mut self.order);
        }
        self.cursor = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn shards_partition_dataset() {
        let n = 103;
        let mut seen = HashSet::new();
        for rank in 0..4 {
            let l = ShardLoader::new(n, rank, 4, 5, 1).unwrap();
            for &g in &l.shard {
                assert!(seen.insert(g), "index {g} in two shards");
                assert_eq!(g % 4, rank);
            }
            assert_eq!(l.shard_len(), shard_len_for(n, 4, rank).unwrap());
        }
        assert_eq!(seen.len(), n);
    }

    #[test]
    fn shard_len_for_counts_strided_members() {
        for (n, k) in [(103usize, 4usize), (64, 2), (10, 4), (7, 8)] {
            let mut total = 0;
            for r in 0..k {
                let expect = (r..n).step_by(k).count();
                assert_eq!(shard_len_for(n, k, r).unwrap(), expect, "n={n} k={k} r={r}");
                total += expect;
            }
            assert_eq!(total, n);
        }
    }

    #[test]
    fn shard_len_for_agrees_with_loader_on_degenerate_topologies() {
        // the satellite contract: shard_len_for validates exactly what
        // ShardLoader::new validates (minus the batch size)
        assert!(shard_len_for(10, 0, 0).is_err(), "empty world");
        assert!(shard_len_for(10, 2, 2).is_err(), "rank >= world");
        let err = shard_len_for(0, 3, 0).unwrap_err();
        assert!(format!("{err}").contains("n_train"), "actionable: {err}");
        // fewer samples than workers: the count is legitimately 0 and
        // the loader rejects it against the batch with its own message
        assert_eq!(shard_len_for(7, 8, 7).unwrap(), 0);
        assert!(ShardLoader::new(7, 7, 8, 1, 0).is_err());
        // n_train == 0 errors in the loader with the same message shape
        let err = ShardLoader::new(0, 0, 2, 1, 0).unwrap_err();
        assert!(format!("{err}").contains("n_train"), "actionable: {err}");
    }

    #[test]
    fn epoch_covers_shard_once() {
        let mut l = ShardLoader::new(64, 1, 2, 8, 3).unwrap();
        let mut seen = HashSet::new();
        for _ in 0..l.iters_per_epoch() {
            let b = l.next_batch();
            assert_eq!(b.epoch, 0);
            for &g in &b.global_indices {
                assert!(seen.insert(g));
            }
        }
        assert_eq!(seen.len(), 32);
        assert_eq!(l.next_batch().epoch, 1);
    }

    #[test]
    fn local_positions_match_globals() {
        let mut l = ShardLoader::new(40, 3, 4, 4, 7).unwrap();
        for _ in 0..5 {
            let b = l.next_batch();
            for (&g, &p) in b.global_indices.iter().zip(&b.local_positions) {
                assert_eq!(g, 3 + 4 * p);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = ShardLoader::new(50, 0, 2, 5, 9).unwrap();
        let mut b = ShardLoader::new(50, 0, 2, 5, 9).unwrap();
        for _ in 0..10 {
            assert_eq!(a.next_batch().global_indices, b.next_batch().global_indices);
        }
    }

    #[test]
    fn reshuffles_between_epochs() {
        let mut l = ShardLoader::new(64, 0, 1, 64, 5).unwrap();
        let e0 = l.next_batch().global_indices;
        let e1 = l.next_batch().global_indices;
        assert_ne!(e0, e1);
        let mut s0 = e0.clone();
        let mut s1 = e1.clone();
        s0.sort();
        s1.sort();
        assert_eq!(s0, s1);
    }

    #[test]
    fn rejects_batch_larger_than_shard() {
        let err = ShardLoader::new(10, 0, 4, 5, 0).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("batch"), "actionable message, got: {msg}");
        assert!(ShardLoader::new(10, 1, 2, 1, 0).is_ok());
        assert!(ShardLoader::new(10, 1, 2, 0, 0).is_err(), "zero batch");
        assert!(ShardLoader::new(10, 3, 2, 1, 0).is_err(), "rank >= world");
        assert!(ShardLoader::new(10, 0, 0, 1, 0).is_err(), "empty world");
    }

    #[test]
    fn export_import_resumes_batches_bitwise() {
        let mut a = ShardLoader::new(96, 1, 3, 4, 11).unwrap();
        for _ in 0..13 {
            a.next_batch(); // cross an epoch boundary (8 iters/epoch)
        }
        let snap = a.export();
        let mut b = ShardLoader::new(96, 1, 3, 4, 11).unwrap();
        b.import(snap).unwrap();
        for _ in 0..30 {
            let ba = a.next_batch();
            let bb = b.next_batch();
            assert_eq!(ba.global_indices, bb.global_indices);
            assert_eq!(ba.epoch, bb.epoch);
        }
    }

    #[test]
    fn import_rejects_corrupt_state() {
        let a = ShardLoader::new(40, 0, 2, 4, 1).unwrap();
        let mut b = ShardLoader::new(40, 0, 2, 4, 1).unwrap();
        // wrong length
        let mut s = a.export();
        s.order.pop();
        assert!(b.import(s).is_err());
        // duplicate position
        let mut s = a.export();
        s.order[0] = s.order[1];
        assert!(b.import(s).is_err());
        // cursor out of range
        let mut s = a.export();
        s.cursor = s.order.len() + 1;
        assert!(b.import(s).is_err());
    }

    #[test]
    fn advance_to_epoch_matches_continuous_run() {
        // a loader advanced through next_batch to epoch 2 has the same
        // order as a fresh loader fast-forwarded to epoch 2
        let mut cont = ShardLoader::new(32, 0, 2, 4, 5).unwrap();
        while cont.epoch() < 2 {
            cont.next_batch();
        }
        let mut jump = ShardLoader::new(32, 0, 2, 4, 5).unwrap();
        jump.advance_to_epoch(2);
        assert_eq!(jump.order, cont.order);
        assert_eq!(jump.epoch(), 2);
    }
}
