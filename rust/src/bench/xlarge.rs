//! xLarge-scale experiments:
//! * `xlarge` — Fig. 4(a) / Table 6 / Fig. 10(c): FastCLIP-v3 vs OpenCLIP
//!   accuracy curves on the largest analog setting;
//! * `epsilon` — Fig. 7 / Appendix D: the effect of ε ∈ {1e-14, 1e-6} in
//!   (RGCL-g) at xlarge scale.

use anyhow::Result;

use crate::config::Algorithm;
use crate::output::{f2, sparkline, Table};
use crate::util::{Args, Json};

use super::common::{algo_config, apply_overrides, progress_logger, results_dir, run_seeds, Setting};

/// Fig. 4(a) / Table 6: the xlarge accuracy curve + final table.
pub fn xlarge(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut table = Table::new(
        "Table 6 analog — xlarge setting (IN-analog zero-shot, final)",
        &["Algorithm", "ZeroShot(IN-analog)", "Datacomp", "Retrieval"],
    );
    let mut json_rows = Vec::new();
    for algo in [Algorithm::OpenClip, Algorithm::FastClipV3] {
        let mut cfg = algo_config(Setting::XLarge, algo);
        cfg.eval_every = args.u32_or("eval-every", (cfg.steps / 6).max(1))?;
        let seeds = apply_overrides(&mut cfg, args)?;
        let results = run_seeds(&cfg, &seeds[..1], algo.name(), log)?;
        let r = &results[0];
        let curve: Vec<(u32, f32)> = r
            .evals
            .iter()
            .map(|e| (e.step, e.summary.task("zeroshot_clean").unwrap_or(f32::NAN)))
            .collect();
        let series: Vec<f32> = curve.iter().map(|(_, v)| *v).collect();
        log.status(&format!(
            "  {} IN-analog curve: {}  (final {:.2})",
            algo.name(),
            sparkline(&series, 32),
            series.last().copied().unwrap_or(f32::NAN)
        ));
        table.row(vec![
            algo.name().into(),
            f2(series.last().copied().unwrap_or(f32::NAN) as f64),
            f2(r.final_eval.datacomp as f64),
            f2(r.final_eval.retrieval as f64),
        ]);
        json_rows.push(Json::obj(vec![
            ("algorithm", Json::str(algo.name())),
            (
                "curve",
                Json::arr(curve.iter().map(|(s, v)| {
                    Json::obj(vec![
                        ("step", Json::num(*s as f64)),
                        ("zeroshot", Json::num(*v as f64)),
                    ])
                })),
            ),
            ("final_datacomp", Json::num(r.final_eval.datacomp as f64)),
            ("final_retrieval", Json::num(r.final_eval.retrieval as f64)),
        ]));
    }
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join("xlarge.csv"))?;
    crate::output::write_result(&dir, "xlarge", &Json::arr(json_rows))?;
    Ok(())
}

/// Fig. 7: ε ∈ {1e-14, 1e-6} in (RGCL-g) — the Appendix D observation that
/// a larger ε bounds the 1/(ε+u) gradient scaling for well-learned
/// examples and improves xlarge accuracy.
pub fn epsilon(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut table = Table::new(
        "Fig. 7 analog — effect of eps in RGCL-g (xlarge setting)",
        &["eps", "ZeroShot(IN-analog)", "Datacomp", "final loss"],
    );
    let mut json_rows = Vec::new();
    for eps in [1e-14f32, 1e-6] {
        let mut cfg = algo_config(Setting::XLarge, Algorithm::FastClipV3);
        cfg.eps = eps;
        cfg.eval_every = args.u32_or("eval-every", (cfg.steps / 6).max(1))?;
        let seeds = apply_overrides(&mut cfg, args)?;
        cfg.eps = eps; // keep after overrides
        let results = run_seeds(&cfg, &seeds[..1], &format!("eps={eps:e}"), log)?;
        let r = &results[0];
        let zs: Vec<f32> = r
            .evals
            .iter()
            .map(|e| e.summary.task("zeroshot_clean").unwrap_or(f32::NAN))
            .collect();
        log.status(&format!("  eps={eps:e} curve: {}", sparkline(&zs, 32)));
        table.row(vec![
            format!("{eps:e}"),
            f2(zs.last().copied().unwrap_or(f32::NAN) as f64),
            f2(r.final_eval.datacomp as f64),
            format!("{:.4}", r.tail_loss(8)),
        ]);
        json_rows.push(Json::obj(vec![
            ("eps", Json::num(eps as f64)),
            ("zeroshot_curve", Json::arr(zs.iter().map(|&v| Json::num(v as f64)))),
            ("final_datacomp", Json::num(r.final_eval.datacomp as f64)),
        ]));
    }
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join("epsilon.csv"))?;
    crate::output::write_result(&dir, "epsilon", &Json::arr(json_rows))?;
    Ok(())
}
