//! In-process collectives between the K worker threads.
//!
//! All methods are *collective*: every rank must call the same method in
//! the same order (lockstep), as with MPI/NCCL. Data really moves (the
//! numerics of distributed training are exact); time is charged separately
//! through [`super::CostModel`] by the coordinator.
//!
//! # Fault model
//!
//! Every collective returns `Result<_, `[`CommError`]`>`: a world whose
//! shared [`CancellationToken`] is cancelled (a rank declared lost, or a
//! watchdog expiry) fails every blocking wait instead of deadlocking —
//! the barrier is a [`CancellableBarrier`], and every collective checks
//! the token on entry, so a cancelled world is permanently failed and
//! survivors can rebuild at K′ (DESIGN.md §13). Clean runs pay one atomic
//! load per collective for this. Deterministic latency skew (`--straggle`)
//! is injected here too: a configured rank sleeps at the entry of every
//! collective, which is how the straggler harness produces honest
//! hidden/exposed numbers without touching the numerics.
//!
//! Two kinds of byte accounting coexist in [`CommStats`]:
//!
//! * **payload counters** (`*_bytes`): the per-rank payload each collective
//!   was called with — what the seed tracked, useful for cross-checking
//!   the modeled volumes. Payloads are charged at the encoded width of
//!   the collective's [`WireCodec`] (4 bytes/element for f32, 2 for
//!   bf16, 1 for int8, 8 per selected element for topk — DESIGN.md §15);
//! * **wire counters** (`grad_wire_bytes`, `grad_wire_bytes_naive`,
//!   `param_wire_bytes`): the bytes a real fabric would carry per rank
//!   under the chosen gradient-reduction algorithm, charged by
//!   [`super::GradientReduction::reduce_and_apply`]. The
//!   naive-baseline counter is always charged alongside the chosen
//!   algorithm's, so every run carries its own before/after comparison.
//!
//! # Snapshot consistency
//!
//! Every counter lives behind ONE mutex, and multi-counter updates (the
//! chosen/naive gradient-wire pair, the hidden/exposed overlap split)
//! happen under a single lock acquisition — so a [`CommStats::snapshot`]
//! taken while the overlap pipeline's reduction workers are mid-update
//! can never pair one bucket's bytes with another's timing. (The counters
//! used to be independent relaxed atomics read field-by-field, which
//! could tear exactly that way.) The lock is uncontended in practice:
//! it is taken once per collective, not per element.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::codec::WireCodec;
use super::fault::{CancellableBarrier, CancellationToken, CommError};

/// Per-collective result: `Err` only when the world was cancelled (a
/// rank lost or a watchdog expiry), never for data errors — length
/// mismatches remain panics, as they are caller bugs, not faults.
pub type CommResult<T> = std::result::Result<T, CommError>;

/// Which payload counter a collective charges (see [`CommStats`]).
#[derive(Debug, Clone, Copy)]
enum Payload {
    Gather,
    AllReduce,
    ReduceScatter,
    Broadcast,
}

/// Byte counters per collective, for reporting and model cross-checks.
/// All updates and reads go through one internal mutex — see the module
/// docs for the snapshot-consistency guarantee.
///
/// Beyond the byte counters, the stats block carries the run's
/// **fault-event log** ([`TraceEvent`]): straggle sleeps, watchdog
/// expiries, rank losses, shrinks and resumes, recorded here because
/// the stats `Arc` is the one structure shared across both comm worlds
/// and *every incarnation* of an elastic run — events recorded before a
/// shrink survive it. The trainer drains the log into the `--trace-out`
/// JSONL sink ([`CommStats::take_events`]).
#[derive(Debug, Default)]
pub struct CommStats {
    inner: Mutex<CommStatsSnapshot>,
    events: Mutex<EventLog>,
    /// last iteration each rank reported via [`CommStats::set_rank_iter`]
    /// — stamps comm-layer events (which have no iteration context of
    /// their own) with the iteration the rank was in.
    cur_iter: Mutex<Vec<u64>>,
}

/// What kind of fault-path occurrence a [`TraceEvent`] records
/// (DESIGN.md §13/§14).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceEventKind {
    /// An injected `--straggle` sleep at a collective entry.
    Straggle,
    /// A watchdog expiry on a blocking wait (`CommError::Watchdog`).
    Watchdog,
    /// A rank observed as lost (cancellation with a declared loss).
    RankLost,
    /// A live world shrink K→K′ after a loss.
    Shrink,
    /// A worker (re)starting from a snapshot — cold resume or
    /// post-shrink rollback.
    Resume,
}

impl TraceEventKind {
    /// Stable identifier used in the JSONL `"kind"` field.
    pub fn id(&self) -> &'static str {
        match self {
            TraceEventKind::Straggle => "straggle",
            TraceEventKind::Watchdog => "watchdog",
            TraceEventKind::RankLost => "rank_lost",
            TraceEventKind::Shrink => "shrink",
            TraceEventKind::Resume => "resume",
        }
    }
}

/// One fault-path event: what happened, to which rank, at which
/// iteration (per [`CommStats::set_rank_iter`], 0 if never set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// What happened.
    pub kind: TraceEventKind,
    /// The rank it happened to (for `Shrink`/`Resume`: the reporting
    /// rank in the NEW world).
    pub rank: usize,
    /// The iteration the rank last reported before the event.
    pub iter: u64,
    /// Kind-specific payload: straggle/watchdog duration in µs,
    /// `Shrink`'s previous K, `Resume`'s snapshot step.
    pub a: u64,
    /// Kind-specific payload: `Shrink`'s new K′ (0 otherwise).
    pub b: u64,
}

/// Bounded event buffer: a runaway straggle configuration must not grow
/// memory without bound, so past [`EventLog::CAP`] events are counted
/// but dropped.
#[derive(Debug, Default)]
struct EventLog {
    events: Vec<TraceEvent>,
    dropped: u64,
}

impl EventLog {
    const CAP: usize = 65_536;
}

/// A point-in-time copy of [`CommStats`] — consistent by construction:
/// every field was read under the same lock each writer held.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CommStatsSnapshot {
    /// payload bytes handed to `all_gather` / `all_gather_chunks`
    pub all_gather_bytes: u64,
    /// payload bytes handed to `all_reduce_sum` (and `all_reduce_mean`)
    pub all_reduce_bytes: u64,
    /// payload bytes handed to `reduce_scatter_sum` / `reduce_range_sum`
    pub reduce_scatter_bytes: u64,
    /// payload bytes broadcast from a root rank
    pub broadcast_bytes: u64,
    /// number of collective operations charged
    pub ops: u64,
    /// modeled fabric bytes per rank moved reducing gradients, under the
    /// algorithm actually used (and at the encoded width of the wire
    /// codec actually used: bf16 charges half the f32 bytes, int8 a
    /// quarter — DESIGN.md §12/§15)
    pub grad_wire_bytes: u64,
    /// what [`super::NaiveAllReduce`] would have moved for the same
    /// reductions at the same wire width — the "before" of the
    /// before/after comparison
    pub grad_wire_bytes_naive: u64,
    /// sharded strategy only: the updated-parameter all-gather traffic
    /// (always full-width f32 — the parameters are the master state)
    pub param_wire_bytes: u64,
    /// `--loss-shard on` only: the cross-rank feature-gradient exchange
    /// traffic (DESIGN.md §16) — the (K−1) remote-destination segments
    /// each rank sends per [`WorkerComm::exchange_block_sums`], at the
    /// exchange codec's encoded width. Zero when the loss is unsharded.
    pub featgrad_wire_bytes: u64,
    /// measured reduction-worker time that ran concurrently with backward
    /// compute (µs, summed over ranks) — the part of the gradient
    /// reduction the overlap pipeline HID off the critical path
    /// (DESIGN.md §11). Zero for serial (`--overlap off`) runs, which
    /// expose every reduction microsecond.
    pub hidden_comm_us: u64,
    /// measured time the compute thread blocked waiting on outstanding
    /// bucket reductions after backward finished (µs, summed over ranks)
    /// — the reduction cost still on the critical path under overlap
    pub exposed_comm_us: u64,
}

impl CommStatsSnapshot {
    /// Total collective payload bytes (the seed's `comm_bytes` quantity).
    pub fn payload_bytes(&self) -> u64 {
        self.all_gather_bytes
            + self.all_reduce_bytes
            + self.reduce_scatter_bytes
            + self.broadcast_bytes
    }

    /// Gradient bytes-on-wire saving of the chosen reduction algorithm
    /// over naive all-reduce (1.0 = no saving; 2·(K-1)/K·… see
    /// [`super::collective`]). Returns 1.0 when nothing was reduced.
    pub fn grad_wire_saving(&self) -> f64 {
        if self.grad_wire_bytes == 0 {
            return 1.0;
        }
        self.grad_wire_bytes_naive as f64 / self.grad_wire_bytes as f64
    }
}

impl CommStats {
    /// Copy every counter into an immutable snapshot — one lock
    /// acquisition, so the copy is consistent even while other threads
    /// are charging counters (see the module docs).
    pub fn snapshot(&self) -> CommStatsSnapshot {
        *self.inner.lock().unwrap()
    }

    fn add_payload(&self, which: Payload, elems: usize, wire: WireCodec) {
        let bytes = wire.encoded_bytes(elems as u64);
        let mut s = self.inner.lock().unwrap();
        match which {
            Payload::Gather => s.all_gather_bytes += bytes,
            Payload::AllReduce => s.all_reduce_bytes += bytes,
            Payload::ReduceScatter => s.reduce_scatter_bytes += bytes,
            Payload::Broadcast => s.broadcast_bytes += bytes,
        }
        s.ops += 1;
    }

    /// Charge one gradient reduction: the chosen algorithm's wire bytes
    /// and the naive baseline's, per rank. The pair is written under one
    /// lock, so no snapshot can observe one half without the other.
    pub fn add_grad_wire(&self, chosen: u64, naive: u64) {
        let mut s = self.inner.lock().unwrap();
        s.grad_wire_bytes += chosen;
        s.grad_wire_bytes_naive += naive;
    }

    /// Charge the sharded strategy's updated-parameter all-gather bytes.
    pub fn add_param_wire(&self, bytes: u64) {
        self.inner.lock().unwrap().param_wire_bytes += bytes;
    }

    /// Charge one sharded-loss feature-gradient exchange's wire bytes
    /// (the remote-destination segments only — see
    /// [`WorkerComm::exchange_block_sums`]).
    pub fn add_featgrad_wire(&self, bytes: u64) {
        self.inner.lock().unwrap().featgrad_wire_bytes += bytes;
    }

    /// Report that `rank` entered iteration `iter`, so comm-layer events
    /// recorded from inside collectives carry the right iteration tag.
    /// Ranks beyond the initial world (never: worlds only shrink) grow
    /// the table on demand.
    pub fn set_rank_iter(&self, rank: usize, iter: u64) {
        let mut cur = self.cur_iter.lock().unwrap();
        if cur.len() <= rank {
            cur.resize(rank + 1, 0);
        }
        cur[rank] = iter;
    }

    /// Record one fault-path event for `rank`, stamped with the rank's
    /// last reported iteration. `a`/`b` are kind-specific (see
    /// [`TraceEvent`]). Bounded: past the internal cap (65536 events)
    /// the log only counts drops.
    pub fn record_event(&self, kind: TraceEventKind, rank: usize, a: u64, b: u64) {
        let iter = self.cur_iter.lock().unwrap().get(rank).copied().unwrap_or(0);
        let mut log = self.events.lock().unwrap();
        if log.events.len() >= EventLog::CAP {
            log.dropped += 1;
            return;
        }
        log.events.push(TraceEvent { kind, rank, iter, a, b });
    }

    /// Record one injected straggle sleep of `dur` on `rank`.
    pub fn record_straggle(&self, rank: usize, dur: Duration) {
        self.record_event(TraceEventKind::Straggle, rank, dur.as_micros() as u64, 0);
    }

    /// Take every recorded event, leaving the log empty (the trainer
    /// drains into the JSONL sink at the end of the run).
    pub fn take_events(&self) -> Vec<TraceEvent> {
        std::mem::take(&mut self.events.lock().unwrap().events)
    }

    /// How many events the bounded log dropped (see [`Self::record_event`]).
    pub fn events_dropped(&self) -> u64 {
        self.events.lock().unwrap().dropped
    }

    /// Charge one iteration's measured overlap split: `hidden_us` of
    /// reduction ran under backward compute, `exposed_us` blocked the
    /// compute thread (DESIGN.md §11). Charged once per rank per
    /// iteration by the overlap pipeline's owner, never by the serial
    /// path — so serial and pipelined runs are directly comparable
    /// without double-counting the overlap win. The pair is written under
    /// one lock acquisition (no torn hidden/exposed snapshots).
    pub fn add_overlap_us(&self, hidden_us: u64, exposed_us: u64) {
        let mut s = self.inner.lock().unwrap();
        s.hidden_comm_us += hidden_us;
        s.exposed_comm_us += exposed_us;
    }
}

/// The collective world shared by K worker threads: a cancellable
/// barrier, per-rank exchange slots, the shared cancellation token and
/// the byte/time counters. Create once per world with [`CommWorld::new`]
/// (or [`CommWorld::with_stats`] to share counters with another world,
/// or [`CommWorld::with_faults`] for a fault-injected world) and hand
/// each worker its [`WorkerComm`] via [`CommWorld::handle`].
pub struct CommWorld {
    k: usize,
    barrier: CancellableBarrier,
    /// per-rank input slots
    slots: Vec<Mutex<Vec<f32>>>,
    /// per-chunk reduction outputs (chunk c owned by rank c)
    chunks: Vec<Mutex<Vec<f32>>>,
    /// shared cancellation state — possibly shared with a sibling world
    /// (the trainer hands the training and reduction worlds one token,
    /// so a loss cancels both; see DESIGN.md §13)
    token: Arc<CancellationToken>,
    /// watchdog bound on every blocking wait (None = wait forever, the
    /// pre-fault behaviour — clean runs pay no deadline bookkeeping)
    watchdog: Option<Duration>,
    /// injected per-rank latency skew, applied at collective entry
    straggle: Vec<Duration>,
    /// shared counters — possibly shared with a sibling world (the
    /// overlap pipeline runs its bucket collectives on a second world so
    /// they never interleave with the compute thread's collectives, but
    /// both charge the same run-level stats)
    pub stats: Arc<CommStats>,
}

impl CommWorld {
    /// A fresh world of `k` ranks with its own counters.
    pub fn new(k: usize) -> Arc<Self> {
        CommWorld::with_stats(k, Arc::new(CommStats::default()))
    }

    /// A world of `k` ranks charging an existing set of counters — used
    /// by the overlap pipeline's dedicated reduction world (DESIGN.md
    /// §11), whose traffic belongs to the same training run. No faults:
    /// fresh token, no watchdog, no straggle.
    pub fn with_stats(k: usize, stats: Arc<CommStats>) -> Arc<Self> {
        CommWorld::with_faults(
            k,
            stats,
            Arc::new(CancellationToken::new()),
            None,
            vec![Duration::ZERO; k],
        )
    }

    /// A fault-aware world: `token` is the shared cancellation state
    /// (pass one token to sibling worlds so a loss cancels both),
    /// `watchdog` bounds every blocking wait, and `straggle[r]` is the
    /// latency rank `r` sleeps at the entry of every collective
    /// (DESIGN.md §13).
    pub fn with_faults(
        k: usize,
        stats: Arc<CommStats>,
        token: Arc<CancellationToken>,
        watchdog: Option<Duration>,
        straggle: Vec<Duration>,
    ) -> Arc<Self> {
        assert!(k > 0);
        assert_eq!(straggle.len(), k, "straggle must name every rank");
        Arc::new(Self {
            k,
            barrier: CancellableBarrier::new(k),
            slots: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            chunks: (0..k).map(|_| Mutex::new(Vec::new())).collect(),
            token,
            watchdog,
            straggle,
            stats,
        })
    }

    /// Number of ranks in the world.
    pub fn world_size(&self) -> usize {
        self.k
    }

    /// The shared cancellation token (declare losses through this).
    pub fn token(&self) -> &Arc<CancellationToken> {
        &self.token
    }

    /// The per-worker handle rank `rank` uses for every collective.
    pub fn handle(self: &Arc<Self>, rank: usize) -> WorkerComm {
        assert!(rank < self.k);
        WorkerComm { world: Arc::clone(self), rank }
    }
}

/// Per-worker handle to the collective world.
pub struct WorkerComm {
    world: Arc<CommWorld>,
    rank: usize,
}

impl WorkerComm {
    /// This worker's rank in `[0, K)`.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in the world.
    pub fn world_size(&self) -> usize {
        self.world.k
    }

    /// The world's shared counters.
    pub fn stats(&self) -> &CommStats {
        self.world.stats.as_ref()
    }

    /// The world's shared cancellation token.
    pub fn token(&self) -> &Arc<CancellationToken> {
        self.world.token()
    }

    /// Collective entry protocol: fail fast on a cancelled world, then
    /// apply this rank's injected straggle. The cancel check is what
    /// makes a failed world *permanently* failed — no collective can be
    /// issued on it again — and the straggle sleep models a slow rank
    /// without touching any numerics. K = 1 skips the sleep (there is no
    /// peer to be slow relative to) but keeps the cancel check.
    fn pre_op(&self) -> CommResult<()> {
        let w = &self.world;
        if w.token.is_cancelled() {
            return Err(w.token.error());
        }
        let skew = w.straggle[self.rank];
        if w.k > 1 && skew > Duration::ZERO {
            std::thread::sleep(skew);
            // telemetry after the sleep: clock-only, outside numerics
            w.stats.record_straggle(self.rank, skew);
        }
        Ok(())
    }

    /// Block until every rank reaches the same barrier call — or until
    /// the world is cancelled / the watchdog expires, in which case every
    /// waiter returns `Err` instead of hanging (DESIGN.md §13). A
    /// watchdog expiry is recorded in the shared event log before it is
    /// returned, so the trail names the rank whose wait timed out.
    pub fn barrier(&self) -> CommResult<()> {
        let res = self.world.barrier.wait(&self.world.token, self.world.watchdog);
        if matches!(res, Err(CommError::Watchdog)) {
            let us = self.world.watchdog.map_or(0, |d| d.as_micros() as u64);
            self.world.stats.record_event(TraceEventKind::Watchdog, self.rank, us, 0);
        }
        res
    }

    /// Bounds `[lo, hi)` of the chunk this rank owns when an `n`-element
    /// buffer is split over the world in `ceil(n/K)`-sized chunks (the
    /// last chunks may be short or empty when K does not divide n).
    pub fn owned_chunk(&self, n: usize) -> (usize, usize) {
        chunk_bounds(n, self.world.k, self.rank)
    }

    /// Concatenate every rank's `data` in rank order. All ranks must pass
    /// equal-length slices. The codec sets the wire format (DESIGN.md
    /// §12/§15): every rank's contribution is passed through
    /// [`WireCodec::wire_round`] before it enters the wire (the identity
    /// for `f32`, bf16 rounding for `bf16`, the blockwise round trip for
    /// `int8` — a no-op when the payload is already representable, as
    /// the native backend's bf16 embeddings are) and the payload
    /// counters charge the codec's encoded bytes. A gather has no return
    /// leg, so the transform is applied exactly once — K = 1 included.
    pub fn all_gather(&self, data: &[f32], wire: WireCodec) -> CommResult<Vec<f32>> {
        self.pre_op()?;
        let w = &self.world;
        if w.k == 1 {
            return Ok(wire.wire_rounded(data));
        }
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(data);
            wire.wire_round(&mut slot);
        }
        w.stats.add_payload(Payload::Gather, data.len(), wire);
        self.barrier()?;
        let mut out = Vec::with_capacity(data.len() * w.k);
        for r in 0..w.k {
            out.extend_from_slice(&w.slots[r].lock().unwrap());
        }
        self.barrier()?; // slots free for reuse
        Ok(out)
    }

    /// Concatenate per-rank chunks of *unequal* lengths in rank order —
    /// the gather half of the sharded strategy, where the chunking of
    /// [`Self::owned_chunk`] leaves the tail ranks short. `total_len` is
    /// the expected concatenated length (a cheap lockstep sanity check).
    /// Always full-width: this collective carries updated parameters —
    /// master state — which never travel in bf16 (DESIGN.md §12).
    pub fn all_gather_chunks(&self, mine: &[f32], total_len: usize) -> CommResult<Vec<f32>> {
        self.pre_op()?;
        let w = &self.world;
        if w.k == 1 {
            assert_eq!(mine.len(), total_len);
            return Ok(mine.to_vec());
        }
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(mine);
        }
        w.stats.add_payload(Payload::Gather, mine.len(), WireCodec::F32);
        self.barrier()?;
        let mut out = Vec::with_capacity(total_len);
        for r in 0..w.k {
            out.extend_from_slice(&w.slots[r].lock().unwrap());
        }
        self.barrier()?; // slots free for reuse
        assert_eq!(out.len(), total_len, "ranks disagreed on chunking");
        Ok(out)
    }

    /// SUM-reduce `buf` across ranks and return only the chunk this rank
    /// owns (see [`Self::owned_chunk`]). Elements are summed in rank
    /// order `0..K`, so the result is bit-identical to a rank-ordered
    /// local reduction of the same contributions. See
    /// [`Self::reduce_range_sum`] for the codec's wire contract.
    pub fn reduce_scatter_sum(&self, buf: &[f32], wire: WireCodec) -> CommResult<Vec<f32>> {
        let (lo, hi) = self.owned_chunk(buf.len());
        self.reduce_range_sum(buf, lo, hi, wire)
    }

    /// SUM-reduce `buf` across ranks and return the sub-range `[lo, hi)`
    /// of the reduced buffer. All ranks must pass equal-length buffers
    /// (lockstep), but each rank may request a *different* — possibly
    /// empty — sub-range: the overlap pipeline's bucketed sharded
    /// reduction asks each rank for the intersection of its global
    /// parameter chunk with the bucket (DESIGN.md §11). Per element the
    /// additions run in rank order `0..K` from a 0.0 accumulator, exactly
    /// as [`Self::reduce_scatter_sum`] — which is this method with the
    /// owned chunk as the range — so any tiling of requests over any
    /// bucketing reproduces the unbucketed reduction bitwise.
    ///
    /// The codec's wire contract (DESIGN.md §12/§15), per element: every
    /// rank's contribution passes through [`WireCodec::wire_round`]
    /// before transmission, the K contributions are summed in **f32** in
    /// rank order `0..K`, and the reduced value is rounded again for the
    /// return leg — `q(Σ_r q(g_r))`. The same per-element operation
    /// sequence holds for every algorithm, every bucketing and K = 1
    /// (which applies `q(q(·))` explicitly rather than relying on the
    /// codec being idempotent — bf16 is, int8 need not be), which is
    /// what keeps a FIXED codec bitwise deterministic everywhere, and
    /// keeps naive|ring|sharded × bucketed|whole identical under f32 and
    /// bf16 exactly as before.
    pub fn reduce_range_sum(
        &self,
        buf: &[f32],
        lo: usize,
        hi: usize,
        wire: WireCodec,
    ) -> CommResult<Vec<f32>> {
        debug_assert!(lo <= hi && hi <= buf.len());
        self.pre_op()?;
        let w = &self.world;
        if w.k == 1 {
            let mut out = wire.wire_rounded(&buf[lo..hi]);
            wire.wire_round(&mut out); // return leg: q(Σ q(·)) with K = 1
            return Ok(out);
        }
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(buf);
            wire.wire_round(&mut slot);
        }
        w.stats.add_payload(Payload::ReduceScatter, buf.len(), wire);
        self.barrier()?;
        let mut acc = vec![0.0f32; hi - lo];
        for r in 0..w.k {
            let slot = w.slots[r].lock().unwrap();
            for (a, v) in acc.iter_mut().zip(&slot[lo..hi]) {
                *a += v;
            }
        }
        self.barrier()?; // slots free for reuse
        wire.wire_round(&mut acc);
        Ok(acc)
    }

    /// The sharded-loss feature-gradient exchange (DESIGN.md §16):
    /// every rank contributes one `seg_len`-element segment per
    /// DESTINATION rank — `fill(s, seg)` is called for each destination
    /// `s` in ascending order (including `s == self`) and must write
    /// this rank's contribution to rank `s`'s features — and each rank
    /// receives the SUM over all source ranks of the segments destined
    /// for it.
    ///
    /// The per-element fold is the [`Self::reduce_range_sum`] wire
    /// contract verbatim: each segment passes through
    /// [`WireCodec::wire_round`] outbound, the K contributions are
    /// summed in f32 in **ascending source-rank order** from a 0.0
    /// accumulator, and the result is rounded again for the return leg
    /// — `q(Σ_r q(g_r))`, K = 1 applying `q(q(·))` explicitly. That
    /// fixed fold is the reduction order DESIGN.md §16 pins for both
    /// shard modes.
    ///
    /// Accounting: one ReduceScatter-payload charge of `K·seg_len`
    /// elements, plus `featgrad_wire_bytes` for the `(K−1)` segments a
    /// real fabric would carry off-rank (the self-segment never leaves
    /// the device). K = 1 charges nothing, like every other local fast
    /// path.
    pub fn exchange_block_sums(
        &self,
        seg_len: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
        wire: WireCodec,
    ) -> CommResult<Vec<f32>> {
        self.pre_op()?;
        let w = &self.world;
        if w.k == 1 {
            let mut seg = vec![0.0f32; seg_len];
            fill(0, &mut seg);
            wire.wire_round(&mut seg); // outbound leg
            wire.wire_round(&mut seg); // return leg: q(Σ q(·)) with K = 1
            return Ok(seg);
        }
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.resize(w.k * seg_len, 0.0);
            for s in 0..w.k {
                let seg = &mut slot[s * seg_len..(s + 1) * seg_len];
                fill(s, seg);
                wire.wire_round(seg);
            }
        }
        w.stats.add_payload(Payload::ReduceScatter, w.k * seg_len, wire);
        w.stats.add_featgrad_wire((w.k as u64 - 1) * wire.encoded_bytes(seg_len as u64));
        self.barrier()?;
        let mut acc = vec![0.0f32; seg_len];
        for r in 0..w.k {
            let slot = w.slots[r].lock().unwrap();
            let seg = &slot[self.rank * seg_len..(self.rank + 1) * seg_len];
            for (a, v) in acc.iter_mut().zip(seg) {
                *a += v;
            }
        }
        self.barrier()?; // slots free for reuse
        wire.wire_round(&mut acc);
        Ok(acc)
    }

    /// Element-wise SUM across ranks, result replicated into `buf`.
    /// Implemented reduce-scatter + all-gather style: rank r reduces chunk
    /// r so the reduction parallelizes across workers (O(n) per rank).
    /// On `Err` the contents of `buf` are unspecified (partially
    /// exchanged) — a cancelled iteration's data is rolled back anyway.
    /// Same per-element `q(Σ_r q(g_r))` codec contract as
    /// [`Self::reduce_range_sum`] (the contribution is rounded outbound,
    /// summed in f32 by the chunk owner, and the reduced value rounded
    /// again for the all-gather leg).
    pub fn all_reduce_sum(&self, buf: &mut [f32], wire: WireCodec) -> CommResult<()> {
        self.pre_op()?;
        let w = &self.world;
        if w.k == 1 {
            // both legs explicitly — q(q(x)) — rather than relying on the
            // codec being idempotent (bf16 is; int8 need not be)
            wire.wire_round(buf);
            wire.wire_round(buf);
            return Ok(());
        }
        wire.wire_round(buf);
        {
            let mut slot = w.slots[self.rank].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(buf);
        }
        w.stats.add_payload(Payload::AllReduce, buf.len(), wire);
        self.barrier()?;

        let n = buf.len();
        let (lo, hi) = self.owned_chunk(n);
        {
            let mut acc = vec![0.0f32; hi - lo];
            for r in 0..w.k {
                let slot = w.slots[r].lock().unwrap();
                for (a, v) in acc.iter_mut().zip(&slot[lo..hi]) {
                    *a += v;
                }
            }
            wire.wire_round(&mut acc);
            let mut out = w.chunks[self.rank].lock().unwrap();
            *out = acc;
        }
        self.barrier()?;
        for r in 0..w.k {
            let (lo_r, hi_r) = chunk_bounds(n, w.k, r);
            let part = w.chunks[r].lock().unwrap();
            buf[lo_r..hi_r].copy_from_slice(&part);
        }
        self.barrier()?;
        Ok(())
    }

    /// Mean across ranks (sum then scale). Always full-width f32: the
    /// mean is used for scalars and bootstrap state, never gradients.
    pub fn all_reduce_mean(&self, buf: &mut [f32]) -> CommResult<()> {
        self.all_reduce_sum(buf, WireCodec::F32)?;
        let inv = 1.0 / self.world.k as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }

    /// Copy `root`'s buffer to every rank.
    pub fn broadcast(&self, buf: &mut [f32], root: usize) -> CommResult<()> {
        self.pre_op()?;
        let w = &self.world;
        if w.k == 1 {
            return Ok(());
        }
        if self.rank == root {
            let mut slot = w.slots[root].lock().unwrap();
            slot.clear();
            slot.extend_from_slice(buf);
            w.stats.add_payload(Payload::Broadcast, buf.len(), WireCodec::F32);
        }
        self.barrier()?;
        if self.rank != root {
            let slot = w.slots[root].lock().unwrap();
            buf.copy_from_slice(&slot);
        }
        self.barrier()?;
        Ok(())
    }
}

/// `[lo, hi)` of chunk `r` when `n` elements are split into `ceil(n/k)`
/// chunks (tail chunks short or empty for non-divisible n). Public
/// because the checkpoint subsystem re-partitions sharded optimizer
/// state with the same chunking (DESIGN.md §9).
pub fn chunk_bounds(n: usize, k: usize, r: usize) -> (usize, usize) {
    let chunk = n.div_ceil(k);
    ((r * chunk).min(n), ((r + 1) * chunk).min(n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::precision::bf16_round;

    fn run_workers<F>(k: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(WorkerComm) -> Vec<f32> + Send + Sync + 'static,
    {
        let world = CommWorld::new(k);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..k)
            .map(|r| {
                let h = world.handle(r);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(h))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn event_log_records_tags_and_drains() {
        let stats = CommStats::default();
        stats.set_rank_iter(1, 7);
        stats.record_straggle(1, Duration::from_micros(250));
        stats.record_event(TraceEventKind::Shrink, 0, 4, 2);
        let evs = stats.take_events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, TraceEventKind::Straggle);
        assert_eq!((evs[0].rank, evs[0].iter, evs[0].a), (1, 7, 250));
        assert_eq!(evs[1].kind, TraceEventKind::Shrink);
        assert_eq!((evs[1].iter, evs[1].a, evs[1].b), (0, 4, 2));
        assert!(stats.take_events().is_empty(), "take drains the log");
        assert_eq!(stats.events_dropped(), 0);
    }

    #[test]
    fn straggle_sleep_is_recorded_per_collective() {
        let stats = Arc::new(CommStats::default());
        let token = Arc::new(CancellationToken::new());
        let mut straggle = vec![Duration::ZERO; 2];
        straggle[1] = Duration::from_micros(10);
        let world = CommWorld::with_faults(2, Arc::clone(&stats), token, None, straggle);
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let h = world.handle(r);
                std::thread::spawn(move || {
                    h.all_reduce_sum(&mut [1.0f32], WireCodec::F32).unwrap()
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let evs = stats.take_events();
        assert!(!evs.is_empty(), "the straggler must log its sleeps");
        assert!(evs.iter().all(|e| e.kind == TraceEventKind::Straggle && e.rank == 1));
        assert!(evs.iter().all(|e| e.a == 10));
    }

    #[test]
    fn all_gather_orders_by_rank() {
        for k in [1, 2, 4, 7] {
            let outs = run_workers(k, move |c| {
                let mine = vec![c.rank() as f32; 3];
                c.all_gather(&mine, WireCodec::F32).unwrap()
            });
            let expect: Vec<f32> =
                (0..k).flat_map(|r| std::iter::repeat(r as f32).take(3)).collect();
            for o in outs {
                assert_eq!(o, expect);
            }
        }
    }

    #[test]
    fn all_reduce_sum_correct() {
        for k in [1, 2, 3, 8] {
            let n = 1000; // exercises uneven chunking for k=3
            let outs = run_workers(k, move |c| {
                let mut buf: Vec<f32> =
                    (0..n).map(|i| (i as f32) + c.rank() as f32).collect();
                c.all_reduce_sum(&mut buf, WireCodec::F32).unwrap();
                buf
            });
            let rank_sum: f32 = (0..k).map(|r| r as f32).sum();
            for o in &outs {
                for (i, v) in o.iter().enumerate() {
                    let want = k as f32 * i as f32 + rank_sum;
                    assert!((v - want).abs() < 1e-3, "k={k} i={i} {v} vs {want}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_chunks_tile_the_buffer() {
        // non-divisible: n=10 over k=4 gives chunks 3,3,3,1
        for (k, n) in [(1usize, 7usize), (2, 9), (4, 10), (3, 1000)] {
            let outs = run_workers(k, move |c| {
                let buf: Vec<f32> = (0..n).map(|i| i as f32 * (c.rank() + 1) as f32).collect();
                c.reduce_scatter_sum(&buf, WireCodec::F32).unwrap()
            });
            let scale: f32 = (1..=k).map(|r| r as f32).sum();
            let mut covered = 0;
            for (r, o) in outs.iter().enumerate() {
                let chunk = n.div_ceil(k);
                let lo = (r * chunk).min(n);
                let hi = ((r + 1) * chunk).min(n);
                assert_eq!(o.len(), hi - lo, "k={k} n={n} r={r}");
                for (j, v) in o.iter().enumerate() {
                    let want = (lo + j) as f32 * scale;
                    assert!((v - want).abs() < 1e-3, "k={k} n={n} r={r} j={j}");
                }
                covered += o.len();
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn reduce_range_sum_arbitrary_ranges() {
        // per-rank ranges may differ and may be empty; summation matches
        // reduce_scatter_sum element-for-element (same rank order)
        for (k, n) in [(1usize, 6usize), (2, 9), (4, 10), (3, 17)] {
            let outs = run_workers(k, move |c| {
                let buf: Vec<f32> = (0..n).map(|i| i as f32 * (c.rank() + 1) as f32).collect();
                // rank r asks for [r, n) clamped — unequal, rank-specific
                let lo = c.rank().min(n);
                let mut got = c.reduce_range_sum(&buf, lo, n, WireCodec::F32).unwrap();
                // empty range is a legal collective call
                let empty = c.reduce_range_sum(&buf, 0, 0, WireCodec::F32).unwrap();
                assert!(empty.is_empty());
                got.insert(0, lo as f32); // carry lo for the assertion
                got
            });
            let scale: f32 = (1..=k).map(|r| r as f32).sum();
            for o in &outs {
                let lo = o[0] as usize;
                for (j, v) in o[1..].iter().enumerate() {
                    let want = (lo + j) as f32 * scale;
                    assert!((v - want).abs() < 1e-3, "k={k} n={n} lo={lo} j={j}");
                }
            }
        }
    }

    /// Run one of each data collective at `wire` on a K=2 world and
    /// return the charged payload counters (64 elements per call).
    fn stats_at(wire: WireCodec) -> CommStatsSnapshot {
        let world = CommWorld::new(2);
        let handles: Vec<_> = (0..2)
            .map(|r| {
                let h = world.handle(r);
                std::thread::spawn(move || {
                    let buf = vec![1.5f32; 64];
                    h.all_gather(&buf, wire).unwrap();
                    let mut b = buf.clone();
                    h.all_reduce_sum(&mut b, wire).unwrap();
                    h.reduce_range_sum(&buf, 0, 64, wire).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        world.stats.snapshot()
    }

    /// The bf16 wire contract: per element `q(Σ_r q(g_r))`, and the
    /// payload counters charge exactly half the f32 bytes.
    #[test]
    fn bf16_wire_quantizes_and_charges_half() {
        for k in [1usize, 2, 3] {
            let n = 37;
            let outs = run_workers(k, move |c| {
                let buf: Vec<f32> =
                    (0..n).map(|i| 0.1 + i as f32 * 1.017 + c.rank() as f32 * 0.31).collect();
                c.reduce_range_sum(&buf, 0, n, WireCodec::Bf16).unwrap()
            });
            // reference: quantize contributions, f32 sum in rank order,
            // quantize the result
            for o in &outs {
                for (i, v) in o.iter().enumerate() {
                    let mut acc = 0.0f32;
                    for r in 0..k {
                        acc += bf16_round(0.1 + i as f32 * 1.017 + r as f32 * 0.31);
                    }
                    let want = bf16_round(acc);
                    assert_eq!(v.to_bits(), want.to_bits(), "k={k} i={i}");
                }
            }
        }
        // payload accounting at half width (K=2 so bytes actually move)
        let f = stats_at(WireCodec::F32);
        let b = stats_at(WireCodec::Bf16);
        assert_eq!(f.all_gather_bytes, 2 * b.all_gather_bytes);
        assert_eq!(f.all_reduce_bytes, 2 * b.all_reduce_bytes);
        assert_eq!(f.reduce_scatter_bytes, 2 * b.reduce_scatter_bytes);
        assert_eq!(f.ops, b.ops);
    }

    /// The lossy codecs charge their exact encoded widths: int8 exactly
    /// a quarter of f32 (the CI 4x gate), topk 8 bytes per selected
    /// element — 64 elems -> k = 4 -> 32 B vs f32's 256 B.
    #[test]
    fn lossy_codecs_charge_encoded_bytes() {
        let f = stats_at(WireCodec::F32);
        let i8s = stats_at(WireCodec::Int8);
        let t = stats_at(WireCodec::TopK);
        assert_eq!(f.all_gather_bytes, 4 * i8s.all_gather_bytes);
        assert_eq!(f.all_reduce_bytes, 4 * i8s.all_reduce_bytes);
        assert_eq!(f.reduce_scatter_bytes, 4 * i8s.reduce_scatter_bytes);
        assert_eq!(t.all_gather_bytes, 2 * 8 * (64u64 / 16));
        assert_eq!(t.all_reduce_bytes, 2 * 8 * (64u64 / 16));
        assert_eq!(t.reduce_scatter_bytes, 2 * 8 * (64u64 / 16));
        assert_eq!(f.ops, i8s.ops);
        assert_eq!(f.ops, t.ops);
    }

    /// Regression test for torn snapshots: paired counters (hidden vs
    /// exposed, chosen vs naive wire bytes) are updated under one lock,
    /// so a snapshot taken mid-hammering always observes exact pair
    /// ratios — never one bucket's bytes with another's timing. With the
    /// old field-by-field relaxed atomics this raced.
    #[test]
    fn snapshots_never_tear_paired_counters() {
        let stats = Arc::new(CommStats::default());
        let writers: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&stats);
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        s.add_overlap_us(70, 30);
                        s.add_grad_wire(512, 1536);
                    }
                })
            })
            .collect();
        let reader = {
            let s = Arc::clone(&stats);
            std::thread::spawn(move || {
                for _ in 0..20_000 {
                    let snap = s.snapshot();
                    // every update adds (70, 30): hidden/exposed must sit
                    // exactly on the 7:3 line at every instant
                    assert_eq!(snap.hidden_comm_us * 3, snap.exposed_comm_us * 7);
                    // every update adds (512, 1536): exact 1:3 line
                    assert_eq!(snap.grad_wire_bytes * 3, snap.grad_wire_bytes_naive);
                }
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        reader.join().unwrap();
        let s = stats.snapshot();
        assert_eq!(s.hidden_comm_us, 4 * 5_000 * 70);
        assert_eq!(s.exposed_comm_us, 4 * 5_000 * 30);
        assert_eq!(s.grad_wire_bytes, 4 * 5_000 * 512);
        assert_eq!(s.grad_wire_bytes_naive, 4 * 5_000 * 1536);
    }

    #[test]
    fn shared_stats_accumulate_across_worlds() {
        let stats = Arc::new(CommStats::default());
        let a = CommWorld::with_stats(1, Arc::clone(&stats));
        let b = CommWorld::with_stats(1, Arc::clone(&stats));
        a.handle(0).all_gather(&[1.0; 4], WireCodec::F32).unwrap();
        b.handle(0).all_gather(&[1.0; 4], WireCodec::F32).unwrap();
        b.stats.add_overlap_us(70, 30);
        let s = stats.snapshot();
        assert_eq!(s.ops, 0, "K=1 gathers are local, nothing charged");
        assert_eq!(s.hidden_comm_us, 70);
        assert_eq!(s.exposed_comm_us, 30);
        assert_eq!(a.stats.snapshot(), b.stats.snapshot());
    }

    /// exchange_block_sums: each rank receives the ascending-source-rank
    /// f32 fold of every rank's segment destined for it — bitwise equal
    /// to the same fold computed locally — and the accounting charges
    /// one K·seg_len ReduceScatter payload plus (K−1) segments of
    /// featgrad wire per call per rank.
    #[test]
    fn exchange_block_sums_folds_in_rank_order() {
        for k in [1usize, 2, 3, 4] {
            let n = 13; // non-divisible by anything interesting
            let outs = run_workers(k, move |c| {
                c.exchange_block_sums(
                    n,
                    &mut |dest, seg| {
                        for (j, v) in seg.iter_mut().enumerate() {
                            // distinct per (src, dest, j) contribution
                            *v = (c.rank() * 100 + dest * 10) as f32 + j as f32 * 0.25;
                        }
                    },
                    WireCodec::F32,
                )
                .unwrap()
            });
            for (dest, o) in outs.iter().enumerate() {
                for (j, v) in o.iter().enumerate() {
                    // the pinned fold: ascending source rank from 0.0
                    let mut want = 0.0f32;
                    for src in 0..k {
                        want += (src * 100 + dest * 10) as f32 + j as f32 * 0.25;
                    }
                    assert_eq!(v.to_bits(), want.to_bits(), "k={k} dest={dest} j={j}");
                }
            }
        }
    }

    /// The exchange honors the per-segment codec contract
    /// (q(Σ_r q(g_r))) and charges the codec's encoded bytes — K = 1
    /// applies both legs but charges nothing.
    #[test]
    fn exchange_block_sums_codec_contract_and_accounting() {
        let k = 3;
        let n = 37;
        let world = CommWorld::new(k);
        let handles: Vec<_> = (0..k)
            .map(|r| {
                let c = world.handle(r);
                std::thread::spawn(move || {
                    c.exchange_block_sums(
                        n,
                        &mut |dest, seg| {
                            for (j, v) in seg.iter_mut().enumerate() {
                                *v = 0.1 + (r + dest) as f32 * 0.31 + j as f32 * 1.017;
                            }
                        },
                        WireCodec::Bf16,
                    )
                    .unwrap()
                })
            })
            .collect();
        let outs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (dest, o) in outs.iter().enumerate() {
            for (j, v) in o.iter().enumerate() {
                let mut acc = 0.0f32;
                for src in 0..k {
                    acc += bf16_round(0.1 + (src + dest) as f32 * 0.31 + j as f32 * 1.017);
                }
                let want = bf16_round(acc);
                assert_eq!(v.to_bits(), want.to_bits(), "dest={dest} j={j}");
            }
        }
        let s = world.stats.snapshot();
        // one call per rank: K·n elements of bf16 ReduceScatter payload
        assert_eq!(s.reduce_scatter_bytes, k as u64 * (k * n) as u64 * 2);
        // featgrad wire: (K−1) segments of n bf16 elements per rank
        assert_eq!(s.featgrad_wire_bytes, k as u64 * (k as u64 - 1) * (n as u64 * 2));
        assert_eq!(s.ops, k as u64);

        // K = 1: local, both codec legs applied, nothing charged
        let world1 = CommWorld::new(1);
        let got = world1
            .handle(0)
            .exchange_block_sums(
                4,
                &mut |dest, seg| {
                    assert_eq!(dest, 0);
                    seg.copy_from_slice(&[0.1, 1.117, 2.134, 3.151]);
                },
                WireCodec::Bf16,
            )
            .unwrap();
        for (j, v) in got.iter().enumerate() {
            let want = bf16_round(bf16_round(0.1 + j as f32 * 1.017));
            assert_eq!(v.to_bits(), want.to_bits(), "K=1 j={j}");
        }
        let s1 = world1.stats.snapshot();
        assert_eq!(s1.reduce_scatter_bytes, 0);
        assert_eq!(s1.featgrad_wire_bytes, 0);
    }

    #[test]
    fn all_gather_chunks_reassembles_uneven() {
        for (k, n) in [(1usize, 5usize), (2, 9), (4, 10), (3, 7)] {
            let outs = run_workers(k, move |c| {
                let (lo, hi) = c.owned_chunk(n);
                let mine: Vec<f32> = (lo..hi).map(|i| i as f32).collect();
                c.all_gather_chunks(&mine, n).unwrap()
            });
            let expect: Vec<f32> = (0..n).map(|i| i as f32).collect();
            for o in outs {
                assert_eq!(o, expect, "k={k} n={n}");
            }
        }
    }

    #[test]
    fn all_reduce_mean_correct() {
        let outs = run_workers(4, |c| {
            let mut buf = vec![c.rank() as f32; 5];
            c.all_reduce_mean(&mut buf).unwrap();
            buf
        });
        for o in outs {
            for v in o {
                assert!((v - 1.5).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn broadcast_from_root() {
        let outs = run_workers(4, |c| {
            let mut buf = if c.rank() == 2 { vec![7.0; 4] } else { vec![0.0; 4] };
            c.broadcast(&mut buf, 2).unwrap();
            buf
        });
        for o in outs {
            assert_eq!(o, vec![7.0; 4]);
        }
    }

    #[test]
    fn repeated_collectives_no_deadlock() {
        let outs = run_workers(3, |c| {
            let mut acc = vec![0.0f32; 3];
            for it in 0..50 {
                let g = c.all_gather(&[it as f32, c.rank() as f32], WireCodec::F32).unwrap();
                acc[0] += g.iter().sum::<f32>();
                let mut buf = vec![1.0f32; 2];
                c.all_reduce_sum(&mut buf, WireCodec::F32).unwrap();
                acc[1] += buf[0];
                let chunk = c.reduce_scatter_sum(&[1.0; 5], WireCodec::F32).unwrap();
                acc[2] += chunk.iter().sum::<f32>();
            }
            acc
        });
        for o in &outs {
            assert_eq!(o, &outs[0]);
        }
    }

    #[test]
    fn stats_accumulate() {
        let world = CommWorld::new(2);
        let h0 = world.handle(0);
        let h1 = world.handle(1);
        let t = std::thread::spawn(move || {
            h1.all_gather(&[1.0; 8], WireCodec::F32).unwrap();
            h1.reduce_scatter_sum(&[1.0; 8], WireCodec::F32).unwrap();
        });
        h0.all_gather(&[2.0; 8], WireCodec::F32).unwrap();
        h0.reduce_scatter_sum(&[2.0; 8], WireCodec::F32).unwrap();
        t.join().unwrap();
        let s = world.stats.snapshot();
        assert_eq!(s.all_gather_bytes, 2 * 8 * 4);
        assert_eq!(s.reduce_scatter_bytes, 2 * 8 * 4);
        assert_eq!(s.ops, 4);
        assert_eq!(s.payload_bytes(), 4 * 8 * 4);
        assert_eq!(s.grad_wire_saving(), 1.0, "no gradient reductions charged");
    }

    /// A cancelled world fails every rank's collective with the lost
    /// ranks, never hangs — including a rank that arrives at the
    /// collective only after cancellation.
    #[test]
    fn cancellation_fails_collectives_instead_of_hanging() {
        use crate::comm::fault::CommError;
        let world = CommWorld::new(3);
        // ranks 0 and 1 enter the collective; rank 2 never will
        let h: Vec<_> = (0..2)
            .map(|r| {
                let c = world.handle(r);
                std::thread::spawn(move || {
                    let mut buf = vec![r as f32; 16];
                    c.all_reduce_sum(&mut buf, WireCodec::F32)
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        world.token().declare_lost(2);
        for t in h {
            assert_eq!(t.join().unwrap().unwrap_err(), CommError::RanksLost(vec![2]));
        }
        // permanently failed: a later collective errs immediately, K=1
        // fast paths included
        let c = world.handle(0);
        assert!(c.all_gather(&[1.0], WireCodec::F32).is_err());
        assert!(c.barrier().is_err());
    }

    /// An injected straggler delays but does not change results, and the
    /// hidden/exposed accounting the bench paths build on stays exact.
    #[test]
    fn straggler_skews_latency_not_numerics() {
        let k = 2;
        let make = |skew_ms: u64| {
            let straggle = vec![Duration::from_millis(skew_ms), Duration::ZERO];
            CommWorld::with_faults(
                k,
                Arc::new(CommStats::default()),
                Arc::new(CancellationToken::new()),
                Some(Duration::from_secs(30)),
                straggle,
            )
        };
        let run = |world: &Arc<CommWorld>| {
            let handles: Vec<_> = (0..k)
                .map(|r| {
                    let c = world.handle(r);
                    std::thread::spawn(move || {
                        let mut buf: Vec<f32> = (0..17).map(|i| (i + r) as f32).collect();
                        c.all_reduce_sum(&mut buf, WireCodec::F32).unwrap();
                        buf
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        };
        let skewed = make(15);
        let clean = make(0);
        let t0 = std::time::Instant::now();
        let a = run(&skewed);
        let skewed_elapsed = t0.elapsed();
        let b = run(&clean);
        assert_eq!(a, b, "straggle must not change any reduced value");
        assert!(skewed_elapsed >= Duration::from_millis(15), "the skew really applies");
    }
}
