//! Blocked, multithreaded f32 GEMM with a deterministic summation tree.
//!
//! Three layouts cover everything the native backend needs:
//! * [`matmul`]      — `C[m,n] = A[m,k] · B[k,n]` (encoder forward),
//! * [`matmul_bt`]   — `C[m,n] = A[m,k] · B[n,k]ᵀ` (pairwise similarity),
//! * [`matmul_at_b`] — `C[k,n] = A[m,k]ᵀ · B[m,n]` (weight gradients).
//!
//! All matrices are dense row-major. The k (reduction) dimension is walked
//! in ascending order inside fixed-size blocks of [`KC`]; since block
//! boundaries never reorder the per-element addition sequence, every
//! output element's summation tree is the plain left-to-right scalar one —
//! the blocked kernels are **bitwise identical** to the `*_ref` naive
//! triple loops at any thread count (threads partition output rows only).
//! The inner loops are written as long contiguous row AXPYs / dot products
//! so the auto-vectorizer can use SIMD lanes across the *output* (j) axis,
//! which does not touch the reduction order.

use super::{par_rows, split_ranges};

/// Reduction-dimension block size (cache tile, ~16 KiB of B panel rows).
pub const KC: usize = 64;

/// `C[m,n] = A[m,k] · B[k,n]`, row-major, C overwritten.
pub fn matmul(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    par_rows(c, m, n, threads, |lo, hi, chunk| {
        chunk.fill(0.0);
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for i in lo..hi {
                let crow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
                for kk in kb..kend {
                    let aik = a[i * k + kk];
                    let brow = &b[kk * n..kk * n + n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * *bv;
                    }
                }
            }
        }
    });
}

/// Naive scalar reference for [`matmul`] — same summation tree.
pub fn matmul_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[kk * n + j];
            }
            c[i * n + j] = acc;
        }
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` — both operands row-major with contiguous
/// k, i.e. the pairwise-similarity form `s_ij = <a_i, b_j>`.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bt(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), n * k, "B shape");
    assert_eq!(c.len(), m * n, "C shape");
    par_rows(c, m, n, threads, |lo, hi, chunk| {
        for i in lo..hi {
            let arow = &a[i * k..i * k + k];
            let crow = &mut chunk[(i - lo) * n..(i - lo + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv = dot(arow, &b[j * k..j * k + k]);
            }
        }
    });
}

/// Naive scalar reference for [`matmul_bt`] — same summation tree.
pub fn matmul_bt_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a[i * k + kk] * b[j * k + kk];
            }
            c[i * n + j] = acc;
        }
    }
}

/// `C[k,n] = A[m,k]ᵀ · B[m,n]` — the weight-gradient form
/// `dW[p,q] = Σ_i A[i,p]·B[i,q]`, reduced over rows `i` in ascending
/// order. Threads partition the rows of C (the `p` axis).
#[allow(clippy::too_many_arguments)]
pub fn matmul_at_b(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), m * n, "B shape");
    assert_eq!(c.len(), k * n, "C shape");
    par_rows(c, k, n, threads, |lo, hi, chunk| {
        chunk.fill(0.0);
        for i in 0..m {
            let brow = &b[i * n..i * n + n];
            for p in lo..hi {
                let aip = a[i * k + p];
                let crow = &mut chunk[(p - lo) * n..(p - lo + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aip * *bv;
                }
            }
        }
    });
}

/// Naive scalar reference for [`matmul_at_b`] — same summation tree.
pub fn matmul_at_b_ref(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    for p in 0..k {
        for q in 0..n {
            let mut acc = 0.0f32;
            for i in 0..m {
                acc += a[i * k + p] * b[i * n + q];
            }
            c[p * n + q] = acc;
        }
    }
}

/// Sequential (ascending-index) dot product — THE reduction primitive all
/// similarity rows share; public so callers stay on the same tree.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        acc += *a * *b;
    }
    acc
}

/// Column sums of a row-major (m, n) matrix: `out[j] = Σ_i x[i,j]`,
/// reduced over rows in ascending order (bias gradients).
pub fn col_sums(x: &[f32], m: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * n);
    assert_eq!(out.len(), n);
    out.fill(0.0);
    for i in 0..m {
        let row = &x[i * n..i * n + n];
        for (o, v) in out.iter_mut().zip(row) {
            *o += *v;
        }
    }
}

/// Used by tests and the parity suite: split ranges identical to the
/// parallel partitioning (re-exported for bench labelling).
pub fn row_partition(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    split_ranges(rows, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn matmul_matches_ref_bitwise_all_threads() {
        // odd shapes, k crossing the KC block boundary non-divisibly
        let shapes = [(1usize, 1usize, 1usize), (3, 5, 7), (8, 64, 16), (13, 65, 9), (2, 130, 3)];
        for (m, k, n) in shapes {
            let a = randn(m * k, 1);
            let b = randn(k * n, 2);
            let mut want = vec![0.0f32; m * n];
            matmul_ref(&a, &b, &mut want, m, k, n);
            for threads in [1usize, 2, 4] {
                let mut got = vec![0.0f32; m * n];
                matmul(&a, &b, &mut got, m, k, n, threads);
                assert_eq!(bits(&got), bits(&want), "m={m} k={k} n={n} t={threads}");
            }
        }
    }

    #[test]
    fn matmul_bt_matches_ref_bitwise() {
        for (m, k, n) in [(5usize, 3usize, 5usize), (8, 64, 8), (7, 33, 11)] {
            let a = randn(m * k, 3);
            let b = randn(n * k, 4);
            let mut want = vec![0.0f32; m * n];
            matmul_bt_ref(&a, &b, &mut want, m, k, n);
            for threads in [1usize, 2, 4] {
                let mut got = vec![0.0f32; m * n];
                matmul_bt(&a, &b, &mut got, m, k, n, threads);
                assert_eq!(bits(&got), bits(&want), "t={threads}");
            }
        }
    }

    #[test]
    fn matmul_at_b_matches_ref_bitwise() {
        for (m, k, n) in [(4usize, 6usize, 2usize), (9, 5, 13), (16, 32, 64)] {
            let a = randn(m * k, 5);
            let b = randn(m * n, 6);
            let mut want = vec![0.0f32; k * n];
            matmul_at_b_ref(&a, &b, &mut want, m, k, n);
            for threads in [1usize, 2, 4] {
                let mut got = vec![0.0f32; k * n];
                matmul_at_b(&a, &b, &mut got, m, k, n, threads);
                assert_eq!(bits(&got), bits(&want), "t={threads}");
            }
        }
    }

    #[test]
    fn small_known_product() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let mut c = [0.0f32; 4];
        matmul(&a, &b, &mut c, 2, 2, 2, 1);
        assert_eq!(c, [19.0, 22.0, 43.0, 50.0]);
        // bt form: B here interpreted as rows b0=(5,6), b1=(7,8)
        let mut cbt = [0.0f32; 4];
        matmul_bt(&a, &b, &mut cbt, 2, 2, 2, 1);
        assert_eq!(cbt, [17.0, 23.0, 39.0, 53.0]);
    }

    #[test]
    fn col_sums_and_dot() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut s = [0.0f32; 3];
        col_sums(&x, 2, 3, &mut s);
        assert_eq!(s, [5.0, 7.0, 9.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
