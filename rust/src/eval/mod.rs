//! Downstream evaluation — the Datacomp-benchmark analog (DESIGN.md §1).
//!
//! Three task families computed from the learned joint embedding, mirroring
//! the paper's metric groups:
//! * **Retrieval** (Flickr/MSCOCO analog): image↔text R@1 on the held-out
//!   paired split;
//! * **IN & Variants** (ImageNet + distribution shifts analog): zero-shot
//!   classification of held-out images against class-prompt text
//!   embeddings, on the clean set and 3 procedural shifts
//!   (noisy / occluded / scrambled);
//! * **Datacomp** = mean over all task scores.
//!
//! All scores are percentages in [0, 100].
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

mod metrics;

pub use metrics::{retrieval_recall_at_k, zero_shot_accuracy};

use anyhow::Result;

use crate::data::{Dataset, EvalVariant};
use crate::runtime::ComputeBackend;

/// One evaluation snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalSummary {
    /// mean of image→text R@1 and text→image R@1
    pub retrieval: f32,
    /// mean zero-shot accuracy over clean + 3 shifted variants
    pub in_variants: f32,
    /// mean over every task score (the headline metric)
    pub datacomp: f32,
    /// individual (name, score) task results
    pub tasks: Vec<(String, f32)>,
}

impl EvalSummary {
    pub fn task(&self, name: &str) -> Option<f32> {
        self.tasks.iter().find(|(n, _)| n == name).map(|(_, s)| *s)
    }
}

/// Evaluate the model with parameters `params` on the dataset's held-out
/// split, running the encoder through the worker's compute backend in
/// local-batch-sized chunks.
pub fn evaluate(rt: &mut dyn ComputeBackend, ds: &Dataset, params: &[f32]) -> Result<EvalSummary> {
    let d = rt.manifest().model.d_embed;
    let mut tasks = Vec::new();

    // ---- retrieval on the clean paired split -----------------------------
    let clean = ds.eval_set(EvalVariant::Clean);
    let img_emb = embed_images(rt, params, &clean.images, clean.n)?;
    let txt_emb = embed_texts(rt, params, &clean.texts, clean.n)?;
    let i2t = retrieval_recall_at_k(&img_emb, &txt_emb, d, 1);
    let t2i = retrieval_recall_at_k(&txt_emb, &img_emb, d, 1);
    tasks.push(("retrieval_i2t_r1".to_string(), i2t));
    tasks.push(("retrieval_t2i_r1".to_string(), t2i));
    let retrieval = 0.5 * (i2t + t2i);

    // ---- zero-shot over the class prompts, clean + shifted ---------------
    let prompts = ds.class_prompts();
    let class_emb = embed_texts(rt, params, &prompts, ds.n_classes())?;
    let mut zs_sum = 0.0;
    for variant in EvalVariant::all() {
        let set = ds.eval_set(variant);
        let emb = if variant == EvalVariant::Clean {
            img_emb.clone()
        } else {
            embed_images(rt, params, &set.images, set.n)?
        };
        let acc = zero_shot_accuracy(&emb, &class_emb, &set.labels, d);
        tasks.push((format!("zeroshot_{}", variant.name()), acc));
        zs_sum += acc;
    }
    let in_variants = zs_sum / EvalVariant::all().len() as f32;

    let datacomp = tasks.iter().map(|(_, s)| s).sum::<f32>() / tasks.len() as f32;
    Ok(EvalSummary { retrieval, in_variants, datacomp, tasks })
}

/// Embed `n` images (row-major (n, img_dim)) through the backend's
/// `encode` in chunks of the bundle's local batch, padding the tail.
fn embed_images(
    rt: &mut dyn ComputeBackend,
    params: &[f32],
    images: &[f32],
    n: usize,
) -> Result<Vec<f32>> {
    let m = rt.manifest().clone();
    let bl = m.local_batch;
    let img_dim = m.model.v_patches * m.model.v_patch_dim;
    let dummy_texts = vec![0i32; bl * m.model.t_len];
    let mut out = Vec::with_capacity(n * m.model.d_embed);
    let mut chunk = vec![0.0f32; bl * img_dim];
    let mut i = 0;
    while i < n {
        let take = (n - i).min(bl);
        chunk[..take * img_dim].copy_from_slice(&images[i * img_dim..(i + take) * img_dim]);
        chunk[take * img_dim..].fill(0.0); // pad tail
        let (e1, _e2) = rt.encode(params, &chunk, &dummy_texts)?;
        out.extend_from_slice(&e1[..take * m.model.d_embed]);
        i += take;
    }
    Ok(out)
}

/// Embed `n` token sequences (row-major (n, t_len)); same chunking.
fn embed_texts(
    rt: &mut dyn ComputeBackend,
    params: &[f32],
    texts: &[i32],
    n: usize,
) -> Result<Vec<f32>> {
    let m = rt.manifest().clone();
    let bl = m.local_batch;
    let img_dim = m.model.v_patches * m.model.v_patch_dim;
    let dummy_images = vec![0.0f32; bl * img_dim];
    let mut out = Vec::with_capacity(n * m.model.d_embed);
    let mut chunk = vec![0i32; bl * m.model.t_len];
    let mut i = 0;
    while i < n {
        let take = (n - i).min(bl);
        chunk[..take * m.model.t_len]
            .copy_from_slice(&texts[i * m.model.t_len..(i + take) * m.model.t_len]);
        chunk[take * m.model.t_len..].fill(0);
        let (_e1, e2) = rt.encode(params, &dummy_images, &chunk)?;
        out.extend_from_slice(&e2[..take * m.model.d_embed]);
        i += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;
    use crate::data::ModelDims;
    use crate::runtime::{Manifest, NativeBackend};

    #[test]
    fn evaluate_random_model_near_chance() {
        let m = Manifest::native("tiny", 2, 8, 0).unwrap();
        let mut rt = NativeBackend::new(&m, Some("gcl"), 1).unwrap();
        let dcfg = DataConfig { n_train: 64, n_eval: 64, n_classes: 8, ..DataConfig::default() };
        let ds = Dataset::new(dcfg, m.model_dims());
        let params = m.load_init_params().unwrap();
        let s = evaluate(&mut rt, &ds, &params).unwrap();
        assert_eq!(s.tasks.len(), 6);
        // untrained: zero-shot should be in a loose band around chance
        // (1/8 = 12.5%); far from perfect
        assert!(s.in_variants < 60.0, "untrained in_variants {}", s.in_variants);
        assert!(s.datacomp >= 0.0 && s.datacomp <= 100.0);
        assert!(s.task("retrieval_i2t_r1").is_some());
        assert!(s.task("zeroshot_occluded").is_some());
        assert!(s.task("nope").is_none());
    }

    #[test]
    fn chunked_embedding_matches_direct() {
        let m = Manifest::native("tiny", 2, 8, 0).unwrap();
        let mut rt = NativeBackend::new(&m, Some("gcl"), 2).unwrap();
        let params = m.load_init_params().unwrap();
        let dims: ModelDims = m.model_dims();
        let img_dim = dims.v_patches * dims.v_patch_dim;
        // n = bl + 3 exercises the padded tail
        let n = m.local_batch + 3;
        let mut rng = crate::util::Rng::new(3);
        let mut images = vec![0.0f32; n * img_dim];
        rng.fill_normal(&mut images, 1.0);
        let emb = embed_images(&mut rt, &params, &images, n).unwrap();
        assert_eq!(emb.len(), n * m.model.d_embed);
        // each row L2-normalized (encode normalizes)
        for row in emb.chunks(m.model.d_embed) {
            let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-3);
        }
        // re-embedding the tail sample alone gives the same embedding
        let last = &images[(n - 1) * img_dim..];
        let mut single = vec![0.0f32; img_dim];
        single.copy_from_slice(last);
        let emb_single = embed_images(&mut rt, &params, &single, 1).unwrap();
        let got = &emb[(n - 1) * m.model.d_embed..];
        for (a, b) in got.iter().zip(&emb_single) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
