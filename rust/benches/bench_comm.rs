//! Collective benchmarks: real in-process collectives (all_gather /
//! all_reduce) across worker counts and payload sizes, plus the α–β cost
//! model's analytic times for the same shapes — the microbenchmark behind
//! the Fig. 3 communication bars.

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;

use fastclip::comm::{Collective, CommWorld, CostModel, ProfileName};
use harness::{black_box, Bench};

fn bench_collective(k: usize, n: usize, op: &str) {
    let world = CommWorld::new(k);
    let name = format!("{op} k={k} n={n}");
    // run the collective k-threaded; rank 0's thread does the timing
    let stats = Bench::new(name).samples(20).warmup(2).run(|| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let h = world.handle(rank);
                std::thread::spawn(move || match rank % 2 {
                    _ => {
                        let mut buf = vec![rank as f32; n];
                        h.all_reduce_sum(&mut buf);
                        black_box(buf[0]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let _ = stats;
    let _ = Arc::strong_count(&world);
}

fn bench_all_gather(k: usize, n: usize) {
    let world = CommWorld::new(k);
    Bench::new(format!("all_gather k={k} n={n}")).samples(20).warmup(2).run(|| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let h = world.handle(rank);
                std::thread::spawn(move || {
                    let buf = vec![rank as f32; n];
                    black_box(h.all_gather(&buf));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn main() {
    println!("== real in-process collectives (threads, 1 host) ==");
    for k in [2usize, 4] {
        for n in [1 << 10, 1 << 16, 1 << 20] {
            bench_collective(k, n, "all_reduce_sum");
        }
    }
    for k in [2usize, 4] {
        bench_all_gather(k, 1 << 14);
    }

    println!("\n== alpha-beta cost model (paper-scale volumes, analytic) ==");
    for profile in [ProfileName::InfiniBand, ProfileName::Slingshot1, ProfileName::Slingshot2] {
        for nodes in [2usize, 8] {
            let m = CostModel::new(profile.profile(), nodes, 4);
            let k = m.world_size();
            let (bl, d, p) = (128usize, 512usize, 151_000_000usize);
            println!(
                "{:<12} {}n: featAG {:>8.3}ms  uAG {:>8.4}ms  RS {:>8.3}ms  gradAR {:>9.3}ms",
                profile.id(),
                nodes,
                m.time(Collective::AllGather, 2 * bl * d * 4) * 1e3,
                m.time(Collective::AllGather, 2 * bl * 4) * 1e3,
                m.time(Collective::ReduceScatter, 2 * k * bl * d * 4) * 1e3,
                m.time(Collective::AllReduce, p * 4) * 1e3,
            );
        }
    }
}
