//! Raw tensor blobs: little-endian `f32` / `bf16` / `u64` / `resid`
//! files with FNV-1a 64 integrity hashes (DESIGN.md §9). A blob file is
//! exactly its elements' LE bytes — no header; the checkpoint manifest
//! records each blob's dtype tag, element count and hash, so a single
//! flipped byte anywhere is detected on read and by `fastclip ckpt
//! verify`.
//!
//! The `bf16` kind (DESIGN.md §12) tags half-width bfloat16 payloads —
//! exports and derived artifacts. Training state itself is deliberately
//! never written bf16: the snapshot carries the f32 *master* weights and
//! estimators even for `--precision bf16` runs, which is what keeps
//! resume bitwise and elastic re-sharding precision-agnostic.
//!
//! The `resid` kind (DESIGN.md §15) tags per-rank error-feedback
//! residuals banked by the `topk` wire codec. The payload is f32 LE —
//! the distinct tag keeps residuals from being confused with model or
//! estimator state by tools that scan the blob table, and lets resume
//! detect their presence cheaply.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

/// Element type of a blob. Continuous training state is `f32` (always —
/// masters are snapshotted, see the module docs), counters / cursors /
/// RNG words are `u64`, and `bf16` tags half-width exports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlobKind {
    /// 4-byte little-endian IEEE-754 single floats.
    F32,
    /// 2-byte little-endian bfloat16 (raw `u16` words, DESIGN.md §12).
    Bf16,
    /// 8-byte little-endian unsigned integers.
    U64,
    /// 4-byte little-endian f32 error-feedback residuals of the `topk`
    /// wire codec (DESIGN.md §15) — same encoding as [`BlobKind::F32`],
    /// distinct tag.
    Resid,
}

impl BlobKind {
    /// File-extension id: `f32` | `bf16` | `u64` | `resid`.
    pub fn id(&self) -> &'static str {
        match self {
            BlobKind::F32 => "f32",
            BlobKind::Bf16 => "bf16",
            BlobKind::U64 => "u64",
            BlobKind::Resid => "resid",
        }
    }

    /// Parse an id; unknown values are an error.
    pub fn from_id(id: &str) -> Result<BlobKind> {
        match id {
            "f32" => Ok(BlobKind::F32),
            "bf16" => Ok(BlobKind::Bf16),
            "u64" => Ok(BlobKind::U64),
            "resid" => Ok(BlobKind::Resid),
            _ => bail!("unknown blob kind '{id}' (expected f32|bf16|u64|resid)"),
        }
    }

    /// Bytes per element.
    pub fn width(&self) -> usize {
        match self {
            BlobKind::F32 | BlobKind::Resid => 4,
            BlobKind::Bf16 => 2,
            BlobKind::U64 => 8,
        }
    }

    /// Kind from a blob file's extension
    /// (`.f32` / `.bf16` / `.u64` / `.resid`).
    pub fn from_path(path: &Path) -> Result<BlobKind> {
        match path.extension().and_then(|e| e.to_str()) {
            Some("f32") => Ok(BlobKind::F32),
            Some("bf16") => Ok(BlobKind::Bf16),
            Some("u64") => Ok(BlobKind::U64),
            Some("resid") => Ok(BlobKind::Resid),
            _ => bail!("{} is not a blob file (.f32/.bf16/.u64/.resid)", path.display()),
        }
    }
}

/// One blob's manifest entry: file name (relative to the checkpoint
/// directory), element kind and count, and the FNV-1a 64 hash of the
/// file's bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct BlobSpec {
    /// file name, relative to the checkpoint directory
    pub file: String,
    /// element type (also the file extension)
    pub kind: BlobKind,
    /// element count
    pub len: usize,
    /// FNV-1a 64 hash of the file's raw bytes
    pub hash: u64,
}

/// FNV-1a 64-bit over raw bytes — tiny, dependency-free, and entirely
/// adequate for corruption detection (it is not a cryptographic hash).
///
/// ```
/// use fastclip::ckpt::fnv1a64;
/// // the FNV-1a offset basis: hashing nothing returns it unchanged
/// assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
/// // one flipped bit changes the hash
/// assert_ne!(fnv1a64(&[0x00]), fnv1a64(&[0x01]));
/// ```
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Serialize f32 elements to their little-endian bytes.
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize little-endian bytes back to f32 elements (bitwise exact,
/// including NaN payloads and -0.0).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    ensure!(bytes.len() % 4 == 0, "f32 blob is {} bytes (not a multiple of 4)", bytes.len());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Serialize raw bf16 words to their little-endian bytes.
pub fn bf16s_to_bytes(xs: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize little-endian bytes back to raw bf16 words (bitwise
/// exact, including NaN payloads).
pub fn bytes_to_bf16s(bytes: &[u8]) -> Result<Vec<u16>> {
    ensure!(bytes.len() % 2 == 0, "bf16 blob is {} bytes (not a multiple of 2)", bytes.len());
    Ok(bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
}

/// Serialize u64 elements to their little-endian bytes.
pub fn u64s_to_bytes(xs: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 8);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Deserialize little-endian bytes back to u64 elements.
pub fn bytes_to_u64s(bytes: &[u8]) -> Result<Vec<u64>> {
    ensure!(bytes.len() % 8 == 0, "u64 blob is {} bytes (not a multiple of 8)", bytes.len());
    Ok(bytes
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect())
}

/// Write `<dir>/<name>.f32`.
pub fn write_f32_blob(dir: &Path, name: &str, xs: &[f32]) -> Result<()> {
    let path = dir.join(format!("{name}.f32"));
    std::fs::write(&path, f32s_to_bytes(xs))
        .with_context(|| format!("writing blob {}", path.display()))
}

/// Write `<dir>/<name>.bf16`.
pub fn write_bf16_blob(dir: &Path, name: &str, xs: &[u16]) -> Result<()> {
    let path = dir.join(format!("{name}.bf16"));
    std::fs::write(&path, bf16s_to_bytes(xs))
        .with_context(|| format!("writing blob {}", path.display()))
}

/// Write `<dir>/<name>.u64`.
pub fn write_u64_blob(dir: &Path, name: &str, xs: &[u64]) -> Result<()> {
    let path = dir.join(format!("{name}.u64"));
    std::fs::write(&path, u64s_to_bytes(xs))
        .with_context(|| format!("writing blob {}", path.display()))
}

/// Write `<dir>/<name>.resid` — f32 LE payload, residual tag.
pub fn write_resid_blob(dir: &Path, name: &str, xs: &[f32]) -> Result<()> {
    let path = dir.join(format!("{name}.resid"));
    std::fs::write(&path, f32s_to_bytes(xs))
        .with_context(|| format!("writing blob {}", path.display()))
}

/// Read a blob's bytes and verify length + integrity hash against its
/// manifest entry. Every checkpoint read goes through this, so corruption
/// surfaces at resume time, not as silently wrong training state.
pub fn read_verified(dir: &Path, spec: &BlobSpec) -> Result<Vec<u8>> {
    let path = dir.join(&spec.file);
    let bytes =
        std::fs::read(&path).with_context(|| format!("reading blob {}", path.display()))?;
    ensure!(
        bytes.len() == spec.len * spec.kind.width(),
        "{} is {} bytes, manifest says {} x {} = {}",
        path.display(),
        bytes.len(),
        spec.len,
        spec.kind.width(),
        spec.len * spec.kind.width()
    );
    let h = fnv1a64(&bytes);
    ensure!(
        h == spec.hash,
        "integrity check failed for {}: hash {h:016x} != manifest {:016x}",
        path.display(),
        spec.hash
    );
    Ok(bytes)
}

/// [`read_verified`] + f32 decode (errors on a non-f32 spec).
pub fn read_f32_verified(dir: &Path, spec: &BlobSpec) -> Result<Vec<f32>> {
    ensure!(spec.kind == BlobKind::F32, "{} is not an f32 blob", spec.file);
    bytes_to_f32s(&read_verified(dir, spec)?)
}

/// [`read_verified`] + bf16 decode (errors on a non-bf16 spec).
pub fn read_bf16_verified(dir: &Path, spec: &BlobSpec) -> Result<Vec<u16>> {
    ensure!(spec.kind == BlobKind::Bf16, "{} is not a bf16 blob", spec.file);
    bytes_to_bf16s(&read_verified(dir, spec)?)
}

/// [`read_verified`] + u64 decode (errors on a non-u64 spec).
pub fn read_u64_verified(dir: &Path, spec: &BlobSpec) -> Result<Vec<u64>> {
    ensure!(spec.kind == BlobKind::U64, "{} is not a u64 blob", spec.file);
    bytes_to_u64s(&read_verified(dir, spec)?)
}

/// [`read_verified`] + f32 decode of a residual blob (errors on a
/// non-resid spec). Bitwise exact — error-feedback resume depends on it.
pub fn read_resid_verified(dir: &Path, spec: &BlobSpec) -> Result<Vec<f32>> {
    ensure!(spec.kind == BlobKind::Resid, "{} is not a resid blob", spec.file);
    bytes_to_f32s(&read_verified(dir, spec)?)
}

/// Hash every blob file in `dir` (anything with a
/// `.f32`/`.bf16`/`.u64`/`.resid` extension) into a sorted blob table —
/// the finalize step of a snapshot.
pub fn scan_dir(dir: &Path) -> Result<Vec<BlobSpec>> {
    let mut specs = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("scanning {}", dir.display()))?
    {
        let path = entry?.path();
        let Ok(kind) = BlobKind::from_path(&path) else {
            continue; // MANIFEST.json and anything else non-blob
        };
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        ensure!(
            bytes.len() % kind.width() == 0,
            "{} is {} bytes, not a multiple of {}",
            path.display(),
            bytes.len(),
            kind.width()
        );
        let file = path
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| anyhow::anyhow!("non-UTF8 blob name in {}", dir.display()))?
            .to_string();
        specs.push(BlobSpec { file, kind, len: bytes.len() / kind.width(), hash: fnv1a64(&bytes) });
    }
    specs.sort_by(|a, b| a.file.cmp(&b.file));
    Ok(specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_vectors() {
        // standard FNV-1a test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
        // sensitive to a single flipped bit
        assert_ne!(fnv1a64(&[0x00, 0x01]), fnv1a64(&[0x00, 0x00]));
    }

    #[test]
    fn f32_and_u64_bytes_roundtrip() {
        let xs = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -3.25e-40, 1e38];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap(), xs);
        let us = vec![0u64, 1, u64::MAX, 0xdead_beef_0123_4567];
        assert_eq!(bytes_to_u64s(&u64s_to_bytes(&us)).unwrap(), us);
        assert!(bytes_to_f32s(&[0u8; 5]).is_err());
        assert!(bytes_to_u64s(&[0u8; 12]).is_err());
    }

    #[test]
    fn bf16_bytes_roundtrip_and_kind_tags() {
        let ws = vec![0x0000u16, 0x8000, 0x3F80, 0x7F80, 0xFF80, 0x7FC1, 0x0001];
        assert_eq!(bytes_to_bf16s(&bf16s_to_bytes(&ws)).unwrap(), ws);
        assert!(bytes_to_bf16s(&[0u8; 3]).is_err());
        assert_eq!(BlobKind::from_id("bf16").unwrap(), BlobKind::Bf16);
        assert_eq!(BlobKind::Bf16.width(), 2);
        assert_eq!(BlobKind::from_path(Path::new("x/params.bf16")).unwrap(), BlobKind::Bf16);
        assert_eq!(BlobKind::from_id("resid").unwrap(), BlobKind::Resid);
        assert_eq!(BlobKind::Resid.width(), 4);
        assert_eq!(BlobKind::from_path(Path::new("x/ef_rank0.resid")).unwrap(), BlobKind::Resid);
        assert!(BlobKind::from_id("f16").is_err());
    }

    #[test]
    fn write_scan_read_verify_cycle() {
        let dir = std::env::temp_dir().join("fastclip_blob_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        write_f32_blob(&dir, "a", &[1.0, 2.0, -0.5]).unwrap();
        write_u64_blob(&dir, "b", &[7, 8]).unwrap();
        write_bf16_blob(&dir, "c", &[0x3F80, 0xC000]).unwrap();
        write_resid_blob(&dir, "d", &[-0.0, 3.5e-12, 9.0]).unwrap();
        std::fs::write(dir.join("MANIFEST.json"), "{}").unwrap();
        let specs = scan_dir(&dir).unwrap();
        assert_eq!(specs.len(), 4, "manifest not scanned as a blob");
        assert_eq!(specs[0].file, "a.f32");
        assert_eq!(specs[0].len, 3);
        assert_eq!(specs[1].file, "b.u64");
        assert_eq!(specs[2].file, "c.bf16");
        assert_eq!(specs[2].kind, BlobKind::Bf16);
        assert_eq!(specs[2].len, 2);
        assert_eq!(specs[3].file, "d.resid");
        assert_eq!(specs[3].kind, BlobKind::Resid);
        assert_eq!(specs[3].len, 3);
        assert_eq!(read_f32_verified(&dir, &specs[0]).unwrap(), vec![1.0, 2.0, -0.5]);
        assert_eq!(read_u64_verified(&dir, &specs[1]).unwrap(), vec![7, 8]);
        assert_eq!(read_bf16_verified(&dir, &specs[2]).unwrap(), vec![0x3F80, 0xC000]);
        let resid = read_resid_verified(&dir, &specs[3]).unwrap();
        assert_eq!(resid.iter().map(|v| v.to_bits()).collect::<Vec<_>>(), vec![
            (-0.0f32).to_bits(),
            3.5e-12f32.to_bits(),
            9.0f32.to_bits()
        ]);
        assert!(read_bf16_verified(&dir, &specs[0]).is_err(), "kind mismatch rejected");
        assert!(read_resid_verified(&dir, &specs[0]).is_err(), "f32 blob is not a resid blob");

        // flip one byte: the read must fail the integrity check
        let path = dir.join("a.f32");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[5] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = read_f32_verified(&dir, &specs[0]).unwrap_err();
        assert!(format!("{err}").contains("integrity"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
