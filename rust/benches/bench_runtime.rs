//! PJRT runtime benchmarks: executable invocation cost for each artifact
//! (encode / phase_g / step) plus the literal I/O overhead — the L3↔XLA
//! boundary (DESIGN.md §8) whose marshalling cost the runtime keeps to
//! one copy per literal.

#[path = "harness.rs"]
mod harness;

use fastclip::runtime::{Manifest, TauInput, WorkerRuntime};
use fastclip::util::Rng;
use harness::{black_box, Bench};

fn main() -> anyhow::Result<()> {
    // cargo bench appends a `--bench` flag; only positional args count
    let bundle = std::env::args()
        .skip(1)
        .find(|a| !a.starts_with('-'))
        .unwrap_or_else(|| "artifacts/tiny_k2_b8".into());
    if !std::path::Path::new(&bundle).join("manifest.json").exists() {
        eprintln!("bundle {bundle} not built — run `make artifacts`");
        return Ok(());
    }
    let m = Manifest::load(&bundle)?;
    println!(
        "bundle {bundle}: P={} bl={} bg={} d={}",
        m.n_params, m.local_batch, m.global_batch, m.model.d_embed
    );
    let mut rt = WorkerRuntime::load(&m, None)?;
    let params = m.load_init_params()?;
    let mut rng = Rng::new(1);
    let mut images = vec![0.0f32; m.local_batch * m.model.v_patches * m.model.v_patch_dim];
    rng.fill_normal(&mut images, 1.0);
    let texts: Vec<i32> =
        (0..m.local_batch * m.model.t_len).map(|_| rng.below(m.model.t_vocab) as i32).collect();

    // encode
    let (e1, e2) = rt.encode(&params, &images, &texts)?;
    Bench::new("encode (local batch)").samples(20).run(|| {
        black_box(rt.encode(&params, &images, &texts).unwrap());
    });

    // phase_g
    let reps = m.global_batch / m.local_batch;
    let e1g: Vec<f32> = std::iter::repeat(e1.clone()).take(reps).flatten().collect();
    let e2g: Vec<f32> = std::iter::repeat(e2.clone()).take(reps).flatten().collect();
    let u = vec![0.5f32; m.local_batch];
    let tau = vec![0.05f32; m.local_batch];
    Bench::new("phase_g (Eq. 1 u-update)").samples(20).run(|| {
        black_box(rt.phase_g(&e1g, &e2g, 0, &u, &u, &tau, &tau, 0.5).unwrap());
    });

    // each step variant
    let ug = vec![0.5f32; m.global_batch];
    let taug = vec![0.05f32; m.global_batch];
    for variant in m.variants.clone() {
        let tau_in = if variant == "rgcl_i" {
            TauInput::Individual { tau1g: &taug, tau2g: &taug }
        } else {
            TauInput::Global(0.05)
        };
        Bench::new(format!("step_{variant} (fwd+bwd+estimators)")).samples(10).run(|| {
            black_box(
                rt.step(
                    &variant, &params, &images, &texts, &e1g, &e2g, &ug, &ug, 0, 1e-14, 6.5,
                    tau_in.clone(),
                )
                .unwrap(),
            );
        });
    }
    Ok(())
}
