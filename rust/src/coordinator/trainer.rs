//! The distributed trainer: K worker threads executing the FastCLIP
//! iteration of DESIGN.md §4 in lockstep over in-process collectives.
//!
//! Per iteration, each worker k:
//!   1. loads its local batch and runs `encode`                  (compute)
//!   2. ALL_GATHERs the embeddings — O(K·B·d)                    (comm)
//!   3. runs `phase_g` (Eq. 1 u-update) and writes u back        (compute)
//!   4. ALL_GATHERs the updated u scalars — O(K·B)               (comm)
//!      [OpenCLIP instead pays a REDUCE_SCATTER of feature-sized
//!       gradient terms here; charged to the cost model]
//!   5. runs `step_<variant>` → gradient contribution            (compute)
//!   6. reduces gradient + loss + τ-gradient — O(P)              (comm)
//!   7. applies the optimizer, temperature rule and schedules    (others)
//!
//! Steps 6–7 for the parameter gradient go through the pluggable
//! [`GradientReduction`](crate::comm::GradientReduction) algorithms
//! (DESIGN.md §4 "Gradient reduction"): replicated strategies (naive /
//! ring) materialize the reduced gradient everywhere and every worker
//! applies the identical full-length optimizer update; the paper's
//! sharded strategy reduce-scatters the gradient, each rank applies its
//! 1/K optimizer shard, and the updated parameters are all-gathered. All
//! strategies leave parameters bitwise replicated; `cfg.reduce` selects
//! one (or `auto` asks the α–β cost model).
//!
//! With `--overlap on|auto` (DESIGN.md §11) steps 5–6 pipeline: the
//! backward emits the gradient leaf by leaf
//! ([`step_emit`](crate::runtime::ComputeBackend::step_emit)), completed
//! [`BucketPlan`](crate::comm::BucketPlan) buckets reduce on a background
//! worker over a dedicated sibling collective world, and the optimizer
//! still applies exactly once per iteration — bitwise identical to the
//! serial path for every variant × reduction algorithm, with the
//! measured hidden/exposed reduction split charged to [`CommStats`] and
//! the timing breakdown.
//!
//! Numerics are exact (bytes really move between threads); communication
//! *time* is charged by the α–β cost model over the configured topology
//! (`timing.rs`).
//!
//! # Fault tolerance (DESIGN.md §13)
//!
//! Every worker thread runs an **incarnation loop**: the lockstep
//! iteration body above, restarted when the world shrinks. A rank lost
//! mid-run (injected with `--fail rank=R@iter=N`, or any future real
//! detector) cancels both collective worlds; every survivor's blocking
//! collective returns `Err(RanksLost)`, the survivors meet at a
//! [`ShrinkCell`] rendezvous, roll back to the latest finalized
//! snapshot, rebuild both worlds at K′ = K − lost, re-shard u/τ/optimizer
//! state through the elastic restore path (DESIGN.md §9) and continue.
//! Because the incarnation body keys everything off the *current* world
//! size and the restore path is exactly the one `--resume` uses, the
//! post-shrink trajectory is bitwise-equal to a cold elastic resume at
//! K′ from the same snapshot — pinned by `tests/fault_injection.rs`.

use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::ckpt::{self, CkptMeta, CkptRunStats};
use crate::comm::{
    reduction, BucketPlan, CancellationToken, CommError, CommStats, CommWorld, CostModel, EfState,
    FailSpec, FaultPlan, GradientReduction, OverlapPipeline, ReduceAlgo, ReduceCtx, ReduceStrategy,
    TraceEventKind, WireCodec, WorkerComm,
};
use crate::config::{OptimizerKind, TrainConfig};
use crate::data::{Dataset, ShardLoader};
use crate::eval::{evaluate, EvalSummary};
use crate::kernels::Precision;
use crate::runtime::{ComputeBackend, FeatGradReduce, LossShard, Manifest, TauGrads, TauInput};
use crate::telemetry::{sink as tsink, Logger, MetricsRegistry, SpanRecorder, TraceSink};
use crate::util::Json;

use super::state::UState;
use super::temperature::TauState;
use super::timing::{
    charge_iteration_overlapped, charge_iteration_with, IterationVolumes, TimeBreakdown,
};

/// One logged training iteration (rank-0 view; loss is the global mean).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterRecord {
    pub step: u32,
    pub epoch: u32,
    pub loss: f32,
    pub gamma: f32,
    pub lr: f32,
    pub tau: f32,
}

/// A periodic evaluation snapshot.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: u32,
    pub summary: EvalSummary,
}

/// Everything a finished run reports.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub algorithm: &'static str,
    pub history: Vec<IterRecord>,
    pub evals: Vec<EvalRecord>,
    pub final_eval: EvalSummary,
    /// rank-0 timing (workers are symmetric)
    pub timing: TimeBreakdown,
    /// the gradient-reduction algorithm the run resolved (`cfg.reduce`)
    pub reduce_algorithm: &'static str,
    /// the storage/wire precision the run computed at (`cfg.precision`,
    /// DESIGN.md §12): "f32" or "bf16"
    pub precision: &'static str,
    /// the gradient wire codec the run reduced with (`cfg.wire`,
    /// DESIGN.md §15): "f32", "bf16", "int8" or "topk"
    pub wire: &'static str,
    /// whether the bucketed overlap pipeline ran (`cfg.overlap` resolved
    /// against the world size and bucket count, DESIGN.md §11)
    pub overlap: bool,
    /// whether the run computed the memory-sharded contrastive loss
    /// (`cfg.loss_shard` resolved against the backend, DESIGN.md §16)
    pub loss_shard: bool,
    /// analytic peak bytes of the loss-stage working set under the
    /// resolved shard mode — also the `loss.peak_bytes` trace gauge
    pub loss_peak_bytes: u64,
    /// buckets per iteration under `cfg.bucket_bytes` (1 when serial)
    pub n_buckets: usize,
    /// measured reduction time hidden behind backward compute (µs, one
    /// rank; 0 for serial runs)
    pub hidden_comm_us: u64,
    /// measured reduction time still exposed on the critical path under
    /// overlap (µs, one rank; 0 for serial runs)
    pub exposed_comm_us: u64,
    /// real bytes moved through the in-process collectives, all ranks
    pub comm_bytes: u64,
    /// feature-gradient bytes-on-wire per rank for the sharded loss's
    /// column exchange — 0 under `--loss-shard off` or K=1 (DESIGN.md §16)
    pub featgrad_wire_bytes: u64,
    /// modeled gradient bytes-on-wire per rank over the whole run, under
    /// the chosen reduction algorithm…
    pub grad_wire_bytes: u64,
    /// …and what naive all-reduce would have moved (before/after pair)
    pub grad_wire_bytes_naive: u64,
    /// modeled communication volume per iteration (bytes, one worker)
    pub modeled_iter_bytes: usize,
    pub final_tau: f32,
    pub final_params: Vec<f32>,
    pub wall_s: f64,
    /// checkpoint activity: snapshots written, write/restore wall time,
    /// and the step resumed from (DESIGN.md §9)
    pub ckpt: CkptRunStats,
    /// ranks in the world when the run finished — smaller than the
    /// configured K after a live shrink (DESIGN.md §13)
    pub final_world: usize,
    /// live shrinks survived (0 for a clean run)
    pub shrinks: u32,
    /// the ranks declared lost, by their rank at the time of loss
    pub lost_ranks: Vec<usize>,
}

impl TrainResult {
    pub fn final_loss(&self) -> f32 {
        self.history.last().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last `n` iterations (smoother than final_loss).
    pub fn tail_loss(&self, n: usize) -> f32 {
        let tail: Vec<f32> =
            self.history.iter().rev().take(n).map(|r| r.loss).collect();
        crate::util::mean(&tail)
    }
}

/// The distributed trainer. Construct with a validated [`TrainConfig`];
/// [`Trainer::run`] blocks until the run completes and returns the result.
pub struct Trainer {
    cfg: TrainConfig,
    manifest: Manifest,
    fault: FaultPlan,
}

impl Trainer {
    pub fn new(mut cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate()?;
        // fail before touching the artifact bundle: the pjrt step graphs
        // lower the unsharded loss only (DESIGN.md §16). `auto` never
        // trips this — it resolves to off away from native.
        ensure!(
            cfg.loss_shard != crate::runtime::LossShardMode::On
                || cfg.resolved_backend() == crate::runtime::BackendKind::Native,
            "--loss-shard on requires the native backend (the AOT-lowered HLO step artifacts \
             compute the unsharded loss); pass --backend native or --loss-shard off"
        );
        // resolve `--resume latest` to a concrete checkpoint directory
        // here, once, so every worker opens the same snapshot even if a
        // new one lands mid-startup
        if cfg.resume.as_deref() == Some("latest") {
            let root = cfg
                .ckpt_dir
                .as_deref()
                .ok_or_else(|| anyhow::anyhow!("--resume latest requires --ckpt-dir"))?;
            let dir = ckpt::latest(Path::new(root))?
                .ok_or_else(|| anyhow::anyhow!("no checkpoints under {root} to resume from"))?;
            cfg.resume = Some(dir.to_string_lossy().into_owned());
        }
        // native: synthesized from preset/n_workers/local_batch;
        // pjrt: loaded from the artifact bundle (DESIGN.md §10)
        let manifest = cfg.load_manifest()?;
        let variant = cfg.algorithm.variant();
        ensure!(
            manifest.variants.iter().any(|v| v == variant),
            "bundle {} lacks step_{variant}; rebuild with `make artifacts`",
            cfg.artifact_dir
        );
        ensure!(
            cfg.data.n_train / manifest.k_workers >= manifest.local_batch,
            "dataset too small: {} samples over {} workers < local batch {}",
            cfg.data.n_train,
            manifest.k_workers,
            manifest.local_batch
        );
        // fail before spawning workers: the PJRT graphs are f32-only
        ensure!(
            cfg.precision == Precision::F32
                || cfg.resolved_backend() == crate::runtime::BackendKind::Native,
            "--precision bf16 requires the native backend (the AOT-lowered HLO artifacts \
             compute in f32); pass --backend native"
        );
        // fault injection (DESIGN.md §13): grammar was validated with the
        // config; rank bounds need the world size, and an injected death
        // needs a snapshot boundary to roll back to
        let fault = FaultPlan::parse(cfg.fail.as_deref(), cfg.straggle.as_deref(), cfg.watchdog_ms)
            .context("parsing the fault-injection flags")?;
        fault.check_ranks(manifest.k_workers)?;
        if let Some(f) = &fault.fail {
            ensure!(
                f.iter < cfg.steps,
                "--fail iter={} is past the run ({} steps): nothing would be injected",
                f.iter,
                cfg.steps
            );
            ensure!(
                cfg.ckpt_every > 0 && cfg.ckpt_dir.is_some(),
                "--fail needs a rollback snapshot: set --ckpt-dir and --ckpt-every"
            );
            ensure!(
                f.iter >= cfg.ckpt_every,
                "--fail at iter {} precedes the first snapshot boundary (--ckpt-every {}): \
                 the survivors would have nothing to roll back to",
                f.iter,
                cfg.ckpt_every
            );
        }
        Ok(Trainer { cfg, manifest, fault })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn run(&self) -> Result<TrainResult> {
        let t0 = Instant::now();
        let k = self.manifest.k_workers;
        // telemetry (DESIGN.md §14): one shared JSONL sink, one shared
        // span epoch (keeps per-rank start_us monotone across shrink
        // incarnations), one progress logger. The `meta` line is written
        // here, before any worker spawns, so it is always line 1.
        let log = Logger::from_format(self.cfg.quiet, &self.cfg.log_format)?;
        let sink = match &self.cfg.trace_out {
            Some(p) => Some(Arc::new(TraceSink::create(p)?)),
            None => None,
        };
        if let Some(s) = &sink {
            s.emit(&tsink::event(
                "meta",
                vec![
                    ("algo", Json::str(self.cfg.algorithm.id())),
                    ("world", Json::num(k as f64)),
                    ("steps", Json::num(self.cfg.steps)),
                    ("precision", Json::str(self.cfg.precision.id())),
                    ("wire", Json::str(self.cfg.wire_codec().id())),
                    ("reduce", Json::str(self.cfg.reduce.id())),
                    ("overlap", Json::str(self.cfg.overlap.id())),
                    // resolved, not the raw mode: the trail records what
                    // the run actually computed (DESIGN.md §16)
                    (
                        "loss_shard",
                        Json::str(
                            if self.cfg.loss_shard.resolve(self.cfg.resolved_backend()) {
                                "on"
                            } else {
                                "off"
                            },
                        ),
                    ),
                    ("preset", Json::str(self.cfg.preset.as_str())),
                    ("seed", Json::num(self.cfg.seed as f64)),
                ],
            ));
        }
        let span_epoch = Instant::now();
        // two sibling collective worlds over shared counters: the
        // training world for the lockstep iteration, and a dedicated
        // world for the overlap pipeline's bucket reductions so the
        // background workers never interleave with training collectives
        // (DESIGN.md §11; unused in serial mode). Both share ONE
        // cancellation token, so a loss detected on either — a training
        // collective or an in-flight bucket — cancels both (DESIGN.md §13)
        let stats = Arc::new(CommStats::default());
        let token = Arc::new(CancellationToken::new());
        let watchdog = self.fault.watchdog();
        let straggle = self.fault.straggle_for(k);
        let world = CommWorld::with_faults(
            k,
            Arc::clone(&stats),
            Arc::clone(&token),
            watchdog,
            straggle.clone(),
        );
        let reduce_world = CommWorld::with_faults(k, Arc::clone(&stats), token, watchdog, straggle);
        let cfg = Arc::new(self.cfg.clone());
        let dataset = Arc::new(Dataset::new(cfg.data, self.manifest.model_dims()));
        let shrink = Arc::new(ShrinkCell::new());

        let mut joins = Vec::with_capacity(k);
        for rank in 0..k {
            let train_world = Arc::clone(&world);
            let reduce_world = Arc::clone(&reduce_world);
            let cfg = Arc::clone(&cfg);
            let dataset = Arc::clone(&dataset);
            let manifest = self.manifest.clone();
            let fault = self.fault.clone();
            let shrink = Arc::clone(&shrink);
            let stats = Arc::clone(&stats);
            let sink = sink.clone();
            joins.push(
                std::thread::Builder::new()
                    .name(format!("worker-{rank}"))
                    .spawn(move || {
                        worker_thread(
                            rank,
                            train_world,
                            reduce_world,
                            cfg,
                            dataset,
                            manifest,
                            fault,
                            shrink,
                            stats,
                            sink,
                            log,
                            span_epoch,
                        )
                    })
                    .context("spawning worker thread")?,
            );
        }

        // the lead output is whichever worker finished as rank 0 of the
        // FINAL incarnation — after a shrink that may be a different
        // thread than original rank 0 (which may be the one that died,
        // returning None)
        let mut lead: Option<WorkerOutput> = None;
        for (rank, j) in joins.into_iter().enumerate() {
            let out = j
                .join()
                .map_err(|_| anyhow!("worker {rank} panicked"))?
                .with_context(|| format!("worker {rank} failed"))?;
            if let Some(out) = out {
                if out.rank == 0 {
                    ensure!(lead.is_none(), "two workers finished as rank 0");
                    lead = Some(out);
                }
            }
        }
        let out = lead.ok_or_else(|| anyhow!("no worker finished as rank 0"))?;
        let k_final = out.world;
        let stats = world.stats.snapshot();

        // telemetry epilogue (workers already joined): drain the
        // comm-layer fault events into `"event"` lines, then write one
        // exact `"metrics"` line — the registry absorbs the same
        // CommStats/TimeBreakdown totals TrainResult reports, so `trace
        // summary` reproduces the in-process breakdown exactly.
        if let Some(s) = &sink {
            let events = world.stats.take_events();
            let reg = MetricsRegistry::new();
            reg.absorb_comm(&stats);
            reg.absorb_timing(&out.timing);
            reg.gauge_set("overlap.max_queue_depth", out.max_queue_depth as f64);
            reg.gauge_set("loss.peak_bytes", out.loss_peak_bytes as f64);
            reg.counter_add("events.dropped", world.stats.events_dropped());
            for e in &events {
                reg.counter_add(&format!("events.{}", e.kind.id()), 1);
                s.emit(&tsink::fault_event(e));
            }
            let mut ev = tsink::event("metrics", vec![]);
            if let Json::Obj(map) = reg.to_json() {
                for (key, val) in map {
                    ev.set(&key, val);
                }
            }
            s.emit(&ev);
            s.flush();
        }

        Ok(TrainResult {
            algorithm: self.cfg.algorithm.name(),
            history: out.history,
            evals: out.evals,
            final_eval: out
                .final_eval
                .ok_or_else(|| anyhow::anyhow!("lead worker produced no final evaluation"))?,
            timing: out.timing,
            reduce_algorithm: out.reduce_id,
            precision: self.cfg.precision.id(),
            wire: self.cfg.wire_codec().id(),
            overlap: out.overlap,
            loss_shard: out.loss_shard,
            loss_peak_bytes: out.loss_peak_bytes,
            n_buckets: out.n_buckets,
            comm_bytes: stats.payload_bytes(),
            // per-rank counters are charged by every rank; report one
            // rank's share (after a shrink the divisor is the final world,
            // so shrink runs over-attribute slightly — the counters mixed
            // K- and K′-rank incarnations)
            featgrad_wire_bytes: stats.featgrad_wire_bytes / k_final as u64,
            grad_wire_bytes: stats.grad_wire_bytes / k_final as u64,
            grad_wire_bytes_naive: stats.grad_wire_bytes_naive / k_final as u64,
            hidden_comm_us: stats.hidden_comm_us / k_final as u64,
            exposed_comm_us: stats.exposed_comm_us / k_final as u64,
            modeled_iter_bytes: out.modeled_iter_bytes,
            final_tau: out.final_tau,
            final_params: out.params,
            wall_s: t0.elapsed().as_secs_f64(),
            ckpt: out.ckpt,
            final_world: k_final,
            shrinks: out.shrinks,
            lost_ranks: out.lost,
        })
    }
}

struct WorkerOutput {
    /// this worker's rank in the FINAL incarnation
    rank: usize,
    /// world size of the final incarnation (= K for clean runs)
    world: usize,
    shrinks: u32,
    lost: Vec<usize>,
    history: Vec<IterRecord>,
    evals: Vec<EvalRecord>,
    final_eval: Option<EvalSummary>,
    timing: TimeBreakdown,
    modeled_iter_bytes: usize,
    reduce_id: &'static str,
    overlap: bool,
    /// whether the sharded loss ran (`cfg.loss_shard` resolved)
    loss_shard: bool,
    /// `ComputeBackend::loss_peak_bytes` under the resolved mode
    loss_peak_bytes: u64,
    n_buckets: usize,
    /// high-water mark of the overlap pipeline's bucket queue (0 when
    /// serial) — reported as the `overlap.max_queue_depth` gauge
    max_queue_depth: usize,
    final_tau: f32,
    params: Vec<f32>,
    ckpt: CkptRunStats,
}

/// Adapts the run's gradient-reduction algorithm plus the training-world
/// comm handle into the [`FeatGradReduce`] exchange the sharded loss
/// calls mid-step (DESIGN.md §16). The leg's codec is pinned to f32:
/// the exchange is loss-internal state, not a parameter gradient, so
/// `--wire` compression never perturbs the loss numerics and
/// `--loss-shard on ≡ off` stays bitwise under every codec.
struct FeatGradOverComm<'a> {
    comm: &'a WorkerComm,
    reducer: &'a dyn GradientReduction,
}

impl FeatGradReduce for FeatGradOverComm<'_> {
    fn exchange(
        &mut self,
        seg_len: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
    ) -> Result<Vec<f32>> {
        Ok(self.reducer.reduce_feature_grads(self.comm, seg_len, fill, &ReduceCtx::f32())?)
    }
}

/// State a worker accumulates ACROSS incarnations: the training history
/// and evals (truncated to the rollback step on each shrink, so the
/// final record covers every step exactly once) and the timing and
/// checkpoint counters (never truncated — rolled-back work was really
/// performed, and the accounting stays honest about it).
#[derive(Default)]
struct Accum {
    history: Vec<IterRecord>,
    evals: Vec<EvalRecord>,
    timing: TimeBreakdown,
    ckpt: CkptRunStats,
}

/// The new-world plan one survivor builds at the shrink rendezvous and
/// every survivor adopts: two fresh collective worlds at K′ (sharing the
/// run's counters, carrying a fresh shared token), the snapshot every
/// survivor rolls back to, and the survivor → new-rank mapping.
struct ShrinkPlan {
    train: Arc<CommWorld>,
    reduce: Arc<CommWorld>,
    /// the rollback snapshot directory (`ckpt::latest` at shrink time)
    resume: String,
    /// surviving previous ranks, sorted; position = new rank
    survivors: Vec<usize>,
}

impl ShrinkPlan {
    fn new_rank(&self, prev_rank: usize) -> Option<usize> {
        self.survivors.iter().position(|&r| r == prev_rank)
    }
}

/// The survivors' rendezvous point after a loss cancels the world: each
/// survivor arrives with the lost-rank list its collective error carried;
/// the LAST arriver builds the [`ShrinkPlan`] (everyone must agree on one
/// `ckpt::latest` answer and one pair of new worlds) and wakes the rest.
/// Single-shot: one fail spec means at most one shrink per run. All waits
/// are deadline-bounded — the rendezvous itself must not reintroduce the
/// unbounded blocking the cancellable collectives just removed.
struct ShrinkCell {
    state: Mutex<ShrinkState>,
    cv: Condvar,
}

struct ShrinkState {
    arrived: Vec<usize>,
    plan: Option<std::result::Result<Arc<ShrinkPlan>, String>>,
}

impl ShrinkCell {
    fn new() -> ShrinkCell {
        ShrinkCell {
            state: Mutex::new(ShrinkState { arrived: Vec::new(), plan: None }),
            cv: Condvar::new(),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn rendezvous(
        &self,
        rank: usize,
        prev_k: usize,
        lost: &[usize],
        fault: &FaultPlan,
        stats: &Arc<CommStats>,
        ckpt_dir: Option<&str>,
        log: Logger,
    ) -> Result<Arc<ShrinkPlan>> {
        let survivors: Vec<usize> = (0..prev_k).filter(|r| !lost.contains(r)).collect();
        // a shrink implies an injected fault, so watchdog() is Some; the
        // fallback keeps the wait bounded even for exotic callers
        let bound = fault.watchdog().unwrap_or(Duration::from_secs(60));
        let deadline = Instant::now() + bound;
        let mut s = self.state.lock().unwrap();
        ensure!(!s.arrived.contains(&rank), "rank {rank} arrived twice at the shrink rendezvous");
        s.arrived.push(rank);
        if s.arrived.len() == survivors.len() {
            let mut arrived = s.arrived.clone();
            arrived.sort_unstable();
            let built = (|| -> Result<Arc<ShrinkPlan>> {
                ensure!(
                    arrived == survivors,
                    "shrink rendezvous mismatch: arrived {arrived:?}, expected {survivors:?}"
                );
                let root = ckpt_dir.ok_or_else(|| {
                    anyhow!("cannot shrink without --ckpt-dir: no snapshot to roll back to")
                })?;
                let dir = ckpt::latest(Path::new(root))?.ok_or_else(|| {
                    anyhow!("cannot shrink: no finalized snapshot under {root} to roll back to")
                })?;
                let k2 = survivors.len();
                let token = Arc::new(CancellationToken::new());
                // stragglers keep their skew in their new slots
                let prev = fault.straggle_for(prev_k);
                let skew: Vec<Duration> = survivors.iter().map(|&r| prev[r]).collect();
                let train = CommWorld::with_faults(
                    k2,
                    Arc::clone(stats),
                    Arc::clone(&token),
                    fault.watchdog(),
                    skew.clone(),
                );
                let reduce =
                    CommWorld::with_faults(k2, Arc::clone(stats), token, fault.watchdog(), skew);
                // one survivor builds the plan, so these record exactly
                // once per shrink; the events surface in the JSONL trail
                // at the end of the run (DESIGN.md §14)
                for &l in lost {
                    stats.record_event(TraceEventKind::RankLost, l, 0, 0);
                }
                stats.record_event(TraceEventKind::Shrink, rank, prev_k as u64, k2 as u64);
                log.status(&format!(
                    "rank(s) {lost:?} lost: shrinking world {prev_k} -> {k2}, rolling back to {}",
                    dir.display()
                ));
                Ok(Arc::new(ShrinkPlan {
                    train,
                    reduce,
                    resume: dir.to_string_lossy().into_owned(),
                    survivors: survivors.clone(),
                }))
            })();
            s.plan = Some(built.map_err(|e| format!("{e:#}")));
            self.cv.notify_all();
        }
        loop {
            match &s.plan {
                Some(Ok(p)) => return Ok(Arc::clone(p)),
                Some(Err(msg)) => bail!("shrink failed: {msg}"),
                None => {
                    ensure!(
                        Instant::now() < deadline,
                        "shrink rendezvous timed out after {bound:?}: expected survivors \
                         {survivors:?}, arrived {:?}",
                        s.arrived
                    );
                    s = self.cv.wait_timeout(s, Duration::from_millis(1)).unwrap().0;
                }
            }
        }
    }
}

/// One worker thread for the whole run: the incarnation loop. Returns
/// `Ok(None)` when this rank was the injected death (its exit is the
/// fault, not an error), `Ok(Some(output))` when it finished training in
/// the final incarnation, `Err` for real failures.
#[allow(clippy::too_many_arguments)]
fn worker_thread(
    orig_rank: usize,
    mut train_world: Arc<CommWorld>,
    mut reduce_world: Arc<CommWorld>,
    cfg: Arc<TrainConfig>,
    dataset: Arc<Dataset>,
    manifest: Manifest,
    fault: FaultPlan,
    shrink: Arc<ShrinkCell>,
    stats: Arc<CommStats>,
    sink: Option<Arc<TraceSink>>,
    log: Logger,
    span_epoch: Instant,
) -> Result<Option<WorkerOutput>> {
    let mut rank = orig_rank;
    let mut inc_cfg = (*cfg).clone();
    let mut acc = Accum::default();
    let mut shrinks = 0u32;
    let mut lost_all: Vec<usize> = Vec::new();
    loop {
        let comm = train_world.handle(rank);
        let reduce_comm = reduce_world.handle(rank);
        let attempt = worker_loop(
            orig_rank,
            comm,
            reduce_comm,
            &inc_cfg,
            &dataset,
            &manifest,
            fault.fail,
            &mut acc,
            &sink,
            log,
            span_epoch,
        );
        match attempt {
            Ok(None) => return Ok(None),
            Ok(Some(mut out)) => {
                out.shrinks = shrinks;
                out.lost = lost_all;
                return Ok(Some(out));
            }
            Err(e) => {
                // shrinkable failures are exactly the lost-rank errors;
                // anything else (I/O, watchdog-without-loss) is fatal
                let lost = match e.root_cause().downcast_ref::<CommError>() {
                    Some(CommError::RanksLost(l)) => l.clone(),
                    _ => return Err(e),
                };
                // the trail must survive the crash: push buffered trace
                // lines to the OS before heading into the rendezvous
                if let Some(s) = &sink {
                    s.flush();
                }
                let plan = shrink
                    .rendezvous(
                        rank,
                        train_world.world_size(),
                        &lost,
                        &fault,
                        &stats,
                        inc_cfg.ckpt_dir.as_deref(),
                        log,
                    )
                    .with_context(|| format!("after losing rank(s) {lost:?}"))?;
                rank = plan.new_rank(rank).ok_or_else(|| {
                    anyhow::anyhow!("rank {rank} survived the shrink but got no new rank")
                })?;
                train_world = Arc::clone(&plan.train);
                reduce_world = Arc::clone(&plan.reduce);
                inc_cfg.resume = Some(plan.resume.clone());
                shrinks += 1;
                lost_all.extend(lost);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    orig_rank: usize,
    comm: WorkerComm,
    reduce_comm: WorkerComm,
    cfg: &TrainConfig,
    dataset: &Dataset,
    manifest: &Manifest,
    fail: Option<FailSpec>,
    acc: &mut Accum,
    sink: &Option<Arc<TraceSink>>,
    log: Logger,
    span_epoch: Instant,
) -> Result<Option<WorkerOutput>> {
    // the rank in THIS incarnation's world; `orig_rank` (the thread's
    // rank at spawn) only matters for matching the injected fail spec
    let rank = comm.rank();
    // per-rank span recorder (DESIGN.md §14): the shared epoch keeps
    // start_us monotone across incarnations; disabled (no --trace-out)
    // it never reads the clock, so telemetry-off runs are untouched
    let mut rec = SpanRecorder::with_epoch(rank, sink.is_some(), span_epoch);
    let variant = cfg.algorithm.variant();
    // `cfg.backend` may still be Auto here: create_backend resolves it
    // against the manifest kind, which `TrainConfig::load_manifest`
    // already fixed, so every worker lands on the same engine
    let mut rt = crate::runtime::create_backend(
        cfg.backend,
        manifest,
        Some(variant),
        cfg.kernel_threads,
        cfg.precision,
    )?;
    let rt = rt.as_mut();
    // wire codecs (DESIGN.md §15): the feature gathers follow the compute
    // precision (embeddings are bf16-representable under bf16 compute),
    // while the gradient wire can compress independently (`--wire`) —
    // int8-blockwise or top-k with error feedback. Master-state legs
    // (u/τ gathers, the sharded parameter all-gather, loss scalars)
    // always stay f32.
    let feat_wire = WireCodec::from_precision(cfg.precision);
    let grad_wire = cfg.wire_codec();
    // sharded contrastive loss (DESIGN.md §16): resolved once against
    // the backend the run executes on. Deliberately NOT in the
    // checkpoint meta — the mode is bitwise-invisible, so any snapshot
    // resumes under any shard mode.
    let loss_shard_on = cfg.loss_shard.resolve(cfg.resolved_backend());
    let k = comm.world_size();
    let bl = manifest.local_batch;
    let (d, p) = (manifest.model.d_embed, manifest.n_params);
    let dims = manifest.model_dims();
    let img_dim = dims.v_patches * dims.v_patch_dim;
    let individual_tau = variant == "rgcl_i";

    // cannot fail on a subset of ranks: Trainer::new pre-validated
    // n_train/K >= bl, which is exactly the smallest strided shard — a
    // partial failure here would strand the surviving ranks on their
    // first collective
    let mut loader = ShardLoader::new(cfg.data.n_train, rank, k, bl, cfg.seed)
        .context("building the shard loader")?;
    let mut ustate = UState::new(loader.shard_len());
    let mut tau = TauState::new(cfg, loader.shard_len());
    let mut params = manifest.load_init_params()?;

    // communication accounting: modeled topology (cfg.nodes×gpus_per_node)
    // may exceed the thread count — volumes and α–β times follow the model
    let cost = CostModel::new(cfg.network.profile(), cfg.nodes, cfg.gpus_per_node);

    // gradient-reduction strategy: resolved once from the gradient's
    // encoded WIRE size (the codec changes the byte width, and with it
    // the cheapest algorithm — topk's index overhead included); the
    // sharded strategy builds optimizer state over this rank's chunk
    // only (segments clipped to the shard, DESIGN.md §4)
    let mut algo = cfg.reduce.resolve(&cost, grad_wire, p);
    if algo == ReduceAlgo::Sharded
        && cfg.reduce == ReduceStrategy::Auto
        && cfg.optimizer.kind == OptimizerKind::Lamb
    {
        // LAMB's trust ratio is per leaf; sharding clips leaves at chunk
        // boundaries and changes the numerics (optim::shard_segments).
        // Auto never trades exactness for bytes — keep the update
        // replicated. An explicit `reduce = "sharded"` still opts in.
        algo = ReduceAlgo::Ring;
    }
    let reducer = reduction(algo);
    let (lo, hi) = comm.owned_chunk(p);
    let mut optimizer = match algo {
        ReduceAlgo::Sharded => crate::optim::build(
            &cfg.optimizer,
            hi - lo,
            crate::optim::shard_segments(&manifest.segments(), lo, hi),
        ),
        _ => crate::optim::build(&cfg.optimizer, p, manifest.segments()),
    };
    // overlapped reduction (DESIGN.md §11): split the flat gradient into
    // size-targeted buckets and reduce finished buckets on a background
    // worker (over the dedicated reduce world) while the backward pass
    // still writes later segments. Auto enables it exactly when there is
    // something to hide: K > 1 and more than one bucket. The pipeline
    // itself is spawned after the resume block so its reduction context
    // can be seeded from the checkpoint's residuals.
    let plan = BucketPlan::for_bytes(p, cfg.bucket_bytes);
    let n_buckets = plan.len();
    let overlap_on = cfg.overlap.enabled(k, n_buckets);

    let n_scalar_vectors = if individual_tau { 4 } else { 2 };
    let volumes = IterationVolumes::for_pattern(
        cfg.algorithm.comm_pattern(),
        bl,
        cost.world_size(),
        d,
        p,
        n_scalar_vectors,
    );

    // resume: replace the freshly initialized state with the checkpoint's
    // (DESIGN.md §9). Same world size restores bit-exactly, including the
    // loader cursor and RNG stream; a different world size re-shards u/τ
    // through the global-index mapping and re-partitions the optimizer.
    // Every fallible step goes through `ckpt_sync`: a rank that bailed
    // with a local `?` while its peers head into the next collective
    // would deadlock the world, so errors are made collective instead.
    let mut start_step: u32 = 0;
    let mut restored_resid: Option<Vec<f32>> = None;
    if let Some(resume) = &cfg.resume {
        let t0 = Instant::now();
        let attempt = (|| -> Result<ckpt::RestoredWorker> {
            let ck = ckpt::Checkpoint::open(Path::new(resume))
                .with_context(|| format!("opening checkpoint {resume}"))?;
            ckpt::check_compatible(ck.meta(), cfg, p)?;
            let restored =
                ckpt::restore_worker(&ck, cfg, rank, k, bl, algo == ReduceAlgo::Sharded)
                    .with_context(|| format!("restoring rank {rank} from {resume}"))?;
            ensure!(
                restored.start_step <= cfg.steps,
                "checkpoint is at step {}, past the configured {} steps",
                restored.start_step,
                cfg.steps
            );
            if rank == 0 {
                log.status(&format!(
                    "resumed from {} at step {} (checkpoint world {}, run world {k})",
                    ck.dir().display(),
                    restored.start_step,
                    ck.meta().world
                ));
            }
            Ok(restored)
        })();
        let restored = ckpt_sync(&comm, attempt, "restoring state")?;
        params = restored.params;
        ustate = restored.ustate;
        tau = restored.tau;
        loader = restored.loader;
        start_step = restored.start_step;
        restored_resid = restored.resid;
        let imported = optimizer.import_state(&restored.optim);
        ckpt_sync(&comm, imported, "importing optimizer state")?;
        acc.ckpt.restore_s += t0.elapsed().as_secs_f64();
        acc.ckpt.resumed_at = Some(start_step);
        if rank == 0 {
            comm.stats().record_event(TraceEventKind::Resume, 0, start_step as u64, 0);
        }
        // a live shrink replays [start_step, crash): drop the rolled-back
        // records so the final history holds every step exactly once
        acc.history.retain(|r| r.step < start_step);
        acc.evals.retain(|e| e.step < start_step);
    }

    // gradient-wire reduction context (DESIGN.md §15): the codec plus,
    // for topk, this rank's error-feedback residuals — seeded from the
    // checkpoint on a same-world resume so the compressed trajectory
    // continues bitwise, zeroed otherwise
    let ctx = match (grad_wire, restored_resid) {
        (WireCodec::TopK, Some(r)) => {
            ReduceCtx { codec: grad_wire, ef: Some(Arc::new(EfState::from_residual(r))) }
        }
        _ => ReduceCtx::for_run(grad_wire, p),
    };
    let mut pipeline = if overlap_on {
        // the worker thread owns a clone of the context — same codec,
        // same shared residual store — so pipelined topk banks residuals
        // at the same global parameter indices the serial path would
        Some(OverlapPipeline::spawn(reduce_comm, algo, plan, p, ctx.clone()))
    } else {
        None
    };

    let mut images = vec![0.0f32; bl * img_dim];
    let mut texts = vec![0i32; bl * dims.t_len];

    for t in start_step..cfg.steps {
        // deterministic failure injection (DESIGN.md §13): the rank dies
        // at the TOP of its iteration — after the previous iteration
        // fully committed (including any snapshot at this boundary),
        // before any collective of this one. Declaring the loss cancels
        // both worlds, so every survivor's next blocking wait errors out
        // instead of hanging; this thread then simply exits, as a dead
        // process would.
        if let Some(f) = fail {
            if f.rank == orig_rank && f.iter == t {
                comm.token().declare_lost(orig_rank);
                return Ok(None);
            }
        }
        // tag the comm layer with this rank's iteration so straggle and
        // watchdog events it records carry the right `iter`; open the
        // root span the phase spans below nest under (DESIGN.md §14)
        comm.stats().set_rank_iter(rank, t as u64);
        let iter_tok = rec.begin("iter", t);
        let timing_before = acc.timing;
        let epoch = t / cfg.iters_per_epoch.max(1);
        let gamma = if cfg.algorithm.forces_gamma_one() { 1.0 } else { cfg.gamma.value(epoch) };
        let lr = cfg.lr.value(t);
        let compute_before = rt.timers().compute_s();
        let step_before = rt.timers().step_s;

        // 1. local batch ----------------------------------------- (others)
        let t_other = Instant::now();
        let batch = loader.next_batch();
        dataset.fill_batch(&batch.global_indices, &mut images, &mut texts);
        let mut others_s = t_other.elapsed().as_secs_f64();

        // 2. encode + gather features ------------------- (compute + comm)
        // under bf16 the embeddings are already bf16-representable, so
        // the half-width gather is lossless — only the payload accounting
        // changes (DESIGN.md §12)
        let (e1, e2) = crate::span!(rec, "encode", t, rt.encode(&params, &images, &texts))?;
        let gather_tok = rec.begin("gather", t);
        let e1g = comm.all_gather(&e1, feat_wire)?;
        let e2g = comm.all_gather(&e2, feat_wire)?;
        rec.end(gather_tok);

        // 3. phase_g: Eq. (1) u update ---------------------------- (compute)
        let t_other = Instant::now();
        let (u1, u2) = ustate.gather(&batch.local_positions);
        let (tau1_rows, tau2_rows) = tau.rows(&batch.local_positions);
        others_s += t_other.elapsed().as_secs_f64();
        let offset = rank * bl;
        let (_g1, _g2, u1n, u2n) = crate::span!(
            rec,
            "phase_g",
            t,
            rt.phase_g(&e1g, &e2g, offset, &u1, &u2, &tau1_rows, &tau2_rows, gamma)
        )?;
        let t_other = Instant::now();
        ustate.scatter(&batch.local_positions, &u1n, &u2n);
        others_s += t_other.elapsed().as_secs_f64();

        // 4. gather the scalar state ---------------------------------- (comm)
        let gather_tok = rec.begin("gather", t);
        let u1g = comm.all_gather(&u1n, WireCodec::F32)?;
        let u2g = comm.all_gather(&u2n, WireCodec::F32)?;
        let tau_input_vecs; // keeps gathered τ alive across the step call
        let tau_input = if individual_tau {
            let t1g = comm.all_gather(&tau1_rows, WireCodec::F32)?;
            let t2g = comm.all_gather(&tau2_rows, WireCodec::F32)?;
            tau_input_vecs = (t1g, t2g);
            TauInput::Individual { tau1g: &tau_input_vecs.0, tau2g: &tau_input_vecs.1 }
        } else {
            TauInput::Global(tau.global_tau())
        };
        rec.end(gather_tok);

        // 5+6. gradient step; reduce scalars; reduce gradient + apply
        // the optimizer. Pipelined mode reduces buckets in the background
        // as the backward emits them and only waits out the stragglers;
        // serial mode reduces after the whole backward. Both paths apply
        // the optimizer exactly once per iteration — for the sharded
        // algorithm between the (bucketed) reduce-scatter and the
        // parameter all-gather — so they are bitwise identical.
        // the sharded loss's mid-step column exchange runs over the
        // TRAINING world — the reduce world stays dedicated to overlap
        // buckets, so the two never interleave (DESIGN.md §11, §16)
        let mut featx = FeatGradOverComm { comm: &comm, reducer };
        let mut opt_s = 0.0f64;
        let (loss, tau_grad, tau_grads, overlap_rep) = if let Some(pipe) = pipeline.as_mut() {
            let step_tok = rec.begin("step", t);
            let shard = if loss_shard_on { LossShard::On(&mut featx) } else { LossShard::Off };
            let emit = rt.step_emit(
                variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, offset,
                cfg.eps, cfg.rho, tau_input, shard, &mut |off, seg| pipe.emit(off, seg),
            )?;
            let (loss, tau_grad) = reduce_step_scalars(&comm, emit.loss, &emit.tau)?;
            rec.end(step_tok);
            let reduce_tok = rec.begin("reduce", t);
            let rep = pipe.finish(&comm, &mut params, &mut |pslice, gslice| {
                let t_opt = Instant::now();
                optimizer.step(pslice, gslice, lr);
                opt_s += t_opt.elapsed().as_secs_f64();
            })?;
            rec.end(reduce_tok);
            (loss, tau_grad, emit.tau, Some(rep))
        } else {
            let step_tok = rec.begin("step", t);
            let shard = if loss_shard_on { LossShard::On(&mut featx) } else { LossShard::Off };
            let out = rt.step(
                variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, offset,
                cfg.eps, cfg.rho, tau_input, shard,
            )?;
            let (loss, tau_grad) = reduce_step_scalars(&comm, out.loss, &out.tau)?;
            rec.end(step_tok);
            let mut grad = out.grad;
            let reduce_tok = rec.begin("reduce", t);
            reducer.reduce_and_apply(&comm, &mut grad, &mut params, &ctx, &mut |pslice, gslice| {
                let t_opt = Instant::now();
                optimizer.step(pslice, gslice, lr);
                opt_s += t_opt.elapsed().as_secs_f64();
            })?;
            rec.end(reduce_tok);
            (loss, tau_grad, out.tau, None)
        };
        others_s += opt_s;

        // 7. temperature + schedules ---------------------------------- (others)
        let t_other = Instant::now();
        match (&mut tau, tau_grads) {
            (TauState::Constant(_), _) => {}
            (TauState::Global(g), TauGrads::Global(_)) => g.step(tau_grad),
            (TauState::Individual(it), TauGrads::Individual { tau1, tau2 }) => {
                it.update(&batch.local_positions, &tau1, &tau2, cfg.tau_lr);
            }
            _ => unreachable!("tau rule / grad kind mismatch"),
        }
        others_s += t_other.elapsed().as_secs_f64();

        // timing bookkeeping: pipelined iterations charge the measured
        // hidden/exposed reduction split (never the serial heuristic on
        // top of it — no double-counted overlap win)
        let step_compute = rt.timers().step_s - step_before;
        acc.timing.compute_s += rt.timers().compute_s() - compute_before;
        acc.timing.others_s += others_s;
        acc.timing.iterations += 1;
        match &overlap_rep {
            Some(rep) => {
                let to_us = |s: f64| (s * 1e6) as u64;
                comm.stats().add_overlap_us(to_us(rep.hidden_s()), to_us(rep.exposed_s));
                charge_iteration_overlapped(&mut acc.timing, &cost, &volumes, algo, rep);
            }
            None => charge_iteration_with(&mut acc.timing, &cost, &volumes, step_compute, algo),
        }
        rec.end(iter_tok);

        // every rank records history (the values are replicated — loss is
        // all-reduced, schedules are deterministic): after a shrink ANY
        // survivor can end up as the lead rank reporting the full run
        acc.history.push(IterRecord { step: t, epoch, loss, gamma, lr, tau: tau.mean_tau() });

        // periodic evaluation (rank 0 computes; all ranks synchronize)
        if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 && t + 1 < cfg.steps {
            comm.barrier()?;
            if rank == 0 {
                let summary = crate::span!(rec, "eval", t, evaluate(&mut *rt, dataset, &params))?;
                acc.evals.push(EvalRecord { step: t + 1, summary });
            }
            comm.barrier()?;
        }

        // periodic snapshot (DESIGN.md §9): rank 0 stages, every rank
        // writes its own blobs, rank 0 hashes + writes the manifest and
        // atomically renames the stage into place. Each fallible phase
        // ends in a `ckpt_sync` (an all-reduced failure flag, itself the
        // synchronization point): an I/O error — disk full, permissions —
        // on ANY rank surfaces as an error on EVERY rank, instead of one
        // rank exiting early and deadlocking its peers on a barrier.
        let wrote_snapshot = cfg.ckpt_every > 0 && (t + 1) % cfg.ckpt_every == 0;
        if wrote_snapshot {
            let ckpt_tok = rec.begin("ckpt", t);
            let t0 = Instant::now();
            let root_s = cfg
                .ckpt_dir
                .as_deref()
                .ok_or_else(|| anyhow::anyhow!("--ckpt-every requires --ckpt-dir"))?;
            let root = Path::new(root_s);
            let stage = ckpt::stage_path(root, t + 1);
            let staged = if rank == 0 { ckpt::prepare_stage(&stage) } else { Ok(()) };
            ckpt_sync(&comm, staged, "staging the snapshot directory")?;
            // sharded reduction: every rank persists its optimizer shard;
            // replicated: the state is identical everywhere, rank 0's copy
            // suffices
            let sharded = algo == ReduceAlgo::Sharded;
            let opt_state =
                if sharded || rank == 0 { Some(optimizer.export_state()) } else { None };
            // topk wire state: every rank snapshots its error-feedback
            // residuals so a same-world resume continues the compressed
            // trajectory bitwise (DESIGN.md §15)
            let resid = ctx.ef.as_ref().map(|ef| ef.export());
            let wrote = ckpt::write_rank_state(
                &stage,
                rank,
                &ustate,
                &tau,
                &loader,
                opt_state.as_ref().map(|s| (s, sharded)),
                resid.as_deref(),
            );
            ckpt_sync(&comm, wrote, "writing rank state blobs")?;
            let finalized = if rank == 0 {
                let meta = CkptMeta::for_run(cfg, t + 1, k, p, bl, algo.id());
                ckpt::finalize(root, &stage, &meta, &params, cfg.keep_last)
                    .map(|_| ())
                    .with_context(|| format!("writing checkpoint at step {}", t + 1))
            } else {
                Ok(())
            };
            ckpt_sync(&comm, finalized, "finalizing the snapshot")?;
            acc.ckpt.snapshots += 1;
            acc.ckpt.write_s += t0.elapsed().as_secs_f64();
            rec.end(ckpt_tok);
        }

        // telemetry drain — after ALL the iteration's bookkeeping, off
        // the compute/comm path (DESIGN.md §14): this rank's spans, plus
        // (rank 0 only) the exact per-iteration timing deltas and the
        // `--log-every` heartbeat
        let heartbeat = cfg.log_every > 0 && (t + 1) % cfg.log_every == 0 && rank == 0;
        if heartbeat {
            log.line(&format!(
                "step {:>6}/{} loss {:.4} lr {:.5} tau {:.4}",
                t + 1,
                cfg.steps,
                loss,
                lr,
                tau.mean_tau()
            ));
        }
        if let Some(s) = sink {
            let mut evs = tsink::span_events(rank, &rec.drain());
            if rank == 0 {
                let d = |cur: f64, before: f64| Json::num(cur - before);
                evs.push(tsink::event(
                    "iter",
                    vec![
                        ("rank", Json::num(0)),
                        ("iter", Json::num(t)),
                        ("loss", Json::num(loss as f64)),
                        ("compute_s", d(acc.timing.compute_s, timing_before.compute_s)),
                        ("comm_total_s", d(acc.timing.comm_total_s, timing_before.comm_total_s)),
                        (
                            "comm_overlap_s",
                            d(acc.timing.comm_overlap_s, timing_before.comm_overlap_s),
                        ),
                        ("comm_pure_s", d(acc.timing.comm_pure_s, timing_before.comm_pure_s)),
                        ("others_s", d(acc.timing.others_s, timing_before.others_s)),
                        (
                            "overlap_hidden_s",
                            d(acc.timing.overlap_hidden_s, timing_before.overlap_hidden_s),
                        ),
                        (
                            "overlap_exposed_s",
                            d(acc.timing.overlap_exposed_s, timing_before.overlap_exposed_s),
                        ),
                    ],
                ));
            }
            if heartbeat {
                evs.push(tsink::event(
                    "heartbeat",
                    vec![
                        ("rank", Json::num(0)),
                        ("iter", Json::num(t)),
                        ("t_us", Json::num(s.now_us() as f64)),
                        ("loss", Json::num(loss as f64)),
                        ("lr", Json::num(lr as f64)),
                        ("tau", Json::num(tau.mean_tau() as f64)),
                    ],
                ));
            }
            s.emit_all(&evs);
            // snapshot boundaries double as trace durability points
            if wrote_snapshot {
                s.flush();
            }
        }
    }

    // final evaluation on rank 0
    comm.barrier()?;
    let final_eval = if rank == 0 {
        let summary =
            crate::span!(rec, "eval", cfg.steps, evaluate(&mut *rt, dataset, &params))?;
        acc.evals.push(EvalRecord { step: cfg.steps, summary: summary.clone() });
        Some(summary)
    } else {
        None
    };
    comm.barrier()?;
    if let Some(s) = sink {
        s.emit_all(&tsink::span_events(rank, &rec.drain()));
        s.flush();
    }

    // close the job channel and join the reduction worker before the
    // output leaves the thread
    let max_queue_depth = pipeline.as_ref().map_or(0, |p| p.max_queue_depth());
    drop(pipeline);

    Ok(Some(WorkerOutput {
        rank,
        world: k,
        shrinks: 0, // worker_thread fills these from its incarnation count
        lost: Vec::new(),
        history: std::mem::take(&mut acc.history),
        evals: std::mem::take(&mut acc.evals),
        final_eval,
        timing: std::mem::take(&mut acc.timing),
        modeled_iter_bytes: volumes.total_bytes(),
        reduce_id: algo.id(),
        overlap: overlap_on,
        loss_shard: loss_shard_on,
        loss_peak_bytes: rt.loss_peak_bytes(loss_shard_on),
        n_buckets,
        max_queue_depth,
        final_tau: tau.mean_tau(),
        params,
        ckpt: std::mem::take(&mut acc.ckpt),
    }))
}

/// SUM-all-reduce one step's scalar contributions — the loss and, for
/// global temperature rules, dL/dτ. One shared implementation for the
/// serial and pipelined paths, so the two can never drift in what they
/// reduce. Returns `(global_loss, global_tau_grad)`.
fn reduce_step_scalars(comm: &WorkerComm, loss: f32, tau: &TauGrads) -> Result<(f32, f32)> {
    let mut scalars = [loss, 0.0];
    if let TauGrads::Global(g) = tau {
        scalars[1] = *g;
    }
    comm.all_reduce_sum(&mut scalars, WireCodec::F32)?;
    Ok((scalars[0], scalars[1]))
}

/// Collective error propagation for the checkpoint protocol: all ranks
/// SUM-reduce a failure flag (the reduce doubles as the phase's sync
/// point), so either every rank proceeds or every rank returns an error
/// together. Without it, one rank propagating a local I/O error with `?`
/// exits the lockstep loop while its peers block forever on the next
/// collective — turning a disk-full error into a hang of
/// [`Trainer::run`].
///
/// The reduce is cancellable, which closes the protocol's former
/// death-window deadlock: a rank that dies between raising its flag and
/// the reduce's internal barriers cancels the world, so every survivor
/// errors out of this call — with the lost ranks attached, ready for the
/// shrink path — instead of waiting forever (pinned by
/// `tests/fault_injection.rs`).
fn ckpt_sync<T>(comm: &WorkerComm, local: Result<T>, what: &str) -> Result<T> {
    let mut flag = [if local.is_err() { 1.0f32 } else { 0.0 }];
    comm.all_reduce_sum(&mut flag, WireCodec::F32)
        .with_context(|| format!("checkpoint: {what}"))?;
    match local {
        Err(e) => Err(e).with_context(|| format!("checkpoint: {what}")),
        Ok(v) => {
            ensure!(flag[0] == 0.0, "checkpoint: {what} failed on another rank");
            Ok(v)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, DataConfig, GammaSchedule};

    /// The native backend executes these end-to-end on any machine —
    /// encode, phase_g, step, eval, all through real worker threads and
    /// collectives (DESIGN.md §10). Backend pinned to Native so the suite
    /// is identical with and without the `pjrt` feature/artifacts.
    fn quick_cfg(algo: Algorithm, steps: u32) -> TrainConfig {
        let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", algo);
        cfg.backend = crate::runtime::BackendKind::Native;
        cfg.kernel_threads = 1;
        cfg.steps = steps;
        cfg.iters_per_epoch = 4;
        cfg.data = DataConfig { n_train: 64, n_eval: 32, n_classes: 8, ..DataConfig::default() };
        cfg.lr.warmup_iters = 2;
        cfg.lr.total_iters = steps;
        cfg
    }

    #[test]
    fn v3_short_run_loss_decreases() {
        let cfg = quick_cfg(Algorithm::FastClipV3, 30);
        let r = Trainer::new(cfg).unwrap().run().unwrap();
        assert_eq!(r.history.len(), 30);
        let first5 = crate::util::mean(&r.history[..5].iter().map(|h| h.loss).collect::<Vec<_>>());
        let last5 = r.tail_loss(5);
        assert!(
            last5 < first5,
            "loss should decrease: first {first5} last {last5}"
        );
        assert!(r.final_tau > 0.0);
        assert_eq!(r.timing.iterations, 30);
        assert!(r.comm_bytes > 0, "K=2: bytes must actually move");
        assert!(r.final_params.len() > 0);
        assert!(r.final_eval.datacomp >= 0.0);
    }

    #[test]
    fn all_algorithms_run_three_steps() {
        for algo in Algorithm::all() {
            let cfg = quick_cfg(algo, 3);
            let r = Trainer::new(cfg).unwrap().run()
                .unwrap_or_else(|e| panic!("{}: {e:?}", algo.name()));
            assert_eq!(r.history.len(), 3, "{}", algo.name());
            assert!(r.history.iter().all(|h| h.loss.is_finite()), "{}", algo.name());
        }
    }

    #[test]
    fn openclip_gamma_is_one() {
        let mut cfg = quick_cfg(Algorithm::OpenClip, 2);
        cfg.gamma = GammaSchedule::Cosine { gamma_min: 0.2, decay_epochs: 1 }; // ignored
        let r = Trainer::new(cfg).unwrap().run().unwrap();
        assert!(r.history.iter().all(|h| h.gamma == 1.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || Trainer::new(quick_cfg(Algorithm::FastClipV1, 5)).unwrap().run().unwrap();
        let a = run();
        let b = run();
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.loss, y.loss, "bitwise reproducible");
        }
        assert_eq!(a.final_params, b.final_params);
    }

    #[test]
    fn openclip_models_more_comm_volume_than_v3() {
        let mut oc = quick_cfg(Algorithm::OpenClip, 2);
        let mut v3 = quick_cfg(Algorithm::FastClipV3, 2);
        for c in [&mut oc, &mut v3] {
            c.nodes = 8;
            c.gpus_per_node = 4;
        }
        let ro = Trainer::new(oc).unwrap().run().unwrap();
        let rv = Trainer::new(v3).unwrap().run().unwrap();
        assert!(ro.modeled_iter_bytes > rv.modeled_iter_bytes);
        assert!(ro.timing.comm_pure_s > rv.timing.comm_pure_s);
    }

    #[test]
    fn reduce_strategies_bitwise_agree_end_to_end() {
        use crate::comm::{ReduceAlgo, ReduceStrategy};
        let run = |algo: ReduceAlgo| {
            let mut cfg = quick_cfg(Algorithm::FastClipV1, 5);
            cfg.reduce = ReduceStrategy::Fixed(algo);
            Trainer::new(cfg).unwrap().run().unwrap()
        };
        let naive = run(ReduceAlgo::Naive);
        let ring = run(ReduceAlgo::Ring);
        let sharded = run(ReduceAlgo::Sharded);
        // all strategies sum in rank order: bitwise-identical training
        assert_eq!(naive.final_params, ring.final_params);
        assert_eq!(naive.final_params, sharded.final_params);
        for (a, b) in naive.history.iter().zip(&sharded.history) {
            assert_eq!(a.loss, b.loss);
        }
        // and the sharded run moved strictly fewer gradient bytes (K=2)
        assert!(sharded.grad_wire_bytes < sharded.grad_wire_bytes_naive);
        assert_eq!(naive.grad_wire_bytes, naive.grad_wire_bytes_naive);
        assert_eq!(sharded.reduce_algorithm, "sharded");
    }

    #[test]
    fn loss_shard_on_bitwise_equals_off_end_to_end() {
        use crate::runtime::LossShardMode;
        let run = |mode: LossShardMode| {
            let mut cfg = quick_cfg(Algorithm::FastClipV3, 5);
            cfg.loss_shard = mode;
            Trainer::new(cfg).unwrap().run().unwrap()
        };
        let on = run(LossShardMode::On);
        let off = run(LossShardMode::Off);
        assert!(on.loss_shard && !off.loss_shard);
        assert_eq!(on.final_params, off.final_params, "bitwise");
        for (a, b) in on.history.iter().zip(&off.history) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.tau, b.tau);
        }
        // auto resolves to on for the native backend
        let auto = run(LossShardMode::Auto);
        assert!(auto.loss_shard);
        assert_eq!(auto.final_params, on.final_params);
        // the analytic working-set gauge shrinks under sharding (K=2);
        // tests/telemetry.rs pins the exact formula
        assert!(on.loss_peak_bytes < off.loss_peak_bytes);
    }

    #[test]
    fn overlap_auto_stays_serial_when_one_bucket() {
        // tiny preset gradient (~74 KB) fits one default 4 MB bucket:
        // auto must resolve to the serial path, with zero overlap charged
        let r = Trainer::new(quick_cfg(Algorithm::FastClipV1, 2)).unwrap().run().unwrap();
        assert!(!r.overlap);
        assert_eq!(r.n_buckets, 1);
        assert_eq!(r.hidden_comm_us, 0);
        assert_eq!(r.exposed_comm_us, 0);
        assert_eq!(r.timing.overlap_hidden_s, 0.0);
    }

    #[test]
    fn overlap_on_bitwise_equals_serial_quick() {
        use crate::comm::OverlapMode;
        let run = |overlap: OverlapMode| {
            let mut cfg = quick_cfg(Algorithm::FastClipV3, 5);
            cfg.overlap = overlap;
            cfg.bucket_bytes = 4 << 10; // ~19 buckets over the tiny preset
            Trainer::new(cfg).unwrap().run().unwrap()
        };
        let serial = run(OverlapMode::Off);
        let piped = run(OverlapMode::On);
        assert!(piped.overlap && !serial.overlap);
        assert!(piped.n_buckets > 1, "small buckets must split the gradient");
        assert_eq!(serial.final_params, piped.final_params, "bitwise");
        for (a, b) in serial.history.iter().zip(&piped.history) {
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.tau, b.tau);
        }
        // the pipeline measured its reduction split; serial charged none
        assert!(piped.hidden_comm_us > 0 || piped.exposed_comm_us > 0);
        assert_eq!(serial.hidden_comm_us + serial.exposed_comm_us, 0);
    }

    #[test]
    fn lossy_wire_codecs_train_and_cut_gradient_bytes() {
        use crate::comm::{ReduceAlgo, ReduceStrategy, WireCodec};
        let run = |wire: Option<WireCodec>| {
            let mut cfg = quick_cfg(Algorithm::FastClipV1, 4);
            // fix the algorithm so byte counts compare across codecs
            cfg.reduce = ReduceStrategy::Fixed(ReduceAlgo::Ring);
            cfg.wire = wire;
            Trainer::new(cfg).unwrap().run().unwrap()
        };
        let f = run(None);
        let int8 = run(Some(WireCodec::Int8));
        let topk = run(Some(WireCodec::TopK));
        assert_eq!(f.wire, "f32");
        assert_eq!(int8.wire, "int8");
        assert_eq!(topk.wire, "topk");
        // int8 is an exact 4x cut (per-block scales are framing, §15);
        // topk moves 8 bytes per kept element, 1 in 16 kept
        assert_eq!(int8.grad_wire_bytes * 4, f.grad_wire_bytes);
        assert_eq!(topk.grad_wire_bytes * 8, f.grad_wire_bytes);
        for r in [&int8, &topk] {
            assert!(r.history.iter().all(|h| h.loss.is_finite()));
            assert!(r.final_params.iter().all(|p| p.is_finite()));
        }
        // lossy wires stay run-to-run deterministic
        let int8b = run(Some(WireCodec::Int8));
        assert_eq!(int8.final_params, int8b.final_params);
        let topkb = run(Some(WireCodec::TopK));
        assert_eq!(topk.final_params, topkb.final_params);
    }

    #[test]
    fn eval_every_produces_snapshots() {
        let mut cfg = quick_cfg(Algorithm::FastClipV1, 6);
        cfg.eval_every = 2;
        let r = Trainer::new(cfg).unwrap().run().unwrap();
        // steps 2, 4 (6 coincides with final) + final = 3 records
        assert_eq!(r.evals.len(), 3);
        assert_eq!(r.evals.last().unwrap().step, 6);
    }

    #[test]
    fn rejects_missing_variant_or_small_data() {
        let mut cfg = quick_cfg(Algorithm::FastClipV3, 2);
        cfg.data.n_train = 8; // 8/2 workers = 4 < bl 8
        assert!(Trainer::new(cfg).is_err());
    }
}
