//! Fixture crate; see DESIGN.md §1 and DESIGN.md §9.
