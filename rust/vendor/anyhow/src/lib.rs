//! In-tree subset of the `anyhow` API, vendored so the workspace builds
//! with no registry access (the vendored crate set policy — see
//! `rust/src/util/mod.rs`). Implements exactly what the crate uses:
//!
//! * [`Error`]: an opaque error with a context chain. `{e}` prints the
//!   outermost message, `{e:#}` the full `outer: inner: ...` chain, and
//!   `{e:?}` an anyhow-style report with a `Caused by:` block.
//! * [`Result<T>`] with a defaulted error type.
//! * [`Context::context`] / [`Context::with_context`] on any
//!   `Result<_, E>` whose error is `std::error::Error` or [`Error`].
//! * The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, [`Error`] deliberately does NOT implement
//! `std::error::Error`: that is what keeps the blanket `From` /
//! `Context` impls coherent.

use std::fmt::{self, Debug, Display};

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus the chain of lower-level causes it wraps.
/// `chain[0]` is the outermost (most recently attached) context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: Display + Send + Sync + 'static>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    fn push_context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message, then each cause from outer to inner.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain on one line, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` conversion from any concrete std error. Coherent with
// `impl From<T> for T` only because `Error: !std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Private unification of "things that convert to [`Error`]" — the same
/// trick the real crate's `ext::StdError` uses to make [`Context`] apply
/// to both std errors and its own `Error`.
pub trait IntoError: Send + Sync + 'static {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Attach human context to an error as it propagates.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: IntoError> Context<T, E> for Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().push_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().push_context(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros_and_with_context() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        let e = f(0).with_context(|| format!("calling f({})", 0)).unwrap_err();
        assert_eq!(format!("{e:#}"), "calling f(0): zero");
        let e2 = anyhow!("plain {}", 7);
        assert_eq!(format!("{e2}"), "plain 7");
    }

    #[test]
    fn parse_error_via_msg() {
        let r: Result<u32> = "abc".parse::<u32>().map_err(Error::msg);
        assert!(format!("{}", r.unwrap_err()).contains("invalid digit"));
    }
}
