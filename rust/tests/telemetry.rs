//! Telemetry determinism and trace fidelity (DESIGN.md §14).
//!
//! The tentpole contract of the telemetry subsystem: turning on the
//! full observability surface (`--trace-out` JSONL spans + `--log-every`
//! heartbeats) must be **bitwise invisible** to training — telemetry
//! reads clocks and buffers records, it never sits between compute and
//! communication. Checked here for f32 and bf16 at 1 and 4 kernel
//! threads, with the overlap pipeline engaged so every span kind is
//! exercised. The written trace must also validate structurally and
//! reproduce the in-process Fig.-3 breakdown within 1% (the end-of-run
//! `"metrics"` event carries the exact totals, so the comparison is in
//! practice exact).

use std::path::PathBuf;

use fastclip::comm::{OverlapMode, ReduceAlgo, ReduceStrategy};
use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::kernels::Precision;
use fastclip::telemetry::trace;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastclip_telemetry_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Native-backend K=2 run with the overlap pipeline forced through
/// several buckets — the richest span set (encode / gather / phase_g /
/// step / reduce under an `iter` root).
fn base_cfg(precision: Precision, threads: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", Algorithm::FastClipV3);
    cfg.backend = fastclip::runtime::BackendKind::Native;
    cfg.kernel_threads = threads;
    cfg.steps = 8;
    cfg.iters_per_epoch = 4;
    cfg.data.n_train = 64;
    cfg.data.n_eval = 32;
    cfg.data.n_classes = 8;
    cfg.lr.warmup_iters = 2;
    cfg.lr.total_iters = 8;
    cfg.precision = precision;
    cfg.overlap = OverlapMode::On;
    cfg.reduce = ReduceStrategy::Fixed(ReduceAlgo::Ring);
    cfg.bucket_bytes = 1024;
    cfg
}

fn telemetry_is_bitwise_invisible(precision: Precision) {
    for threads in [1usize, 4] {
        let label = format!("precision={} threads={threads}", precision.id());
        let off = Trainer::new(base_cfg(precision, threads)).unwrap().run().unwrap();

        let dir = tmp_dir(&format!("det_{}_{threads}", precision.id()));
        let trace_path = dir.join("trace.jsonl");
        let mut cfg = base_cfg(precision, threads);
        cfg.trace_out = Some(trace_path.to_string_lossy().into_owned());
        cfg.log_every = 2;
        cfg.quiet = true;
        let on = Trainer::new(cfg).unwrap().run().unwrap();

        // ---- bitwise equality: params, τ, and the whole trajectory ----
        assert_eq!(off.final_params, on.final_params, "params: {label}");
        assert_eq!(off.final_tau.to_bits(), on.final_tau.to_bits(), "tau: {label}");
        assert_eq!(off.history.len(), on.history.len(), "{label}");
        for (a, b) in off.history.iter().zip(&on.history) {
            assert_eq!(a.step, b.step, "{label}");
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss at step {}: {label}", a.step);
            assert_eq!(a.tau.to_bits(), b.tau.to_bits(), "tau at step {}: {label}", a.step);
        }
        // telemetry must not change what moves on the wire either
        assert_eq!(off.comm_bytes, on.comm_bytes, "{label}");
        assert_eq!(off.grad_wire_bytes, on.grad_wire_bytes, "{label}");

        // ---- the trace validates and reproduces the breakdown ---------
        trace::verify_file(&trace_path).unwrap();
        let sum = trace::summarize_file(&trace_path).unwrap();
        assert_eq!(sum.breakdown_source, "metrics", "{label}");
        assert_eq!(sum.breakdown.iterations, on.timing.iterations, "{label}");
        for (name, got, want) in [
            ("compute_s", sum.breakdown.compute_s, on.timing.compute_s),
            ("comm_total_s", sum.breakdown.comm_total_s, on.timing.comm_total_s),
            ("comm_overlap_s", sum.breakdown.comm_overlap_s, on.timing.comm_overlap_s),
            ("comm_pure_s", sum.breakdown.comm_pure_s, on.timing.comm_pure_s),
            ("others_s", sum.breakdown.others_s, on.timing.others_s),
            ("overlap_hidden_s", sum.breakdown.overlap_hidden_s, on.timing.overlap_hidden_s),
            ("overlap_exposed_s", sum.breakdown.overlap_exposed_s, on.timing.overlap_exposed_s),
        ] {
            // the acceptance bound is 1%; the metrics event makes it exact
            let tol = want.abs() * 0.01 + 1e-12;
            assert!(
                (got - want).abs() <= tol,
                "trace {name} {got} vs in-process {want}: {label}"
            );
        }

        // ---- span + heartbeat structure -------------------------------
        let meta = sum.meta.as_ref().expect("meta event");
        assert_eq!(meta.get("algo").unwrap().as_str().unwrap(), "fastclip-v3");
        assert_eq!(meta.get("precision").unwrap().as_str().unwrap(), precision.id());
        // the default wire codec follows the precision (DESIGN.md §15)
        assert_eq!(meta.get("wire").unwrap().as_str().unwrap(), precision.id());
        assert_eq!(sum.ranks.len(), 2, "both ranks traced: {label}");
        assert_eq!(sum.heartbeats, 4, "log_every=2 over 8 steps: {label}");
        for name in ["iter", "encode", "phase_g", "step", "reduce"] {
            assert!(sum.span_stats.contains_key(name), "span '{name}' missing: {label}");
        }
        assert_eq!(sum.span_stats["iter"].count, 2 * 8, "2 ranks x 8 iters: {label}");

        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn telemetry_is_bitwise_invisible_f32() {
    telemetry_is_bitwise_invisible(Precision::F32);
}

#[test]
fn telemetry_is_bitwise_invisible_bf16() {
    telemetry_is_bitwise_invisible(Precision::Bf16);
}
