//! Size-targeted bucketing of the flat gradient vector (DESIGN.md §11).
//!
//! The overlapped reduction pipeline ([`super::OverlapPipeline`]) does not
//! reduce the P-length gradient in one collective: it partitions the flat
//! vector into contiguous, ascending **buckets** of a target element
//! count (`--bucket-mb`, DDP-style) and reduces each bucket as soon as
//! the backward pass has finished writing it. The partition is exact —
//! buckets tile `[0, P)` with no gap and no overlap, the last bucket
//! absorbing the remainder — so per-bucket reduction touches every
//! element exactly once, in the same rank-ordered summation as the
//! unbucketed collective (the bitwise-equality argument of
//! [`super::GradientReduction::reduce_bucket`]).

/// One contiguous bucket `[lo, hi)` of the flat vector, `index`-th in
/// ascending order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bucket {
    /// Position in the plan (0-based, ascending with `lo`).
    pub index: usize,
    /// First element (inclusive).
    pub lo: usize,
    /// One past the last element (exclusive).
    pub hi: usize,
}

impl Bucket {
    /// Number of elements in the bucket.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// True for the degenerate empty bucket (only possible when the whole
    /// vector is empty).
    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// An exact partition of `[0, n)` into ascending size-targeted buckets.
///
/// # Example
///
/// Buckets tile the vector exactly, the last one absorbing the remainder:
///
/// ```
/// use fastclip::comm::BucketPlan;
///
/// let plan = BucketPlan::new(10, 4); // 10 elements, 4 per bucket
/// let ranges: Vec<(usize, usize)> = plan.iter().map(|b| (b.lo, b.hi)).collect();
/// assert_eq!(ranges, vec![(0, 4), (4, 8), (8, 10)]);
/// assert_eq!(plan.iter().map(|b| b.len()).sum::<usize>(), plan.total_len());
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketPlan {
    buckets: Vec<Bucket>,
    n: usize,
}

impl BucketPlan {
    /// Partition `n` elements into buckets of `target` elements each (the
    /// last bucket may be short). `target = 0` is treated as 1; a target
    /// larger than `n` yields a single bucket covering everything.
    pub fn new(n: usize, target: usize) -> BucketPlan {
        let target = target.max(1);
        let count = n.div_ceil(target).max(1);
        let mut buckets = Vec::with_capacity(count);
        let mut lo = 0;
        for index in 0..count {
            let hi = ((index + 1) * target).min(n);
            buckets.push(Bucket { index, lo, hi });
            lo = hi;
        }
        BucketPlan { buckets, n }
    }

    /// Partition `n_elems` f32 elements into buckets of roughly
    /// `bucket_bytes` bytes (4 bytes per element, at least one element).
    pub fn for_bytes(n_elems: usize, bucket_bytes: usize) -> BucketPlan {
        BucketPlan::new(n_elems, (bucket_bytes / 4).max(1))
    }

    /// Number of buckets (at least 1, even for an empty vector).
    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    /// True when the plan covers zero elements.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Total element count the plan partitions (`n`).
    pub fn total_len(&self) -> usize {
        self.n
    }

    /// The `index`-th bucket.
    pub fn get(&self, index: usize) -> Bucket {
        self.buckets[index]
    }

    /// Iterate the buckets in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = Bucket> + '_ {
        self.buckets.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The satellite property test: for a sweep of vector lengths
    /// (including odd lengths and 1-element vectors) and bucket targets
    /// (including 1 and targets larger than the whole vector), the plan
    /// tiles `[0, n)` exactly — no gap, no overlap, ascending.
    #[test]
    fn partition_covers_exactly() {
        // the 18560-element case (the tiny preset's gradient) is the real
        // shape but makes Miri crawl; the small lengths cover the same
        // boundary arithmetic under the interpreter
        let big = if cfg!(miri) { 1856 } else { 18560 };
        for n in [0usize, 1, 2, 3, 7, 64, 1003, big] {
            for target in [1usize, 2, 3, 5, 64, 1000, n.max(1), n + 7] {
                let plan = BucketPlan::new(n, target);
                assert_eq!(plan.total_len(), n);
                assert!(plan.len() >= 1, "n={n} target={target}");
                let mut expect = 0;
                for (i, b) in plan.iter().enumerate() {
                    assert_eq!(b.index, i, "n={n} target={target}");
                    assert_eq!(b.lo, expect, "no gap/overlap: n={n} target={target}");
                    assert!(b.hi >= b.lo && b.hi <= n);
                    assert!(b.len() <= target, "n={n} target={target}");
                    // every bucket except the last is exactly `target`
                    if i + 1 < plan.len() {
                        assert_eq!(b.len(), target, "n={n} target={target}");
                    }
                    expect = b.hi;
                }
                assert_eq!(expect, n, "tiles the whole vector: n={n} target={target}");
            }
        }
    }

    #[test]
    fn degenerate_plans() {
        // empty vector: one empty bucket, still a valid (trivial) plan
        let empty = BucketPlan::new(0, 8);
        assert_eq!(empty.len(), 1);
        assert!(empty.is_empty());
        assert!(empty.get(0).is_empty());
        // target 0 behaves as 1
        let ones = BucketPlan::new(3, 0);
        assert_eq!(ones.len(), 3);
        assert!(ones.iter().all(|b| b.len() == 1));
        // target beyond the vector: a single covering bucket
        let single = BucketPlan::new(5, 100);
        assert_eq!(single.len(), 1);
        assert_eq!((single.get(0).lo, single.get(0).hi), (0, 5));
        assert!(!single.get(0).is_empty());
    }

    #[test]
    fn for_bytes_converts_elements() {
        // 16 bytes = 4 f32 elements per bucket
        let plan = BucketPlan::for_bytes(10, 16);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.get(0).len(), 4);
        // fewer than 4 bytes still holds one element per bucket
        assert_eq!(BucketPlan::for_bytes(3, 1).len(), 3);
    }
}
