# L1 correctness: Pallas `pair_exp_rowsum` vs the pure-jnp oracle.
#
# hypothesis sweeps shapes / dtypes / temperature scales / block shapes and
# asserts allclose for the forward value AND for every gradient (a, b, tau)
# through the custom_vjp. This is the core correctness signal for the stack.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.contrastive import pair_exp_rowsum, _pick_blocks
from compile.kernels.ref import pair_exp_rowsum_ref

jax.config.update("jax_enable_x64", False)


def _make_inputs(m, n, d, seed, tau_lo=0.03, tau_hi=1.0):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, d)).astype(np.float32)
    b = rng.standard_normal((n, d)).astype(np.float32)
    a /= np.linalg.norm(a, axis=-1, keepdims=True) + 1e-12
    b /= np.linalg.norm(b, axis=-1, keepdims=True) + 1e-12
    diag = rng.integers(0, n, size=(m,)).astype(np.int32)
    tau = rng.uniform(tau_lo, tau_hi, size=(m,)).astype(np.float32)
    w = rng.standard_normal((m,)).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b), jnp.asarray(diag), jnp.asarray(tau), jnp.asarray(w)


def _assert_close(x, y, rtol=3e-5, atol=3e-5):
    np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    n=st.integers(2, 96),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_forward_matches_ref(m, n, d, seed):
    a, b, diag, tau, _ = _make_inputs(m, n, d, seed)
    _assert_close(pair_exp_rowsum(a, b, diag, tau), pair_exp_rowsum_ref(a, b, diag, tau))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 32),
    n=st.integers(2, 64),
    d=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_gradients_match_ref(m, n, d, seed):
    a, b, diag, tau, w = _make_inputs(m, n, d, seed)
    f = lambda a_, b_, t_: jnp.sum(w * pair_exp_rowsum(a_, b_, diag, t_))
    fr = lambda a_, b_, t_: jnp.sum(w * pair_exp_rowsum_ref(a_, b_, diag, t_))
    got = jax.grad(f, argnums=(0, 1, 2))(a, b, tau)
    want = jax.grad(fr, argnums=(0, 1, 2))(a, b, tau)
    for x, y in zip(got, want):
        _assert_close(x, y, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bm,bn", [(8, 128), (16, 128), (32, 256), (128, 128)])
def test_block_shapes_equivalent(bm, bn):
    # The block-shape sweep used in the perf pass must not change numerics.
    a, b, diag, tau, _ = _make_inputs(40, 100, 32, seed=7)
    base = pair_exp_rowsum(a, b, diag, tau)
    _assert_close(pair_exp_rowsum(a, b, diag, tau, bm=bm, bn=bn), base, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    a, b, diag, tau, _ = _make_inputs(24, 48, 32, seed=3)
    g = pair_exp_rowsum(a.astype(dtype), b.astype(dtype), diag, tau)
    gr = pair_exp_rowsum_ref(a.astype(dtype), b.astype(dtype), diag, tau)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    _assert_close(g, gr, rtol=tol, atol=tol)
    assert g.dtype == jnp.float32  # accumulation stays f32


def test_scalar_tau_broadcast():
    a, b, diag, _, _ = _make_inputs(16, 32, 8, seed=11)
    t = jnp.full((16,), 0.05)
    _assert_close(pair_exp_rowsum(a, b, diag, t), pair_exp_rowsum_ref(a, b, diag, t))


def test_permutation_equivariance():
    # Permuting candidate rows (and remapping diag_idx) must not change g.
    a, b, diag, tau, _ = _make_inputs(12, 30, 16, seed=5)
    perm = np.random.default_rng(0).permutation(30)
    inv = np.argsort(perm)
    g1 = pair_exp_rowsum(a, b, diag, tau)
    g2 = pair_exp_rowsum(a, b[perm], jnp.asarray(inv)[diag], tau)
    _assert_close(g1, g2, rtol=1e-6, atol=1e-6)


def test_positive_outputs():
    a, b, diag, _, _ = _make_inputs(8, 16, 8, seed=13)
    g_hi = pair_exp_rowsum(a, b, diag, jnp.full((8,), 0.5))
    g_lo = pair_exp_rowsum(a, b, diag, jnp.full((8,), 0.05))
    assert bool(jnp.all(g_hi > 0)) and bool(jnp.all(g_lo > 0))


def test_diag_exclusion():
    # g must exclude the positive-pair term: with diag_idx = arange, the
    # excluded entry is exp(0) = 1, so g == (full row sum - 1)/(N-1).
    a, b, _, tau, _ = _make_inputs(6, 12, 8, seed=17)
    diag = jnp.arange(6, dtype=jnp.int32)
    g1 = pair_exp_rowsum(a, b, diag, tau)
    s = a @ b.T
    sd = jnp.take_along_axis(s, diag[:, None], axis=1)[:, 0]
    full = jnp.sum(jnp.exp((s - sd[:, None]) / tau[:, None]), axis=1)
    manual = (full - 1.0) / (12 - 1)
    _assert_close(g1, manual, rtol=1e-5, atol=1e-5)


def test_pick_blocks_bounds():
    for m, n in [(1, 2), (7, 130), (128, 1024), (1000, 3)]:
        bm, bn = _pick_blocks(m, n, None, None)
        assert bm % 8 == 0 and bn % 128 == 0
        assert bm <= 128 and bn <= 256


def test_jit_compatible():
    a, b, diag, tau, _ = _make_inputs(16, 32, 16, seed=23)
    jf = jax.jit(lambda a_, b_: pair_exp_rowsum(a_, b_, diag, tau))
    _assert_close(jf(a, b), pair_exp_rowsum_ref(a, b, diag, tau))
