//! Elastic resume (DESIGN.md §9): loading a checkpoint written at world
//! size K into a run at world size K′ ≠ K.
//!
//! Per-sample state (`u`, individual τ) lives in *shard-local* order, but
//! every shard-local position maps to a unique global sample index under
//! the strided partition (`global = rank + pos·K`,
//! see [`crate::data::ShardLoader`]). Re-sharding therefore walks the new
//! rank's shard and pulls each global index's state from whichever old
//! rank owned it — exact, no interpolation. Replicated scalar state
//! (global τ) is taken from old rank 0 (all ranks held identical copies).
//! Optimizer state re-partitions through the same `ceil(P/K)` chunking
//! the sharded reduction uses ([`crate::comm::chunk_bounds`]).
//!
//! The one thing that cannot be mapped is the loaders' *positions*: the
//! shards themselves changed, so the resized run restarts its loaders at
//! the checkpoint's epoch (deterministically, via
//! [`crate::data::ShardLoader::advance_to_epoch`]). Same-world resume
//! restores loader positions exactly and stays bitwise.

use anyhow::{ensure, Result};

use crate::config::OptimizerKind;
use crate::coordinator::IndividualTauState;
use crate::data::shard_len_for;
use crate::optim::OptimState;

use super::snapshot::{Checkpoint, RankState, TauCkpt};

/// Rebuild `new_rank`'s state (of a `new_world`-worker run) from a
/// checkpoint written at a different world size, through the
/// global-index mapping.
///
/// Each caller loads every old rank's state independently — K reads per
/// new rank, K·K′ for a full restore. That mirrors a real multi-process
/// restore, where each worker only has the filesystem in common with its
/// peers, and elastic resume happens once per session; if resize restore
/// time ever matters, memoizing the old-rank states inside
/// [`Checkpoint`] is the lever.
pub fn resize_rank_state(
    ck: &Checkpoint,
    new_rank: usize,
    new_world: usize,
) -> Result<RankState> {
    let meta = ck.meta();
    let old_world = meta.world;
    let n = meta.n_train;
    ensure!(new_world > 0 && new_rank < new_world, "bad target rank/world");

    // pull every old rank's state once
    let old: Vec<RankState> =
        (0..old_world).map(|r| ck.load_rank_state(r)).collect::<Result<Vec<_>>>()?;

    // resume epoch: old rank 0's loader epoch (identical across ranks
    // whenever shard sizes divide evenly; the reference rank otherwise)
    let epoch = old[0].epoch;

    let new_len = shard_len_for(n, new_world, new_rank)?;
    let mut u1 = Vec::with_capacity(new_len);
    let mut u2 = Vec::with_capacity(new_len);
    let individual = matches!(old[0].tau, TauCkpt::Individual(_));
    let mut itau = IndividualTauState {
        tau1: Vec::new(),
        tau2: Vec::new(),
        m1: Vec::new(),
        v1: Vec::new(),
        m2: Vec::new(),
        v2: Vec::new(),
        t1: Vec::new(),
        t2: Vec::new(),
    };

    for new_pos in 0..new_len {
        let g = new_rank + new_pos * new_world; // global sample index
        let old_rank = g % old_world;
        let old_pos = g / old_world;
        let o = &old[old_rank];
        u1.push(o.u1[old_pos]);
        u2.push(o.u2[old_pos]);
        if individual {
            let TauCkpt::Individual(s) = &o.tau else {
                anyhow::bail!("rank {old_rank} checkpoint lacks individual-tau state");
            };
            itau.tau1.push(s.tau1[old_pos]);
            itau.tau2.push(s.tau2[old_pos]);
            itau.m1.push(s.m1[old_pos]);
            itau.v1.push(s.v1[old_pos]);
            itau.m2.push(s.m2[old_pos]);
            itau.v2.push(s.v2[old_pos]);
            itau.t1.push(s.t1[old_pos]);
            itau.t2.push(s.t2[old_pos]);
        }
    }

    let tau = if individual {
        TauCkpt::Individual(itau)
    } else {
        old[0].tau.clone() // replicated scalar state: any rank's copy
    };

    // topk error-feedback residuals are per-rank wire state; a resized
    // world has different per-rank selections anyway, so resume restarts
    // the codec from zero residuals (same as the live-shrink path)
    Ok(RankState { u1, u2, tau, loader: None, epoch, resid: None })
}

/// Reassemble a full optimizer state from per-rank shards written under
/// the sharded reduction (shard r covers `chunk_bounds(P, K, r)`; the
/// chunks tile `[0, P)` exactly).
pub fn concat_optimizer_shards(
    kind: OptimizerKind,
    shards: &[OptimState],
    n_params: usize,
) -> Result<OptimState> {
    ensure!(!shards.is_empty(), "no optimizer shards");
    let tc = OptimState::tensor_count(kind);
    let t = shards[0].t;
    let mut tensors = vec![Vec::with_capacity(n_params); tc];
    for (r, shard) in shards.iter().enumerate() {
        ensure!(
            shard.kind == kind && shard.tensors.len() == tc,
            "optimizer shard {r} has the wrong shape"
        );
        ensure!(
            shard.t == t,
            "optimizer shards disagree on the step counter ({} vs {t})",
            shard.t
        );
        let (lo, hi) = crate::comm::chunk_bounds(n_params, shards.len(), r);
        ensure!(
            shard.n() == hi - lo,
            "optimizer shard {r} covers {} params, chunk is {}",
            shard.n(),
            hi - lo
        );
        for (full, part) in tensors.iter_mut().zip(&shard.tensors) {
            full.extend_from_slice(part);
        }
    }
    for full in &tensors {
        ensure!(full.len() == n_params, "optimizer shards do not tile the parameter vector");
    }
    Ok(OptimState { kind, t, tensors })
}

/// Slice a full optimizer state down to one rank's chunk `[lo, hi)`.
pub fn slice_optimizer_state(full: &OptimState, lo: usize, hi: usize) -> OptimState {
    OptimState {
        kind: full.kind,
        t: full.t,
        tensors: full.tensors.iter().map(|t| t[lo..hi].to_vec()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_then_slice_is_identity() {
        let kind = OptimizerKind::AdamW;
        let p = 10; // K=4 chunks: 3,3,3,1
        let full = OptimState {
            kind,
            t: 5,
            tensors: vec![
                (0..p).map(|i| i as f32).collect(),
                (0..p).map(|i| -(i as f32)).collect(),
            ],
        };
        let shards: Vec<OptimState> = (0..4)
            .map(|r| {
                let (lo, hi) = crate::comm::chunk_bounds(p, 4, r);
                slice_optimizer_state(&full, lo, hi)
            })
            .collect();
        let back = concat_optimizer_shards(kind, &shards, p).unwrap();
        assert_eq!(back, full);
        // re-partition for K'=2
        let (lo, hi) = crate::comm::chunk_bounds(p, 2, 1);
        let half = slice_optimizer_state(&back, lo, hi);
        assert_eq!(half.n(), hi - lo);
        assert_eq!(half.tensors[0], full.tensors[0][lo..hi].to_vec());
    }

    #[test]
    fn concat_rejects_inconsistent_shards() {
        let kind = OptimizerKind::Lion;
        let mk = |n: usize, t: i64| OptimState { kind, t, tensors: vec![vec![0.0; n]] };
        // wrong tiling (chunks of 10 over 2 ranks must be 5+5)
        assert!(concat_optimizer_shards(kind, &[mk(4, 1), mk(6, 1)], 10).is_err());
        // step-counter disagreement
        assert!(concat_optimizer_shards(kind, &[mk(5, 1), mk(5, 2)], 10).is_err());
        assert!(concat_optimizer_shards(kind, &[], 10).is_err());
    }
}
