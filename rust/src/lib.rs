//! FastCLIP — a distributed CLIP-training framework reproducing
//! *"FastCLIP: A Suite of Optimization Techniques to Accelerate CLIP
//! Training with Limited Resources"* (Wei et al., 2024).
//!
//! Architecture (three layers, DESIGN.md §2):
//! * **L1/L2** (build time, Python): Pallas contrastive kernels + JAX CLIP
//!   model, AOT-lowered to HLO-text artifacts by `python/compile/aot.py` —
//!   OR, with the default native backend, the pure-Rust [`kernels`] and
//!   the embedding-table model of [`runtime::NativeBackend`] (no Python,
//!   no artifacts; DESIGN.md §10).
//! * **L3** (this crate): the distributed coordinator — worker topology,
//!   the paper's gradient-reduction strategy, inner-LR (γ) schedules,
//!   temperature rules v0–v3, optimizers, interconnect cost accounting,
//!   evaluation and the experiment harness, all written against the
//!   [`runtime::ComputeBackend`] trait (`--backend native|pjrt|auto`).
//!
//! Entry points: [`coordinator::Trainer`] for training (with periodic
//! snapshots and `--resume` through [`ckpt`], DESIGN.md §9; overlapped
//! bucketed gradient reduction via `--overlap`, DESIGN.md §11; bf16
//! storage + half-width gradient wire via `--precision`, DESIGN.md §12;
//! structured tracing via `--trace-out` + [`telemetry`], DESIGN.md §14),
//! [`bench`] for the paper's tables/figures, the `fastclip` CLI for both.

// The documented public surface (comm, ckpt, kernels, runtime) is gated
// by the CI `docs` job (RUSTDOCFLAGS="-D warnings" + doctests); modules
// outside it opt out locally until their own doc pass lands.
#![warn(missing_docs)]

pub mod bench;
pub mod ckpt;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod kernels;
pub mod lint;
pub mod optim;
pub mod output;
pub mod runtime;
pub mod telemetry;
pub mod util;

pub use config::TrainConfig;
pub use coordinator::{TrainResult, Trainer};
