//! End-to-end iteration benchmark — one bench per paper timing table:
//! full distributed iterations (encode → gathers → phase_g → step →
//! reduce → optimizer) per algorithm on the NATIVE backend, reporting
//! the Fig. 3 compute / pure-comm / overlap / others split plus real
//! iteration throughput, **serial vs overlapped** (DESIGN.md §11): every
//! algorithm runs once with `--overlap off` and once with the bucketed
//! pipeline on, and the report carries both rows plus the speedup.
//!
//! Runs on any machine (no artifacts). CI (`bench-smoke`) runs it in
//! `--quick` mode, writes `BENCH_iteration.json` and gates iteration
//! throughput against the committed baseline
//! (`benches/baseline/BENCH_iteration.json`, 25% floor; the overlap rows
//! are new and report-only until they join the baseline):
//!
//! ```text
//! cargo bench --bench bench_iteration -- --quick \
//!     --json BENCH_iteration.json \
//!     --baseline benches/baseline/BENCH_iteration.json --max-regress 0.25
//! ```

#[path = "harness.rs"]
mod harness;

use fastclip::comm::OverlapMode;
use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::runtime::BackendKind;
use fastclip::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.flag("quick");
    let steps: u32 = if quick { 12 } else { 32 };
    let repeats: usize = if quick { 3 } else { 5 };

    println!(
        "end-to-end native iterations (preset tiny, K=2, Bl=8; {steps} steps x {repeats} runs, \
         modeled 8x4 infiniband; serial vs overlapped reduction)\n"
    );
    println!(
        "{:<14} {:<8} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "algorithm", "mode", "iters/s", "total", "compute", "pure", "overlap", "others", "speedup"
    );

    let mut rows = Vec::new();
    for algo in Algorithm::all() {
        let make_cfg = |overlap: OverlapMode| {
            let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", algo);
            cfg.backend = BackendKind::Native;
            cfg.steps = steps;
            cfg.iters_per_epoch = 8;
            cfg.data.n_train = 256;
            cfg.data.n_eval = 16;
            cfg.lr.total_iters = steps;
            cfg.lr.warmup_iters = 2;
            cfg.nodes = 8;
            cfg.gpus_per_node = 4;
            cfg.overlap = overlap;
            // small buckets so the tiny preset's ~74 KB gradient actually
            // splits (the 4 MB default would pipeline as a single bucket)
            cfg.bucket_bytes = 8 << 10;
            cfg
        };
        // per mode: warmup run (thread pools, page faults), then timed
        // repeats; the MEDIAN run's throughput goes into the report
        let measure = |overlap: OverlapMode| -> anyhow::Result<(f64, fastclip::TrainResult)> {
            let _ = Trainer::new(make_cfg(overlap))?.run()?;
            let mut samples = Vec::with_capacity(repeats);
            let mut last = None;
            for _ in 0..repeats {
                let r = Trainer::new(make_cfg(overlap))?.run()?;
                samples.push(r.wall_s);
                last = Some(r);
            }
            samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            Ok((steps as f64 / samples[samples.len() / 2], last.expect("at least one run")))
        };
        let (serial_rate, serial_run) = measure(OverlapMode::Off)?;
        let (overlap_rate, overlap_run) = measure(OverlapMode::On)?;
        assert!(overlap_run.overlap && overlap_run.n_buckets > 1, "pipeline must engage");

        for (mode, rate, run, speedup) in [
            ("serial", serial_rate, &serial_run, None),
            ("overlap", overlap_rate, &overlap_run, Some(overlap_rate / serial_rate)),
        ] {
            let ms = run.timing.per_iter_ms();
            println!(
                "{:<14} {:<8} {:>10.1} {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>8}",
                algo.name(),
                mode,
                rate,
                ms.total,
                ms.compute,
                ms.comm_pure,
                ms.comm_overlap,
                ms.others,
                speedup.map_or(String::from("-"), |s| format!("{s:.2}x")),
            );
        }
        println!(
            "{:<14} {:<8} measured reduction: {:.1} us hidden / {:.1} us exposed per run",
            "", "", overlap_run.hidden_comm_us as f64, overlap_run.exposed_comm_us as f64
        );

        // the serial row keeps the historical name so the committed
        // baseline keeps gating it; overlap rows ride along report-only
        rows.push(harness::JsonRow {
            name: format!("iteration/{}", algo.id()),
            rate_per_sec: serial_rate,
            median_s: 1.0 / serial_rate,
        });
        rows.push(harness::JsonRow {
            name: format!("iteration/{}/overlap", algo.id()),
            rate_per_sec: overlap_rate,
            median_s: 1.0 / overlap_rate,
        });
    }

    harness::finalize_report("iteration", quick, &rows, &args)
}
