//! Checkpoint/resume subsystem (DESIGN.md §9).
//!
//! A FastCLIP run's state is strictly richer than a params-only
//! checkpoint: the Eq. (1) `u` inner estimators, per-sample learnable
//! temperatures with their Adam moments, the temperature-rule and
//! schedule positions, optimizer moments (replicated or per-rank shards,
//! matching the active gradient-reduction strategy), each worker's
//! `ShardLoader` cursor/order and RNG stream. This module persists *all*
//! of it — a versioned JSON manifest ([`manifest`]) plus raw
//! little-endian f32/u64 tensor blobs with per-blob FNV-1a integrity
//! hashes ([`blob`]) — and restores it bit-exactly: training N, then
//! snapshot → restore → M more steps is bitwise identical to training
//! N+M straight through (pinned by `tests/ckpt_resume.rs`).
//!
//! Snapshots are atomic (stage → write → `MANIFEST.json` last → rename,
//! [`snapshot`]) with a `keep_last` retention policy, and **elastic**: a
//! checkpoint written at world size K can resume at K′ by re-sharding the
//! per-sample state through the global-index mapping and re-partitioning
//! (or re-replicating) the optimizer shards ([`elastic`]) — a run can
//! lose or gain workers between sessions, which is exactly the
//! preemptible-cluster reality the paper's limited-resources premise
//! implies.
//!
//! Entry points: the trainer calls [`write_rank_state`]/[`finalize`]
//! periodically and [`restore_worker`] on `--resume`; the CLI exposes
//! `fastclip ckpt inspect|verify`.

pub mod blob;
pub mod elastic;
pub mod manifest;
pub mod snapshot;

pub use blob::{fnv1a64, BlobKind, BlobSpec};
pub use manifest::{CkptManifest, CkptMeta, CKPT_VERSION, MANIFEST_FILE};
pub use snapshot::{
    check_compatible, export_tau, finalize, latest, prepare_stage, restore_tau, restore_worker,
    stage_path, step_path, write_rank_state, Checkpoint, RankState, RestoredWorker, TauCkpt,
    VerifyReport,
};

/// Checkpoint activity of one finished run (rank-0 view), reported in
/// [`crate::coordinator::TrainResult`] and by the `exp ckpt` study.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CkptRunStats {
    /// snapshots written during the run
    pub snapshots: u32,
    /// total wall time spent writing them, seconds
    pub write_s: f64,
    /// wall time spent restoring state at startup, seconds
    pub restore_s: f64,
    /// step the run resumed from, if it resumed
    pub resumed_at: Option<u32>,
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::config::{Algorithm, TrainConfig};
    use crate::coordinator::{TauState, UState};
    use crate::data::ShardLoader;
    use crate::optim::{build, Segments};

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fastclip_ckpt_{name}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn cfg(algo: Algorithm) -> TrainConfig {
        let mut c = TrainConfig::new("unused", algo);
        c.data.n_train = 64;
        c
    }

    fn meta_for(cfg: &TrainConfig, step: u32, world: usize, n_params: usize) -> CkptMeta {
        CkptMeta::for_run(cfg, step, world, n_params, 4, "ring")
    }

    /// Pre-§12 checkpoints carry a hyper echo without the trailing
    /// ` prec=` field (they were all f32 runs): an f32 resume must still
    /// accept them, a bf16 resume must not, and a precision flip between
    /// current-format snapshots is rejected either way.
    #[test]
    fn legacy_echo_without_precision_resumes_under_f32_only() {
        let c = cfg(Algorithm::FastClipV1);
        let mut meta = meta_for(&c, 3, 2, 11);
        // simulate a PR-2..4-era manifest: strip the precision suffix
        meta.hyper = meta.hyper.strip_suffix(" prec=f32").unwrap().to_string();
        check_compatible(&meta, &c, 11).expect("legacy f32 checkpoint must stay resumable");
        let mut bf = c.clone();
        bf.precision = crate::kernels::Precision::Bf16;
        let err = check_compatible(&meta, &bf, 11).unwrap_err();
        assert!(format!("{err}").contains("hyper"), "{err}");
        // current-format echoes: precision drift is rejected both ways
        let meta_bf = meta_for(&bf, 3, 2, 11);
        assert!(check_compatible(&meta_bf, &c, 11).is_err());
        assert!(check_compatible(&meta_bf, &bf, 11).is_ok());
    }

    /// Full write→finalize→open→restore cycle for each temperature rule,
    /// asserting every piece of state survives bit-for-bit.
    #[test]
    fn snapshot_restore_roundtrip_all_tau_rules() {
        for algo in [Algorithm::FastClipV1, Algorithm::FastClipV3, Algorithm::FastClipV2] {
            let root = tmp(&format!("roundtrip_{}", algo.id()));
            let c = cfg(algo);
            let world = 2;
            let n_params = 11;
            let seg: Segments = vec![(0, 11)];

            // build live state on both ranks and move it off the origin
            let mut states = Vec::new();
            for rank in 0..world {
                let mut loader = ShardLoader::new(64, rank, world, 4, c.seed).unwrap();
                for _ in 0..11 {
                    loader.next_batch();
                }
                let mut ustate = UState::new(loader.shard_len());
                let pos: Vec<usize> = (0..loader.shard_len()).collect();
                let vals: Vec<f32> =
                    pos.iter().map(|&p| (rank * 100 + p) as f32 * 0.25).collect();
                let negs: Vec<f32> = vals.iter().map(|v| -v).collect();
                ustate.scatter(&pos, &vals, &negs);
                let mut tau = TauState::new(&c, loader.shard_len());
                match &mut tau {
                    TauState::Constant(_) => {}
                    TauState::Global(g) => {
                        for i in 0..5 {
                            g.step(0.1 * i as f32);
                        }
                    }
                    TauState::Individual(it) => {
                        it.update(&[1, 3], &[0.5, -0.5], &[-0.5, 0.5], 1e-2);
                    }
                }
                let mut opt = build(&c.optimizer, n_params, seg.clone());
                let mut p = vec![0.5f32; n_params];
                for t in 0..7 {
                    let g: Vec<f32> = (0..n_params).map(|i| ((t + i) as f32).sin()).collect();
                    opt.step(&mut p, &g, 1e-3);
                }
                states.push((loader, ustate, tau, opt, p));
            }

            // snapshot (replicated optimizer: rank 0 writes it); each
            // rank also banks distinct topk error-feedback residuals
            let resid_for = |rank: usize| -> Vec<f32> {
                (0..n_params).map(|i| (rank as f32 + 1.0) * (i as f32 - 4.5) * 1e-3).collect()
            };
            let stage = stage_path(&root, 11);
            prepare_stage(&stage).unwrap();
            for (rank, (loader, ustate, tau, opt, _)) in states.iter().enumerate() {
                let opt_state = opt.export_state();
                let opt_arg = if rank == 0 { Some((&opt_state, false)) } else { None };
                write_rank_state(&stage, rank, ustate, tau, loader, opt_arg, Some(&resid_for(rank)))
                    .unwrap();
            }
            let meta = meta_for(&c, 11, world, n_params);
            let final_dir = finalize(&root, &stage, &meta, &states[0].4, 3).unwrap();
            assert!(final_dir.ends_with("step_00000011"));
            assert!(!stage.exists(), "stage renamed away");

            // open via the root (resolves to latest) and restore
            let ck = Checkpoint::open(&root).unwrap();
            assert_eq!(ck.meta().step, 11);
            ck.verify().unwrap();
            check_compatible(ck.meta(), &c, n_params).unwrap();
            // exact same-world resume under a different batch size would
            // corrupt the restored loader cursor: rejected
            assert!(restore_worker(&ck, &c, 0, world, 8, false).is_err());
            for rank in 0..world {
                let r = restore_worker(&ck, &c, rank, world, 4, false).unwrap();
                let (loader, ustate, tau, opt, p) = &states[rank];
                assert_eq!(&r.params, p, "{}", algo.id());
                assert_eq!(r.start_step, 11);
                assert_eq!(r.ustate.parts().0, ustate.parts().0);
                assert_eq!(r.ustate.parts().1, ustate.parts().1);
                assert_eq!(export_tau(&r.tau), export_tau(tau), "{}", algo.id());
                assert_eq!(r.loader.export(), loader.export());
                assert_eq!(r.optim, opt.export_state());
                // per-rank residuals come back bitwise, tagged .resid
                assert_eq!(r.resid.as_deref(), Some(resid_for(rank).as_slice()));
            }
            // elastic resume restarts the codec from zero residuals
            let elastic = restore_worker(&ck, &c, 0, 1, 4, false).unwrap();
            assert!(elastic.resid.is_none(), "resized world must not inherit residuals");
            let _ = std::fs::remove_dir_all(&root);
        }
    }

    #[test]
    fn verify_detects_single_flipped_byte() {
        let root = tmp("flip");
        let c = cfg(Algorithm::FastClipV1);
        let loader = ShardLoader::new(64, 0, 1, 4, 0).unwrap();
        let ustate = UState::new(loader.shard_len());
        let tau = TauState::new(&c, loader.shard_len());
        let opt = build(&c.optimizer, 5, vec![(0, 5)]);
        let stage = stage_path(&root, 1);
        prepare_stage(&stage).unwrap();
        let os = opt.export_state();
        write_rank_state(&stage, 0, &ustate, &tau, &loader, Some((&os, false)), None).unwrap();
        let meta = CkptMeta { world: 1, step: 1, ..meta_for(&c, 1, 1, 5) };
        let dir = finalize(&root, &stage, &meta, &[0.25; 5], 0).unwrap();

        let ck = Checkpoint::open(&dir).unwrap();
        ck.verify().unwrap();

        // flip one byte in one blob
        let path = dir.join("u_rank0.f32");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[3] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let ck = Checkpoint::open(&dir).unwrap();
        let err = ck.verify().unwrap_err();
        assert!(format!("{err}").contains("integrity"), "{err}");
        // and the state-loading path refuses it too
        assert!(ck.load_rank_state(0).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn retention_keeps_last_n() {
        let root = tmp("retention");
        let c = cfg(Algorithm::FastClipV1);
        let loader = ShardLoader::new(64, 0, 1, 4, 0).unwrap();
        let ustate = UState::new(loader.shard_len());
        let tau = TauState::new(&c, loader.shard_len());
        let opt = build(&c.optimizer, 3, vec![(0, 3)]);
        for step in [2u32, 4, 6, 8] {
            let stage = stage_path(&root, step);
            prepare_stage(&stage).unwrap();
            let os = opt.export_state();
            write_rank_state(&stage, 0, &ustate, &tau, &loader, Some((&os, false)), None).unwrap();
            let meta = CkptMeta { step, ..meta_for(&c, step, 1, 3) };
            finalize(&root, &stage, &meta, &[1.0; 3], 2).unwrap();
        }
        assert!(!step_path(&root, 2).exists());
        assert!(!step_path(&root, 4).exists());
        assert!(step_path(&root, 6).exists());
        assert!(step_path(&root, 8).exists());
        let latest_dir = latest(&root).unwrap().unwrap();
        assert!(latest_dir.ends_with("step_00000008"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn finalize_sweeps_debris_and_replaces_without_deleting_first() {
        let root = tmp("debris");
        let c = cfg(Algorithm::FastClipV1);
        let loader = ShardLoader::new(64, 0, 1, 4, 0).unwrap();
        let ustate = UState::new(loader.shard_len());
        let tau = TauState::new(&c, loader.shard_len());
        let opt = build(&c.optimizer, 3, vec![(0, 3)]);
        let snap = |step: u32, val: f32| {
            let stage = stage_path(&root, step);
            prepare_stage(&stage).unwrap();
            let os = opt.export_state();
            write_rank_state(&stage, 0, &ustate, &tau, &loader, Some((&os, false)), None).unwrap();
            finalize(&root, &stage, &meta_for(&c, step, 1, 3), &[val; 3], 0).unwrap()
        };
        // a stale stage from a "crashed" earlier run at an unrelated step
        let stale = stage_path(&root, 777);
        std::fs::create_dir_all(&stale).unwrap();
        std::fs::write(stale.join("junk.f32"), [0u8; 4]).unwrap();

        let dir = snap(2, 1.0);
        assert!(!stale.exists(), "stale stage swept by the next snapshot");

        // re-finalizing the same step replaces the checkpoint and leaves
        // no .old_step_* debris behind
        snap(2, 2.0);
        let ck = Checkpoint::open(&dir).unwrap();
        assert_eq!(ck.load_params().unwrap(), vec![2.0; 3]);
        assert!(!root.join(".old_step_00000002").exists());
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn check_compatible_rejects_mismatches() {
        let c = cfg(Algorithm::FastClipV3);
        let meta = meta_for(&c, 1, 2, 9);
        check_compatible(&meta, &c, 9).unwrap();
        let mut other = cfg(Algorithm::FastClipV1);
        assert!(check_compatible(&meta, &other, 9).is_err(), "algorithm");
        other = cfg(Algorithm::FastClipV3);
        assert!(check_compatible(&meta, &other, 10).is_err(), "n_params");
        other.seed = 99;
        assert!(check_compatible(&meta, &other, 9).is_err(), "seed");
        other = cfg(Algorithm::FastClipV3);
        other.data.n_train = 128;
        assert!(check_compatible(&meta, &other, 9).is_err(), "n_train");
        // drifted update-driving hyperparameters are rejected too
        other = cfg(Algorithm::FastClipV3);
        other.tau_lr *= 2.0;
        assert!(check_compatible(&meta, &other, 9).is_err(), "hyper drift");
        other = cfg(Algorithm::FastClipV3);
        other.lr.total_iters = 999;
        assert!(check_compatible(&meta, &other, 9).is_err(), "lr schedule drift");
    }

    #[test]
    fn open_errors_without_checkpoints() {
        let root = tmp("empty");
        assert!(Checkpoint::open(&root).is_err());
        assert!(latest(&root).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&root);
    }
}
