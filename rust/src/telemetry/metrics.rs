//! The metrics registry: counters, gauges and fixed-bucket histograms
//! behind string names, serialized into one `"metrics"` JSONL event at
//! the end of a run (DESIGN.md §14).
//!
//! The registry absorbs the bespoke aggregate structs —
//! [`CommStatsSnapshot`](crate::comm::CommStatsSnapshot) and
//! [`TimeBreakdown`](crate::coordinator::TimeBreakdown) — as
//! first-class instruments, so the JSONL trail carries the same
//! quantities the in-process report prints (`comm.*` counters,
//! `time.*` gauges), plus instruments those structs never had:
//! bucket-queue depth, fault-event counts, heartbeat counts.
//!
//! Names are dotted paths (`comm.grad_wire_bytes`,
//! `overlap.max_queue_depth`); the registry is internally locked so any
//! thread may record, but in practice only the lead worker writes it,
//! once, after the workers join.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::comm::CommStatsSnapshot;
use crate::coordinator::TimeBreakdown;
use crate::util::Json;

/// A fixed-bucket histogram: `counts[i]` observations fell in
/// `(bounds[i-1], bounds[i]]` (first bucket: `<= bounds[0]`), with one
/// overflow bucket above the last bound.
#[derive(Debug, Clone)]
struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    n: u64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        Histogram { bounds: bounds.to_vec(), counts: vec![0; bounds.len() + 1], sum: 0.0, n: 0 }
    }

    fn observe(&mut self, v: f64) {
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
        self.sum += v;
        self.n += 1;
    }
}

/// Registry of named counters (monotone `u64`), gauges (`f64`
/// last-write-wins) and fixed-bucket histograms.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `v` to the counter `name` (created at zero on first use).
    pub fn counter_add(&self, name: &str, v: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += v;
    }

    /// Set the gauge `name` to `v` (last write wins).
    pub fn gauge_set(&self, name: &str, v: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    /// Declare the histogram `name` with the given ascending upper
    /// bucket bounds (plus an implicit overflow bucket). Re-declaring
    /// an existing histogram is a no-op.
    pub fn hist_declare(&self, name: &str, bounds: &[f64]) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(bounds));
    }

    /// Record one observation into the histogram `name`. Undeclared
    /// names get a default power-of-ten µs-scale bucket layout.
    pub fn observe(&self, name: &str, v: f64) {
        const DEFAULT_BOUNDS: [f64; 7] = [1.0, 10.0, 100.0, 1e3, 1e4, 1e5, 1e6];
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Histogram::new(&DEFAULT_BOUNDS))
            .observe(v);
    }

    /// Absorb a [`CommStatsSnapshot`] as `comm.*` counters — payload
    /// bytes per collective, op count, gradient/parameter wire bytes
    /// (chosen and naive baseline), and the measured hidden/exposed
    /// overlap microseconds.
    pub fn absorb_comm(&self, s: &CommStatsSnapshot) {
        self.counter_add("comm.all_gather_bytes", s.all_gather_bytes);
        self.counter_add("comm.all_reduce_bytes", s.all_reduce_bytes);
        self.counter_add("comm.reduce_scatter_bytes", s.reduce_scatter_bytes);
        self.counter_add("comm.broadcast_bytes", s.broadcast_bytes);
        self.counter_add("comm.payload_bytes", s.payload_bytes());
        self.counter_add("comm.ops", s.ops);
        self.counter_add("comm.grad_wire_bytes", s.grad_wire_bytes);
        self.counter_add("comm.grad_wire_bytes_naive", s.grad_wire_bytes_naive);
        self.counter_add("comm.param_wire_bytes", s.param_wire_bytes);
        self.counter_add("comm.featgrad_wire_bytes", s.featgrad_wire_bytes);
        self.counter_add("comm.hidden_comm_us", s.hidden_comm_us);
        self.counter_add("comm.exposed_comm_us", s.exposed_comm_us);
    }

    /// Absorb a [`TimeBreakdown`] as `time.*` gauges (seconds), the
    /// Fig.-3 split: compute / total / overlapped / pure communication
    /// / others, the measured hidden/exposed seconds, and the iteration
    /// count.
    pub fn absorb_timing(&self, t: &TimeBreakdown) {
        self.gauge_set("time.compute_s", t.compute_s);
        self.gauge_set("time.comm_total_s", t.comm_total_s);
        self.gauge_set("time.comm_overlap_s", t.comm_overlap_s);
        self.gauge_set("time.comm_pure_s", t.comm_pure_s);
        self.gauge_set("time.others_s", t.others_s);
        self.gauge_set("time.overlap_hidden_s", t.overlap_hidden_s);
        self.gauge_set("time.overlap_exposed_s", t.overlap_exposed_s);
        self.gauge_set("time.iterations", t.iterations as f64);
        if let Some(f) = t.hidden_fraction() {
            self.gauge_set("time.hidden_fraction", f);
        }
    }

    /// Serialize every instrument:
    /// `{"counters": {..}, "gauges": {..}, "histograms": {name:
    /// {"bounds": [..], "counts": [..], "sum": s, "n": n}}}`.
    pub fn to_json(&self) -> Json {
        let counters = self.counters.lock().unwrap();
        let gauges = self.gauges.lock().unwrap();
        let hists = self.histograms.lock().unwrap();
        let mut c = Json::obj(vec![]);
        for (k, v) in counters.iter() {
            c.set(k, Json::num(*v as f64));
        }
        let mut g = Json::obj(vec![]);
        for (k, v) in gauges.iter() {
            g.set(k, Json::num(*v));
        }
        let mut h = Json::obj(vec![]);
        for (k, v) in hists.iter() {
            h.set(
                k,
                Json::obj(vec![
                    ("bounds", Json::arr(v.bounds.iter().map(|&b| Json::num(b)))),
                    ("counts", Json::arr(v.counts.iter().map(|&c| Json::num(c as f64)))),
                    ("sum", Json::num(v.sum)),
                    ("n", Json::num(v.n as f64)),
                ]),
            );
        }
        Json::obj(vec![("counters", c), ("gauges", g), ("histograms", h)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms() {
        let m = MetricsRegistry::new();
        m.counter_add("a.b", 3);
        m.counter_add("a.b", 4);
        m.gauge_set("g", 1.5);
        m.gauge_set("g", 2.5);
        m.hist_declare("h", &[10.0, 100.0]);
        for v in [5.0, 50.0, 500.0, 7.0] {
            m.observe("h", v);
        }
        let j = m.to_json();
        assert_eq!(j.get("counters").unwrap().get("a.b").unwrap().as_usize().unwrap(), 7);
        assert_eq!(j.get("gauges").unwrap().get("g").unwrap().as_f64().unwrap(), 2.5);
        let h = j.get("histograms").unwrap().get("h").unwrap();
        let bins = h.get("counts").unwrap().as_arr().unwrap();
        let counts: Vec<usize> = bins.iter().map(|c| c.as_usize().unwrap()).collect();
        assert_eq!(counts, vec![2, 1, 1]);
        assert_eq!(h.get("n").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn absorbs_comm_and_timing() {
        let m = MetricsRegistry::new();
        let s = CommStatsSnapshot {
            all_gather_bytes: 100,
            grad_wire_bytes: 40,
            ..Default::default()
        };
        m.absorb_comm(&s);
        let t = TimeBreakdown { compute_s: 2.0, iterations: 4, ..Default::default() };
        m.absorb_timing(&t);
        let j = m.to_json();
        let c = j.get("counters").unwrap();
        assert_eq!(c.get("comm.all_gather_bytes").unwrap().as_usize().unwrap(), 100);
        assert_eq!(c.get("comm.payload_bytes").unwrap().as_usize().unwrap(), 100);
        assert_eq!(c.get("comm.grad_wire_bytes").unwrap().as_usize().unwrap(), 40);
        let g = j.get("gauges").unwrap();
        assert_eq!(g.get("time.compute_s").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(g.get("time.iterations").unwrap().as_f64().unwrap(), 4.0);
    }
}
