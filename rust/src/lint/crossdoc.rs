//! Cross-doc integrity: every `DESIGN.md §N` reference in code, tests,
//! benches and the READMEs must resolve to a real `## §N` section of
//! `DESIGN.md`, and every section must be referenced from somewhere
//! outside `DESIGN.md` itself (orphans warn — a section nothing points
//! at is either dead or its references rotted away).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::source::SourceFile;
use super::{Finding, Severity};

const REF_NEEDLE: &str = "DESIGN.md \u{a7}"; // "DESIGN.md §"

/// Parse `## §N` headings out of DESIGN.md text: section number → 1-based
/// heading line.
fn design_sections(text: &str) -> BTreeMap<u32, usize> {
    let mut out = BTreeMap::new();
    for (idx, line) in text.lines().enumerate() {
        let Some(rest) = line.strip_prefix("## \u{a7}") else {
            continue;
        };
        let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
        if let Ok(n) = digits.parse::<u32>() {
            out.entry(n).or_insert(idx + 1);
        }
    }
    out
}

/// Extract every `DESIGN.md §N` reference from one line.
fn refs_in_line(line: &str) -> Vec<u32> {
    let mut out = Vec::new();
    for at in super::source::find_all(line, REF_NEEDLE) {
        let digits: String = line[at + REF_NEEDLE.len()..]
            .chars()
            .take_while(|c| c.is_ascii_digit())
            .collect();
        if let Ok(n) = digits.parse::<u32>() {
            out.push(n);
        }
    }
    out
}

/// Run the cross-doc checks over the scanned sources plus the markdown
/// docs. Skipped entirely when the tree has no `DESIGN.md`.
pub fn check(root: &Path, sources: &[SourceFile], findings: &mut Vec<Finding>) -> Result<()> {
    let design_path = root.join("DESIGN.md");
    if !design_path.is_file() {
        return Ok(());
    }
    let design = std::fs::read_to_string(&design_path)
        .with_context(|| format!("reading {}", design_path.display()))?;
    let sections = design_sections(&design);
    let mut referenced: Vec<u32> = Vec::new();

    let mut check_line = |rel: &str, idx: usize, line: &str, findings: &mut Vec<Finding>| {
        for n in refs_in_line(line) {
            if sections.contains_key(&n) {
                if rel != "DESIGN.md" {
                    referenced.push(n);
                }
            } else {
                findings.push(Finding {
                    rule: "doc-dangling-ref",
                    severity: Severity::Error,
                    file: rel.to_string(),
                    line: idx + 1,
                    message: format!(
                        "DESIGN.md \u{a7}{n} does not resolve to any `## \u{a7}N` section"
                    ),
                });
            }
        }
    };

    for sf in sources {
        for (idx, line) in sf.raw.iter().enumerate() {
            check_line(&sf.rel, idx, line, findings);
        }
    }
    for md in ["README.md", "rust/benches/baseline/README.md", "DESIGN.md"] {
        let p = root.join(md);
        if !p.is_file() {
            continue;
        }
        let text = std::fs::read_to_string(&p).with_context(|| format!("reading {}", p.display()))?;
        for (idx, line) in text.lines().enumerate() {
            check_line(md, idx, line, findings);
        }
    }

    for (n, heading_line) in &sections {
        if !referenced.contains(n) {
            findings.push(Finding {
                rule: "doc-orphan-section",
                severity: Severity::Warning,
                file: "DESIGN.md".to_string(),
                line: *heading_line,
                message: format!(
                    "\u{a7}{n} is referenced from no code, test or README; \
                     link it or fold it into a live section"
                ),
            });
        }
    }
    Ok(())
}
