//! Native kernel micro-benchmarks (DESIGN.md §10): blocked GEMM, the
//! fused masked-exp row-sum (forward + both backward sides), row
//! L2-normalize and the embedding-table encoder, at 1 and 2 kernel
//! threads — the per-kernel complement of `bench_iteration`.
//!
//! CI (`bench-smoke`) runs `--quick` and uploads `BENCH_kernels.json`;
//! pass `--baseline <file>` to gate like the iteration bench:
//!
//! ```text
//! cargo bench --bench bench_kernels -- --quick --json BENCH_kernels.json
//! ```

#[path = "harness.rs"]
mod harness;

use fastclip::kernels::{encoder, gemm, norm, softmax};
use fastclip::util::{Args, Rng};
use harness::{black_box, Bench, JsonRow};

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.flag("quick");
    let samples = if quick { 10 } else { 30 };
    let mut rows: Vec<JsonRow> = Vec::new();
    let mut push = |name: String, stats: harness::Stats| {
        rows.push(JsonRow {
            name,
            rate_per_sec: 1.0 / stats.median_s.max(1e-12),
            median_s: stats.median_s,
        });
    };

    println!("native kernel micro-benchmarks ({} samples each)\n", samples);

    // ---- GEMM: the encoder/weight-gradient shapes plus a square tile ----
    for (m, k, n) in [(8usize, 32usize, 64usize), (128, 128, 128)] {
        let a = randn(m * k, 1);
        let b = randn(k * n, 2);
        let mut c = vec![0.0f32; m * n];
        for threads in [1usize, 2] {
            let s = Bench::new(format!("gemm {m}x{k}x{n} t{threads}"))
                .samples(samples)
                .run(|| {
                    gemm::matmul(&a, &b, &mut c, m, k, n, threads);
                    black_box(c[0]);
                });
            push(format!("gemm/{m}x{k}x{n}/t{threads}"), s);
        }
    }

    // ---- fused masked exp row-sum: the Bl x Bg contrastive hot-spot ----
    for (m, n, d) in [(8usize, 16usize, 64usize), (64, 128, 128)] {
        let a = randn(m * d, 3);
        let b = randn(n * d, 4);
        let diag: Vec<isize> = (0..m).map(|i| (i % n) as isize).collect();
        let sd = vec![0.9f32; m];
        let tau = vec![0.05f32; m];
        let gbar = vec![0.4f32; m];
        let denom = (n - 1) as f32;
        for threads in [1usize, 2] {
            let s = Bench::new(format!("exp_rowsum fwd {m}x{n}x{d} t{threads}"))
                .samples(samples)
                .run(|| {
                    black_box(softmax::masked_exp_rowsum(
                        &a, &b, &diag, &sd, &tau, denom, m, n, d, threads,
                    ));
                });
            push(format!("exp_rowsum_fwd/{m}x{n}x{d}/t{threads}"), s);
            let s = Bench::new(format!("exp_rowsum bwd {m}x{n}x{d} t{threads}"))
                .samples(samples)
                .run(|| {
                    black_box(softmax::masked_exp_rowsum_bwd_row(
                        &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, threads,
                    ));
                    black_box(softmax::masked_exp_rowsum_bwd_col(
                        &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, threads,
                    ));
                });
            push(format!("exp_rowsum_bwd/{m}x{n}x{d}/t{threads}"), s);
        }
    }

    // ---- row L2-normalize fwd+bwd ----
    {
        let (m, d) = (64usize, 128usize);
        let x = randn(m * d, 5);
        let dy = randn(m * d, 6);
        for threads in [1usize, 2] {
            let s = Bench::new(format!("l2_normalize {m}x{d} t{threads}"))
                .samples(samples)
                .run(|| {
                    let (y, norms) = norm::l2_normalize_fwd(&x, m, d, threads);
                    black_box(norm::l2_normalize_bwd(&x, &norms, &dy, m, d, threads));
                    black_box(y[0]);
                });
            push(format!("l2_normalize/{m}x{d}/t{threads}"), s);
        }
    }

    // ---- embedding-table encoder fwd+bwd (tiny-preset shapes) ----
    {
        let (bl, patches, pd, d, vocab, t_len) =
            (8usize, 16usize, 32usize, 64usize, 256usize, 16usize);
        let images = randn(bl * patches * pd, 7);
        let w = randn(pd * d, 8);
        let bias = randn(d, 9);
        let table = randn(vocab * d, 10);
        let mut rng = Rng::new(11);
        let texts: Vec<i32> = (0..bl * t_len).map(|_| rng.below(vocab) as i32).collect();
        let cot = randn(bl * d, 12);
        let s = Bench::new("encoder fwd+bwd tiny t1".to_string()).samples(samples).run(|| {
            let xbar = encoder::patch_mean(&images, bl, patches, pd);
            let pooled = encoder::image_fwd(&w, &bias, &xbar, bl, pd, d, 1);
            black_box(encoder::image_bwd(&xbar, &cot, bl, pd, d, 1));
            let t = encoder::text_fwd(&table, &bias, &texts, bl, t_len, vocab, d);
            black_box(encoder::text_bwd(&texts, &cot, bl, t_len, vocab, d));
            black_box((pooled[0], t[0]));
        });
        push("encoder/tiny/t1".to_string(), s);
    }

    harness::finalize_report("kernels", quick, &rows, &args)
}
