//! Small shared utilities: a deterministic, splittable PRNG (so synthetic
//! data is reproducible across platforms without external crates) and a few
//! numeric helpers used across modules.

/// SplitMix64 — tiny, fast, full-period, and trivially splittable.
/// Used everywhere randomness is needed so runs are bit-reproducible.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

/// A serializable snapshot of an [`Rng`]'s exact position in its stream
/// (checkpoint/resume, DESIGN.md §9). Restoring it reproduces the draw
/// sequence bit-for-bit, including the cached Box–Muller spare.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RngState {
    pub state: u64,
    /// bits of the cached second normal, if one is pending
    pub spare_bits: Option<u64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare: None }
    }

    /// Snapshot the generator's exact stream position.
    pub fn export(&self) -> RngState {
        RngState { state: self.state, spare_bits: self.spare.map(f64::to_bits) }
    }

    /// Rebuild a generator at a previously exported stream position.
    pub fn restore(s: RngState) -> Self {
        Self { state: s.state, spare: s.spare_bits.map(f64::from_bits) }
    }

    /// Derive an independent stream (e.g. per worker / per purpose).
    pub fn split(&self, stream: u64) -> Self {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        r.next_u64();
        r
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s as f32;
        }
        let (mut u1, u2) = (self.next_f64(), self.next_f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let t = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * t.sin());
        (r * t.cos()) as f32
    }

    /// Fill a slice with N(0, sigma^2).
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = self.normal() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Zipf-like class draw over [0, n): P(c) ∝ 1/(c+1)^s. Long-tailed like
    /// web image–text data; s=0 gives uniform.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        if s == 0.0 {
            return self.below(n);
        }
        // inverse-CDF on precomputable weights would be faster; n is small
        // (hundreds of classes), so a linear scan is fine here.
        let total: f64 = (1..=n).map(|c| (c as f64).powf(-s)).sum();
        let mut t = self.next_f64() * total;
        for c in 0..n {
            t -= ((c + 1) as f64).powf(-s);
            if t <= 0.0 {
                return c;
            }
        }
        n - 1
    }
}

/// L2-normalize rows of a (rows, d) row-major matrix in place.
pub fn l2_normalize_rows(x: &mut [f32], d: usize) {
    for row in x.chunks_mut(d) {
        let n = row.iter().map(|v| v * v).sum::<f32>().sqrt() + 1e-8;
        for v in row {
            *v /= n;
        }
    }
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f32>() / xs.len() as f32
    }
}

/// Sample standard deviation (0 for len < 2).
pub fn std_dev(xs: &[f32]) -> f32 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / (xs.len() - 1) as f32).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn export_restore_resumes_stream_bitwise() {
        let mut a = Rng::new(99);
        // advance into the stream, leaving a Box–Muller spare cached
        for _ in 0..7 {
            a.next_u64();
        }
        a.normal();
        let snap = a.export();
        let mut b = Rng::restore(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // normals too (exercises the spare)
        let mut a2 = Rng::new(5);
        a2.normal();
        let mut b2 = Rng::restore(a2.export());
        for _ in 0..32 {
            assert!(a2.normal() == b2.normal());
        }
    }

    #[test]
    fn rng_split_independent() {
        let root = Rng::new(1);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let xs: Vec<f32> = (0..200_000).map(|_| r.normal()).collect();
        let m = mean(&xs);
        let s = std_dev(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((s - 1.0).abs() < 0.02, "std {s}");
    }

    #[test]
    fn uniform_range_and_below() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let u = r.next_f64();
            assert!((0.0..1.0).contains(&u));
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            counts[r.zipf(10, 1.0)] += 1;
        }
        assert!(counts[0] > counts[9] * 3);
        assert_eq!(counts.iter().sum::<usize>(), 20_000);
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut x = vec![3.0, 4.0, 0.0, 5.0, 12.0, 0.0];
        l2_normalize_rows(&mut x, 3);
        for row in x.chunks(3) {
            let n: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
