//! Bitwise resume-equivalence tests for the checkpoint subsystem
//! (DESIGN.md §9): training N+M steps continuously must equal training
//! N → snapshot → restore → M, bit for bit, in parameters, `u` state and
//! τ state — for every step-graph variant of DESIGN.md §3 and every
//! gradient-reduction strategy — plus an elastic K=4 → K′=2 resume case
//! asserting exact re-sharding through the global-index mapping.
//!
//! The equivalence matrix runs on a *state-faithful simulated trainer*:
//! it evolves the real `ShardLoader` / `UState` / `TauState` / optimizer
//! objects exactly like `worker_loop` (rank-ordered summation mirrors the
//! collectives' bit-exact reduction order; the sharded strategy applies
//! per-chunk optimizers), with deterministic pseudo-gradients standing in
//! for the HLO step graphs, and goes through the real checkpoint
//! writer/reader. End-to-end `Trainer` resume tests run unconditionally
//! on the native backend (DESIGN.md §10) — real worker threads, real
//! collectives, real step compute, no artifacts.

use std::path::{Path, PathBuf};

use fastclip::ckpt::{self, CkptMeta};
use fastclip::comm::chunk_bounds;
use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::{TauState, Trainer, UState};
use fastclip::data::ShardLoader;
use fastclip::optim::{build, shard_segments, Optimizer, Segments};

const N_PARAMS: usize = 10; // K=4 chunks 3,3,3,1: exercises ragged tails
const N_TRAIN: usize = 64;
const BL: usize = 4;

fn sim_cfg(algo: Algorithm, total_steps: u32) -> TrainConfig {
    let mut cfg = TrainConfig::new("unused", algo);
    cfg.steps = total_steps;
    cfg.iters_per_epoch = 4; // epochs advance: γ schedules move
    cfg.lr.total_iters = total_steps;
    cfg.lr.warmup_iters = 2;
    cfg.data.n_train = N_TRAIN;
    cfg
}

/// Deterministic pseudo-gradient: a fixed mixing of step, rank, index and
/// a live state value, so every piece of restored state feeds the next
/// update — any restoration defect breaks bitwise equality downstream.
fn pseudo(t: u32, r: u32, i: u32, x: f32) -> f32 {
    let key = t.wrapping_mul(31).wrapping_add(r.wrapping_mul(17)).wrapping_add(i);
    ((key % 1024) as f32 * 0.013).sin() * 0.1 + x * 0.01
}

/// The simulated K-worker trainer (see module docs).
struct SimWorld {
    cfg: TrainConfig,
    k: usize,
    sharded: bool,
    reduce_id: &'static str,
    loaders: Vec<ShardLoader>,
    ustates: Vec<UState>,
    taus: Vec<TauState>,
    opts: Vec<Box<dyn Optimizer>>,
    params: Vec<Vec<f32>>,
    step: u32,
}

impl SimWorld {
    fn new(cfg: &TrainConfig, k: usize, reduce_id: &'static str) -> SimWorld {
        let sharded = reduce_id == "sharded";
        let segments: Segments = vec![(0, 7), (7, N_PARAMS - 7)]; // two leaves
        let mut loaders = Vec::new();
        let mut ustates = Vec::new();
        let mut taus = Vec::new();
        let mut opts = Vec::new();
        let mut params = Vec::new();
        for rank in 0..k {
            let loader = ShardLoader::new(cfg.data.n_train, rank, k, BL, cfg.seed).unwrap();
            ustates.push(UState::new(loader.shard_len()));
            taus.push(TauState::new(cfg, loader.shard_len()));
            loaders.push(loader);
            opts.push(if sharded {
                let (lo, hi) = chunk_bounds(N_PARAMS, k, rank);
                build(&cfg.optimizer, hi - lo, shard_segments(&segments, lo, hi))
            } else {
                build(&cfg.optimizer, N_PARAMS, segments.clone())
            });
            params.push((0..N_PARAMS).map(|i| 0.25 + i as f32 * 0.01).collect());
        }
        SimWorld {
            cfg: cfg.clone(),
            k,
            sharded,
            reduce_id,
            loaders,
            ustates,
            taus,
            opts,
            params,
            step: 0,
        }
    }

    fn one_step(&mut self) {
        let t = self.step;
        let epoch = t / self.cfg.iters_per_epoch.max(1);
        let gamma = if self.cfg.algorithm.forces_gamma_one() {
            1.0
        } else {
            self.cfg.gamma.value(epoch)
        };
        let lr = self.cfg.lr.value(t);
        let k = self.k;

        let batches: Vec<_> = (0..k).map(|r| self.loaders[r].next_batch()).collect();

        // "phase_g": Eq. (1)-shaped u update over the batch rows
        for r in 0..k {
            let b = &batches[r];
            let (u1, u2) = self.ustates[r].gather(&b.local_positions);
            let (t1, t2) = self.taus[r].rows(&b.local_positions);
            let mut u1n = Vec::with_capacity(BL);
            let mut u2n = Vec::with_capacity(BL);
            for (i, &g) in b.global_indices.iter().enumerate() {
                let x = self.params[r][g % N_PARAMS];
                let sig = pseudo(t, r as u32, g as u32, x);
                u1n.push((1.0 - gamma) * u1[i] + gamma * (sig + t1[i]));
                u2n.push((1.0 - gamma) * u2[i] + gamma * (0.5 * sig - t2[i]));
            }
            self.ustates[r].scatter(&b.local_positions, &u1n, &u2n);
        }

        // gradient + scalar contributions, summed in rank order exactly
        // like the collectives reduce them
        let mut grad = vec![0.0f32; N_PARAMS];
        let mut tau_grad = 0.0f32;
        for r in 0..k {
            let (mu1, mu2) = self.ustates[r].mean_u();
            let mt = self.taus[r].mean_tau();
            for (i, g) in grad.iter_mut().enumerate() {
                *g += pseudo(t, r as u32, i as u32, self.params[r][i]) * 0.1
                    + (mu1 - mu2) * 1e-3
                    + mt * 1e-3;
            }
            tau_grad += pseudo(t, r as u32, 9001, mu1 + mt);
        }

        // optimizer: replicated full-vector update vs sharded per-chunk
        // update + parameter "all-gather"
        if self.sharded {
            let mut new_params = self.params[0].clone();
            for r in 0..k {
                let (lo, hi) = chunk_bounds(N_PARAMS, k, r);
                let mut chunk = self.params[r][lo..hi].to_vec();
                self.opts[r].step(&mut chunk, &grad[lo..hi], lr);
                new_params[lo..hi].copy_from_slice(&chunk);
            }
            for r in 0..k {
                self.params[r].copy_from_slice(&new_params);
            }
        } else {
            for r in 0..k {
                self.opts[r].step(&mut self.params[r], &grad, lr);
            }
        }

        // temperature rule
        for r in 0..k {
            let b = &batches[r];
            match &mut self.taus[r] {
                TauState::Constant(_) => {}
                TauState::Global(gl) => gl.step(tau_grad),
                TauState::Individual(it) => {
                    let g1: Vec<f32> = b
                        .local_positions
                        .iter()
                        .map(|&p| pseudo(t, r as u32, p as u32, 0.1))
                        .collect();
                    let g2: Vec<f32> = g1.iter().map(|v| -v).collect();
                    it.update(&b.local_positions, &g1, &g2, self.cfg.tau_lr);
                }
            }
        }
        self.step += 1;
    }

    fn run_steps(&mut self, n: u32) {
        for _ in 0..n {
            self.one_step();
        }
    }

    fn meta(&self) -> CkptMeta {
        CkptMeta::for_run(&self.cfg, self.step, self.k, N_PARAMS, BL, self.reduce_id)
    }

    /// Snapshot through the real checkpoint writer (the trainer's exact
    /// protocol: stage, per-rank blobs, finalize with params + manifest).
    fn snapshot(&self, root: &Path) -> PathBuf {
        let stage = ckpt::stage_path(root, self.step);
        ckpt::prepare_stage(&stage).unwrap();
        for r in 0..self.k {
            let os = self.opts[r].export_state();
            let arg = if self.sharded || r == 0 { Some((&os, self.sharded)) } else { None };
            ckpt::write_rank_state(
                &stage,
                r,
                &self.ustates[r],
                &self.taus[r],
                &self.loaders[r],
                arg,
                None,
            )
            .unwrap();
        }
        ckpt::finalize(root, &stage, &self.meta(), &self.params[0], 3).unwrap()
    }

    /// A fresh world restored from a checkpoint through the real reader —
    /// `new_k` may differ from the snapshot's world size (elastic).
    fn restore(cfg: &TrainConfig, new_k: usize, reduce_id: &'static str, dir: &Path) -> SimWorld {
        let mut w = SimWorld::new(cfg, new_k, reduce_id);
        let ck = ckpt::Checkpoint::open(dir).unwrap();
        ckpt::check_compatible(ck.meta(), cfg, N_PARAMS).unwrap();
        for r in 0..new_k {
            let rw = ckpt::restore_worker(&ck, cfg, r, new_k, BL, w.sharded).unwrap();
            w.params[r] = rw.params;
            w.ustates[r] = rw.ustate;
            w.taus[r] = rw.tau;
            w.loaders[r] = rw.loader;
            w.opts[r].import_state(&rw.optim).unwrap();
        }
        w.step = ck.meta().step;
        w
    }

    fn assert_bitwise_eq(&self, other: &SimWorld) {
        assert_eq!(self.step, other.step);
        assert_eq!(self.k, other.k);
        for r in 0..self.k {
            let label = format!(
                "{} reduce={} rank {r}",
                self.cfg.algorithm.id(),
                self.reduce_id
            );
            assert_eq!(self.params[r], other.params[r], "params: {label}");
            assert_eq!(self.ustates[r].parts().0, other.ustates[r].parts().0, "u1: {label}");
            assert_eq!(self.ustates[r].parts().1, other.ustates[r].parts().1, "u2: {label}");
            assert_eq!(
                ckpt::export_tau(&self.taus[r]),
                ckpt::export_tau(&other.taus[r]),
                "tau: {label}"
            );
            assert_eq!(self.loaders[r].export(), other.loaders[r].export(), "loader: {label}");
            assert_eq!(
                self.opts[r].export_state(),
                other.opts[r].export_state(),
                "optimizer: {label}"
            );
        }
    }
}

fn tmp_root(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fastclip_resume_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// All seven Table-1 algorithms — covering the five step-graph variants
/// of DESIGN.md §3 (mbcl, gcl, gcl_v0, rgcl_i, rgcl_g) and all three
/// temperature rules.
const ALGOS: [Algorithm; 7] = [
    Algorithm::OpenClip,   // mbcl,   global learnable τ
    Algorithm::SogClr,     // gcl,    constant τ, constant γ
    Algorithm::ISogClr,    // rgcl_i, individual τ, constant γ
    Algorithm::FastClipV0, // gcl_v0, global learnable τ
    Algorithm::FastClipV1, // gcl,    constant τ, cosine γ
    Algorithm::FastClipV2, // rgcl_i, individual τ, cosine γ
    Algorithm::FastClipV3, // rgcl_g, global learnable τ
];

/// THE equivalence matrix: N+M continuous vs N → snapshot → restore → M,
/// for every algorithm variant × every reduction strategy, K=2.
#[test]
fn resume_is_bitwise_for_all_variants_and_reduce_strategies() {
    let (n, m) = (10u32, 7u32);
    for algo in ALGOS {
        for reduce_id in ["naive", "ring", "sharded"] {
            let cfg = sim_cfg(algo, n + m);
            let root = tmp_root(&format!("{}_{}", algo.id(), reduce_id));

            let mut continuous = SimWorld::new(&cfg, 2, reduce_id);
            continuous.run_steps(n + m);

            let mut first = SimWorld::new(&cfg, 2, reduce_id);
            first.run_steps(n);
            let dir = first.snapshot(&root);

            let mut resumed = SimWorld::restore(&cfg, 2, reduce_id, &dir);
            // the restored world must equal the one that wrote it...
            resumed.assert_bitwise_eq(&first);
            // ...and continue exactly like the uninterrupted run
            resumed.run_steps(m);
            resumed.assert_bitwise_eq(&continuous);

            // replicated-parameter sanity
            for r in 1..2 {
                assert_eq!(resumed.params[r], resumed.params[0]);
            }
            let _ = std::fs::remove_dir_all(&root);
        }
    }
}

/// Elastic resume K=4 → K′=2 (FastCLIP-v2: the richest state — individual
/// τ with per-sample Adam moments): every u/τ scalar must land exactly
/// where the global-index mapping says, and the optimizer state must
/// re-partition exactly; the resized world must keep training.
#[test]
fn elastic_resume_reshards_u_and_tau_exactly() {
    for reduce_id in ["ring", "sharded"] {
        let cfg = sim_cfg(Algorithm::FastClipV2, 24);
        let root = tmp_root(&format!("elastic_{reduce_id}"));
        let mut old = SimWorld::new(&cfg, 4, reduce_id);
        old.run_steps(9);
        let dir = old.snapshot(&root);

        let resumed = SimWorld::restore(&cfg, 2, reduce_id, &dir);
        assert_eq!(resumed.step, 9);

        // exact u/τ re-sharding through global = rank + pos·K
        for new_rank in 0..2usize {
            let (nu1, nu2) = resumed.ustates[new_rank].parts();
            let ntau = match ckpt::export_tau(&resumed.taus[new_rank]) {
                ckpt::TauCkpt::Individual(s) => s,
                other => panic!("expected individual tau, got {other:?}"),
            };
            assert_eq!(nu1.len(), N_TRAIN / 2);
            for new_pos in 0..nu1.len() {
                let g = new_rank + new_pos * 2; // global sample index
                let (old_rank, old_pos) = (g % 4, g / 4);
                let (ou1, ou2) = old.ustates[old_rank].parts();
                assert_eq!(nu1[new_pos], ou1[old_pos], "u1 at global {g}");
                assert_eq!(nu2[new_pos], ou2[old_pos], "u2 at global {g}");
                let otau = match ckpt::export_tau(&old.taus[old_rank]) {
                    ckpt::TauCkpt::Individual(s) => s,
                    _ => unreachable!(),
                };
                assert_eq!(ntau.tau1[new_pos], otau.tau1[old_pos], "tau1 at global {g}");
                assert_eq!(ntau.tau2[new_pos], otau.tau2[old_pos], "tau2 at global {g}");
                assert_eq!(ntau.m1[new_pos], otau.m1[old_pos], "m1 at global {g}");
                assert_eq!(ntau.v2[new_pos], otau.v2[old_pos], "v2 at global {g}");
                assert_eq!(ntau.t1[new_pos], otau.t1[old_pos], "t1 at global {g}");
                assert_eq!(ntau.t2[new_pos], otau.t2[old_pos], "t2 at global {g}");
            }
        }

        // parameters carry over exactly; optimizer state re-partitions
        // exactly (old full state == new full state)
        assert_eq!(resumed.params[0], old.params[0]);
        let old_full = full_optimizer_state(&old);
        let new_full = full_optimizer_state(&resumed);
        assert_eq!(old_full, new_full, "optimizer state re-partition (reduce={reduce_id})");

        // the resized world keeps training, loaders restarted at the
        // checkpoint's loader epoch (shard 16, batch 4 → 4 iters/epoch;
        // 9 steps land in epoch 2)
        assert_eq!(resumed.loaders[0].epoch(), old.loaders[0].epoch());
        assert_eq!(resumed.loaders[0].epoch(), 2);
        let mut resumed = resumed;
        resumed.run_steps(6);
        assert_eq!(resumed.step, 15);
        assert!(resumed.params[0].iter().all(|v| v.is_finite()));
        assert_eq!(resumed.params[0], resumed.params[1], "replication invariant");
        let _ = std::fs::remove_dir_all(&root);
    }
}

/// Flatten a world's optimizer state to the full parameter vector
/// (identity for replicated; chunk-concatenation for sharded).
fn full_optimizer_state(w: &SimWorld) -> Vec<Vec<f32>> {
    if !w.sharded {
        return w.opts[0].export_state().tensors;
    }
    let states: Vec<_> = (0..w.k).map(|r| w.opts[r].export_state()).collect();
    let tc = states[0].tensors.len();
    let mut out = vec![Vec::with_capacity(N_PARAMS); tc];
    for s in &states {
        for (full, part) in out.iter_mut().zip(&s.tensors) {
            full.extend_from_slice(part);
        }
    }
    out
}

/// Elastic resume can also *grow* the world: K=2 → K′=4.
#[test]
fn elastic_resume_grows_world() {
    let cfg = sim_cfg(Algorithm::FastClipV3, 20);
    let root = tmp_root("grow");
    let mut old = SimWorld::new(&cfg, 2, "sharded");
    old.run_steps(8);
    let dir = old.snapshot(&root);
    let mut grown = SimWorld::restore(&cfg, 4, "sharded", &dir);
    assert_eq!(grown.params[0], old.params[0]);
    // global τ is replicated scalar state: carried over exactly
    assert_eq!(ckpt::export_tau(&grown.taus[3]), ckpt::export_tau(&old.taus[0]));
    for new_rank in 0..4usize {
        let (nu1, _) = grown.ustates[new_rank].parts();
        for new_pos in 0..nu1.len() {
            let g = new_rank + new_pos * 4;
            let (ou1, _) = old.ustates[g % 2].parts();
            assert_eq!(nu1[new_pos], ou1[g / 2], "u1 at global {g}");
        }
    }
    grown.run_steps(4);
    assert_eq!(grown.step, 12);
    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------
// End-to-end Trainer resume on the native backend (DESIGN.md §10):
// runs unconditionally — no artifacts, no pjrt feature.
// ---------------------------------------------------------------------

fn trainer_cfg(algo: Algorithm, steps: u32) -> TrainConfig {
    let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", algo);
    cfg.backend = fastclip::runtime::BackendKind::Native;
    cfg.kernel_threads = 1;
    cfg.steps = steps;
    cfg.iters_per_epoch = 4;
    cfg.data.n_train = 64;
    cfg.data.n_eval = 32;
    cfg.data.n_classes = 8;
    cfg.lr.warmup_iters = 2;
    cfg.lr.total_iters = steps;
    cfg
}

#[test]
fn trainer_resume_bitwise_all_variants_and_reduces() {
    use fastclip::comm::{ReduceAlgo, ReduceStrategy};
    let (n, m) = (6u32, 4u32);
    for algo in ALGOS {
        for reduce in [ReduceAlgo::Naive, ReduceAlgo::Ring, ReduceAlgo::Sharded] {
            let root = tmp_root(&format!("trainer_{}_{}", algo.id(), reduce.id()));
            let mut base = trainer_cfg(algo, n + m);
            base.reduce = ReduceStrategy::Fixed(reduce);

            let continuous = Trainer::new(base.clone()).unwrap().run().unwrap();

            let mut leg1 = base.clone();
            leg1.steps = n; // schedules still span n+m (lr.total_iters)
            leg1.ckpt_dir = Some(root.to_string_lossy().into_owned());
            leg1.ckpt_every = n;
            let first = Trainer::new(leg1).unwrap().run().unwrap();
            assert_eq!(first.ckpt.snapshots, 1);

            let mut leg2 = base.clone();
            leg2.ckpt_dir = Some(root.to_string_lossy().into_owned());
            leg2.resume = Some("latest".to_string());
            let resumed = Trainer::new(leg2).unwrap().run().unwrap();
            assert_eq!(resumed.ckpt.resumed_at, Some(n));
            assert_eq!(resumed.history.len(), m as usize);

            assert_eq!(
                continuous.final_params,
                resumed.final_params,
                "{} reduce={}: resumed params must be bitwise equal",
                algo.id(),
                reduce.id()
            );
            // the resumed loss trajectory matches the continuous tail
            for (a, b) in continuous.history[n as usize..].iter().zip(&resumed.history) {
                assert_eq!(a.loss, b.loss, "{} reduce={}", algo.id(), reduce.id());
                assert_eq!(a.step, b.step);
                assert_eq!(a.tau, b.tau);
            }
            let _ = std::fs::remove_dir_all(&root);
        }
    }
}

/// Top-k error-feedback residuals ride the checkpoint as `Resid` blobs
/// (DESIGN.md §15): same-world resume must be bitwise — the resumed rank
/// seeds its `EfState` from `ef_rank{r}` so the dropped-coordinate
/// accumulators continue exactly where the snapshot left off. Covered
/// across every reduction algorithm × serial|overlap execution.
#[test]
fn trainer_resume_bitwise_topk_residuals_all_reduces_and_overlap() {
    use fastclip::comm::{OverlapMode, ReduceAlgo, ReduceStrategy, WireCodec};
    let (n, m) = (6u32, 4u32);
    for reduce in [ReduceAlgo::Naive, ReduceAlgo::Ring, ReduceAlgo::Sharded] {
        for overlap in [OverlapMode::Off, OverlapMode::On] {
            let label = format!("reduce={} overlap={}", reduce.id(), overlap.id());
            let root = tmp_root(&format!("topk_{}_{}", reduce.id(), overlap.id()));
            let mut base = trainer_cfg(Algorithm::FastClipV3, n + m);
            base.reduce = ReduceStrategy::Fixed(reduce);
            base.overlap = overlap;
            base.bucket_bytes = 1024; // several buckets when overlapped
            base.wire = Some(WireCodec::TopK);

            let continuous = Trainer::new(base.clone()).unwrap().run().unwrap();
            assert_eq!(continuous.wire, "topk", "{label}");

            let mut leg1 = base.clone();
            leg1.steps = n;
            leg1.ckpt_dir = Some(root.to_string_lossy().into_owned());
            leg1.ckpt_every = n;
            let first = Trainer::new(leg1).unwrap().run().unwrap();
            assert_eq!(first.ckpt.snapshots, 1, "{label}");

            let mut leg2 = base.clone();
            leg2.ckpt_dir = Some(root.to_string_lossy().into_owned());
            leg2.resume = Some("latest".to_string());
            let resumed = Trainer::new(leg2).unwrap().run().unwrap();
            assert_eq!(resumed.ckpt.resumed_at, Some(n), "{label}");

            // residual restoration defects would desync the EF carry and
            // break this equality within a step or two
            assert_eq!(
                continuous.final_params, resumed.final_params,
                "topk resume params must be bitwise equal: {label}"
            );
            assert_eq!(continuous.final_tau.to_bits(), resumed.final_tau.to_bits(), "{label}");
            for (a, b) in continuous.history[n as usize..].iter().zip(&resumed.history) {
                assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}: {label}", a.step);
            }
            let _ = std::fs::remove_dir_all(&root);
        }
    }
}

/// Loss sharding (DESIGN.md §16) composes with checkpointing. The shard
/// mode is deliberately *not* checkpoint state — a snapshot carries only
/// params/u/τ/loader/optimizer, all of which are bitwise identical under
/// either mode — so a snapshot written with `--loss-shard off` must
/// resume bitwise under `--loss-shard on` (and vice versa), and both
/// must match the uninterrupted sharded run.
#[test]
fn trainer_resume_bitwise_across_loss_shard_modes() {
    use fastclip::runtime::LossShardMode;
    let (n, m) = (6u32, 4u32);
    // FastClipV2 (rgcl_i): individual-τ state, the richest resume payload
    for (snap_mode, resume_mode) in [
        (LossShardMode::Off, LossShardMode::On),
        (LossShardMode::On, LossShardMode::Off),
        (LossShardMode::On, LossShardMode::On),
    ] {
        let label = format!("snap={} resume={}", snap_mode.id(), resume_mode.id());
        let root = tmp_root(&format!("shard_{}_{}", snap_mode.id(), resume_mode.id()));
        let mut base = trainer_cfg(Algorithm::FastClipV2, n + m);
        base.loss_shard = LossShardMode::On;
        let continuous = Trainer::new(base.clone()).unwrap().run().unwrap();
        assert!(continuous.loss_shard, "{label}");

        let mut leg1 = base.clone();
        leg1.loss_shard = snap_mode;
        leg1.steps = n;
        leg1.ckpt_dir = Some(root.to_string_lossy().into_owned());
        leg1.ckpt_every = n;
        let first = Trainer::new(leg1).unwrap().run().unwrap();
        assert_eq!(first.ckpt.snapshots, 1, "{label}");

        let mut leg2 = base.clone();
        leg2.loss_shard = resume_mode;
        leg2.ckpt_dir = Some(root.to_string_lossy().into_owned());
        leg2.resume = Some("latest".to_string());
        let resumed = Trainer::new(leg2).unwrap().run().unwrap();
        assert_eq!(resumed.ckpt.resumed_at, Some(n), "{label}");

        assert_eq!(
            continuous.final_params, resumed.final_params,
            "cross-mode resume params must be bitwise equal: {label}"
        );
        assert_eq!(continuous.final_tau.to_bits(), resumed.final_tau.to_bits(), "{label}");
        for (a, b) in continuous.history[n as usize..].iter().zip(&resumed.history) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {}: {label}", a.step);
        }
        let _ = std::fs::remove_dir_all(&root);
    }
}

/// Elastic resume (K=2 → K′=1) under `--loss-shard on`: the sharded
/// loss path re-derives its row/column slices from the new topology's
/// offsets, so the resized world keeps training with finite losses.
#[test]
fn trainer_elastic_resume_under_loss_shard() {
    use fastclip::runtime::LossShardMode;
    let root = tmp_root("trainer_elastic_shard");
    let mut leg1 = trainer_cfg(Algorithm::FastClipV3, 8);
    leg1.loss_shard = LossShardMode::On;
    leg1.steps = 4;
    leg1.ckpt_dir = Some(root.to_string_lossy().into_owned());
    leg1.ckpt_every = 4;
    Trainer::new(leg1).unwrap().run().unwrap();

    let mut leg2 = trainer_cfg(Algorithm::FastClipV3, 8);
    leg2.set_bundle("artifacts/tiny_k1_b16");
    leg2.loss_shard = LossShardMode::On;
    leg2.ckpt_dir = Some(root.to_string_lossy().into_owned());
    leg2.resume = Some("latest".to_string());
    let r = Trainer::new(leg2).unwrap().run().unwrap();
    assert!(r.loss_shard);
    assert_eq!(r.ckpt.resumed_at, Some(4));
    // K′=1: the exchange is a loopback — no featgrad wire traffic
    assert_eq!(r.featgrad_wire_bytes, 0);
    assert!(r.history.iter().all(|h| h.loss.is_finite()));
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn trainer_elastic_resume_k2_to_k1() {
    // K=2 topology writes the checkpoint; K=1 resumes it (elastic)
    let root = tmp_root("trainer_elastic");
    // schedules must span the same horizon as the resuming run (the
    // hyper echo in the manifest enforces this)
    let mut leg1 = trainer_cfg(Algorithm::FastClipV3, 8);
    leg1.steps = 4;
    leg1.ckpt_dir = Some(root.to_string_lossy().into_owned());
    leg1.ckpt_every = 4;
    Trainer::new(leg1).unwrap().run().unwrap();

    let mut leg2 = trainer_cfg(Algorithm::FastClipV3, 8);
    leg2.set_bundle("artifacts/tiny_k1_b16"); // native K=1, Bl=16
    leg2.ckpt_dir = Some(root.to_string_lossy().into_owned());
    leg2.resume = Some("latest".to_string());
    let r = Trainer::new(leg2).unwrap().run().unwrap();
    assert_eq!(r.ckpt.resumed_at, Some(4));
    assert!(r.history.iter().all(|h| h.loss.is_finite()));
    let _ = std::fs::remove_dir_all(&root);
}
