//! Per-iteration time breakdown — the quantities of Fig. 3 / Tables 15–22:
//! computation, communication (split into the part overlapped with
//! computation and "pure" blocking communication), and others.
//!
//! Computation and "others" are *measured* on this host; communication is
//! *modeled* by the α–β interconnect cost model over the configured
//! topology (threads on one host are not a fabric — see DESIGN.md §1).
//! The split follows DDP semantics: the parameter-gradient reduction can
//! overlap with the backward pass, the feature / u gathers (and
//! OpenCLIP's REDUCE_SCATTER) happen between forward and backward and
//! are blocking. How much of the gradient phase hides depends on the run
//! mode (DESIGN.md §11):
//!
//! * **serial** (`--overlap off`, or auto with nothing to hide): the
//!   trainer reduces after the whole backward, so the overlap is purely
//!   *hypothetical* — [`charge_iteration_with`] models it with the
//!   [`OVERLAP_FRACTION`] heuristic, as DDP-style training would achieve;
//! * **pipelined** (`--overlap on`/`auto`): the bucketed pipeline
//!   actually overlaps, and [`charge_iteration_overlapped`] splits the
//!   modeled gradient-phase time by the **measured** hidden fraction of
//!   this iteration's [`OverlapReport`] instead of the heuristic — so an
//!   overlapped run never double-counts a win the pipeline did not
//!   deliver, and `exp reduce` / `bench_iteration` report hidden vs
//!   exposed from the same measurement.

use crate::comm::{Collective, CostModel, OverlapReport, ReduceAlgo};
use crate::config::CommPattern;

/// Serial-mode heuristic: fraction of the `step` computation assumed
/// available to hide the gradient reduction (the backward pass; forward
/// cannot overlap because the gathers must complete first). Pipelined
/// runs use the measured fraction instead ([`charge_iteration_overlapped`]).
pub const OVERLAP_FRACTION: f64 = 0.6;

/// Cumulative timing for one worker, in seconds.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct TimeBreakdown {
    /// measured: encode + phase_g + step executions
    pub compute_s: f64,
    /// modeled: total communication time (overlapped + pure)
    pub comm_total_s: f64,
    /// modeled: communication hidden behind backward compute
    pub comm_overlap_s: f64,
    /// modeled: blocking communication on the critical path
    pub comm_pure_s: f64,
    /// measured: data loading, optimizer, state bookkeeping
    pub others_s: f64,
    /// measured (pipelined runs only): reduction-worker time that ran
    /// under backward compute — real hidden communication, DESIGN.md §11
    pub overlap_hidden_s: f64,
    /// measured (pipelined runs only): reduction time the compute thread
    /// blocked on after backward finished
    pub overlap_exposed_s: f64,
    /// number of iterations charged
    pub iterations: u64,
}

impl TimeBreakdown {
    /// Modeled per-iteration wall time: compute + pure comm + others
    /// (overlapped communication is hidden by definition).
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_pure_s + self.others_s
    }

    pub fn per_iter_ms(&self) -> PerIterMs {
        let n = self.iterations.max(1) as f64;
        PerIterMs {
            total: self.total_s() / n * 1e3,
            compute: self.compute_s / n * 1e3,
            comm_total: self.comm_total_s / n * 1e3,
            comm_pure: self.comm_pure_s / n * 1e3,
            comm_overlap: self.comm_overlap_s / n * 1e3,
            others: self.others_s / n * 1e3,
        }
    }

    /// Measured fraction of the overlapped reduction that ran hidden
    /// behind backward compute — `hidden / (hidden + exposed)` — or
    /// `None` when nothing was measured (serial runs, zero iterations):
    /// the 0/0 of an empty run must surface as "n/a", never as a NaN
    /// that poisons a report (the `inf`/`NaN` hardening satellite).
    pub fn hidden_fraction(&self) -> Option<f64> {
        crate::util::safe_ratio(
            self.overlap_hidden_s,
            self.overlap_hidden_s + self.overlap_exposed_s,
        )
    }

    /// Accumulate another worker's (or run's) breakdown into this one.
    pub fn merge(&mut self, other: &TimeBreakdown) {
        self.compute_s += other.compute_s;
        self.comm_total_s += other.comm_total_s;
        self.comm_overlap_s += other.comm_overlap_s;
        self.comm_pure_s += other.comm_pure_s;
        self.others_s += other.others_s;
        self.overlap_hidden_s += other.overlap_hidden_s;
        self.overlap_exposed_s += other.overlap_exposed_s;
        self.iterations += other.iterations;
    }
}

/// Per-iteration milliseconds, the unit of Fig. 3.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct PerIterMs {
    pub total: f64,
    pub compute: f64,
    pub comm_total: f64,
    pub comm_pure: f64,
    pub comm_overlap: f64,
    pub others: f64,
}

/// The communication volumes of one training iteration (§4 of the paper),
/// turned into modeled time by [`charge_iteration`].
#[derive(Debug, Clone, Copy)]
pub struct IterationVolumes {
    /// ALL_GATHER of the two feature matrices: per-rank payload bytes
    pub feature_gather_bytes: usize,
    /// ALL_GATHER of the u (and, for rgcl_i, τ) scalars: per-rank bytes.
    /// Zero for OpenCLIP (no u sequence).
    pub scalar_gather_bytes: usize,
    /// OpenCLIP only: REDUCE_SCATTER of per-pair gradient terms,
    /// O(K·B·d) total buffer bytes
    pub reduce_scatter_bytes: usize,
    /// ALL_REDUCE of the parameter gradient: buffer bytes (P × 4)
    pub grad_reduce_bytes: usize,
}

impl IterationVolumes {
    /// The volumes implied by the algorithm's communication pattern.
    ///
    /// `n_scalar_vectors` is the number of per-sample scalar vectors
    /// gathered per iteration: 2 for u1/u2 (plus 2 more when the algorithm
    /// gathers per-sample temperatures, i.e. rgcl_i).
    pub fn for_pattern(
        pattern: CommPattern,
        local_batch: usize,
        world: usize,
        d_embed: usize,
        n_params: usize,
        n_scalar_vectors: usize,
    ) -> Self {
        let f4 = 4; // f32 bytes
        let feature_gather_bytes = 2 * local_batch * d_embed * f4;
        match pattern {
            CommPattern::FastClip => IterationVolumes {
                feature_gather_bytes,
                scalar_gather_bytes: n_scalar_vectors * local_batch * f4,
                reduce_scatter_bytes: 0,
                grad_reduce_bytes: n_params * f4,
            },
            CommPattern::OpenClip => IterationVolumes {
                feature_gather_bytes,
                scalar_gather_bytes: 0,
                // per-pair gradient terms for both loss sides: the full
                // K·B×d matrices get reduce-scattered (§4 "Difference from
                // OpenCLIP")
                reduce_scatter_bytes: 2 * world * local_batch * d_embed * f4,
                grad_reduce_bytes: n_params * f4,
            },
        }
    }

    pub fn total_bytes(&self) -> usize {
        self.feature_gather_bytes
            + self.scalar_gather_bytes
            + self.reduce_scatter_bytes
            + self.grad_reduce_bytes
    }
}

/// Charge one iteration's communication to the breakdown, reducing the
/// gradient with a ring all-reduce (the historical default; equivalent to
/// [`charge_iteration_with`] with [`ReduceAlgo::Ring`]).
pub fn charge_iteration(
    bd: &mut TimeBreakdown,
    model: &CostModel,
    vol: &IterationVolumes,
    step_compute_s: f64,
) {
    charge_iteration_with(bd, model, vol, step_compute_s, ReduceAlgo::Ring);
}

/// Charge one iteration's communication to the breakdown. `step_compute_s`
/// is the measured step-graph time of this iteration (the overlap budget);
/// `grad_algo` is the gradient-reduction algorithm the trainer resolved,
/// which sets the α–β cost of the gradient phase
/// ([`CostModel::reduce_time`]). For the sharded strategy that phase is
/// the gradient reduce-scatter plus the updated-parameter all-gather; the
/// latter happens after the optimizer shard runs, but it can overlap the
/// *next* iteration's forward just as the bucketed all-reduce overlaps
/// backward, so it shares the same overlap budget.
pub fn charge_iteration_with(
    bd: &mut TimeBreakdown,
    model: &CostModel,
    vol: &IterationVolumes,
    step_compute_s: f64,
    grad_algo: ReduceAlgo,
) {
    let blocking = blocking_time(model, vol);
    let grad = model.reduce_time(grad_algo, vol.grad_reduce_bytes);
    let overlap = grad.min(OVERLAP_FRACTION * step_compute_s);

    bd.comm_total_s += blocking + grad;
    bd.comm_overlap_s += overlap;
    bd.comm_pure_s += blocking + (grad - overlap);
}

/// Charge one PIPELINED iteration (DESIGN.md §11): the blocking gathers
/// are modeled as in [`charge_iteration_with`], but the gradient phase is
/// split by the **measured** hidden fraction of `report` — the share of
/// reduction-worker time that actually ran under backward compute —
/// instead of the [`OVERLAP_FRACTION`] heuristic. The measured seconds
/// themselves accumulate into `overlap_hidden_s` / `overlap_exposed_s`,
/// so reports can show both the modeled α–β split and the real one
/// without double-counting either.
pub fn charge_iteration_overlapped(
    bd: &mut TimeBreakdown,
    model: &CostModel,
    vol: &IterationVolumes,
    grad_algo: ReduceAlgo,
    report: &OverlapReport,
) {
    let blocking = blocking_time(model, vol);
    let grad = model.reduce_time(grad_algo, vol.grad_reduce_bytes);
    let hidden = report.hidden_s();
    // guarded: an all-zero report (nothing measured) hides nothing —
    // 0/0 must not leak a NaN into the breakdown
    let fraction = crate::util::safe_ratio(hidden, hidden + report.exposed_s).unwrap_or(0.0);
    let overlap = grad * fraction;

    bd.comm_total_s += blocking + grad;
    bd.comm_overlap_s += overlap;
    bd.comm_pure_s += blocking + (grad - overlap);
    bd.overlap_hidden_s += hidden;
    bd.overlap_exposed_s += report.exposed_s;
}

/// Modeled time of one iteration's blocking collectives — the feature
/// gather, the u/τ scalar gather and OpenCLIP's REDUCE_SCATTER — which
/// sit between forward and backward and can never overlap. Shared by the
/// serial and pipelined charge paths so they always price the same
/// volumes identically.
fn blocking_time(model: &CostModel, vol: &IterationVolumes) -> f64 {
    model.time(Collective::AllGather, vol.feature_gather_bytes)
        + if vol.scalar_gather_bytes > 0 {
            model.time(Collective::AllGather, vol.scalar_gather_bytes)
        } else {
            0.0
        }
        + if vol.reduce_scatter_bytes > 0 {
            model.time(Collective::ReduceScatter, vol.reduce_scatter_bytes)
        } else {
            0.0
        }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ProfileName;

    fn model(nodes: usize) -> CostModel {
        CostModel::new(ProfileName::InfiniBand.profile(), nodes, 4)
    }

    fn volumes(pattern: CommPattern) -> IterationVolumes {
        IterationVolumes::for_pattern(pattern, 128, 32, 512, 20_000_000, 2)
    }

    #[test]
    fn openclip_moves_more_bytes() {
        let oc = volumes(CommPattern::OpenClip);
        let fc = volumes(CommPattern::FastClip);
        assert!(oc.total_bytes() > fc.total_bytes());
        // the scalar gather is O(K·B) vs O(K·B·d): tiny
        assert!(fc.scalar_gather_bytes * 100 < oc.reduce_scatter_bytes);
        assert_eq!(oc.scalar_gather_bytes, 0);
        assert_eq!(fc.reduce_scatter_bytes, 0);
    }

    #[test]
    fn fastclip_comm_time_beats_openclip() {
        // the paper's Fig. 3 claim in model terms, at every node count
        for nodes in [2, 4, 8] {
            let m = model(nodes);
            let mut oc = TimeBreakdown::default();
            let mut fc = TimeBreakdown::default();
            charge_iteration(&mut oc, &m, &volumes(CommPattern::OpenClip), 0.5);
            charge_iteration(&mut fc, &m, &volumes(CommPattern::FastClip), 0.5);
            assert!(
                oc.comm_pure_s > fc.comm_pure_s,
                "nodes={nodes}: oc {} fc {}",
                oc.comm_pure_s,
                fc.comm_pure_s
            );
            assert!(oc.comm_total_s > fc.comm_total_s);
        }
    }

    #[test]
    fn comm_gap_grows_with_nodes() {
        let gap = |nodes: usize| {
            let m = model(nodes);
            let mut oc = TimeBreakdown::default();
            let mut fc = TimeBreakdown::default();
            charge_iteration(&mut oc, &m, &volumes(CommPattern::OpenClip), 0.5);
            charge_iteration(&mut fc, &m, &volumes(CommPattern::FastClip), 0.5);
            oc.comm_pure_s - fc.comm_pure_s
        };
        assert!(gap(4) > gap(2));
        assert!(gap(8) > gap(4));
    }

    #[test]
    fn overlap_capped_by_backward() {
        let m = model(8);
        let mut bd = TimeBreakdown::default();
        // zero step compute: nothing can be hidden
        charge_iteration(&mut bd, &m, &volumes(CommPattern::FastClip), 0.0);
        assert_eq!(bd.comm_overlap_s, 0.0);
        assert!((bd.comm_pure_s - bd.comm_total_s).abs() < 1e-12);

        // huge step compute: the whole grad all-reduce hides
        let mut bd2 = TimeBreakdown::default();
        charge_iteration(&mut bd2, &m, &volumes(CommPattern::FastClip), 1e6);
        let grad = m.time(Collective::AllReduce, volumes(CommPattern::FastClip).grad_reduce_bytes);
        assert!((bd2.comm_overlap_s - grad).abs() < 1e-9);
    }

    #[test]
    fn totals_and_per_iter() {
        let mut bd = TimeBreakdown {
            compute_s: 2.0,
            comm_total_s: 1.0,
            comm_overlap_s: 0.4,
            comm_pure_s: 0.6,
            others_s: 0.4,
            iterations: 2,
            ..Default::default()
        };
        assert!((bd.total_s() - 3.0).abs() < 1e-12);
        let ms = bd.per_iter_ms();
        assert!((ms.total - 1500.0).abs() < 1e-9);
        assert!((ms.compute - 1000.0).abs() < 1e-9);
        let other = bd;
        bd.merge(&other);
        assert_eq!(bd.iterations, 4);
        assert!((bd.compute_s - 4.0).abs() < 1e-12);
    }

    #[test]
    fn grad_algo_changes_only_the_grad_phase() {
        let m = model(8);
        let vol = volumes(CommPattern::FastClip);
        let mut ring = TimeBreakdown::default();
        let mut naive = TimeBreakdown::default();
        let mut sharded = TimeBreakdown::default();
        charge_iteration_with(&mut ring, &m, &vol, 0.0, ReduceAlgo::Ring);
        charge_iteration_with(&mut naive, &m, &vol, 0.0, ReduceAlgo::Naive);
        charge_iteration_with(&mut sharded, &m, &vol, 0.0, ReduceAlgo::Sharded);
        // ring == the historical AllReduce charge; sharded == RS + AG == ring
        let legacy = {
            let mut bd = TimeBreakdown::default();
            charge_iteration(&mut bd, &m, &vol, 0.0);
            bd
        };
        assert_eq!(ring, legacy);
        assert!((sharded.comm_total_s - ring.comm_total_s).abs() < 1e-12);
        // a 20 MB gradient over 8 nodes is bandwidth-bound: naive pays more
        assert!(naive.comm_total_s > ring.comm_total_s);
        // the blocking (gather) part is identical across algorithms
        let blocking = |bd: &TimeBreakdown| bd.comm_total_s - m.reduce_time(ReduceAlgo::Ring, vol.grad_reduce_bytes);
        assert!((blocking(&ring) - blocking(&sharded)).abs() < 1e-12);
    }

    #[test]
    fn overlapped_charge_uses_measured_fraction() {
        let m = model(8);
        let vol = volumes(CommPattern::FastClip);
        let grad = m.reduce_time(ReduceAlgo::Ring, vol.grad_reduce_bytes);

        // 75% of the reduction measured as hidden → 75% of the modeled
        // grad time moves off the critical path, heuristic ignored
        let mut bd = TimeBreakdown::default();
        let rep = OverlapReport { busy_s: 0.4, exposed_s: 0.1 };
        charge_iteration_overlapped(&mut bd, &m, &vol, ReduceAlgo::Ring, &rep);
        assert!((bd.comm_overlap_s - 0.75 * grad).abs() < 1e-12);
        assert!((bd.overlap_hidden_s - 0.3).abs() < 1e-12);
        assert!((bd.overlap_exposed_s - 0.1).abs() < 1e-12);
        assert!((bd.comm_total_s - (bd.comm_pure_s + bd.comm_overlap_s)).abs() < 1e-12);

        // nothing measured → nothing hidden (no double-counted win)
        let mut none = TimeBreakdown::default();
        charge_iteration_overlapped(&mut none, &m, &vol, ReduceAlgo::Ring, &Default::default());
        assert_eq!(none.comm_overlap_s, 0.0);
        assert!((none.comm_pure_s - none.comm_total_s).abs() < 1e-12);

        // same total as the serial charge for the same volumes
        let mut serial = TimeBreakdown::default();
        charge_iteration_with(&mut serial, &m, &vol, 0.5, ReduceAlgo::Ring);
        assert!((serial.comm_total_s - bd.comm_total_s).abs() < 1e-12);
        assert_eq!(serial.overlap_hidden_s, 0.0, "serial runs measure no overlap");
    }

    #[test]
    fn hidden_fraction_guards_empty_runs() {
        // a zero-iteration / serial breakdown has no measured overlap:
        // the fraction is None (rendered "n/a"), never NaN
        let empty = TimeBreakdown::default();
        assert_eq!(empty.hidden_fraction(), None);
        let bd = TimeBreakdown {
            overlap_hidden_s: 0.3,
            overlap_exposed_s: 0.1,
            ..Default::default()
        };
        let f = bd.hidden_fraction().unwrap();
        assert!((f - 0.75).abs() < 1e-12);
        // per-iter ms of an empty run is all zeros, not inf
        let ms = empty.per_iter_ms();
        assert!(ms.total.is_finite() && ms.total == 0.0);
    }

    #[test]
    fn single_rank_has_zero_comm() {
        let m = CostModel::new(ProfileName::InfiniBand.profile(), 1, 1);
        let mut bd = TimeBreakdown::default();
        let vol = IterationVolumes::for_pattern(CommPattern::FastClip, 8, 1, 64, 1000, 2);
        charge_iteration(&mut bd, &m, &vol, 1.0);
        assert_eq!(bd.comm_total_s, 0.0);
        assert_eq!(bd.comm_pure_s, 0.0);
    }
}
