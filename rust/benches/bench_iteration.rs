//! End-to-end iteration benchmark — one bench per paper timing table:
//! full distributed iterations (encode → gathers → phase_g → step →
//! all-reduce → optimizer) per algorithm on the NATIVE backend, reporting
//! the Fig. 3 compute / pure-comm / overlap / others split plus real
//! iteration throughput.
//!
//! Runs on any machine (no artifacts). CI (`bench-smoke`) runs it in
//! `--quick` mode, writes `BENCH_iteration.json` and gates iteration
//! throughput against the committed baseline
//! (`benches/baseline/BENCH_iteration.json`, 25% floor):
//!
//! ```text
//! cargo bench --bench bench_iteration -- --quick \
//!     --json BENCH_iteration.json \
//!     --baseline benches/baseline/BENCH_iteration.json --max-regress 0.25
//! ```

#[path = "harness.rs"]
mod harness;

use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::runtime::BackendKind;
use fastclip::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.flag("quick");
    let steps: u32 = if quick { 12 } else { 32 };
    let repeats: usize = if quick { 3 } else { 5 };

    println!(
        "end-to-end native iterations (preset tiny, K=2, Bl=8; {steps} steps x {repeats} runs, \
         modeled 8x4 infiniband)\n"
    );
    println!(
        "{:<14} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "algorithm", "iters/s", "total", "compute", "pure", "overlap", "others"
    );

    let mut rows = Vec::new();
    for algo in Algorithm::all() {
        let make_cfg = || {
            let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", algo);
            cfg.backend = BackendKind::Native;
            cfg.steps = steps;
            cfg.iters_per_epoch = 8;
            cfg.data.n_train = 256;
            cfg.data.n_eval = 16;
            cfg.lr.total_iters = steps;
            cfg.lr.warmup_iters = 2;
            cfg.nodes = 8;
            cfg.gpus_per_node = 4;
            cfg
        };
        // warmup run (thread pools, page faults), then the timed repeats;
        // the MEDIAN run's throughput goes into the report
        let _ = Trainer::new(make_cfg())?.run()?;
        let mut samples = Vec::with_capacity(repeats);
        let mut last = None;
        for _ in 0..repeats {
            let r = Trainer::new(make_cfg())?.run()?;
            samples.push(r.wall_s);
            last = Some(r);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median_wall = samples[samples.len() / 2];
        let iters_per_sec = steps as f64 / median_wall;
        let r = last.expect("at least one run");
        let ms = r.timing.per_iter_ms();
        println!(
            "{:<14} {:>10.1} {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>7.2}ms",
            algo.name(),
            iters_per_sec,
            ms.total,
            ms.compute,
            ms.comm_pure,
            ms.comm_overlap,
            ms.others
        );
        rows.push(harness::JsonRow {
            name: format!("iteration/{}", algo.id()),
            rate_per_sec: iters_per_sec,
            median_s: median_wall / steps as f64,
        });
    }

    harness::finalize_report("iteration", quick, &rows, &args)
}
