//! Fixture bench.

const GATED_ROWS: &[&str] = &[
    "iteration/ghost",
];

fn main() {
    let row = Row { name: "iteration/real".to_string(), rate: 1.0 };
    let _ = (row, GATED_ROWS);
}

struct Row {
    name: String,
    rate: f64,
}
