# L1: Pallas kernels for the contrastive hot-spot of FastCLIP.
#
# The paper's compute hot-spot is the B x B pairwise-similarity +
# exponential reduction at the heart of every contrastive loss it studies
# (GCL / RGCL / RGCL-g / MBCL). On GPU the reference implementation
# materializes the full similarity matrix; here we re-think it for the TPU
# programming model (see DESIGN.md "Hardware adaptation"):
#
#   * the (M, N) similarity matrix is NEVER materialized in HBM — each grid
#     step holds one (bm, d) anchor tile and one (bn, d) candidate tile in
#     VMEM, computes the (bm, bn) similarity tile on the MXU
#     (jnp.dot with preferred_element_type=f32), and fuses the masked
#     exp-reduction into the matmul epilogue (FlashAttention-style);
#   * the backward pass RECOMPUTES the probability tile instead of storing
#     it, so HBM traffic is O((M+N) d) rather than O(M N);
#   * block shapes default to MXU/VPU-friendly multiples of (8, 128).
#
# interpret=True always: the CPU PJRT plugin cannot run Mosaic
# custom-calls, so these kernels lower to plain HLO for execution here;
# the BlockSpec structure is what a real-TPU build would reuse verbatim.
#
# Public API (differentiable via jax.custom_vjp):
#   pair_exp_rowsum(a, b, diag_idx, tau)          — self-contained form
#   pair_exp_rowsum_nodiag(a, b, sd, tau, denom)  — distributed column form
#
# computing g_i = 1/denom * sum_{j != diag_idx[i]} exp((s_ij - sd_i)/tau_i),
# which is exactly g_1(w, tau, i, B_{i-}) (and by symmetry g_2) of the
# paper — the inner function of the FCCO compositional loss.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU/VPU-aligned tile sizes. Overridable for the block-shape sweep
# in the performance pass (see EXPERIMENTS.md §Perf).
DEFAULT_BM = 128
DEFAULT_BN = 128

_INTERPRET = True  # CPU PJRT cannot execute Mosaic custom-calls.


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_blocks(m: int, n: int, bm: int | None, bn: int | None):
    bm = bm or min(DEFAULT_BM, _ceil_to(m, 8))
    bn = bn or min(DEFAULT_BN, _ceil_to(n, 128))
    return bm, bn


# ---------------------------------------------------------------------------
# Forward kernel: masked exp row-sum fused into the similarity matmul.
# Grid (M/bm, N/bn); the output row block is revisited across the j axis and
# accumulated in place (initialized at j == 0).
# ---------------------------------------------------------------------------
def _fwd_kernel(a_ref, b_ref, diag_ref, tau_ref, sd_ref, g_ref, *, bn, n_valid, denom):
    j = pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)       # (bm, d)
    b = b_ref[...].astype(jnp.float32)       # (bn, d)
    s = jnp.dot(a, b.T, preferred_element_type=jnp.float32)  # (bm, bn) on MXU
    diag = diag_ref[...].astype(jnp.int32)   # (bm,) — -1 encodes "no mask"
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (cols != diag[:, None]) & (cols < n_valid)
    z = (s - sd_ref[...][:, None]) / tau_ref[...][:, None]
    p = jnp.where(mask, jnp.exp(z), 0.0)
    part = jnp.sum(p, axis=1) / denom

    @pl.when(j == 0)
    def _init():
        g_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        g_ref[...] += part


# ---------------------------------------------------------------------------
# Backward row kernel: da (bm, d) and the raw dtau term, accumulated over j.
#   da_i   += (gbar_i/tau_i) * sum_j p_ij * b_j
#   dtau_i += -(gbar_i/tau_i^2) * sum_j p_ij * (s_ij - sd_i)
# (the sd-path cotangent dsd_i = -(gbar_i/tau_i) * g_i is applied by the
# vjp wrapper outside the kernel — it is an O(M) jnp op).
# ---------------------------------------------------------------------------
def _bwd_row_kernel(a_ref, b_ref, diag_ref, tau_ref, sd_ref, gbar_ref,
                    da_ref, dtau_ref, *, bn, n_valid, denom):
    j = pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    s = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    diag = diag_ref[...].astype(jnp.int32)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (cols != diag[:, None]) & (cols < n_valid)
    zraw = s - sd_ref[...][:, None]
    tau = tau_ref[...]
    p = jnp.where(mask, jnp.exp(zraw / tau[:, None]), 0.0) / denom
    c = gbar_ref[...] / tau                                  # (bm,)
    da_part = jnp.dot(c[:, None] * p, b, preferred_element_type=jnp.float32)
    dtau_part = -(c / tau) * jnp.sum(p * zraw, axis=1)

    @pl.when(j == 0)
    def _init():
        da_ref[...] = da_part
        dtau_ref[...] = dtau_part

    @pl.when(j > 0)
    def _acc():
        da_ref[...] += da_part
        dtau_ref[...] += dtau_part


# ---------------------------------------------------------------------------
# Backward col kernel: db (bn, d), accumulated over the i axis. Grid is
# transposed to (N/bn, M/bm) so the db block is the contiguous revisit.
#   db_j += sum_i (gbar_i/tau_i) * p_ij * a_i
# ---------------------------------------------------------------------------
def _bwd_col_kernel(a_ref, b_ref, diag_ref, tau_ref, sd_ref, gbar_ref,
                    db_ref, *, bn, n_valid, denom):
    jb, i = pl.program_id(0), pl.program_id(1)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    s = jnp.dot(a, b.T, preferred_element_type=jnp.float32)   # (bm, bn)
    diag = diag_ref[...].astype(jnp.int32)
    cols = jb * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = (cols != diag[:, None]) & (cols < n_valid)
    zraw = s - sd_ref[...][:, None]
    tau = tau_ref[...]
    p = jnp.where(mask, jnp.exp(zraw / tau[:, None]), 0.0) / denom
    cp = (gbar_ref[...] / tau)[:, None] * p                   # (bm, bn)
    db_part = jnp.dot(cp.T, a, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        db_ref[...] = db_part

    @pl.when(i > 0)
    def _acc():
        db_ref[...] += db_part


def _pad_rows(x, target):
    if x.shape[0] == target:
        return x
    pad = [(0, target - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def _row_specs(bm, bn, d):
    """BlockSpecs for (a, b, diag, tau, sd[, gbar]) on an (i, j) grid."""
    return [
        pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
        pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        pl.BlockSpec((bm,), lambda i, j: (i,)),
        pl.BlockSpec((bm,), lambda i, j: (i,)),
        pl.BlockSpec((bm,), lambda i, j: (i,)),
    ]


def _padded(a, b, diag_f, tau, sd, bm, bn, extra=None):
    m, n = a.shape[0], b.shape[0]
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    out = [
        _pad_rows(a, mp), _pad_rows(b, np_), _pad_rows(diag_f, mp),
        jnp.pad(tau, (0, mp - m), constant_values=1.0), _pad_rows(sd, mp),
    ]
    if extra is not None:
        out.append(_pad_rows(extra, mp))
    return out, mp, np_


def _pallas_fwd(a, b, diag_f, tau, sd, denom, bm, bn):
    m, d = a.shape
    n = b.shape[0]
    ins, mp, np_ = _padded(a, b, diag_f, tau, sd, bm, bn)
    g = pl.pallas_call(
        functools.partial(_fwd_kernel, bn=bn, n_valid=n, denom=denom),
        grid=(mp // bm, np_ // bn),
        in_specs=_row_specs(bm, bn, d),
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.float32),
        interpret=_INTERPRET,
    )(*ins)
    return g[:m]


def _pallas_bwd_row(a, b, diag_f, tau, sd, gbar, denom, bm, bn):
    m, d = a.shape
    n = b.shape[0]
    ins, mp, np_ = _padded(a, b, diag_f, tau, sd, bm, bn, extra=gbar)
    da, dtau = pl.pallas_call(
        functools.partial(_bwd_row_kernel, bn=bn, n_valid=n, denom=denom),
        grid=(mp // bm, np_ // bn),
        in_specs=_row_specs(bm, bn, d) + [pl.BlockSpec((bm,), lambda i, j: (i,))],
        out_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, d), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=_INTERPRET,
    )(*ins)
    return da[:m], dtau[:m]


def _pallas_bwd_col(a, b, diag_f, tau, sd, gbar, denom, bm, bn):
    m, d = a.shape
    n = b.shape[0]
    ins, mp, np_ = _padded(a, b, diag_f, tau, sd, bm, bn, extra=gbar)
    specs = [
        pl.BlockSpec((bm, d), lambda jb, i: (i, 0)),
        pl.BlockSpec((bn, d), lambda jb, i: (jb, 0)),
        pl.BlockSpec((bm,), lambda jb, i: (i,)),
        pl.BlockSpec((bm,), lambda jb, i: (i,)),
        pl.BlockSpec((bm,), lambda jb, i: (i,)),
        pl.BlockSpec((bm,), lambda jb, i: (i,)),
    ]
    db = pl.pallas_call(
        functools.partial(_bwd_col_kernel, bn=bn, n_valid=n, denom=denom),
        grid=(np_ // bn, mp // bm),  # transposed: db block is the fast revisit
        in_specs=specs,
        out_specs=pl.BlockSpec((bn, d), lambda jb, i: (jb, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, d), jnp.float32),
        interpret=_INTERPRET,
    )(*ins)
    return db[:n]


# ---------------------------------------------------------------------------
# Differentiable core: explicit sd, explicit denominator. diag_f is a pure
# mask input (float-encoded; -1 = "mask nothing"); its cotangent is zero.
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _masked_exp_rowsum(a, b, diag_f, sd, tau, denom, bm, bn):
    return _pallas_fwd(a, b, diag_f, tau, sd, denom, bm, bn)


def _core_fwd(a, b, diag_f, sd, tau, denom, bm, bn):
    g = _pallas_fwd(a, b, diag_f, tau, sd, denom, bm, bn)
    return g, (a, b, diag_f, sd, tau, g)


def _core_bwd(denom, bm, bn, res, gbar):
    a, b, diag_f, sd, tau, g = res
    gbar = gbar.astype(jnp.float32)
    da, dtau = _pallas_bwd_row(a, b, diag_f, tau, sd, gbar, denom, bm, bn)
    db = _pallas_bwd_col(a, b, diag_f, tau, sd, gbar, denom, bm, bn)
    dsd = -(gbar / tau) * g  # every term carries -1/tau_i through z
    return (da.astype(a.dtype), db.astype(b.dtype), jnp.zeros_like(diag_f),
            dsd.astype(sd.dtype), dtau.astype(tau.dtype))


_masked_exp_rowsum.defvjp(_core_fwd, _core_bwd)


def pair_exp_rowsum(a, b, diag_idx, tau, *, bm=None, bn=None):
    """Differentiable masked exp row-sum over pairwise similarities.

    g_i = 1/(N-1) * sum_{j != diag_idx[i]} exp((<a_i,b_j> - <a_i,b_diag_i>)/tau_i)

    Args:
      a: (M, d) anchor embeddings (f32 or bf16, L2-normalized by caller).
      b: (N, d) candidate embeddings.
      diag_idx: (M,) integer (or float-encoded) positive-pair column index.
      tau: (M,) per-row temperature (broadcast a scalar for global tau).
    Returns:
      g: (M,) f32. Differentiable w.r.t. a, b and tau (the s_diag path —
      the gather of b at diag_idx — is plain jnp, so autodiff covers it).
    """
    bm, bn = _pick_blocks(a.shape[0], b.shape[0], bm, bn)
    diag_f = diag_idx.astype(jnp.float32)
    sd = jnp.sum(a.astype(jnp.float32)
                 * jnp.take(b, diag_idx.astype(jnp.int32), axis=0).astype(jnp.float32),
                 axis=-1)
    return _masked_exp_rowsum(a, b, diag_f, sd, tau, b.shape[0] - 1, bm, bn)


def pair_exp_rowsum_nodiag(a, b, sd, tau, denom, *, bm=None, bn=None):
    """Distributed column form: no positive column present in `b`.

    g_i = 1/denom * sum_{j in b} exp((<a_i,b_j> - sd_i)/tau_i)

    Used for the (non-local row, local column) partial sums of the
    FastCLIP gradient estimator, where the positive pair of row i lives on
    another worker: `sd` (= s_{i,i}) is passed in precomputed from the
    gathered embeddings and `denom` is the GLOBAL |B|-1. Differentiable
    w.r.t. a, b, sd and tau.
    """
    bm, bn = _pick_blocks(a.shape[0], b.shape[0], bm, bn)
    diag_f = jnp.full((a.shape[0],), -1.0, jnp.float32)
    return _masked_exp_rowsum(a, b, diag_f, sd, tau, float(denom), bm, bn)
