//! The native CPU backend (DESIGN.md §10): the full FastCLIP step surface
//! — `encode`, `phase_g`, `step_<variant>` for every variant of Table 1 —
//! implemented over the pure-Rust kernels of [`crate::kernels`], with no
//! artifacts, no Python and no PJRT.
//!
//! # Model
//!
//! The native model is the embedding-table encoder pair of
//! [`crate::kernels::encoder`] (patch-mean → linear projection on the
//! image side; token-table mean on the text side; shared row
//! L2-normalize). It intentionally replaces the artifact bundle's
//! transformer towers with something exactly hand-differentiable; the
//! *algorithm* — Eq. (1) u-estimation, the distributed surrogate gradient
//! decomposition of `python/compile/losses.py`, the Eq. (8)/(9)/(10)
//! temperature gradients — is the paper's, unchanged.
//!
//! # The surrogate gradient, by hand
//!
//! Mirroring `losses.py::_surrogate` term for term: with row weights
//! `w_i = f'(u_i)` held constant,
//!
//! ```text
//! S = (1/Bg) [ Σ_{i∈local}    w1_i·g1_i(e1_i, E2sp) + w2_i·g2_i(e2_i, E1sp)
//!            + Σ_{i∈nonlocal} w1_i·ĝ1_i(e1g_i, e2)  + w2_i·ĝ2_i(e2g_i, e1) ]
//! ```
//!
//! where `E*sp` are the gathered embeddings with the local block replaced
//! by live (recomputed) rows, g is the masked exp row-sum
//! ([`crate::kernels::softmax`]) and ĝ its no-diag column form. ∂S/∂params
//! flows through the row kernels' `da` (+ the s_diag path), the local
//! columns' `db`, and the column kernels' `db`, then back through the
//! normalize and encoder backward kernels. ∂S/∂τ flows only through the
//! local *row* calls (each (i, j) pair is counted exactly once across
//! workers), exactly as the stop-gradient placement in `losses.py`
//! dictates. A finite-difference oracle in `tests/native_backend.rs` pins
//! this derivation against [`NativeBackend::surrogate_value`].
//!
//! # The sharded column exchange (`--loss-shard`, DESIGN.md §16)
//!
//! The column part of the surrogate backward — every row's contribution
//! to the *candidate-side* feature gradients — is organized as one fold
//! per destination column block: for each block of `B_local` columns the
//! per-source-rank partials are summed in ascending source-rank order
//! from a zero accumulator. Under `LossShard::Off` this worker evaluates
//! all source blocks itself against its spliced gathered copies; under
//! `LossShard::On` it evaluates only its *own* rows' partials (one
//! [`crate::kernels::softmax::masked_exp_rowsum_bwd_col_range`] call per
//! destination block) and hands them to a [`super::FeatGradReduce`]
//! exchange, which returns the same ascending-source fold computed
//! cooperatively. The fold order is pinned, so the two modes are bitwise
//! identical — the §16 equivalence matrix in `tests/native_backend.rs`
//! holds this line.
//!
//! # Determinism
//!
//! Every reduction inherits the kernels' fixed summation trees, so one
//! step is bitwise identical across kernel thread counts and equal to the
//! scalar-reference composition.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::kernels::{encoder, gemm, norm, precision, resolve_threads, softmax, sum, Precision};
use crate::util::Rng;

use super::backend::{
    ComputeBackend, LossShard, RuntimeTimers, StepEmit, StepOutput, TauGrads, TauInput,
};
use super::manifest::{Manifest, ModelInfo, ParamSegment};

/// The step variants the native backend implements — all of Table 1.
pub const VARIANTS: [&str; 5] = ["gcl", "gcl_v0", "rgcl_i", "rgcl_g", "mbcl"];

/// Model dims per preset — mirrors the interface shapes of
/// `python/compile/model.py::PRESETS` (d_embed, v_patches, v_patch_dim,
/// t_vocab, t_len); tower widths/depths do not apply to the native model.
pub fn preset_dims(name: &str) -> Result<ModelInfo> {
    let (d_embed, v_patches, v_patch_dim, t_vocab, t_len) = match name {
        "tiny" => (64, 16, 32, 256, 16),
        "small" => (128, 16, 32, 512, 24),
        "medium" => (256, 32, 48, 1024, 32),
        "base" => (512, 49, 64, 4096, 32),
        other => anyhow::bail!("unknown preset '{other}' (expected tiny|small|medium|base)"),
    };
    Ok(ModelInfo { d_embed, v_patches, v_patch_dim, t_vocab, t_len })
}

/// The native flat-parameter layout: image projection + bias, token
/// embedding table + bias.
pub fn param_spec(model: &ModelInfo) -> Vec<ParamSegment> {
    let d = model.d_embed;
    let sizes = [
        ("v.proj", model.v_patch_dim * d),
        ("v.bias", d),
        ("t.tok", model.t_vocab * d),
        ("t.bias", d),
    ];
    let mut spec = Vec::with_capacity(sizes.len());
    let mut off = 0;
    for (name, size) in sizes {
        spec.push(ParamSegment { name: name.to_string(), offset: off, size });
        off += size;
    }
    spec
}

/// Deterministic native init (the aot.py `init_params` analog): the image
/// projection is fan-in scaled, the token table GPT-style 0.02-std, both
/// biases zero. Seeded from the manifest so runs are bit-reproducible.
pub fn init_params(m: &Manifest) -> Vec<f32> {
    let mut rng = Rng::new(m.seed ^ 0x4E57_1A7E);
    let mut out = vec![0.0f32; m.n_params];
    for seg in &m.param_spec {
        let slice = &mut out[seg.offset..seg.offset + seg.size];
        match seg.name.as_str() {
            "v.proj" => {
                let std = (m.model.v_patch_dim as f32).powf(-0.5);
                rng.fill_normal(slice, std);
            }
            "t.tok" => rng.fill_normal(slice, 0.02),
            // biases stay zero
            _ => {}
        }
    }
    out
}

/// Resolved offsets of the four native parameter leaves.
#[derive(Debug, Clone, Copy)]
struct Layout {
    vproj: (usize, usize),
    vbias: (usize, usize),
    ttok: (usize, usize),
    tbias: (usize, usize),
}

impl Layout {
    fn resolve(m: &Manifest) -> Result<Layout> {
        let find = |name: &str| -> Result<(usize, usize)> {
            m.param_spec
                .iter()
                .find(|s| s.name == name)
                .map(|s| (s.offset, s.offset + s.size))
                .ok_or_else(|| anyhow::anyhow!("manifest lacks native parameter leaf '{name}'"))
        };
        Ok(Layout {
            vproj: find("v.proj")?,
            vbias: find("v.bias")?,
            ttok: find("t.tok")?,
            tbias: find("t.bias")?,
        })
    }
}

/// Cached forward activations one step needs for its backward pass.
struct EncodeCache {
    xbar: Vec<f32>,
    pooled1: Vec<f32>,
    norms1: Vec<f32>,
    e1: Vec<f32>,
    pooled2: Vec<f32>,
    norms2: Vec<f32>,
    e2: Vec<f32>,
}

/// The pure-Rust compute engine: the full `encode` / `phase_g` /
/// `step_<variant>` surface over [`crate::kernels`], no artifacts, no
/// Python, bitwise deterministic at any kernel thread count (see the
/// module docs and DESIGN.md §10).
pub struct NativeBackend {
    manifest: Manifest,
    layout: Layout,
    threads: usize,
    precision: Precision,
    timers: RuntimeTimers,
}

impl NativeBackend {
    /// Build a native backend for `manifest` (which must be a native
    /// manifest — artifact bundles carry a transformer parameter layout
    /// the native model does not implement). `variant = None` accepts all
    /// variants; `kernel_threads = 0` auto-sizes. Computes in full f32;
    /// use [`Self::with_precision`] for the bf16 storage path.
    pub fn new(
        manifest: &Manifest,
        variant: Option<&str>,
        kernel_threads: usize,
    ) -> Result<NativeBackend> {
        Self::with_precision(manifest, variant, kernel_threads, Precision::F32)
    }

    /// [`Self::new`] with an explicit compute [`Precision`] (DESIGN.md
    /// §12). Under `Bf16` the parameter working copies and the cached
    /// activations are stored bfloat16 (the f32 `params` the caller holds
    /// stay the untouched master weights) and the emitted gradient leaves
    /// are bf16-rounded; every kernel accumulation stays f32, so the §10
    /// determinism contract — bitwise identical at any kernel thread
    /// count — holds unchanged.
    pub fn with_precision(
        manifest: &Manifest,
        variant: Option<&str>,
        kernel_threads: usize,
        precision: Precision,
    ) -> Result<NativeBackend> {
        ensure!(
            manifest.native,
            "the native backend needs a native manifest (Manifest::native / --backend native); \
             '{}' is an artifact bundle — use --backend pjrt for it",
            manifest.preset
        );
        if let Some(v) = variant {
            ensure!(
                manifest.variants.iter().any(|x| x == v),
                "variant '{v}' not in bundle {:?}",
                manifest.variants
            );
        }
        Ok(NativeBackend {
            layout: Layout::resolve(manifest)?,
            manifest: manifest.clone(),
            threads: resolve_threads(kernel_threads),
            precision,
            timers: RuntimeTimers::default(),
        })
    }

    /// The kernel thread count this backend runs with.
    pub fn kernel_threads(&self) -> usize {
        self.threads
    }

    /// The storage precision this backend computes at (DESIGN.md §12).
    pub fn precision(&self) -> Precision {
        self.precision
    }

    fn check_encode_inputs(&self, params: &[f32], images: &[f32], texts: &[i32]) -> Result<()> {
        let m = &self.manifest;
        let bl = m.local_batch;
        ensure!(params.len() == m.n_params, "params len {}", params.len());
        ensure!(images.len() == bl * m.model.v_patches * m.model.v_patch_dim, "images len");
        ensure!(texts.len() == bl * m.model.t_len, "texts len");
        let vocab = m.model.t_vocab as i32;
        ensure!(
            texts.iter().all(|&t| (0..vocab).contains(&t)),
            "token id out of vocab range [0, {vocab})"
        );
        Ok(())
    }

    /// Full forward with cached activations (the step's backward needs
    /// them; `encode` discards everything but e1/e2).
    ///
    /// Under `--precision bf16` (DESIGN.md §12) the parameter leaves get
    /// bf16 *working copies* (`params` itself — the caller's master
    /// weights — is never touched) and the forward runs through the
    /// bf16-storage kernel entry points of [`crate::kernels::precision`];
    /// every activation is rounded to bf16 at its storage boundary, so
    /// the cache holds exactly the (bf16-representable) values the
    /// backward must differentiate through. Accumulations stay f32.
    fn encode_cached(&self, params: &[f32], images: &[f32], texts: &[i32]) -> EncodeCache {
        let m = &self.manifest;
        let (bl, d) = (m.local_batch, m.model.d_embed);
        let pd = m.model.v_patch_dim;
        let w = &params[self.layout.vproj.0..self.layout.vproj.1];
        let bv = &params[self.layout.vbias.0..self.layout.vbias.1];
        let tok = &params[self.layout.ttok.0..self.layout.ttok.1];
        let bt = &params[self.layout.tbias.0..self.layout.tbias.1];

        let xbar = encoder::patch_mean(images, bl, m.model.v_patches, pd);
        if self.precision == Precision::Bf16 {
            let (wq, bvq) = (precision::to_bf16(w), precision::to_bf16(bv));
            let btq = precision::to_bf16(bt);
            let xq = precision::to_bf16(&xbar);
            let xbar = precision::from_bf16(&xq);
            let mut pooled1 = precision::image_fwd_bf16(&wq, &bvq, &xq, bl, pd, d, self.threads);
            self.precision.quantize(&mut pooled1);
            let (mut e1, norms1) = norm::l2_normalize_fwd(&pooled1, bl, d, self.threads);
            self.precision.quantize(&mut e1);
            // on-access variant: the token table is ~90% of the
            // parameters — converting all of it per call would spend
            // more bandwidth than bf16 storage saves
            let mut pooled2 = precision::text_fwd_bf16_from_f32(
                tok,
                &btq,
                texts,
                bl,
                m.model.t_len,
                m.model.t_vocab,
                d,
            );
            self.precision.quantize(&mut pooled2);
            let (mut e2, norms2) = norm::l2_normalize_fwd(&pooled2, bl, d, self.threads);
            self.precision.quantize(&mut e2);
            return EncodeCache { xbar, pooled1, norms1, e1, pooled2, norms2, e2 };
        }
        let pooled1 = encoder::image_fwd(w, bv, &xbar, bl, pd, d, self.threads);
        let (e1, norms1) = norm::l2_normalize_fwd(&pooled1, bl, d, self.threads);
        let pooled2 = encoder::text_fwd(tok, bt, texts, bl, m.model.t_len, m.model.t_vocab, d);
        let (e2, norms2) = norm::l2_normalize_fwd(&pooled2, bl, d, self.threads);
        EncodeCache { xbar, pooled1, norms1, e1, pooled2, norms2, e2 }
    }

    /// The surrogate scalar S whose ∂/∂params is this worker's gradient
    /// contribution — forward value only, with the gathered inputs and
    /// u/τ treated as constants (the stop-gradient placement of
    /// `losses.py`). Public as a finite-difference oracle for the parity
    /// suite; not part of the training path. A bf16 backend evaluates the
    /// quantized forward; the bf16 gradient check therefore differences
    /// an `F32` oracle backend and widens its tolerance (DESIGN.md §12).
    #[doc(hidden)]
    #[allow(clippy::too_many_arguments)]
    pub fn surrogate_value(
        &self,
        variant: &str,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
        e1g: &[f32],
        e2g: &[f32],
        u1g: &[f32],
        u2g: &[f32],
        tau1g: &[f32],
        tau2g: &[f32],
        offset: usize,
        eps: f32,
    ) -> Result<f32> {
        let m = &self.manifest;
        let (bl, bg, d) = (m.local_batch, m.global_batch, m.model.d_embed);
        self.check_encode_inputs(params, images, texts)?;
        ensure!(offset + bl <= bg, "offset {offset} out of range");
        let cache = self.encode_cached(params, images, texts);
        let (e1sp, e2sp) = splice(e1g, e2g, &cache.e1, &cache.e2, offset, bl, d);
        let bgf = bg as f32;
        let denom = (bg - 1) as f32;
        let diag: Vec<isize> = (0..bl).map(|i| (offset + i) as isize).collect();
        let sd: Vec<f32> = (0..bl)
            .map(|i| gemm::dot(&cache.e1[i * d..(i + 1) * d], &cache.e2[i * d..(i + 1) * d]))
            .collect();
        let u1l = &u1g[offset..offset + bl];
        let u2l = &u2g[offset..offset + bl];
        let tau1l = &tau1g[offset..offset + bl];
        let tau2l = &tau2g[offset..offset + bl];
        let w1l = weights(variant, u1l, tau1l, eps, bgf);
        let w2l = weights(variant, u2l, tau2l, eps, bgf);
        let t = self.threads;
        let g1 =
            softmax::masked_exp_rowsum(&cache.e1, &e2sp, &diag, &sd, tau1l, denom, bl, bg, d, t);
        let g2 =
            softmax::masked_exp_rowsum(&cache.e2, &e1sp, &diag, &sd, tau2l, denom, bl, bg, d, t);
        let mut s: f32 = 0.0;
        for i in 0..bl {
            s += w1l[i] * g1[i] + w2l[i] * g2[i];
        }
        if bg > bl {
            let nl = nonlocal_indices(bg, bl, offset);
            let e1nl = gather_rows(e1g, &nl, d);
            let e2nl = gather_rows(e2g, &nl, d);
            let sd_nl: Vec<f32> = nl
                .iter()
                .map(|&gi| gemm::dot(&e1g[gi * d..(gi + 1) * d], &e2g[gi * d..(gi + 1) * d]))
                .collect();
            let no_diag = vec![softmax::NO_DIAG; nl.len()];
            let u1n: Vec<f32> = nl.iter().map(|&gi| u1g[gi]).collect();
            let u2n: Vec<f32> = nl.iter().map(|&gi| u2g[gi]).collect();
            let t1n: Vec<f32> = nl.iter().map(|&gi| tau1g[gi]).collect();
            let t2n: Vec<f32> = nl.iter().map(|&gi| tau2g[gi]).collect();
            let w1n = weights(variant, &u1n, &t1n, eps, bgf);
            let w2n = weights(variant, &u2n, &t2n, eps, bgf);
            let nn = nl.len();
            let g1c = softmax::masked_exp_rowsum(
                &e1nl, &cache.e2, &no_diag, &sd_nl, &t1n, denom, nn, bl, d, t,
            );
            let g2c = softmax::masked_exp_rowsum(
                &e2nl, &cache.e1, &no_diag, &sd_nl, &t2n, denom, nn, bl, d, t,
            );
            for i in 0..nl.len() {
                s += w1n[i] * g1c[i] + w2n[i] * g2c[i];
            }
        }
        Ok(s / bgf)
    }
}

/// Row weights f'(u) per loss family (`losses.py::_weights`).
fn weights(variant: &str, u: &[f32], tau_rows: &[f32], eps: f32, bg: f32) -> Vec<f32> {
    match variant {
        "mbcl" => u.iter().map(|&ui| (bg - 1.0) / (1.0 + (bg - 1.0) * ui)).collect(),
        "gcl_v0" => u.iter().map(|&ui| 1.0 / (eps + ui)).collect(),
        _ => u.iter().zip(tau_rows).map(|(&ui, &t)| t / (eps + ui)).collect(),
    }
}

/// Reported local-mean loss value (`losses.py::_loss_value`), scaled by
/// 1/K so the SUM over workers is the global mean.
#[allow(clippy::too_many_arguments)]
fn local_loss(
    variant: &str,
    u1l: &[f32],
    u2l: &[f32],
    t1l: &[f32],
    t2l: &[f32],
    eps: f32,
    rho: f32,
    bg: f32,
    k_workers: f32,
) -> f32 {
    let bl = u1l.len();
    let mut acc = 0.0f32;
    for i in 0..bl {
        acc += match variant {
            "mbcl" => {
                (1.0 / bg + (bg - 1.0) / bg * u1l[i]).ln()
                    + (1.0 / bg + (bg - 1.0) / bg * u2l[i]).ln()
            }
            "gcl" | "gcl_v0" => t1l[i] * (eps + u1l[i]).ln() + t2l[i] * (eps + u2l[i]).ln(),
            // rgcl family carries the +rho margin terms
            _ => t1l[i] * ((eps + u1l[i]).ln() + rho) + t2l[i] * ((eps + u2l[i]).ln() + rho),
        };
    }
    acc / bl as f32 / k_workers
}

/// Global indices of the nonlocal rows in the Python `_split_nonlocal`
/// (rolled) order: offset+bl, …, bg−1, 0, …, offset−1.
fn nonlocal_indices(bg: usize, bl: usize, offset: usize) -> Vec<usize> {
    (0..bg - bl).map(|i| (offset + bl + i) % bg).collect()
}

fn gather_rows(x: &[f32], idx: &[usize], d: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * d);
    for &i in idx {
        out.extend_from_slice(&x[i * d..(i + 1) * d]);
    }
    out
}

/// The gathered embeddings with the local block replaced by live rows
/// (`dynamic_update_slice(sg(eg), e, offset)`).
#[allow(clippy::too_many_arguments)]
fn splice(
    e1g: &[f32],
    e2g: &[f32],
    e1: &[f32],
    e2: &[f32],
    offset: usize,
    bl: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut e1sp = e1g.to_vec();
    let mut e2sp = e2g.to_vec();
    e1sp[offset * d..(offset + bl) * d].copy_from_slice(e1);
    e2sp[offset * d..(offset + bl) * d].copy_from_slice(e2);
    (e1sp, e2sp)
}

impl ComputeBackend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn backend_id(&self) -> &'static str {
        "native"
    }

    fn timers(&self) -> RuntimeTimers {
        self.timers
    }

    /// The §16 gauge, priced from what each mode must hold live through
    /// the column part: unsharded keeps the two spliced gathered copies
    /// (2·Bg·d floats) plus the fold buffers and one transient partial
    /// pair (4·Bl·d); sharded replaces the Bg-proportional splices with
    /// one outbound per-destination segment plus the reduced column sums
    /// (2·Bl·d each). At K workers the ratio is (2K+4)/4 — 3× at K=4,
    /// K/2 asymptotically.
    fn loss_peak_bytes(&self, sharded: bool) -> u64 {
        let m = &self.manifest;
        let (bl, bg, d) = (m.local_batch as u64, m.global_batch as u64, m.model.d_embed as u64);
        if sharded {
            4 * 4 * bl * d
        } else {
            4 * (2 * bg * d + 4 * bl * d)
        }
    }

    fn encode(
        &mut self,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_encode_inputs(params, images, texts)?;
        let t0 = Instant::now();
        let cache = self.encode_cached(params, images, texts);
        self.timers.encode_s += t0.elapsed().as_secs_f64();
        Ok((cache.e1, cache.e2))
    }

    fn phase_g(
        &mut self,
        e1g: &[f32],
        e2g: &[f32],
        offset: usize,
        u1: &[f32],
        u2: &[f32],
        tau1: &[f32],
        tau2: &[f32],
        gamma: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = &self.manifest;
        let (bl, bg, d) = (m.local_batch, m.global_batch, m.model.d_embed);
        ensure!(e1g.len() == bg * d && e2g.len() == bg * d, "gathered feats len");
        ensure!(u1.len() == bl && u2.len() == bl, "u len");
        ensure!(tau1.len() == bl && tau2.len() == bl, "tau len");
        ensure!(offset + bl <= bg, "offset {offset} out of range");

        let t0 = Instant::now();
        let e1l = &e1g[offset * d..(offset + bl) * d];
        let e2l = &e2g[offset * d..(offset + bl) * d];
        let diag: Vec<isize> = (0..bl).map(|i| (offset + i) as isize).collect();
        // s_diag: the positive-pair similarity <e1_i, e2_i>
        let sd: Vec<f32> = (0..bl)
            .map(|i| {
                gemm::dot(
                    &e1l[i * d..(i + 1) * d],
                    &e2g[(offset + i) * d..(offset + i + 1) * d],
                )
            })
            .collect();
        let denom = (bg - 1) as f32;
        let t = self.threads;
        let g1 = softmax::masked_exp_rowsum(e1l, e2g, &diag, &sd, tau1, denom, bl, bg, d, t);
        let g2 = softmax::masked_exp_rowsum(e2l, e1g, &diag, &sd, tau2, denom, bl, bg, d, t);
        let mix = |u: &f32, g: &f32| (1.0 - gamma) * *u + gamma * *g;
        let u1n: Vec<f32> = u1.iter().zip(&g1).map(|(u, g)| mix(u, g)).collect();
        let u2n: Vec<f32> = u2.iter().zip(&g2).map(|(u, g)| mix(u, g)).collect();
        self.timers.phase_g_s += t0.elapsed().as_secs_f64();
        Ok((g1, g2, u1n, u2n))
    }

    fn step(
        &mut self,
        variant: &str,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
        e1g: &[f32],
        e2g: &[f32],
        u1g: &[f32],
        u2g: &[f32],
        offset: usize,
        eps: f32,
        rho: f32,
        tau: TauInput,
        shard: LossShard<'_>,
    ) -> Result<StepOutput> {
        // the emitting path is the implementation; assembling its
        // segments here is exactly the old whole-gradient layout
        let p = self.manifest.n_params;
        let mut grad = vec![0.0f32; p];
        let out = self.step_emit(
            variant, params, images, texts, e1g, e2g, u1g, u2g, offset, eps, rho, tau, shard,
            &mut |off, seg| grad[off..off + seg.len()].copy_from_slice(seg),
        )?;
        Ok(StepOutput { grad, loss: out.loss, tau: out.tau })
    }

    /// The native backward emits each parameter leaf the moment its
    /// gradient is final, in layout order: `v.proj`, `v.bias` right after
    /// the image-side backward, then `t.tok`, `t.bias` after the
    /// text-side backward — so the overlap pipeline can start reducing
    /// the image leaves while the text backward is still running.
    fn step_emit(
        &mut self,
        variant: &str,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
        e1g: &[f32],
        e2g: &[f32],
        u1g: &[f32],
        u2g: &[f32],
        offset: usize,
        eps: f32,
        rho: f32,
        tau: TauInput,
        shard: LossShard<'_>,
        sink: &mut dyn FnMut(usize, &[f32]),
    ) -> Result<StepEmit> {
        let m = &self.manifest;
        let (bl, bg, d) = (m.local_batch, m.global_batch, m.model.d_embed);
        ensure!(VARIANTS.contains(&variant), "unknown step variant '{variant}'");
        self.check_encode_inputs(params, images, texts)?;
        ensure!(e1g.len() == bg * d && e2g.len() == bg * d, "gathered feats len");
        ensure!(u1g.len() == bg && u2g.len() == bg, "gathered u len");
        ensure!(offset + bl <= bg, "offset {offset} out of range");
        let individual = match &tau {
            TauInput::Global(_) => {
                ensure!(variant != "rgcl_i", "rgcl_i needs TauInput::Individual");
                false
            }
            TauInput::Individual { tau1g, tau2g } => {
                ensure!(variant == "rgcl_i", "{variant} takes a global tau");
                ensure!(tau1g.len() == bg && tau2g.len() == bg, "gathered tau len");
                true
            }
        };
        let (tau1g_vec, tau2g_vec): (Vec<f32>, Vec<f32>) = match &tau {
            TauInput::Global(t) => (vec![*t; bg], vec![*t; bg]),
            TauInput::Individual { tau1g, tau2g } => (tau1g.to_vec(), tau2g.to_vec()),
        };

        let t0 = Instant::now();
        let threads = self.threads;
        let bgf = bg as f32;
        let k = m.k_workers;
        let denom = (bg - 1) as f32;

        // ---- live forward + (off-mode) splice ---------------------------
        // The spliced gathered copies exist only under LossShard::Off:
        // the sharded path reads e1g/e2g directly, which is bitwise the
        // same — the local block of a gathered tensor is the wire-exact
        // copy of this worker's live rows (f32 identity wire; the bf16
        // feature wire is lossless on bf16-valued embeddings).
        let cache = self.encode_cached(params, images, texts);
        let spliced: (Vec<f32>, Vec<f32>);
        let (e1b, e2b): (&[f32], &[f32]) = if matches!(shard, LossShard::Off) {
            spliced = splice(e1g, e2g, &cache.e1, &cache.e2, offset, bl, d);
            (&spliced.0, &spliced.1)
        } else {
            (e1g, e2g)
        };

        let u1l = &u1g[offset..offset + bl];
        let u2l = &u2g[offset..offset + bl];
        let tau1l = &tau1g_vec[offset..offset + bl];
        let tau2l = &tau2g_vec[offset..offset + bl];
        let w1l = weights(variant, u1l, tau1l, eps, bgf);
        let w2l = weights(variant, u2l, tau2l, eps, bgf);
        let gbar1: Vec<f32> = w1l.iter().map(|w| w / bgf).collect();
        let gbar2: Vec<f32> = w2l.iter().map(|w| w / bgf).collect();

        let diag: Vec<isize> = (0..bl).map(|i| (offset + i) as isize).collect();
        let sd: Vec<f32> = (0..bl)
            .map(|i| gemm::dot(&cache.e1[i * d..(i + 1) * d], &cache.e2[i * d..(i + 1) * d]))
            .collect();

        // ---- row part: local rows × all columns -------------------------
        let g1row = softmax::masked_exp_rowsum(
            &cache.e1, e2b, &diag, &sd, tau1l, denom, bl, bg, d, threads,
        );
        let g2row = softmax::masked_exp_rowsum(
            &cache.e2, e1b, &diag, &sd, tau2l, denom, bl, bg, d, threads,
        );

        let mut de1 = vec![0.0f32; bl * d];
        let mut de2 = vec![0.0f32; bl * d];

        // side 1: a = e1 (live), b = e2b (local columns live)
        let (da1, dtau1) = softmax::masked_exp_rowsum_bwd_row(
            &cache.e1, e2b, &diag, &sd, tau1l, &gbar1, denom, bl, bg, d, threads,
        );
        add_assign(&mut de1, &da1);
        // side 2: a = e2 (live), b = e1b
        let (da2, dtau2) = softmax::masked_exp_rowsum_bwd_row(
            &cache.e2, e1b, &diag, &sd, tau2l, &gbar2, denom, bl, bg, d, threads,
        );
        add_assign(&mut de2, &da2);

        // s_diag path: sd_i = <e1_i, e2_i>, both live, shared by both
        // sides — dsd_i = −(ḡ_i/τ_i)·g_i from each
        for i in 0..bl {
            let dsd = -(gbar1[i] / tau1l[i]) * g1row[i] - (gbar2[i] / tau2l[i]) * g2row[i];
            let e1row = &cache.e1[i * d..(i + 1) * d];
            let e2row = &cache.e2[i * d..(i + 1) * d];
            for q in 0..d {
                de1[i * d + q] += dsd * e2row[q];
                de2[i * d + q] += dsd * e1row[q];
            }
        }

        // ---- column part: all rows × local columns (DESIGN.md §16) ------
        // Both modes compute the same fold: the gradient flowing into this
        // worker's live candidate columns is the sum over SOURCE row
        // blocks, folded in ascending block order from a zero accumulator
        // (a single-source fold is the partial itself — mirroring
        // `exchange_block_sums` exactly is what keeps on≡off bitwise).
        let (colsum1, colsum2) = match shard {
            LossShard::Off => {
                // ascending source blocks of ≤ B_local rows, cut at the
                // local block; under the trainer's block-aligned offsets
                // this is exactly the per-rank row decomposition
                let mut blocks: Vec<(usize, usize, bool)> = Vec::new();
                let mut g = 0usize;
                while g < bg {
                    if g == offset {
                        blocks.push((g, g + bl, true));
                        g += bl;
                    } else {
                        let end =
                            if g < offset { (g + bl).min(offset) } else { (g + bl).min(bg) };
                        blocks.push((g, end, false));
                        g = end;
                    }
                }
                let single = blocks.len() == 1;
                let mut colsum1 = vec![0.0f32; bl * d];
                let mut colsum2 = vec![0.0f32; bl * d];
                for &(lo, hi, is_self) in &blocks {
                    let (p1, p2) = if is_self {
                        // the global diag indices mask exactly the local
                        // positives inside the [offset, offset+bl) range
                        (
                            softmax::masked_exp_rowsum_bwd_col_range(
                                &cache.e1, e2b, &diag, &sd, tau1l, &gbar1, denom, bl, bg, d,
                                offset, offset + bl, threads,
                            ),
                            softmax::masked_exp_rowsum_bwd_col_range(
                                &cache.e2, e1b, &diag, &sd, tau2l, &gbar2, denom, bl, bg, d,
                                offset, offset + bl, threads,
                            ),
                        )
                    } else {
                        // a nonlocal source block, replayed from the
                        // gathered copies — rows are contiguous, so the
                        // anchor slices borrow straight out of e1g/e2g
                        let mb = hi - lo;
                        let diag_b: Vec<isize> = (lo..hi).map(|gi| gi as isize).collect();
                        let sd_b: Vec<f32> = (lo..hi)
                            .map(|gi| {
                                gemm::dot(
                                    &e1g[gi * d..(gi + 1) * d],
                                    &e2g[gi * d..(gi + 1) * d],
                                )
                            })
                            .collect();
                        let t1b = &tau1g_vec[lo..hi];
                        let t2b = &tau2g_vec[lo..hi];
                        let w1b = weights(variant, &u1g[lo..hi], t1b, eps, bgf);
                        let w2b = weights(variant, &u2g[lo..hi], t2b, eps, bgf);
                        let gbar1b: Vec<f32> = w1b.iter().map(|w| w / bgf).collect();
                        let gbar2b: Vec<f32> = w2b.iter().map(|w| w / bgf).collect();
                        (
                            softmax::masked_exp_rowsum_bwd_col_range(
                                &e1g[lo * d..hi * d], e2b, &diag_b, &sd_b, t1b, &gbar1b,
                                denom, mb, bg, d, offset, offset + bl, threads,
                            ),
                            softmax::masked_exp_rowsum_bwd_col_range(
                                &e2g[lo * d..hi * d], e1b, &diag_b, &sd_b, t2b, &gbar2b,
                                denom, mb, bg, d, offset, offset + bl, threads,
                            ),
                        )
                    };
                    if single {
                        colsum1 = p1;
                        colsum2 = p2;
                    } else {
                        add_assign(&mut colsum1, &p1);
                        add_assign(&mut colsum2, &p2);
                    }
                }
                (colsum1, colsum2)
            }
            LossShard::On(fx) => {
                ensure!(
                    bg % bl == 0 && offset % bl == 0,
                    "--loss-shard on needs block-aligned batches \
                     (global {bg}, local {bl}, offset {offset})"
                );
                // this worker's rows' contribution to EVERY destination
                // block, exchanged for the ascending-source fold over its
                // own columns; both halves of the segment travel together
                let summed = fx.exchange(2 * bl * d, &mut |s, seg| {
                    let (lo, hi) = (s * bl, (s + 1) * bl);
                    let p1 = softmax::masked_exp_rowsum_bwd_col_range(
                        &cache.e1, e2b, &diag, &sd, tau1l, &gbar1, denom, bl, bg, d, lo, hi,
                        threads,
                    );
                    let p2 = softmax::masked_exp_rowsum_bwd_col_range(
                        &cache.e2, e1b, &diag, &sd, tau2l, &gbar2, denom, bl, bg, d, lo, hi,
                        threads,
                    );
                    seg[..bl * d].copy_from_slice(&p1);
                    seg[bl * d..].copy_from_slice(&p2);
                })?;
                ensure!(summed.len() == 2 * bl * d, "feature-grad exchange segment len");
                let colsum2 = summed[bl * d..].to_vec();
                let mut colsum1 = summed;
                colsum1.truncate(bl * d);
                (colsum1, colsum2)
            }
        };
        add_assign(&mut de2, &colsum1);
        add_assign(&mut de1, &colsum2);

        // ---- backprop through normalize + encoders ----------------------
        // segment-ordered emission (DESIGN.md §11): each leaf's gradient
        // goes to the sink the moment it is final, image side first —
        // its buckets reduce in the background while the text backward
        // (the t.tok scatter, usually the largest leaf) still runs.
        // Cotangents accumulate in f32; under bf16 only the FINAL
        // per-leaf gradients are rounded to storage width before
        // emission (DESIGN.md §12) — so the wire's own bf16 rounding of
        // the local contribution is a no-op and serial vs bucketed paths
        // see identical payloads.
        let dpooled1 = norm::l2_normalize_bwd(&cache.pooled1, &cache.norms1, &de1, bl, d, threads);
        let (mut dw, mut dbv) =
            encoder::image_bwd(&cache.xbar, &dpooled1, bl, m.model.v_patch_dim, d, threads);
        self.precision.quantize(&mut dw);
        self.precision.quantize(&mut dbv);
        sink(self.layout.vproj.0, &dw);
        sink(self.layout.vbias.0, &dbv);
        let dpooled2 = norm::l2_normalize_bwd(&cache.pooled2, &cache.norms2, &de2, bl, d, threads);
        let (mut dtok, mut dbt) =
            encoder::text_bwd(texts, &dpooled2, bl, m.model.t_len, m.model.t_vocab, d);
        self.precision.quantize(&mut dtok);
        self.precision.quantize(&mut dbt);
        sink(self.layout.ttok.0, &dtok);
        sink(self.layout.tbias.0, &dbt);

        // ---- loss + temperature gradients -------------------------------
        let loss = local_loss(variant, u1l, u2l, tau1l, tau2l, eps, rho, bgf, k as f32);
        let tau_out = match variant {
            "gcl" => TauGrads::Global(0.0),
            "gcl_v0" | "mbcl" => TauGrads::Global(sum(&dtau1) + sum(&dtau2)),
            "rgcl_g" => {
                // Eq. (10): per-worker log terms + the 2ρ constant split
                // across workers + the exp-path τ gradient
                let mut log_terms = 0.0f32;
                for i in 0..bl {
                    log_terms += (eps + u1l[i]).ln() + (eps + u2l[i]).ln();
                }
                TauGrads::Global(
                    log_terms / bgf + 2.0 * rho / k as f32 + sum(&dtau1) + sum(&dtau2),
                )
            }
            _ => {
                debug_assert!(individual);
                // Eq. (9), per local sample: the surrogate's dτ carries
                // the 1/Bg batch scale — rescale to the per-sample
                // estimator (see losses.py)
                let tau1v: Vec<f32> = (0..bl)
                    .map(|i| (eps + u1l[i]).ln() + rho + bgf * dtau1[i])
                    .collect();
                let tau2v: Vec<f32> = (0..bl)
                    .map(|i| (eps + u2l[i]).ln() + rho + bgf * dtau2[i])
                    .collect();
                TauGrads::Individual { tau1: tau1v, tau2: tau2v }
            }
        };
        self.timers.step_s += t0.elapsed().as_secs_f64();
        Ok(StepEmit { loss, tau: tau_out })
    }
}

fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (a, b) in dst.iter_mut().zip(src) {
        *a += *b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(k: usize, bl: usize) -> NativeBackend {
        let m = Manifest::native("tiny", k, bl, 3).unwrap();
        NativeBackend::new(&m, Some("gcl"), 1).unwrap()
    }

    fn demo_inputs(m: &Manifest, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let params = m.load_init_params().unwrap();
        let mut rng = Rng::new(seed);
        let mut images = vec![0.0; m.local_batch * m.model.v_patches * m.model.v_patch_dim];
        rng.fill_normal(&mut images, 1.0);
        let texts: Vec<i32> = (0..m.local_batch * m.model.t_len)
            .map(|_| rng.below(m.model.t_vocab) as i32)
            .collect();
        (params, images, texts)
    }

    #[test]
    fn encode_produces_normalized_embeddings() {
        let mut rt = backend(2, 8);
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m, 7);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        assert_eq!(e1.len(), m.local_batch * m.model.d_embed);
        for row in e1.chunks(m.model.d_embed).chain(e2.chunks(m.model.d_embed)) {
            let n: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-3, "norm {n}");
        }
        let (e1b, _) = rt.encode(&params, &images, &texts).unwrap();
        assert_eq!(e1, e1b, "deterministic");
        assert!(rt.timers().encode_s > 0.0);
    }

    #[test]
    fn phase_g_gamma_one_equals_g() {
        let mut rt = backend(2, 8);
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m, 7);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        let e1g = [e1.clone(), e1.clone()].concat();
        let e2g = [e2.clone(), e2.clone()].concat();
        let bl = m.local_batch;
        let (u1, u2) = (vec![0.5; bl], vec![0.5; bl]);
        let tau = vec![0.05; bl];
        let (g1, _g2, u1n, u2n) = rt.phase_g(&e1g, &e2g, 0, &u1, &u2, &tau, &tau, 1.0).unwrap();
        assert_eq!(g1, u1n, "gamma = 1: u_new == g");
        assert!(u2n.iter().all(|v| v.is_finite()));
        assert!(g1.iter().all(|&v| v > 0.0), "exp-sums are positive");
        let (g1b, _, u1b, _) = rt.phase_g(&e1g, &e2g, 0, &u1, &u2, &tau, &tau, 0.25).unwrap();
        for i in 0..bl {
            let want = 0.75 * 0.5 + 0.25 * g1b[i];
            assert!((u1b[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    fn step_all_variants_run_and_shapes_match() {
        let mut rt = {
            let m = Manifest::native("tiny", 2, 8, 3).unwrap();
            NativeBackend::new(&m, None, 1).unwrap()
        };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m, 11);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        let e1g = [e1.clone(), e1.clone()].concat();
        let e2g = [e2.clone(), e2.clone()].concat();
        let bg = m.global_batch;
        let (u1g, u2g) = (vec![0.8; bg], vec![0.8; bg]);
        let taus: Vec<f32> = (0..bg).map(|i| 0.04 + 0.001 * i as f32).collect();
        for variant in VARIANTS {
            let tau = if variant == "rgcl_i" {
                TauInput::Individual { tau1g: &taus, tau2g: &taus }
            } else {
                TauInput::Global(0.05)
            };
            let out = rt
                .step(
                    variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, 0, 1e-8, 6.5, tau,
                    LossShard::Off,
                )
                .unwrap_or_else(|e| panic!("{variant}: {e:#}"));
            assert_eq!(out.grad.len(), m.n_params, "{variant}");
            assert!(out.loss.is_finite(), "{variant}");
            let gnorm: f32 = out.grad.iter().map(|g| g * g).sum::<f32>().sqrt();
            assert!(gnorm > 0.0 && gnorm.is_finite(), "{variant}: grad norm {gnorm}");
            match (variant, &out.tau) {
                ("gcl", TauGrads::Global(g)) => assert_eq!(*g, 0.0, "gcl has no tau grad"),
                ("rgcl_i", TauGrads::Individual { tau1, tau2 }) => {
                    assert_eq!(tau1.len(), m.local_batch);
                    assert_eq!(tau2.len(), m.local_batch);
                }
                (_, TauGrads::Global(g)) => assert!(g.is_finite(), "{variant}"),
                _ => panic!("{variant}: wrong tau grad kind"),
            }
        }
    }

    #[test]
    fn step_emit_segments_tile_and_match_step_bitwise() {
        let mut rt = {
            let m = Manifest::native("tiny", 2, 8, 3).unwrap();
            NativeBackend::new(&m, None, 2).unwrap()
        };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m, 13);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        let e1g = [e1.clone(), e1.clone()].concat();
        let e2g = [e2.clone(), e2.clone()].concat();
        let bg = m.global_batch;
        let (u1g, u2g) = (vec![0.7; bg], vec![0.6; bg]);
        for variant in VARIANTS {
            let taus: Vec<f32> = (0..bg).map(|i| 0.04 + 0.001 * i as f32).collect();
            let tau = if variant == "rgcl_i" {
                TauInput::Individual { tau1g: &taus, tau2g: &taus }
            } else {
                TauInput::Global(0.05)
            };
            let whole = rt
                .step(
                    variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, 0, 1e-8, 6.5,
                    tau.clone(),
                    LossShard::Off,
                )
                .unwrap();
            // emission: contiguous ascending segments (one per leaf)
            // whose concatenation is bitwise the whole gradient
            let mut assembled = vec![0.0f32; m.n_params];
            let mut cursor = 0usize;
            let mut n_segments = 0usize;
            let emit = rt
                .step_emit(
                    variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, 0, 1e-8, 6.5,
                    tau,
                    LossShard::Off,
                    &mut |off, seg| {
                        assert_eq!(off, cursor, "{variant}: segments must be contiguous");
                        assembled[off..off + seg.len()].copy_from_slice(seg);
                        cursor = off + seg.len();
                        n_segments += 1;
                    },
                )
                .unwrap();
            assert_eq!(cursor, m.n_params, "{variant}: segments tile [0, P)");
            assert_eq!(n_segments, 4, "{variant}: one segment per parameter leaf");
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&assembled), bits(&whole.grad), "{variant}");
            assert_eq!(emit.loss.to_bits(), whole.loss.to_bits(), "{variant}");
            assert_eq!(emit.tau, whole.tau, "{variant}");
        }
    }

    #[test]
    fn step_rejects_wrong_tau_kind_and_variant() {
        let mut rt = backend(2, 8);
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m, 5);
        let bg = m.global_batch;
        let d = m.model.d_embed;
        let feats = vec![0.1; bg * d];
        let u = vec![0.5; bg];
        let t = vec![0.05; bg];
        let r = rt.step(
            "gcl", &params, &images, &texts, &feats, &feats, &u, &u, 0, 1e-14, 0.0,
            TauInput::Individual { tau1g: &t, tau2g: &t },
            LossShard::Off,
        );
        assert!(r.is_err());
        let r = rt.step(
            "nonsense", &params, &images, &texts, &feats, &feats, &u, &u, 0, 1e-14, 0.0,
            TauInput::Global(0.05),
            LossShard::Off,
        );
        assert!(r.is_err());
    }

    /// K=1 smoke test of the §16 contract: a loopback exchange (the one
    /// rank's fill IS the fold) must leave every output bitwise equal to
    /// the unsharded path — the multi-rank matrix lives in
    /// `tests/native_backend.rs`.
    #[test]
    fn loss_shard_on_matches_off_at_k1() {
        use super::super::backend::FeatGradReduce;
        struct Loopback;
        impl FeatGradReduce for Loopback {
            fn exchange(
                &mut self,
                seg_len: usize,
                fill: &mut dyn FnMut(usize, &mut [f32]),
            ) -> Result<Vec<f32>> {
                let mut seg = vec![0.0f32; seg_len];
                fill(0, &mut seg);
                Ok(seg)
            }
        }
        let mut rt = {
            let m = Manifest::native("tiny", 1, 8, 3).unwrap();
            NativeBackend::new(&m, None, 2).unwrap()
        };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m, 23);
        let (e1g, e2g) = rt.encode(&params, &images, &texts).unwrap();
        let bg = m.global_batch;
        let (u1g, u2g) = (vec![0.7; bg], vec![0.6; bg]);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for variant in VARIANTS {
            let taus: Vec<f32> = (0..bg).map(|i| 0.04 + 0.001 * i as f32).collect();
            let tau = || {
                if variant == "rgcl_i" {
                    TauInput::Individual { tau1g: &taus, tau2g: &taus }
                } else {
                    TauInput::Global(0.05)
                }
            };
            let off = rt
                .step(
                    variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, 0, 1e-8, 6.5,
                    tau(),
                    LossShard::Off,
                )
                .unwrap();
            let on = rt
                .step(
                    variant, &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, 0, 1e-8, 6.5,
                    tau(),
                    LossShard::On(&mut Loopback),
                )
                .unwrap();
            assert_eq!(bits(&on.grad), bits(&off.grad), "{variant}");
            assert_eq!(on.loss.to_bits(), off.loss.to_bits(), "{variant}");
            assert_eq!(on.tau, off.tau, "{variant}");
        }
        // the gauge prices sharding as the strict memory win it is
        assert!(rt.loss_peak_bytes(false) > rt.loss_peak_bytes(true));
    }

    #[test]
    fn new_rejects_artifact_manifest_and_unknown_variant() {
        let m = Manifest::native("tiny", 2, 8, 0).unwrap();
        assert!(NativeBackend::new(&m, Some("not_a_variant"), 1).is_err());
        let mut art = m.clone();
        art.native = false;
        // artifact manifests need executables, which this one lacks — but
        // NativeBackend must reject it on kind, not on a missing file
        let err = NativeBackend::new(&art, Some("gcl"), 1).unwrap_err();
        assert!(format!("{err}").contains("native"), "{err}");
    }

    #[test]
    fn nonlocal_indices_roll_like_python() {
        // bg=16, bl=8, offset=8 -> 0..8 ; offset=0 -> 8..16
        assert_eq!(nonlocal_indices(16, 8, 8), (0..8).collect::<Vec<_>>());
        assert_eq!(nonlocal_indices(16, 8, 0), (8..16).collect::<Vec<_>>());
        // K=4 middle rank rolls around the end
        assert_eq!(nonlocal_indices(8, 2, 4), vec![6, 7, 0, 1, 2, 3]);
    }
}
