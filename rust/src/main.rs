//! The `fastclip` CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   train       run one training configuration (flags or --config preset)
//!   eval        evaluate saved parameters on the synthetic benchmark
//!   exp `<id>`  regenerate a paper table/figure (see `exp list`)
//!   comm-bench  α–β cost-model sweep over node counts
//!   inspect     print an artifact bundle's manifest summary
//!   ckpt        inspect/verify training checkpoints (DESIGN.md §9)
//!   trace       analyze a `--trace-out` JSONL trace (DESIGN.md §14)
//!   lint        repo-invariant static analysis (DESIGN.md §17)
//!
//! Examples:
//!   fastclip train --algo fastclip-v3 --bundle artifacts/tiny_k2_b8 --steps 100
//!   fastclip train --ckpt-dir ckpts/run1 --ckpt-every 50 --steps 200
//!   fastclip train --ckpt-dir ckpts/run1 --resume latest --steps 200
//!   fastclip ckpt verify ckpts/run1
//!   fastclip exp table4 --setting medium --seeds 3
//!   fastclip exp timing --profile slingshot1
//!   fastclip inspect artifacts/tiny_k2_b8

use std::path::Path;

use anyhow::{bail, Context, Result};

use fastclip::bench;
use fastclip::ckpt::Checkpoint;
use fastclip::config::{Algorithm, GammaSchedule, OptimizerKind, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::output::{sparkline, Table};
use fastclip::runtime::Manifest;
use fastclip::util::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => train(&args),
        "eval" => eval(&args),
        "exp" => exp(&args),
        "comm-bench" => bench::timing::comm_bench(&args),
        "inspect" => inspect(&args),
        "ckpt" => ckpt_cmd(&args),
        "trace" => fastclip::telemetry::trace::trace_cmd(&args),
        "lint" => fastclip::lint::lint_cmd(&args),
        "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `fastclip help`)"),
    }
}

fn print_help() {
    println!(
        "fastclip — distributed CLIP training with compositional optimization\n\
         \n\
         USAGE: fastclip <command> [options]\n\
         \n\
         COMMANDS:\n\
           train       run one training configuration\n\
             --algo <id>        {algos}\n\
             --backend <id>     native|pjrt|auto (default auto; native needs\n\
                                no artifacts and runs everywhere)\n\
             --preset <id>      native model preset tiny|small|medium|base\n\
             --workers K --batch B --kernel-threads T   native topology\n\
             --precision f32|bf16   bf16 compute + half-width gradient wire\n\
                                (native backend; f32 master weights, DESIGN.md §12)\n\
             --wire f32|bf16|int8|topk   gradient wire codec (default follows\n\
                                --precision; int8 = exact 4x cut, topk = ~8x\n\
                                with error feedback — DESIGN.md §15)\n\
             --bundle <dir>     artifact bundle (default artifacts/tiny_k2_b8)\n\
             --config <file>    load a configs/*.toml preset instead of flags\n\
             --steps N --seed S --optimizer adamw|lamb|lion|sgdm\n\
             --iters-per-epoch N   epoch length for schedule bookkeeping\n\
             --lr P --warmup N     peak outer LR and warmup iterations\n\
             --gamma-min G | --gamma-const G | --decay-epochs E   inner-LR\n\
                                schedule\n\
             --eps E --rho R --tau-init T --tau-lr T --eval-every N\n\
             --n-train N --n-eval N --n-classes C   synthetic dataset shape\n\
             --nodes N --gpus-per-node M --network {nets}\n\
             --reduce naive|ring|sharded|auto   gradient-reduction strategy\n\
             --overlap on|off|auto   overlap bucketed reduction with backward\n\
             --loss-shard on|off|auto   shard the contrastive loss's pairwise\n\
                                terms across ranks — ~K-fold smaller loss-stage\n\
                                working set, bitwise-identical training (native\n\
                                backend; auto = on for native — DESIGN.md §16)\n\
             --bucket-mb N           bucket size for the overlap pipeline (MB)\n\
             --ckpt-dir <dir> --ckpt-every N --keep-last N   periodic snapshots\n\
             --resume <dir|latest>              resume a checkpointed run\n\
             --fail rank=R@iter=N    kill rank R at iteration N; survivors\n\
                                roll back and shrink the world (DESIGN.md §13)\n\
             --straggle rank=R:ms=M[,...]   per-rank latency skew before\n\
                                every collective (numerics unchanged)\n\
             --watchdog-ms N    collective watchdog (default 60000 when\n\
                                fault injection is active, unbounded otherwise)\n\
             --trace-out <file> write a per-rank JSONL trace (spans, events,\n\
                                metrics — DESIGN.md §14; analyze with `trace`)\n\
             --log-every N      heartbeat every N steps (iter/loss/lr/tau)\n\
             --quiet            suppress progress output (results still print)\n\
             --log-format <f>   text|json progress lines (default text)\n\
             --save <file>      save final parameters (f32 LE)\n\
           eval        evaluate parameters: --bundle <dir> --params <file>\n\
           exp <id>    regenerate a paper table/figure (exp list to enumerate)\n\
           comm-bench  cost-model sweep: --profile <net> --n-params P\n\
           inspect     <bundle-dir>: print manifest summary\n\
           ckpt        inspect <dir> | verify <dir>  (or --dir <dir>; a step\n\
                       dir or a ckpt root)\n\
           trace       summary <f> | verify <f> | diff <a> <b>  (JSONL traces)\n\
           lint        repo-invariant static analysis (DESIGN.md §17)\n\
             --root <dir>       repo root (default: discovered upward)\n\
             --deny-warnings    warnings fail the run (the CI policy)\n\
             --list-rules       print the rule catalog and exit\n",
        algos = Algorithm::all().map(|a| a.id()).join("|"),
        nets = "infiniband|slingshot1|slingshot2",
    );
}

fn build_config(args: &Args) -> Result<TrainConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        TrainConfig::from_file(path)?
    } else {
        let algo = Algorithm::from_id(&args.str_or("algo", "fastclip-v3"))?;
        TrainConfig::new(args.str_or("bundle", "artifacts/tiny_k2_b8"), algo)
    };
    if let Some(b) = args.get("bundle") {
        cfg.set_bundle(b);
    }
    // backend typos exit non-zero with the valid choices listed
    cfg.backend = fastclip::runtime::BackendKind::from_id(
        &args.str_or("backend", cfg.backend.id()),
    )?;
    cfg.preset = args.str_or("preset", &cfg.preset);
    cfg.n_workers = args.usize_or("workers", cfg.n_workers)?;
    cfg.local_batch = args.usize_or("batch", cfg.local_batch)?;
    cfg.kernel_threads = args.usize_or("kernel-threads", cfg.kernel_threads)?;
    // precision typos exit non-zero with the valid choices listed
    cfg.precision = fastclip::kernels::Precision::from_id(
        &args.str_or("precision", cfg.precision.id()),
    )?;
    // gradient wire codec (DESIGN.md §15): unset keeps the precision's
    // lossless default; codec typos exit non-zero with the choices listed
    if let Some(w) = args.get("wire") {
        cfg.wire = Some(fastclip::comm::WireCodec::from_id(w)?);
    }
    cfg.steps = args.u32_or("steps", cfg.steps)?;
    cfg.iters_per_epoch = args.u32_or("iters-per-epoch", cfg.iters_per_epoch)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.data.seed = cfg.seed;
    cfg.eps = args.f32_or("eps", cfg.eps)?;
    cfg.rho = args.f32_or("rho", cfg.rho)?;
    cfg.tau_init = args.f32_or("tau-init", cfg.tau_init)?;
    cfg.tau_lr = args.f32_or("tau-lr", cfg.tau_lr)?;
    cfg.eval_every = args.u32_or("eval-every", cfg.eval_every)?;
    cfg.nodes = args.usize_or("nodes", cfg.nodes)?;
    cfg.gpus_per_node = args.usize_or("gpus-per-node", cfg.gpus_per_node)?;
    cfg.network = fastclip::comm::ProfileName::from_id(
        &args.str_or("network", cfg.network.id()),
    )?;
    cfg.reduce = fastclip::comm::ReduceStrategy::from_id(
        &args.str_or("reduce", cfg.reduce.id()),
    )?;
    cfg.overlap = fastclip::comm::OverlapMode::from_id(
        &args.str_or("overlap", cfg.overlap.id()),
    )?;
    // sharded contrastive loss (DESIGN.md §16); mode typos exit non-zero
    // with the valid choices listed, on+pjrt is rejected by Trainer::new
    cfg.loss_shard = fastclip::runtime::LossShardMode::from_id(
        &args.str_or("loss-shard", cfg.loss_shard.id()),
    )?;
    if args.get("bucket-mb").is_some() {
        cfg.bucket_bytes = args.usize_or("bucket-mb", 0)? << 20;
    }
    cfg.lr.peak = args.f32_or("lr", cfg.lr.peak)?;
    cfg.lr.total_iters = cfg.steps;
    cfg.lr.warmup_iters = args.u32_or("warmup", cfg.steps / 10)?;
    cfg.data.n_train = args.usize_or("n-train", cfg.data.n_train)?;
    cfg.data.n_eval = args.usize_or("n-eval", cfg.data.n_eval)?;
    cfg.data.n_classes = args.usize_or("n-classes", cfg.data.n_classes)?;
    if let Some(k) = args.get("optimizer") {
        cfg.optimizer = fastclip::config::OptimizerConfig::with_kind(OptimizerKind::from_id(k)?);
    }
    if let Some(d) = args.get("ckpt-dir") {
        cfg.ckpt_dir = Some(d.to_string());
    }
    cfg.ckpt_every = args.u32_or("ckpt-every", cfg.ckpt_every)?;
    cfg.keep_last = args.usize_or("keep-last", cfg.keep_last)?;
    if let Some(r) = args.get("resume") {
        cfg.resume = Some(r.to_string());
    }
    // fault injection (DESIGN.md §13); grammar typos exit non-zero with
    // the expected grammar in the message (via cfg.validate below)
    if let Some(f) = args.get("fail") {
        cfg.fail = Some(f.to_string());
    }
    if let Some(sg) = args.get("straggle") {
        cfg.straggle = Some(sg.to_string());
    }
    cfg.watchdog_ms = args.u64_or("watchdog-ms", cfg.watchdog_ms)?;
    // telemetry (DESIGN.md §14): JSONL trace, heartbeat, progress channel
    if let Some(t) = args.get("trace-out") {
        cfg.trace_out = Some(t.to_string());
    }
    cfg.log_every = args.u32_or("log-every", cfg.log_every)?;
    cfg.quiet = cfg.quiet || args.flag("quiet");
    cfg.log_format = args.str_or("log-format", &cfg.log_format);
    let epochs = (cfg.steps / cfg.iters_per_epoch.max(1)).max(1);
    if let Some(g) = args.get("gamma-const") {
        cfg.gamma = GammaSchedule::Constant { gamma: g.parse().map_err(anyhow::Error::msg)? };
    } else if let Some(g) = args.get("gamma-min") {
        cfg.gamma = GammaSchedule::Cosine {
            gamma_min: g.parse().map_err(anyhow::Error::msg)?,
            decay_epochs: args.u32_or("decay-epochs", (epochs / 2).max(1))?,
        };
    }
    cfg.validate()?;
    Ok(cfg)
}

fn train(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let log = fastclip::telemetry::Logger::from_format(cfg.quiet, &cfg.log_format)?;
    let trainer = Trainer::new(cfg.clone())?;
    let m = trainer.manifest();
    log.status(&format!(
        "training {} via the {} backend ({}) for {} steps (K={} workers, modeled {}x{} {})",
        cfg.algorithm.name(),
        cfg.resolved_backend().id(),
        if m.native { format!("preset {}", m.preset) } else { cfg.artifact_dir.clone() },
        cfg.steps,
        m.k_workers,
        cfg.nodes,
        cfg.gpus_per_node,
        cfg.network.id(),
    ));
    let result = trainer.run()?;

    let losses: Vec<f32> = result.history.iter().map(|h| h.loss).collect();
    log.line(&format!("loss curve: {}", sparkline(&losses, 48)));
    let mut t = Table::new("Run summary", &["metric", "value"]);
    t.row(vec!["algorithm".into(), result.algorithm.into()]);
    t.row(vec!["final loss (tail-8 mean)".into(), format!("{:.4}", result.tail_loss(8))]);
    t.row(vec!["final tau".into(), format!("{:.4}", result.final_tau)]);
    t.row(vec!["Datacomp".into(), format!("{:.2}", result.final_eval.datacomp)]);
    t.row(vec!["Retrieval".into(), format!("{:.2}", result.final_eval.retrieval)]);
    t.row(vec!["IN & Variants".into(), format!("{:.2}", result.final_eval.in_variants)]);
    let ms = result.timing.per_iter_ms();
    t.row(vec!["iter total (ms, modeled)".into(), format!("{:.2}", ms.total)]);
    t.row(vec!["  compute".into(), format!("{:.2}", ms.compute)]);
    t.row(vec!["  pure comm".into(), format!("{:.2}", ms.comm_pure)]);
    t.row(vec!["  overlapped comm".into(), format!("{:.2}", ms.comm_overlap)]);
    t.row(vec!["  others".into(), format!("{:.2}", ms.others)]);
    t.row(vec!["real bytes moved".into(), format!("{}", result.comm_bytes)]);
    t.row(vec!["grad reduction".into(), result.reduce_algorithm.into()]);
    t.row(vec!["precision".into(), result.precision.into()]);
    t.row(vec!["grad wire codec".into(), result.wire.into()]);
    t.row(vec![
        "loss shard".into(),
        if result.loss_shard {
            format!("on (loss-stage peak {} bytes/rank)", result.loss_peak_bytes)
        } else {
            format!("off (loss-stage peak {} bytes/rank)", result.loss_peak_bytes)
        },
    ]);
    if result.overlap {
        t.row(vec![
            "overlap pipeline".into(),
            format!("on ({} buckets/iter)", result.n_buckets),
        ]);
        t.row(vec![
            "  reduction hidden/exposed".into(),
            format!(
                "{:.1} ms / {:.1} ms measured",
                result.hidden_comm_us as f64 / 1e3,
                result.exposed_comm_us as f64 / 1e3
            ),
        ]);
        // guarded: "n/a" (never NaN) when nothing was measured
        t.row(vec![
            "  hidden fraction".into(),
            result
                .timing
                .hidden_fraction()
                .map_or_else(|| "n/a".into(), |f| format!("{:.0}%", f * 100.0)),
        ]);
    } else {
        t.row(vec!["overlap pipeline".into(), "off (serial reduction)".into()]);
    }
    t.row(vec![
        "grad wire bytes/rank".into(),
        format!(
            "{} (naive would move {}, {:.2}x)",
            result.grad_wire_bytes,
            result.grad_wire_bytes_naive,
            result.grad_wire_bytes_naive as f64 / result.grad_wire_bytes.max(1) as f64
        ),
    ]);
    if result.shrinks > 0 {
        t.row(vec![
            "world shrank".into(),
            format!(
                "{} time(s): lost rank(s) {:?}, finished at K={}",
                result.shrinks, result.lost_ranks, result.final_world
            ),
        ]);
    }
    if let Some(step) = result.ckpt.resumed_at {
        t.row(vec![
            "resumed at step".into(),
            format!("{step} (restore {:.1} ms)", result.ckpt.restore_s * 1e3),
        ]);
    }
    if result.ckpt.snapshots > 0 {
        t.row(vec![
            "snapshots written".into(),
            format!("{} ({:.1} ms total)", result.ckpt.snapshots, result.ckpt.write_s * 1e3),
        ]);
    }
    t.row(vec!["wall time (s)".into(), format!("{:.1}", result.wall_s)]);
    t.print();

    if let Some(path) = args.get("save") {
        let bytes: Vec<u8> =
            result.final_params.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(path, bytes).with_context(|| format!("saving {path}"))?;
        log.status(&format!("saved {} params to {path}", result.final_params.len()));
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let cfg = build_config(args)?;
    let manifest = cfg.load_manifest()?;
    let params = match args.get("params") {
        Some(path) => {
            let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
            anyhow::ensure!(bytes.len() == manifest.n_params * 4, "params size mismatch");
            bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
        }
        None => manifest.load_init_params()?,
    };
    let mut rt = fastclip::runtime::create_backend(
        cfg.backend,
        &manifest,
        Some("gcl"),
        cfg.kernel_threads,
        cfg.precision,
    )?;
    let data_cfg = fastclip::config::DataConfig {
        n_eval: args.usize_or("n-eval", 256)?,
        n_classes: args.usize_or("n-classes", fastclip::config::DataConfig::default().n_classes)?,
        ..Default::default()
    };
    let ds = fastclip::data::Dataset::new(data_cfg, manifest.model_dims());
    let s = fastclip::eval::evaluate(rt.as_mut(), &ds, &params)?;
    let mut t = Table::new("Evaluation", &["task", "score"]);
    for (name, score) in &s.tasks {
        t.row(vec![name.clone(), format!("{score:.2}")]);
    }
    t.row(vec!["Retrieval (mean)".into(), format!("{:.2}", s.retrieval)]);
    t.row(vec!["IN & Variants (mean)".into(), format!("{:.2}", s.in_variants)]);
    t.row(vec!["Datacomp (mean)".into(), format!("{:.2}", s.datacomp)]);
    t.print();
    Ok(())
}

fn exp(args: &Args) -> Result<()> {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("list");
    if id == "list" {
        println!("available experiments:");
        for (k, v) in bench::EXPERIMENTS {
            println!("  {k:10} {v}");
        }
        return Ok(());
    }
    bench::run_experiment(id, args)
}

fn ckpt_cmd(args: &Args) -> Result<()> {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("help");
    let dir = args
        .positional
        .get(2)
        .cloned()
        .or_else(|| args.get("dir").map(|s| s.to_string()));
    let open = || -> Result<Checkpoint> {
        let dir = dir
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("usage: fastclip ckpt {sub} <checkpoint-dir>"))?;
        Checkpoint::open(Path::new(dir))
    };
    match sub {
        "inspect" => {
            let ck = open()?;
            let m = ck.meta();
            let mut t = Table::new(format!("Checkpoint {}", ck.dir().display()), &["field", "value"]);
            t.row(vec!["step".into(), m.step.to_string()]);
            t.row(vec!["world size".into(), m.world.to_string()]);
            t.row(vec!["algorithm".into(), m.algorithm.clone()]);
            t.row(vec!["optimizer".into(), m.optimizer.clone()]);
            t.row(vec!["grad reduction".into(), m.reduce.clone()]);
            t.row(vec!["n_params".into(), m.n_params.to_string()]);
            t.row(vec!["n_train".into(), m.n_train.to_string()]);
            t.row(vec!["local batch".into(), m.local_batch.to_string()]);
            t.row(vec!["seed / data seed".into(), format!("{} / {}", m.seed, m.data_seed)]);
            let mut bytes = 0u64;
            for b in &ck.manifest().blobs {
                bytes += (b.len * b.kind.width()) as u64;
                t.row(vec![
                    format!("blob {}", b.file),
                    format!("{} x {} ({:016x})", b.len, b.kind.id(), b.hash),
                ]);
            }
            t.row(vec!["total blob bytes".into(), bytes.to_string()]);
            t.print();
            Ok(())
        }
        "verify" => {
            let ck = open()?;
            let report = ck
                .verify()
                .with_context(|| format!("verifying {}", ck.dir().display()))?;
            println!(
                "OK: {} — {} blobs, {} bytes, all integrity hashes match",
                ck.dir().display(),
                report.blobs,
                report.bytes
            );
            Ok(())
        }
        "help" => {
            println!(
                "usage: fastclip ckpt <inspect|verify> <dir>\n\
                 <dir> is one step_NNNNNNNN directory or a checkpoint root\n\
                 (the most recent finalized step is used)"
            );
            Ok(())
        }
        // exit non-zero on typos so `ckpt verify` can gate scripts/CI
        other => bail!("unknown ckpt subcommand '{other}' (try `fastclip ckpt help`)"),
    }
}

fn inspect(args: &Args) -> Result<()> {
    let bundle = args
        .positional
        .get(1)
        .cloned()
        .or_else(|| args.get("bundle").map(|s| s.to_string()))
        .unwrap_or_else(|| "artifacts/tiny_k2_b8".into());
    let m = Manifest::load(&bundle)?;
    let mut t = Table::new(format!("Bundle {bundle}"), &["field", "value"]);
    t.row(vec!["preset".into(), m.preset.clone()]);
    t.row(vec!["n_params".into(), m.n_params.to_string()]);
    t.row(vec!["param leaves".into(), m.param_spec.len().to_string()]);
    t.row(vec!["K workers".into(), m.k_workers.to_string()]);
    t.row(vec!["local batch".into(), m.local_batch.to_string()]);
    t.row(vec!["global batch".into(), m.global_batch.to_string()]);
    t.row(vec!["d_embed".into(), m.model.d_embed.to_string()]);
    t.row(vec![
        "image".into(),
        format!("{} patches x {}", m.model.v_patches, m.model.v_patch_dim),
    ]);
    t.row(vec![
        "text".into(),
        format!("len {} vocab {}", m.model.t_len, m.model.t_vocab),
    ]);
    t.row(vec!["variants".into(), m.variants.join(", ")]);
    for e in &m.executables {
        t.row(vec![
            format!("exec {}", e.name),
            format!("{} inputs -> {} outputs", e.inputs.len(), e.outputs.len()),
        ]);
    }
    t.print();
    Ok(())
}
