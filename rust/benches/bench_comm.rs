//! Collective benchmarks: real in-process collectives (all_gather /
//! all_reduce / reduce_scatter) across worker counts and payload sizes,
//! the pluggable gradient-reduction algorithms with their bytes-on-wire
//! accounting (naive vs ring vs sharded — the before/after comparison of
//! DESIGN.md §4 "Gradient reduction"), the gradient wire codecs
//! (f32/bf16/int8/topk, DESIGN.md §15) over the ring reduction, and the
//! α–β cost model's analytic times for the same shapes — the
//! microbenchmark behind the Fig. 3 communication bars.

#[path = "harness.rs"]
mod harness;

use fastclip::comm::{
    reduction, Collective, CommWorld, CostModel, ProfileName, ReduceAlgo, ReduceCtx, WireCodec,
};
use harness::{black_box, Bench};

fn bench_all_reduce(k: usize, n: usize) {
    let world = CommWorld::new(k);
    Bench::new(format!("all_reduce_sum k={k} n={n}")).samples(20).warmup(2).run(|| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let h = world.handle(rank);
                std::thread::spawn(move || {
                    let mut buf = vec![rank as f32; n];
                    h.all_reduce_sum(&mut buf, WireCodec::F32).unwrap();
                    black_box(buf[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn bench_all_gather(k: usize, n: usize) {
    let world = CommWorld::new(k);
    Bench::new(format!("all_gather k={k} n={n}")).samples(20).warmup(2).run(|| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let h = world.handle(rank);
                std::thread::spawn(move || {
                    let buf = vec![rank as f32; n];
                    black_box(h.all_gather(&buf, WireCodec::F32).unwrap());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Executions per bench_reduction call (warmup + samples); divides the
/// accumulated wire counters back to per-reduction numbers.
const REDUCE_WARMUP: usize = 2;
const REDUCE_SAMPLES: usize = 20;
const REDUCE_EXECS: u64 = (REDUCE_WARMUP + REDUCE_SAMPLES) as u64;

/// One full gradient reduction + optimizer-style apply with `algo` over
/// the `wire` codec. Returns the CommStats snapshot so main() can print
/// the wire-byte comparison next to the timings.
fn bench_reduction(
    algo: ReduceAlgo,
    wire: WireCodec,
    k: usize,
    n: usize,
) -> fastclip::comm::CommStatsSnapshot {
    let world = CommWorld::new(k);
    Bench::new(format!("reduce[{}/{}] k={k} n={n}", algo.id(), wire.id()))
        .samples(REDUCE_SAMPLES)
        .warmup(REDUCE_WARMUP)
        .run(|| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let h = world.handle(rank);
                std::thread::spawn(move || {
                    let ctx = ReduceCtx::for_run(wire, n);
                    let mut grad = vec![rank as f32 + 0.5; n];
                    let mut params = vec![1.0f32; n];
                    reduction(algo)
                        .reduce_and_apply(&h, &mut grad, &mut params, &ctx, &mut |p, g| {
                            for (pi, gi) in p.iter_mut().zip(g) {
                                *pi -= 1e-3 * gi;
                            }
                        })
                        .unwrap();
                    black_box(params[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    world.stats.snapshot()
}

fn main() {
    println!("== real in-process collectives (threads, 1 host) ==");
    for k in [2usize, 4] {
        for n in [1 << 10, 1 << 16, 1 << 20] {
            bench_all_reduce(k, n);
        }
    }
    for k in [2usize, 4] {
        bench_all_gather(k, 1 << 14);
    }

    println!("\n== gradient-reduction algorithms (real, + bytes-on-wire) ==");
    for k in [2usize, 4] {
        let n = 1 << 20;
        let mut snaps = Vec::new();
        for algo in ReduceAlgo::all() {
            snaps.push((algo, bench_reduction(algo, WireCodec::F32, k, n)));
        }
        // counters accumulate over all REDUCE_EXECS executions and all k
        // ranks; divide back to one rank's traffic for ONE reduction
        let per_reduction = |total: u64| total / k as u64 / REDUCE_EXECS;
        let naive_wire = per_reduction(snaps[0].1.grad_wire_bytes);
        println!("  -- grad bytes-on-wire per rank per reduction, K={k}, n={n} f32 --");
        for (algo, s) in &snaps {
            let wire = per_reduction(s.grad_wire_bytes);
            println!(
                "  {:8} {:>14} B   ({:.2}x fewer than naive)",
                algo.id(),
                wire,
                naive_wire as f64 / wire.max(1) as f64
            );
            assert_eq!(
                s.grad_wire_bytes_naive, snaps[0].1.grad_wire_bytes,
                "baseline counter must match the naive run"
            );
        }
        let sharded = snaps.iter().find(|(a, _)| *a == ReduceAlgo::Sharded).unwrap();
        assert!(
            sharded.1.grad_wire_bytes < sharded.1.grad_wire_bytes_naive,
            "sharded must move strictly fewer gradient bytes than naive for K={k}"
        );
    }

    println!("\n== gradient wire codecs over the ring reduction (DESIGN.md §15) ==");
    {
        let (k, n) = (4usize, 1 << 20);
        let mut f32_wire = 0u64;
        for wire in WireCodec::all() {
            let s = bench_reduction(ReduceAlgo::Ring, wire, k, n);
            let per = s.grad_wire_bytes / k as u64 / REDUCE_EXECS;
            if wire == WireCodec::F32 {
                f32_wire = per;
            }
            println!(
                "  {:5} {:>14} B/rank/reduction   ({:.2}x fewer than f32)",
                wire.id(),
                per,
                f32_wire as f64 / per.max(1) as f64
            );
        }
    }

    println!("\n== alpha-beta cost model (paper-scale volumes, analytic) ==");
    for profile in [ProfileName::InfiniBand, ProfileName::Slingshot1, ProfileName::Slingshot2] {
        for nodes in [2usize, 8] {
            let m = CostModel::new(profile.profile(), nodes, 4);
            let k = m.world_size();
            let (bl, d, p) = (128usize, 512usize, 151_000_000usize);
            println!(
                "{:<12} {}n: featAG {:>8.3}ms  uAG {:>8.4}ms  RS {:>8.3}ms  gradAR {:>9.3}ms",
                profile.id(),
                nodes,
                m.time(Collective::AllGather, 2 * bl * d * 4) * 1e3,
                m.time(Collective::AllGather, 2 * bl * 4) * 1e3,
                m.time(Collective::ReduceScatter, 2 * k * bl * d * 4) * 1e3,
                m.time(Collective::AllReduce, p * 4) * 1e3,
            );
            println!(
                "{:<12} {}n: grad reduce  naive {:>9.3}ms  ring {:>9.3}ms  sharded {:>9.3}ms  -> auto picks {}",
                profile.id(),
                nodes,
                m.reduce_time(ReduceAlgo::Naive, p * 4) * 1e3,
                m.reduce_time(ReduceAlgo::Ring, p * 4) * 1e3,
                m.reduce_time(ReduceAlgo::Sharded, p * 4) * 1e3,
                m.cheapest_reduce(p * 4).id(),
            );
        }
    }
}
