//! Tiny CLI argument parser: `--flag`, `--key value`, `--key=value` and
//! positional arguments. Built in-tree (no clap in the vendored crate set).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// every --key seen, for unknown-option detection
    seen: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `std::env::args().skip(1)`
    /// in main. Flags are options without a following value; an option's
    /// value may be attached with `=` or given as the next token.
    /// Bare `-x` short options are not supported (we use none).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` separator: rest are positional
                    args.positional.extend(iter);
                    break;
                }
                let key;
                if let Some((k, v)) = rest.split_once('=') {
                    key = k.to_string();
                    args.options.insert(key.clone(), v.to_string());
                } else if iter.peek().is_some_and(|n| !n.starts_with("--")) {
                    key = rest.to_string();
                    // the peek above guarantees a next token
                    args.options.insert(key.clone(), iter.next().unwrap_or_default());
                } else {
                    key = rest.to_string();
                    args.flags.push(key.clone());
                }
                args.seen.push(key);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required option --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        self.parse_or(name, default)
    }

    pub fn u32_or(&self, name: &str, default: u32) -> Result<u32> {
        self.parse_or(name, default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        self.parse_or(name, default)
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        self.parse_or(name, default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        self.parse_or(name, default)
    }

    fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => {
                v.parse().map_err(|e| anyhow!("invalid value for --{name} ('{v}'): {e}"))
            }
        }
    }

    /// Error if any provided option/flag is not in `known` — catches typos.
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in &self.seen {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn positional_and_options() {
        let a = parse(&["train", "--steps", "100", "--algo=fastclip-v3", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("algo"), Some("fastclip-v3"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_access() {
        let a = parse(&["--n", "42", "--lr", "1e-3"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 42);
        assert!((a.f32_or("lr", 0.0).unwrap() - 1e-3).abs() < 1e-9);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.usize_or("lr", 0).is_err());
    }

    #[test]
    fn required_missing_errors() {
        let a = parse(&["cmd"]);
        assert!(a.required("out").is_err());
        assert!(parse(&["--out", "x"]).required("out").is_ok());
    }

    #[test]
    fn double_dash_separator() {
        let a = parse(&["--a", "1", "--", "--not-an-option"]);
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn negative_number_is_a_value() {
        // "-3" does not start with "--" so it is consumed as the value
        let a = parse(&["--shift", "-3"]);
        assert_eq!(a.get("shift"), Some("-3"));
    }

    #[test]
    fn unknown_option_detection() {
        let a = parse(&["--steps", "5", "--typo", "x"]);
        assert!(a.check_known(&["steps"]).is_err());
        assert!(a.check_known(&["steps", "typo"]).is_ok());
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse(&["--dry-run", "--steps", "3"]);
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("steps"), Some("3"));
    }
}
