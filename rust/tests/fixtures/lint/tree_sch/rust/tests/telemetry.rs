#[test]
fn metrics() {
    assert_metric("loss.real");
    assert_metric("foo.bar");
}
