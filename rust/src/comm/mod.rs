//! Communication substrate: in-process collectives between worker
//! threads, pluggable gradient-reduction algorithms, and the analytic
//! interconnect cost model.
//!
//! Numerics are REAL — bytes actually move between workers through shared
//! slots — while *time* is accounted analytically by [`CostModel`]
//! (α–β ring collectives, hierarchical intra-/inter-node), because the
//! testbed is threads on one host, not GPUs across a fabric. The paper's
//! communication claims are volume arguments (ALL_GATHER of scalar `u`
//! vs REDUCE_SCATTER of feature-sized terms; sharded vs replicated
//! gradient reduction), which volume-based accounting preserves exactly
//! (DESIGN.md §1).
//!
//! # Calling convention
//!
//! Every method on [`WorkerComm`] and every
//! [`GradientReduction::reduce_and_apply`] call is a *collective*: all K
//! ranks must call the same operation in the same order (lockstep), as
//! with MPI/NCCL. A rank that passes a different buffer length panics.
//! Collectives return `Ok` only after every rank's contribution is
//! visible, and buffers handed in by value are safe to reuse immediately
//! on return.
//!
//! # Fault model
//!
//! A rank that stops participating no longer deadlocks the world: every
//! world carries a shared [`CancellationToken`], every barrier is a
//! [`CancellableBarrier`], and every collective returns
//! `Err(`[`CommError::RanksLost`]`)` once a loss is declared — including
//! mid-collective, from every waiter, bounded by an optional watchdog
//! ([`CommError::Watchdog`]). [`FaultPlan`] parses the deterministic
//! injection grammar (`--fail rank=R@iter=N`, `--straggle rank=R:ms=M`)
//! the trainer and tests drive this machinery with. See DESIGN.md §13
//! for the failure model and the live-shrink protocol built on top.
//!
//! # Gradient-reduction algorithms
//!
//! [`collective`] provides three interchangeable [`GradientReduction`]
//! implementations — [`NaiveAllReduce`] (gather + local reduce),
//! [`RingAllReduce`] (reduce-scatter + all-gather of the gradient) and
//! [`ShardedReduceScatter`] (the paper's strategy: reduce-scatter the
//! gradient, apply this rank's optimizer shard, all-gather updated
//! parameters). All three leave parameters bitwise identical; they differ
//! in bytes-on-wire and local work, which [`CommStats`] and
//! [`CostModel::reduce_time`] account per algorithm.
//! [`CostModel::cheapest_reduce`] implements the α–β selection policy
//! behind [`ReduceStrategy::Auto`]. Under `--loss-shard on` the trait
//! carries a fourth leg, [`GradientReduction::reduce_feature_grads`]:
//! the sharded contrastive loss exchanges per-rank feature-gradient
//! segments through [`WorkerComm::exchange_block_sums`], charged
//! separately as `featgrad_wire_bytes` (DESIGN.md §16).
//!
//! # Wire codecs
//!
//! What travels on the wire is decided by a [`WireCodec`] ([`codec`],
//! DESIGN.md §15): `f32` identity, `bf16` half-width rounding, `int8`
//! blockwise quantization (4× cut) or `topk` sparsification with
//! per-rank error-feedback residuals ([`EfState`]). The codec — plus
//! the residual state — rides in a [`ReduceCtx`] through every
//! reduction signature; collectives charge the codec's exact encoded
//! bytes and [`ReduceStrategy::Auto`] prices algorithms with them.
//!
//! # Overlapped reduction
//!
//! All three algorithms also reduce **bucket-wise**
//! ([`GradientReduction::reduce_bucket`] over a [`BucketPlan`]), which is
//! bitwise-identical to the whole-vector reduce for any bucket size and
//! feeds the [`OverlapPipeline`]: a background worker reduces finished
//! buckets while the backward pass is still writing later ones, hiding
//! wire time behind compute (`--overlap on|off|auto`, DESIGN.md §11).
//! [`CommStats`] splits the measured reduction time into
//! `hidden_comm_us` / `exposed_comm_us` so overlapped runs never
//! double-count the win.
//!
//! # Example
//!
//! Four ranks reduce a gradient with the sharded strategy and apply a
//! plain SGD step; parameters end up replicated and identical to a naive
//! all-reduce:
//!
//! ```
//! use fastclip::comm::{reduction, CommWorld, ReduceAlgo, ReduceCtx};
//!
//! let k = 4;
//! let n = 10; // non-divisible: ranks own chunks of 3,3,3,1
//! let world = CommWorld::new(k);
//! let handles: Vec<_> = (0..k)
//!     .map(|rank| {
//!         let comm = world.handle(rank);
//!         std::thread::spawn(move || {
//!             let mut grad: Vec<f32> = (0..n).map(|i| (i + rank) as f32).collect();
//!             let mut params = vec![1.0f32; n];
//!             reduction(ReduceAlgo::Sharded)
//!                 .reduce_and_apply(
//!                     &comm,
//!                     &mut grad,
//!                     &mut params,
//!                     // f32 identity wire — or ReduceCtx::new(WireCodec::Bf16)
//!                     // etc. for a compressed gradient wire
//!                     &ReduceCtx::f32(),
//!                     &mut |p, g| {
//!                         for (pi, gi) in p.iter_mut().zip(g) {
//!                             *pi -= 0.1 * gi; // each rank updates only its shard
//!                         }
//!                     },
//!                 )
//!                 .unwrap(); // Err only when the world is cancelled (a rank lost)
//!             params
//!         })
//!     })
//!     .collect();
//! let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
//! // replicated: every rank holds the same updated parameters
//! assert!(results.iter().all(|r| r == &results[0]));
//! // and the sharded strategy moved fewer gradient bytes than naive would
//! let s = world.stats.snapshot();
//! assert!(s.grad_wire_bytes < s.grad_wire_bytes_naive);
//! ```

pub mod bucket;
pub mod codec;
pub mod collective;
mod cost_model;
pub mod fault;
pub mod overlap;
mod world;

pub use bucket::{Bucket, BucketPlan};
pub use codec::{EfState, ReduceCtx, WireCodec};
pub use collective::{
    reduction, GradientReduction, NaiveAllReduce, ReduceAlgo, ReduceStrategy, ReducedSegment,
    RingAllReduce, ShardedReduceScatter,
};
pub use cost_model::{Collective, CostModel, ProfileName};
pub use fault::{
    parse_fail, parse_straggle, CancellableBarrier, CancellationToken, CommError, FailSpec,
    FaultPlan, StraggleSpec,
};
pub use overlap::{OverlapMode, OverlapPipeline, OverlapReport};
pub use world::{
    chunk_bounds, CommResult, CommStats, CommStatsSnapshot, CommWorld, TraceEvent, TraceEventKind,
    WorkerComm,
};
