//! Fused row-reduction kernels over pairwise similarities: the masked
//! exp row-sum of the FastCLIP contrastive losses (forward + backward),
//! plus numerically-stable fused row softmax / logsumexp.
//!
//! The masked exp row-sum mirrors `python/compile/kernels/contrastive.py`
//! exactly in structure: the (m, n) similarity matrix is never
//! materialized — each output row consumes one anchor row against the
//! candidate block, with the exp-reduction fused into the similarity dot
//! products, and the backward pass *recomputes* the probabilities instead
//! of storing them (FlashAttention-style), so memory traffic stays
//! O((m+n)·d).
//!
//! Semantics (the paper's inner function g of Eq. (1); DESIGN.md §3):
//!
//! ```text
//! g_i = (1/denom) · Σ_{j ≠ diag[i]} exp((<a_i, b_j> − sd_i) / τ_i)
//! ```
//!
//! `diag[i] = -1` disables the mask (the distributed column form, where
//! row i's positive pair lives on another worker and `sd_i` is passed in
//! precomputed). The `sd` path's own cotangent (`dsd_i = −(ḡ_i/τ_i)·g_i`)
//! is applied by the caller, which knows whether `sd` came from live
//! embeddings.
//!
//! Determinism: identical contract to [`super::gemm`] — threads partition
//! output rows, every reduction runs in ascending index order, and each
//! kernel is bitwise equal to its `*_ref` scalar reference.

use super::gemm::dot;
use super::par_rows;

/// Sentinel for "no masked column" in `diag`.
pub const NO_DIAG: isize = -1;

#[allow(clippy::too_many_arguments)]
fn check_shapes(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    m: usize,
    n: usize,
    d: usize,
) {
    assert_eq!(a.len(), m * d, "anchor shape");
    assert_eq!(b.len(), n * d, "candidate shape");
    assert_eq!(diag.len(), m, "diag len");
    assert_eq!(sd.len(), m, "sd len");
    assert_eq!(tau.len(), m, "tau len");
}

/// Forward masked exp row-sum (fused: no similarity matrix materialized).
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
    threads: usize,
) -> Vec<f32> {
    check_shapes(a, b, diag, sd, tau, m, n, d);
    let mut g = vec![0.0f32; m];
    par_rows(&mut g, m, 1, threads, |lo, hi, chunk| {
        for i in lo..hi {
            let arow = &a[i * d..i * d + d];
            let inv_tau = 1.0 / tau[i];
            let mut acc = 0.0f32;
            for j in 0..n {
                if j as isize == diag[i] {
                    continue;
                }
                acc += ((dot(arow, &b[j * d..j * d + d]) - sd[i]) * inv_tau).exp();
            }
            chunk[i - lo] = acc / denom;
        }
    });
    g
}

/// Scalar single-threaded reference for [`masked_exp_rowsum`] — same
/// summation tree (ascending j).
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_ref(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
) -> Vec<f32> {
    check_shapes(a, b, diag, sd, tau, m, n, d);
    let mut g = vec![0.0f32; m];
    for i in 0..m {
        // the reciprocal is shared with the vectorized kernel: x * (1/τ)
        // and x / τ round differently, and the contract is BITWISE
        let inv_tau = 1.0 / tau[i];
        let mut acc = 0.0f32;
        for j in 0..n {
            if j as isize == diag[i] {
                continue;
            }
            let mut s = 0.0f32;
            for q in 0..d {
                s += a[i * d + q] * b[j * d + q];
            }
            acc += ((s - sd[i]) * inv_tau).exp();
        }
        g[i] = acc / denom;
    }
    g
}

/// Backward, row side. Given the cotangent `gbar` of g:
///
/// ```text
/// da_i  = (ḡ_i/τ_i) · Σ_j p_ij · b_j            p_ij = e_ij / denom
/// dτ_i  = −(ḡ_i/τ_i²) · Σ_j p_ij · (s_ij − sd_i)
/// ```
///
/// The probabilities are recomputed tile-free per row; reductions run in
/// ascending j. Returns `(da (m,d), dtau (m))`.
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_bwd_row(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    gbar: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    check_shapes(a, b, diag, sd, tau, m, n, d);
    assert_eq!(gbar.len(), m, "gbar len");
    // da and dtau share one fused pass (the Pallas _bwd_row_kernel shape):
    // the similarity dot and exp — the dominant cost — are computed once
    // per (i, j). Both outputs are row-partitioned together through a
    // (d+1)-wide scratch row, split apart at the end.
    let mut fused = vec![0.0f32; m * (d + 1)];
    par_rows(&mut fused, m, d + 1, threads, |lo, hi, chunk| {
        for i in lo..hi {
            let arow = &a[i * d..i * d + d];
            let inv_tau = 1.0 / tau[i];
            let c = gbar[i] * inv_tau;
            let row = &mut chunk[(i - lo) * (d + 1)..(i - lo + 1) * (d + 1)];
            let (darow, ztail) = row.split_at_mut(d);
            let mut acc = 0.0f32;
            for j in 0..n {
                if j as isize == diag[i] {
                    continue;
                }
                let brow = &b[j * d..j * d + d];
                let z = dot(arow, brow) - sd[i];
                let p = (z * inv_tau).exp() / denom;
                let w = c * p;
                for (dv, bv) in darow.iter_mut().zip(brow) {
                    *dv += w * *bv;
                }
                acc += p * z;
            }
            ztail[0] = -(gbar[i] * inv_tau * inv_tau) * acc;
        }
    });
    let mut da = vec![0.0f32; m * d];
    let mut dtau = vec![0.0f32; m];
    for i in 0..m {
        da[i * d..(i + 1) * d].copy_from_slice(&fused[i * (d + 1)..i * (d + 1) + d]);
        dtau[i] = fused[i * (d + 1) + d];
    }
    (da, dtau)
}

/// Scalar reference for [`masked_exp_rowsum_bwd_row`].
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_bwd_row_ref(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    gbar: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut da = vec![0.0f32; m * d];
    let mut dtau = vec![0.0f32; m];
    for i in 0..m {
        let inv_tau = 1.0 / tau[i];
        let c = gbar[i] * inv_tau;
        let mut acc = 0.0f32;
        for j in 0..n {
            if j as isize == diag[i] {
                continue;
            }
            let mut s = 0.0f32;
            for q in 0..d {
                s += a[i * d + q] * b[j * d + q];
            }
            let z = s - sd[i];
            let p = (z * inv_tau).exp() / denom;
            let w = c * p;
            for q in 0..d {
                da[i * d + q] += w * b[j * d + q];
            }
            acc += p * z;
        }
        dtau[i] = -(gbar[i] * inv_tau * inv_tau) * acc;
    }
    (da, dtau)
}

/// Backward, candidate side: `db_j = Σ_i (ḡ_i/τ_i) · p_ij · a_i`,
/// reduced over rows i in ascending order; threads partition the rows of
/// `db` (the j axis), mirroring the transposed-grid Pallas col kernel.
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_bwd_col(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    gbar: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
    threads: usize,
) -> Vec<f32> {
    masked_exp_rowsum_bwd_col_range(a, b, diag, sd, tau, gbar, denom, m, n, d, 0, n, threads)
}

/// Column-range form of [`masked_exp_rowsum_bwd_col`]: computes `db_j`
/// only for the global candidate columns `j ∈ [col_lo, col_hi)`,
/// returning a `(col_hi − col_lo, d)` block. `diag[i]` holds GLOBAL
/// column indices, so the positive-pair mask applies regardless of
/// which range is requested.
///
/// This is the sharded-loss building block (DESIGN.md §16): every
/// output column's reduction is an independent ascending-i fold, so
/// the range output is bitwise-identical to the corresponding row
/// slice of the full `bwd_col` output — threads partition only the
/// range's columns and never split a column's reduction.
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_bwd_col_range(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    gbar: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
    col_lo: usize,
    col_hi: usize,
    threads: usize,
) -> Vec<f32> {
    check_shapes(a, b, diag, sd, tau, m, n, d);
    assert_eq!(gbar.len(), m, "gbar len");
    assert!(col_lo <= col_hi && col_hi <= n, "column range [{col_lo},{col_hi}) out of 0..{n}");
    let nr = col_hi - col_lo;
    let mut db = vec![0.0f32; nr * d];
    par_rows(&mut db, nr, d, threads, |lo, hi, chunk| {
        for i in 0..m {
            let arow = &a[i * d..i * d + d];
            let inv_tau = 1.0 / tau[i];
            let c = gbar[i] * inv_tau;
            for j in lo..hi {
                let jg = col_lo + j;
                if jg as isize == diag[i] {
                    continue;
                }
                let brow = &b[jg * d..jg * d + d];
                let p = ((dot(arow, brow) - sd[i]) * inv_tau).exp() / denom;
                let w = c * p;
                let dbrow = &mut chunk[(j - lo) * d..(j - lo + 1) * d];
                for (dv, av) in dbrow.iter_mut().zip(arow) {
                    *dv += w * *av;
                }
            }
        }
    });
    db
}

/// Scalar reference for [`masked_exp_rowsum_bwd_col_range`] — same
/// ascending-i fold per output column.
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_bwd_col_range_ref(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    gbar: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
    col_lo: usize,
    col_hi: usize,
) -> Vec<f32> {
    assert!(col_lo <= col_hi && col_hi <= n, "column range [{col_lo},{col_hi}) out of 0..{n}");
    let nr = col_hi - col_lo;
    let mut db = vec![0.0f32; nr * d];
    for i in 0..m {
        let inv_tau = 1.0 / tau[i];
        let c = gbar[i] * inv_tau;
        for j in 0..nr {
            let jg = col_lo + j;
            if jg as isize == diag[i] {
                continue;
            }
            let mut s = 0.0f32;
            for q in 0..d {
                s += a[i * d + q] * b[jg * d + q];
            }
            let p = ((s - sd[i]) * inv_tau).exp() / denom;
            let w = c * p;
            for q in 0..d {
                db[j * d + q] += w * a[i * d + q];
            }
        }
    }
    db
}

/// Scalar reference for [`masked_exp_rowsum_bwd_col`].
#[allow(clippy::too_many_arguments)]
pub fn masked_exp_rowsum_bwd_col_ref(
    a: &[f32],
    b: &[f32],
    diag: &[isize],
    sd: &[f32],
    tau: &[f32],
    gbar: &[f32],
    denom: f32,
    m: usize,
    n: usize,
    d: usize,
) -> Vec<f32> {
    let mut db = vec![0.0f32; n * d];
    for i in 0..m {
        let inv_tau = 1.0 / tau[i];
        let c = gbar[i] * inv_tau;
        for j in 0..n {
            if j as isize == diag[i] {
                continue;
            }
            let mut s = 0.0f32;
            for q in 0..d {
                s += a[i * d + q] * b[j * d + q];
            }
            let p = ((s - sd[i]) * inv_tau).exp() / denom;
            let w = c * p;
            for q in 0..d {
                db[j * d + q] += w * a[i * d + q];
            }
        }
    }
    db
}

/// Numerically-stable fused row logsumexp of a row-major (m, n) matrix:
/// `out_i = max_j x_ij + log Σ_j exp(x_ij − max_j x_ij)` (ascending j).
pub fn row_logsumexp(x: &[f32], m: usize, n: usize, threads: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * n);
    assert!(n > 0, "logsumexp over an empty row");
    let mut out = vec![0.0f32; m];
    par_rows(&mut out, m, 1, threads, |lo, hi, chunk| {
        for i in lo..hi {
            let row = &x[i * n..i * n + n];
            let mut mx = f32::NEG_INFINITY;
            for v in row {
                mx = mx.max(*v);
            }
            let mut acc = 0.0f32;
            for v in row {
                acc += (*v - mx).exp();
            }
            chunk[i - lo] = mx + acc.ln();
        }
    });
    out
}

/// Numerically-stable fused in-place row softmax (max-shift + one-pass
/// normalization; ascending-j reductions).
pub fn row_softmax(x: &mut [f32], m: usize, n: usize, threads: usize) {
    assert_eq!(x.len(), m * n);
    par_rows(x, m, n, threads, |_lo, _hi, chunk| {
        for row in chunk.chunks_mut(n) {
            let mut mx = f32::NEG_INFINITY;
            for v in row.iter() {
                mx = mx.max(*v);
            }
            let mut z = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            let inv = 1.0 / z;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    type Fixture = (Vec<f32>, Vec<f32>, Vec<isize>, Vec<f32>, Vec<f32>, Vec<f32>);

    fn setup(m: usize, n: usize, d: usize) -> Fixture {
        let a = randn(m * d, 10);
        let b = randn(n * d, 11);
        let diag: Vec<isize> = (0..m)
            .map(|i| if i % 3 == 2 { NO_DIAG } else { (i % n) as isize })
            .collect();
        let sd: Vec<f32> = (0..m).map(|i| 0.1 * i as f32).collect();
        let tau: Vec<f32> = (0..m).map(|i| 0.05 + 0.01 * i as f32).collect();
        let gbar: Vec<f32> = (0..m).map(|i| 0.3 - 0.07 * i as f32).collect();
        (a, b, diag, sd, tau, gbar)
    }

    #[test]
    fn fwd_matches_ref_bitwise_all_threads() {
        for (m, n, d) in [(1usize, 1usize, 1usize), (5, 7, 3), (8, 16, 64), (13, 9, 33)] {
            let (a, b, diag, sd, tau, _) = setup(m, n, d);
            let denom = (n.max(2) - 1) as f32;
            let want = masked_exp_rowsum_ref(&a, &b, &diag, &sd, &tau, denom, m, n, d);
            for threads in [1usize, 2, 4] {
                let got = masked_exp_rowsum(&a, &b, &diag, &sd, &tau, denom, m, n, d, threads);
                assert_eq!(bits(&got), bits(&want), "m={m} n={n} d={d} t={threads}");
            }
        }
    }

    #[test]
    fn bwd_matches_ref_bitwise_all_threads() {
        for (m, n, d) in [(5usize, 7usize, 3usize), (8, 16, 32), (9, 4, 17)] {
            let (a, b, diag, sd, tau, gbar) = setup(m, n, d);
            let denom = (n - 1) as f32;
            let (da_want, dtau_want) =
                masked_exp_rowsum_bwd_row_ref(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d);
            let db_want =
                masked_exp_rowsum_bwd_col_ref(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d);
            for threads in [1usize, 2, 4] {
                let (da, dtau) = masked_exp_rowsum_bwd_row(
                    &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, threads,
                );
                let db = masked_exp_rowsum_bwd_col(
                    &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, threads,
                );
                assert_eq!(bits(&da), bits(&da_want), "da t={threads}");
                assert_eq!(bits(&dtau), bits(&dtau_want), "dtau t={threads}");
                assert_eq!(bits(&db), bits(&db_want), "db t={threads}");
            }
        }
    }

    /// The column-range kernel is bitwise-equal to the corresponding
    /// slice of the full bwd_col output — including non-divisible
    /// ranges (the kernel-level face of "B_global not divisible by K")
    /// and single-column ranges — at every thread count, and matches
    /// its own scalar reference.
    #[test]
    fn bwd_col_range_bitwise_equals_full_slice() {
        for (m, n, d) in [(5usize, 7usize, 3usize), (8, 16, 32), (9, 4, 17)] {
            let (a, b, diag, sd, tau, gbar) = setup(m, n, d);
            let denom = (n - 1) as f32;
            let full =
                masked_exp_rowsum_bwd_col(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, 1);
            // divisible and non-divisible partitions of the columns,
            // plus degenerate single-column and empty ranges
            let mut ranges = vec![(0usize, n), (0, n / 2), (n / 2, n), (1, n), (0, 1), (n, n)];
            if n >= 3 {
                ranges.push((n / 3, n - 1)); // straddles, non-divisible
            }
            for (lo, hi) in ranges {
                let want = &full[lo * d..hi * d];
                let r = masked_exp_rowsum_bwd_col_range_ref(
                    &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, lo, hi,
                );
                assert_eq!(bits(&r), bits(want), "ref [{lo},{hi}) m={m} n={n}");
                for threads in [1usize, 2, 4] {
                    let got = masked_exp_rowsum_bwd_col_range(
                        &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, lo, hi, threads,
                    );
                    assert_eq!(bits(&got), bits(want), "[{lo},{hi}) t={threads} m={m} n={n}");
                }
            }
        }
    }

    /// Covering the columns with per-rank ranges and stacking the
    /// blocks reconstructs the full bwd_col output bitwise — the exact
    /// decomposition `--loss-shard on` relies on (DESIGN.md §16).
    #[test]
    fn bwd_col_range_blocks_cover_full_output() {
        let (m, n, d) = (6usize, 10usize, 8usize);
        let (a, b, diag, sd, tau, gbar) = setup(m, n, d);
        let denom = (n - 1) as f32;
        let full = masked_exp_rowsum_bwd_col(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, 2);
        for k in [1usize, 2, 3, 4] {
            // ceil-partition: uneven last block when k doesn't divide n
            let bl = n.div_ceil(k);
            let mut stacked = Vec::with_capacity(n * d);
            for r in 0..k {
                let lo = (r * bl).min(n);
                let hi = ((r + 1) * bl).min(n);
                stacked.extend_from_slice(&masked_exp_rowsum_bwd_col_range(
                    &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, lo, hi, 3,
                ));
            }
            assert_eq!(bits(&stacked), bits(&full), "k={k}");
        }
    }

    #[test]
    fn forward_gradient_check_finite_difference() {
        // d(sum_i w_i g_i)/da and /db and /dtau vs central differences
        let (m, n, d) = (3usize, 5usize, 4usize);
        let (a, b, diag, sd, tau, gbar) = setup(m, n, d);
        let denom = (n - 1) as f32;
        let value = |a_: &[f32], b_: &[f32], tau_: &[f32]| -> f64 {
            // recompute sd from scratch NOT — sd is an independent input here
            let g = masked_exp_rowsum_ref(a_, b_, &diag, &sd, tau_, denom, m, n, d);
            g.iter().zip(&gbar).map(|(x, w)| (*x as f64) * (*w as f64)).sum()
        };
        let (da, dtau) =
            masked_exp_rowsum_bwd_row_ref(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d);
        let db = masked_exp_rowsum_bwd_col_ref(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d);
        let h = 1e-3f32;
        for idx in [0usize, 3, 7, m * d - 1] {
            let mut ap = a.clone();
            let mut am = a.clone();
            ap[idx] += h;
            am[idx] -= h;
            let num = (value(&ap, &b, &tau) - value(&am, &b, &tau)) / (2.0 * h as f64);
            assert!(
                (num - da[idx] as f64).abs() < 2e-2 * num.abs().max(1.0),
                "da[{idx}]: {num} vs {}",
                da[idx]
            );
        }
        for idx in [0usize, 5, n * d - 1] {
            let mut bp = b.clone();
            let mut bm = b.clone();
            bp[idx] += h;
            bm[idx] -= h;
            let num = (value(&a, &bp, &tau) - value(&a, &bm, &tau)) / (2.0 * h as f64);
            assert!(
                (num - db[idx] as f64).abs() < 2e-2 * num.abs().max(1.0),
                "db[{idx}]: {num} vs {}",
                db[idx]
            );
        }
        for idx in 0..m {
            let mut tp = tau.clone();
            let mut tm = tau.clone();
            tp[idx] += h * 0.01;
            tm[idx] -= h * 0.01;
            let num = (value(&a, &b, &tp) - value(&a, &b, &tm)) / (2.0 * (h * 0.01) as f64);
            assert!(
                (num - dtau[idx] as f64).abs() < 5e-2 * num.abs().max(1.0),
                "dtau[{idx}]: {num} vs {}",
                dtau[idx]
            );
        }
    }

    #[test]
    fn diag_mask_excludes_positive_pair() {
        // with a == b rows and sd = self-sim, the diag term would be
        // exp(0) = 1; masking it must lower g by exactly 1/denom
        let d = 8;
        let n = 4;
        let x = randn(n * d, 9);
        let tau = vec![1.0f32; n];
        let diag: Vec<isize> = (0..n as isize).collect();
        let none = vec![NO_DIAG; n];
        let sd: Vec<f32> = (0..n)
            .map(|i| dot(&x[i * d..(i + 1) * d], &x[i * d..(i + 1) * d]))
            .collect();
        let masked = masked_exp_rowsum_ref(&x, &x, &diag, &sd, &tau, 1.0, n, n, d);
        let full = masked_exp_rowsum_ref(&x, &x, &none, &sd, &tau, 1.0, n, n, d);
        for i in 0..n {
            let gap = full[i] - masked[i];
            assert!((gap - 1.0).abs() < 1e-4, "row {i}: {} vs {}", full[i], masked[i]);
        }
    }

    #[test]
    fn softmax_and_logsumexp_consistent() {
        let (m, n) = (6usize, 9usize);
        let x = randn(m * n, 21);
        for threads in [1usize, 2, 4] {
            let lse = row_logsumexp(&x, m, n, threads);
            let mut p = x.clone();
            row_softmax(&mut p, m, n, threads);
            for i in 0..m {
                let row = &p[i * n..(i + 1) * n];
                let s: f32 = row.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "softmax row sums to {s}");
                // softmax == exp(x - lse)
                for j in 0..n {
                    let want = (x[i * n + j] - lse[i]).exp();
                    assert!((row[j] - want).abs() < 1e-5);
                }
            }
            // bitwise thread-independence
            let lse1 = row_logsumexp(&x, m, n, 1);
            assert_eq!(
                lse.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                lse1.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
        // stability: huge logits do not overflow
        let big = vec![1000.0f32; 4];
        let l = row_logsumexp(&big, 2, 2, 1);
        assert!(l.iter().all(|v| v.is_finite()));
    }
}
