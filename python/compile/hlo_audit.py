# L2 performance audit: static analysis of the lowered HLO artifacts
# (EXPERIMENTS.md §Perf L2). Reports per-executable op histograms, dot
# (matmul) counts, fusion counts, and flags the two regressions the perf
# plan watches for:
#   * double encode: the step graph must contain exactly ONE live
#     encoder pass per tower (forward) plus its transposed backward —
#     i.e. dot count ~= 3x the encode graph's dot count (fwd+bwd+bwd-acc),
#     not 4x+ (which would mean the surrogate re-encoded the batch);
#   * unfused elementwise storms: elementwise op count should collapse
#     into fusions after XLA optimization (we audit the *input* HLO, so we
#     report the raw counts and rely on XLA's fusion — the check is that
#     raw elementwise ops stay O(graph size), not O(batch^2)).
#
# Usage: python -m compile.hlo_audit [--bundle ../artifacts/tiny_k2_b8]
import argparse
import collections
import json
import os
import re


def audit_file(path):
    ops = collections.Counter()
    entry = False
    total = 0
    for line in open(path):
        line = line.strip()
        m = re.match(r"%?[\w.-]+ = \S+ ([a-z-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
            total += 1
        if line.startswith("ENTRY"):
            entry = True
    assert entry, f"no ENTRY in {path}"
    return ops, total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bundle", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "tiny_k2_b8"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    report = {}
    encode_dots = None
    for name in sorted(os.listdir(args.bundle)):
        if not name.endswith(".hlo.txt"):
            continue
        ops, total = audit_file(os.path.join(args.bundle, name))
        key = name.replace(".hlo.txt", "")
        report[key] = {
            "total_ops": total,
            "dot": ops.get("dot", 0),
            "exponential": ops.get("exponential", 0),
            "broadcast": ops.get("broadcast", 0),
            "top": ops.most_common(8),
        }
        if key == "encode":
            encode_dots = ops.get("dot", 0)
        print(f"{key:14} ops={total:5}  dot={ops.get('dot', 0):3}  "
              f"exp={ops.get('exponential', 0):3}  "
              f"top={ops.most_common(5)}")

    # the double-encode check: each step graph encodes the local batch once
    # (forward, 1x the encode dots) and differentiates through it (~2x for
    # the backward), plus ~12 dots from the four Pallas kernel calls
    # (fwd + da + db each). Expected ratio ~3.7x; a second live encode
    # would push it past ~4.7x.
    if encode_dots:
        for key, r in report.items():
            if not key.startswith("step_"):
                continue
            ratio = r["dot"] / encode_dots
            status = "OK" if ratio <= 3.9 else "SUSPECT double-encode"
            print(f"{key:14} dot ratio vs encode: {ratio:.2f}x  [{status}]")
            r["dot_ratio_vs_encode"] = ratio
            assert ratio <= 4.5, f"{key}: dot ratio {ratio:.2f} — re-encoding?"

    out = args.out or os.path.join(os.path.dirname(__file__), "..", "..",
                                   "results", "l2_hlo_audit.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(report, f, indent=1, default=str)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
