//! Fault model for the in-process collective world (DESIGN.md §13):
//! cancellation tokens, a cancellable barrier, and deterministic failure
//! injection.
//!
//! A lost rank in a lockstep collective system is a *deadlock*, not an
//! error: every surviving rank blocks forever on a barrier the dead rank
//! will never reach. This module turns that hang into a typed error.
//! Every world carries a shared [`CancellationToken`]; the moment a rank
//! is declared lost (or a watchdog expires) every blocking wait in the
//! world returns [`CommError`] instead of blocking, the overlap workers
//! drain out, and the trainer can roll back and shrink (DESIGN.md §13).
//!
//! Failure *injection* is configuration, not chaos: [`FailSpec`] kills a
//! specific rank at a specific iteration (`--fail rank=R@iter=N`) and
//! [`StraggleSpec`] skews a rank's per-collective latency (`--straggle
//! rank=R:ms=M`), so every fault scenario is deterministic and
//! CI-replayable.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Why a collective returned instead of completing. Implements
/// [`std::error::Error`], so it travels through `anyhow` chains and the
/// trainer can `downcast_ref` it to decide whether a failure is
/// shrinkable (a lost rank) or fatal (a watchdog bug).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CommError {
    /// One or more ranks were declared lost (sorted, deduplicated).
    /// Survivors can roll back to the last snapshot and shrink the world.
    RanksLost(Vec<usize>),
    /// A watchdog expired with no rank declared lost — a liveness bug or
    /// a watchdog set shorter than the slowest straggler; not shrinkable.
    Watchdog,
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::RanksLost(ranks) => {
                write!(f, "collective cancelled: rank(s) {ranks:?} lost")
            }
            CommError::Watchdog => {
                write!(f, "collective watchdog expired with no rank declared lost")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Shared cancellation state for one collective world (and its overlap
/// sibling — the trainer hands both worlds the SAME token, so a loss
/// detected on either cancels every blocking wait on both).
///
/// Cancellation is permanent: once set, every subsequent collective on
/// the world returns [`CommError`] immediately. Survivors build fresh
/// worlds (with a fresh token) for the post-shrink incarnation.
#[derive(Debug, Default)]
pub struct CancellationToken {
    cancelled: AtomicBool,
    watchdog_fired: AtomicBool,
    lost: Mutex<Vec<usize>>,
}

impl CancellationToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare `rank` lost and cancel every blocking wait on worlds
    /// sharing this token. Idempotent; multiple losses accumulate.
    pub fn declare_lost(&self, rank: usize) {
        let mut lost = self.lost.lock().unwrap();
        if !lost.contains(&rank) {
            lost.push(rank);
            lost.sort_unstable();
        }
        // ordering: the rank list is published before the flag flips, so
        // any waiter that observes `cancelled` finds a non-empty list
        drop(lost);
        self.cancelled.store(true, Ordering::SeqCst);
    }

    /// Cancel because a watchdog expired (no specific rank to blame).
    pub fn cancel_watchdog(&self) {
        self.watchdog_fired.store(true, Ordering::SeqCst);
        self.cancelled.store(true, Ordering::SeqCst);
    }

    /// Has any loss or watchdog cancelled this token?
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// The ranks declared lost so far (sorted, deduplicated).
    pub fn lost(&self) -> Vec<usize> {
        self.lost.lock().unwrap().clone()
    }

    /// The error every cancelled wait returns: the lost ranks when any
    /// were declared, [`CommError::Watchdog`] otherwise.
    pub fn error(&self) -> CommError {
        let lost = self.lost();
        if lost.is_empty() {
            CommError::Watchdog
        } else {
            CommError::RanksLost(lost)
        }
    }
}

/// How often a parked waiter re-checks its token and watchdog. The happy
/// path never polls — the last arriver wakes everyone via `notify_all` —
/// this only bounds how stale a *cancellation* can go unnoticed.
const POLL: Duration = Duration::from_millis(1);

/// A [`std::sync::Barrier`] that can be cancelled: `wait` returns
/// `Err(CommError)` instead of blocking forever when the token is
/// cancelled or the watchdog deadline passes. The normal path costs the
/// same one-mutex-one-condvar handshake as `std::sync::Barrier`.
#[derive(Debug)]
pub struct CancellableBarrier {
    k: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

#[derive(Debug)]
struct BarrierState {
    count: usize,
    generation: u64,
}

impl CancellableBarrier {
    /// A barrier for `k` participants.
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        Self { k, state: Mutex::new(BarrierState { count: 0, generation: 0 }), cv: Condvar::new() }
    }

    /// Block until all `k` participants arrive, the token is cancelled,
    /// or `watchdog` (when set) expires — whichever comes first. A waiter
    /// that leaves on cancellation *withdraws* its arrival, which is safe
    /// because cancellation is permanent: every later arriver errors out
    /// at its own entry check, so a half-filled generation can never
    /// complete spuriously. Watchdog expiry cancels the token itself, so
    /// one stuck barrier releases every waiter in the world.
    pub fn wait(
        &self,
        token: &CancellationToken,
        watchdog: Option<Duration>,
    ) -> std::result::Result<(), CommError> {
        if token.is_cancelled() {
            return Err(token.error());
        }
        let deadline = watchdog.map(|d| Instant::now() + d);
        let mut s = self.state.lock().unwrap();
        s.count += 1;
        if s.count == self.k {
            s.count = 0;
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let gen = s.generation;
        while s.generation == gen {
            if token.is_cancelled() {
                s.count -= 1; // withdraw: this generation must not complete
                return Err(token.error());
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    token.cancel_watchdog();
                    s.count -= 1;
                    return Err(token.error());
                }
            }
            s = self.cv.wait_timeout(s, POLL).unwrap().0;
        }
        Ok(())
    }
}

/// Deterministic failure injection: kill rank `rank` at the top of
/// iteration `iter` (0-based step index). Grammar: `rank=R@iter=N`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FailSpec {
    /// The rank that dies.
    pub rank: usize,
    /// The 0-based training step at whose start it dies.
    pub iter: u32,
}

/// Deterministic latency skew: rank `rank` sleeps `ms` milliseconds at
/// the entry of every collective. Grammar: `rank=R:ms=M`, comma-separated
/// for several ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StraggleSpec {
    /// The straggling rank.
    pub rank: usize,
    /// Added latency per collective, in milliseconds.
    pub ms: u64,
}

/// The fault scenario of one run: at most one injected death, any number
/// of stragglers, and the watchdog bound on every blocking wait.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// The injected death, if any.
    pub fail: Option<FailSpec>,
    /// Per-rank latency skew.
    pub straggle: Vec<StraggleSpec>,
    /// Explicit watchdog in milliseconds (0 = pick a default when faults
    /// are active, no watchdog otherwise).
    pub watchdog_ms: u64,
}

/// Default watchdog when faults are injected but none was configured:
/// generous enough for CI machines, finite enough that no fault test can
/// hang (the ISSUE's "every blocking path is watchdog-bounded").
const DEFAULT_WATCHDOG: Duration = Duration::from_secs(60);

impl FaultPlan {
    /// Build a plan from the raw config strings (`None` = absent).
    pub fn parse(
        fail: Option<&str>,
        straggle: Option<&str>,
        watchdog_ms: u64,
    ) -> Result<FaultPlan> {
        Ok(FaultPlan {
            fail: fail.map(parse_fail).transpose()?,
            straggle: straggle.map(parse_straggle).transpose()?.unwrap_or_default(),
            watchdog_ms,
        })
    }

    /// Is any fault injected?
    pub fn active(&self) -> bool {
        self.fail.is_some() || !self.straggle.is_empty()
    }

    /// The watchdog every blocking wait runs under: the configured bound,
    /// a 60 s default when faults are injected, none otherwise (a clean
    /// run pays no deadline bookkeeping).
    pub fn watchdog(&self) -> Option<Duration> {
        if self.watchdog_ms > 0 {
            Some(Duration::from_millis(self.watchdog_ms))
        } else if self.active() {
            Some(DEFAULT_WATCHDOG)
        } else {
            None
        }
    }

    /// Per-rank straggle sleeps for a world of `k` ranks.
    pub fn straggle_for(&self, k: usize) -> Vec<Duration> {
        let mut out = vec![Duration::ZERO; k];
        for s in &self.straggle {
            if s.rank < k {
                out[s.rank] = Duration::from_millis(s.ms);
            }
        }
        out
    }

    /// Reject specs that name ranks outside a world of `k` ranks.
    pub fn check_ranks(&self, k: usize) -> Result<()> {
        if let Some(f) = &self.fail {
            if f.rank >= k {
                bail!("--fail rank={} is outside the world (K={k} ranks, 0..{})", f.rank, k - 1);
            }
            if k == 1 {
                bail!("--fail with K=1 kills the only rank: nothing survives to shrink");
            }
        }
        for s in &self.straggle {
            if s.rank >= k {
                bail!(
                    "--straggle rank={} is outside the world (K={k} ranks, 0..{})",
                    s.rank,
                    k - 1
                );
            }
        }
        Ok(())
    }
}

const FAIL_GRAMMAR: &str = "expected rank=R@iter=N (e.g. --fail rank=1@iter=17)";
const STRAGGLE_GRAMMAR: &str =
    "expected rank=R:ms=M[,rank=R2:ms=M2] (e.g. --straggle rank=0:ms=20)";

fn field<T: std::str::FromStr>(part: &str, key: &str, grammar: &str) -> Result<T>
where
    T::Err: fmt::Display,
{
    let val = part
        .strip_prefix(key)
        .and_then(|r| r.strip_prefix('='))
        .with_context(|| format!("bad fault spec field '{part}': {grammar}"))?;
    val.parse::<T>()
        .map_err(|e| anyhow::anyhow!("bad fault spec value '{val}' for {key} ({e}): {grammar}"))
}

/// Parse `rank=R@iter=N`.
pub fn parse_fail(s: &str) -> Result<FailSpec> {
    let (r, i) = s.split_once('@').with_context(|| format!("bad --fail '{s}': {FAIL_GRAMMAR}"))?;
    Ok(FailSpec { rank: field(r, "rank", FAIL_GRAMMAR)?, iter: field(i, "iter", FAIL_GRAMMAR)? })
}

/// Parse `rank=R:ms=M[,rank=R2:ms=M2]`.
pub fn parse_straggle(s: &str) -> Result<Vec<StraggleSpec>> {
    s.split(',')
        .map(|spec| {
            let (r, m) = spec
                .split_once(':')
                .with_context(|| format!("bad --straggle '{spec}': {STRAGGLE_GRAMMAR}"))?;
            Ok(StraggleSpec {
                rank: field(r, "rank", STRAGGLE_GRAMMAR)?,
                ms: field(m, "ms", STRAGGLE_GRAMMAR)?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fail_spec_grammar_roundtrip_and_rejection() {
        assert_eq!(parse_fail("rank=1@iter=17").unwrap(), FailSpec { rank: 1, iter: 17 });
        assert_eq!(parse_fail("rank=0@iter=0").unwrap(), FailSpec { rank: 0, iter: 0 });
        for bad in ["", "rank=1", "rank=1@iter=", "iter=3@rank=1", "rank=x@iter=2", "1@17"] {
            let err = parse_fail(bad).unwrap_err();
            assert!(format!("{err:#}").contains("rank=R@iter=N"), "{bad}: {err:#}");
        }
    }

    #[test]
    fn straggle_spec_grammar_roundtrip_and_rejection() {
        assert_eq!(
            parse_straggle("rank=0:ms=20").unwrap(),
            vec![StraggleSpec { rank: 0, ms: 20 }]
        );
        assert_eq!(
            parse_straggle("rank=0:ms=5,rank=3:ms=11").unwrap(),
            vec![StraggleSpec { rank: 0, ms: 5 }, StraggleSpec { rank: 3, ms: 11 }]
        );
        for bad in ["", "rank=0", "rank=0:ms=x", "ms=5:rank=0", "rank=0:ms=1,,"] {
            let err = parse_straggle(bad).unwrap_err();
            assert!(format!("{err:#}").contains("rank=R:ms=M"), "{bad}: {err:#}");
        }
    }

    #[test]
    fn fault_plan_bounds_and_defaults() {
        let none = FaultPlan::default();
        assert!(!none.active());
        assert_eq!(none.watchdog(), None, "clean runs pay no watchdog");

        let plan = FaultPlan::parse(Some("rank=1@iter=3"), Some("rank=0:ms=7"), 0).unwrap();
        assert!(plan.active());
        assert_eq!(plan.watchdog(), Some(Duration::from_secs(60)));
        assert_eq!(plan.straggle_for(2), vec![Duration::from_millis(7), Duration::ZERO]);
        plan.check_ranks(2).unwrap();
        assert!(plan.check_ranks(1).is_err(), "failing rank 1 of a K=1 world");

        let explicit = FaultPlan::parse(None, None, 250).unwrap();
        assert_eq!(explicit.watchdog(), Some(Duration::from_millis(250)));

        let k1_kill = FaultPlan::parse(Some("rank=0@iter=1"), None, 0).unwrap();
        let err = k1_kill.check_ranks(1).unwrap_err();
        assert!(format!("{err}").contains("nothing survives"), "{err}");
    }

    #[test]
    fn token_records_losses_and_is_permanent() {
        let t = CancellationToken::new();
        assert!(!t.is_cancelled());
        assert_eq!(t.error(), CommError::Watchdog, "no loss recorded yet");
        t.declare_lost(3);
        t.declare_lost(1);
        t.declare_lost(3); // idempotent
        assert!(t.is_cancelled());
        assert_eq!(t.lost(), vec![1, 3]);
        assert_eq!(t.error(), CommError::RanksLost(vec![1, 3]));
    }

    #[test]
    fn barrier_completes_normally_and_repeatedly() {
        let k = 4;
        let barrier = Arc::new(CancellableBarrier::new(k));
        let token = Arc::new(CancellationToken::new());
        let handles: Vec<_> = (0..k)
            .map(|_| {
                let b = Arc::clone(&barrier);
                let t = Arc::clone(&token);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        b.wait(&t, Some(Duration::from_secs(10))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn barrier_cancellation_releases_every_waiter() {
        let k = 3;
        let barrier = Arc::new(CancellableBarrier::new(k));
        let token = Arc::new(CancellationToken::new());
        // only k-1 threads arrive; the missing rank is declared lost
        let handles: Vec<_> = (0..k - 1)
            .map(|_| {
                let b = Arc::clone(&barrier);
                let t = Arc::clone(&token);
                std::thread::spawn(move || b.wait(&t, Some(Duration::from_secs(30))))
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        token.declare_lost(k - 1);
        for h in handles {
            let err = h.join().unwrap().unwrap_err();
            assert_eq!(err, CommError::RanksLost(vec![k - 1]));
        }
        // and the world stays cancelled: later arrivals error immediately
        let err = barrier.wait(&token, None).unwrap_err();
        assert_eq!(err, CommError::RanksLost(vec![k - 1]));
    }

    #[test]
    fn barrier_watchdog_bounds_the_wait_and_cancels_the_token() {
        let barrier = CancellableBarrier::new(2);
        let token = CancellationToken::new();
        let t0 = Instant::now();
        let err = barrier.wait(&token, Some(Duration::from_millis(50))).unwrap_err();
        assert_eq!(err, CommError::Watchdog);
        assert!(t0.elapsed() < Duration::from_secs(10), "watchdog must bound the wait");
        assert!(token.is_cancelled(), "watchdog expiry cancels the whole world");
    }

    #[test]
    fn comm_error_travels_through_anyhow() {
        let e: anyhow::Error = CommError::RanksLost(vec![2]).into();
        let e = e.context("reducing bucket 3").context("iteration 17");
        let c = e.root_cause().downcast_ref::<CommError>().unwrap();
        assert_eq!(*c, CommError::RanksLost(vec![2]));
    }
}
