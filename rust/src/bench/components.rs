//! Component-study experiments — §5.1 of the paper (DESIGN.md §6):
//! * `table3` — inner-LR (γ) schedule: constant vs cosine, three pairs;
//! * `table4` — temperature update rules: FastCLIP-v0..v3;
//! * `table5` — optimizers: SGDM / LAMB / Lion / AdamW on FastCLIP-v3;
//! * `reduce` — gradient-reduction strategies: bytes-on-wire + α–β time
//!   per algorithm, with a live exactness check on real collectives.
//!
//! Each runner prints the paper-shaped rows (mean (std) over seeds) and
//! writes CSV + JSON under `results/`.

use anyhow::Result;

use crate::comm::{reduction, CommWorld, CostModel, ProfileName, ReduceAlgo, ReduceCtx, WireCodec};
use crate::config::{Algorithm, GammaSchedule, OptimizerKind};
use crate::kernels::Precision;
use crate::output::{mean_std_cell, Table};
use crate::util::{Args, Json};

use super::common::{
    algo_config, apply_overrides, progress_logger, results_dir, run_seeds, scores, Setting,
};

fn settings_from(args: &Args) -> Result<Vec<Setting>> {
    match args.get("setting") {
        Some("all") => Ok(vec![Setting::Medium, Setting::Large]),
        Some(s) => Ok(vec![Setting::from_id(s)?]),
        None => Ok(vec![Setting::Medium]),
    }
}

/// Table 3 / Fig. 8: constant γ vs cosine γ, three algorithm pairs.
pub fn table3(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut table = Table::new(
        "Table 3 — inner LR schedule (constant vs cosine gamma)",
        &["Setting", "Algorithm", "Schedule", "Datacomp", "Retrieval", "IN&Var"],
    );
    let mut json_rows = Vec::new();
    for setting in settings_from(args)? {
        // (label, base algorithm, override-to-constant?)
        let pairs: [(&str, Algorithm, bool); 6] = [
            ("SogCLR", Algorithm::SogClr, false),
            ("FastCLIP-v1", Algorithm::FastClipV1, false),
            ("iSogCLR", Algorithm::ISogClr, false),
            ("FastCLIP-v2", Algorithm::FastClipV2, false),
            ("v3 (Const. gamma)", Algorithm::FastClipV3, true),
            ("FastCLIP-v3", Algorithm::FastClipV3, false),
        ];
        for (label, algo, force_const) in pairs {
            let mut cfg = algo_config(setting, algo);
            if force_const {
                cfg.gamma = GammaSchedule::Constant { gamma: 0.6 };
            }
            let seeds = apply_overrides(&mut cfg, args)?;
            let results = run_seeds(&cfg, &seeds, label, log)?;
            let s = scores(&results);
            let schedule = match cfg.gamma {
                GammaSchedule::Constant { .. } => "constant",
                GammaSchedule::Cosine { .. } => "cosine",
            };
            table.row(vec![
                setting.name().into(),
                label.into(),
                schedule.into(),
                mean_std_cell(&s.datacomp),
                mean_std_cell(&s.retrieval),
                mean_std_cell(&s.in_variants),
            ]);
            json_rows.push(result_json(setting, label, schedule, &s));
        }
    }
    finish(args, "table3", table, json_rows)
}

/// Table 4 / Fig. 9(a,b): temperature update rules v0–v3.
pub fn table4(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut table = Table::new(
        "Table 4 — temperature parameter updates (FastCLIP-v0..v3)",
        &["Setting", "Algorithm", "Datacomp", "Retrieval", "IN&Var"],
    );
    let mut json_rows = Vec::new();
    for setting in settings_from(args)? {
        for algo in [
            Algorithm::FastClipV0,
            Algorithm::FastClipV1,
            Algorithm::FastClipV2,
            Algorithm::FastClipV3,
        ] {
            let mut cfg = algo_config(setting, algo);
            let seeds = apply_overrides(&mut cfg, args)?;
            let results = run_seeds(&cfg, &seeds, algo.name(), log)?;
            let s = scores(&results);
            table.row(vec![
                setting.name().into(),
                algo.name().into(),
                mean_std_cell(&s.datacomp),
                mean_std_cell(&s.retrieval),
                mean_std_cell(&s.in_variants),
            ]);
            json_rows.push(result_json(setting, algo.name(), "-", &s));
        }
    }
    finish(args, "table4", table, json_rows)
}

/// Table 5 / Fig. 9(c,d): optimizers on FastCLIP-v3.
pub fn table5(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut table = Table::new(
        "Table 5 — optimizers (FastCLIP-v3 base)",
        &["Setting", "Optimizer", "Datacomp", "Retrieval", "IN&Var"],
    );
    let mut json_rows = Vec::new();
    for setting in settings_from(args)? {
        for kind in [
            OptimizerKind::Sgdm,
            OptimizerKind::Lamb,
            OptimizerKind::Lion,
            OptimizerKind::AdamW,
        ] {
            let mut cfg = algo_config(setting, Algorithm::FastClipV3);
            cfg.optimizer = crate::config::OptimizerConfig::with_kind(kind);
            // Table 10 tuned (lr, wd) scaled: SGDM needs a far larger lr,
            // Lion a smaller one, than AdamW's peak
            match kind {
                OptimizerKind::Sgdm => {
                    cfg.lr.peak = 1.0;
                    cfg.optimizer.weight_decay = 3e-6;
                }
                OptimizerKind::Lion => {
                    cfg.lr.peak = setting.lion_lr();
                    cfg.optimizer.weight_decay = 0.3;
                }
                OptimizerKind::Lamb => {
                    cfg.lr.peak = 2e-3;
                    cfg.optimizer.weight_decay = 0.1;
                }
                OptimizerKind::AdamW => {}
            }
            let seeds = apply_overrides(&mut cfg, args)?;
            let results = run_seeds(&cfg, &seeds, kind.name(), log)?;
            let s = scores(&results);
            table.row(vec![
                setting.name().into(),
                kind.name().into(),
                mean_std_cell(&s.datacomp),
                mean_std_cell(&s.retrieval),
                mean_std_cell(&s.in_variants),
            ]);
            json_rows.push(result_json(setting, kind.name(), "-", &s));
        }
    }
    finish(args, "table5", table, json_rows)
}

/// `reduce` — the gradient-reduction strategy study (DESIGN.md §4/§12/§15).
/// Needs no artifact bundles: for each world size × gradient size it
/// reports each algorithm's modeled bytes-on-wire per rank (at both the
/// f32 and the half-width bf16 wire format) and α–β time (and the cost
/// model's `auto` pick), then verifies on REAL in-process collectives —
/// under every wire codec — that all strategies produce bit-identical
/// parameters for the lossless codecs (and rank-replicated ones for the
/// lossy codecs), that the sharded strategy's gradient traffic, as
/// counted by `CommStats`, is strictly lower than the naive baseline,
/// and that each codec charges its exact encoded byte width against f32
/// (bf16 1/2, int8 1/4, topk per its 8-bytes-per-kept-element format).
pub fn reduce_table(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let profile = ProfileName::from_id(&args.str_or("profile", "infiniband"))?;
    let n_params = args.usize_or("n-params", 20_000_000)?;
    let mut table = Table::new(
        "Gradient-reduction strategies (bytes-on-wire per rank, alpha-beta time)",
        &[
            "Nodes x GPUs",
            "Grad MB",
            "Algorithm",
            "Wire MB/rank",
            "bf16 MB/rank",
            "Time (ms)",
            "Auto pick",
        ],
    );
    let mut json_rows = Vec::new();
    for (nodes, gpus) in [(1usize, 2usize), (1, 4), (2, 4), (8, 4)] {
        let cost = CostModel::new(profile.profile(), nodes, gpus);
        let k = cost.world_size();
        for n in [2 * 128usize, n_params] {
            let bytes = n * 4;
            let auto = cost.cheapest_reduce(bytes);
            for algo in ReduceAlgo::all() {
                let r = reduction(algo);
                // divide on elements, scale by width (see comm::collective
                // charge()): keeps the bf16 column exactly half of f32
                let wire = r.grad_wire_bytes(k, n as u64) * 4;
                let wire_bf16 = r.grad_wire_bytes(k, n as u64) * 2;
                let time = cost.reduce_time(algo, bytes);
                table.row(vec![
                    format!("{nodes}x{gpus}"),
                    format!("{:.2}", bytes as f64 / 1e6),
                    algo.id().into(),
                    format!("{:.3}", wire as f64 / 1e6),
                    format!("{:.3}", wire_bf16 as f64 / 1e6),
                    format!("{:.3}", time * 1e3),
                    if algo == auto { "<-".into() } else { String::new() },
                ]);
                json_rows.push(Json::obj(vec![
                    ("nodes", Json::num(nodes as f64)),
                    ("gpus_per_node", Json::num(gpus as f64)),
                    ("grad_bytes", Json::num(bytes as f64)),
                    ("algorithm", Json::str(algo.id())),
                    ("wire_bytes_per_rank", Json::num(wire as f64)),
                    ("wire_bytes_per_rank_bf16", Json::num(wire_bf16 as f64)),
                    ("modeled_time_s", Json::num(time)),
                    ("auto_pick", Json::str(auto.id())),
                ]));
            }
        }
    }
    // live exactness + traffic check on real collectives (threads), once
    // per wire codec; finish() prints the table afterwards

    let k = 4usize;
    let n = 1003; // non-divisible chunking
    let mut f32_wire_bytes: Vec<u64> = Vec::new(); // per algo, filled by the f32 pass
    for wire in WireCodec::all() {
        let mut reference: Option<Vec<f32>> = None; // naive's result, the baseline
        for (ai, algo) in ReduceAlgo::all().into_iter().enumerate() {
            let world = CommWorld::new(k);
            let handles: Vec<_> = (0..k)
                .map(|rank| {
                    let comm = world.handle(rank);
                    std::thread::spawn(move || {
                        let ctx = ReduceCtx::for_run(wire, n);
                        let mut grad: Vec<f32> =
                            (0..n).map(|i| ((i * 7 + rank * 13) % 97) as f32 * 0.125).collect();
                        let mut params = vec![0.0f32; n];
                        reduction(algo)
                            .reduce_and_apply(&comm, &mut grad, &mut params, &ctx, &mut |p, g| {
                                p.copy_from_slice(g)
                            })
                            // lint:allow(err-unwrap): panic surfaces at the join below
                            .unwrap();
                        params
                    })
                })
                .collect();
            let outs: Vec<Vec<f32>> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            anyhow::ensure!(
                outs.iter().all(|o| o == &outs[0]),
                "{} ({}): ranks disagree on the reduced result",
                algo.id(),
                wire.id()
            );
            // cross-ALGORITHM bit-identity — the lossless contract only:
            // lossy codecs round at algorithm-specific slice boundaries,
            // so they promise determinism per (codec, algo), not
            // cross-algo equality (DESIGN.md §15)
            if !wire.lossy() {
                match &reference {
                    None => reference = Some(outs[0].clone()),
                    Some(r) => anyhow::ensure!(
                        &outs[0] == r,
                        "{} ({}): result differs bitwise from naive",
                        algo.id(),
                        wire.id()
                    ),
                }
            }
            let s = world.stats.snapshot();
            anyhow::ensure!(
                algo != ReduceAlgo::Sharded || s.grad_wire_bytes < s.grad_wire_bytes_naive,
                "sharded must move fewer gradient bytes than naive"
            );
            // the §12/§15 acceptance checks: every codec charges its
            // exact encoded width against f32, algorithm by algorithm
            let per_rank_elems = f32_wire_bytes.get(ai).copied().unwrap_or(0) / 4 / k as u64;
            match wire {
                WireCodec::F32 => f32_wire_bytes.push(s.grad_wire_bytes),
                WireCodec::Bf16 => anyhow::ensure!(
                    2 * s.grad_wire_bytes == f32_wire_bytes[ai],
                    "{}: bf16 wire must charge exactly half of f32 ({} vs {})",
                    algo.id(),
                    s.grad_wire_bytes,
                    f32_wire_bytes[ai]
                ),
                WireCodec::Int8 => anyhow::ensure!(
                    4 * s.grad_wire_bytes == f32_wire_bytes[ai],
                    "{}: int8 wire must charge exactly a quarter of f32 ({} vs {})",
                    algo.id(),
                    s.grad_wire_bytes,
                    f32_wire_bytes[ai]
                ),
                WireCodec::TopK => anyhow::ensure!(
                    s.grad_wire_bytes == k as u64 * wire.encoded_bytes(per_rank_elems),
                    "{}: topk wire bytes off the 8-bytes-per-kept-element format",
                    algo.id()
                ),
            }
            log.status(&format!(
                "exactness ok: {:8} {:5}  grad wire {:>7} B (naive baseline {:>7} B, {:.2}x)",
                algo.id(),
                wire.id(),
                s.grad_wire_bytes / k as u64,
                s.grad_wire_bytes_naive / k as u64,
                s.grad_wire_saving()
            ));
        }
    }

    // live overlapped-reduction check (DESIGN.md §11): a short pipelined
    // run must match the serial run bitwise, and its overlap win is
    // reported ONCE — the measured hidden/exposed split below; the
    // modeled wire/time table above never adds a second overlap credit.
    {
        use crate::comm::OverlapMode;
        use crate::coordinator::TrainResult;
        let quick = |overlap: OverlapMode,
                     precision: Precision,
                     wire: Option<WireCodec>|
         -> Result<TrainResult> {
            let mut cfg = crate::config::TrainConfig::new("native", Algorithm::FastClipV3);
            cfg.backend = crate::runtime::BackendKind::Native;
            cfg.steps = 6;
            cfg.iters_per_epoch = 3;
            cfg.data.n_train = 64;
            cfg.data.n_eval = 16;
            cfg.data.n_classes = 8;
            cfg.lr.warmup_iters = 1;
            cfg.lr.total_iters = 6;
            cfg.overlap = overlap;
            cfg.precision = precision;
            cfg.wire = wire;
            // pinned: auto could resolve differently for the half-width
            // gradient, which would break the exact-2x byte comparison
            cfg.reduce = crate::comm::ReduceStrategy::Fixed(ReduceAlgo::Ring);
            cfg.bucket_bytes = 4 << 10;
            // `--trace-out` wires the live check into the telemetry
            // subsystem too (last run wins, like bench_iteration)
            cfg.trace_out = args.get("trace-out").map(str::to_string);
            crate::coordinator::Trainer::new(cfg)?.run()
        };
        let serial = quick(OverlapMode::Off, Precision::F32, None)?;
        let piped = quick(OverlapMode::On, Precision::F32, None)?;
        anyhow::ensure!(
            serial.final_params == piped.final_params,
            "overlapped reduction diverged from serial training"
        );
        log.status(&format!(
            "overlap ok: {} buckets/iter, bitwise equal to serial; measured reduction \
             {} us hidden / {} us exposed",
            piped.n_buckets, piped.hidden_comm_us, piped.exposed_comm_us
        ));
        // the same invariants under the bf16 wire + storage path, plus
        // the end-to-end ~2x wire-byte cut vs the f32 run above
        let bf_serial = quick(OverlapMode::Off, Precision::Bf16, None)?;
        let bf_piped = quick(OverlapMode::On, Precision::Bf16, None)?;
        anyhow::ensure!(
            bf_serial.final_params == bf_piped.final_params,
            "bf16 overlapped reduction diverged from bf16 serial training"
        );
        anyhow::ensure!(
            serial.grad_wire_bytes == 2 * bf_serial.grad_wire_bytes,
            "bf16 training must halve gradient wire bytes ({} vs {})",
            bf_serial.grad_wire_bytes,
            serial.grad_wire_bytes
        );
        log.status(&format!(
            "bf16 ok: bitwise serial==overlap; grad wire {} B vs f32 {} B per rank",
            bf_serial.grad_wire_bytes, serial.grad_wire_bytes
        ));
        // lossy gradient codecs end-to-end (DESIGN.md §15): run-to-run
        // deterministic under a fixed (codec, algo), with the exact
        // modeled byte cuts against the f32 serial run above
        let i8a = quick(OverlapMode::Off, Precision::F32, Some(WireCodec::Int8))?;
        let i8b = quick(OverlapMode::Off, Precision::F32, Some(WireCodec::Int8))?;
        anyhow::ensure!(
            i8a.final_params == i8b.final_params,
            "int8-wire training must be run-to-run deterministic"
        );
        anyhow::ensure!(
            4 * i8a.grad_wire_bytes == serial.grad_wire_bytes,
            "int8 training must quarter gradient wire bytes ({} vs {})",
            i8a.grad_wire_bytes,
            serial.grad_wire_bytes
        );
        let tk = quick(OverlapMode::Off, Precision::F32, Some(WireCodec::TopK))?;
        anyhow::ensure!(
            8 * tk.grad_wire_bytes == serial.grad_wire_bytes,
            "topk (1-in-16 kept, 8 B each) must cut gradient wire bytes 8x ({} vs {})",
            tk.grad_wire_bytes,
            serial.grad_wire_bytes
        );
        log.status(&format!(
            "lossy codecs ok: int8 {} B, topk {} B vs f32 {} B per rank",
            i8a.grad_wire_bytes, tk.grad_wire_bytes, serial.grad_wire_bytes
        ));
    }
    finish(args, "reduce", table, json_rows)
}

impl Setting {
    fn lion_lr(&self) -> f32 {
        match self {
            Setting::Medium => 2e-4, // Table 10
            _ => 1e-4,
        }
    }
}

fn result_json(setting: Setting, label: &str, extra: &str, s: &super::common::ScoreVecs) -> Json {
    Json::obj(vec![
        ("setting", Json::str(setting.name())),
        ("algorithm", Json::str(label)),
        ("schedule", Json::str(extra)),
        ("datacomp", Json::arr(s.datacomp.iter().map(|&v| Json::num(v as f64)))),
        ("retrieval", Json::arr(s.retrieval.iter().map(|&v| Json::num(v as f64)))),
        ("in_variants", Json::arr(s.in_variants.iter().map(|&v| Json::num(v as f64)))),
    ])
}

fn finish(args: &Args, name: &str, table: Table, rows: Vec<Json>) -> Result<()> {
    let log = progress_logger(args)?;
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join(format!("{name}.csv")))?;
    crate::output::write_result(&dir, name, &Json::arr(rows))?;
    log.status(&format!("wrote {}/{name}.{{csv,json}}", dir.display()));
    Ok(())
}
