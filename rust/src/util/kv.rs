//! Config-file format: a TOML-subset key/value parser for run presets
//! (`configs/*.toml`). Supports `[section]` headers, `key = value` lines,
//! `#` comments, strings (quoted), booleans, integers and floats. Nested
//! tables and arrays are not needed by our configs and are rejected loudly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed config file: flat `section.key -> raw string value` map.
#[derive(Debug, Default, Clone)]
pub struct KvFile {
    values: BTreeMap<String, String>,
}

impl KvFile {
    pub fn parse(text: &str) -> Result<KvFile> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?;
                if name.contains('[') || name.is_empty() {
                    bail!("line {}: invalid section '{name}'", lineno + 1);
                }
                section = name.trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            let val = val.trim();
            if val.starts_with('[') || val.starts_with('{') {
                bail!("line {}: arrays/inline tables unsupported ({full})", lineno + 1);
            }
            let val = val.trim_matches('"').to_string();
            values.insert(full, val);
        }
        Ok(KvFile { values })
    }

    pub fn parse_file(path: &std::path::Path) -> Result<KvFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        KvFile::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("config key {key}='{v}': {e}")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        self.parse_or(key, default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# a run preset
algorithm = "fastclip-v3"
steps = 200

[optimizer]
kind = "adamw"   # the paper's winner
lr = 1e-3
decoupled = true

[data]
n_train = 8192
"#;

    #[test]
    fn parses_sections_and_types() {
        let kv = KvFile::parse(SAMPLE).unwrap();
        assert_eq!(kv.get("algorithm"), Some("fastclip-v3"));
        assert_eq!(kv.parse_or::<u32>("steps", 0).unwrap(), 200);
        assert_eq!(kv.get("optimizer.kind"), Some("adamw"));
        assert!((kv.parse_or::<f32>("optimizer.lr", 0.0).unwrap() - 1e-3).abs() < 1e-9);
        assert!(kv.bool_or("optimizer.decoupled", false).unwrap());
        assert_eq!(kv.parse_or::<usize>("data.n_train", 0).unwrap(), 8192);
    }

    #[test]
    fn defaults_for_missing() {
        let kv = KvFile::parse("a = 1").unwrap();
        assert_eq!(kv.parse_or::<u32>("missing", 9).unwrap(), 9);
        assert_eq!(kv.str_or("missing", "d"), "d");
    }

    #[test]
    fn comments_and_hash_in_string() {
        let kv = KvFile::parse("name = \"a#b\" # trailing").unwrap();
        assert_eq!(kv.get("name"), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(KvFile::parse("[unterminated").is_err());
        assert!(KvFile::parse("no_equals_here").is_err());
        assert!(KvFile::parse("arr = [1, 2]").is_err());
        assert!(KvFile::parse(" = 3").is_err());
    }

    #[test]
    fn bad_typed_value_errors() {
        let kv = KvFile::parse("steps = banana").unwrap();
        assert!(kv.parse_or::<u32>("steps", 0).is_err());
    }
}
