//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Python never runs here — after `make artifacts` the Rust binary is
//! self-contained. Interchange is HLO *text* (xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos; the text parser reassigns ids).
//!
//! The `xla` crate types wrap raw PJRT pointers and are neither `Send` nor
//! `Sync`, so every worker thread owns its own [`WorkerRuntime`] (client +
//! compiled executables). Parameters are replicated and updated
//! deterministically on every worker, so no cross-thread buffer sharing is
//! needed (DESIGN.md §8).

//! Builds without the `pjrt` cargo feature substitute the in-tree
//! [`pjrt_stub`] for the `xla` crate: marshalling types work, execution
//! fails at client construction with an actionable message. Artifact
//! bundles are only producible with a working Python/JAX toolchain, so
//! every test that would execute an artifact skips (or is `#[ignore]`d)
//! when `artifacts/` is absent.

mod manifest;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;
mod worker;

pub use manifest::{ExecSig, Manifest, ModelInfo, ParamSegment, TensorSig};
pub use worker::{StepOutput, TauGrads, TauInput, WorkerRuntime};
