//! Fig. 6 / Appendix C: the impact of batch size and dataset size on
//! OpenCLIP, with the paper's two curve fits:
//! * reciprocal  p = -a/x + b   (accuracy vs batch size),
//! * power       p = α·x^β + p0 (accuracy vs dataset size).
//!
//! The fitting code is also used standalone (`fit_reciprocal`,
//! `fit_power`) and unit-tested against synthetic data.

use anyhow::Result;

use crate::config::Algorithm;
use crate::output::{f2, Table};
use crate::util::{Args, Json};

use super::common::{algo_config, apply_overrides, progress_logger, results_dir, run_seeds, Setting};

/// Least-squares fit of p = -a/x + b. Returns (a, b).
pub fn fit_reciprocal(xs: &[f64], ps: &[f64]) -> (f64, f64) {
    // linear regression of p on z = -1/x
    let zs: Vec<f64> = xs.iter().map(|&x| -1.0 / x).collect();
    let n = zs.len() as f64;
    let zm = zs.iter().sum::<f64>() / n;
    let pm = ps.iter().sum::<f64>() / n;
    let cov: f64 = zs.iter().zip(ps).map(|(z, p)| (z - zm) * (p - pm)).sum();
    let var: f64 = zs.iter().map(|z| (z - zm) * (z - zm)).sum();
    let a = cov / var.max(1e-300);
    let b = pm - a * zm;
    (a, b)
}

/// Fit p = α·x^β + p0 by grid-searching p0 and linear-regressing
/// log(p - p0) on log(x) — adequate for the 3–5 points the paper fits.
/// Returns (alpha, beta, p0).
pub fn fit_power(xs: &[f64], ps: &[f64]) -> (f64, f64, f64) {
    let pmax = ps.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mut best = (0.0, 0.0, 0.0);
    let mut best_err = f64::INFINITY;
    // p0 grid above the largest observed p (saturating growth toward p0
    // when beta < 0 is not our case; the paper's fit has alpha < 0 with
    // p0 as the asymptote) — search both sides to be safe.
    for i in 0..400 {
        let p0 = pmax + 0.01 + i as f64 * 0.25;
        // alpha negative: p0 - p = -alpha * x^beta, log-linear fit
        let ys: Vec<f64> = ps.iter().map(|&p| (p0 - p).max(1e-12).ln()).collect();
        let ls: Vec<f64> = xs.iter().map(|&x| x.ln()).collect();
        let n = ys.len() as f64;
        let lm = ls.iter().sum::<f64>() / n;
        let ym = ys.iter().sum::<f64>() / n;
        let cov: f64 = ls.iter().zip(&ys).map(|(l, y)| (l - lm) * (y - ym)).sum();
        let var: f64 = ls.iter().map(|l| (l - lm) * (l - lm)).sum();
        let beta = cov / var.max(1e-300);
        let lna = ym - beta * lm;
        let alpha = -lna.exp();
        let err: f64 = xs
            .iter()
            .zip(ps)
            .map(|(&x, &p)| {
                let pred = alpha * x.powf(beta) + p0;
                (pred - p) * (pred - p)
            })
            .sum();
        if err < best_err {
            best_err = err;
            best = (alpha, beta, p0);
        }
    }
    best
}

/// Fig. 6: OpenCLIP batch-size sweep (reciprocal fit) and dataset-size
/// sweep (power fit).
pub fn fits(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    // ---- batch-size sweep -------------------------------------------------
    let bundles = match args.get("bundles") {
        Some(list) => list.split(',').map(|s| s.to_string()).collect::<Vec<_>>(),
        None => vec![
            "artifacts/tiny_k2_b4".to_string(),
            "artifacts/tiny_k2_b8".to_string(),
            "artifacts/tiny_k2_b16".to_string(),
            "artifacts/tiny_k2_b32".to_string(),
        ],
    };
    let mut table = Table::new(
        "Fig. 6(a) analog — OpenCLIP accuracy vs global batch size",
        &["Global batch", "ZeroShot", "Datacomp"],
    );
    let mut xs = Vec::new();
    let mut ps = Vec::new();
    let mut json_batch = Vec::new();
    for bundle in &bundles {
        let mut cfg = algo_config(Setting::Medium, Algorithm::OpenClip);
        cfg.set_bundle(bundle);
        let seeds = apply_overrides(&mut cfg, args)?;
        let m = cfg.load_manifest()?;
        // keep samples-seen constant across batch sizes: steps ∝ 1/batch
        let base_samples = cfg.steps * 16 * 2; // default steps at bg=32
        cfg.steps = (base_samples / m.global_batch as u32).max(8);
        cfg.lr.total_iters = cfg.steps;
        cfg.lr.warmup_iters = cfg.steps / 8;
        let results = run_seeds(&cfg, &seeds[..1], &format!("bg={}", m.global_batch), log)?;
        let zs = results[0].final_eval.task("zeroshot_clean").unwrap_or(f32::NAN) as f64;
        table.row(vec![
            m.global_batch.to_string(),
            f2(zs),
            f2(results[0].final_eval.datacomp as f64),
        ]);
        xs.push(m.global_batch as f64);
        ps.push(zs);
        json_batch.push(Json::obj(vec![
            ("global_batch", Json::num(m.global_batch as f64)),
            ("zeroshot", Json::num(zs)),
        ]));
    }
    let (a, b) = fit_reciprocal(&xs, &ps);
    table.print();
    println!("reciprocal fit: p = -{a:.2}/x + {b:.2}");

    // ---- dataset-size sweep ----------------------------------------------
    let mut table2 = Table::new(
        "Fig. 6(b) analog — OpenCLIP accuracy vs dataset size",
        &["n_train", "ZeroShot", "Datacomp"],
    );
    let mut xs2 = Vec::new();
    let mut ps2 = Vec::new();
    let mut json_data = Vec::new();
    for n_train in [256usize, 512, 1024, 2048] {
        let mut cfg = algo_config(Setting::Medium, Algorithm::OpenClip);
        let seeds = apply_overrides(&mut cfg, args)?;
        cfg.data.n_train = n_train;
        let results = run_seeds(&cfg, &seeds[..1], &format!("n={n_train}"), log)?;
        let zs = results[0].final_eval.task("zeroshot_clean").unwrap_or(f32::NAN) as f64;
        table2.row(vec![
            n_train.to_string(),
            f2(zs),
            f2(results[0].final_eval.datacomp as f64),
        ]);
        xs2.push(n_train as f64);
        ps2.push(zs);
        json_data.push(Json::obj(vec![
            ("n_train", Json::num(n_train as f64)),
            ("zeroshot", Json::num(zs)),
        ]));
    }
    let (alpha, beta, p0) = fit_power(&xs2, &ps2);
    table2.print();
    println!("power fit: p = {alpha:.2} * x^{beta:.3} + {p0:.2}");

    let dir = results_dir(args);
    table.write_csv(&dir.join("fits_batch.csv"))?;
    table2.write_csv(&dir.join("fits_data.csv"))?;
    crate::output::write_result(
        &dir,
        "fits",
        &Json::obj(vec![
            ("batch_sweep", Json::arr(json_batch)),
            ("reciprocal_a", Json::num(a)),
            ("reciprocal_b", Json::num(b)),
            ("data_sweep", Json::arr(json_data)),
            ("power_alpha", Json::num(alpha)),
            ("power_beta", Json::num(beta)),
            ("power_p0", Json::num(p0)),
        ]),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reciprocal_fit_recovers_parameters() {
        let xs = [8.0, 16.0, 32.0, 64.0, 128.0];
        let ps: Vec<f64> = xs.iter().map(|x| -120.0 / x + 55.0).collect();
        let (a, b) = fit_reciprocal(&xs, &ps);
        assert!((a - 120.0).abs() < 1e-6, "a {a}");
        assert!((b - 55.0).abs() < 1e-6, "b {b}");
    }

    #[test]
    fn reciprocal_fit_tolerates_noise() {
        let xs = [8.0, 16.0, 32.0, 64.0];
        let ps = [40.1, 47.4, 51.8, 51.9]; // like Chen et al. rows
        let (a, b) = fit_reciprocal(&xs, &ps);
        assert!(a > 0.0, "accuracy grows with batch");
        assert!(b > 50.0 && b < 60.0, "asymptote near the top scores, got {b}");
    }

    #[test]
    fn power_fit_recovers_shape() {
        let xs = [80.0, 400.0, 2000.0];
        // p = -300 x^-0.5 + 70  -> 36.5, 55.0, 63.3
        let ps: Vec<f64> = xs.iter().map(|&x: &f64| -300.0 * x.powf(-0.5) + 70.0).collect();
        let (alpha, beta, p0) = fit_power(&xs, &ps);
        assert!(alpha < 0.0);
        assert!(beta < 0.0, "decay exponent, got {beta}");
        assert!((p0 - 70.0).abs() < 3.0, "asymptote near 70, got {p0}");
        // predictions interpolate well
        let pred = alpha * 315.0f64.powf(beta) + p0;
        let want = -300.0 * 315.0f64.powf(-0.5) + 70.0;
        assert!((pred - want).abs() < 1.0, "pred {pred} want {want}");
    }

    #[test]
    fn power_fit_monotone_series() {
        let xs = [256.0, 512.0, 1024.0, 2048.0];
        let ps = [10.0, 14.0, 16.5, 18.0];
        let (alpha, beta, p0) = fit_power(&xs, &ps);
        // fitted curve must be increasing over the data range
        let f = |x: f64| alpha * x.powf(beta) + p0;
        assert!(f(512.0) > f(256.0));
        assert!(f(2048.0) > f(1024.0));
        assert!(p0 >= 18.0, "asymptote above the best observation");
    }
}
