//! Shared substrates built in-tree (the vendored crate set contains only
//! `xla` + `anyhow`): a deterministic splittable PRNG, a JSON
//! parser/writer (artifact manifests, result files), a small CLI argument
//! parser, a key-value config file format, and numeric helpers.
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

mod cli;
mod json;
mod kv;
mod ratio;
mod rng;

pub use cli::Args;
pub use json::Json;
pub use kv::KvFile;
pub use ratio::{ratio_cell, safe_rate, safe_ratio};
pub use rng::{l2_normalize_rows, mean, std_dev, Rng, RngState};
