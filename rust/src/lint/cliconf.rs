//! CLI/config drift: the four places a training knob lives — the
//! `main.rs` parser, the `fastclip help` text, `TrainConfig::KNOWN` (and
//! its `from_kv` / `to_file_string` round-trip) and the README — must
//! agree. Flags that exist in one surface but not another are exactly
//! how "works on my invocation" drift starts.

use std::path::Path;

use anyhow::Result;

use super::source::{find_all, is_ident, SourceFile};
use super::{Finding, Severity};

/// `Args` accessor calls whose first argument is a flag name literal.
const ACCESSORS: &[&str] = &[
    "args.get(\"",
    "args.str_or(\"",
    "args.usize_or(\"",
    "args.u32_or(\"",
    "args.u64_or(\"",
    "args.f32_or(\"",
    "args.flag(\"",
    "args.required(\"",
];

/// CLI flag → `TrainConfig` key when the spelling differs from the
/// mechanical dash→underscore mapping.
const ALIAS: &[(&str, &str)] = &[
    ("algo", "algorithm"),
    ("bundle", "artifact_dir"),
    ("workers", "n_workers"),
    ("batch", "local_batch"),
    ("lr", "lr.peak"),
    ("warmup", "lr.warmup_iters"),
    ("gamma-const", "gamma.gamma"),
    ("gamma-min", "gamma.gamma_min"),
    ("decay-epochs", "gamma.decay_epochs"),
    ("optimizer", "optimizer.kind"),
    ("n-train", "data.n_train"),
    ("n-eval", "data.n_eval"),
    ("n-classes", "data.n_classes"),
    ("bucket-mb", "bucket_mb"),
];

/// Flags that are CLI machinery, not training configuration: they have
/// no `TrainConfig` key on purpose.
const CLI_ONLY: &[&str] =
    &["config", "save", "params", "dir", "root", "deny-warnings", "list-rules"];

/// Config keys reachable only through a config file (defaults or derived
/// on the CLI side), never as a dedicated flag.
const CONFIG_ONLY: &[&str] = &[
    "tau_min",
    "tau_lr_decay_below",
    "bucket_bytes",
    "lr.min",
    "lr.total_iters",
    "optimizer.beta1",
    "optimizer.beta2",
    "optimizer.eps",
    "optimizer.weight_decay",
    "optimizer.momentum",
    "gamma.kind",
    "data.noise",
    "data.zipf_s",
    "data.seed",
];

/// Keys `from_kv` accepts that `to_file_string` intentionally never
/// writes (read-only aliases).
const TO_FILE_EXEMPT: &[&str] = &["bucket_mb"];

fn flag_char(c: char) -> bool {
    c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-'
}

/// Extract `(name, first line)` pairs of flag-name literals passed to the
/// accessor calls in `prefixes`, from the comment-stripped view.
fn accessor_flags(sf: &SourceFile, prefixes: &[&str]) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for idx in 0..sf.nocomment.len() {
        let line = &sf.nocomment[idx];
        for pre in prefixes {
            for at in find_all(line, pre) {
                let name: String =
                    line[at + pre.len()..].chars().take_while(|c| flag_char(*c)).collect();
                if !name.is_empty()
                    && line[at + pre.len()..].chars().nth(name.chars().count()) == Some('"')
                    && !out.iter().any(|(n, _)| *n == name)
                {
                    out.push((name, idx + 1));
                }
            }
        }
    }
    out
}

/// Every `--flag` mention in the file's strings (the help text), with the
/// line it first appears on.
fn dash_flags(lines: &[String]) -> Vec<(String, usize)> {
    let mut out: Vec<(String, usize)> = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        for at in find_all(line, "--") {
            let rest = &line[at + 2..];
            if !rest.chars().next().is_some_and(|c| c.is_ascii_lowercase()) {
                continue;
            }
            let name: String = rest.chars().take_while(|c| flag_char(*c)).collect();
            let name = name.trim_end_matches('-').to_string();
            if !name.is_empty() && !out.iter().any(|(n, _)| *n == name) {
                out.push((name, idx + 1));
            }
        }
    }
    out
}

fn config_key(flag: &str) -> String {
    ALIAS
        .iter()
        .find(|(f, _)| *f == flag)
        .map(|(_, k)| k.to_string())
        .unwrap_or_else(|| flag.replace('-', "_"))
}

fn key_char(c: char) -> bool {
    is_ident(c) || c == '.'
}

/// Walk a function body by brace depth starting at `start` (the line
/// containing the `fn` keyword); calls `visit` for each in-body line.
fn for_fn_body(sf: &SourceFile, start: usize, mut visit: impl FnMut(usize)) {
    let mut depth = 0i64;
    let mut entered = false;
    for idx in start..sf.code.len() {
        visit(idx);
        depth += sf.code[idx].matches('{').count() as i64;
        depth -= sf.code[idx].matches('}').count() as i64;
        if depth > 0 {
            entered = true;
        }
        if entered && depth <= 0 {
            break;
        }
    }
}

fn find_line(sf: &SourceFile, needle: &str) -> Option<usize> {
    (0..sf.nocomment.len()).find(|&i| sf.nocomment[i].contains(needle))
}

/// Run the CLI/config drift checks. Either side (main.rs, config/mod.rs,
/// README.md) being absent skips the checks that need it.
pub fn check(root: &Path, sources: &[SourceFile], findings: &mut Vec<Finding>) -> Result<()> {
    let main = sources.iter().find(|s| s.rel == "rust/src/main.rs");
    let config = sources.iter().find(|s| s.rel == "rust/src/config/mod.rs");
    let readme_path = root.join("README.md");
    let readme = if readme_path.is_file() {
        Some(std::fs::read_to_string(&readme_path)?)
    } else {
        None
    };

    let mut err = |rule: &'static str, file: &str, line: usize, message: String| {
        findings.push(Finding {
            rule,
            severity: Severity::Error,
            file: file.to_string(),
            line,
            message,
        });
    };

    let parsed = main.map(|m| accessor_flags(m, ACCESSORS)).unwrap_or_default();

    // ---- cli-flag-drift -------------------------------------------------
    if let Some(m) = main {
        let help = dash_flags(&m.nocomment);
        let readme_flags = readme
            .as_deref()
            .map(|t| {
                let lines: Vec<String> = t.lines().map(str::to_string).collect();
                dash_flags(&lines)
            })
            .unwrap_or_default();
        // flags parsed outside main.rs (bench binaries, `fastclip lint`
        // itself) are legitimate help-text entries too
        let mut other_flags: Vec<(String, usize)> = Vec::new();
        for sf in sources {
            if sf.rel != m.rel {
                other_flags.extend(accessor_flags(sf, ACCESSORS));
            }
        }
        for (f, line) in &parsed {
            if !help.iter().any(|(h, _)| h == f) {
                err(
                    "cli-flag-drift",
                    &m.rel,
                    *line,
                    format!("--{f} is parsed but missing from the `fastclip help` text"),
                );
            }
            if readme.is_some() && !readme_flags.iter().any(|(h, _)| h == f) {
                err(
                    "cli-flag-drift",
                    &m.rel,
                    *line,
                    format!("--{f} is parsed but undocumented in README.md"),
                );
            }
        }
        for (f, line) in &help {
            if f != "help"
                && !parsed.iter().any(|(p, _)| p == f)
                && !other_flags.iter().any(|(p, _)| p == f)
            {
                err(
                    "cli-flag-drift",
                    &m.rel,
                    *line,
                    format!("--{f} appears in the help text but is parsed nowhere"),
                );
            }
        }
    }

    // ---- cli-config-drift -----------------------------------------------
    let Some(cfg) = config else {
        return Ok(());
    };

    // KNOWN keys, with their lines
    let mut known: Vec<(String, usize)> = Vec::new();
    if let Some(start) = find_line(cfg, "const KNOWN") {
        for idx in start..cfg.nocomment.len() {
            for lit in cfg.string_literals(idx) {
                if !lit.is_empty() && lit.chars().all(key_char) {
                    known.push((lit, idx + 1));
                }
            }
            if cfg.code[idx].contains("];") {
                break;
            }
        }
    }

    // from_kv reads
    let mut fromkv: Vec<(String, usize)> = Vec::new();
    if let Some(start) = find_line(cfg, "fn from_kv") {
        for_fn_body(cfg, start, |idx| {
            for pre in ["kv.parse_or(\"", "kv.get(\"", "kv.str_or(\""] {
                for at in find_all(&cfg.nocomment[idx], pre) {
                    let key: String = cfg.nocomment[idx][at + pre.len()..]
                        .chars()
                        .take_while(|c| key_char(*c))
                        .collect();
                    if !key.is_empty() && !fromkv.iter().any(|(k, _)| *k == key) {
                        fromkv.push((key, idx + 1));
                    }
                }
            }
        });
    }

    // to_file_string writes, section-prefix aware
    let mut tofile: Vec<(String, usize)> = Vec::new();
    if let Some(start) = find_line(cfg, "fn to_file_string") {
        let mut prefix = String::new();
        for_fn_body(cfg, start, |idx| {
            if !cfg.nocomment[idx].contains("writeln!") {
                return;
            }
            let Some(lit) = cfg.string_literals(idx).into_iter().next() else {
                return;
            };
            if let Some(rest) = lit.strip_prefix("\\n[") {
                if let Some(sec) = rest.split(']').next() {
                    prefix = format!("{sec}.");
                }
            } else if let Some((key, _)) = lit.split_once(" = ") {
                if !key.is_empty() && key.chars().all(key_char) {
                    let full = format!("{prefix}{key}");
                    if !tofile.iter().any(|(k, _)| *k == full) {
                        tofile.push((full, idx + 1));
                    }
                }
            }
        });
    }

    let cli_image: Vec<String> = parsed
        .iter()
        .filter(|(f, _)| !CLI_ONLY.contains(&f.as_str()))
        .map(|(f, _)| config_key(f))
        .collect();

    if let Some(m) = main {
        for (f, line) in &parsed {
            if CLI_ONLY.contains(&f.as_str()) {
                continue;
            }
            let key = config_key(f);
            if !known.iter().any(|(k, _)| *k == key) {
                err(
                    "cli-config-drift",
                    &m.rel,
                    *line,
                    format!("--{f} maps to config key '{key}' which is not in TrainConfig::KNOWN"),
                );
            }
        }
    }
    for (k, line) in &known {
        if !fromkv.iter().any(|(f, _)| f == k) {
            err(
                "cli-config-drift",
                &cfg.rel,
                *line,
                format!("KNOWN key '{k}' is never read by from_kv"),
            );
        }
        if !tofile.iter().any(|(f, _)| f == k) && !TO_FILE_EXEMPT.contains(&k.as_str()) {
            err(
                "cli-config-drift",
                &cfg.rel,
                *line,
                format!("KNOWN key '{k}' is never written by to_file_string (round-trip hole)"),
            );
        }
        if main.is_some()
            && !cli_image.contains(k)
            && !CONFIG_ONLY.contains(&k.as_str())
        {
            err(
                "cli-config-drift",
                &cfg.rel,
                *line,
                format!("KNOWN key '{k}' is reachable from no CLI flag (and not CONFIG_ONLY)"),
            );
        }
    }
    for (k, line) in &fromkv {
        if !known.iter().any(|(n, _)| n == k) {
            err(
                "cli-config-drift",
                &cfg.rel,
                *line,
                format!("from_kv reads '{k}' which is not in TrainConfig::KNOWN"),
            );
        }
    }
    for (k, line) in &tofile {
        if !known.iter().any(|(n, _)| n == k) {
            err(
                "cli-config-drift",
                &cfg.rel,
                *line,
                format!("to_file_string writes '{k}' which is not in TrainConfig::KNOWN"),
            );
        }
    }
    Ok(())
}
