//! Guarded ratio/rate helpers for reporting code (the `inf`/`NaN`
//! hardening satellite): every speedup, throughput and hidden-fraction a
//! report emits goes through these, so a zero-duration or zero-iteration
//! run yields an explicit `None` — rendered as `"n/a"` / JSON `null` —
//! instead of a non-finite number that JSON cannot encode and a
//! regression gate cannot compare.

/// `count / seconds` as a rate, or `None` when the denominator is zero,
/// negative or non-finite (an unmeasurably fast or empty run), or the
/// numerator is non-finite.
pub fn safe_rate(count: f64, seconds: f64) -> Option<f64> {
    safe_ratio(count, seconds)
}

/// `a / b`, or `None` when the quotient would be non-finite (`b` zero or
/// non-finite, `a` non-finite). `b` must be strictly positive — rates
/// and durations are magnitudes.
pub fn safe_ratio(a: f64, b: f64) -> Option<f64> {
    if !a.is_finite() || !b.is_finite() || b <= 0.0 {
        return None;
    }
    let q = a / b;
    q.is_finite().then_some(q)
}

/// Render an optional ratio for a table cell: `"{:.2}x"` or `"n/a"`.
pub fn ratio_cell(r: Option<f64>) -> String {
    match r {
        Some(v) => format!("{v:.2}x"),
        None => "n/a".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_duration_is_none_not_inf() {
        // the regression the satellite pins: a 0-second run must not
        // produce inf/NaN speedups
        assert_eq!(safe_rate(12.0, 0.0), None);
        assert_eq!(safe_rate(0.0, 0.0), None, "0/0 would be NaN");
        assert_eq!(safe_ratio(1.0, -2.0), None, "negative denominators rejected");
        assert_eq!(safe_ratio(f64::INFINITY, 2.0), None);
        assert_eq!(safe_ratio(3.0, f64::NAN), None);
        assert_eq!(safe_ratio(1.0, 5e-324), None, "overflowing quotient");
        assert_eq!(safe_rate(12.0, 2.0), Some(6.0));
        assert_eq!(ratio_cell(Some(1.5)), "1.50x");
        assert_eq!(ratio_cell(None), "n/a");
    }

    #[test]
    fn emitted_json_stays_valid_for_missing_rates() {
        // None → Json::Null; and even a raw non-finite Num degrades to
        // null (not an invalid token), so a BENCH_*.json always parses
        use crate::util::Json;
        let doc = Json::obj(vec![
            ("rate", Json::Null),
            ("bad", Json::num(f64::NAN)),
            ("worse", Json::num(f64::INFINITY)),
        ]);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).expect("document must stay valid JSON");
        assert!(matches!(back.get("rate").unwrap(), Json::Null));
        assert!(matches!(back.get("bad").unwrap(), Json::Null));
        assert!(matches!(back.get("worse").unwrap(), Json::Null));
    }
}
