# L2: distributed FCCO/SogCLR training-step graphs for every loss family
# in the paper: GCL (SogCLR / FastCLIP-v1), unscaled-GCL (FastCLIP-v0),
# RGCL with individual temperatures (iSogCLR / FastCLIP-v2), RGCL-g with a
# single learnable temperature (FastCLIP-v3), and MBCL (OpenCLIP baseline).
#
# Per iteration the Rust coordinator runs, on every worker k (DESIGN.md §4):
#   1. `encode`           local batch -> (e1_k, e2_k)
#   2. ALL_GATHER(e1,e2)  O(K*B*d)   and later ALL_GATHER(u) O(K*B) scalars
#   3. `phase_g`          gathered feats -> (g1, g2) and u^{t+1} (Eq. 1)
#   4. `step_<variant>`   gathered feats + gathered u^{t+1} -> local gradient
#                         contribution + loss + tau-gradient
#   5. ALL_REDUCE(grad)   and the Rust-side optimizer / tau / gamma updates
#
# The gradient estimator is realized as a *surrogate*: with row weights
# w_i = f'(u_i^{t+1}) held by stop_gradient,
#     Surr = (1/|B|) sum_i sg(w_i) * g_i(live embeddings)
# whose autodiff gradient is exactly (1/|B|) sum_i f'(u_i) * grad(g_i) —
# Eq. (2)-(7) of the paper. Each worker differentiates only through its own
# live rows/columns, splits the sum as
#     (local rows x all cols)   -> pair_exp_rowsum       (the "G_{w,a,k}" part)
#   + (nonlocal rows x local cols) -> pair_exp_rowsum_nodiag ("G_{w,b,k}")
# and SUM-ALL_REDUCE recovers the full estimator without ever forming the
# (nonlocal x nonlocal) terms (which carry no local gradient).
import functools

import jax
import jax.numpy as jnp

from .kernels.contrastive import pair_exp_rowsum, pair_exp_rowsum_nodiag
from . import model as model_lib

VARIANTS = ("gcl", "gcl_v0", "rgcl_i", "rgcl_g", "mbcl")

sg = jax.lax.stop_gradient


def phase_g(e1g, e2g, offset, u1, u2, tau1, tau2, gamma, *, bl):
    """Compute batch estimators g1, g2 for the LOCAL rows of the gathered
    embeddings and the moving-average update of u (Eq. 1).

    Variant-independent (OpenCLIP passes gamma=1 so u^{t+1} = g).

    e1g, e2g: (Bg, d) gathered; offset: () i32 local row offset;
    u1, u2, tau1, tau2: (Bl,); gamma: () f32.
    Returns g1, g2, u1_new, u2_new: (Bl,).
    """
    d = e1g.shape[1]
    e1l = jax.lax.dynamic_slice(e1g, (offset, 0), (bl, d))
    e2l = jax.lax.dynamic_slice(e2g, (offset, 0), (bl, d))
    diag = offset + jnp.arange(bl, dtype=jnp.int32)
    g1 = pair_exp_rowsum(e1l, e2g, diag, tau1)
    g2 = pair_exp_rowsum(e2l, e1g, diag, tau2)
    u1n = (1.0 - gamma) * u1 + gamma * g1
    u2n = (1.0 - gamma) * u2 + gamma * g2
    return g1, g2, u1n, u2n


def _weights(variant, u, tau_rows, eps, bg):
    """Row weight f'(u^{t+1}) per loss family (stop-grad applied by caller).

    gcl / rgcl_g : d/dg [tau * log(eps+g)]            = tau/(eps+u)
    gcl_v0       : d/dg [log(eps+g)]                  = 1/(eps+u)
    rgcl_i       : d/dg [tau_i * log(eps+g)]          = tau_i/(eps+u)
    mbcl         : d/dg [log(1/B + (B-1)/B * g)]      = (B-1)/(1+(B-1)u)
    """
    if variant == "mbcl":
        return (bg - 1.0) / (1.0 + (bg - 1.0) * u)
    if variant == "gcl_v0":
        return 1.0 / (eps + u)
    return tau_rows / (eps + u)


def _loss_value(variant, u1l, u2l, tau1l, tau2l, eps, rho, bg):
    """Reported (local-mean) loss value for logging, from updated u."""
    if variant == "mbcl":
        t1 = jnp.log(1.0 / bg + (bg - 1.0) / bg * u1l)
        t2 = jnp.log(1.0 / bg + (bg - 1.0) / bg * u2l)
        return jnp.mean(t1 + t2)
    l1, l2 = jnp.log(eps + u1l), jnp.log(eps + u2l)
    if variant in ("gcl", "gcl_v0"):
        return jnp.mean(tau1l * l1 + tau2l * l2)
    # rgcl family carries the +rho margin terms
    return jnp.mean(tau1l * (l1 + rho) + tau2l * (l2 + rho))


def _split_nonlocal(x, offset, bl):
    """Drop the local block [offset, offset+bl) via a dynamic roll."""
    return jnp.roll(x, -offset, axis=0)[bl:]


def _surrogate(variant, cfg, flat, images, texts, e1g, e2g, u1g, u2g,
               tau1g, tau2g, tau1g_row, tau2g_row, offset, eps, *, bl, bg):
    """The scalar whose gradient w.r.t. `flat` is this worker's gradient
    contribution (and w.r.t. tau*_row, the temperature gradient terms).

    tau1g/tau2g feed the *column* kernel calls (always stop-grad);
    tau1g_row/tau2g_row feed the *row* calls — passing the differentiable
    temperature there makes d(surrogate)/d(tau_row) count every (i, j)
    pair exactly once across workers (rows partition the global batch).
    """
    e1, e2 = model_lib.encode(cfg, flat, images, texts)      # (Bl, d) live
    e1g_sp = jax.lax.dynamic_update_slice(sg(e1g), e1, (offset, 0))
    e2g_sp = jax.lax.dynamic_update_slice(sg(e2g), e2, (offset, 0))
    diag = offset + jnp.arange(bl, dtype=jnp.int32)

    u1l = jax.lax.dynamic_slice(u1g, (offset,), (bl,))
    u2l = jax.lax.dynamic_slice(u2g, (offset,), (bl,))
    tau1l_row = jax.lax.dynamic_slice(tau1g_row, (offset,), (bl,))
    tau2l_row = jax.lax.dynamic_slice(tau2g_row, (offset,), (bl,))

    # --- local rows x all columns (covers (loc,loc) and (loc,nonloc)) ---
    g1_row = pair_exp_rowsum(e1, e2g_sp, diag, tau1l_row)
    g2_row = pair_exp_rowsum(e2, e1g_sp, diag, tau2l_row)
    w1l = sg(_weights(variant, u1l, tau1l_row, eps, bg))
    w2l = sg(_weights(variant, u2l, tau2l_row, eps, bg))
    row_part = jnp.sum(w1l * g1_row + w2l * g2_row)

    if bg == bl:  # single-worker: every row is local, no column part
        return row_part / bg, (u1l, u2l)

    # --- nonlocal rows x local columns ------------------------------------
    e1_nl = _split_nonlocal(sg(e1g), offset, bl)             # (Bg-Bl, d)
    e2_nl = _split_nonlocal(sg(e2g), offset, bl)
    sd_nl = jnp.sum(e1_nl * e2_nl, axis=-1)                  # s_ii, constant
    u1_nl = _split_nonlocal(u1g, offset, bl)
    u2_nl = _split_nonlocal(u2g, offset, bl)
    tau1_nl = sg(_split_nonlocal(tau1g, offset, bl))
    tau2_nl = sg(_split_nonlocal(tau2g, offset, bl))
    g1_col = pair_exp_rowsum_nodiag(e1_nl, e2, sd_nl, tau1_nl, bg - 1)
    g2_col = pair_exp_rowsum_nodiag(e2_nl, e1, sd_nl, tau2_nl, bg - 1)
    w1n = sg(_weights(variant, u1_nl, tau1_nl, eps, bg))
    w2n = sg(_weights(variant, u2_nl, tau2_nl, eps, bg))
    col_part = jnp.sum(w1n * g1_col + w2n * g2_col)

    return (row_part + col_part) / bg, (u1l, u2l)


def step(variant, cfg, flat, images, texts, e1g, e2g, u1g, u2g,
         tau_args, offset, eps, rho, *, bl, bg, k_workers):
    """One worker's gradient computation for `variant`.

    tau_args: (tau,) scalar for global-temperature variants, or
              (tau1g, tau2g) — gathered (Bg,) vectors — for rgcl_i.
    Returns dict with: grad (P,), loss (), and the variant's tau grads.
    SUM-ALL_REDUCE every output across workers (loss/tau terms carry 1/K
    or row-partition scaling so that the sum is the paper's estimator).
    """
    if variant == "rgcl_i":
        tau1g, tau2g = tau_args
        tau_scalar = None
    else:
        (tau_scalar,) = tau_args
        tau1g = tau2g = jnp.full((bg,), 1.0, jnp.float32) * tau_scalar

    def surr(flat_, tau1g_row, tau2g_row):
        return _surrogate(variant, cfg, flat_, images, texts, e1g, e2g,
                          u1g, u2g, tau1g, tau2g, tau1g_row, tau2g_row,
                          offset, eps, bl=bl, bg=bg)

    if variant in ("gcl", "mbcl"):
        # constant tau (v1/SogCLR) or tau handled as learnable-by-row (mbcl)
        if variant == "mbcl":
            (grad, dtau1, dtau2), (_, aux) = _grad_with_tau(surr, flat, tau1g, tau2g)
            tau_grad = jnp.sum(dtau1) + jnp.sum(dtau2)
        else:
            grad, aux = _grad_only(surr, flat, tau1g, tau2g)
            tau_grad = jnp.zeros(())
        u1l, u2l = aux
        loss = _local_loss(variant, u1l, u2l, tau1g, tau2g, offset, eps, rho,
                           bl, bg, k_workers)
        return {"grad": grad, "loss": loss, "tau_grad": tau_grad}

    if variant == "gcl_v0":
        # Eq. (8): G_tau = (1/Bg) sum_i w0_i dg_i/dtau, rows partitioned.
        (grad, dtau1, dtau2), (_, aux) = _grad_with_tau(surr, flat, tau1g, tau2g)
        tau_grad = jnp.sum(dtau1) + jnp.sum(dtau2)
        u1l, u2l = aux
        loss = _local_loss(variant, u1l, u2l, tau1g, tau2g, offset, eps, rho,
                           bl, bg, k_workers)
        return {"grad": grad, "loss": loss, "tau_grad": tau_grad}

    if variant == "rgcl_g":
        # Eq. (10): log terms + 2*rho + tau * (unscaled dg/dtau sum).
        # The surrogate's row weights already carry tau/(eps+u); its tau-row
        # gradient is  (1/Bg) sum_i tau*w0_i*dg_i/dtau  == the last term.
        (grad, dtau1, dtau2), (_, aux) = _grad_with_tau(surr, flat, tau1g, tau2g)
        u1l, u2l = aux
        log_terms = jnp.sum(jnp.log(eps + u1l) + jnp.log(eps + u2l)) / bg
        tau_grad = log_terms + 2.0 * rho / k_workers + jnp.sum(dtau1) + jnp.sum(dtau2)
        loss = _local_loss(variant, u1l, u2l, tau1g, tau2g, offset, eps, rho,
                           bl, bg, k_workers)
        return {"grad": grad, "loss": loss, "tau_grad": tau_grad}

    assert variant == "rgcl_i"
    # Eq. (9), per local sample (stochastic coordinate update; 1/|S| scale
    # is applied by the Rust coordinator, which knows the dataset size).
    (grad, dtau1g, dtau2g), (_, aux) = _grad_with_tau(surr, flat, tau1g, tau2g)
    u1l, u2l = aux
    tau1l = jax.lax.dynamic_slice(tau1g, (offset,), (bl,))
    tau2l = jax.lax.dynamic_slice(tau2g, (offset,), (bl,))
    dtau1l = jax.lax.dynamic_slice(dtau1g, (offset,), (bl,))
    dtau2l = jax.lax.dynamic_slice(dtau2g, (offset,), (bl,))
    # dtau*l is (1/Bg) w_i dg_i/dtau_i with w = tau/(eps+u); Eq. 9 wants
    # log(eps+u)+rho + tau*(1/(eps+u))*dg/dtau (per-sample, batch estimator
    # of the per-sample loss, NOT averaged over the batch) -> rescale by Bg.
    tau1_grad = jnp.log(eps + u1l) + rho + bg * dtau1l
    tau2_grad = jnp.log(eps + u2l) + rho + bg * dtau2l
    loss = _local_loss(variant, u1l, u2l, tau1l, tau2l, offset, eps, rho,
                       bl, bg, k_workers, per_sample_tau=True)
    return {"grad": grad, "loss": loss,
            "tau1_grad": tau1_grad, "tau2_grad": tau2_grad}


def _grad_only(surr, flat, tau1g, tau2g):
    def f(flat_):
        v, aux = surr(flat_, sg(tau1g), sg(tau2g))
        return v, aux
    (_, aux), grad = jax.value_and_grad(f, has_aux=True)(flat)
    return grad, aux


def _grad_with_tau(surr, flat, tau1g, tau2g):
    def f(flat_, t1, t2):
        v, aux = surr(flat_, t1, t2)
        return v, aux
    grads, (v, aux) = _value_grads(f, flat, tau1g, tau2g)
    return grads, (v, aux)


def _value_grads(f, flat, t1, t2):
    (v, aux), grads = jax.value_and_grad(f, argnums=(0, 1, 2), has_aux=True)(flat, t1, t2)
    return grads, (v, aux)


def _local_loss(variant, u1l, u2l, tau1g, tau2g, offset, eps, rho, bl, bg,
                k_workers, per_sample_tau=False):
    if per_sample_tau:
        t1l, t2l = tau1g, tau2g  # already sliced by caller
    else:
        t1l = jax.lax.dynamic_slice(tau1g, (offset,), (bl,))
        t2l = jax.lax.dynamic_slice(tau2g, (offset,), (bl,))
    # scaled so that SUM over workers = global mean loss
    return _loss_value(variant, u1l, u2l, t1l, t2l, eps, rho, bg) / k_workers
