use std::sync::Mutex;

pub struct Pair {
    pub a: Mutex<u32>,
    pub b: Mutex<u32>,
}

pub fn forward(p: &Pair) -> u32 {
    let ga = p.a.lock().unwrap();
    let gb = p.b.lock().unwrap();
    *ga + *gb
}

pub fn backward(p: &Pair) -> u32 {
    let gb = p.b.lock().unwrap();
    let ga = p.a.lock().unwrap();
    *ga + *gb
}
