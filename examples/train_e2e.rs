//! End-to-end driver (EXPERIMENTS.md §E2E): train the largest bundle built
//! on this testbed through the full three-layer stack — synthetic
//! image–text corpus → Pallas-kernel loss graphs (AOT HLO) → distributed
//! Rust coordinator — for a few hundred steps, logging the loss curve and
//! periodic Datacomp-analog evaluations.
//!
//! Bundle selection: `medium_k2_b8` (~21M-parameter CLIP) when built,
//! falling back to `small_k2_b16` (~4.4M) then `tiny_k2_b8`. Override
//! with `--bundle` / `--steps` / `--algo`.
//!
//! Run with: `cargo run --release --example train_e2e -- [--steps N]`

use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::output::{sparkline, Table};
use fastclip::util::{Args, Json};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let bundle = args.get("bundle").map(|s| s.to_string()).unwrap_or_else(|| {
        for b in ["artifacts/medium_k2_b8", "artifacts/small_k2_b16", "artifacts/tiny_k2_b8"] {
            if std::path::Path::new(b).join("manifest.json").exists() {
                return b.to_string();
            }
        }
        "artifacts/tiny_k2_b8".to_string()
    });
    let algo = Algorithm::from_id(&args.str_or("algo", "fastclip-v3"))?;

    let mut cfg = TrainConfig::new(&bundle, algo);
    cfg.steps = args.u32_or("steps", 240)?;
    cfg.iters_per_epoch = 16;
    cfg.data.n_train = args.usize_or("n-train", 4096)?;
    cfg.data.n_eval = 192;
    cfg.data.n_classes = 64;
    cfg.lr.peak = 2e-4;
    cfg.lr.total_iters = cfg.steps;
    cfg.lr.warmup_iters = cfg.steps / 10;
    cfg.eval_every = args.u32_or("eval-every", cfg.steps / 6)?;
    cfg.eps = 1e-6; // xlarge-analog setting (Appendix D)
    cfg.rho = 16.0;

    // native backend (no artifacts): the bundle name still selects the
    // preset/topology via TrainConfig::set_bundle
    let manifest = cfg.load_manifest()?;
    println!(
        "e2e: {} on {} — {} params, K={} workers, global batch {}, {} steps",
        algo.name(),
        bundle,
        manifest.n_params,
        manifest.k_workers,
        manifest.global_batch,
        cfg.steps
    );

    let t0 = std::time::Instant::now();
    let result = Trainer::new(cfg)?.run()?;

    let losses: Vec<f32> = result.history.iter().map(|h| h.loss).collect();
    println!("\nloss curve: {}", sparkline(&losses, 64));
    let mut t = Table::new(
        "E2E evaluation trajectory",
        &["step", "loss", "Datacomp", "Retrieval", "IN&Var"],
    );
    for e in &result.evals {
        let loss = result
            .history
            .iter()
            .rev()
            .find(|h| h.step < e.step)
            .map(|h| h.loss)
            .unwrap_or(f32::NAN);
        t.row(vec![
            e.step.to_string(),
            format!("{loss:.4}"),
            format!("{:.2}", e.summary.datacomp),
            format!("{:.2}", e.summary.retrieval),
            format!("{:.2}", e.summary.in_variants),
        ]);
    }
    t.print();
    let ms = result.timing.per_iter_ms();
    println!(
        "per-iter: {:.1} ms total ({:.1} compute / {:.2} pure comm / {:.2} others), wall {:.1}s",
        ms.total, ms.compute, ms.comm_pure, ms.others, t0.elapsed().as_secs_f64()
    );

    // persist the curve for EXPERIMENTS.md
    let json = Json::obj(vec![
        ("bundle", Json::str(bundle)),
        ("algorithm", Json::str(algo.name())),
        ("n_params", Json::num(manifest.n_params as f64)),
        ("loss", Json::arr(losses.iter().map(|&v| Json::num(v as f64)))),
        (
            "evals",
            Json::arr(result.evals.iter().map(|e| {
                Json::obj(vec![
                    ("step", Json::num(e.step as f64)),
                    ("datacomp", Json::num(e.summary.datacomp as f64)),
                    ("retrieval", Json::num(e.summary.retrieval as f64)),
                    ("in_variants", Json::num(e.summary.in_variants as f64)),
                ])
            })),
        ),
    ]);
    fastclip::output::write_result(std::path::Path::new("results"), "train_e2e", &json)?;
    println!("wrote results/train_e2e.json");

    let head_n = 8.min(losses.len());
    let head = losses[..head_n].iter().sum::<f32>() / head_n as f32;
    anyhow::ensure!(result.tail_loss(16) < head, "e2e sanity: loss should decrease");
    println!("E2E OK");
    Ok(())
}
