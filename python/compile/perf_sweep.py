# L1 performance analysis: block-shape sweep for the pair_exp_rowsum
# Pallas kernel (EXPERIMENTS.md §Perf, DESIGN.md §7).
#
# interpret=True timings are CPU-numpy and NOT a TPU proxy, so this sweep
# optimizes STRUCTURE, not wallclock: for each (bm, bn) candidate it
# reports
#   * VMEM footprint of one grid step (A-tile + B-tile + vectors + the
#     accumulator block) against the ~16 MiB/core budget;
#   * MXU utilization estimate: the fraction of an aligned
#     128x128x(d) systolic pass that the tile's real work occupies
#     (padding waste from ceil-rounding M, N, d to the tile grid);
#   * HBM traffic per kernel invocation (tiles re-read per grid axis) and
#     arithmetic intensity (flops/byte), locating the kernel against the
#     roofline ridge;
# and verifies numerics vs the pure-jnp oracle at every candidate.
#
# Usage: python -m compile.perf_sweep [--m 256] [--n 256] [--d 128]
import argparse
import itertools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import contrastive, ref

MXU = 128          # systolic array dim (TPU v4/v5 class)
VMEM_BYTES = 16 * 2**20
F4 = 4


def ceil_to(x, m):
    return (x + m - 1) // m * m


def analyze(m, n, d, bm, bn):
    """Static structure analysis of one (bm, bn) choice."""
    mp, np_ = ceil_to(m, bm), ceil_to(n, bn)
    grid = (mp // bm) * (np_ // bn)
    # one grid step holds: A (bm,d), B (bn,d), 4 bm-vectors, g-block (bm,)
    vmem = (bm * d + bn * d + 5 * bm) * F4
    # useful MAC work vs aligned-systolic work for the (bm,d)x(d,bn) tile
    useful = m * n * d
    padded = mp * np_ * ceil_to(d, MXU)
    mxu_util = useful / padded
    # HBM traffic: A re-read once per j-step? No — A block is revisited
    # along j with the same i: stays resident; B re-read per i-row.
    hbm = (mp * d * (1) + np_ * d * (mp // bm) + 2 * mp) * F4
    flops = 2 * m * n * d + 4 * m * n  # matmul + exp/mask epilogue
    return {
        "bm": bm,
        "bn": bn,
        "grid_steps": grid,
        "vmem_bytes": vmem,
        "vmem_frac": vmem / VMEM_BYTES,
        "mxu_utilization": mxu_util,
        "hbm_bytes": hbm,
        "arith_intensity": flops / hbm,
    }


def check_numerics(m, n, d, bm, bn, rng):
    a = rng.standard_normal((m, d)).astype(np.float32)
    b = rng.standard_normal((n, d)).astype(np.float32)
    a /= np.linalg.norm(a, axis=1, keepdims=True)
    b /= np.linalg.norm(b, axis=1, keepdims=True)
    diag = np.arange(m, dtype=np.int32) % n
    tau = np.full((m,), 0.05, np.float32)
    got = contrastive.pair_exp_rowsum(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(diag), jnp.asarray(tau), bm=bm, bn=bn
    )
    want = ref.pair_exp_rowsum_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(diag),
                                   jnp.asarray(tau))
    err = float(jnp.max(jnp.abs(got - want) / (jnp.abs(want) + 1e-6)))
    return err


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    m, n, d = args.m, args.n, args.d
    rng = np.random.default_rng(0)

    rows = []
    print(f"pair_exp_rowsum block sweep  M={m} N={n} d={d}")
    print(f"{'bm':>5} {'bn':>5} {'grid':>6} {'VMEM':>10} {'MXU util':>9} "
          f"{'AI f/B':>7} {'max rel err':>12}")
    for bm, bn in itertools.product([8, 32, 64, 128, 256], [128, 256, 512]):
        if bm > ceil_to(m, 8) or bn > ceil_to(n, 128):
            continue
        info = analyze(m, n, d, bm, bn)
        if info["vmem_frac"] > 1.0:
            continue  # does not fit VMEM: rejected structurally
        t0 = time.time()
        err = check_numerics(m, n, d, bm, bn, rng)
        info["max_rel_err"] = err
        info["interp_s"] = time.time() - t0  # compile+run; NOT a TPU proxy
        rows.append(info)
        print(f"{bm:>5} {bn:>5} {info['grid_steps']:>6} "
              f"{info['vmem_bytes']:>9}B {info['mxu_utilization']:>9.3f} "
              f"{info['arith_intensity']:>7.1f} {err:>12.2e}")
        assert err < 1e-4, f"numerics regressed at bm={bm} bn={bn}"

    # pick: max MXU utilization, tie-break on arithmetic intensity then
    # smaller VMEM (leaves room for double-buffering)
    best = max(rows, key=lambda r: (r["mxu_utilization"], r["arith_intensity"],
                                    -r["vmem_bytes"]))
    print(f"\nbest block: bm={best['bm']} bn={best['bn']} "
          f"(MXU {best['mxu_utilization']:.3f}, "
          f"VMEM {best['vmem_bytes']/2**10:.0f} KiB, "
          f"AI {best['arith_intensity']:.1f} flops/B)")
    out = args.out or os.path.join(os.path.dirname(__file__), "..", "..",
                                   "results", "l1_blocks.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"m": m, "n": n, "d": d, "rows": rows, "best": best}, f, indent=1)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
