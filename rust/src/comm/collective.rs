//! Pluggable gradient-reduction algorithms (DESIGN.md §4 "Gradient
//! reduction").
//!
//! The paper's systems contribution is *where* the P-length parameter
//! gradient is reduced: naively, every rank materializes the full reduced
//! gradient and applies the identical optimizer update (replicated
//! parameters); with the weight-sharded strategy, rank `c` of K reduces
//! only chunk `c` (a REDUCE_SCATTER), applies its 1/K optimizer shard,
//! and the updated *parameters* are ALL_GATHERed back — cutting the
//! gradient bytes each rank puts on the wire from `(K-1)·P` (naive
//! pairwise exchange) or `2·(K-1)/K·P` (ring) down to `(K-1)/K·P`, and
//! cutting optimizer state and update FLOPs K-fold. DisCo-CLIP makes the
//! same sharded-communication argument for memory.
//!
//! Three algorithms implement the [`GradientReduction`] trait:
//!
//! | algorithm                | dataflow                         | grad wire bytes / rank |
//! |--------------------------|----------------------------------|------------------------|
//! | [`NaiveAllReduce`]       | gather K·P, reduce locally       | `(K-1)·P`              |
//! | [`RingAllReduce`]        | reduce-scatter + all-gather grad | `2·(K-1)/K·P`          |
//! | [`ShardedReduceScatter`] | reduce-scatter grad, update own  | `(K-1)/K·P` (+ param   |
//! |                          | shard, all-gather *params*       | all-gather, counted    |
//! |                          |                                  | separately)            |
//!
//! where `P` is the gradient's **wire size** under the run's
//! [`WireCodec`] (DESIGN.md §15): `n_params` elements encoded at 4
//! bytes each for `f32`, 2 for `bf16` (the half-width format of
//! DESIGN.md §12, `q(Σ_r q(g_r))` per element), 1 for `int8`, and 8 per
//! selected element for `topk`. The codec — plus the shared
//! error-feedback state the `topk` codec needs — arrives bundled in a
//! [`ReduceCtx`], so future reduction knobs don't fan a new parameter
//! through every signature again.
//!
//! All three reductions are bit-identical by construction under the
//! lossless codecs (`f32`, `bf16`): every element is summed over ranks
//! in rank order `0..K` from the same (possibly bf16-rounded)
//! contributions, so the f32 rounding sequence is the same regardless of
//! which rank performs the addition. The exactness tests in
//! `rust/tests/integration.rs` pin this for K ∈ {1,2,4} and
//! non-divisible chunkings. The lossy codecs keep a weaker — still
//! strong — contract: bitwise determinism under a FIXED (codec,
//! algorithm, bucketing, overlap) configuration, run-to-run and across
//! checkpoint/resume, but no cross-algorithm equality (int8's blockwise
//! rounding is alignment-dependent, topk's selection is per-bucket).
//! One caveat lives above the collective layer: LAMB computes per-leaf
//! trust ratios, and the sharded strategy clips leaves at chunk
//! boundaries (ZeRO-style, see `optim::shard_segments`), so
//! sharded-LAMB *updates* differ from replicated-LAMB ones — the
//! trainer therefore never resolves `Auto` to `Sharded` for LAMB;
//! element-wise optimizers (AdamW, Lion, SGDM) are bit-identical under
//! every strategy.
//!
//! Selection is driven by the α–β cost model
//! ([`CostModel::cheapest_reduce`](super::CostModel::cheapest_reduce)):
//! small single-node worlds (few peers, latency-bound) prefer the direct
//! naive exchange, multi-node and bandwidth-bound shapes the chunked
//! algorithms. The trainer resolves [`ReduceStrategy::Auto`] once per
//! run from the gradient's wire size — the CODEC's encoded bytes, not a
//! dtype width, so a compressed wire can legitimately flip the choice
//! toward the latency-bound algorithms (the topk index overhead counts).

use super::bucket::Bucket;
use super::codec::{ReduceCtx, WireCodec};
use super::cost_model::CostModel;
use super::world::{CommResult, WorkerComm};

/// A concrete reduction algorithm (the resolved form of
/// [`ReduceStrategy`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// Every rank gathers all K contributions and reduces the full buffer
    /// locally. One communication step; `(K-1)·n` wire bytes per rank.
    Naive,
    /// Ring all-reduce: reduce-scatter then all-gather of the gradient.
    /// `2·(K-1)` steps; `2·(K-1)/K·n` wire bytes per rank.
    Ring,
    /// The paper's weight-sharded update: reduce-scatter the gradient,
    /// apply the local optimizer shard, all-gather updated parameters.
    /// Gradient wire bytes per rank drop to `(K-1)/K·n`.
    Sharded,
}

impl ReduceAlgo {
    /// Every algorithm, in the order the tables report them.
    pub fn all() -> [ReduceAlgo; 3] {
        [ReduceAlgo::Naive, ReduceAlgo::Ring, ReduceAlgo::Sharded]
    }

    /// Kebab-case id used by the CLI and config files.
    pub fn id(&self) -> &'static str {
        match self {
            ReduceAlgo::Naive => "naive",
            ReduceAlgo::Ring => "ring",
            ReduceAlgo::Sharded => "sharded",
        }
    }
}

/// Config-facing strategy: a fixed algorithm or cost-model-driven choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceStrategy {
    /// Always use this algorithm.
    Fixed(ReduceAlgo),
    /// Pick the cheapest algorithm for the gradient size under the run's
    /// α–β topology (see [`CostModel::cheapest_reduce`]).
    Auto,
}

impl ReduceStrategy {
    /// Kebab-case id used by the CLI and config files.
    pub fn id(&self) -> &'static str {
        match self {
            ReduceStrategy::Fixed(a) => a.id(),
            ReduceStrategy::Auto => "auto",
        }
    }

    /// Parse a CLI/config id; unknown values are an error listing the
    /// valid choices.
    pub fn from_id(id: &str) -> anyhow::Result<ReduceStrategy> {
        if id == "auto" {
            return Ok(ReduceStrategy::Auto);
        }
        for a in ReduceAlgo::all() {
            if a.id() == id {
                return Ok(ReduceStrategy::Fixed(a));
            }
        }
        anyhow::bail!("unknown reduce strategy '{id}' (expected naive|ring|sharded|auto)")
    }

    /// Resolve to a concrete algorithm for a `grad_elems`-element
    /// gradient travelling under `codec`. `Auto` prices the CODEC's
    /// actual encoded bytes ([`WireCodec::encoded_bytes`], including
    /// topk's per-element index overhead) — a compressed wire shrinks
    /// the bandwidth term and can flip the choice toward the
    /// latency-bound naive exchange.
    pub fn resolve(&self, cost: &CostModel, codec: WireCodec, grad_elems: usize) -> ReduceAlgo {
        match self {
            ReduceStrategy::Fixed(a) => *a,
            ReduceStrategy::Auto => {
                cost.cheapest_reduce(codec.encoded_bytes(grad_elems as u64) as usize)
            }
        }
    }
}

/// One gradient-reduction algorithm: reduce each rank's additive gradient
/// contribution across the world and apply the optimizer update, keeping
/// parameters replicated (bitwise equal) on every rank afterwards.
///
/// Calling convention: [`reduce_and_apply`](Self::reduce_and_apply) is a
/// *collective* — every rank must call it in lockstep with equal-length
/// `grad`/`params`, a [`ReduceCtx`] naming the same codec (the
/// error-feedback state inside it is per-rank), and an `apply` callback
/// that is deterministic given its slice arguments. Replicated algorithms
/// invoke `apply` once with the full parameter/gradient range;
/// [`ShardedReduceScatter`] invokes it with this rank's owned chunk only
/// (so the caller must size optimizer state accordingly — see
/// `optim::shard_segments`).
pub trait GradientReduction: Send + Sync {
    /// The concrete algorithm this implementation realizes.
    fn algo(&self) -> ReduceAlgo;

    /// Kebab-case id of [`Self::algo`].
    fn id(&self) -> &'static str {
        self.algo().id()
    }

    /// Modeled fabric units ONE rank transmits to reduce an `n`-unit
    /// gradient over `k` ranks. The formula is unit-agnostic (pass bytes
    /// to get bytes); byte accounting divides on ELEMENT counts and
    /// encodes through the codec afterwards (see [`charge`]'s rationale:
    /// the truncating `(K-1)/K` division must round identically for
    /// every codec, or the narrow wires would not charge their exact
    /// ½/¼ ratios). Parameter all-gather traffic of the sharded
    /// strategy is charged separately as `param_wire_bytes`.
    fn grad_wire_bytes(&self, k: usize, n: u64) -> u64;

    /// Collective: reduce `grad` over all ranks under `ctx`'s codec and
    /// apply the update. Postcondition on `Ok`: `params` is updated
    /// and bitwise replicated on every rank. `grad` contents are
    /// algorithm-dependent afterwards (the replicated algorithms leave
    /// the reduced gradient in it, the sharded one leaves the wire form
    /// of the local contribution) — treat it as scratch. `Err`
    /// means the world was cancelled (a rank lost, DESIGN.md §13):
    /// `grad`/`params` are unspecified and the iteration must be rolled
    /// back, never committed. (Under `topk` the error-feedback residual
    /// may have absorbed the cancelled contribution — the trainer
    /// rebuilds the context from the last checkpoint on rollback, which
    /// is also what keeps live shrink ≡ cold elastic resume.)
    fn reduce_and_apply(
        &self,
        comm: &WorkerComm,
        grad: &mut [f32],
        params: &mut [f32],
        ctx: &ReduceCtx,
        apply: &mut dyn FnMut(&mut [f32], &[f32]),
    ) -> CommResult<()>;

    /// Collective: reduce ONE bucket of the flat `full_len`-element
    /// gradient — `data` is this rank's local contribution for
    /// `[bucket.lo, bucket.hi)` — under `ctx`'s codec and return the
    /// reduced segment this rank is responsible for: the whole bucket for
    /// the replicated algorithms, the (possibly empty) intersection of
    /// the bucket with this rank's owned chunk of `full_len` for the
    /// sharded one. The caller applies the optimizer and, for the sharded
    /// strategy, all-gathers parameters once per *iteration*, not per
    /// bucket.
    ///
    /// Bitwise contract (DESIGN.md §11/§12/§15): every element is summed
    /// over ranks in rank order `0..K` from a 0.0 accumulator over the
    /// same wire-rounded contributions, exactly as
    /// [`Self::reduce_and_apply`] sums it — so under the lossless codecs
    /// reducing any bucketing of the vector, in any size, reproduces the
    /// unbucketed reduction of the same elements bit for bit. Under
    /// `topk` the selection (and the residual slice it compensates) is
    /// per-bucket — [`ReduceCtx::sparsify`] addresses the residual by
    /// the bucket's global offset — so a fixed bucketing is bitwise
    /// deterministic but different bucketings legitimately differ.
    /// `Err` means the world was cancelled mid-bucket — the overlap
    /// pipeline propagates it out of `finish` so the trainer can roll
    /// back.
    fn reduce_bucket(
        &self,
        comm: &WorkerComm,
        data: &[f32],
        bucket: Bucket,
        full_len: usize,
        ctx: &ReduceCtx,
    ) -> CommResult<ReducedSegment>;

    /// Collective: the sharded-loss feature-gradient leg (DESIGN.md
    /// §16). `fill(s, seg)` writes this rank's `seg_len`-element
    /// contribution to destination rank `s`'s features; the return is
    /// this rank's sum over all sources, folded in ascending
    /// source-rank order — [`WorkerComm::exchange_block_sums`]'s
    /// `q(Σ_r q(g_r))` contract under `ctx`'s codec.
    ///
    /// Provided (identical) for every algorithm: the exchange is a
    /// fixed dest-major block pattern with nothing algorithm-shaped to
    /// vary — what `--reduce` chooses is how the PARAMETER gradient is
    /// reduced, while this leg's fold order is pinned by the §16
    /// bitwise contract. It lives on the trait so the loss shard rides
    /// the same machinery (and the same `ReduceCtx`) as every other
    /// reduction, and so a future algorithm CAN specialize the
    /// dataflow as long as it preserves the fold.
    fn reduce_feature_grads(
        &self,
        comm: &WorkerComm,
        seg_len: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
        ctx: &ReduceCtx,
    ) -> CommResult<Vec<f32>> {
        comm.exchange_block_sums(seg_len, fill, ctx.codec)
    }
}

/// The reduced output of one [`GradientReduction::reduce_bucket`] call:
/// `data` holds the reduced values for `[lo, lo + data.len())` of the
/// flat gradient (absolute offsets).
#[derive(Debug, Clone, PartialEq)]
pub struct ReducedSegment {
    /// Absolute offset of the first reduced element.
    pub lo: usize,
    /// The reduced values (empty when this rank owns nothing here).
    pub data: Vec<f32>,
}

/// Gather-everything-reduce-locally — the seed's strategy. One
/// communication step (lowest latency), `(K-1)·n` wire bytes per rank,
/// O(K·P) local reduction work.
pub struct NaiveAllReduce;

impl GradientReduction for NaiveAllReduce {
    fn algo(&self) -> ReduceAlgo {
        ReduceAlgo::Naive
    }

    fn grad_wire_bytes(&self, k: usize, n: u64) -> u64 {
        (k as u64 - 1) * n
    }

    fn reduce_and_apply(
        &self,
        comm: &WorkerComm,
        grad: &mut [f32],
        params: &mut [f32],
        ctx: &ReduceCtx,
        apply: &mut dyn FnMut(&mut [f32], &[f32]),
    ) -> CommResult<()> {
        charge(comm, self, grad.len(), ctx.codec);
        ctx.sparsify(grad, 0);
        let n = grad.len();
        let gathered = comm.all_gather(grad, ctx.codec)?;
        // rank-major accumulation: sequential access over the K·n buffer,
        // and per element the additions still happen in rank order from a
        // 0.0 accumulator — identical f32 rounding on every rank and to
        // the chunked algorithms below. The final wire-format rounding
        // matches the redistribution leg the chunked algorithms pay.
        grad.fill(0.0);
        for r in 0..comm.world_size() {
            let part = &gathered[r * n..(r + 1) * n];
            for (g, v) in grad.iter_mut().zip(part) {
                *g += v;
            }
        }
        ctx.codec.wire_round(grad);
        apply(params, grad);
        Ok(())
    }

    fn reduce_bucket(
        &self,
        comm: &WorkerComm,
        data: &[f32],
        bucket: Bucket,
        _full_len: usize,
        ctx: &ReduceCtx,
    ) -> CommResult<ReducedSegment> {
        charge(comm, self, data.len(), ctx.codec);
        let sp = ctx.sparsified(data, bucket.lo);
        let data: &[f32] = sp.as_deref().unwrap_or(data);
        let n = data.len();
        let gathered = comm.all_gather(data, ctx.codec)?;
        // same rank-major, rank-ordered accumulation as reduce_and_apply:
        // per element the f32 rounding sequence is identical
        let mut out = vec![0.0f32; n];
        for r in 0..comm.world_size() {
            let part = &gathered[r * n..(r + 1) * n];
            for (g, v) in out.iter_mut().zip(part) {
                *g += v;
            }
        }
        ctx.codec.wire_round(&mut out);
        Ok(ReducedSegment { lo: bucket.lo, data: out })
    }
}

/// Ring all-reduce: reduce-scatter the gradient, all-gather the reduced
/// chunks. `2·(K-1)/K·n` wire bytes per rank, O(P) local reduction work
/// (each rank reduces only its chunk).
pub struct RingAllReduce;

impl GradientReduction for RingAllReduce {
    fn algo(&self) -> ReduceAlgo {
        ReduceAlgo::Ring
    }

    fn grad_wire_bytes(&self, k: usize, n: u64) -> u64 {
        2 * (k as u64 - 1) * n / k as u64
    }

    fn reduce_and_apply(
        &self,
        comm: &WorkerComm,
        grad: &mut [f32],
        params: &mut [f32],
        ctx: &ReduceCtx,
        apply: &mut dyn FnMut(&mut [f32], &[f32]),
    ) -> CommResult<()> {
        charge(comm, self, grad.len(), ctx.codec);
        ctx.sparsify(grad, 0);
        // all_reduce_sum IS the RS+AG ring dataflow, in place and with
        // the same rank-ordered (bit-identical) summation and the same
        // per-element wire rounding
        comm.all_reduce_sum(grad, ctx.codec)?;
        apply(params, grad);
        Ok(())
    }

    fn reduce_bucket(
        &self,
        comm: &WorkerComm,
        data: &[f32],
        bucket: Bucket,
        _full_len: usize,
        ctx: &ReduceCtx,
    ) -> CommResult<ReducedSegment> {
        charge(comm, self, data.len(), ctx.codec);
        let mut out = data.to_vec();
        ctx.sparsify(&mut out, bucket.lo);
        comm.all_reduce_sum(&mut out, ctx.codec)?;
        Ok(ReducedSegment { lo: bucket.lo, data: out })
    }
}

/// The paper's weight-sharded reduction: each rank owns chunk `c` of the
/// flat parameter vector ([`WorkerComm::owned_chunk`]), reduces only that
/// chunk of the gradient, applies its optimizer shard to `params[lo..hi]`
/// and all-gathers the updated parameters. The full reduced gradient is
/// never materialized; optimizer state shrinks K-fold.
pub struct ShardedReduceScatter;

impl GradientReduction for ShardedReduceScatter {
    fn algo(&self) -> ReduceAlgo {
        ReduceAlgo::Sharded
    }

    fn grad_wire_bytes(&self, k: usize, n: u64) -> u64 {
        (k as u64 - 1) * n / k as u64
    }

    fn reduce_and_apply(
        &self,
        comm: &WorkerComm,
        grad: &mut [f32],
        params: &mut [f32],
        ctx: &ReduceCtx,
        apply: &mut dyn FnMut(&mut [f32], &[f32]),
    ) -> CommResult<()> {
        charge(comm, self, grad.len(), ctx.codec);
        ctx.sparsify(grad, 0);
        let p = params.len();
        debug_assert_eq!(p, grad.len(), "sharded update needs grad.len == params.len");
        let shard = comm.reduce_scatter_sum(grad, ctx.codec)?;
        let (lo, hi) = comm.owned_chunk(p);
        apply(&mut params[lo..hi], &shard);
        allgather_updated_params(comm, params, lo, hi)
    }

    fn reduce_bucket(
        &self,
        comm: &WorkerComm,
        data: &[f32],
        bucket: Bucket,
        full_len: usize,
        ctx: &ReduceCtx,
    ) -> CommResult<ReducedSegment> {
        charge(comm, self, data.len(), ctx.codec);
        let sp = ctx.sparsified(data, bucket.lo);
        let data: &[f32] = sp.as_deref().unwrap_or(data);
        // ownership stays the GLOBAL chunking of the full vector — the
        // bucket is reduced into the intersection with this rank's chunk,
        // so assembling every bucket's segment yields exactly the shard
        // reduce_and_apply would hand the optimizer (same state layout,
        // same checkpoint format). The updated-parameter all-gather (and
        // its param_wire charge) happens once per iteration, in the
        // pipeline's finish step.
        let (clo, chi) = comm.owned_chunk(full_len);
        let s = bucket.lo.max(clo);
        let e = bucket.hi.min(chi);
        if s < e {
            let out = comm.reduce_range_sum(data, s - bucket.lo, e - bucket.lo, ctx.codec)?;
            Ok(ReducedSegment { lo: s, data: out })
        } else {
            // empty intersection — the call is still a collective, so
            // this rank participates with an empty range
            let out = comm.reduce_range_sum(data, 0, 0, ctx.codec)?;
            Ok(ReducedSegment { lo: clo, data: out })
        }
    }
}

/// The sharded strategy's parameter publication: all-gather the updated
/// chunk `[lo, hi)` back into a replicated `params` and charge the
/// traffic to `param_wire_bytes` (the all-gather replaces the gradient
/// all-gather of a ring all-reduce). Always full-width f32: the updated
/// parameters ARE the master weights, which never travel in bf16
/// (DESIGN.md §12). Shared by the serial
/// [`ShardedReduceScatter::reduce_and_apply`] and the overlap pipeline's
/// finish step (DESIGN.md §11), so the two paths stay provably identical
/// in both bytes accounting and dataflow.
pub(crate) fn allgather_updated_params(
    comm: &WorkerComm,
    params: &mut [f32],
    lo: usize,
    hi: usize,
) -> CommResult<()> {
    let p = params.len();
    let k = comm.world_size() as u64;
    comm.stats().add_param_wire((k - 1) * (p as u64 * 4) / k);
    let updated = comm.all_gather_chunks(&params[lo..hi], p)?;
    params.copy_from_slice(&updated);
    Ok(())
}

/// Charge this iteration's gradient wire bytes: the chosen algorithm's
/// actual traffic plus, for comparison, what [`NaiveAllReduce`] would
/// have moved (the before/after pair surfaced by
/// [`CommStats`](super::CommStats) and `benches/bench_comm.rs`). Both
/// sides are charged under the run's codec, so the chosen-vs-naive
/// ratio isolates the algorithm choice while a bf16 run's absolute
/// counters land at EXACTLY half the f32 bytes and an int8 run's at
/// EXACTLY a quarter (DESIGN.md §12/§15 — the 4× gate in CI). The
/// `(K-1)/K`-style division runs on the ELEMENT count and the codec
/// encodes the result — dividing a byte count would truncate
/// differently per width (k=4, 1003 elems: 3·4012/4 = 3009 vs
/// 2·(3·2006/4) = 3008) and break the exact-ratio invariants the tests
/// and CI gate assert.
fn charge(comm: &WorkerComm, algo: &dyn GradientReduction, len: usize, wire: WireCodec) {
    let k = comm.world_size();
    let elems = len as u64;
    let stats = comm.stats();
    stats.add_grad_wire(
        wire.encoded_bytes(algo.grad_wire_bytes(k, elems)),
        wire.encoded_bytes(NaiveAllReduce.grad_wire_bytes(k, elems)),
    );
}

/// The static instance implementing `algo` (algorithms are stateless).
pub fn reduction(algo: ReduceAlgo) -> &'static dyn GradientReduction {
    match algo {
        ReduceAlgo::Naive => &NaiveAllReduce,
        ReduceAlgo::Ring => &RingAllReduce,
        ReduceAlgo::Sharded => &ShardedReduceScatter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{BucketPlan, CommStatsSnapshot, CommWorld};
    use std::sync::Arc;

    /// Local gradient contribution of `rank` for an `n`-element vector —
    /// irregular enough that mis-assembled buckets cannot cancel out.
    fn contribution(rank: usize, n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 7 + rank * 13) % 97) as f32 * 0.37 - 11.0).collect()
    }

    /// The exactness property, per lossless wire codec: reducing any
    /// bucketing of the flat vector — bucket by bucket, for every
    /// algorithm — assembles to the bitwise-identical result of the
    /// whole-vector reduce, for odd lengths, 1-element buckets and
    /// buckets larger than the vector; and under one wire codec
    /// every algorithm agrees bitwise with naive. Scoped to f32/bf16:
    /// the lossy codecs intentionally drop cross-algorithm and
    /// cross-bucketing equality (DESIGN.md §15) and are covered by the
    /// determinism tests below instead.
    #[test]
    fn bucketed_reduce_bitwise_equals_whole_vector() {
        for wire in [WireCodec::F32, WireCodec::Bf16] {
            for (k, n) in [(1usize, 7usize), (2, 64), (4, 10), (3, 1003)] {
                let mut naive_ref: Option<Vec<f32>> = None;
                for algo in ReduceAlgo::all() {
                    // whole-vector reference: reduce_and_apply with apply
                    // writing the reduced gradient into params
                    let world = CommWorld::new(k);
                    let whole: Vec<Vec<f32>> = run_ranks(&world, k, move |comm| {
                        let mut grad = contribution(comm.rank(), n);
                        let mut params = vec![0.0f32; n];
                        let ctx = ReduceCtx::new(wire);
                        reduction(algo)
                            .reduce_and_apply(&comm, &mut grad, &mut params, &ctx, &mut |p, g| {
                                p.copy_from_slice(g)
                            })
                            .unwrap();
                        params
                    });
                    // cross-algorithm bit-identity at this wire width
                    match &naive_ref {
                        None => naive_ref = Some(whole[0].clone()),
                        Some(r) => assert_eq!(
                            bits(&whole[0]),
                            bits(r),
                            "{} k={k} n={n} {}: differs from naive",
                            algo.id(),
                            wire.id()
                        ),
                    }
                    for target in [1usize, 3, n.div_ceil(2).max(1), n + 5] {
                        let world = CommWorld::new(k);
                        let bucketed: Vec<Vec<f32>> = run_ranks(&world, k, move |comm| {
                            let plan = BucketPlan::new(n, target);
                            let local = contribution(comm.rank(), n);
                            // replicated algos fill everything; sharded
                            // fills only the owned chunk — compare
                            // chunk-wise below
                            let mut out = vec![f32::NAN; n];
                            let ctx = ReduceCtx::new(wire);
                            for b in plan.iter() {
                                let seg = reduction(algo)
                                    .reduce_bucket(&comm, &local[b.lo..b.hi], b, n, &ctx)
                                    .unwrap();
                                out[seg.lo..seg.lo + seg.data.len()].copy_from_slice(&seg.data);
                            }
                            out
                        });
                        for (rank, got) in bucketed.iter().enumerate() {
                            let (lo, hi) = match algo {
                                ReduceAlgo::Sharded => crate::comm::chunk_bounds(n, k, rank),
                                _ => (0, n),
                            };
                            assert_eq!(
                                bits(&got[lo..hi]),
                                bits(&whole[rank][lo..hi]),
                                "{} k={k} n={n} target={target} rank={rank} wire={}",
                                algo.id(),
                                wire.id()
                            );
                            if algo == ReduceAlgo::Sharded {
                                // and nothing outside the chunk was written
                                assert!(got[..lo].iter().chain(&got[hi..]).all(|v| v.is_nan()));
                            }
                        }
                    }
                }
            }
        }
    }

    /// The half-width wire format halves the charged gradient wire bytes
    /// exactly, for every algorithm (the acceptance criterion of
    /// DESIGN.md §12), and actually quantizes: the bf16 result differs
    /// from the f32 one on non-representable sums.
    #[test]
    fn bf16_wire_halves_grad_bytes_every_algorithm() {
        for algo in ReduceAlgo::all() {
            let (sf, outf) = reduce_at(algo, WireCodec::F32);
            let (sb, outb) = reduce_at(algo, WireCodec::Bf16);
            assert_eq!(
                sf.grad_wire_bytes,
                2 * sb.grad_wire_bytes,
                "{}: bf16 wire must charge exactly half",
                algo.id()
            );
            assert_eq!(sf.grad_wire_bytes_naive, 2 * sb.grad_wire_bytes_naive, "{}", algo.id());
            assert!(sb.grad_wire_bytes > 0, "{}: something must be charged", algo.id());
            // every bf16 value is bf16-representable, and the reduction
            // genuinely rounded (contributions here are not representable)
            use crate::kernels::precision::bf16_round;
            assert!(outb[0].iter().all(|&v| v.to_bits() == bf16_round(v).to_bits()));
            assert_ne!(bits(&outf[0]), bits(&outb[0]), "{}: bf16 must round", algo.id());
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// K=4, n=1003 whole-vector reduce of [`contribution`]s at `wire`
    /// with a copy-out apply: returns the charged stats and per-rank
    /// resulting params.
    fn reduce_at(algo: ReduceAlgo, wire: WireCodec) -> (CommStatsSnapshot, Vec<Vec<f32>>) {
        let world = CommWorld::new(4);
        let outs = run_ranks(&world, 4, move |comm| {
            let mut grad = contribution(comm.rank(), 1003);
            let mut params = vec![0.0f32; 1003];
            let ctx = ReduceCtx::for_run(wire, 1003);
            reduction(algo)
                .reduce_and_apply(&comm, &mut grad, &mut params, &ctx, &mut |p, g| {
                    p.copy_from_slice(g)
                })
                .unwrap();
            params
        });
        (world.stats.snapshot(), outs)
    }

    /// int8 charges EXACTLY a quarter of the f32 gradient wire bytes for
    /// every algorithm — the invariant the CI baseline gate asserts —
    /// and genuinely quantizes (the reduced values differ from f32's)
    /// while staying bitwise deterministic run-to-run.
    #[test]
    fn int8_wire_quarters_grad_bytes_every_algorithm() {
        for algo in ReduceAlgo::all() {
            let (sf, outf) = reduce_at(algo, WireCodec::F32);
            let (si, outi) = reduce_at(algo, WireCodec::Int8);
            assert_eq!(
                sf.grad_wire_bytes,
                4 * si.grad_wire_bytes,
                "{}: int8 wire must charge exactly a quarter",
                algo.id()
            );
            assert_eq!(sf.grad_wire_bytes_naive, 4 * si.grad_wire_bytes_naive, "{}", algo.id());
            assert!(si.grad_wire_bytes > 0, "{}: something must be charged", algo.id());
            assert_ne!(bits(&outf[0]), bits(&outi[0]), "{}: int8 must quantize", algo.id());
            // run-to-run bitwise determinism under the fixed codec
            let (_, again) = reduce_at(algo, WireCodec::Int8);
            assert_eq!(bits(&outi[0]), bits(&again[0]), "{}", algo.id());
        }
    }

    /// topk reduces to a sparse sum (at most K·⌈n/16⌉ nonzeros), charges
    /// its value+index encoded bytes, and is bitwise deterministic
    /// run-to-run — with the error-feedback residual starting from the
    /// same (zero) state each run.
    #[test]
    fn topk_reduction_sparse_and_deterministic() {
        for algo in ReduceAlgo::all() {
            let (st, outt) = reduce_at(algo, WireCodec::TopK);
            // K=4 ranks each transmit ceil(1003/16) = 63 elements
            assert!(
                outt[0].iter().filter(|v| **v != 0.0).count() <= 4 * 63,
                "{}: reduced vector must stay sparse",
                algo.id()
            );
            assert!(st.grad_wire_bytes > 0, "{}", algo.id());
            let (_, again) = reduce_at(algo, WireCodec::TopK);
            assert_eq!(bits(&outt[0]), bits(&again[0]), "{}", algo.id());
            // replicated postcondition holds for lossy codecs too
            for r in 1..4 {
                assert_eq!(bits(&outt[r]), bits(&outt[0]), "{} rank {r}", algo.id());
            }
        }
    }

    /// The `--reduce auto` regression (satellite): the cost model prices
    /// the CODEC's encoded bytes, so switching codec flips the resolved
    /// algorithm. 1 node x 4 GPUs InfiniBand: naive and sharded cross at
    /// ~180 kB on the wire; 80k gradient elements sit above that under
    /// f32 (320 kB -> Sharded) and far below under topk (8·⌈80k/16⌉ =
    /// 40 kB, index overhead included -> Naive) or int8 (80 kB -> Naive).
    #[test]
    fn auto_resolution_follows_codec_encoded_bytes() {
        use super::super::cost_model::ProfileName;
        let cost = CostModel::new(ProfileName::InfiniBand.profile(), 1, 4);
        let n = 80_000usize;
        assert_eq!(ReduceStrategy::Auto.resolve(&cost, WireCodec::F32, n), ReduceAlgo::Sharded);
        assert_eq!(ReduceStrategy::Auto.resolve(&cost, WireCodec::TopK, n), ReduceAlgo::Naive);
        assert_eq!(ReduceStrategy::Auto.resolve(&cost, WireCodec::Int8, n), ReduceAlgo::Naive);
        // Fixed strategies ignore the codec
        for codec in WireCodec::all() {
            assert_eq!(
                ReduceStrategy::Fixed(ReduceAlgo::Ring).resolve(&cost, codec, n),
                ReduceAlgo::Ring
            );
        }
    }

    fn run_ranks<F>(world: &Arc<CommWorld>, k: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(crate::comm::WorkerComm) -> Vec<f32> + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..k)
            .map(|r| {
                let h = world.handle(r);
                let f = Arc::clone(&f);
                std::thread::spawn(move || f(h))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// The feature-gradient leg (DESIGN.md §16) is algorithm-invariant:
    /// all three `--reduce` choices route it through the same
    /// ascending-source-rank fold, so their outputs are bitwise
    /// identical to each other and to a locally computed
    /// `q(Σ_src q(g_src))` — under both the f32 identity wire and a
    /// lossy one.
    #[test]
    fn feature_grad_leg_identical_across_algorithms() {
        use crate::kernels::precision::bf16_round;
        let (k, seg) = (3usize, 11usize);
        let contrib = |src: usize, dest: usize, j: usize| -> f32 {
            0.1 + (src * 10 + dest) as f32 * 0.31 + j as f32 * 1.017
        };
        for wire in [WireCodec::F32, WireCodec::Bf16] {
            let mut per_algo: Vec<Vec<Vec<f32>>> = Vec::new();
            for algo in ReduceAlgo::all() {
                let world = CommWorld::new(k);
                let outs = run_ranks(&world, k, move |comm| {
                    let src = comm.rank();
                    let ctx = ReduceCtx::new(wire);
                    reduction(algo)
                        .reduce_feature_grads(
                            &comm,
                            seg,
                            &mut |dest, out| {
                                for (j, v) in out.iter_mut().enumerate() {
                                    *v = contrib(src, dest, j);
                                }
                            },
                            &ctx,
                        )
                        .unwrap()
                });
                per_algo.push(outs);
            }
            for outs in &per_algo[1..] {
                for r in 0..k {
                    assert_eq!(bits(&outs[r]), bits(&per_algo[0][r]), "wire={}", wire.id());
                }
            }
            // local replay of the pinned fold
            let q = |v: f32| match wire {
                WireCodec::Bf16 => bf16_round(v),
                _ => v,
            };
            for (dest, got) in per_algo[0].iter().enumerate() {
                let want: Vec<f32> = (0..seg)
                    .map(|j| q((0..k).fold(0.0f32, |acc, src| acc + q(contrib(src, dest, j)))))
                    .collect();
                assert_eq!(bits(got), bits(&want), "dest={dest} wire={}", wire.id());
            }
        }
    }

    #[test]
    fn ids_roundtrip() {
        for a in ReduceAlgo::all() {
            assert_eq!(ReduceStrategy::from_id(a.id()).unwrap(), ReduceStrategy::Fixed(a));
            assert_eq!(reduction(a).algo(), a);
        }
        assert_eq!(ReduceStrategy::from_id("auto").unwrap(), ReduceStrategy::Auto);
        assert!(ReduceStrategy::from_id("nope").is_err());
    }

    #[test]
    fn wire_bytes_ordering() {
        // the paper's volume claim: sharded < ring < naive for K > 2,
        // sharded < ring == naive at K = 2
        let n = 1_000_000u64;
        for k in [2usize, 4, 8, 32] {
            let naive = NaiveAllReduce.grad_wire_bytes(k, n);
            let ring = RingAllReduce.grad_wire_bytes(k, n);
            let sharded = ShardedReduceScatter.grad_wire_bytes(k, n);
            assert!(sharded < naive, "k={k}");
            assert!(sharded < ring, "k={k}");
            assert!(ring <= naive, "k={k}");
            assert_eq!(sharded, (k as u64 - 1) * n / k as u64);
        }
        // K=1 is free
        for a in ReduceAlgo::all() {
            assert_eq!(reduction(a).grad_wire_bytes(1, n), 0);
        }
    }
}
