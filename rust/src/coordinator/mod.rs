//! The L3 coordinator — the paper's system contribution: distributed
//! data-parallel CLIP training with compositional optimization.
//!
//! * [`Trainer`] drives K lockstep worker threads (trainer.rs);
//! * [`state`] holds the per-shard u estimators and individual τ
//!   (state.rs);
//! * [`temperature`] implements the four τ-update rules of Proc. 5
//!   (temperature.rs);
//! * [`timing`] produces the Fig. 3 per-iteration breakdown (timing.rs).
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

pub mod state;
pub mod temperature;
pub mod timing;

mod trainer;

pub use state::{IndividualTau, IndividualTauState, UState};
pub use temperature::{GlobalTau, GlobalTauState, TauState};
pub use timing::{
    charge_iteration, charge_iteration_overlapped, charge_iteration_with, IterationVolumes,
    PerIterMs, TimeBreakdown, OVERLAP_FRACTION,
};
pub use trainer::{EvalRecord, IterRecord, TrainResult, Trainer};
