//! `exp ckpt` — the interrupted-run study (DESIGN.md §9).
//!
//! Two parts:
//! * a **state throughput study** (always runs; no artifacts needed):
//!   synthetic full worker states at growing parameter counts, timing
//!   snapshot write, restore and `verify`;
//! * an **interrupted-run study** (needs the artifact bundle + `pjrt`
//!   runtime, like every training experiment): train N+M steps
//!   continuously vs train N → snapshot → restore → M, reporting the
//!   snapshot/restore overhead and checking the two runs end bitwise
//!   identical.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::ckpt::{
    finalize, prepare_stage, restore_worker, stage_path, write_rank_state, Checkpoint, CkptMeta,
};
use crate::config::{Algorithm, TrainConfig};
use crate::coordinator::{TauState, Trainer, UState};
use crate::data::ShardLoader;
use crate::optim::Optimizer;
use crate::output::Table;
use crate::util::{Args, Json};

use super::common::{progress_logger, results_dir};

/// One rank's synthetic worker state in the richest shape (individual τ
/// with per-sample Adam moments + AdamW) — the shared fixture for the
/// `exp ckpt` throughput study and `benches/bench_ckpt.rs`.
pub struct SyntheticRank {
    pub loader: ShardLoader,
    pub ustate: UState,
    pub tau: TauState,
    pub opt: Box<dyn Optimizer>,
    pub params: Vec<f32>,
}

/// Build one rank's state and move every component off its origin so a
/// snapshot has something non-trivial to persist.
pub fn synthetic_rank(
    cfg: &TrainConfig,
    rank: usize,
    world: usize,
    n_params: usize,
    local_batch: usize,
) -> Result<SyntheticRank> {
    let mut loader = ShardLoader::new(cfg.data.n_train, rank, world, local_batch, cfg.seed)?;
    for _ in 0..5 {
        loader.next_batch();
    }
    let mut ustate = UState::new(loader.shard_len());
    let pos: Vec<usize> = (0..loader.shard_len()).collect();
    let vals: Vec<f32> = pos.iter().map(|&p| p as f32 * 1e-3).collect();
    ustate.scatter(&pos, &vals, &vals);
    let mut tau = TauState::new(cfg, loader.shard_len());
    if let TauState::Individual(it) = &mut tau {
        it.update(&[0, 1], &[0.2, -0.2], &[-0.2, 0.2], 1e-2);
    }
    let mut opt = crate::optim::build(&cfg.optimizer, n_params, vec![(0, n_params)]);
    let mut params = vec![0.1f32; n_params];
    let grad = vec![1e-3f32; n_params];
    opt.step(&mut params, &grad, 1e-3);
    Ok(SyntheticRank { loader, ustate, tau, opt, params })
}

/// Snapshot the synthetic world through the real writer (replicated
/// optimizer layout: only rank 0 exports and writes its state, exactly
/// like the trainer — keeps the timed region free of dead clones).
/// Returns the finalized checkpoint directory.
pub fn snapshot_synthetic(
    root: &Path,
    cfg: &TrainConfig,
    ranks: &[SyntheticRank],
    n_params: usize,
    local_batch: usize,
    step: u32,
) -> Result<PathBuf> {
    let stage = stage_path(root, step);
    prepare_stage(&stage)?;
    for (rank, f) in ranks.iter().enumerate() {
        let os = if rank == 0 { Some(f.opt.export_state()) } else { None };
        write_rank_state(
            &stage,
            rank,
            &f.ustate,
            &f.tau,
            &f.loader,
            os.as_ref().map(|s| (s, false)),
            None,
        )?;
    }
    let meta = CkptMeta::for_run(cfg, step, ranks.len(), n_params, local_batch, "ring");
    finalize(root, &stage, &meta, &ranks[0].params, 0)
}

pub fn ckpt_study(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let mut json_rows = Vec::new();
    state_throughput(args, &mut json_rows)?;

    let bundle = args.str_or("bundle", "artifacts/tiny_k2_b8");
    if Path::new(&bundle).join("manifest.json").exists() {
        interrupted_run(args, &bundle, &mut json_rows)?;
    } else {
        log.status(&format!(
            "note: skipping the interrupted-run study — {bundle} not built \
             (run `make artifacts`; needs the pjrt feature to execute)"
        ));
    }

    let dir = results_dir(args);
    crate::output::write_result(&dir, "ckpt", &Json::arr(json_rows))?;
    log.status(&format!("wrote {}/ckpt.json", dir.display()));
    Ok(())
}

/// Synthetic full worker states (the richest variant: individual τ +
/// AdamW) at growing parameter counts: snapshot → restore → verify.
fn state_throughput(args: &Args, json_rows: &mut Vec<Json>) -> Result<()> {
    let world = 2;
    let n_train = 4096;
    let sizes = [10_000usize, 100_000, 1_000_000];
    let mut table = Table::new(
        "Checkpoint state throughput (synthetic, individual-tau + AdamW)",
        &["n_params", "state MB", "write ms", "write MB/s", "restore ms", "verify ms"],
    );
    for &n_params in &sizes {
        let mut cfg = TrainConfig::new("unused", Algorithm::FastClipV2);
        cfg.data.n_train = n_train;

        let root = std::env::temp_dir().join(format!("fastclip_exp_ckpt_{n_params}"));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(&root)?;

        let ranks: Vec<SyntheticRank> = (0..world)
            .map(|r| synthetic_rank(&cfg, r, world, n_params, 64))
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let dir = snapshot_synthetic(&root, &cfg, &ranks, n_params, 64, 5)?;
        let write_s = t0.elapsed().as_secs_f64();

        let ck = Checkpoint::open(&dir)?;
        let bytes: u64 =
            ck.manifest().blobs.iter().map(|b| (b.len * b.kind.width()) as u64).sum();

        let t1 = Instant::now();
        for rank in 0..world {
            let r = restore_worker(&ck, &cfg, rank, world, 64, false)?;
            ensure!(r.params.len() == n_params, "restore sanity");
        }
        let restore_s = t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        ck.verify()?;
        let verify_s = t2.elapsed().as_secs_f64();

        let mb = bytes as f64 / (1024.0 * 1024.0);
        table.row(vec![
            n_params.to_string(),
            format!("{mb:.2}"),
            format!("{:.2}", write_s * 1e3),
            format!("{:.1}", mb / write_s.max(1e-9)),
            format!("{:.2}", restore_s * 1e3),
            format!("{:.2}", verify_s * 1e3),
        ]);
        json_rows.push(Json::obj(vec![
            ("study", Json::str("state_throughput")),
            ("n_params", Json::num(n_params as f64)),
            ("bytes", Json::num(bytes as f64)),
            ("write_s", Json::num(write_s)),
            ("restore_s", Json::num(restore_s)),
            ("verify_s", Json::num(verify_s)),
        ]));
        let _ = std::fs::remove_dir_all(&root);
    }
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join("ckpt_throughput.csv"))?;
    Ok(())
}

/// Train N+M continuously vs N → snapshot → restore → M with the real
/// trainer, and report resume overhead + bitwise equivalence.
fn interrupted_run(args: &Args, bundle: &str, json_rows: &mut Vec<Json>) -> Result<()> {
    let algo = Algorithm::from_id(&args.str_or("algo", "fastclip-v3"))?;
    let steps = args.u32_or("steps", 32)?;
    let ckpt_at = args.u32_or("ckpt-at", (steps / 2).max(1))?;
    ensure!(ckpt_at < steps, "--ckpt-at must be below --steps");
    let ckpt_root: PathBuf = std::env::temp_dir().join("fastclip_exp_ckpt_run");
    let _ = std::fs::remove_dir_all(&ckpt_root);

    // one base config so both runs share every schedule position
    let mut base = TrainConfig::new(bundle, algo);
    base.steps = steps;
    base.iters_per_epoch = 8;
    base.data.n_train = 512;
    base.data.n_eval = 64;
    base.lr.warmup_iters = (steps / 10).max(1);
    base.lr.total_iters = steps;

    let continuous =
        Trainer::new(base.clone())?.run().context("continuous reference run")?;

    let mut leg1 = base.clone();
    leg1.steps = ckpt_at; // schedules still span the full `steps`
    leg1.ckpt_dir = Some(ckpt_root.to_string_lossy().into_owned());
    leg1.ckpt_every = ckpt_at;
    let first = Trainer::new(leg1)?.run().context("interrupted leg 1")?;

    let mut leg2 = base.clone();
    leg2.ckpt_dir = Some(ckpt_root.to_string_lossy().into_owned());
    leg2.resume = Some("latest".to_string());
    let resumed = Trainer::new(leg2)?.run().context("resumed leg 2")?;

    let bitwise = continuous.final_params == resumed.final_params;
    let mut table = Table::new(
        format!("Interrupted-run study — {} on {bundle}", algo.name()),
        &["metric", "value"],
    );
    table.row(vec!["steps (N+M)".into(), format!("{steps} ({ckpt_at}+{})", steps - ckpt_at)]);
    table.row(vec![
        "snapshot write (ms)".into(),
        format!("{:.1}", first.ckpt.write_s * 1e3),
    ]);
    table.row(vec![
        "restore (ms)".into(),
        format!("{:.1}", resumed.ckpt.restore_s * 1e3),
    ]);
    table.row(vec![
        "resume overhead (% of continuous wall)".into(),
        format!(
            "{:.2}",
            100.0 * (first.ckpt.write_s + resumed.ckpt.restore_s) / continuous.wall_s.max(1e-9)
        ),
    ]);
    table.row(vec!["bitwise params match".into(), bitwise.to_string()]);
    table.row(vec![
        "final loss (cont / resumed)".into(),
        format!("{:.6} / {:.6}", continuous.final_loss(), resumed.final_loss()),
    ]);
    table.print();
    ensure!(bitwise, "resumed run diverged from the continuous reference");

    json_rows.push(Json::obj(vec![
        ("study", Json::str("interrupted_run")),
        ("algorithm", Json::str(algo.id())),
        ("steps", Json::num(steps as f64)),
        ("ckpt_at", Json::num(ckpt_at as f64)),
        ("write_s", Json::num(first.ckpt.write_s)),
        ("restore_s", Json::num(resumed.ckpt.restore_s)),
        ("bitwise", Json::Bool(bitwise)),
    ]));
    let _ = std::fs::remove_dir_all(&ckpt_root);
    Ok(())
}
