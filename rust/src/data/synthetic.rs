//! Procedural paired image–text generator.
//!
//! Latent structure: `n_classes` classes, each with
//!   * an image prototype — a (v_patches, v_patch_dim) patch grid;
//!   * a text topic — a small pool of vocabulary tokens.
//! A sample of class c is (prototype_c + σ·noise, tokens mixing topic and
//! background vocabulary). Samples are generated lazily and
//! deterministically from their index, so multi-hundred-thousand-sample
//! "datasets" cost no memory and any worker can materialize any index.

use crate::config::DataConfig;
use crate::util::Rng;

/// The tensor dims the generator must match — taken from the artifact
/// manifest by the caller (`runtime::Manifest::model_dims`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub v_patches: usize,
    pub v_patch_dim: usize,
    pub t_vocab: usize,
    pub t_len: usize,
}

/// Distribution-shifted evaluation variants — the "ImageNet & variants"
/// analog (clean + 3 shifts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalVariant {
    Clean,
    /// 2x prototype noise
    Noisy,
    /// half the patches zeroed
    Occluded,
    /// patch order scrambled
    Scrambled,
}

impl EvalVariant {
    pub fn all() -> [EvalVariant; 4] {
        [EvalVariant::Clean, EvalVariant::Noisy, EvalVariant::Occluded, EvalVariant::Scrambled]
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalVariant::Clean => "clean",
            EvalVariant::Noisy => "noisy",
            EvalVariant::Occluded => "occluded",
            EvalVariant::Scrambled => "scrambled",
        }
    }
}

/// A materialized evaluation split.
pub struct EvalSet {
    /// (n, v_patches*v_patch_dim) row-major
    pub images: Vec<f32>,
    /// (n, t_len)
    pub texts: Vec<i32>,
    pub labels: Vec<u32>,
    pub n: usize,
}

pub struct Dataset {
    cfg: DataConfig,
    dims: ModelDims,
    /// (n_classes, v_patches*v_patch_dim)
    prototypes: Vec<f32>,
    /// (n_classes, TOPIC) topic token pools
    topics: Vec<i32>,
    /// train sample -> class
    classes: Vec<u16>,
    /// eval sample -> class (separate draw, same distribution)
    eval_classes: Vec<u16>,
}

const TOPIC: usize = 8;
/// token-position fraction drawn from the class topic pool
const TOPIC_FRAC: f64 = 0.7;

impl Dataset {
    pub fn new(cfg: DataConfig, dims: ModelDims) -> Self {
        assert!(cfg.n_classes >= 2 && cfg.n_classes < u16::MAX as usize);
        assert!(dims.t_vocab > TOPIC);
        let root = Rng::new(cfg.seed ^ 0xDA7A_5EED);
        let mut proto_rng = root.split(1);
        let img_dim = dims.v_patches * dims.v_patch_dim;
        let mut prototypes = vec![0.0f32; cfg.n_classes * img_dim];
        proto_rng.fill_normal(&mut prototypes, 1.0);

        let mut topic_rng = root.split(2);
        let mut topics = Vec::with_capacity(cfg.n_classes * TOPIC);
        for _ in 0..cfg.n_classes {
            for _ in 0..TOPIC {
                topics.push(topic_rng.below(dims.t_vocab) as i32);
            }
        }

        let mut cls_rng = root.split(3);
        let classes =
            (0..cfg.n_train).map(|_| cls_rng.zipf(cfg.n_classes, cfg.zipf_s) as u16).collect();
        let mut ecls_rng = root.split(4);
        let eval_classes =
            (0..cfg.n_eval).map(|_| ecls_rng.zipf(cfg.n_classes, cfg.zipf_s) as u16).collect();

        Self { cfg, dims, prototypes, topics, classes, eval_classes }
    }

    pub fn n_train(&self) -> usize {
        self.cfg.n_train
    }

    pub fn n_eval(&self) -> usize {
        self.cfg.n_eval
    }

    pub fn n_classes(&self) -> usize {
        self.cfg.n_classes
    }

    pub fn dims(&self) -> ModelDims {
        self.dims
    }

    pub fn class_of(&self, idx: usize) -> usize {
        self.classes[idx] as usize
    }

    fn image_into(&self, class: usize, rng: &mut Rng, noise_scale: f32, out: &mut [f32]) {
        let img_dim = self.dims.v_patches * self.dims.v_patch_dim;
        let proto = &self.prototypes[class * img_dim..(class + 1) * img_dim];
        for (o, p) in out.iter_mut().zip(proto) {
            *o = p + rng.normal() * self.cfg.noise * noise_scale;
        }
    }

    fn text_into(&self, class: usize, rng: &mut Rng, out: &mut [i32]) {
        let topic = &self.topics[class * TOPIC..(class + 1) * TOPIC];
        for o in out.iter_mut() {
            *o = if rng.next_f64() < TOPIC_FRAC {
                topic[rng.below(TOPIC)]
            } else {
                rng.below(self.dims.t_vocab) as i32
            };
        }
    }

    /// Materialize training sample `idx` into the provided buffers.
    pub fn train_sample_into(&self, idx: usize, img: &mut [f32], txt: &mut [i32]) {
        let class = self.classes[idx] as usize;
        let mut rng = Rng::new(self.cfg.seed ^ 0x5A5A_0000).split(idx as u64);
        self.image_into(class, &mut rng, 1.0, img);
        self.text_into(class, &mut rng, txt);
    }

    /// Fill a batch from global sample indices. Buffers are
    /// (len, img_dim) and (len, t_len) row-major.
    pub fn fill_batch(&self, indices: &[usize], images: &mut [f32], texts: &mut [i32]) {
        let img_dim = self.dims.v_patches * self.dims.v_patch_dim;
        assert_eq!(images.len(), indices.len() * img_dim);
        assert_eq!(texts.len(), indices.len() * self.dims.t_len);
        for (i, &idx) in indices.iter().enumerate() {
            self.train_sample_into(
                idx,
                &mut images[i * img_dim..(i + 1) * img_dim],
                &mut texts[i * self.dims.t_len..(i + 1) * self.dims.t_len],
            );
        }
    }

    /// Held-out paired split under a distribution-shift variant.
    pub fn eval_set(&self, variant: EvalVariant) -> EvalSet {
        let img_dim = self.dims.v_patches * self.dims.v_patch_dim;
        let n = self.cfg.n_eval;
        let mut images = vec![0.0f32; n * img_dim];
        let mut texts = vec![0i32; n * self.dims.t_len];
        let mut labels = vec![0u32; n];
        for i in 0..n {
            let class = self.eval_classes[i] as usize;
            labels[i] = class as u32;
            // eval seed space disjoint from training
            let mut rng = Rng::new(self.cfg.seed ^ 0xE7A1_0000).split(i as u64);
            let noise_scale = if variant == EvalVariant::Noisy { 2.0 } else { 1.0 };
            let img = &mut images[i * img_dim..(i + 1) * img_dim];
            self.image_into(class, &mut rng, noise_scale, img);
            match variant {
                EvalVariant::Occluded => {
                    let pd = self.dims.v_patch_dim;
                    for patch in 0..self.dims.v_patches {
                        if rng.next_f64() < 0.5 {
                            img[patch * pd..(patch + 1) * pd].fill(0.0);
                        }
                    }
                }
                EvalVariant::Scrambled => {
                    let pd = self.dims.v_patch_dim;
                    let mut order: Vec<usize> = (0..self.dims.v_patches).collect();
                    rng.shuffle(&mut order);
                    let orig = img.to_vec();
                    for (dst, &src) in order.iter().enumerate() {
                        img[dst * pd..(dst + 1) * pd]
                            .copy_from_slice(&orig[src * pd..(src + 1) * pd]);
                    }
                }
                _ => {}
            }
            self.text_into(class, &mut rng, &mut texts[i * self.dims.t_len..(i + 1) * self.dims.t_len]);
        }
        EvalSet { images, texts, labels, n }
    }

    /// Canonical class prompts for zero-shot classification: each class's
    /// topic tokens cycled to t_len (the "a photo of a {class}" analog).
    pub fn class_prompts(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.cfg.n_classes * self.dims.t_len);
        for c in 0..self.cfg.n_classes {
            let topic = &self.topics[c * TOPIC..(c + 1) * TOPIC];
            for t in 0..self.dims.t_len {
                out.push(topic[t % TOPIC]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { v_patches: 4, v_patch_dim: 8, t_vocab: 64, t_len: 12 }
    }

    fn cfg() -> DataConfig {
        DataConfig { n_train: 200, n_eval: 50, n_classes: 10, noise: 0.5, zipf_s: 0.7, seed: 9 }
    }

    #[test]
    fn deterministic_samples() {
        let ds = Dataset::new(cfg(), dims());
        let (mut i1, mut t1) = (vec![0.0; 32], vec![0; 12]);
        let (mut i2, mut t2) = (vec![0.0; 32], vec![0; 12]);
        ds.train_sample_into(17, &mut i1, &mut t1);
        ds.train_sample_into(17, &mut i2, &mut t2);
        assert_eq!(i1, i2);
        assert_eq!(t1, t2);
        ds.train_sample_into(18, &mut i2, &mut t2);
        assert_ne!(i1, i2);
    }

    #[test]
    fn same_class_images_correlated() {
        let ds = Dataset::new(cfg(), dims());
        // find two samples of the same class and one of a different class
        let c0 = ds.class_of(0);
        let same = (1..200).find(|&i| ds.class_of(i) == c0).unwrap();
        let diff = (1..200).find(|&i| ds.class_of(i) != c0).unwrap();
        let get = |idx: usize| {
            let (mut im, mut tx) = (vec![0.0; 32], vec![0; 12]);
            ds.train_sample_into(idx, &mut im, &mut tx);
            im
        };
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let (a, b, c) = (get(0), get(same), get(diff));
        assert!(dot(&a, &b) > dot(&a, &c), "class structure should dominate noise");
    }

    #[test]
    fn texts_share_topic_tokens_within_class() {
        let ds = Dataset::new(cfg(), dims());
        let c0 = ds.class_of(0);
        let same = (1..200).find(|&i| ds.class_of(i) == c0).unwrap();
        let get = |idx: usize| {
            let (mut im, mut tx) = (vec![0.0; 32], vec![0; 12]);
            ds.train_sample_into(idx, &mut im, &mut tx);
            tx
        };
        let (a, b) = (get(0), get(same));
        let overlap = a.iter().filter(|t| b.contains(t)).count();
        assert!(overlap >= 4, "topic overlap {overlap}");
    }

    #[test]
    fn eval_variants_differ_but_share_labels() {
        let ds = Dataset::new(cfg(), dims());
        let clean = ds.eval_set(EvalVariant::Clean);
        let noisy = ds.eval_set(EvalVariant::Noisy);
        let occ = ds.eval_set(EvalVariant::Occluded);
        assert_eq!(clean.labels, noisy.labels);
        assert_eq!(clean.labels, occ.labels);
        assert_ne!(clean.images, noisy.images);
        // occlusion zeroes roughly half the patches
        let zeros = occ.images.iter().filter(|v| **v == 0.0).count();
        assert!(zeros > occ.images.len() / 8);
        assert_eq!(clean.n, 50);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let ds = Dataset::new(cfg(), dims());
        let es = ds.eval_set(EvalVariant::Clean);
        assert!(es.texts.iter().all(|&t| (0..64).contains(&t)));
        let prompts = ds.class_prompts();
        assert_eq!(prompts.len(), 10 * 12);
        assert!(prompts.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn zipf_classes_long_tailed() {
        let mut c = cfg();
        c.n_train = 5000;
        c.zipf_s = 1.0;
        let ds = Dataset::new(c, dims());
        let mut counts = vec![0usize; 10];
        for i in 0..5000 {
            counts[ds.class_of(i)] += 1;
        }
        assert!(counts[0] > counts[9] * 2);
    }

    #[test]
    fn fill_batch_matches_single_samples() {
        let ds = Dataset::new(cfg(), dims());
        let idx = [3usize, 99, 0];
        let mut imgs = vec![0.0; 3 * 32];
        let mut txts = vec![0; 3 * 12];
        ds.fill_batch(&idx, &mut imgs, &mut txts);
        let (mut im, mut tx) = (vec![0.0; 32], vec![0; 12]);
        ds.train_sample_into(99, &mut im, &mut tx);
        assert_eq!(&imgs[32..64], &im[..]);
        assert_eq!(&txts[12..24], &tx[..]);
    }
}
