//! Compute runtimes behind the [`ComputeBackend`] trait (DESIGN.md §10).
//!
//! Two engines implement the same `encode` / `phase_g` / `step` surface:
//!
//! * **native** ([`NativeBackend`]) — pure-Rust kernels
//!   ([`crate::kernels`]) over a synthesized [`Manifest`]
//!   ([`Manifest::native`]): no artifacts, no Python, bitwise
//!   deterministic at any kernel thread count. The default on any machine
//!   without artifacts.
//! * **pjrt** ([`WorkerRuntime`]) — loads the HLO-text artifacts produced
//!   by `python/compile/aot.py` and executes them through PJRT. The `xla`
//!   crate types wrap raw PJRT pointers and are neither `Send` nor
//!   `Sync`, so every worker thread owns its own runtime. Builds without
//!   the `pjrt` cargo feature substitute the in-tree [`pjrt_stub`]:
//!   marshalling types work, execution fails at client construction with
//!   an actionable message (DESIGN.md §8). The `pjrt` feature therefore
//!   only swaps the execution engine — everything above this module is
//!   backend-agnostic.
//!
//! [`create_backend`] constructs the right engine for a resolved
//! [`BackendKind`]; `BackendKind::Auto` resolves per manifest kind.

mod backend;
mod manifest;
pub mod native;
#[cfg(not(feature = "pjrt"))]
pub mod pjrt_stub;
mod worker;

use anyhow::Result;

pub use backend::{
    BackendKind, ComputeBackend, FeatGradReduce, LossShard, LossShardMode, RuntimeTimers,
    StepEmit, StepOutput, TauGrads, TauInput,
};
pub use manifest::{ExecSig, Manifest, ModelInfo, ParamSegment, TensorSig};
pub use native::NativeBackend;
pub use worker::WorkerRuntime;

/// Construct the compute backend for one worker. `Auto` resolves from the
/// manifest kind (native manifests run natively, artifact bundles through
/// PJRT); an explicit kind is honored or errors loudly — a native
/// manifest cannot execute under PJRT and vice versa (the parameter
/// layouts differ). `precision` selects the storage width (DESIGN.md
/// §12); only the native backend implements the bf16 path — the
/// AOT-lowered PJRT graphs are f32-only, so `bf16` there is an error.
pub fn create_backend(
    kind: BackendKind,
    manifest: &Manifest,
    variant: Option<&str>,
    kernel_threads: usize,
    precision: crate::kernels::Precision,
) -> Result<Box<dyn ComputeBackend>> {
    let resolved = match kind {
        BackendKind::Auto => {
            if manifest.native {
                BackendKind::Native
            } else {
                BackendKind::Pjrt
            }
        }
        k => k,
    };
    match resolved {
        BackendKind::Native => Ok(Box::new(NativeBackend::with_precision(
            manifest,
            variant,
            kernel_threads,
            precision,
        )?)),
        BackendKind::Pjrt => {
            anyhow::ensure!(
                !manifest.native,
                "--backend pjrt needs an artifact bundle; '{}' is a native manifest \
                 (use --backend native, or point --bundle at a built artifact dir)",
                manifest.preset
            );
            anyhow::ensure!(
                precision == crate::kernels::Precision::F32,
                "--precision bf16 requires the native backend: the AOT-lowered HLO \
                 artifacts compute in f32 (use --backend native)"
            );
            Ok(Box::new(WorkerRuntime::load(manifest, variant)?))
        }
        BackendKind::Auto => unreachable!("resolved above"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::kernels::Precision;

    #[test]
    fn auto_resolves_native_manifest_to_native_backend() {
        let m = Manifest::native("tiny", 1, 4, 0).unwrap();
        let b = create_backend(BackendKind::Auto, &m, Some("gcl"), 1, Precision::F32).unwrap();
        assert_eq!(b.backend_id(), "native");
        assert_eq!(b.manifest().global_batch, 4);
        // bf16 is a native-backend capability; constructing one works
        let b = create_backend(BackendKind::Native, &m, Some("gcl"), 1, Precision::Bf16).unwrap();
        assert_eq!(b.backend_id(), "native");
    }

    #[test]
    fn pjrt_on_native_manifest_is_an_error() {
        let m = Manifest::native("tiny", 1, 4, 0).unwrap();
        let err =
            create_backend(BackendKind::Pjrt, &m, Some("gcl"), 1, Precision::F32).unwrap_err();
        assert!(format!("{err}").contains("artifact"), "{err}");
    }
}
