//! Bench/telemetry schema drift. Two contracts:
//!
//! * The gated bench rows — `GATED_ROWS` in `rust/benches/bench_iteration.rs`
//!   — must equal the committed baseline rows in
//!   `rust/benches/baseline/BENCH_iteration.json` *and* be producible by
//!   the bench's `name:` emitters (format templates match with `{…}` as
//!   wildcards). Deleting a baseline row, a manifest entry or an emitter
//!   therefore fails the lint with a file:line diagnostic, in addition to
//!   the runtime assertion inside the bench itself.
//! * Every dotted metric name asserted by `rust/tests/telemetry.rs` must
//!   be registered somewhere in `rust/src` (`counter_add`/`gauge_set`/
//!   `observe`/`hist_declare`, literal or `format!` template).

use std::path::Path;

use anyhow::{Context, Result};

use super::source::{find_all, template_matches, SourceFile};
use super::{Finding, Severity};

/// Emitted row families that are deliberately report-only (no baseline
/// gate): overlap rows vary with machine load, so the baseline would
/// either flake or gate nothing.
const REPORT_ONLY: &[&str] = &["iteration/*/overlap"];

fn row_char(c: char) -> bool {
    c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '-' || c == '/'
}

/// Extract the `GATED_ROWS` manifest entries (name, line).
fn gated_rows(sf: &SourceFile) -> Option<Vec<(String, usize)>> {
    let start = (0..sf.nocomment.len()).find(|&i| sf.nocomment[i].contains("GATED_ROWS"))?;
    let mut out = Vec::new();
    for idx in start..sf.nocomment.len() {
        for lit in sf.string_literals(idx) {
            if !lit.is_empty() && lit.chars().all(row_char) {
                out.push((lit, idx + 1));
            }
        }
        if sf.code[idx].contains("];") {
            break;
        }
    }
    Some(out)
}

/// Extract `name: "<row>"` / `name: format!("<template>")` emitters.
fn emitters(sf: &SourceFile) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for idx in 0..sf.nocomment.len() {
        let line = &sf.nocomment[idx];
        for at in find_all(line, "name:") {
            let rest = line[at + 5..].trim_start();
            let rest = rest.strip_prefix("format!(").unwrap_or(rest).trim_start();
            let Some(body) = rest.strip_prefix('"') else {
                continue;
            };
            let mut lit = String::new();
            let mut chars = body.chars();
            let mut closed = false;
            while let Some(c) = chars.next() {
                if c == '\\' {
                    lit.push(c);
                    if let Some(n) = chars.next() {
                        lit.push(n);
                    }
                    continue;
                }
                if c == '"' {
                    closed = true;
                    break;
                }
                lit.push(c);
            }
            if closed && !lit.is_empty() {
                out.push((lit, idx + 1));
            }
        }
    }
    out
}

/// Replace `{…}` holes with `*` for family comparison / display.
fn canon(template: &str) -> String {
    let mut out = String::new();
    let mut chars = template.chars();
    while let Some(c) = chars.next() {
        if c == '{' {
            for nc in chars.by_ref() {
                if nc == '}' {
                    break;
                }
            }
            out.push('*');
        } else {
            out.push(c);
        }
    }
    out
}

fn family(row: &str) -> &str {
    row.split('/').next().unwrap_or(row)
}

/// Is `lit` a dotted metric name (`comm.grad_wire_bytes`)? Lowercase
/// segments joined by single dots, at least two segments, and not a file
/// name (extension suffixes are excluded).
fn is_metric_name(lit: &str) -> bool {
    const EXT: &[&str] = &[".jsonl", ".json", ".rs", ".toml", ".md", ".bin", ".csv", ".txt"];
    if EXT.iter().any(|e| lit.ends_with(e)) {
        return false;
    }
    if !lit.contains('.') || !lit.chars().next().is_some_and(|c| c.is_ascii_lowercase()) {
        return false;
    }
    lit.split('.').all(|seg| {
        !seg.is_empty()
            && seg.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
    })
}

/// Registered metric names/templates across `rust/src`.
fn registered_metrics(sources: &[SourceFile]) -> Vec<String> {
    const CALLS: &[&str] = &[".counter_add(", ".gauge_set(", ".observe(", ".hist_declare("];
    let mut out = Vec::new();
    for sf in sources {
        if !sf.rel.starts_with("rust/src/") {
            continue;
        }
        for idx in 0..sf.nocomment.len() {
            let line = &sf.nocomment[idx];
            for call in CALLS {
                for at in find_all(line, call) {
                    let rest = line[at + call.len()..].trim_start();
                    let rest = rest.strip_prefix("&format!(").unwrap_or(rest).trim_start();
                    let Some(body) = rest.strip_prefix('"') else {
                        continue;
                    };
                    if let Some(end) = body.find('"') {
                        let lit = &body[..end];
                        if !lit.is_empty() {
                            out.push(lit.to_string());
                        }
                    }
                }
            }
        }
    }
    out
}

/// Run the schema drift checks. Trees without the bench/baseline/test
/// files skip the corresponding halves.
pub fn check(root: &Path, sources: &[SourceFile], findings: &mut Vec<Finding>) -> Result<()> {
    let mut err = |rule: &'static str, file: String, line: usize, message: String| {
        findings.push(Finding { rule, severity: Severity::Error, file, line, message });
    };

    // ---- gated rows vs baseline vs emitters -----------------------------
    let bench = sources.iter().find(|s| s.rel == "rust/benches/bench_iteration.rs");
    let baseline_rel = "rust/benches/baseline/BENCH_iteration.json";
    let baseline_path = root.join(baseline_rel);
    if let Some(bench) = bench {
        let rows = gated_rows(bench);
        if rows.is_none() {
            err(
                "sch-baseline-drift",
                bench.rel.clone(),
                1,
                "bench_iteration.rs has no GATED_ROWS manifest".to_string(),
            );
        }
        let rows = rows.unwrap_or_default();
        let pats = emitters(bench);

        if baseline_path.is_file() {
            let text = std::fs::read_to_string(&baseline_path)
                .with_context(|| format!("reading {}", baseline_path.display()))?;
            let json = crate::util::Json::parse(&text)
                .with_context(|| format!("parsing {}", baseline_path.display()))?;
            let mut base_rows: Vec<String> = Vec::new();
            for r in json.get("results").and_then(|r| r.as_arr().map(<[_]>::to_vec))? {
                base_rows.push(r.get("name")?.as_str()?.to_string());
            }
            for (g, line) in &rows {
                if !base_rows.contains(g) {
                    err(
                        "sch-baseline-drift",
                        bench.rel.clone(),
                        *line,
                        format!("gated row '{g}' has no row in {baseline_rel}"),
                    );
                }
            }
            for b in &base_rows {
                if !rows.iter().any(|(g, _)| g == b) {
                    let line = text
                        .lines()
                        .position(|l| l.contains(&format!("\"{b}\"")))
                        .map(|i| i + 1)
                        .unwrap_or(1);
                    err(
                        "sch-baseline-drift",
                        baseline_rel.to_string(),
                        line,
                        format!("baseline row '{b}' is not in the bench GATED_ROWS manifest"),
                    );
                }
            }
        }

        let families: Vec<&str> = rows.iter().map(|(g, _)| family(g)).collect();
        for (g, line) in &rows {
            if !pats.iter().any(|(p, _)| template_matches(p, g)) {
                err(
                    "sch-emitter-drift",
                    bench.rel.clone(),
                    *line,
                    format!("gated row '{g}' matches no `name:` emitter in the bench"),
                );
            }
        }
        for (p, line) in &pats {
            let c = canon(p);
            if families.contains(&family(&c))
                && !REPORT_ONLY.contains(&c.as_str())
                && !rows.iter().any(|(g, _)| template_matches(p, g))
            {
                err(
                    "sch-emitter-drift",
                    bench.rel.clone(),
                    *line,
                    format!("emitter '{c}' produces rows outside the GATED_ROWS manifest"),
                );
            }
        }
    }

    // ---- asserted metric names vs registrations -------------------------
    if let Some(tel) = sources.iter().find(|s| s.rel == "rust/tests/telemetry.rs") {
        let registered = registered_metrics(sources);
        let mut seen: Vec<String> = Vec::new();
        for idx in 0..tel.nocomment.len() {
            for lit in tel.string_literals(idx) {
                if !is_metric_name(&lit) || seen.contains(&lit) {
                    continue;
                }
                seen.push(lit.clone());
                let covered = registered
                    .iter()
                    .any(|r| if r.contains('{') { template_matches(r, &lit) } else { r == &lit });
                if !covered {
                    err(
                        "sch-metric-drift",
                        tel.rel.clone(),
                        idx + 1,
                        format!("metric '{lit}' is asserted but registered nowhere in rust/src"),
                    );
                }
            }
        }
    }
    Ok(())
}
