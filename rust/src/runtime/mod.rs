//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the L3 hot path.
//!
//! Python never runs here — after `make artifacts` the Rust binary is
//! self-contained. Interchange is HLO *text* (xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos; the text parser reassigns ids).
//!
//! The `xla` crate types wrap raw PJRT pointers and are neither `Send` nor
//! `Sync`, so every worker thread owns its own [`WorkerRuntime`] (client +
//! compiled executables). Parameters are replicated and updated
//! deterministically on every worker, so no cross-thread buffer sharing is
//! needed (DESIGN.md §8).

mod manifest;
mod worker;

pub use manifest::{ExecSig, Manifest, ModelInfo, ParamSegment, TensorSig};
pub use worker::{StepOutput, TauGrads, TauInput, WorkerRuntime};
