//! Data-pipeline benchmarks: synthetic sample materialization, batch fill
//! and shard loading — the "others" budget of the iteration loop.

#[path = "harness.rs"]
mod harness;

use fastclip::config::DataConfig;
use fastclip::data::{Dataset, EvalVariant, ModelDims, ShardLoader};
use harness::{black_box, Bench};

fn main() {
    let dims = ModelDims { v_patches: 16, v_patch_dim: 32, t_vocab: 256, t_len: 16 };
    let cfg = DataConfig { n_train: 65_536, n_eval: 512, n_classes: 64, ..DataConfig::default() };
    let ds = Dataset::new(cfg, dims);
    let img_dim = dims.v_patches * dims.v_patch_dim;

    let mut img = vec![0.0f32; img_dim];
    let mut txt = vec![0i32; dims.t_len];
    Bench::new("train_sample_into (1 sample)").samples(50).run(|| {
        ds.train_sample_into(12345, &mut img, &mut txt);
        black_box(img[0]);
    });

    for bl in [16usize, 128] {
        let idx: Vec<usize> = (0..bl).map(|i| i * 37 % 65_536).collect();
        let mut images = vec![0.0f32; bl * img_dim];
        let mut texts = vec![0i32; bl * dims.t_len];
        Bench::new(format!("fill_batch bl={bl}")).samples(30).run(|| {
            ds.fill_batch(&idx, &mut images, &mut texts);
            black_box(images[0]);
        });
    }

    let mut loader = ShardLoader::new(65_536, 0, 4, 128, 7).expect("valid loader config");
    Bench::new("shard next_batch (bl=128)").samples(50).run(|| {
        black_box(loader.next_batch());
    });

    Bench::new("eval_set clean (512 samples)").samples(5).run(|| {
        black_box(ds.eval_set(EvalVariant::Clean).n);
    });
    Bench::new("eval_set scrambled (512 samples)").samples(5).run(|| {
        black_box(ds.eval_set(EvalVariant::Scrambled).n);
    });
}
