//! The versioned checkpoint manifest (`MANIFEST.json`, DESIGN.md §9): run
//! identity (step, world size, algorithm, optimizer, reduction strategy,
//! seeds) plus the integrity-hashed blob table. Written last during a
//! snapshot — a directory without a readable manifest is not a
//! checkpoint, which is what makes write-then-rename atomic in practice.

use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::config::{GammaSchedule, TrainConfig};
use crate::util::Json;

use super::blob::{BlobKind, BlobSpec};

/// Format version stamped into (and checked from) every manifest.
pub const CKPT_VERSION: usize = 1;
/// The manifest's file name inside a checkpoint directory.
pub const MANIFEST_FILE: &str = "MANIFEST.json";

/// Canonical echo of every hyperparameter that drives the update rule or
/// the synthetic data — anything whose drift between snapshot and resume
/// would silently break the bitwise-continuation guarantee. Deliberately
/// excludes `steps` (resume legitimately extends it), the topology /
/// network / reduce / overlap knobs (timing and layout only — layouts
/// convert), and `n_train` / seeds / world (checked as dedicated
/// fields). The compute `precision` IS included: unlike overlap it
/// changes the numerics (bf16 working copies round every activation), so
/// resuming a bf16 snapshot under f32 — or vice versa — would silently
/// fork the trajectory. f32 Display is shortest-round-trip, so string
/// equality is value equality.
///
/// The gradient wire codec is echoed as a trailing ` wire=<id>` field
/// **only when it differs from the precision's default**
/// ([`crate::comm::WireCodec::from_precision`]): a lossy codec changes
/// the update numerics, but the default wire echoes nothing so every
/// pre-§15 checkpoint stays resumable (same trick as the `prec=` legacy
/// suffix handling in [`super::check_compatible`]).
pub fn hyper_echo(cfg: &TrainConfig) -> String {
    let o = &cfg.optimizer;
    let d = &cfg.data;
    let gamma = match cfg.gamma {
        GammaSchedule::Constant { gamma } => format!("const({gamma})"),
        GammaSchedule::Cosine { gamma_min, decay_epochs } => {
            format!("cosine({gamma_min},{decay_epochs})")
        }
    };
    let mut echo = format!(
        "tau=({},{},{},{:?}) eps={} rho={} gamma={gamma} \
         lr=({},{},{},{}) iters_per_epoch={} opt=({},{},{},{},{}) \
         data=({},{},{}) prec={}",
        cfg.tau_init,
        cfg.tau_lr,
        cfg.tau_min,
        cfg.tau_lr_decay_below,
        cfg.eps,
        cfg.rho,
        cfg.lr.peak,
        cfg.lr.min,
        cfg.lr.warmup_iters,
        cfg.lr.total_iters,
        cfg.iters_per_epoch,
        o.beta1,
        o.beta2,
        o.eps,
        o.weight_decay,
        o.momentum,
        d.n_classes,
        d.noise,
        d.zipf_s,
        cfg.precision.id(),
    );
    let wire = cfg.wire_codec();
    if wire != crate::comm::WireCodec::from_precision(cfg.precision) {
        echo.push_str(&format!(" wire={}", wire.id()));
    }
    echo
}

/// Run identity recorded with every snapshot. Resume checks it against
/// the resuming run's config ([`super::check_compatible`]); `world` may
/// differ (elastic resume re-shards), everything else must match.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptMeta {
    /// completed training steps at snapshot time
    pub step: u32,
    /// worker count the snapshot was written at (K)
    pub world: usize,
    /// flat parameter-vector length
    pub n_params: usize,
    /// training-set size (the strided shard partition depends on it)
    pub n_train: usize,
    /// per-worker batch size the snapshot was written at
    pub local_batch: usize,
    /// [`crate::config::Algorithm::id`]
    pub algorithm: String,
    /// [`crate::config::OptimizerKind::id`]
    pub optimizer: String,
    /// resolved [`crate::comm::ReduceAlgo::id`] — decides whether the
    /// optimizer state is one replicated blob or K per-rank shards
    pub reduce: String,
    /// run seed (init, loader shuffling)
    pub seed: u64,
    /// synthetic-data generator seed
    pub data_seed: u64,
    /// [`hyper_echo`] of the writing run's config — compared exactly on
    /// resume
    pub hyper: String,
}

impl CkptMeta {
    /// Assemble the meta for a snapshot of `cfg`'s run — the one
    /// constructor every writer (trainer, studies, benches, tests) goes
    /// through, so the `hyper` echo can never be forgotten or drift.
    pub fn for_run(
        cfg: &TrainConfig,
        step: u32,
        world: usize,
        n_params: usize,
        local_batch: usize,
        reduce: &str,
    ) -> CkptMeta {
        CkptMeta {
            step,
            world,
            n_params,
            n_train: cfg.data.n_train,
            local_batch,
            algorithm: cfg.algorithm.id().to_string(),
            optimizer: cfg.optimizer.kind.id().to_string(),
            reduce: reduce.to_string(),
            seed: cfg.seed,
            data_seed: cfg.data.seed,
            hyper: hyper_echo(cfg),
        }
    }
}

/// The parsed `MANIFEST.json`: run identity plus the blob table.
#[derive(Debug, Clone)]
pub struct CkptManifest {
    /// run identity at snapshot time
    pub meta: CkptMeta,
    /// every blob in the checkpoint, sorted by file name
    pub blobs: Vec<BlobSpec>,
}

impl CkptManifest {
    /// Serialize to the on-disk JSON shape.
    pub fn to_json(&self) -> Json {
        let m = &self.meta;
        Json::obj(vec![
            ("version", Json::num(CKPT_VERSION as f64)),
            (
                "meta",
                Json::obj(vec![
                    ("step", Json::num(m.step as f64)),
                    ("world", Json::num(m.world as f64)),
                    ("n_params", Json::num(m.n_params as f64)),
                    ("n_train", Json::num(m.n_train as f64)),
                    ("local_batch", Json::num(m.local_batch as f64)),
                    ("algorithm", Json::str(m.algorithm.clone())),
                    ("optimizer", Json::str(m.optimizer.clone())),
                    ("reduce", Json::str(m.reduce.clone())),
                    // u64 seeds as decimal strings: JSON numbers are f64
                    // and would lose bits past 2^53
                    ("seed", Json::str(m.seed.to_string())),
                    ("data_seed", Json::str(m.data_seed.to_string())),
                    ("hyper", Json::str(m.hyper.clone())),
                ]),
            ),
            (
                "blobs",
                Json::arr(self.blobs.iter().map(|b| {
                    Json::obj(vec![
                        ("file", Json::str(b.file.clone())),
                        ("kind", Json::str(b.kind.id())),
                        ("len", Json::num(b.len as f64)),
                        ("hash", Json::str(format!("{:016x}", b.hash))),
                    ])
                })),
            ),
        ])
    }

    /// Write `MANIFEST.json` into `dir` (the finalize step writes it
    /// LAST — a directory without it is not a checkpoint).
    pub fn write(&self, dir: &Path) -> Result<()> {
        self.to_json().write_file(&dir.join(MANIFEST_FILE))
    }

    /// Parse `<dir>/MANIFEST.json`, rejecting unknown format versions.
    pub fn load(dir: &Path) -> Result<CkptManifest> {
        let path = dir.join(MANIFEST_FILE);
        let j = Json::parse_file(&path)?;
        ensure!(
            j.get("version")?.as_usize()? == CKPT_VERSION,
            "unsupported checkpoint version in {}",
            path.display()
        );
        let m = j.get("meta")?;
        let parse_u64 = |key: &str| -> Result<u64> {
            m.get(key)?
                .as_str()?
                .parse::<u64>()
                .map_err(|e| anyhow!("bad {key} in {}: {e}", path.display()))
        };
        let meta = CkptMeta {
            step: m.get("step")?.as_usize()? as u32,
            world: m.get("world")?.as_usize()?,
            n_params: m.get("n_params")?.as_usize()?,
            n_train: m.get("n_train")?.as_usize()?,
            local_batch: m.get("local_batch")?.as_usize()?,
            algorithm: m.get("algorithm")?.as_str()?.to_string(),
            optimizer: m.get("optimizer")?.as_str()?.to_string(),
            reduce: m.get("reduce")?.as_str()?.to_string(),
            seed: parse_u64("seed")?,
            data_seed: parse_u64("data_seed")?,
            hyper: m.get("hyper")?.as_str()?.to_string(),
        };
        ensure!(meta.world > 0, "checkpoint world size is 0");
        let mut blobs = Vec::new();
        for b in j.get("blobs")?.as_arr()? {
            let hash_hex = b.get("hash")?.as_str()?.to_string();
            blobs.push(BlobSpec {
                file: b.get("file")?.as_str()?.to_string(),
                kind: BlobKind::from_id(b.get("kind")?.as_str()?)?,
                len: b.get("len")?.as_usize()?,
                hash: u64::from_str_radix(&hash_hex, 16)
                    .with_context(|| format!("bad blob hash '{hash_hex}'"))?,
            });
        }
        Ok(CkptManifest { meta, blobs })
    }

    /// Look up a blob by file name.
    pub fn blob(&self, file: &str) -> Result<&BlobSpec> {
        self.blobs
            .iter()
            .find(|b| b.file == file)
            .ok_or_else(|| anyhow!("checkpoint is missing blob '{file}'"))
    }

    /// Whether a blob with this file name exists.
    pub fn has_blob(&self, file: &str) -> bool {
        self.blobs.iter().any(|b| b.file == file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo() -> CkptManifest {
        CkptManifest {
            meta: CkptMeta {
                step: 40,
                world: 4,
                n_params: 103,
                n_train: 512,
                local_batch: 8,
                algorithm: "fastclip-v3".into(),
                optimizer: "adamw".into(),
                reduce: "sharded".into(),
                seed: u64::MAX - 3, // exercises the >2^53 string encoding
                data_seed: 7,
                hyper: "tau=(0.07,...)".into(),
            },
            blobs: vec![
                BlobSpec { file: "params.f32".into(), kind: BlobKind::F32, len: 103, hash: 0xdead },
                BlobSpec { file: "loader_rank0.u64".into(), kind: BlobKind::U64, len: 9, hash: 1 },
            ],
        }
    }

    #[test]
    fn manifest_roundtrips_through_disk() {
        let dir = std::env::temp_dir().join("fastclip_ckpt_manifest_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = demo();
        m.write(&dir).unwrap();
        let back = CkptManifest::load(&dir).unwrap();
        assert_eq!(back.meta, m.meta);
        assert_eq!(back.blobs, m.blobs);
        assert!(back.has_blob("params.f32"));
        assert!(back.blob("nope.f32").is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hyper_echo_tracks_update_driving_fields() {
        let mut cfg = TrainConfig::new("x", crate::config::Algorithm::FastClipV3);
        let base = hyper_echo(&cfg);
        let meta = CkptMeta::for_run(&cfg, 3, 2, 9, 4, "ring");
        assert_eq!(meta.hyper, base);
        assert_eq!(meta.reduce, "ring");
        assert_eq!(meta.local_batch, 4);
        // steps is excluded by design: resume legitimately extends it
        cfg.steps += 100;
        assert_eq!(hyper_echo(&cfg), base);
        // but update-driving knobs are all echoed
        cfg.tau_lr *= 2.0;
        assert_ne!(hyper_echo(&cfg), base);
        let mut cfg2 = TrainConfig::new("x", crate::config::Algorithm::FastClipV3);
        cfg2.lr.warmup_iters += 1;
        assert_ne!(hyper_echo(&cfg2), base);
        let mut cfg3 = TrainConfig::new("x", crate::config::Algorithm::FastClipV3);
        cfg3.data.noise += 0.1;
        assert_ne!(hyper_echo(&cfg3), base);
        // precision changes the numerics, so it is part of the echo —
        // a bf16 snapshot cannot silently resume under f32
        let mut cfg4 = TrainConfig::new("x", crate::config::Algorithm::FastClipV3);
        cfg4.precision = crate::kernels::Precision::Bf16;
        assert_ne!(hyper_echo(&cfg4), base);
        // the wire codec is echoed only when it departs from the
        // precision default: default wires keep old checkpoints readable
        let mut cfg5 = TrainConfig::new("x", crate::config::Algorithm::FastClipV3);
        cfg5.wire = Some(crate::comm::WireCodec::F32);
        assert_eq!(hyper_echo(&cfg5), base, "explicit default wire must echo nothing");
        cfg5.wire = Some(crate::comm::WireCodec::TopK);
        assert_eq!(hyper_echo(&cfg5), format!("{base} wire=topk"));
        cfg5.wire = Some(crate::comm::WireCodec::Int8);
        assert!(hyper_echo(&cfg5).ends_with(" wire=int8"));
        // bf16 wire on a bf16-precision run is that precision's default
        cfg4.wire = Some(crate::comm::WireCodec::Bf16);
        assert!(!hyper_echo(&cfg4).contains("wire="));
    }

    #[test]
    fn load_rejects_missing_or_bad_version() {
        let dir = std::env::temp_dir().join("fastclip_ckpt_manifest_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(CkptManifest::load(&dir).is_err(), "no manifest file");
        std::fs::write(dir.join(MANIFEST_FILE), r#"{"version": 99}"#).unwrap();
        assert!(CkptManifest::load(&dir).is_err(), "future version");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
