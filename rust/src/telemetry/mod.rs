//! Structured telemetry: per-rank spans, a metrics registry, a JSONL
//! event sink and the `fastclip trace` analyzer (DESIGN.md §14).
//!
//! The paper's efficiency claims are time-breakdown claims (Fig. 3 /
//! Tables 15–22), and the fault-tolerance layer (§13) produces event
//! sequences — shrink, watchdog, straggle — that are invisible in
//! end-of-run aggregates. This module gives every layer a common,
//! durable trail:
//!
//! * [`span`] — a per-rank span recorder: `begin`/`end` tokens around
//!   encode/phase_g/step/gather/reduce/ckpt, with explicit parent
//!   nesting, buffered per rank and drained *off* the hot path. The
//!   recorder only reads the clock — telemetry-on runs are
//!   bitwise-identical to telemetry-off (pinned in
//!   `tests/telemetry.rs`).
//! * [`metrics`] — counters / gauges / fixed-bucket histograms that
//!   absorb `CommStats` and `TimeBreakdown` as first-class instruments.
//! * [`sink`] — the JSONL sink behind `--trace-out FILE`: one
//!   schema-versioned event per line, rank-tagged, flushed on snapshot
//!   boundaries and on `RanksLost` so the trail survives a crash; plus
//!   [`sink::Logger`], the `--quiet` / `--log-format text|json` switch
//!   for human progress output.
//! * [`trace`] — the `fastclip trace summary|verify|diff` subcommand:
//!   replays a JSONL file into the Fig.-3-style breakdown, validates
//!   schema / monotonicity / span balance, diffs two runs phase by
//!   phase.
//!
//! Every event line carries `"v": 1` ([`SCHEMA_VERSION`]) and a
//! `"type"` tag; unknown types are a verify error, unknown *fields*
//! are ignored (forward-compatible).

pub mod metrics;
pub mod sink;
pub mod span;
pub mod trace;

pub use metrics::MetricsRegistry;
pub use sink::{Logger, TraceSink};
pub use span::{SpanRecord, SpanRecorder, SpanToken};

/// Version tag stamped on every JSONL event line as `"v"`. Bump on any
/// schema change that a reader must distinguish; `trace verify` rejects
/// files written by a different version.
pub const SCHEMA_VERSION: u32 = 1;
