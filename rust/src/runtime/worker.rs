//! Per-worker PJRT execution: one CPU client + one compiled executable per
//! artifact, with typed wrappers over the three step phases
//! (`encode`, `phase_g`, `step_<variant>`).
//!
//! Everything here is thread-LOCAL (`xla` types are !Send); the coordinator
//! creates one `WorkerRuntime` inside each worker thread.
// Not yet part of the rustdoc-gated public surface (ISSUE 4 scoped the
// doc pass to comm/, ckpt/, kernels/ and the runtime backend); the doc
// lint is opted out here until this module gets its own pass.
#![allow(missing_docs)]

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

// Without the `pjrt` feature the `xla` crate is absent from the build;
// the in-tree stub provides the same API surface (DESIGN.md §8).
#[cfg(not(feature = "pjrt"))]
use super::pjrt_stub as xla;

use super::backend::{ComputeBackend, LossShard, RuntimeTimers, StepOutput, TauGrads, TauInput};
use super::manifest::Manifest;

pub struct WorkerRuntime {
    manifest: Manifest,
    #[allow(dead_code)] // owns the executables' platform; must outlive them
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub timers: RuntimeTimers,
}

impl WorkerRuntime {
    /// Load + compile the artifacts needed to run `variant` steps.
    /// `variant = None` compiles every variant in the bundle (used by the
    /// inspection CLI; training compiles only what it runs).
    pub fn load(manifest: &Manifest, variant: Option<&str>) -> Result<WorkerRuntime> {
        let client = xla::PjRtClient::cpu().map_err(wrap_xla)?;
        let mut names = vec!["encode".to_string(), "phase_g".to_string()];
        match variant {
            Some(v) => {
                ensure!(
                    manifest.variants.iter().any(|x| x == v),
                    "variant '{v}' not in bundle {:?}",
                    manifest.variants
                );
                names.push(format!("step_{v}"));
            }
            None => names.extend(manifest.variants.iter().map(|v| format!("step_{v}"))),
        }
        let mut executables = BTreeMap::new();
        for name in names {
            let path = manifest.hlo_path(&name);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(wrap_xla)
                .with_context(|| format!("loading {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(wrap_xla)
                .with_context(|| format!("compiling {name}"))?;
            executables.insert(name, exe);
        }
        Ok(WorkerRuntime {
            manifest: manifest.clone(),
            client,
            executables,
            timers: RuntimeTimers::default(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Encode the local batch: (params, images, texts) -> (e1, e2), each
    /// (Bl * d) row-major.
    pub fn encode(
        &mut self,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let m = &self.manifest;
        let (bl, d) = (m.local_batch, m.model.d_embed);
        ensure!(params.len() == m.n_params, "params len {}", params.len());
        ensure!(images.len() == bl * m.model.v_patches * m.model.v_patch_dim, "images len");
        ensure!(texts.len() == bl * m.model.t_len, "texts len");

        let t0 = Instant::now();
        let args = [
            lit_f32(params, &[m.n_params])?,
            lit_f32(images, &[bl, m.model.v_patches, m.model.v_patch_dim])?,
            lit_i32(texts, &[bl, m.model.t_len])?,
        ];
        self.timers.io_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let outs = self.run("encode", &args)?;
        self.timers.encode_s += t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let [e1, e2]: [xla::Literal; 2] =
            outs.try_into().map_err(|_| anyhow!("encode returned wrong arity"))?;
        let e1 = to_vec_f32(&e1, bl * d)?;
        let e2 = to_vec_f32(&e2, bl * d)?;
        self.timers.io_s += t2.elapsed().as_secs_f64();
        Ok((e1, e2))
    }

    /// The Eq. (1) inner-estimator update for the local rows:
    /// gathered feats + local u/τ + γ -> (g1, g2, u1_new, u2_new), each Bl.
    #[allow(clippy::too_many_arguments)]
    pub fn phase_g(
        &mut self,
        e1g: &[f32],
        e2g: &[f32],
        offset: usize,
        u1: &[f32],
        u2: &[f32],
        tau1: &[f32],
        tau2: &[f32],
        gamma: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = &self.manifest;
        let (bl, bg, d) = (m.local_batch, m.global_batch, m.model.d_embed);
        ensure!(e1g.len() == bg * d && e2g.len() == bg * d, "gathered feats len");
        ensure!(u1.len() == bl && u2.len() == bl, "u len");
        ensure!(tau1.len() == bl && tau2.len() == bl, "tau len");
        ensure!(offset + bl <= bg, "offset {offset} out of range");

        let t0 = Instant::now();
        let args = [
            lit_f32(e1g, &[bg, d])?,
            lit_f32(e2g, &[bg, d])?,
            xla::Literal::scalar(offset as i32),
            lit_f32(u1, &[bl])?,
            lit_f32(u2, &[bl])?,
            lit_f32(tau1, &[bl])?,
            lit_f32(tau2, &[bl])?,
            xla::Literal::scalar(gamma),
        ];
        self.timers.io_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let outs = self.run("phase_g", &args)?;
        self.timers.phase_g_s += t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let [g1, g2, u1n, u2n]: [xla::Literal; 4] =
            outs.try_into().map_err(|_| anyhow!("phase_g returned wrong arity"))?;
        let out = (
            to_vec_f32(&g1, bl)?,
            to_vec_f32(&g2, bl)?,
            to_vec_f32(&u1n, bl)?,
            to_vec_f32(&u2n, bl)?,
        );
        self.timers.io_s += t2.elapsed().as_secs_f64();
        Ok(out)
    }

    /// One worker's gradient computation for `variant` — the surrogate
    /// gradient of DESIGN.md §4 step 3. All outputs are this worker's
    /// additive contribution; the coordinator SUM-all-reduces them.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        variant: &str,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
        e1g: &[f32],
        e2g: &[f32],
        u1g: &[f32],
        u2g: &[f32],
        offset: usize,
        eps: f32,
        rho: f32,
        tau: TauInput,
    ) -> Result<StepOutput> {
        let m = &self.manifest;
        let (bl, bg, d, p) = (m.local_batch, m.global_batch, m.model.d_embed, m.n_params);
        ensure!(params.len() == p, "params len");
        ensure!(e1g.len() == bg * d && e2g.len() == bg * d, "gathered feats len");
        ensure!(u1g.len() == bg && u2g.len() == bg, "gathered u len");

        let t0 = Instant::now();
        let mut args = vec![
            lit_f32(params, &[p])?,
            lit_f32(images, &[bl, m.model.v_patches, m.model.v_patch_dim])?,
            lit_i32(texts, &[bl, m.model.t_len])?,
            lit_f32(e1g, &[bg, d])?,
            lit_f32(e2g, &[bg, d])?,
            lit_f32(u1g, &[bg])?,
            lit_f32(u2g, &[bg])?,
            xla::Literal::scalar(offset as i32),
            xla::Literal::scalar(eps),
            xla::Literal::scalar(rho),
        ];
        let individual = match &tau {
            TauInput::Global(t) => {
                ensure!(variant != "rgcl_i", "rgcl_i needs TauInput::Individual");
                args.push(xla::Literal::scalar(*t));
                false
            }
            TauInput::Individual { tau1g, tau2g } => {
                ensure!(variant == "rgcl_i", "{variant} takes a global tau");
                ensure!(tau1g.len() == bg && tau2g.len() == bg, "gathered tau len");
                args.push(lit_f32(tau1g, &[bg])?);
                args.push(lit_f32(tau2g, &[bg])?);
                true
            }
        };
        self.timers.io_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let outs = self.run(&format!("step_{variant}"), &args)?;
        self.timers.step_s += t1.elapsed().as_secs_f64();

        let t2 = Instant::now();
        let result = if individual {
            let [grad, loss, t1g, t2g]: [xla::Literal; 4] =
                outs.try_into().map_err(|_| anyhow!("step returned wrong arity"))?;
            StepOutput {
                grad: to_vec_f32(&grad, p)?,
                loss: scalar_f32(&loss)?,
                tau: TauGrads::Individual {
                    tau1: to_vec_f32(&t1g, bl)?,
                    tau2: to_vec_f32(&t2g, bl)?,
                },
            }
        } else {
            let [grad, loss, tg]: [xla::Literal; 3] =
                outs.try_into().map_err(|_| anyhow!("step returned wrong arity"))?;
            StepOutput {
                grad: to_vec_f32(&grad, p)?,
                loss: scalar_f32(&loss)?,
                tau: TauGrads::Global(scalar_f32(&tg)?),
            }
        };
        self.timers.io_s += t2.elapsed().as_secs_f64();
        Ok(result)
    }

    /// Execute one artifact; unwraps the jax `return_tuple=True` 1-tuple.
    fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not loaded"))?;
        let buffers = exe.execute::<xla::Literal>(args).map_err(wrap_xla)?;
        let result = buffers
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{name}: empty execution result"))?
            .to_literal_sync()
            .map_err(wrap_xla)?;
        result.to_tuple().map_err(wrap_xla)
    }
}

/// The PJRT path seen through the backend abstraction: pure delegation to
/// the inherent methods (which keep their concrete signatures for the
/// artifact-gated tests and tools).
impl ComputeBackend for WorkerRuntime {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn backend_id(&self) -> &'static str {
        "pjrt"
    }

    fn timers(&self) -> RuntimeTimers {
        self.timers
    }

    fn encode(
        &mut self,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        WorkerRuntime::encode(self, params, images, texts)
    }

    fn phase_g(
        &mut self,
        e1g: &[f32],
        e2g: &[f32],
        offset: usize,
        u1: &[f32],
        u2: &[f32],
        tau1: &[f32],
        tau2: &[f32],
        gamma: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        WorkerRuntime::phase_g(self, e1g, e2g, offset, u1, u2, tau1, tau2, gamma)
    }

    fn step(
        &mut self,
        variant: &str,
        params: &[f32],
        images: &[f32],
        texts: &[i32],
        e1g: &[f32],
        e2g: &[f32],
        u1g: &[f32],
        u2g: &[f32],
        offset: usize,
        eps: f32,
        rho: f32,
        tau: TauInput,
        shard: LossShard<'_>,
    ) -> Result<StepOutput> {
        // defense in depth behind the trainer's config-time rejection:
        // the AOT-lowered step graphs materialize the full candidate
        // structure and have no exchange hook to hand segments to
        ensure!(
            matches!(shard, LossShard::Off),
            "--loss-shard on is not supported by the pjrt backend: the AOT-lowered \
             HLO step artifacts compute the unsharded loss (use --backend native)"
        );
        WorkerRuntime::step(
            self, variant, params, images, texts, e1g, e2g, u1g, u2g, offset, eps, rho, tau,
        )
    }
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

// Single-copy literal construction: create_from_shape_and_untyped_data
// copies the host slice straight into the shaped literal. (The obvious
// `Literal::vec1(..).reshape(..)` costs a second full copy — measured at
// ~7% of tiny-bundle iteration time by `benches/bench_runtime.rs`.)
fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    ensure!(data.len() == numel, "literal data {} != shape numel {numel}", data.len());
    // SAFETY: `data` is a live, initialized &[f32]; viewing it as bytes is
    // valid for any POD type, the length is exactly data.len() * 4, and the
    // borrow outlives this call (the literal copies out immediately).
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
        .map_err(wrap_xla)
}

fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    ensure!(data.len() == numel, "literal data {} != shape numel {numel}", data.len());
    // SAFETY: same as lit_f32 — POD i32 slice viewed as its own bytes with
    // the exact byte length, copied out before the borrow ends.
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
        .map_err(wrap_xla)
}

fn to_vec_f32(lit: &xla::Literal, expect: usize) -> Result<Vec<f32>> {
    let v = lit.to_vec::<f32>().map_err(wrap_xla)?;
    if v.len() != expect {
        bail!("output length {} != expected {expect}", v.len());
    }
    Ok(v)
}

fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(wrap_xla)?;
    ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUNDLE: &str = "artifacts/tiny_k2_b8";

    fn runtime(variant: Option<&str>) -> Option<WorkerRuntime> {
        if !std::path::Path::new(BUNDLE).join("manifest.json").exists() {
            eprintln!("skipping: {BUNDLE} not built (run `make artifacts`)");
            return None;
        }
        let m = Manifest::load(BUNDLE).unwrap();
        Some(WorkerRuntime::load(&m, variant).unwrap())
    }

    fn demo_inputs(m: &Manifest) -> (Vec<f32>, Vec<f32>, Vec<i32>) {
        let params = m.load_init_params().unwrap();
        let mut rng = crate::util::Rng::new(7);
        let mut images = vec![0.0; m.local_batch * m.model.v_patches * m.model.v_patch_dim];
        rng.fill_normal(&mut images, 1.0);
        let texts: Vec<i32> = (0..m.local_batch * m.model.t_len)
            .map(|_| rng.below(m.model.t_vocab) as i32)
            .collect();
        (params, images, texts)
    }

    #[test]
    #[ignore = "executes HLO artifacts: needs `make artifacts` and a `--features pjrt` build"]
    fn encode_produces_normalized_embeddings() {
        let Some(mut rt) = runtime(Some("gcl")) else { return };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        assert_eq!(e1.len(), m.local_batch * m.model.d_embed);
        for row in e1.chunks(m.model.d_embed).chain(e2.chunks(m.model.d_embed)) {
            let n: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-3, "norm {n}");
        }
        // deterministic
        let (e1b, _) = rt.encode(&params, &images, &texts).unwrap();
        assert_eq!(e1, e1b);
    }

    #[test]
    #[ignore = "executes HLO artifacts: needs `make artifacts` and a `--features pjrt` build"]
    fn phase_g_gamma_one_equals_g() {
        let Some(mut rt) = runtime(Some("gcl")) else { return };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        // duplicate the local block to fake a K=2 gather
        let e1g = [e1.clone(), e1.clone()].concat();
        let e2g = [e2.clone(), e2.clone()].concat();
        let bl = m.local_batch;
        let (u1, u2) = (vec![0.5; bl], vec![0.5; bl]);
        let tau = vec![0.05; bl];
        let (g1, _g2, u1n, u2n) =
            rt.phase_g(&e1g, &e2g, 0, &u1, &u2, &tau, &tau, 1.0).unwrap();
        // gamma = 1: u_new == g
        assert_eq!(g1, u1n[..].to_vec());
        assert!(u2n.iter().all(|v| v.is_finite()));
        assert!(g1.iter().all(|&v| v > 0.0), "exp-sums are positive");
        // gamma = 0.25 mixes old and new
        let (g1b, _, u1b, _) = rt.phase_g(&e1g, &e2g, 0, &u1, &u2, &tau, &tau, 0.25).unwrap();
        for i in 0..bl {
            let want = 0.75 * 0.5 + 0.25 * g1b[i];
            assert!((u1b[i] - want).abs() < 1e-5);
        }
    }

    #[test]
    #[ignore = "executes HLO artifacts: needs `make artifacts` and a `--features pjrt` build"]
    fn step_gcl_runs_and_shapes_match() {
        let Some(mut rt) = runtime(Some("gcl")) else { return };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m);
        let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
        let e1g = [e1.clone(), e1.clone()].concat();
        let e2g = [e2.clone(), e2.clone()].concat();
        let bg = m.global_batch;
        let (u1g, u2g) = (vec![0.8; bg], vec![0.8; bg]);
        let out = rt
            .step("gcl", &params, &images, &texts, &e1g, &e2g, &u1g, &u2g, 0, 1e-14, 0.0,
                  TauInput::Global(0.05))
            .unwrap();
        assert_eq!(out.grad.len(), m.n_params);
        assert!(out.loss.is_finite());
        assert!(matches!(out.tau, TauGrads::Global(g) if g == 0.0), "gcl has no tau grad");
        let gnorm: f32 = out.grad.iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(gnorm > 0.0 && gnorm.is_finite(), "grad norm {gnorm}");
    }

    #[test]
    #[ignore = "executes HLO artifacts: needs `make artifacts` and a `--features pjrt` build"]
    fn step_rejects_wrong_tau_kind() {
        let Some(mut rt) = runtime(Some("gcl")) else { return };
        let m = rt.manifest().clone();
        let (params, images, texts) = demo_inputs(&m);
        let bg = m.global_batch;
        let d = m.model.d_embed;
        let feats = vec![0.1; bg * d];
        let u = vec![0.5; bg];
        let t = vec![0.05; bg];
        let r = rt.step("gcl", &params, &images, &texts, &feats, &feats, &u, &u, 0, 1e-14, 0.0,
                        TauInput::Individual { tau1g: &t, tau2g: &t });
        assert!(r.is_err());
    }

    #[test]
    #[ignore = "executes HLO artifacts: needs `make artifacts` and a `--features pjrt` build"]
    fn load_rejects_unknown_variant() {
        if !std::path::Path::new(BUNDLE).join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(BUNDLE).unwrap();
        assert!(WorkerRuntime::load(&m, Some("not_a_variant")).is_err());
    }
}
