//! Self-check: run the full `fastclip lint` pass over this repository's
//! real tree from inside `cargo test`, with the CI policy
//! (warnings fatal). This is the belt to the CI job's suspenders: the
//! invariants stay enforced by tier-1 even if workflow configuration
//! drifts, and a PR that introduces a violation fails locally before it
//! ever reaches CI.

use std::path::Path;

#[test]
fn repo_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let report =
        fastclip::lint::lint_repo(&root, &fastclip::lint::LintOptions { deny_warnings: true })
            .expect("lint pass runs on the repo tree");
    if report.failed(true) {
        for f in &report.findings {
            eprintln!("{f}");
        }
        panic!(
            "fastclip lint: {} error(s), {} warning(s) on the repo tree (see stderr)",
            report.errors(),
            report.warnings()
        );
    }
    assert!(
        report.files_scanned > 30,
        "implausibly few files scanned ({}) — did the walk break?",
        report.files_scanned
    );
}
