//! Overlapped gradient reduction: the bucketed async pipeline that hides
//! reduction cost behind backward compute (DESIGN.md §11).
//!
//! The serial trainer runs encode → phase_g → step → reduce strictly in
//! sequence, so every reduction microsecond is exposed latency. This
//! module overlaps them: the backward pass emits the flat gradient in
//! ascending segments ([`ComputeBackend::step_emit`]), segments fill
//! size-targeted [`BucketPlan`] buckets, and each completed bucket is
//! handed to a dedicated **reduction worker thread** that runs the
//! configured [`GradientReduction::reduce_bucket`] collective while the
//! compute thread keeps differentiating the remaining parameters. The
//! compute thread only blocks at [`OverlapPipeline::finish`], on whatever
//! buckets are still in flight.
//!
//! # Why a second collective world
//!
//! Collectives are lockstep and share a barrier; if the reduction workers
//! issued bucket collectives on the *training* world they would interleave
//! with the compute threads' feature gathers and deadlock or corrupt the
//! exchange slots. Each rank's reduction worker therefore gets a handle
//! into a **dedicated sibling world** (same K, same shared
//! [`CommStats`](super::CommStats) via
//! [`CommWorld::with_stats`](super::CommWorld::with_stats)): every
//! rank sends buckets in plan order, so the workers stay in lockstep with
//! each other and never touch the training world.
//!
//! # Determinism
//!
//! Pipelining changes *when* reductions happen, never *what* they
//! compute: buckets tile the vector exactly, each bucket is summed in
//! rank order (see [`GradientReduction::reduce_bucket`]), and the
//! optimizer is applied once per iteration over the fully assembled
//! gradient (or shard) — identical numerics, identical optimizer-state
//! layout, identical checkpoints. `rust/tests/native_backend.rs` pins
//! pipelined == serial bitwise for all 5 loss variants × 3 reduction
//! algorithms.
//!
//! That pipelined == serial guarantee holds for the **lossless** wire
//! codecs (`f32`, `bf16`). A lossy codec like `topk` selects per call
//! buffer, so bucketing changes *which* elements travel — pipelined runs
//! are still deterministic for a fixed plan, but are a different (equally
//! valid) compression than the serial whole-vector reduce (DESIGN.md §15).
//!
//! [`ComputeBackend::step_emit`]: crate::runtime::ComputeBackend::step_emit

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use super::bucket::{Bucket, BucketPlan};
use super::codec::ReduceCtx;
use super::collective::{allgather_updated_params, reduction, GradientReduction, ReduceAlgo};
use super::fault::CommError;
use super::world::WorkerComm;

/// Config-facing switch for the overlap pipeline (`--overlap`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapMode {
    /// Always pipeline, even when it cannot help (K = 1, one bucket) —
    /// the degenerate pipeline stays bitwise-correct.
    On,
    /// Strictly serial reduction (the pre-§11 behaviour).
    Off,
    /// Pipeline exactly when it can hide something: more than one rank
    /// AND more than one bucket for the gradient size.
    Auto,
}

impl OverlapMode {
    /// Every mode, for id round-trips.
    pub fn all() -> [OverlapMode; 3] {
        [OverlapMode::On, OverlapMode::Off, OverlapMode::Auto]
    }

    /// CLI/config id: `on` | `off` | `auto`.
    pub fn id(&self) -> &'static str {
        match self {
            OverlapMode::On => "on",
            OverlapMode::Off => "off",
            OverlapMode::Auto => "auto",
        }
    }

    /// Parse a CLI/config id; unknown values are an error listing the
    /// valid choices.
    pub fn from_id(id: &str) -> Result<OverlapMode> {
        for m in OverlapMode::all() {
            if m.id() == id {
                return Ok(m);
            }
        }
        anyhow::bail!("unknown overlap mode '{id}' (expected on|off|auto)")
    }

    /// Resolve the mode for a world of `k` ranks whose gradient splits
    /// into `n_buckets` buckets.
    pub fn enabled(&self, k: usize, n_buckets: usize) -> bool {
        match self {
            OverlapMode::On => true,
            OverlapMode::Off => false,
            OverlapMode::Auto => k > 1 && n_buckets > 1,
        }
    }
}

/// Measured timing of one pipelined iteration, the overlap-accounting
/// input (`hidden = max(0, busy − exposed)`, DESIGN.md §11).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OverlapReport {
    /// Total wall time the reduction worker spent inside bucket
    /// collectives this iteration (includes peer-wait at their barriers).
    pub busy_s: f64,
    /// Wall time the compute thread blocked in
    /// [`OverlapPipeline::finish`] waiting for in-flight buckets.
    pub exposed_s: f64,
}

impl OverlapReport {
    /// Reduction time hidden behind compute: `max(0, busy − exposed)`.
    pub fn hidden_s(&self) -> f64 {
        (self.busy_s - self.exposed_s).max(0.0)
    }
}

struct Job {
    bucket: Bucket,
    data: Vec<f32>,
}

struct Done {
    lo: usize,
    data: Vec<f32>,
    busy_s: f64,
}

/// What the reduction worker sends back per bucket: the reduced segment,
/// or the [`CommError`] that cancelled it (a rank lost mid-bucket —
/// DESIGN.md §13). After an `Err` the worker exits its loop, so the
/// pipeline's `Drop` join never blocks on a cancelled world.
type BucketResult = Result<Done, CommError>;

/// One rank's overlapped-reduction pipeline: a staging buffer fed by the
/// backward pass's segment emissions, a background reduction worker, and
/// the per-iteration finish step that assembles the reduced gradient and
/// applies the optimizer exactly once (see the module docs for the
/// determinism argument).
///
/// Per iteration: [`OverlapPipeline::emit`] for every gradient segment in
/// ascending offset order (typically via
/// [`ComputeBackend::step_emit`](crate::runtime::ComputeBackend::step_emit)),
/// then [`OverlapPipeline::finish`] with the training-world comm handle,
/// the parameters and the optimizer-apply callback.
pub struct OverlapPipeline {
    plan: BucketPlan,
    algo: ReduceAlgo,
    full_len: usize,
    to_worker: Option<Sender<Job>>,
    done_rx: Receiver<BucketResult>,
    worker: Option<JoinHandle<()>>,
    /// staging for emitted local segments; after finish assembles the
    /// replicated reductions it holds the reduced gradient
    staged: Vec<f32>,
    filled: usize,
    next_bucket: usize,
    /// buckets dispatched to the worker and not yet received back
    in_flight: usize,
    /// high-water mark of `in_flight` over the pipeline's lifetime —
    /// the bucket-queue depth telemetry gauge (DESIGN.md §14)
    max_depth: usize,
}

impl OverlapPipeline {
    /// Spawn the reduction worker for one rank. `reduce_comm` must be a
    /// handle into a world **dedicated to bucket reductions** (all ranks'
    /// pipelines, nothing else — see the module docs); `plan`, `algo` and
    /// the wire codec inside `ctx` (DESIGN.md §15) must be identical on
    /// every rank. The [`ReduceCtx`] is owned by the worker thread for
    /// the pipeline's lifetime — for `topk` it carries this rank's
    /// error-feedback residuals, addressed by each bucket's global
    /// offset, so pipelined and serial runs bank leftovers at the same
    /// parameter indices.
    pub fn spawn(
        reduce_comm: WorkerComm,
        algo: ReduceAlgo,
        plan: BucketPlan,
        full_len: usize,
        ctx: ReduceCtx,
    ) -> OverlapPipeline {
        assert_eq!(plan.total_len(), full_len, "plan must tile the gradient");
        let (job_tx, job_rx) = channel::<Job>();
        let (done_tx, done_rx) = channel::<BucketResult>();
        let rank = reduce_comm.rank();
        let worker = std::thread::Builder::new()
            .name(format!("reduce-{rank}"))
            .spawn(move || {
                let reducer: &'static dyn GradientReduction = reduction(algo);
                while let Ok(job) = job_rx.recv() {
                    let t0 = Instant::now();
                    match reducer.reduce_bucket(&reduce_comm, &job.data, job.bucket, full_len, &ctx)
                    {
                        Ok(seg) => {
                            let busy_s = t0.elapsed().as_secs_f64();
                            let done = Done { lo: seg.lo, data: seg.data, busy_s };
                            if done_tx.send(Ok(done)).is_err() {
                                break; // pipeline dropped mid-iteration
                            }
                        }
                        Err(e) => {
                            // the world is cancelled: report once and exit
                            // so Drop's join returns promptly — further
                            // buckets would only error the same way
                            let _ = done_tx.send(Err(e));
                            break;
                        }
                    }
                }
            })
            // lint:allow(err-unwrap): spawn failure is unrecoverable, no error channel
            .expect("spawn reduction worker");
        OverlapPipeline {
            plan,
            algo,
            full_len,
            to_worker: Some(job_tx),
            done_rx,
            worker: Some(worker),
            staged: vec![0.0f32; full_len],
            filled: 0,
            next_bucket: 0,
            in_flight: 0,
            max_depth: 0,
        }
    }

    /// The number of buckets per iteration.
    pub fn n_buckets(&self) -> usize {
        self.plan.len()
    }

    /// High-water mark of the bucket queue: the most reductions that
    /// were ever in flight (dispatched, not yet drained) at once. A
    /// depth that keeps hitting [`Self::n_buckets`] means the worker
    /// never kept up with the backward pass — buckets were all exposed.
    pub fn max_queue_depth(&self) -> usize {
        self.max_depth
    }

    /// Feed one finished gradient segment `[offset, offset + seg.len())`.
    /// Segments must arrive in ascending order and tile `[0, P)` exactly
    /// (the [`step_emit`](crate::runtime::ComputeBackend::step_emit)
    /// contract); every bucket the segment completes is dispatched to the
    /// reduction worker immediately.
    pub fn emit(&mut self, offset: usize, seg: &[f32]) {
        assert_eq!(
            offset, self.filled,
            "gradient segments must be emitted contiguously in ascending order"
        );
        self.staged[offset..offset + seg.len()].copy_from_slice(seg);
        self.filled += seg.len();
        while self.next_bucket < self.plan.len() {
            let b = self.plan.get(self.next_bucket);
            if b.hi > self.filled {
                break;
            }
            let job = Job { bucket: b, data: self.staged[b.lo..b.hi].to_vec() };
            if let Some(tx) = &self.to_worker {
                // a send can only fail if the worker died (panicked
                // collective); surface that in finish(), not here
                let _ = tx.send(job);
            }
            self.next_bucket += 1;
            self.in_flight += 1;
            self.max_depth = self.max_depth.max(self.in_flight);
        }
    }

    /// Wait for the outstanding bucket reductions, assemble the reduced
    /// gradient, apply the optimizer exactly once, and — for the sharded
    /// algorithm — all-gather the updated parameters on the training
    /// world `comm` (charging `param_wire_bytes` once, as the serial
    /// [`ShardedReduceScatter`](super::ShardedReduceScatter) does).
    /// Returns the measured busy/exposed split and resets the pipeline
    /// for the next iteration.
    ///
    /// `Err` is either a caller bug (partial emission) or a cancelled
    /// world — the latter carries a [`CommError`] as the root cause
    /// (downcastable through `anyhow`), `params` is unspecified, and the
    /// pipeline must be dropped: the trainer rolls the iteration back
    /// and rebuilds at K′ (DESIGN.md §13).
    pub fn finish(
        &mut self,
        comm: &WorkerComm,
        params: &mut [f32],
        apply: &mut dyn FnMut(&mut [f32], &[f32]),
    ) -> Result<OverlapReport> {
        ensure!(
            self.filled == self.full_len && self.next_bucket == self.plan.len(),
            "backward emitted {} of {} gradient elements ({} of {} buckets dispatched)",
            self.filled,
            self.full_len,
            self.next_bucket,
            self.plan.len()
        );
        let t0 = Instant::now();
        let mut busy_s = 0.0f64;
        if self.algo == ReduceAlgo::Sharded {
            let (clo, chi) = comm.owned_chunk(self.full_len);
            let mut shard = vec![0.0f32; chi - clo];
            for _ in 0..self.plan.len() {
                let done = self.recv_done()?;
                busy_s += done.busy_s;
                shard[done.lo - clo..done.lo - clo + done.data.len()].copy_from_slice(&done.data);
            }
            let exposed_s = t0.elapsed().as_secs_f64();
            apply(&mut params[clo..chi], &shard);
            allgather_updated_params(comm, params, clo, chi)?;
            self.reset();
            return Ok(OverlapReport { busy_s, exposed_s });
        }
        for _ in 0..self.plan.len() {
            let done = self.recv_done()?;
            busy_s += done.busy_s;
            self.staged[done.lo..done.lo + done.data.len()].copy_from_slice(&done.data);
        }
        let exposed_s = t0.elapsed().as_secs_f64();
        apply(params, &self.staged);
        self.reset();
        Ok(OverlapReport { busy_s, exposed_s })
    }

    fn recv_done(&mut self) -> Result<Done> {
        let res = self
            .done_rx
            .recv()
            .map_err(|_| anyhow!("the bucket-reduction worker thread died mid-iteration"))?;
        self.in_flight = self.in_flight.saturating_sub(1);
        // a CommError from a cancelled bucket propagates with the lost
        // ranks intact (the trainer downcasts it for the shrink decision)
        Ok(res?)
    }

    fn reset(&mut self) {
        self.filled = 0;
        self.next_bucket = 0;
        self.in_flight = 0;
    }
}

impl Drop for OverlapPipeline {
    fn drop(&mut self) {
        // closing the job channel lets the worker's recv() loop end. A
        // worker mid-collective cannot hang the join anymore: its
        // barriers are cancellable, so a dead peer cancels the world,
        // the bucket errors, and the worker exits (DESIGN.md §13)
        self.to_worker = None;
        if let Some(worker) = self.worker.take() {
            let _ = worker.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommStats, CommWorld, WireCodec};
    use std::sync::Arc;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn contribution(rank: usize, iter: usize, n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 31 + rank * 7 + iter * 3) % 113) as f32 * 0.21 - 9.0).collect()
    }

    /// Drive `iters` SGD-style iterations through the pipeline on K ranks
    /// and return every rank's final parameters.
    fn run_pipelined(
        k: usize,
        n: usize,
        algo: ReduceAlgo,
        target: usize,
        iters: usize,
        segments: usize,
        wire: WireCodec,
    ) -> Vec<Vec<f32>> {
        let stats = Arc::new(CommStats::default());
        let train = CommWorld::with_stats(k, Arc::clone(&stats));
        let reduce = CommWorld::with_stats(k, Arc::clone(&stats));
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let comm = train.handle(rank);
                let rcomm = reduce.handle(rank);
                std::thread::spawn(move || {
                    let plan = BucketPlan::new(n, target);
                    let mut pipe =
                        OverlapPipeline::spawn(rcomm, algo, plan, n, ReduceCtx::new(wire));
                    let mut params = vec![1.0f32; n];
                    for it in 0..iters {
                        let grad = contribution(rank, it, n);
                        // emit in `segments` ascending chunks, like a
                        // backward pass finishing leaf by leaf
                        let seg_len = n.div_ceil(segments.max(1)).max(1);
                        let mut off = 0;
                        while off < n {
                            let hi = (off + seg_len).min(n);
                            pipe.emit(off, &grad[off..hi]);
                            off = hi;
                        }
                        let rep = pipe
                            .finish(&comm, &mut params, &mut |p, g| {
                                for (pi, gi) in p.iter_mut().zip(g) {
                                    *pi -= 0.01 * gi;
                                }
                            })
                            .unwrap();
                        assert!(rep.busy_s >= 0.0 && rep.exposed_s >= 0.0);
                    }
                    params
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// Serial reference: the same iterations through reduce_and_apply.
    fn run_serial(
        k: usize,
        n: usize,
        algo: ReduceAlgo,
        iters: usize,
        wire: WireCodec,
    ) -> Vec<Vec<f32>> {
        let world = CommWorld::new(k);
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let comm = world.handle(rank);
                std::thread::spawn(move || {
                    let mut params = vec![1.0f32; n];
                    for it in 0..iters {
                        let mut grad = contribution(rank, it, n);
                        let ctx = ReduceCtx::new(wire);
                        reduction(algo)
                            .reduce_and_apply(&comm, &mut grad, &mut params, &ctx, &mut |p, g| {
                                for (pi, gi) in p.iter_mut().zip(g) {
                                    *pi -= 0.01 * gi;
                                }
                            })
                            .unwrap();
                    }
                    params
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn pipelined_bitwise_equals_serial_every_algo() {
        // lossless codecs only: topk's per-bucket selection is a
        // different (valid) compression than the serial whole-vector
        // reduce, so bitwise equality is not part of its contract
        for wire in [WireCodec::F32, WireCodec::Bf16] {
            for algo in ReduceAlgo::all() {
                for (k, n) in [(1usize, 13usize), (2, 64), (3, 97)] {
                    let serial = run_serial(k, n, algo, 3, wire);
                    for (target, segments) in [(1usize, 1usize), (5, 3), (n + 1, 4), (16, 7)] {
                        let piped = run_pipelined(k, n, algo, target, 3, segments, wire);
                        for rank in 0..k {
                            assert_eq!(
                                bits(&piped[rank]),
                                bits(&serial[rank]),
                                "{} k={k} n={n} target={target} segs={segments} rank={rank} {}",
                                algo.id(),
                                wire.id()
                            );
                        }
                        // every rank replicated, like the serial postcondition
                        assert!(piped.iter().all(|p| p == &piped[0]));
                    }
                }
            }
        }
    }

    #[test]
    fn finish_rejects_partial_emission() {
        let stats = Arc::new(CommStats::default());
        let train = CommWorld::with_stats(1, Arc::clone(&stats));
        let reduce = CommWorld::with_stats(1, stats);
        let mut pipe = OverlapPipeline::spawn(
            reduce.handle(0),
            ReduceAlgo::Naive,
            BucketPlan::new(8, 4),
            8,
            ReduceCtx::f32(),
        );
        pipe.emit(0, &[1.0; 4]);
        let comm = train.handle(0);
        let mut params = vec![0.0f32; 8];
        let err = pipe.finish(&comm, &mut params, &mut |_, _| {}).unwrap_err();
        assert!(format!("{err}").contains("emitted"), "{err}");
        // completing the emission recovers the iteration
        pipe.emit(4, &[2.0; 4]);
        pipe.finish(&comm, &mut params, &mut |p, g| p.copy_from_slice(g)).unwrap();
        assert_eq!(&params[..4], &[1.0; 4]);
        assert_eq!(&params[4..], &[2.0; 4]);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn emit_rejects_out_of_order_segments() {
        let stats = Arc::new(CommStats::default());
        let reduce = CommWorld::with_stats(1, stats);
        let mut pipe = OverlapPipeline::spawn(
            reduce.handle(0),
            ReduceAlgo::Ring,
            BucketPlan::new(8, 4),
            8,
            ReduceCtx::f32(),
        );
        pipe.emit(4, &[1.0; 4]);
    }

    #[test]
    fn overlap_mode_ids_and_resolution() {
        for m in OverlapMode::all() {
            assert_eq!(OverlapMode::from_id(m.id()).unwrap(), m);
        }
        assert!(OverlapMode::from_id("sometimes").is_err());
        assert!(OverlapMode::On.enabled(1, 1));
        assert!(!OverlapMode::Off.enabled(8, 100));
        assert!(OverlapMode::Auto.enabled(2, 2));
        assert!(!OverlapMode::Auto.enabled(1, 100), "K=1 has nothing to reduce");
        assert!(!OverlapMode::Auto.enabled(4, 1), "one bucket hides nothing");
    }

    /// A world cancelled while buckets are in flight surfaces a
    /// [`CommError`] out of `finish` (downcastable through anyhow) on
    /// every surviving rank, and dropping the pipeline does not hang on
    /// the reduction worker.
    #[test]
    fn cancelled_world_errors_finish_and_drop_joins() {
        let k = 3;
        let stats = Arc::new(CommStats::default());
        let train = CommWorld::with_stats(k, Arc::clone(&stats));
        let reduce = CommWorld::with_stats(k, Arc::clone(&stats));
        let token = Arc::clone(reduce.token());
        // ranks 0 and 1 run a full iteration; rank 2 never participates
        // and is declared lost shortly after the buckets go in flight
        let handles: Vec<_> = (0..2)
            .map(|rank| {
                let comm = train.handle(rank);
                let rcomm = reduce.handle(rank);
                std::thread::spawn(move || {
                    let n = 64;
                    let plan = BucketPlan::new(n, 16);
                    let mut pipe =
                        OverlapPipeline::spawn(rcomm, ReduceAlgo::Ring, plan, n, ReduceCtx::f32());
                    let grad = contribution(rank, 0, n);
                    pipe.emit(0, &grad);
                    let mut params = vec![0.0f32; n];
                    pipe.finish(&comm, &mut params, &mut |_, _| {})
                        .expect_err("finish must fail on a cancelled world")
                })
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(20));
        token.declare_lost(2);
        for h in handles {
            let err = h.join().unwrap();
            let comm_err = err
                .root_cause()
                .downcast_ref::<CommError>()
                .expect("root cause must be the CommError");
            assert_eq!(*comm_err, CommError::RanksLost(vec![2]));
        }
    }

    #[test]
    fn report_hidden_clamps_at_zero() {
        let r = OverlapReport { busy_s: 0.5, exposed_s: 0.2 };
        assert!((r.hidden_s() - 0.3).abs() < 1e-12);
        let r = OverlapReport { busy_s: 0.1, exposed_s: 0.4 };
        assert_eq!(r.hidden_s(), 0.0);
    }
}
