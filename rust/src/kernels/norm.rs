//! Row L2-normalization forward/backward, matching the model's
//! `e = p / (‖p‖ + 1e-8)` (the JAX encoder's epsilon-guarded normalize).
//!
//! Same determinism contract as the rest of [`crate::kernels`]: rows are
//! partitioned across threads, per-row reductions are ascending-index,
//! and both kernels are bitwise equal to their scalar references.

use super::par_rows;

/// The epsilon of the encoder's normalization (kept identical to the JAX
/// model so the two backends compute the same function).
pub const NORM_EPS: f32 = 1e-8;

/// Forward: `y_i = x_i / (‖x_i‖ + ε)`; returns the raw norms `‖x_i‖`
/// (the backward pass and callers need them).
pub fn l2_normalize_fwd(x: &[f32], m: usize, d: usize, threads: usize) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(x.len(), m * d);
    let mut y = vec![0.0f32; m * d];
    let mut norms = vec![0.0f32; m];
    par_rows(&mut y, m, d, threads, |lo, hi, chunk| {
        for i in lo..hi {
            let row = &x[i * d..i * d + d];
            let mut sq = 0.0f32;
            for v in row {
                sq += *v * *v;
            }
            let n = sq.sqrt();
            let inv = 1.0 / (n + NORM_EPS);
            let out = &mut chunk[(i - lo) * d..(i - lo + 1) * d];
            for (o, v) in out.iter_mut().zip(row) {
                *o = *v * inv;
            }
        }
    });
    // norms pass (tiny): recompute serially so `par_rows` needs only one
    // mutable target; the reduction order matches the first pass exactly
    for i in 0..m {
        let row = &x[i * d..i * d + d];
        let mut sq = 0.0f32;
        for v in row {
            sq += *v * *v;
        }
        norms[i] = sq.sqrt();
    }
    (y, norms)
}

/// Backward: with `n_i = ‖x_i‖`, `t_i = n_i + ε`,
///
/// ```text
/// dx_i = dy_i / t_i − x_i · (x_i·dy_i) / (max(n_i, tiny) · t_i²)
/// ```
///
/// (the Jacobian of `x/(‖x‖+ε)`; `max(n, tiny)` guards the undefined
/// gradient at exactly x = 0 instead of emitting NaN).
pub fn l2_normalize_bwd(
    x: &[f32],
    norms: &[f32],
    dy: &[f32],
    m: usize,
    d: usize,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * d);
    assert_eq!(dy.len(), m * d);
    assert_eq!(norms.len(), m);
    let mut dx = vec![0.0f32; m * d];
    par_rows(&mut dx, m, d, threads, |lo, hi, chunk| {
        for i in lo..hi {
            let xrow = &x[i * d..i * d + d];
            let dyrow = &dy[i * d..i * d + d];
            let t = norms[i] + NORM_EPS;
            let mut xd = 0.0f32;
            for (xv, dv) in xrow.iter().zip(dyrow) {
                xd += *xv * *dv;
            }
            let c = xd / (norms[i].max(1e-30) * t * t);
            let out = &mut chunk[(i - lo) * d..(i - lo + 1) * d];
            let inv_t = 1.0 / t;
            for ((o, xv), dv) in out.iter_mut().zip(xrow).zip(dyrow) {
                *o = *dv * inv_t - *xv * c;
            }
        }
    });
    dx
}

/// Scalar reference for [`l2_normalize_fwd`].
pub fn l2_normalize_fwd_ref(x: &[f32], m: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0f32; m * d];
    let mut norms = vec![0.0f32; m];
    for i in 0..m {
        let mut sq = 0.0f32;
        for q in 0..d {
            sq += x[i * d + q] * x[i * d + q];
        }
        let n = sq.sqrt();
        norms[i] = n;
        for q in 0..d {
            y[i * d + q] = x[i * d + q] * (1.0 / (n + NORM_EPS));
        }
    }
    (y, norms)
}

/// Scalar reference for [`l2_normalize_bwd`].
pub fn l2_normalize_bwd_ref(x: &[f32], norms: &[f32], dy: &[f32], m: usize, d: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; m * d];
    for i in 0..m {
        let t = norms[i] + NORM_EPS;
        let mut xd = 0.0f32;
        for q in 0..d {
            xd += x[i * d + q] * dy[i * d + q];
        }
        let c = xd / (norms[i].max(1e-30) * t * t);
        for q in 0..d {
            dx[i * d + q] = dy[i * d + q] * (1.0 / t) - x[i * d + q] * c;
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn fwd_bwd_match_ref_bitwise() {
        for (m, d) in [(1usize, 1usize), (5, 7), (8, 64), (11, 33)] {
            let x = randn(m * d, 31);
            let dy = randn(m * d, 32);
            let (y_want, n_want) = l2_normalize_fwd_ref(&x, m, d);
            let dx_want = l2_normalize_bwd_ref(&x, &n_want, &dy, m, d);
            for threads in [1usize, 2, 4] {
                let (y, norms) = l2_normalize_fwd(&x, m, d, threads);
                assert_eq!(bits(&y), bits(&y_want), "y t={threads}");
                assert_eq!(bits(&norms), bits(&n_want), "norms t={threads}");
                let dx = l2_normalize_bwd(&x, &norms, &dy, m, d, threads);
                assert_eq!(bits(&dx), bits(&dx_want), "dx t={threads}");
            }
        }
    }

    #[test]
    fn rows_become_unit_norm() {
        let x = randn(6 * 16, 33);
        let (y, norms) = l2_normalize_fwd(&x, 6, 16, 2);
        for (i, row) in y.chunks(16).enumerate() {
            let n: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "row {i} norm {n}");
            assert!(norms[i] > 0.0);
        }
    }

    #[test]
    fn bwd_matches_finite_difference() {
        let (m, d) = (3usize, 5usize);
        let x = randn(m * d, 34);
        let w = randn(m * d, 35); // cotangent
        let value = |x_: &[f32]| -> f64 {
            let (y, _) = l2_normalize_fwd_ref(x_, m, d);
            y.iter().zip(&w).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let (_, norms) = l2_normalize_fwd_ref(&x, m, d);
        let dx = l2_normalize_bwd_ref(&x, &norms, &w, m, d);
        let h = 1e-3f32;
        for idx in 0..m * d {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[idx] += h;
            xm[idx] -= h;
            let num = (value(&xp) - value(&xm)) / (2.0 * h as f64);
            assert!(
                (num - dx[idx] as f64).abs() < 2e-2 * num.abs().max(1.0),
                "dx[{idx}] {num} vs {}",
                dx[idx]
            );
        }
    }

    #[test]
    fn zero_row_does_not_nan() {
        let x = vec![0.0f32; 4];
        let (y, norms) = l2_normalize_fwd(&x, 1, 4, 1);
        assert!(y.iter().all(|v| v.is_finite()));
        let dx = l2_normalize_bwd(&x, &norms, &[1.0, 1.0, 1.0, 1.0], 1, 4, 1);
        assert!(dx.iter().all(|v| v.is_finite()));
    }
}
