//! Minimal benchmark harness (criterion is not in the vendored crate set):
//! warmup, N timed samples, median/mean/min report. Deterministic sample
//! counts so `cargo bench` output is stable enough to diff between runs.
//!
//! Shared by every bench target via `#[path = "harness.rs"] mod harness;`
//! (not every target uses every helper, hence the allow).
#![allow(dead_code)]

use std::time::Instant;

pub struct Bench {
    name: String,
    samples: usize,
    warmup: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), samples: 30, warmup: 3 }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(3);
        self
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Time `f` and print a one-line report. Returns the stats so callers
    /// can assert relationships (e.g. scaling behaviour).
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            median_s: times[times.len() / 2],
            min_s: times[0],
            max_s: times[times.len() - 1],
        };
        println!(
            "{:<44} median {:>10}  mean {:>10}  min {:>10}  (n={})",
            self.name,
            fmt(stats.median_s),
            fmt(stats.mean_s),
            fmt(stats.min_s),
            self.samples
        );
        stats
    }
}

pub fn fmt(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Prevent the optimizer from eliding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One benchmark result destined for the machine-readable report
/// (`BENCH_*.json`): a name and a higher-is-better rate.
#[derive(Debug, Clone)]
pub struct JsonRow {
    pub name: String,
    /// operations (iterations, kernel calls, …) per second — the metric
    /// the regression gate compares. A non-finite value means "not
    /// measurable" (e.g. a zero-duration quick run): it is written as
    /// JSON `null`, printed as `n/a`, and never gated — NaN/inf must not
    /// reach the document (JSON cannot encode them) or the gate (every
    /// NaN comparison is false, which would silently pass).
    pub rate_per_sec: f64,
    pub median_s: f64,
}

/// Encode a rate for the report: finite numbers as numbers, anything
/// else as an explicit `null` (see [`JsonRow::rate_per_sec`]).
pub fn rate_json(rate: f64) -> fastclip::util::Json {
    if rate.is_finite() {
        fastclip::util::Json::num(rate)
    } else {
        fastclip::util::Json::Null
    }
}

/// Shared tail of every bench binary (the `bench-smoke` CI contract):
///
/// * `--json <path>`      write the rows as `{bench, quick, results: [...]}`
/// * `--baseline <path>`  compare `rate_per_sec` by name against a
///                        previously committed report
/// * `--max-regress <f>`  fail (non-zero exit) when any shared row's rate
///                        drops below `baseline · (1 − f)` (default 0.25)
///
/// Rows present on only one side are reported but never gate — adding or
/// retiring a benchmark must not break CI. Every skipped row is counted
/// and listed at the end, and a baseline whose gateable rows were ALL
/// skipped fails the run: a silent rename (or a bench that stopped
/// measuring anything) must not read as "no regressions".
pub fn finalize_report(
    bench_name: &str,
    quick: bool,
    rows: &[JsonRow],
    args: &fastclip::util::Args,
) -> anyhow::Result<()> {
    use fastclip::util::Json;
    if let Some(path) = args.get("json") {
        let json = Json::obj(vec![
            ("bench", Json::str(bench_name)),
            ("quick", Json::Bool(quick)),
            (
                "results",
                Json::arr(rows.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("rate_per_sec", rate_json(r.rate_per_sec)),
                        ("median_s", rate_json(r.median_s)),
                    ])
                })),
            ),
        ]);
        json.write_file(std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    let Some(baseline_path) = args.get("baseline") else {
        return Ok(());
    };
    let max_regress = args.f64_or("max-regress", 0.25)?;
    let baseline = fastclip::util::Json::parse_file(std::path::Path::new(baseline_path))?;
    let mut regressions = Vec::new();
    let mut skipped: Vec<String> = Vec::new();
    let mut gateable = 0usize;
    let mut compared = 0usize;
    for base_row in baseline.get("results")?.as_arr()? {
        let name = base_row.get("name")?.as_str()?.to_string();
        // a null baseline rate means "was not measurable when committed"
        // — report-only, never gates (and does not count as gateable)
        let base = base_row.get("rate_per_sec")?;
        let base_rate = match base.as_f64() {
            Ok(r) if r.is_finite() => r,
            _ => {
                println!("baseline row '{name}' has no finite rate — skipping");
                continue;
            }
        };
        gateable += 1;
        let Some(cur) = rows.iter().find(|r| r.name == name) else {
            println!("baseline row '{name}' not measured in this run — skipping");
            skipped.push(name);
            continue;
        };
        if !cur.rate_per_sec.is_finite() {
            // NaN < floor is false: without this arm an unmeasurable run
            // would silently pass the gate
            println!(
                "{name:<40} n/a (unmeasurable this run) vs baseline {base_rate:.2}/s — skipping"
            );
            skipped.push(name);
            continue;
        }
        compared += 1;
        let floor = base_rate * (1.0 - max_regress);
        let verdict = if cur.rate_per_sec < floor { "REGRESSED" } else { "ok" };
        println!(
            "{name:<40} {:.2}/s vs baseline {:.2}/s (floor {:.2}/s) {verdict}",
            cur.rate_per_sec, base_rate, floor
        );
        if cur.rate_per_sec < floor {
            regressions.push(name);
        }
    }
    if !skipped.is_empty() {
        println!(
            "gate skipped {}/{gateable} baseline row(s): {}",
            skipped.len(),
            skipped.join(", ")
        );
    }
    anyhow::ensure!(
        gateable == 0 || compared > 0,
        "baseline {baseline_path} has {gateable} gateable row(s) but NONE were compared \
         (all skipped: {}) — the regression gate measured nothing",
        skipped.join(", ")
    );
    anyhow::ensure!(
        regressions.is_empty(),
        "throughput regressed >{:.0}% vs {baseline_path}: {}",
        max_regress * 100.0,
        regressions.join(", ")
    );
    Ok(())
}
