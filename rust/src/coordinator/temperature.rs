//! Temperature update rules — Proc. 5 of the paper.
//!
//! * constant (SogCLR / FastCLIP-v1): τ never changes;
//! * global learnable (OpenCLIP/MBCL grad, FastCLIP-v0 via Eq. 8,
//!   FastCLIP-v3 via Eq. 10): the workers' scalar τ-gradient contributions
//!   are SUM-all-reduced, then a scalar AdamW (λ=0) step is applied
//!   identically on every worker, clamped at τ ≥ τ_min;
//! * individual learnable (iSogCLR / FastCLIP-v2, Eq. 9): stochastic
//!   coordinate Adam updates on the per-sample temperatures held in
//!   [`super::state::IndividualTau`].
//!
//! FastCLIP-v3 additionally decays the τ learning rate to 1/3 of its value
//! once τ drops below a threshold (Appendix B).

use crate::config::TrainConfig;
use crate::optim::ScalarAdam;

use super::state::IndividualTau;

/// A serializable snapshot of a [`GlobalTau`] (checkpoint/resume,
/// DESIGN.md §9): τ itself, the (possibly decayed) learning rate, and the
/// scalar-Adam moments.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GlobalTauState {
    pub tau: f32,
    pub lr: f32,
    pub decayed: bool,
    pub adam_m: f32,
    pub adam_v: f32,
    pub adam_t: i32,
}

/// Global-τ updater owned by each worker (deterministic: every worker
/// applies the same update to its replica).
#[derive(Debug, Clone, Copy)]
pub struct GlobalTau {
    pub tau: f32,
    adam: ScalarAdam,
    lr: f32,
    tau_min: f32,
    /// Some(threshold): decay lr to 1/3 once tau < threshold (v3 rule)
    decay_below: Option<f32>,
    decayed: bool,
}

impl GlobalTau {
    pub fn new(cfg: &TrainConfig) -> Self {
        Self {
            tau: cfg.tau_init,
            adam: ScalarAdam::default(),
            lr: cfg.tau_lr,
            tau_min: cfg.tau_min,
            decay_below: cfg.tau_lr_decay_below,
            decayed: false,
        }
    }

    /// Apply one step given the all-reduced τ-gradient.
    pub fn step(&mut self, grad: f32) {
        self.tau = self.adam.step(self.tau, grad, self.lr).max(self.tau_min);
        if let Some(th) = self.decay_below {
            if !self.decayed && self.tau < th {
                self.lr /= 3.0;
                self.decayed = true;
            }
        }
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Snapshot the full updater state for a checkpoint.
    pub fn export(&self) -> GlobalTauState {
        let (adam_m, adam_v, adam_t) = self.adam.export();
        GlobalTauState {
            tau: self.tau,
            lr: self.lr,
            decayed: self.decayed,
            adam_m,
            adam_v,
            adam_t,
        }
    }

    /// Restore a snapshot taken by [`Self::export`]. `tau_min` and the
    /// decay threshold stay as constructed (run config, not checkpoint).
    pub fn import(&mut self, s: &GlobalTauState) {
        self.tau = s.tau;
        self.lr = s.lr;
        self.decayed = s.decayed;
        self.adam.import(s.adam_m, s.adam_v, s.adam_t);
    }
}

/// The per-worker temperature state for whichever rule the algorithm uses.
pub enum TauState {
    Constant(f32),
    Global(GlobalTau),
    Individual(IndividualTau),
}

impl TauState {
    pub fn new(cfg: &TrainConfig, shard_len: usize) -> Self {
        use crate::config::TempRule;
        match cfg.algorithm.temp_rule() {
            TempRule::Constant => TauState::Constant(cfg.tau_init),
            TempRule::GlobalLearnable => TauState::Global(GlobalTau::new(cfg)),
            TempRule::Individual => {
                TauState::Individual(IndividualTau::new(shard_len, cfg.tau_init, cfg.tau_min))
            }
        }
    }

    /// The scalar τ fed to global-τ step graphs (panics for individual —
    /// those graphs take gathered vectors instead).
    pub fn global_tau(&self) -> f32 {
        match self {
            TauState::Constant(t) => *t,
            TauState::Global(g) => g.tau,
            TauState::Individual(_) => panic!("individual tau has no global value"),
        }
    }

    /// Mean τ for logging.
    pub fn mean_tau(&self) -> f32 {
        match self {
            TauState::Constant(t) => *t,
            TauState::Global(g) => g.tau,
            TauState::Individual(i) => i.mean_tau(),
        }
    }

    /// (τ1, τ2) row vectors for a batch of local positions — what
    /// `phase_g` and the rgcl_i step graph consume.
    pub fn rows(&self, positions: &[usize]) -> (Vec<f32>, Vec<f32>) {
        match self {
            TauState::Constant(t) => {
                (vec![*t; positions.len()], vec![*t; positions.len()])
            }
            TauState::Global(g) => {
                (vec![g.tau; positions.len()], vec![g.tau; positions.len()])
            }
            TauState::Individual(i) => i.gather(positions),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, TrainConfig};

    fn cfg(algo: Algorithm) -> TrainConfig {
        TrainConfig::new("x", algo)
    }

    #[test]
    fn constant_rule_never_moves() {
        let c = cfg(Algorithm::FastClipV1);
        let t = TauState::new(&c, 16);
        assert!(matches!(t, TauState::Constant(v) if (v - c.tau_init).abs() < 1e-9));
    }

    #[test]
    fn global_tau_descends_and_clamps() {
        let mut c = cfg(Algorithm::FastClipV3);
        c.tau_init = 0.07;
        c.tau_lr = 1e-2;
        c.tau_min = 0.01;
        c.tau_lr_decay_below = None;
        let mut g = GlobalTau::new(&c);
        for _ in 0..200 {
            g.step(1.0);
        }
        assert!((g.tau - 0.01).abs() < 1e-6, "clamped, got {}", g.tau);
    }

    #[test]
    fn v3_lr_decays_once_below_threshold() {
        let mut c = cfg(Algorithm::FastClipV3);
        c.tau_init = 0.07;
        c.tau_lr = 9e-3;
        c.tau_min = 0.005;
        c.tau_lr_decay_below = Some(0.03);
        let mut g = GlobalTau::new(&c);
        let lr0 = g.lr();
        while g.tau >= 0.03 {
            g.step(1.0);
        }
        g.step(1.0);
        assert!((g.lr() - lr0 / 3.0).abs() < 1e-9, "decayed once");
        // and it does not decay again
        for _ in 0..100 {
            g.step(1.0);
        }
        assert!((g.lr() - lr0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn global_tau_export_import_resumes_bitwise() {
        let mut c = cfg(Algorithm::FastClipV3);
        c.tau_lr_decay_below = Some(0.05);
        let mut a = GlobalTau::new(&c);
        for t in 0..30 {
            a.step((t as f32 * 0.4).sin() + 0.5);
        }
        let snap = a.export();
        let mut b = GlobalTau::new(&c);
        b.import(&snap);
        for t in 0..50 {
            let g = (t as f32 * 0.9).cos();
            a.step(g);
            b.step(g);
        }
        assert_eq!(a.export(), b.export(), "resume must be bitwise");
        assert_eq!(a.tau, b.tau);
    }

    #[test]
    fn rows_shapes_match_positions() {
        let c = cfg(Algorithm::FastClipV3);
        let t = TauState::new(&c, 8);
        let (r1, r2) = t.rows(&[0, 3, 5]);
        assert_eq!(r1.len(), 3);
        assert_eq!(r1, r2);
        assert!((r1[0] - c.tau_init).abs() < 1e-9);
    }

    #[test]
    fn individual_state_selected_for_v2() {
        let c = cfg(Algorithm::FastClipV2);
        let t = TauState::new(&c, 8);
        assert!(matches!(t, TauState::Individual(_)));
        let c = cfg(Algorithm::ISogClr);
        assert!(matches!(TauState::new(&c, 8), TauState::Individual(_)));
    }
}
