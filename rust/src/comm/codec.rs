//! Pluggable gradient wire codecs (DESIGN.md §15).
//!
//! A [`WireCodec`] decides how a rank's f32 contribution is represented
//! on the wire during a collective, and how many bytes that
//! representation costs. PR 5's `Precision`-typed `_px` collectives
//! hard-wired the two dtype widths into every signature; this layer
//! replaces them with a closed set of codecs (enum-dispatched, like
//! [`Precision`] and [`super::ReduceAlgo`]) so new wire formats plug in
//! without fanning a new parameter through every call site:
//!
//! | codec  | wire representation                  | bytes per element    |
//! |--------|--------------------------------------|----------------------|
//! | `f32`  | identity                             | 4                    |
//! | `bf16` | round-to-nearest-even bf16           | 2                    |
//! | `int8` | blockwise int8, per-block f32 scale  | 1 (scales = framing) |
//! | `topk` | top `1/16` by magnitude, value+index | 8·⌈n/16⌉ total       |
//!
//! The f32 and bf16 codecs reproduce the pre-codec paths bit for bit:
//! `f32` is the identity ([`WireCodec::wire_round`] is a no-op) and
//! `bf16` delegates to the exact [`Precision::quantize`] rounding of
//! DESIGN.md §12. The two lossy codecs trade exactness for bytes:
//!
//! * **`int8`** quantizes each [`INT8_BLOCK`]-element block to signed
//!   8-bit codes against the block's max-|v| scale — a 4× payload cut
//!   against f32. The per-block f32 scale is declared wire *framing*
//!   (like lengths and tags, which no codec charges), so the accounted
//!   payload is exactly 1 byte/element and the 4× invariant is exact —
//!   the CI byte gates depend on that. Non-finite values pass through
//!   verbatim and are excluded from the scale; an all-zero (or
//!   no-finite) block is left untouched.
//! * **`topk`** transmits only the k = ⌈n/[`TOPK_DIVISOR`]⌉ largest
//!   elements by magnitude. A sparse payload must carry indices, so each
//!   selected element costs 8 bytes (4 value + 4 index) — the index
//!   overhead is real and [`WireCodec::encoded_bytes`] charges it, which
//!   is why the `--reduce auto` cost model resolves through the codec
//!   and not a dtype width. Selection is deterministic: strict
//!   [`f32::total_cmp`] ordering on |v| with ties to the lower index
//!   (NaNs sort largest and are transmitted). The dropped mass is not
//!   lost: [`ReduceCtx`] carries a per-rank [`EfState`] error-feedback
//!   residual that is added back into the next contribution before
//!   selection, and the residual rides the checkpoint as its own blob
//!   kind so resume stays bitwise-exact (DESIGN.md §15).
//!
//! Determinism contract: the lossy codecs drop bitwise-equality to the
//! f32 path *and* to each other across algorithm / bucketing / overlap
//! choices, but under a FIXED (codec, algorithm, bucketing, overlap)
//! configuration every run remains bitwise deterministic — run-to-run,
//! across kernel thread counts, and across checkpoint/resume.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::kernels::Precision;

/// Elements per `int8` quantization block: each block of 64 carries its
/// own f32 scale, so one outlier only coarsens 63 neighbours.
pub const INT8_BLOCK: usize = 64;

/// Density divisor of the `topk` codec: k = ⌈n / 16⌉ elements survive
/// selection (¹⁄₁₆ of the gradient, at least one element).
pub const TOPK_DIVISOR: usize = 16;

/// A gradient wire format (see the module docs for the table). Copy and
/// 2 bytes wide, so it travels freely into reduction-worker closures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WireCodec {
    /// Identity: full-width f32 elements, 4 bytes each.
    #[default]
    F32,
    /// Round-to-nearest-even bf16 on both wire legs (DESIGN.md §12),
    /// 2 bytes per element.
    Bf16,
    /// Blockwise signed 8-bit quantization, 1 byte per element (the
    /// per-block scales are framing — see the module docs).
    Int8,
    /// Top-⌈n/16⌉ magnitude sparsification with per-rank error-feedback
    /// residuals; 8 bytes per selected element (value + index).
    TopK,
}

impl WireCodec {
    /// Every codec, in the order tables and sweeps report them.
    pub fn all() -> [WireCodec; 4] {
        [WireCodec::F32, WireCodec::Bf16, WireCodec::Int8, WireCodec::TopK]
    }

    /// Kebab-case id used by the CLI (`--wire`), config files, trace
    /// meta and bench row names.
    pub fn id(&self) -> &'static str {
        match self {
            WireCodec::F32 => "f32",
            WireCodec::Bf16 => "bf16",
            WireCodec::Int8 => "int8",
            WireCodec::TopK => "topk",
        }
    }

    /// Parse a CLI/config id; unknown values are an error listing the
    /// valid choices.
    pub fn from_id(id: &str) -> Result<WireCodec> {
        for c in WireCodec::all() {
            if c.id() == id {
                return Ok(c);
            }
        }
        anyhow::bail!("unknown wire codec '{id}' (expected f32|bf16|int8|topk)")
    }

    /// The codec matching a compute [`Precision`]'s wire behaviour —
    /// what a run uses when `--wire` is not given, which keeps every
    /// pre-codec configuration bitwise unchanged.
    pub fn from_precision(p: Precision) -> WireCodec {
        match p {
            Precision::F32 => WireCodec::F32,
            Precision::Bf16 => WireCodec::Bf16,
        }
    }

    /// Whether the codec loses information (drops bitwise-equality to
    /// the f32 path — see the module-level determinism contract).
    pub fn lossy(&self) -> bool {
        matches!(self, WireCodec::Int8 | WireCodec::TopK)
    }

    /// Exact wire bytes for an `elems`-element payload under this codec
    /// — the ONE place byte accounting knows codec widths. Callers
    /// compute element counts first and encode last, so the truncating
    /// `(K-1)/K`-style divisions round identically for every codec and
    /// the exact-ratio invariants (bf16 = ½, int8 = ¼ of f32) hold.
    pub fn encoded_bytes(&self, elems: u64) -> u64 {
        match self {
            WireCodec::F32 => 4 * elems,
            WireCodec::Bf16 => 2 * elems,
            WireCodec::Int8 => elems,
            WireCodec::TopK => 8 * elems.div_ceil(TOPK_DIVISOR as u64),
        }
    }

    /// The per-leg wire transform, applied in place: what a value looks
    /// like after travelling one wire leg under this codec. `f32` is the
    /// identity; `bf16` is the exact [`Precision::quantize`] rounding
    /// (bitwise-identical to the pre-codec path); `int8` is the
    /// blockwise quantize→dequantize round trip (blocks of
    /// [`INT8_BLOCK`] from the start of `buf`); `topk` is a no-op here —
    /// sparsification happens once per contribution in
    /// [`ReduceCtx::sparsify`], above the collective layer, because it
    /// needs the error-feedback state.
    pub fn wire_round(&self, buf: &mut [f32]) {
        match self {
            WireCodec::F32 | WireCodec::TopK => {}
            WireCodec::Bf16 => Precision::Bf16.quantize(buf),
            WireCodec::Int8 => {
                for block in buf.chunks_mut(INT8_BLOCK) {
                    int8_round_block(block);
                }
            }
        }
    }

    /// [`Self::wire_round`] into a fresh vector.
    pub fn wire_rounded(&self, data: &[f32]) -> Vec<f32> {
        let mut out = data.to_vec();
        self.wire_round(&mut out);
        out
    }
}

/// Quantize→dequantize one block against its max-|v| scale over FINITE
/// values: `code = round(v · 127/scale)` clamped to [−127, 127],
/// `v' = code · scale/127`. Non-finite values pass through verbatim; a
/// block with no finite non-zero value has no scale and is left as-is.
fn int8_round_block(block: &mut [f32]) {
    let mut scale = 0.0f32;
    for &v in block.iter() {
        if v.is_finite() {
            scale = scale.max(v.abs());
        }
    }
    if scale == 0.0 {
        return;
    }
    let enc = 127.0f32 / scale;
    let dec = scale / 127.0f32;
    for v in block.iter_mut() {
        if v.is_finite() {
            let code = (*v * enc).round().clamp(-127.0, 127.0);
            *v = code * dec;
        }
    }
}

/// Zero all but the k = ⌈n/[`TOPK_DIVISOR`]⌉ largest-|v| elements of
/// `acc` (ties to the lower index; NaNs sort largest and survive).
/// When `resid` is given, dropped values move into it VERBATIM and kept
/// positions are zeroed there, so per element exactly one of
/// (transmitted, residual) carries `acc`'s original bits — the exact
/// carry-forward the error-feedback tests pin.
fn topk_split(acc: &mut [f32], mut resid: Option<&mut [f32]>) {
    let n = acc.len();
    if n == 0 {
        return;
    }
    let k = n.div_ceil(TOPK_DIVISOR);
    let mut idx: Vec<u32> = (0..n as u32).collect();
    if k < n {
        // strict total order (total_cmp + index tie-break) makes the
        // selected SET deterministic regardless of partition internals
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            acc[b as usize]
                .abs()
                .total_cmp(&acc[a as usize].abs())
                .then(a.cmp(&b))
        });
    }
    let mut keep = vec![false; n];
    for &i in &idx[..k] {
        keep[i as usize] = true;
    }
    for (i, kept) in keep.iter().enumerate() {
        if *kept {
            if let Some(r) = resid.as_deref_mut() {
                r[i] = 0.0;
            }
        } else {
            if let Some(r) = resid.as_deref_mut() {
                r[i] = acc[i];
            }
            acc[i] = 0.0;
        }
    }
}

/// One rank's error-feedback residual for the `topk` codec: the gradient
/// mass dropped by past selections, full parameter length, added back
/// into the next contribution before selection (momentum-style
/// compensation, after the DisTrO-family trainers). Shared via `Arc`
/// between the serial reducer and the overlap pipeline's reduction
/// worker — only one of them reduces any given iteration, and bucket
/// slices are disjoint, so the mutex is uncontended.
#[derive(Debug)]
pub struct EfState {
    resid: Mutex<Vec<f32>>,
}

impl EfState {
    /// Fresh all-zero residual for an `n`-parameter gradient.
    pub fn new(n: usize) -> EfState {
        EfState { resid: Mutex::new(vec![0.0f32; n]) }
    }

    /// Rebuild from a checkpointed residual blob (bitwise-exact resume).
    pub fn from_residual(resid: Vec<f32>) -> EfState {
        EfState { resid: Mutex::new(resid) }
    }

    /// Snapshot the residual for a checkpoint blob.
    pub fn export(&self) -> Vec<f32> {
        self.resid.lock().unwrap().clone()
    }

    /// Residual length (= the parameter count it was built for).
    pub fn len(&self) -> usize {
        self.resid.lock().unwrap().len()
    }

    /// Whether the residual is zero-length.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Everything a gradient reduction needs beyond the data itself: the
/// wire codec and (for `topk`) the shared error-feedback state. Bundled
/// so future knobs ride along without fanning a new parameter through
/// [`super::GradientReduction`], the overlap pipeline and every test
/// again. Cheap to clone (`Copy` codec + `Arc` residual) and `Send`, so
/// the overlap pipeline moves a clone into its reduction worker.
#[derive(Debug, Clone, Default)]
pub struct ReduceCtx {
    /// The gradient wire codec for this run.
    pub codec: WireCodec,
    /// Per-rank error-feedback residual; `Some` exactly when `codec` is
    /// [`WireCodec::TopK`] in a trainer run. `None` under `topk` means
    /// plain (uncompensated) top-k — used by micro-tests and benches.
    pub ef: Option<Arc<EfState>>,
}

impl ReduceCtx {
    /// The identity context: f32 wire, no residual — the pre-codec
    /// behaviour, and what scalar/bootstrap collectives use.
    pub fn f32() -> ReduceCtx {
        ReduceCtx { codec: WireCodec::F32, ef: None }
    }

    /// A context for `codec` with no error-feedback state.
    pub fn new(codec: WireCodec) -> ReduceCtx {
        ReduceCtx { codec, ef: None }
    }

    /// The trainer's constructor: allocates the error-feedback residual
    /// exactly when the codec needs one (`topk`), sized for an
    /// `n_params`-element gradient.
    pub fn for_run(codec: WireCodec, n_params: usize) -> ReduceCtx {
        let ef = (codec == WireCodec::TopK).then(|| Arc::new(EfState::new(n_params)));
        ReduceCtx { codec, ef }
    }

    /// Apply the codec's pre-collective transform to this rank's
    /// contribution for `[global_lo, global_lo + buf.len())` of the flat
    /// gradient, in place. A no-op for every codec except `topk`, which
    /// adds the error-feedback residual slice back in, keeps the top
    /// ⌈n/16⌉ elements and banks the rest into the residual (see
    /// [`EfState`]). `global_lo` addresses the residual, so bucketed
    /// reductions compensate exactly the elements they transmit.
    pub fn sparsify(&self, buf: &mut [f32], global_lo: usize) {
        if self.codec != WireCodec::TopK {
            return;
        }
        match &self.ef {
            Some(ef) => {
                let mut resid = ef.resid.lock().unwrap();
                let r = &mut resid[global_lo..global_lo + buf.len()];
                for (b, ri) in buf.iter_mut().zip(r.iter()) {
                    *b += *ri;
                }
                topk_split(buf, Some(r));
            }
            None => topk_split(buf, None),
        }
    }

    /// [`Self::sparsify`] without mutating the caller's slice: returns
    /// the transformed copy, or `None` when the codec's transform is a
    /// no-op (everything but `topk`) — so the f32/bf16/int8 hot paths
    /// pay no copy.
    pub fn sparsified(&self, data: &[f32], global_lo: usize) -> Option<Vec<f32>> {
        if self.codec != WireCodec::TopK {
            return None;
        }
        let mut out = data.to_vec();
        self.sparsify(&mut out, global_lo);
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn ids_roundtrip_and_precision_mapping() {
        for c in WireCodec::all() {
            assert_eq!(WireCodec::from_id(c.id()).unwrap(), c);
        }
        assert!(WireCodec::from_id("fp8").is_err());
        assert_eq!(WireCodec::from_precision(Precision::F32), WireCodec::F32);
        assert_eq!(WireCodec::from_precision(Precision::Bf16), WireCodec::Bf16);
        assert_eq!(WireCodec::default(), WireCodec::F32);
        assert!(!WireCodec::F32.lossy() && !WireCodec::Bf16.lossy());
        assert!(WireCodec::Int8.lossy() && WireCodec::TopK.lossy());
    }

    /// Exact byte accounting per codec, including the odd tails the
    /// `(K-1)/K` divisions produce and topk's index overhead.
    #[test]
    fn encoded_bytes_exact() {
        for n in [0u64, 1, 15, 16, 17, 1003, 18_560] {
            assert_eq!(WireCodec::F32.encoded_bytes(n), 4 * n);
            assert_eq!(WireCodec::Bf16.encoded_bytes(n), 2 * n);
            assert_eq!(WireCodec::Int8.encoded_bytes(n), n);
            // int8 is EXACTLY 4x below f32 for every element count —
            // the CI baseline gate depends on this being exact
            assert_eq!(WireCodec::F32.encoded_bytes(n), 4 * WireCodec::Int8.encoded_bytes(n));
            // topk: 8 bytes (value + index) per selected element
            assert_eq!(WireCodec::TopK.encoded_bytes(n), 8 * n.div_ceil(16));
        }
        assert_eq!(WireCodec::TopK.encoded_bytes(17), 16, "17 elems -> k=2 -> 16 B");
    }

    /// f32 is the identity and bf16 delegates to the exact Precision
    /// rounding — the bitwise bridge to the pre-codec paths.
    #[test]
    fn f32_identity_bf16_matches_precision() {
        let xs: Vec<f32> = (0..257).map(|i| 0.1 + i as f32 * 1.017).collect();
        assert_eq!(bits(&WireCodec::F32.wire_rounded(&xs)), bits(&xs));
        assert_eq!(
            bits(&WireCodec::Bf16.wire_rounded(&xs)),
            bits(&Precision::Bf16.quantized(&xs))
        );
        // topk's wire_round is a no-op: sparsification happens in
        // ReduceCtx::sparsify, above the collective layer
        assert_eq!(bits(&WireCodec::TopK.wire_rounded(&xs)), bits(&xs));
    }

    /// int8 round trip: every finite value lands within half a code
    /// step (scale/254) of its input, blocks are independent, and the
    /// max-|v| element of each block is reproduced to a code step.
    #[test]
    fn int8_roundtrip_error_bound() {
        // 2.5 blocks: exercises the odd 32-element tail block
        let xs: Vec<f32> = (0..160).map(|i| (i as f32 * 0.73 - 37.0) * 1.3).collect();
        let q = WireCodec::Int8.wire_rounded(&xs);
        for (b, (orig, got)) in xs.chunks(INT8_BLOCK).zip(q.chunks(INT8_BLOCK)).enumerate() {
            let scale = orig.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            for (i, (o, g)) in orig.iter().zip(got).enumerate() {
                assert!(
                    (o - g).abs() <= scale / 254.0 + scale * 1e-5,
                    "block {b} elem {i}: {o} -> {g} (scale {scale})"
                );
            }
        }
        // a value only ~1/127 of its block max still quantizes to a
        // nonzero code; values below half a code step collapse to zero
        let mut small = vec![0.0f32; INT8_BLOCK];
        small[0] = 127.0;
        small[1] = 1.0; // exactly one code step
        small[2] = 0.4; // under half a step
        let q = WireCodec::Int8.wire_rounded(&small);
        assert_eq!(q[0], 127.0);
        assert_eq!(q[1], 1.0);
        assert_eq!(q[2], 0.0);
    }

    /// int8 edge policy: all-zero blocks pass through, non-finite values
    /// pass through verbatim and do not poison the block's scale.
    #[test]
    fn int8_edge_blocks() {
        // reference transform, mirrored from int8_round_block
        let step = |v: f32, scale: f32| -> f32 {
            (v * (127.0 / scale)).round().clamp(-127.0, 127.0) * (scale / 127.0)
        };

        // all-zero block is untouched (no 0/0 scale)
        let zeros = vec![0.0f32; INT8_BLOCK];
        assert_eq!(bits(&WireCodec::Int8.wire_rounded(&zeros)), bits(&zeros));

        // non-finite values are excluded from the scale and forwarded
        // verbatim; their finite neighbours quantize against max|finite|
        let mut xs = vec![0.5f32; INT8_BLOCK];
        xs[3] = f32::INFINITY;
        xs[7] = f32::NEG_INFINITY;
        xs[11] = f32::NAN;
        xs[20] = 2.0; // the block scale
        let q = WireCodec::Int8.wire_rounded(&xs);
        assert_eq!(q[3], f32::INFINITY);
        assert_eq!(q[4].to_bits(), step(0.5, 2.0).to_bits(), "finite path vs max|finite| scale");
        assert_eq!(q[7], f32::NEG_INFINITY);
        assert!(q[11].is_nan());
        assert_eq!(q[20].to_bits(), step(2.0, 2.0).to_bits());

        // a block that is ONLY non-finite has no scale: verbatim
        let inf = vec![f32::INFINITY; 5];
        assert_eq!(WireCodec::Int8.wire_rounded(&inf), inf);

        // blocks are independent: a huge value in block 0 must not
        // coarsen block 1
        let mut two = vec![0.01f32; 2 * INT8_BLOCK];
        two[0] = 1e6;
        let q = WireCodec::Int8.wire_rounded(&two);
        assert_eq!(q[INT8_BLOCK].to_bits(), step(0.01, 0.01).to_bits());
        assert!(q[1] == 0.0, "0.01 is far below 1e6's half code step");
        assert_eq!(q[0].to_bits(), step(1e6, 1e6).to_bits());
    }

    /// topk selection: exactly ⌈n/16⌉ survivors, by magnitude, ties to
    /// the lower index, NaNs transmitted — all deterministic.
    #[test]
    fn topk_selection_deterministic() {
        // 33 elements -> k = 3
        let mut xs: Vec<f32> = (0..33).map(|i| ((i * 7) % 13) as f32 - 6.0).collect();
        let ctx = ReduceCtx::new(WireCodec::TopK);
        ctx.sparsify(&mut xs, 0);
        let kept: Vec<usize> =
            xs.iter().enumerate().filter(|(_, v)| **v != 0.0).map(|(i, _)| i).collect();
        assert_eq!(kept.len(), 3);
        // |v| = 6 occurs at multiple indices (values ±6): the lower
        // indices win the tie deterministically
        let mut mags: Vec<(u32, usize)> = (0..33)
            .map(|i| ((((i * 7) % 13) as f32 - 6.0f32).abs().to_bits(), i))
            .collect();
        mags.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        let expect: Vec<usize> = {
            let mut e: Vec<usize> = mags[..3].iter().map(|&(_, i)| i).collect();
            e.sort_unstable();
            e
        };
        assert_eq!(kept, expect);

        // NaN sorts above everything under total_cmp on |v|
        let mut ys = vec![1.0f32, f32::NAN, 3.0, -9.0, 2.0, 0.5, 0.25, 0.125];
        ctx.sparsify(&mut ys, 0); // 8 elements -> k = ceil(8/16) = 1
        let survivors: Vec<usize> =
            ys.iter().enumerate().filter(|(_, v)| **v != 0.0).map(|(i, _)| i).collect();
        assert_eq!(survivors, vec![1], "the NaN is the one transmitted element");

        // short vectors keep at least one element
        let mut one = vec![0.25f32];
        ctx.sparsify(&mut one, 0);
        assert_eq!(one, vec![0.25]);
    }

    /// Error feedback: per element exactly one of (transmitted,
    /// residual) carries the accumulated value's exact bits, and the
    /// banked mass re-enters the next round's selection.
    #[test]
    fn topk_error_feedback_carry_is_exact() {
        let n = 48; // k = 3
        let ef = Arc::new(EfState::new(n));
        let ctx = ReduceCtx { codec: WireCodec::TopK, ef: Some(Arc::clone(&ef)) };

        let g1: Vec<f32> = (0..n).map(|i| ((i * 11) % 17) as f32 * 0.37 - 2.9).collect();
        let mut t1 = g1.clone();
        ctx.sparsify(&mut t1, 0);
        let r1 = ef.export();
        for i in 0..n {
            // acc == g1 here (residual started at zero)
            let (t, r, a) = (t1[i].to_bits(), r1[i].to_bits(), g1[i].to_bits());
            assert!(
                (t == a && r == 0.0f32.to_bits()) || (t == 0.0f32.to_bits() && r == a),
                "elem {i}: transmitted {t:08x} residual {r:08x} acc {a:08x}"
            );
        }
        assert_eq!(t1.iter().filter(|v| **v != 0.0).count(), 3);

        // round 2: the residual is added back before selection
        let g2: Vec<f32> = (0..n).map(|i| ((i * 5) % 23) as f32 * 0.21 - 2.1).collect();
        let acc: Vec<f32> = g2.iter().zip(&r1).map(|(g, r)| g + r).collect();
        let mut t2 = g2.clone();
        ctx.sparsify(&mut t2, 0);
        let r2 = ef.export();
        for i in 0..n {
            let (t, r, a) = (t2[i].to_bits(), r2[i].to_bits(), acc[i].to_bits());
            assert!(
                (t == a && r == 0.0f32.to_bits()) || (t == 0.0f32.to_bits() && r == a),
                "round 2 elem {i}"
            );
        }
    }

    /// Bucketed sparsification addresses the residual by global offset:
    /// compensating `[lo, hi)` touches exactly that residual slice.
    #[test]
    fn topk_residual_addressed_by_global_offset() {
        let ef = Arc::new(EfState::new(64));
        let ctx = ReduceCtx { codec: WireCodec::TopK, ef: Some(Arc::clone(&ef)) };
        let mut bucket: Vec<f32> = (0..32).map(|i| i as f32 + 1.0).collect();
        ctx.sparsify(&mut bucket, 16); // covers global [16, 48)
        let r = ef.export();
        assert!(r[..16].iter().all(|v| *v == 0.0), "below the bucket: untouched");
        assert!(r[48..].iter().all(|v| *v == 0.0), "above the bucket: untouched");
        // k = 2 of 32 kept -> 30 residual entries banked inside [16,48)
        assert_eq!(r[16..48].iter().filter(|v| **v != 0.0).count(), 30);
        assert_eq!(bucket.iter().filter(|v| **v != 0.0).count(), 2);
    }

    /// The non-sparsifying codecs are exempt from the copy: sparsified
    /// returns None and sparsify leaves the buffer untouched.
    #[test]
    fn non_topk_codecs_skip_sparsify() {
        let xs: Vec<f32> = (0..40).map(|i| i as f32 * 0.3).collect();
        for codec in [WireCodec::F32, WireCodec::Bf16, WireCodec::Int8] {
            let ctx = ReduceCtx::new(codec);
            assert!(ctx.sparsified(&xs, 0).is_none(), "{}", codec.id());
            let mut ys = xs.clone();
            ctx.sparsify(&mut ys, 0);
            assert_eq!(bits(&ys), bits(&xs), "{}", codec.id());
        }
        let ctx = ReduceCtx::f32();
        assert_eq!(ctx.codec, WireCodec::F32);
        assert!(ctx.ef.is_none());
        // for_run allocates the residual only for topk
        assert!(ReduceCtx::for_run(WireCodec::Int8, 10).ef.is_none());
        let t = ReduceCtx::for_run(WireCodec::TopK, 10);
        assert_eq!(t.ef.as_ref().unwrap().len(), 10);
        assert!(!t.ef.unwrap().is_empty());
    }

    /// EfState checkpoint round trip is bitwise.
    #[test]
    fn ef_state_export_import_roundtrip() {
        let vals: Vec<f32> = (0..9).map(|i| i as f32 * 0.7 - 3.0).collect();
        let ef = EfState::from_residual(vals.clone());
        assert_eq!(bits(&ef.export()), bits(&vals));
        assert_eq!(ef.len(), 9);
    }
}
