//! File-scoped rules: determinism hazards, concurrency audit, error
//! hygiene. Each rule matches needles against the string-blanked `code`
//! view (see [`super::source`]) so rule needles spelled in string
//! literals — including this module's own — can never self-flag.

use super::source::{find_all, find_word, SourceFile};
use super::{Finding, Severity};

/// Paths (prefix match on the repo-relative path) where wall-clock reads
/// are the point: telemetry spans, bench timing, comm cost accounting and
/// the trainer/backends that feed them. Everything else in `rust/src`
/// must not read the clock — determinism hazards hide behind "just
/// timing" code that later leaks into control flow.
const WALLCLOCK_ALLOW: &[&str] = &[
    "rust/src/telemetry/",
    "rust/src/bench/",
    "rust/src/comm/",
    "rust/src/coordinator/trainer.rs",
    "rust/src/runtime/native.rs",
    "rust/src/runtime/worker.rs",
];

/// Numeric subsystems where every float reduction must go through the
/// fixed ascending-index helpers (`kernels::gemm::dot`, `kernels::sum`).
const REDUCTION_SCOPE: &[&str] = &["rust/src/kernels/", "rust/src/comm/", "rust/src/runtime/"];

fn in_scope(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

fn is_library(sf: &SourceFile, idx: usize) -> bool {
    sf.rel.starts_with("rust/src/") && !sf.in_test[idx]
}

/// Markers that exempt an `unwrap()` from `err-unwrap` when they appear
/// just before it (same line, or the previous non-blank code line for
/// rustfmt-wrapped chains): poisoned-lock and joined-thread unwraps are
/// the idiomatic propagation of a panic that already happened elsewhere,
/// and condvar waits return the guard through `Result` by API shape.
const UNWRAP_IDIOMS: &[&str] =
    &[".lock()", ".join()", ".read()", ".write()", ".wait(", ".wait_timeout(", ".recv_timeout("];

fn unwrap_idiom_before(sf: &SourceFile, idx: usize, col: usize) -> bool {
    let mut window = String::new();
    let mut j = idx;
    while j > 0 {
        j -= 1;
        if !sf.code[j].trim().is_empty() {
            window.push_str(&sf.code[j]);
            break;
        }
    }
    window.push_str(&sf.code[idx][..col]);
    UNWRAP_IDIOMS.iter().any(|m| window.contains(m))
}

/// Run all file-scoped rules on one source file.
pub fn check_file(sf: &SourceFile, findings: &mut Vec<Finding>) {
    let mut push = |rule: &'static str, line: usize, message: String| {
        findings.push(Finding {
            rule,
            severity: Severity::Error,
            file: sf.rel.clone(),
            line,
            message,
        });
    };

    for idx in 0..sf.raw.len() {
        let code = &sf.code[idx];

        // ---- determinism hazards (library code only) --------------------
        if is_library(sf, idx) {
            for needle in ["HashMap", "HashSet"] {
                if find_word(code, needle).is_some() {
                    push(
                        "det-unordered-map",
                        idx + 1,
                        format!("{needle}: nondeterministic iteration order; use a BTree map/set"),
                    );
                }
            }
            if !in_scope(&sf.rel, WALLCLOCK_ALLOW) {
                for needle in ["Instant::now", "SystemTime"] {
                    if code.contains(needle) {
                        push(
                            "det-wallclock",
                            idx + 1,
                            format!("{needle} outside the telemetry/timing allowlist"),
                        );
                    }
                }
            }
            for needle in ["thread_rng", "from_entropy", "rand::random", "env::var", "var_os"] {
                if code.contains(needle) {
                    push(
                        "det-ambient-entropy",
                        idx + 1,
                        format!("{needle}: ambient entropy/environment read in library code"),
                    );
                }
            }
            if in_scope(&sf.rel, REDUCTION_SCOPE) {
                for needle in [".sum::<f32>", ".sum::<f64>", ".product::<f32>", ".product::<f64>"]
                {
                    if code.contains(needle) {
                        push(
                            "det-raw-reduction",
                            idx + 1,
                            format!("{needle}: route float reductions through kernels::sum"),
                        );
                    }
                }
            }
            if sf.rel.starts_with("rust/src/kernels/")
                && !sf.rel.ends_with("kernels/mod.rs")
                && code.contains("spawn(")
            {
                push(
                    "det-raw-reduction",
                    idx + 1,
                    "thread spawn in a kernel outside par_rows: reduction order must stay fixed"
                        .into(),
                );
            }
        }

        // ---- concurrency audit ------------------------------------------
        if sf.rel.starts_with("rust/src/comm/") && code.contains("Ordering::Relaxed") {
            push(
                "con-relaxed-atomic",
                idx + 1,
                "Ordering::Relaxed in comm/: risks torn snapshots; use SeqCst or a Mutex".into(),
            );
        }
        if find_word(code, "unsafe").is_some() {
            let lo = idx.saturating_sub(3);
            let documented = sf.raw[lo..=idx].iter().any(|l| l.contains("SAFETY:"));
            if !documented {
                push(
                    "con-undocumented-unsafe",
                    idx + 1,
                    "unsafe without a // SAFETY: comment within the 3 lines above".into(),
                );
            }
        }

        // ---- error hygiene ----------------------------------------------
        if is_library(sf, idx) {
            for col in find_all(code, ".unwrap()") {
                if !unwrap_idiom_before(sf, idx, col) {
                    push(
                        "err-unwrap",
                        idx + 1,
                        "unwrap() in library code: propagate with ? / context".into(),
                    );
                }
            }
            for col in find_all(code, ".expect(\"") {
                if !unwrap_idiom_before(sf, idx, col) {
                    push(
                        "err-unwrap",
                        idx + 1,
                        "expect(\"…\") in library code: propagate with ? / context".into(),
                    );
                }
            }
        }
    }

    check_lock_order(sf, findings);
}

/// `con-lock-order`: within one `comm/` file, two named locks acquired in
/// opposite orders in different functions is the classic AB-BA deadlock
/// shape. Lock names are the last field segment of the receiver of a
/// `.lock()` call (`self.slots[i].lock()` → `slots`); acquisition order
/// is tracked per function, first-acquisition only.
fn check_lock_order(sf: &SourceFile, findings: &mut Vec<Finding>) {
    if !sf.rel.starts_with("rust/src/comm/") {
        return;
    }
    // (first, second) -> (fn name, line of second acquisition)
    let mut edges: Vec<((String, String), (String, usize))> = Vec::new();
    let mut cur_fn: Option<String> = None;
    let mut held: Vec<String> = Vec::new();
    for idx in 0..sf.code.len() {
        if sf.in_test[idx] {
            continue;
        }
        let code = &sf.code[idx];
        if let Some(at) = find_word(code, "fn") {
            let name: String = code[at + 2..]
                .trim_start()
                .chars()
                .take_while(|c| super::source::is_ident(*c))
                .collect();
            if !name.is_empty() {
                cur_fn = Some(name);
                held.clear();
            }
        }
        if cur_fn.is_none() {
            continue;
        }
        for at in find_all(code, ".lock()") {
            let recv: String = code[..at]
                .chars()
                .rev()
                .take_while(|c| super::source::is_ident(*c) || *c == '.' || *c == '[' || *c == ']')
                .collect::<String>()
                .chars()
                .rev()
                .collect();
            let mut base = String::new();
            let mut bracket = 0u32;
            for c in recv.chars() {
                match c {
                    '[' => bracket += 1,
                    ']' => bracket = bracket.saturating_sub(1),
                    '.' if bracket == 0 => base.clear(),
                    c if bracket == 0 => base.push(c),
                    _ => {}
                }
            }
            if base.is_empty() {
                continue;
            }
            for prev in &held {
                if prev != &base
                    && !edges.iter().any(|(k, _)| k.0 == *prev && k.1 == base)
                {
                    edges.push((
                        (prev.clone(), base.clone()),
                        (cur_fn.clone().unwrap_or_default(), idx + 1),
                    ));
                }
            }
            if !held.contains(&base) {
                held.push(base);
            }
        }
    }
    for ((a, b), (fa, la)) in &edges {
        if a >= b {
            continue;
        }
        let Some((_, (fb, lb))) = edges.iter().find(|(k, _)| k.0 == *b && k.1 == *a) else {
            continue;
        };
        findings.push(Finding {
            rule: "con-lock-order",
            severity: Severity::Error,
            file: sf.rel.clone(),
            line: *la,
            message: format!(
                "inconsistent lock order: {fa} acquires '{a}' then '{b}' (line {la}), \
                 but {fb} acquires '{b}' then '{a}' (line {lb})"
            ),
        });
    }
}
