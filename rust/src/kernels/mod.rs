//! Native CPU compute kernels (DESIGN.md §10).
//!
//! Pure-Rust, multithreaded, SIMD-friendly f32 kernels backing the
//! [`NativeBackend`](crate::runtime::NativeBackend): blocked GEMM, the
//! fused masked-exp row-sum at the heart of every contrastive loss in the
//! paper (forward AND backward, mirroring the Pallas kernel structure of
//! `python/compile/kernels/contrastive.py`: tiled similarity, epilogue
//! fused into the matmul, probabilities recomputed in the backward), row
//! softmax/logsumexp, row L2-normalization, and the embedding-table
//! encoder forward/backward.
//!
//! # Determinism contract
//!
//! Every kernel is **bitwise deterministic regardless of thread count**:
//! parallelism only ever partitions *output* elements across threads, and
//! the summation tree behind each output element is a fixed-order
//! sequential reduction (ascending index; the interconnect/kernel cost
//! model this feeds is DESIGN.md §7). Blocking changes the *visit*
//! order for cache locality, never the per-element *accumulation* order.
//! Consequently every kernel agrees to exact bit equality with its naive
//! single-threaded scalar reference (`*_ref`), which uses the same
//! left-to-right summation tree — the parity suite in
//! `tests/native_backend.rs` pins this for odd shapes, non-divisible
//! blocks, and 1/2/4 threads.

pub mod encoder;
pub mod gemm;
pub mod norm;
pub mod precision;
pub mod softmax;

pub use precision::Precision;

/// Resolve a requested kernel thread count: 0 means "auto" (the machine's
/// available parallelism, capped at 8 — these are latency-bound tiles,
/// not throughput farms). Any explicit value is used as given.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get().min(8)).unwrap_or(1)
}

/// Split `n` items into at most `parts` contiguous ranges of
/// near-equal length (the first `n % parts` ranges are one longer).
/// Empty ranges are omitted, so the result is also the task list.
pub(crate) fn split_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        if len == 0 {
            break;
        }
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Run `f(lo, hi, chunk)` over row-partitioned disjoint chunks of `out`
/// (rows of width `row_len`), one scoped thread per chunk. The chunk
/// passed to `f` covers rows `[lo, hi)`. With one range the call is
/// inlined on the current thread (no spawn).
pub(crate) fn par_rows<F>(out: &mut [f32], rows: usize, row_len: usize, threads: usize, f: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    let ranges = split_ranges(rows, threads);
    if ranges.len() <= 1 {
        if let Some(&(lo, hi)) = ranges.first() {
            f(lo, hi, out);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = out;
        let mut handles = Vec::with_capacity(ranges.len());
        for &(lo, hi) in &ranges {
            // `rest` always starts at row `lo`; peel off this chunk
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * row_len);
            rest = tail;
            let fref = &f;
            handles.push(s.spawn(move || fref(lo, hi, chunk)));
        }
        for h in handles {
            h.join().expect("kernel worker panicked");
        }
    });
}

/// Sequential ascending-index sum — the 1-D companion of
/// [`gemm::dot`], and the only reduction shape library code may use on
/// float slices (bit-identical to `iter().sum::<f32>()`, spelled as a
/// named primitive so the `det-raw-reduction` lint can pin every numeric
/// path to the fixed left-to-right tree).
#[inline]
pub fn sum(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ranges_covers_everything() {
        for n in [0usize, 1, 2, 5, 7, 16, 103] {
            for parts in [1usize, 2, 3, 4, 8] {
                let r = split_ranges(n, parts);
                let total: usize = r.iter().map(|(lo, hi)| hi - lo).sum();
                assert_eq!(total, n, "n={n} parts={parts}");
                let mut expect = 0;
                for &(lo, hi) in &r {
                    assert_eq!(lo, expect);
                    assert!(hi > lo, "no empty ranges");
                    expect = hi;
                }
                assert!(r.len() <= parts.max(1));
            }
        }
    }

    #[test]
    fn par_rows_partitions_disjointly() {
        for threads in [1usize, 2, 3, 4] {
            let rows = 7;
            let d = 3;
            let mut out = vec![0.0f32; rows * d];
            par_rows(&mut out, rows, d, threads, |lo, hi, chunk| {
                assert_eq!(chunk.len(), (hi - lo) * d);
                for (r, row) in chunk.chunks_mut(d).enumerate() {
                    for v in row.iter_mut() {
                        *v += (lo + r) as f32;
                    }
                }
            });
            for r in 0..rows {
                for c in 0..d {
                    assert_eq!(out[r * d + c], r as f32, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn resolve_threads_auto_positive() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
