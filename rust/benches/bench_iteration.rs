//! End-to-end iteration benchmark — one bench per paper timing table:
//! full distributed iterations (encode → gathers → phase_g → step →
//! all-reduce → optimizer) per algorithm, reporting the same
//! compute / pure-comm / overlap / others split as Fig. 3.

#[path = "harness.rs"]
mod harness;

use fastclip::config::{Algorithm, TrainConfig};
use fastclip::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let bundle = "artifacts/tiny_k2_b8";
    if !std::path::Path::new(bundle).join("manifest.json").exists() {
        eprintln!("bundle {bundle} not built — run `make artifacts`");
        return Ok(());
    }
    println!("end-to-end iterations on {bundle} (16 steps each, modeled 8x4 infiniband)\n");
    println!(
        "{:<14} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "algorithm", "total", "compute", "pure", "overlap", "others"
    );
    for algo in Algorithm::all() {
        let mut cfg = TrainConfig::new(bundle, algo);
        cfg.steps = 16;
        cfg.iters_per_epoch = 8;
        cfg.data.n_train = 256;
        cfg.data.n_eval = 32;
        cfg.lr.total_iters = 16;
        cfg.lr.warmup_iters = 2;
        cfg.nodes = 8;
        cfg.gpus_per_node = 4;
        let r = Trainer::new(cfg)?.run()?;
        let ms = r.timing.per_iter_ms();
        println!(
            "{:<14} {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>7.2}ms {:>7.2}ms",
            algo.name(),
            ms.total,
            ms.compute,
            ms.comm_pure,
            ms.comm_overlap,
            ms.others
        );
    }
    Ok(())
}
