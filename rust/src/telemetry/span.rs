//! Per-rank span recording: timed, nested phases of a training
//! iteration, buffered off the hot path (DESIGN.md §14).
//!
//! A [`SpanRecorder`] lives on ONE worker thread (no locks, no
//! sharing); [`SpanRecorder::begin`] stamps the clock and pushes an open
//! record, [`SpanRecorder::end`] closes it, and the trainer drains the
//! buffer into the JSONL sink *after* the iteration's timing
//! bookkeeping — never between compute and communication. A disabled
//! recorder (`--trace-out` absent) never reads the clock at all, so the
//! only difference between telemetry-on and telemetry-off is wall time
//! spent in `Instant::now`, which no numeric path observes.

use std::time::Instant;

/// One closed span: a named, timed phase of one iteration on one rank.
///
/// `parent` is an index into the recorder's buffer (the enclosing span
/// that was open at `begin` time), resolved to the parent's *name* when
/// the record is serialized. Parents always appear before their
/// children in the drained buffer because `begin` pushes in call order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    /// Phase name: the per-iteration root `"iter"`, its children
    /// `"encode"`, `"gather"`, `"phase_g"`, `"step"`, `"reduce"`, and
    /// the top-level `"ckpt"` / `"eval"` phases.
    pub name: &'static str,
    /// Training iteration the span belongs to.
    pub iter: u32,
    /// Start, µs since the recorder's epoch.
    pub start_us: u64,
    /// End, µs since the recorder's epoch (`>= start_us`).
    pub end_us: u64,
    /// Buffer index of the enclosing span, if any.
    pub parent: Option<usize>,
}

/// Token returned by [`SpanRecorder::begin`], consumed by
/// [`SpanRecorder::end`]. Spans must close in LIFO order (enforced by
/// a debug assertion); the token of a disabled recorder is a sentinel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanToken(usize);

const DISABLED: usize = usize::MAX;

/// Single-thread span recorder for one rank (see module docs).
#[derive(Debug)]
pub struct SpanRecorder {
    rank: usize,
    enabled: bool,
    epoch: Instant,
    buf: Vec<SpanRecord>,
    stack: Vec<usize>,
}

impl SpanRecorder {
    /// A recorder for `rank`; `enabled == false` makes every call a
    /// no-op that never reads the clock.
    pub fn new(rank: usize, enabled: bool) -> SpanRecorder {
        SpanRecorder::with_epoch(rank, enabled, Instant::now())
    }

    /// A recorder whose timestamps count from a caller-supplied epoch.
    /// The trainer shares ONE epoch across all ranks and incarnations,
    /// so per-rank `start_us` stays monotone in the trace file even
    /// when a shrink re-creates recorders (`trace verify` checks this).
    pub fn with_epoch(rank: usize, enabled: bool, epoch: Instant) -> SpanRecorder {
        SpanRecorder { rank, enabled, epoch, buf: Vec::new(), stack: Vec::new() }
    }

    /// The rank this recorder belongs to.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Whether spans are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Open a span named `name` for iteration `iter`. The currently
    /// open span (if any) becomes its parent.
    pub fn begin(&mut self, name: &'static str, iter: u32) -> SpanToken {
        if !self.enabled {
            return SpanToken(DISABLED);
        }
        let idx = self.buf.len();
        let now = self.epoch.elapsed().as_micros() as u64;
        self.buf.push(SpanRecord {
            name,
            iter,
            start_us: now,
            end_us: now,
            parent: self.stack.last().copied(),
        });
        self.stack.push(idx);
        SpanToken(idx)
    }

    /// Close the span opened by `token`. Must be the innermost open
    /// span.
    pub fn end(&mut self, token: SpanToken) {
        if token.0 == DISABLED {
            return;
        }
        let popped = self.stack.pop();
        debug_assert_eq!(popped, Some(token.0), "spans must close in LIFO order");
        self.buf[token.0].end_us = self.epoch.elapsed().as_micros() as u64;
    }

    /// Take the buffered records (begin order: parents before
    /// children), leaving the recorder empty for the next iteration.
    /// Call with no span open.
    pub fn drain(&mut self) -> Vec<SpanRecord> {
        debug_assert!(self.stack.is_empty(), "drain with a span still open");
        std::mem::take(&mut self.buf)
    }
}

/// Time a block of code as a span on `$rec`: opens `$name` for
/// iteration `$iter`, evaluates `$body`, closes the span, and returns
/// the body's value. Put `?` *outside* the macro so an early return
/// cannot leave the span open:
///
/// ```
/// use fastclip::telemetry::SpanRecorder;
/// let mut rec = SpanRecorder::new(0, true);
/// let sum: u64 = fastclip::span!(rec, "encode", 3, (0..10u64).sum());
/// assert_eq!(sum, 45);
/// assert_eq!(rec.drain().len(), 1);
/// ```
#[macro_export]
macro_rules! span {
    ($rec:expr, $name:expr, $iter:expr, $body:expr) => {{
        let __span_tok = $rec.begin($name, $iter);
        let __span_val = $body;
        $rec.end(__span_tok);
        __span_val
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nesting_and_drain() {
        let mut rec = SpanRecorder::new(2, true);
        let outer = rec.begin("step", 7);
        let inner = rec.begin("reduce", 7);
        rec.end(inner);
        rec.end(outer);
        let spans = rec.drain();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, "step");
        assert_eq!(spans[0].parent, None);
        assert_eq!(spans[1].name, "reduce");
        assert_eq!(spans[1].parent, Some(0));
        assert!(spans[1].start_us >= spans[0].start_us);
        assert!(spans[1].end_us <= spans[0].end_us);
        assert!(spans.iter().all(|s| s.end_us >= s.start_us && s.iter == 7));
        assert!(rec.drain().is_empty(), "drain empties the buffer");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut rec = SpanRecorder::new(0, false);
        let t = rec.begin("encode", 0);
        rec.end(t);
        let v: u32 = crate::span!(rec, "phase_g", 1, 41 + 1);
        assert_eq!(v, 42);
        assert!(rec.drain().is_empty());
        assert!(!rec.enabled());
    }

    #[test]
    fn macro_returns_body_value_and_balances() {
        let mut rec = SpanRecorder::new(1, true);
        let r: Result<u32, ()> = crate::span!(rec, "gather", 5, Ok(9));
        assert_eq!(r, Ok(9));
        let spans = rec.drain();
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].name, spans[0].iter), ("gather", 5));
    }
}
