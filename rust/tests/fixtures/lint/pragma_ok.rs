pub fn parse(s: &str) -> u32 {
    // lint:allow(err-unwrap): fixture exercises suppression
    s.parse().unwrap()
}
