//! Inner-LR (γ) schedule ablation: constant γ vs the cosine schedule on
//! the same algorithm/data — the paper's §5 "Inner LR Schedule" finding
//! (cosine > constant) as a runnable example.
//!
//! Run with: `cargo run --release --example gamma_ablation -- [--steps N]`

use fastclip::config::{Algorithm, GammaSchedule, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::output::{sparkline, Table};
use fastclip::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let steps = args.u32_or("steps", 64)?;

    let schedules: [(&str, GammaSchedule); 4] = [
        ("constant 0.2", GammaSchedule::Constant { gamma: 0.2 }),
        ("constant 0.6", GammaSchedule::Constant { gamma: 0.6 }),
        ("constant 0.9", GammaSchedule::Constant { gamma: 0.9 }),
        ("cosine ->0.2", GammaSchedule::Cosine { gamma_min: 0.2, decay_epochs: 4 }),
    ];

    let mut table = Table::new(
        "gamma schedule ablation (FastCLIP-v1 base, tiny bundle)",
        &["Schedule", "final loss", "Datacomp", "Retrieval", "IN&Var"],
    );
    for (name, gamma) in schedules {
        let mut cfg = TrainConfig::new("artifacts/tiny_k2_b16", Algorithm::FastClipV1);
        cfg.steps = steps;
        cfg.iters_per_epoch = 8;
        cfg.gamma = gamma;
        cfg.data.n_train = 1024;
        cfg.data.n_eval = 128;
        cfg.data.n_classes = 32;
        cfg.lr.total_iters = steps;
        cfg.lr.warmup_iters = steps / 8;
        let r = Trainer::new(cfg)?.run()?;
        let losses: Vec<f32> = r.history.iter().map(|h| h.loss).collect();
        eprintln!("  {name:14} {}", sparkline(&losses, 40));
        table.row(vec![
            name.into(),
            format!("{:.4}", r.tail_loss(8)),
            format!("{:.2}", r.final_eval.datacomp),
            format!("{:.2}", r.final_eval.retrieval),
            format!("{:.2}", r.final_eval.in_variants),
        ]);
    }
    table.print();
    Ok(())
}
