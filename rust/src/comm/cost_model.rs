//! α–β cost model for ring collectives over a two-level (intra-node /
//! inter-node) topology.
//!
//! Profiles approximate the paper's three clusters: InfiniBand (the main
//! testbed for Fig. 3 / Tables 15–16) and two Slingshot clusters
//! (Fig. 11 / Tables 17–22). Absolute numbers are testbed-specific in the
//! paper too; what the model must preserve is the *shape*: communication
//! grows with node count, and OpenCLIP pays an extra O(K·B·d)
//! REDUCE_SCATTER that FastCLIP replaces with an O(K·B) scalar ALL_GATHER.

use anyhow::{bail, Result};

use super::collective::ReduceAlgo;
use crate::config::NetworkProfile;

/// Named α–β interconnect profile approximating one of the paper's
/// clusters (see [`ProfileName::profile`] for the numbers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProfileName {
    /// ~100 Gb/s EDR InfiniBand — the main testbed (Fig. 3).
    InfiniBand,
    /// Slingshot cluster 1 of Appendix E: higher per-message latency.
    Slingshot1,
    /// Slingshot cluster 2: similar bandwidth, lower latency.
    Slingshot2,
}

impl ProfileName {
    /// Every profile, for id round-trips and sweeps.
    pub fn all() -> [ProfileName; 3] {
        [ProfileName::InfiniBand, ProfileName::Slingshot1, ProfileName::Slingshot2]
    }

    /// CLI/config id: `infiniband` | `slingshot1` | `slingshot2`.
    pub fn id(&self) -> &'static str {
        match self {
            ProfileName::InfiniBand => "infiniband",
            ProfileName::Slingshot1 => "slingshot1",
            ProfileName::Slingshot2 => "slingshot2",
        }
    }

    /// Parse a CLI/config id; unknown values are an error listing the
    /// valid choices.
    pub fn from_id(id: &str) -> Result<ProfileName> {
        for p in ProfileName::all() {
            if p.id() == id {
                return Ok(p);
            }
        }
        bail!("unknown network profile '{id}' (expected infiniband|slingshot1|slingshot2)")
    }

    /// The α–β numbers behind the name.
    pub fn profile(&self) -> NetworkProfile {
        match self {
            // ~100 Gb/s EDR InfiniBand, low latency; fast intra-node links.
            ProfileName::InfiniBand => NetworkProfile {
                name: "infiniband",
                inter_alpha: 5e-6,
                inter_beta: 12.5e9,
                intra_alpha: 1.5e-6,
                intra_beta: 60e9,
            },
            // Slingshot cluster 1 of Appendix E: higher per-message latency.
            ProfileName::Slingshot1 => NetworkProfile {
                name: "slingshot1",
                inter_alpha: 18e-6,
                inter_beta: 10e9,
                intra_alpha: 2e-6,
                intra_beta: 50e9,
            },
            // Slingshot cluster 2: similar bandwidth, lower latency.
            ProfileName::Slingshot2 => NetworkProfile {
                name: "slingshot2",
                inter_alpha: 8e-6,
                inter_beta: 11e9,
                intra_alpha: 2e-6,
                intra_beta: 50e9,
            },
        }
    }
}

/// The collective operations the model prices (`CostModel::time`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Collective {
    /// Concatenate per-rank payloads on every rank.
    AllGather,
    /// SUM-reduce, result replicated (ring: RS + AG phases).
    AllReduce,
    /// SUM-reduce, each rank keeps one chunk.
    ReduceScatter,
    /// Copy a root rank's payload to every rank (tree).
    Broadcast,
}

/// Analytic time for ring collectives over `nodes` x `gpus_per_node`.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// the α–β numbers of the modeled fabric
    pub profile: NetworkProfile,
    /// modeled node count (may exceed the thread count, DESIGN.md §1)
    pub nodes: usize,
    /// modeled accelerators per node
    pub gpus_per_node: usize,
}

impl CostModel {
    /// A model over `nodes` x `gpus_per_node` ranks of `profile` fabric.
    pub fn new(profile: NetworkProfile, nodes: usize, gpus_per_node: usize) -> Self {
        Self { profile, nodes, gpus_per_node }
    }

    /// Modeled rank count (`nodes * gpus_per_node`).
    pub fn world_size(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Ring collective over k ranks with per-rank payload `bytes`:
    ///   all_gather / reduce_scatter:  (k-1)·α + (k-1)/k · (k·bytes)/β
    ///   all_reduce:                   2x the above (RS + AG phases)
    /// `bytes` is the payload each rank contributes (gather) or the full
    /// reduced buffer size (all_reduce), matching NCCL conventions.
    fn ring(alpha: f64, beta: f64, k: usize, bytes: f64, phases: f64) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let steps = (k - 1) as f64;
        phases * (steps * alpha + steps / k as f64 * bytes / beta)
    }

    /// Time in seconds for a collective moving `bytes` (see `ring` for the
    /// convention). Hierarchical: an intra-node phase over gpus_per_node
    /// and an inter-node phase over nodes, executed sequentially — the
    /// standard hierarchical-ring decomposition.
    pub fn time(&self, op: Collective, bytes: usize) -> f64 {
        let p = self.profile;
        let b = bytes as f64;
        let phases = match op {
            Collective::AllReduce => 2.0,
            _ => 1.0,
        };
        let intra = Self::ring(p.intra_alpha, p.intra_beta, self.gpus_per_node, b, phases);
        let inter = Self::ring(p.inter_alpha, p.inter_beta, self.nodes, b, phases);
        match op {
            Collective::Broadcast => {
                // tree broadcast: log2(k) hops of the full payload
                let k = self.world_size();
                if k <= 1 {
                    return 0.0;
                }
                let hops = (k as f64).log2().ceil();
                hops * (p.inter_alpha + b / p.inter_beta.min(p.intra_beta))
            }
            _ => intra + inter,
        }
    }

    /// One flat exchange phase over `k` ranks: every rank sends the full
    /// `bytes` payload to each of its `k-1` peers (the naive gather-based
    /// reduce). Latency-optimal (one α per peer, no pipeline startup) but
    /// bandwidth-pessimal for large payloads.
    fn flat_exchange(alpha: f64, beta: f64, k: usize, bytes: f64) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let steps = (k - 1) as f64;
        steps * (alpha + bytes / beta)
    }

    /// α–β time for one gradient reduction of `bytes` with `algo`
    /// (hierarchical: intra-node phase then inter-node phase, like
    /// [`Self::time`]). Used by [`Self::cheapest_reduce`] and the
    /// per-iteration charge in `coordinator::timing`.
    ///
    /// * `Naive`: direct exchange of the full payload with every peer —
    ///   `(g-1)` intra-node peers plus `(n-1)·g` peers on other nodes,
    ///   totalling `K-1` sends of `bytes` each, CONSISTENT with the
    ///   `(K-1)·bytes` per-rank wire accounting of
    ///   `NaiveAllReduce::grad_wire_bytes`.
    /// * `Ring`: ring all-reduce (reduce-scatter + all-gather phases).
    /// * `Sharded`: reduce-scatter of the gradient plus all-gather of the
    ///   updated parameters — the same total volume as `Ring` on the
    ///   wire, but only half of it is gradient traffic, and the optimizer
    ///   update it brackets runs on 1/K of the parameters.
    pub fn reduce_time(&self, algo: ReduceAlgo, bytes: usize) -> f64 {
        let p = self.profile;
        let b = bytes as f64;
        match algo {
            ReduceAlgo::Naive => {
                let (n, g) = (self.nodes, self.gpus_per_node);
                Self::flat_exchange(p.intra_alpha, p.intra_beta, g, b)
                    + ((n - 1) * g) as f64 * (p.inter_alpha + b / p.inter_beta)
            }
            ReduceAlgo::Ring => self.time(Collective::AllReduce, bytes),
            ReduceAlgo::Sharded => {
                self.time(Collective::ReduceScatter, bytes)
                    + self.time(Collective::AllGather, bytes)
            }
        }
    }

    /// The selection policy for [`super::ReduceStrategy::Auto`]: the
    /// algorithm with the lowest modeled [`Self::reduce_time`] for this
    /// payload, preferring `Sharded` on ties (it moves the fewest
    /// gradient bytes and shards optimizer state K-fold, neither of which
    /// the α–β time captures). The crossover is real: small single-node
    /// worlds (few peers, latency-bound) pick the direct naive exchange,
    /// multi-node and bandwidth-bound shapes pick the chunked algorithms.
    pub fn cheapest_reduce(&self, bytes: usize) -> ReduceAlgo {
        let mut best = ReduceAlgo::Sharded;
        let mut best_t = self.reduce_time(best, bytes);
        for algo in [ReduceAlgo::Ring, ReduceAlgo::Naive] {
            let t = self.reduce_time(algo, bytes);
            if t < best_t {
                best = algo;
                best_t = t;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(nodes: usize) -> CostModel {
        CostModel::new(ProfileName::InfiniBand.profile(), nodes, 4)
    }

    #[test]
    fn single_rank_is_free() {
        let m = CostModel::new(ProfileName::InfiniBand.profile(), 1, 1);
        assert_eq!(m.time(Collective::AllReduce, 1 << 20), 0.0);
        assert_eq!(m.time(Collective::AllGather, 1 << 20), 0.0);
    }

    #[test]
    fn cost_grows_with_nodes() {
        for op in [Collective::AllGather, Collective::AllReduce, Collective::ReduceScatter] {
            let t1 = model(1).time(op, 1 << 22);
            let t2 = model(2).time(op, 1 << 22);
            let t8 = model(8).time(op, 1 << 22);
            assert!(t2 > t1, "{op:?}");
            assert!(t8 > t2, "{op:?}");
        }
    }

    #[test]
    fn all_reduce_twice_gather() {
        let m = model(4);
        let ag = m.time(Collective::AllGather, 1 << 24);
        let ar = m.time(Collective::AllReduce, 1 << 24);
        assert!((ar / ag - 2.0).abs() < 1e-9);
    }

    #[test]
    fn cost_monotone_in_bytes() {
        let m = model(4);
        let a = m.time(Collective::AllGather, 1 << 10);
        let b = m.time(Collective::AllGather, 1 << 20);
        assert!(b > a);
    }

    #[test]
    fn scalar_gather_beats_feature_reduce_scatter() {
        // The paper's headline communication claim, in model terms:
        // ALL_GATHER of K·B scalars is much cheaper than REDUCE_SCATTER of
        // K·B·d floats (d = 512).
        let m = model(8);
        let kb = 8 * 4 * 128; // K * B
        let scalar = m.time(Collective::AllGather, kb * 4);
        let feature = m.time(Collective::ReduceScatter, kb * 512 * 4);
        assert!(feature > 10.0 * scalar);
    }

    #[test]
    fn reduce_time_ring_matches_all_reduce() {
        let m = model(4);
        for bytes in [1usize << 10, 1 << 24] {
            let ring = m.reduce_time(ReduceAlgo::Ring, bytes);
            assert_eq!(ring, m.time(Collective::AllReduce, bytes));
            // sharded = RS + AG = same total α–β volume as a ring all-reduce
            let sharded = m.reduce_time(ReduceAlgo::Sharded, bytes);
            assert!((sharded - ring).abs() < 1e-12);
        }
    }

    #[test]
    fn cheapest_reduce_crossover() {
        // big world, big gradient: bandwidth-bound -> chunked (sharded)
        let m = model(8);
        assert_eq!(m.cheapest_reduce(150_000_000 * 4), ReduceAlgo::Sharded);
        // multi-node even for tiny payloads: naive pays (n-1)*g alphas,
        // the chunked algorithms only 2(k-1) ring steps -> sharded
        assert_eq!(m.cheapest_reduce(8), ReduceAlgo::Sharded);
        // tiny payload on one node: latency-bound -> direct naive exchange
        // ((g-1) alphas vs 2(g-1) ring steps)
        let m4 = CostModel::new(ProfileName::InfiniBand.profile(), 1, 4);
        assert_eq!(m4.cheapest_reduce(8), ReduceAlgo::Naive);
        // K=2 world: one direct send always beats two ring steps
        let m2 = CostModel::new(ProfileName::InfiniBand.profile(), 1, 2);
        assert_eq!(m2.cheapest_reduce(150_000_000 * 4), ReduceAlgo::Naive);
        // single rank: everything is free; the tie-break prefers sharded
        let m1 = CostModel::new(ProfileName::InfiniBand.profile(), 1, 1);
        assert_eq!(m1.cheapest_reduce(1 << 20), ReduceAlgo::Sharded);
    }

    #[test]
    fn naive_time_consistent_with_wire_bytes() {
        // the time model and the wire accounting describe the SAME
        // algorithm: K-1 full-payload sends per rank
        let m = model(8); // 8 nodes x 4 gpus -> K-1 = 31 peers
        let b = 1 << 20;
        let t = m.reduce_time(ReduceAlgo::Naive, b);
        let p = ProfileName::InfiniBand.profile();
        let expect = 3.0 * (p.intra_alpha + b as f64 / p.intra_beta)
            + 28.0 * (p.inter_alpha + b as f64 / p.inter_beta);
        assert!((t - expect).abs() < 1e-15, "{t} vs {expect}");
    }

    #[test]
    fn naive_reduce_time_shape() {
        // monotone in bytes and in world size
        let m = model(4);
        assert!(
            m.reduce_time(ReduceAlgo::Naive, 1 << 20) > m.reduce_time(ReduceAlgo::Naive, 1 << 10)
        );
        assert!(
            model(8).reduce_time(ReduceAlgo::Naive, 1 << 20)
                > model(2).reduce_time(ReduceAlgo::Naive, 1 << 20)
        );
        // large payloads: naive pays (k-1)/k more bandwidth than ring
        let naive = m.reduce_time(ReduceAlgo::Naive, 1 << 26);
        let ring = m.reduce_time(ReduceAlgo::Ring, 1 << 26);
        assert!(naive > ring);
    }

    #[test]
    fn profiles_distinct() {
        let a = ProfileName::InfiniBand.profile();
        let b = ProfileName::Slingshot1.profile();
        let c = ProfileName::Slingshot2.profile();
        assert!(a.inter_alpha < b.inter_alpha);
        assert!(c.inter_alpha < b.inter_alpha);
    }
}
