//! Minimal benchmark harness (criterion is not in the vendored crate set):
//! warmup, N timed samples, median/mean/min report. Deterministic sample
//! counts so `cargo bench` output is stable enough to diff between runs.
//!
//! Shared by every bench target via `#[path = "harness.rs"] mod harness;`
//! (not every target uses every helper, hence the allow).
#![allow(dead_code)]

use std::time::Instant;

pub struct Bench {
    name: String,
    samples: usize,
    warmup: usize,
}

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), samples: 30, warmup: 3 }
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(3);
        self
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    /// Time `f` and print a one-line report. Returns the stats so callers
    /// can assert relationships (e.g. scaling behaviour).
    pub fn run<F: FnMut()>(&self, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = Stats {
            mean_s: times.iter().sum::<f64>() / times.len() as f64,
            median_s: times[times.len() / 2],
            min_s: times[0],
            max_s: times[times.len() - 1],
        };
        println!(
            "{:<44} median {:>10}  mean {:>10}  min {:>10}  (n={})",
            self.name,
            fmt(stats.median_s),
            fmt(stats.mean_s),
            fmt(stats.min_s),
            self.samples
        );
        stats
    }
}

pub fn fmt(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Prevent the optimizer from eliding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
