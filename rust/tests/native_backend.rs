//! Native-backend parity suite (DESIGN.md §10).
//!
//! Pins the three guarantees the native CPU backend makes:
//!
//! 1. **Scalar-reference exactness** — every kernel is bitwise equal to
//!    its naive single-threaded scalar reference on the same summation
//!    tree, for odd shapes and non-divisible blockings;
//! 2. **Thread-count determinism** — one step, and a whole training run,
//!    are bitwise identical across 1/2/4 kernel threads;
//! 3. **Gradient correctness** — the hand-derived surrogate gradient
//!    matches a finite-difference oracle of the surrogate value, per
//!    variant.
//! 4. **Overlap determinism** (DESIGN.md §11) — the bucketed async
//!    reduction pipeline (`--overlap on`) trains bitwise-identically to
//!    the serial path for all 5 loss variants × naive|ring|sharded, and
//!    checkpoint/resume stays bitwise-exact under overlap.
//!
//! Everything runs unconditionally: no artifacts, no pjrt feature.

use fastclip::comm::{CommWorld, OverlapMode, ReduceAlgo, ReduceStrategy, WireCodec, WorkerComm};
use fastclip::config::{Algorithm, DataConfig, TrainConfig};
use fastclip::coordinator::Trainer;
use fastclip::kernels::{gemm, norm, softmax, Precision};
use fastclip::runtime::{
    BackendKind, ComputeBackend, FeatGradReduce, LossShard, Manifest, NativeBackend, StepOutput,
    TauGrads, TauInput,
};
use fastclip::util::Rng;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn randn(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 1.0);
    v
}

// -------------------------------------------------------------------------
// 1. kernel ↔ scalar reference exactness, odd shapes, 1/2/4 threads
// -------------------------------------------------------------------------

#[test]
fn kernel_parity_gemm_all_layouts() {
    // shapes chosen to cross the KC=64 block boundary non-divisibly and
    // to leave ragged thread partitions (13 rows over 4 threads)
    for (m, k, n) in [(1usize, 1usize, 1usize), (13, 65, 9), (8, 64, 16), (3, 200, 5)] {
        let a = randn(m * k, 100);
        let b = randn(k * n, 101);
        let bt = randn(n * k, 102);
        let ab = randn(m * n, 103);
        let mut w1 = vec![0.0f32; m * n];
        gemm::matmul_ref(&a, &b, &mut w1, m, k, n);
        let mut w2 = vec![0.0f32; m * n];
        gemm::matmul_bt_ref(&a, &bt, &mut w2, m, k, n);
        let mut w3 = vec![0.0f32; k * n];
        gemm::matmul_at_b_ref(&a, &ab, &mut w3, m, k, n);
        for threads in [1usize, 2, 4] {
            let mut g1 = vec![0.0f32; m * n];
            gemm::matmul(&a, &b, &mut g1, m, k, n, threads);
            assert_eq!(bits(&g1), bits(&w1), "matmul {m}x{k}x{n} t={threads}");
            let mut g2 = vec![0.0f32; m * n];
            gemm::matmul_bt(&a, &bt, &mut g2, m, k, n, threads);
            assert_eq!(bits(&g2), bits(&w2), "matmul_bt {m}x{k}x{n} t={threads}");
            let mut g3 = vec![0.0f32; k * n];
            gemm::matmul_at_b(&a, &ab, &mut g3, m, k, n, threads);
            assert_eq!(bits(&g3), bits(&w3), "matmul_at_b {m}x{k}x{n} t={threads}");
        }
    }
}

#[test]
fn kernel_parity_contrastive_and_normalize() {
    for (m, n, d) in [(7usize, 13usize, 5usize), (8, 16, 64), (1, 3, 2)] {
        let a = randn(m * d, 110);
        let b = randn(n * d, 111);
        let diag: Vec<isize> =
            (0..m).map(|i| if i % 4 == 3 { softmax::NO_DIAG } else { (i % n) as isize }).collect();
        let sd: Vec<f32> = (0..m).map(|i| 0.05 * i as f32).collect();
        let tau: Vec<f32> = (0..m).map(|i| 0.04 + 0.003 * i as f32).collect();
        let gbar: Vec<f32> = (0..m).map(|i| 1.0 - 0.11 * i as f32).collect();
        let denom = (n.max(2) - 1) as f32;
        let gw = softmax::masked_exp_rowsum_ref(&a, &b, &diag, &sd, &tau, denom, m, n, d);
        let (daw, dtw) =
            softmax::masked_exp_rowsum_bwd_row_ref(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d);
        let dbw =
            softmax::masked_exp_rowsum_bwd_col_ref(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d);
        let (yw, nw) = norm::l2_normalize_fwd_ref(&a, m, d);
        let dxw = norm::l2_normalize_bwd_ref(&a, &nw, &b[..m * d], m, d);
        for threads in [1usize, 2, 4] {
            let g = softmax::masked_exp_rowsum(&a, &b, &diag, &sd, &tau, denom, m, n, d, threads);
            assert_eq!(bits(&g), bits(&gw), "fwd t={threads}");
            let (da, dt) = softmax::masked_exp_rowsum_bwd_row(
                &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, threads,
            );
            assert_eq!(bits(&da), bits(&daw), "bwd row t={threads}");
            assert_eq!(bits(&dt), bits(&dtw), "bwd dtau t={threads}");
            let db = softmax::masked_exp_rowsum_bwd_col(
                &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, threads,
            );
            assert_eq!(bits(&db), bits(&dbw), "bwd col t={threads}");
            let (y, norms) = norm::l2_normalize_fwd(&a, m, d, threads);
            assert_eq!(bits(&y), bits(&yw), "normalize t={threads}");
            let dx = norm::l2_normalize_bwd(&a, &norms, &b[..m * d], m, d, threads);
            assert_eq!(bits(&dx), bits(&dxw), "normalize bwd t={threads}");
        }
    }
}

// -------------------------------------------------------------------------
// 2. thread-count determinism of a full step and a full training run
// -------------------------------------------------------------------------

struct StepFixture {
    manifest: Manifest,
    params: Vec<f32>,
    images: Vec<f32>,
    texts: Vec<i32>,
    e1g: Vec<f32>,
    e2g: Vec<f32>,
    u1g: Vec<f32>,
    u2g: Vec<f32>,
    tau1g: Vec<f32>,
    tau2g: Vec<f32>,
}

fn step_fixture() -> StepFixture {
    let manifest = Manifest::native("tiny", 2, 8, 5).unwrap();
    let params = manifest.load_init_params().unwrap();
    let (bl, bg, d) = (manifest.local_batch, manifest.global_batch, manifest.model.d_embed);
    let dims = manifest.model_dims();
    let mut rng = Rng::new(77);
    let mut images = vec![0.0f32; bl * dims.v_patches * dims.v_patch_dim];
    rng.fill_normal(&mut images, 1.0);
    let texts: Vec<i32> =
        (0..bl * dims.t_len).map(|_| rng.below(dims.t_vocab) as i32).collect();
    // gathered features: local embeddings + a perturbed "remote" block
    let mut rt = NativeBackend::new(&manifest, Some("gcl"), 1).unwrap();
    let (e1, e2) = rt.encode(&params, &images, &texts).unwrap();
    let mut remote1 = e1.clone();
    let mut remote2 = e2.clone();
    for v in remote1.iter_mut().chain(remote2.iter_mut()) {
        *v = -*v;
    }
    let e1g = [e1, remote1].concat();
    let e2g = [e2, remote2].concat();
    assert_eq!(e1g.len(), bg * d);
    let u1g: Vec<f32> = (0..bg).map(|i| 0.3 + 0.02 * i as f32).collect();
    let u2g: Vec<f32> = (0..bg).map(|i| 0.9 - 0.03 * i as f32).collect();
    let tau1g: Vec<f32> = (0..bg).map(|i| 0.03 + 0.001 * i as f32).collect();
    let tau2g: Vec<f32> = (0..bg).map(|i| 0.08 - 0.002 * i as f32).collect();
    StepFixture { manifest, params, images, texts, e1g, e2g, u1g, u2g, tau1g, tau2g }
}

fn run_step(f: &StepFixture, variant: &str, threads: usize) -> StepOutput {
    let mut rt = NativeBackend::new(&f.manifest, Some(variant), threads).unwrap();
    let tau = if variant == "rgcl_i" {
        TauInput::Individual { tau1g: &f.tau1g, tau2g: &f.tau2g }
    } else {
        TauInput::Global(0.05)
    };
    rt.step(
        variant, &f.params, &f.images, &f.texts, &f.e1g, &f.e2g, &f.u1g, &f.u2g, 0, 1e-8, 6.5,
        tau, LossShard::Off,
    )
    .unwrap()
}

#[test]
fn native_step_bitwise_identical_across_kernel_threads() {
    let f = step_fixture();
    for variant in ["gcl", "gcl_v0", "rgcl_g", "rgcl_i", "mbcl"] {
        let base = run_step(&f, variant, 1);
        for threads in [2usize, 3, 4] {
            let got = run_step(&f, variant, threads);
            assert_eq!(bits(&got.grad), bits(&base.grad), "{variant} t={threads} grad");
            assert_eq!(got.loss.to_bits(), base.loss.to_bits(), "{variant} t={threads} loss");
            match (&got.tau, &base.tau) {
                (TauGrads::Global(a), TauGrads::Global(b)) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "{variant} t={threads} tau")
                }
                (
                    TauGrads::Individual { tau1: a1, tau2: a2 },
                    TauGrads::Individual { tau1: b1, tau2: b2 },
                ) => {
                    assert_eq!(bits(a1), bits(b1), "{variant} t={threads} tau1");
                    assert_eq!(bits(a2), bits(b2), "{variant} t={threads} tau2");
                }
                _ => panic!("{variant}: tau grad kind changed with threads"),
            }
        }
    }
}

#[test]
fn native_training_run_bitwise_identical_across_kernel_threads() {
    let run = |threads: usize| {
        let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", Algorithm::FastClipV3);
        cfg.backend = BackendKind::Native;
        cfg.kernel_threads = threads;
        cfg.steps = 8;
        cfg.iters_per_epoch = 4;
        cfg.data = DataConfig { n_train: 64, n_eval: 16, n_classes: 8, ..DataConfig::default() };
        cfg.lr.warmup_iters = 2;
        cfg.lr.total_iters = 8;
        Trainer::new(cfg).unwrap().run().unwrap()
    };
    let a = run(1);
    for threads in [2usize, 4] {
        let b = run(threads);
        assert_eq!(bits(&a.final_params), bits(&b.final_params), "params t={threads}");
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "loss t={threads}");
            assert_eq!(x.tau.to_bits(), y.tau.to_bits(), "tau t={threads}");
        }
    }
}

// -------------------------------------------------------------------------
// 3. finite-difference oracle for the hand-derived surrogate gradient
// -------------------------------------------------------------------------

#[test]
fn step_gradient_matches_finite_difference_oracle() {
    let f = step_fixture();
    let d = f.manifest.model.d_embed;
    // probe indices across all four parameter leaves; the token index
    // must belong to a token actually present in the batch
    let tok_used = f.texts[0] as usize;
    let seg = |name: &str| {
        f.manifest.param_spec.iter().find(|s| s.name == name).unwrap().offset
    };
    let probes = vec![
        seg("v.proj") + 3,
        seg("v.proj") + 2 * d + 1,
        seg("v.bias") + 1,
        seg("t.tok") + tok_used * d + 2,
        seg("t.bias") + d - 1,
    ];
    for variant in ["gcl", "gcl_v0", "rgcl_g", "rgcl_i", "mbcl"] {
        let out = run_step(&f, variant, 2);
        let rt = NativeBackend::new(&f.manifest, Some(variant), 1).unwrap();
        let value = |params: &[f32]| -> f64 {
            rt.surrogate_value(
                variant, params, &f.images, &f.texts, &f.e1g, &f.e2g, &f.u1g, &f.u2g,
                &f.tau1g, &f.tau2g, 0, 1e-8,
            )
            .unwrap() as f64
        };
        let h = 2e-2f32;
        for &idx in &probes {
            let mut pp = f.params.clone();
            let mut pm = f.params.clone();
            pp[idx] += h;
            pm[idx] -= h;
            let num = (value(&pp) - value(&pm)) / (2.0 * h as f64);
            let got = out.grad[idx] as f64;
            // f32 forward + O(h²) truncation: a loose band, but tight
            // enough that a dropped term or wrong scale (the failure
            // modes of a hand-derived backward) is far outside it
            assert!(
                (num - got).abs() < 0.1 * num.abs().max(0.05),
                "{variant} grad[{idx}]: finite-diff {num:.6} vs analytic {got:.6}"
            );
        }
    }
}

// -------------------------------------------------------------------------
// 4. overlap determinism: the bucketed async pipeline is bitwise equal to
//    serial training for every variant × reduction algorithm
// -------------------------------------------------------------------------

fn overlap_cfg(algo: Algorithm, reduce: ReduceAlgo, overlap: OverlapMode) -> TrainConfig {
    let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", algo);
    cfg.backend = BackendKind::Native;
    cfg.kernel_threads = 1;
    cfg.steps = 4;
    cfg.iters_per_epoch = 2;
    cfg.data = DataConfig { n_train: 64, n_eval: 16, n_classes: 8, ..DataConfig::default() };
    cfg.lr.warmup_iters = 1;
    cfg.lr.total_iters = 4;
    cfg.reduce = ReduceStrategy::Fixed(reduce);
    cfg.overlap = overlap;
    // ~2 KB buckets split the tiny preset's ~74 KB gradient into ~37
    // buckets, crossing every parameter-leaf boundary
    cfg.bucket_bytes = 2 << 10;
    cfg
}

/// The acceptance matrix of DESIGN.md §11: 5 step variants (one
/// representative algorithm each) × 3 reduction algorithms, `--overlap
/// on` bitwise-equal to `--overlap off` in parameters, losses and τ.
#[test]
fn overlap_bitwise_equals_serial_all_variants_and_reduces() {
    // one algorithm per step variant: mbcl, gcl, gcl_v0, rgcl_i, rgcl_g
    let variants = [
        Algorithm::OpenClip,
        Algorithm::FastClipV1,
        Algorithm::FastClipV0,
        Algorithm::FastClipV2,
        Algorithm::FastClipV3,
    ];
    for algo in variants {
        for reduce in ReduceAlgo::all() {
            let label = format!("{} x {}", algo.id(), reduce.id());
            let serial = Trainer::new(overlap_cfg(algo, reduce, OverlapMode::Off))
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("{label} serial: {e:#}"));
            let piped = Trainer::new(overlap_cfg(algo, reduce, OverlapMode::On))
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("{label} overlap: {e:#}"));
            assert!(piped.overlap && !serial.overlap, "{label}");
            assert!(piped.n_buckets > 1, "{label}: gradient must split into buckets");
            assert_eq!(
                bits(&serial.final_params),
                bits(&piped.final_params),
                "{label}: overlapped params must be bitwise serial"
            );
            for (s, p) in serial.history.iter().zip(&piped.history) {
                assert_eq!(s.loss.to_bits(), p.loss.to_bits(), "{label} step {}", s.step);
                assert_eq!(s.tau.to_bits(), p.tau.to_bits(), "{label} step {}", s.step);
            }
            assert_eq!(serial.final_tau.to_bits(), piped.final_tau.to_bits(), "{label}");
        }
    }
}

/// Checkpoint/resume stays bitwise-exact under `--overlap on`: a
/// snapshotted + resumed overlapped run matches both the uninterrupted
/// overlapped run and the uninterrupted serial run.
#[test]
fn overlap_snapshot_resume_bitwise() {
    let root = std::env::temp_dir().join(format!("fastclip_overlap_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let base = || {
        let mut cfg = overlap_cfg(Algorithm::FastClipV3, ReduceAlgo::Sharded, OverlapMode::On);
        cfg.steps = 8;
        cfg.lr.total_iters = 8;
        cfg.ckpt_dir = Some(root.to_string_lossy().into_owned());
        cfg.ckpt_every = 4;
        cfg
    };
    let continuous = Trainer::new(base()).unwrap().run().unwrap();
    assert!(continuous.overlap);
    assert_eq!(continuous.ckpt.snapshots, 2);

    let mut serial_cfg = base();
    serial_cfg.overlap = OverlapMode::Off;
    serial_cfg.ckpt_dir = None;
    serial_cfg.ckpt_every = 0;
    let serial = Trainer::new(serial_cfg).unwrap().run().unwrap();
    assert_eq!(
        bits(&continuous.final_params),
        bits(&serial.final_params),
        "overlapped training with snapshots equals serial training"
    );

    let mut resumed_cfg = base();
    resumed_cfg.resume = Some(ckpt_step_dir(&root, 4));
    let resumed = Trainer::new(resumed_cfg).unwrap().run().unwrap();
    assert_eq!(resumed.ckpt.resumed_at, Some(4));
    assert_eq!(
        bits(&continuous.final_params),
        bits(&resumed.final_params),
        "resume under overlap is bitwise"
    );

    // overlap is an execution detail, not training state: a snapshot
    // written under overlap resumes bitwise in serial mode too
    let mut cross_cfg = base();
    cross_cfg.overlap = OverlapMode::Off;
    cross_cfg.resume = Some(ckpt_step_dir(&root, 4));
    let cross = Trainer::new(cross_cfg).unwrap().run().unwrap();
    assert!(!cross.overlap);
    assert_eq!(
        bits(&continuous.final_params),
        bits(&cross.final_params),
        "serial resume of an overlapped snapshot is bitwise"
    );
    let _ = std::fs::remove_dir_all(&root);
}

// -------------------------------------------------------------------------
// full loop smoke: encode → phase_g → step → eval → snapshot → resume,
// through the CLI-visible Trainer surface, zero artifacts
// -------------------------------------------------------------------------

// -------------------------------------------------------------------------
// 5. bf16 storage + wire (DESIGN.md §12): thread-count and run-to-run
//    bitwise reproducibility, bitwise agreement across reduction
//    algorithms and serial|overlap, checkpoint-resume exactness (f32
//    masters), a pinned f32-parity tolerance, the end-to-end 2x wire-byte
//    cut, and a finite-difference gradient check under bf16 storage
// -------------------------------------------------------------------------

fn bf16_cfg(algo: Algorithm, steps: u32) -> TrainConfig {
    let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", algo);
    cfg.backend = BackendKind::Native;
    cfg.kernel_threads = 1;
    cfg.steps = steps;
    cfg.iters_per_epoch = 4;
    cfg.data = DataConfig { n_train: 64, n_eval: 16, n_classes: 8, ..DataConfig::default() };
    cfg.lr.warmup_iters = 2;
    cfg.lr.total_iters = steps;
    cfg.precision = Precision::Bf16;
    cfg
}

#[test]
fn bf16_training_bitwise_reproducible_across_thread_counts_and_runs() {
    let run = |threads: usize| {
        let mut cfg = bf16_cfg(Algorithm::FastClipV3, 8);
        cfg.kernel_threads = threads;
        Trainer::new(cfg).unwrap().run().unwrap()
    };
    let a = run(1);
    // run-to-run: quantization is deterministic
    let a2 = run(1);
    assert_eq!(bits(&a.final_params), bits(&a2.final_params), "bf16 run-to-run bitwise");
    for threads in [2usize, 4] {
        let b = run(threads);
        assert_eq!(bits(&a.final_params), bits(&b.final_params), "bf16 params t={threads}");
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "bf16 loss t={threads}");
            assert_eq!(x.tau.to_bits(), y.tau.to_bits(), "bf16 tau t={threads}");
        }
    }
    assert_eq!(a.precision, "bf16");
}

/// All three reduction algorithms agree bitwise under the bf16 wire, the
/// overlap pipeline agrees with serial, and each algorithm moves exactly
/// half its f32 gradient wire bytes — the DESIGN.md §12 acceptance
/// criteria, end-to-end through the real trainer.
#[test]
fn bf16_reduce_algorithms_and_overlap_bitwise_agree_with_half_wire_bytes() {
    let run = |reduce: ReduceAlgo, overlap: OverlapMode, precision: Precision| {
        let mut cfg = bf16_cfg(Algorithm::FastClipV1, 4);
        cfg.reduce = ReduceStrategy::Fixed(reduce);
        cfg.overlap = overlap;
        cfg.precision = precision;
        cfg.bucket_bytes = 2 << 10; // ~37 buckets: crosses every leaf
        Trainer::new(cfg).unwrap().run().unwrap()
    };
    let naive = run(ReduceAlgo::Naive, OverlapMode::Off, Precision::Bf16);
    for reduce in ReduceAlgo::all() {
        let serial = run(reduce, OverlapMode::Off, Precision::Bf16);
        let piped = run(reduce, OverlapMode::On, Precision::Bf16);
        assert_eq!(
            bits(&serial.final_params),
            bits(&naive.final_params),
            "{}: bf16 must stay bitwise-equal to naive",
            reduce.id()
        );
        assert_eq!(
            bits(&piped.final_params),
            bits(&serial.final_params),
            "{}: bf16 overlap must stay bitwise-equal to serial",
            reduce.id()
        );
        assert!(piped.overlap && piped.n_buckets > 1, "{}", reduce.id());
        // the ~2x wire cut is exact: same element count, half the width
        let f32_run = run(reduce, OverlapMode::Off, Precision::F32);
        assert_eq!(
            f32_run.grad_wire_bytes,
            2 * serial.grad_wire_bytes,
            "{}: bf16 gradient wire bytes must be exactly half of f32",
            reduce.id()
        );
        assert!(serial.grad_wire_bytes > 0, "{}", reduce.id());
    }
}

/// bf16 checkpoint/resume is bitwise: the snapshot carries the f32
/// MASTER state (params, moments, u/τ — dtype-tagged f32 blobs), so a
/// resumed bf16 run reproduces the uninterrupted one exactly.
#[test]
fn bf16_snapshot_resume_bitwise() {
    let root = std::env::temp_dir().join(format!("fastclip_bf16_ckpt_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let base = || {
        let mut cfg = bf16_cfg(Algorithm::FastClipV3, 8);
        cfg.reduce = ReduceStrategy::Fixed(ReduceAlgo::Sharded);
        cfg.ckpt_dir = Some(root.to_string_lossy().into_owned());
        cfg.ckpt_every = 4;
        cfg
    };
    let continuous = Trainer::new(base()).unwrap().run().unwrap();
    assert_eq!(continuous.ckpt.snapshots, 2);

    let mut resumed_cfg = base();
    resumed_cfg.resume = Some(ckpt_step_dir(&root, 4));
    let resumed = Trainer::new(resumed_cfg).unwrap().run().unwrap();
    assert_eq!(resumed.ckpt.resumed_at, Some(4));
    assert_eq!(
        bits(&continuous.final_params),
        bits(&resumed.final_params),
        "bf16 resume is bitwise (f32 masters snapshotted)"
    );

    // precision is part of the checkpoint's hyper echo: a bf16 snapshot
    // cannot silently resume under f32 (it would fork the trajectory)
    let mut wrong = base();
    wrong.precision = Precision::F32;
    wrong.resume = Some(ckpt_step_dir(&root, 4));
    let err = Trainer::new(wrong).unwrap().run().unwrap_err();
    assert!(format!("{err:#}").contains("hyper"), "precision drift rejected: {err:#}");
    let _ = std::fs::remove_dir_all(&root);
}

/// bf16-vs-f32 parity, with the STATED tolerance: over an 8-step tiny
/// run, per-step losses agree within 5% relative and the final
/// parameters within 2e-2 relative L2 — bf16 stores 8-bit mantissas at
/// every activation/gradient boundary (relative step ~2^-8 ≈ 0.4% per
/// rounding), so a few percent accumulated drift is the expected regime;
/// an algorithmic divergence (wrong weights, dropped terms) lands orders
/// of magnitude outside it. The runs must NOT be bitwise equal — that
/// would mean the bf16 path silently no-opped.
#[test]
fn bf16_f32_parity_within_documented_tolerance() {
    let run = |precision: Precision| {
        let mut cfg = bf16_cfg(Algorithm::FastClipV3, 8);
        cfg.precision = precision;
        Trainer::new(cfg).unwrap().run().unwrap()
    };
    let f = run(Precision::F32);
    let b = run(Precision::Bf16);
    for (x, y) in f.history.iter().zip(&b.history) {
        let rel = (x.loss - y.loss).abs() / x.loss.abs().max(1e-6);
        assert!(rel < 0.05, "step {}: loss {} vs {} ({rel:.4} rel)", x.step, x.loss, y.loss);
    }
    let mut diff2 = 0.0f64;
    let mut norm2 = 0.0f64;
    for (x, y) in f.final_params.iter().zip(&b.final_params) {
        diff2 += ((x - y) as f64).powi(2);
        norm2 += (*x as f64).powi(2);
    }
    let rel = (diff2 / norm2.max(1e-30)).sqrt();
    assert!(rel < 2e-2, "final params diverged: {rel:.5} relative L2");
    assert_ne!(
        bits(&f.final_params),
        bits(&b.final_params),
        "bf16 must actually round something"
    );
}

/// Finite-difference gradient check under bf16 storage. The oracle is
/// the UNQUANTIZED f32 surrogate (an F32-precision backend), so the
/// tolerance is widened versus the f32 check: 20% relative with a 0.016
/// absolute floor (vs 10% / 0.005) — the analytic gradient is the exact
/// gradient of the bf16-quantized surrogate, which sits a few
/// bf16-roundings (~0.4% per boundary) away from the f32 one, on top of
/// the shared O(h²) truncation. A dropped term or wrong scale still
/// lands far outside the band.
#[test]
fn bf16_step_gradient_matches_f32_finite_difference_oracle() {
    let f = step_fixture();
    let d = f.manifest.model.d_embed;
    let tok_used = f.texts[0] as usize;
    let seg = |name: &str| {
        f.manifest.param_spec.iter().find(|s| s.name == name).unwrap().offset
    };
    let probes = vec![
        seg("v.proj") + 3,
        seg("v.proj") + 2 * d + 1,
        seg("v.bias") + 1,
        seg("t.tok") + tok_used * d + 2,
        seg("t.bias") + d - 1,
    ];
    for variant in ["gcl", "rgcl_g", "mbcl"] {
        let mut bf = NativeBackend::with_precision(&f.manifest, Some(variant), 2, Precision::Bf16)
            .unwrap();
        let out = bf
            .step(
                variant, &f.params, &f.images, &f.texts, &f.e1g, &f.e2g, &f.u1g, &f.u2g, 0,
                1e-8, 6.5, TauInput::Global(0.05), LossShard::Off,
            )
            .unwrap();
        let oracle = NativeBackend::new(&f.manifest, Some(variant), 1).unwrap();
        let value = |params: &[f32]| -> f64 {
            oracle
                .surrogate_value(
                    variant, params, &f.images, &f.texts, &f.e1g, &f.e2g, &f.u1g, &f.u2g,
                    &f.tau1g, &f.tau2g, 0, 1e-8,
                )
                .unwrap() as f64
        };
        let h = 2e-2f32;
        for &idx in &probes {
            let mut pp = f.params.clone();
            let mut pm = f.params.clone();
            pp[idx] += h;
            pm[idx] -= h;
            let num = (value(&pp) - value(&pm)) / (2.0 * h as f64);
            let got = out.grad[idx] as f64;
            assert!(
                (num - got).abs() < 0.2 * num.abs().max(0.08),
                "{variant} bf16 grad[{idx}]: finite-diff {num:.6} vs analytic {got:.6}"
            );
        }
        // the emitted gradient is bf16-representable storage
        use fastclip::kernels::precision::bf16_round;
        assert!(out.grad.iter().all(|&g| g.to_bits() == bf16_round(g).to_bits()));
    }
}

#[test]
fn full_native_loop_with_eval_snapshot_resume() {
    let root = std::env::temp_dir().join(format!("fastclip_native_e2e_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let mut cfg = TrainConfig::new("artifacts/tiny_k2_b8", Algorithm::FastClipV3);
    cfg.backend = BackendKind::Native;
    cfg.steps = 8;
    cfg.iters_per_epoch = 4;
    cfg.data = DataConfig { n_train: 64, n_eval: 16, n_classes: 8, ..DataConfig::default() };
    cfg.lr.warmup_iters = 2;
    cfg.lr.total_iters = 8;
    cfg.eval_every = 3;
    cfg.ckpt_dir = Some(root.to_string_lossy().into_owned());
    cfg.ckpt_every = 4;

    let continuous = Trainer::new(cfg.clone()).unwrap().run().unwrap();
    assert_eq!(continuous.history.len(), 8);
    assert_eq!(continuous.ckpt.snapshots, 2);
    assert!(continuous.evals.len() >= 2, "periodic + final evals recorded");
    assert!(continuous.final_eval.datacomp >= 0.0);

    // resume the latest snapshot (step 8): zero further steps to run is
    // rejected; resume from step 4 by pointing at that snapshot dir
    let mut resumed_cfg = cfg.clone();
    resumed_cfg.resume = Some(ckpt_step_dir(&root, 4));
    let resumed = Trainer::new(resumed_cfg).unwrap().run().unwrap();
    assert_eq!(resumed.ckpt.resumed_at, Some(4));
    assert_eq!(resumed.history.len(), 4);
    assert_eq!(
        bits(&continuous.final_params),
        bits(&resumed.final_params),
        "native resume is bitwise"
    );
    let _ = std::fs::remove_dir_all(&root);
}

fn ckpt_step_dir(root: &std::path::Path, step: u32) -> String {
    root.join(format!("step_{step:08}")).to_string_lossy().into_owned()
}

// -------------------------------------------------------------------------
// 6. memory-sharded loss (--loss-shard, DESIGN.md §16): the equivalence
//    matrix. A sharded step is bitwise-identical to the unsharded one,
//    per rank, for every variant × world size × precision × kernel-thread
//    count, at B_local = 1 edge shards, and against a finite-difference
//    oracle; the kernel's column decomposition needs no divisibility.
// -------------------------------------------------------------------------

/// The real K-rank column exchange over an in-process collective world —
/// what the trainer adapts onto `GradientReduction::reduce_feature_grads`
/// (the leg's codec is pinned to f32 there too).
struct CommExchange<'a> {
    comm: &'a WorkerComm,
}

impl FeatGradReduce for CommExchange<'_> {
    fn exchange(
        &mut self,
        seg_len: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
    ) -> anyhow::Result<Vec<f32>> {
        Ok(self.comm.exchange_block_sums(seg_len, fill, WireCodec::F32)?)
    }
}

/// Per-rank inputs for a K-rank sharded-vs-unsharded comparison: each
/// rank has its own batch; the "gathered" features are each rank's real
/// encode outputs concatenated in rank order (what `all_gather` moves —
/// bitwise, since the f32 wire is the identity and the bf16 wire is
/// lossless on bf16-valued embeddings).
struct ShardFixture {
    manifest: Manifest,
    params: Vec<f32>,
    images: Vec<Vec<f32>>,
    texts: Vec<Vec<i32>>,
    e1g: Vec<f32>,
    e2g: Vec<f32>,
    u1g: Vec<f32>,
    u2g: Vec<f32>,
    tau1g: Vec<f32>,
    tau2g: Vec<f32>,
}

fn shard_fixture(k: usize, bl: usize, precision: Precision) -> ShardFixture {
    let manifest = Manifest::native("tiny", k, bl, 11).unwrap();
    let params = manifest.load_init_params().unwrap();
    let dims = manifest.model_dims();
    let (bg, d) = (manifest.global_batch, manifest.model.d_embed);
    let (mut images, mut texts) = (Vec::new(), Vec::new());
    let (mut e1g, mut e2g) = (Vec::new(), Vec::new());
    for rank in 0..k {
        let mut rng = Rng::new(900 + rank as u64);
        let mut im = vec![0.0f32; bl * dims.v_patches * dims.v_patch_dim];
        rng.fill_normal(&mut im, 1.0);
        let tx: Vec<i32> =
            (0..bl * dims.t_len).map(|_| rng.below(dims.t_vocab) as i32).collect();
        let mut rt =
            NativeBackend::with_precision(&manifest, Some("gcl"), 1, precision).unwrap();
        let (e1, e2) = rt.encode(&params, &im, &tx).unwrap();
        e1g.extend_from_slice(&e1);
        e2g.extend_from_slice(&e2);
        images.push(im);
        texts.push(tx);
    }
    assert_eq!(e1g.len(), bg * d);
    let u1g: Vec<f32> = (0..bg).map(|i| 0.4 + 0.017 * i as f32).collect();
    let u2g: Vec<f32> = (0..bg).map(|i| 1.1 - 0.021 * i as f32).collect();
    let tau1g: Vec<f32> = (0..bg).map(|i| 0.03 + 0.0013 * i as f32).collect();
    let tau2g: Vec<f32> = (0..bg).map(|i| 0.09 - 0.0017 * i as f32).collect();
    ShardFixture { manifest, params, images, texts, e1g, e2g, u1g, u2g, tau1g, tau2g }
}

fn shard_step(
    f: &ShardFixture,
    variant: &str,
    precision: Precision,
    threads: usize,
    rank: usize,
    shard: LossShard<'_>,
) -> StepOutput {
    let bl = f.manifest.local_batch;
    let mut rt =
        NativeBackend::with_precision(&f.manifest, Some(variant), threads, precision).unwrap();
    let tau = if variant == "rgcl_i" {
        TauInput::Individual { tau1g: &f.tau1g, tau2g: &f.tau2g }
    } else {
        TauInput::Global(0.05)
    };
    rt.step(
        variant, &f.params, &f.images[rank], &f.texts[rank], &f.e1g, &f.e2g, &f.u1g, &f.u2g,
        rank * bl, 1e-8, 6.5, tau, shard,
    )
    .unwrap()
}

/// Spawn one thread per rank over a shared collective world and collect
/// the outputs in rank order.
fn run_ranks<T: Send>(
    world: &std::sync::Arc<CommWorld>,
    k: usize,
    f: impl Fn(WorkerComm) -> T + Sync,
) -> Vec<T> {
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..k)
            .map(|rank| {
                let comm = world.handle(rank);
                let f = &f;
                s.spawn(move || f(comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

fn assert_step_bitwise(a: &StepOutput, b: &StepOutput, label: &str) {
    assert_eq!(bits(&a.grad), bits(&b.grad), "{label}: grad");
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{label}: loss");
    match (&a.tau, &b.tau) {
        (TauGrads::Global(x), TauGrads::Global(y)) => {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: tau")
        }
        (
            TauGrads::Individual { tau1: x1, tau2: x2 },
            TauGrads::Individual { tau1: y1, tau2: y2 },
        ) => {
            assert_eq!(bits(x1), bits(y1), "{label}: tau1");
            assert_eq!(bits(x2), bits(y2), "{label}: tau2");
        }
        _ => panic!("{label}: tau grad kind diverged between shard modes"),
    }
}

/// The acceptance matrix of DESIGN.md §16: all 5 step variants ×
/// K ∈ {1, 2, 4} (including B_local = 1 edge shards at K = 4) ×
/// f32/bf16 × 1/4 kernel threads — `--loss-shard on` over the real
/// K-rank exchange is bitwise equal to `off`, per rank.
#[test]
fn loss_shard_on_off_bitwise_equivalence_matrix() {
    for &(k, bl) in &[(1usize, 8usize), (2, 8), (4, 4), (4, 1)] {
        for precision in [Precision::F32, Precision::Bf16] {
            let f = shard_fixture(k, bl, precision);
            for variant in ["gcl", "gcl_v0", "rgcl_g", "rgcl_i", "mbcl"] {
                for threads in [1usize, 4] {
                    let off: Vec<StepOutput> = (0..k)
                        .map(|r| shard_step(&f, variant, precision, threads, r, LossShard::Off))
                        .collect();
                    let world = CommWorld::new(k);
                    let on = run_ranks(&world, k, |comm| {
                        let rank = comm.rank();
                        let mut fx = CommExchange { comm: &comm };
                        shard_step(&f, variant, precision, threads, rank, LossShard::On(&mut fx))
                    });
                    for (r, (a, b)) in off.iter().zip(&on).enumerate() {
                        let label = format!(
                            "{variant} k={k} bl={bl} {} t={threads} rank {r}",
                            precision.id()
                        );
                        assert_step_bitwise(a, b, &label);
                    }
                }
            }
        }
    }
}

/// Finite-difference oracle under sharding: the sharded step's analytic
/// gradient at a NONZERO offset (rank 1 of 2) matches the same
/// surrogate-value oracle the unsharded check uses, for every variant.
#[test]
fn loss_shard_gradient_matches_finite_difference_oracle() {
    let precision = Precision::F32;
    let (k, bl) = (2usize, 8usize);
    let f = shard_fixture(k, bl, precision);
    let d = f.manifest.model.d_embed;
    let rank = 1usize;
    let tok_used = f.texts[rank][0] as usize;
    let seg = |name: &str| {
        f.manifest.param_spec.iter().find(|s| s.name == name).unwrap().offset
    };
    let probes = vec![
        seg("v.proj") + 3,
        seg("v.bias") + 1,
        seg("t.tok") + tok_used * d + 2,
        seg("t.bias") + d - 1,
    ];
    for variant in ["gcl", "gcl_v0", "rgcl_g", "rgcl_i", "mbcl"] {
        let world = CommWorld::new(k);
        let outs = run_ranks(&world, k, |comm| {
            let r = comm.rank();
            let mut fx = CommExchange { comm: &comm };
            shard_step(&f, variant, precision, 2, r, LossShard::On(&mut fx))
        });
        let out = &outs[rank];
        let rt = NativeBackend::new(&f.manifest, Some(variant), 1).unwrap();
        let value = |params: &[f32]| -> f64 {
            rt.surrogate_value(
                variant, params, &f.images[rank], &f.texts[rank], &f.e1g, &f.e2g, &f.u1g,
                &f.u2g, &f.tau1g, &f.tau2g, rank * bl, 1e-8,
            )
            .unwrap() as f64
        };
        let h = 2e-2f32;
        for &idx in &probes {
            let mut pp = f.params.clone();
            let mut pm = f.params.clone();
            pp[idx] += h;
            pm[idx] -= h;
            let num = (value(&pp) - value(&pm)) / (2.0 * h as f64);
            let got = out.grad[idx] as f64;
            assert!(
                (num - got).abs() < 0.1 * num.abs().max(0.05),
                "{variant} sharded grad[{idx}]: finite-diff {num:.6} vs analytic {got:.6}"
            );
        }
    }
}

/// Kernel-level: the column decomposition needs no divisibility. An
/// uneven ascending partition of the 13 global columns (5/4/4) stitches
/// to the full backward bitwise — per-output-element folds are untouched
/// by where the column cuts fall, so B_global % K ≠ 0 is fine at the
/// kernel layer (the trainer's on-mode additionally requires
/// block-aligned offsets for the exchange segments).
#[test]
fn loss_shard_column_partition_needs_no_divisibility() {
    let (m, n, d) = (7usize, 13usize, 5usize);
    let a = randn(m * d, 210);
    let b = randn(n * d, 211);
    let diag: Vec<isize> =
        (0..m).map(|i| if i % 5 == 4 { softmax::NO_DIAG } else { (i % n) as isize }).collect();
    let sd: Vec<f32> = (0..m).map(|i| 0.04 * i as f32).collect();
    let tau: Vec<f32> = (0..m).map(|i| 0.05 + 0.002 * i as f32).collect();
    let gbar: Vec<f32> = (0..m).map(|i| 0.9 - 0.07 * i as f32).collect();
    let denom = (n - 1) as f32;
    let full =
        softmax::masked_exp_rowsum_bwd_col(&a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, 2);
    let mut stitched: Vec<f32> = Vec::with_capacity(n * d);
    for w in [0usize, 5, 9, 13].windows(2) {
        let part = softmax::masked_exp_rowsum_bwd_col_range(
            &a, &b, &diag, &sd, &tau, &gbar, denom, m, n, d, w[0], w[1], 2,
        );
        stitched.extend_from_slice(&part);
    }
    assert_eq!(bits(&stitched), bits(&full), "uneven column cuts stitch bitwise");
}

/// The alignment precondition is enforced, not assumed: a sharded step
/// whose offset is not a multiple of the local batch is rejected with an
/// actionable error (the trainer always passes rank·B_local, but the
/// kernel-level API must not silently mis-segment).
#[test]
fn loss_shard_rejects_misaligned_offsets() {
    let f = shard_fixture(2, 8, Precision::F32);
    let mut rt = NativeBackend::new(&f.manifest, Some("gcl"), 1).unwrap();
    struct NeverCalled;
    impl FeatGradReduce for NeverCalled {
        fn exchange(
            &mut self,
            _seg_len: usize,
            _fill: &mut dyn FnMut(usize, &mut [f32]),
        ) -> anyhow::Result<Vec<f32>> {
            panic!("exchange must not run for a misaligned shard");
        }
    }
    let mut fx = NeverCalled;
    let err = rt
        .step(
            "gcl", &f.params, &f.images[0], &f.texts[0], &f.e1g, &f.e2g, &f.u1g, &f.u2g,
            3, // not a multiple of bl = 8
            1e-8, 6.5, TauInput::Global(0.05), LossShard::On(&mut fx),
        )
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("block-aligned"),
        "actionable alignment error: {err:#}"
    );
}
