pub fn fine() -> u32 {
    // lint:allow(err-unwrap): nothing below actually violates
    41 + 1
}
