//! `exp compress` — the gradient-compression study (DESIGN.md §15).
//!
//! Trains the same short configuration once per wire codec (f32, bf16,
//! int8-blockwise, top-k with error feedback) with the reduction
//! algorithm FIXED to ring, so the only thing that varies across rows is
//! the codec. Reports gradient bytes-on-wire per rank against the final
//! loss and eval scores — the bytes-vs-convergence trade each codec
//! buys — plus the exact byte cut relative to the f32 row.
//!
//! Needs no artifact bundles: runs on the native backend everywhere.

use anyhow::Result;

use crate::comm::{ReduceAlgo, ReduceStrategy, WireCodec};
use crate::config::{Algorithm, TrainConfig};
use crate::coordinator::{TrainResult, Trainer};
use crate::output::Table;
use crate::util::{Args, Json};

use super::common::{progress_logger, results_dir};

/// Run the bytes-vs-convergence sweep and write `results/compress.*`.
pub fn compress(args: &Args) -> Result<()> {
    let log = progress_logger(args)?;
    let algo = Algorithm::from_id(&args.str_or("algo", "fastclip-v3"))?;
    let steps = args.u32_or("steps", 30)?;

    let run = |wire: WireCodec| -> Result<TrainResult> {
        let mut cfg = TrainConfig::new("native", algo);
        cfg.backend = crate::runtime::BackendKind::Native;
        cfg.preset = args.str_or("preset", &cfg.preset);
        cfg.steps = steps;
        cfg.iters_per_epoch = (steps / 4).max(1);
        cfg.data.n_train = args.usize_or("n-train", 128)?;
        cfg.data.n_eval = args.usize_or("n-eval", 64)?;
        cfg.data.n_classes = 8;
        cfg.lr.warmup_iters = (steps / 10).max(1);
        cfg.lr.total_iters = steps;
        // pinned algorithm: `auto` could legitimately pick a different
        // reduction per codec (the encoded widths differ 8x), which
        // would confound the bytes column
        cfg.reduce = ReduceStrategy::Fixed(ReduceAlgo::Ring);
        cfg.wire = Some(wire);
        cfg.trace_out = args.get("trace-out").map(str::to_string);
        Trainer::new(cfg)?.run()
    };

    let mut table = Table::new(
        format!("Gradient wire codecs — bytes vs convergence ({}, {steps} steps)", algo.name()),
        &["Codec", "Wire B/rank", "vs f32", "Final loss", "Loss vs f32", "Datacomp"],
    );
    let mut json_rows = Vec::new();
    let mut f32_row: Option<(u64, f32)> = None; // (bytes, loss) baseline
    for wire in WireCodec::all() {
        let r = run(wire)?;
        let loss = r.tail_loss(4);
        let (fb, fl) = *f32_row.get_or_insert((r.grad_wire_bytes, loss));
        anyhow::ensure!(
            r.history.iter().all(|h| h.loss.is_finite()),
            "{}: training diverged",
            wire.id()
        );
        if wire == WireCodec::Int8 {
            // the §15 acceptance check, live: exactly a 4x cut
            anyhow::ensure!(
                4 * r.grad_wire_bytes == fb,
                "int8 must cut gradient wire bytes exactly 4x ({} vs {fb})",
                r.grad_wire_bytes
            );
        }
        table.row(vec![
            wire.id().into(),
            r.grad_wire_bytes.to_string(),
            format!("{:.2}x", fb as f64 / r.grad_wire_bytes.max(1) as f64),
            format!("{loss:.4}"),
            format!("{:+.4}", loss - fl),
            format!("{:.2}", r.final_eval.datacomp),
        ]);
        json_rows.push(Json::obj(vec![
            ("codec", Json::str(wire.id())),
            ("lossy", Json::Bool(wire.lossy())),
            ("grad_wire_bytes_per_rank", Json::num(r.grad_wire_bytes as f64)),
            ("bytes_vs_f32", Json::num(fb as f64 / r.grad_wire_bytes.max(1) as f64)),
            ("final_loss", Json::num(loss as f64)),
            ("loss_vs_f32", Json::num((loss - fl) as f64)),
            ("datacomp", Json::num(r.final_eval.datacomp as f64)),
            ("retrieval", Json::num(r.final_eval.retrieval as f64)),
        ]));
        log.status(&format!(
            "{:5} done: {:>8} wire B/rank, final loss {loss:.4}",
            wire.id(),
            r.grad_wire_bytes
        ));
    }
    table.print();
    let dir = results_dir(args);
    table.write_csv(&dir.join("compress.csv"))?;
    crate::output::write_result(&dir, "compress", &Json::arr(json_rows))?;
    log.status(&format!("wrote {}/compress.{{csv,json}}", dir.display()));
    Ok(())
}
